"""Build-time training of the tiny models (accuracy & attack experiments).

Trains, on the synthetic datasets from data_gen.py:
  * bert-tiny classifiers/regressors for each GLUE-like task
  * gpt2-tiny language models for each Wikitext-like corpus
  * MPCFormer / SecFormer *substituted* variants, fine-tuned from the exact
    checkpoint (the paper's "w" rows; the "w/o" rows evaluate the exact
    checkpoint under the substituted forward with no retraining)

Weights are exported in the CTWB format the Rust side reads:
  artifacts/weights/<tag>/manifest.json + weights.bin (LE f32, row-major)
plus artifacts/weights/metrics.json recording plaintext/variant quality
(the python-side half of Table 3; the Rust side recomputes the Centaur and
baseline numbers through the actual protocols).

Pure JAX (no optax offline): Adam implemented inline.
"""

import argparse
import json
import os
import struct
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model
from .configs import CONFIGS, ModelConfig

# ---------------------------------------------------------------------
# Adam (manual)
# ---------------------------------------------------------------------


def adam_init(params):
    z = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": z, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    new_m, new_v, new_p = {}, {}, {}
    for k in params:
        m = b1 * state["m"][k] + (1 - b1) * grads[k]
        v = b2 * state["v"][k] + (1 - b2) * grads[k] ** 2
        mhat = m / (1 - b1**t)
        vhat = v / (1 - b2**t)
        new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
        new_m[k], new_v[k] = m, v
    return new_p, {"m": new_m, "v": new_v, "t": t}


# ---------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------


def cls_loss_fn(cfg: ModelConfig, variant: str):
    fwd = jax.vmap(lambda p, x: model.bert_forward(cfg, p, x, variant=variant), in_axes=(None, 0))

    def loss(p, xs, ys):
        logits = fwd(p, xs)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, ys[:, None], axis=-1))

    return fwd, loss


def reg_loss_fn(cfg: ModelConfig, variant: str):
    fwd = jax.vmap(lambda p, x: model.bert_forward(cfg, p, x, variant=variant), in_axes=(None, 0))

    def loss(p, xs, ys):
        pred = fwd(p, xs)[:, 0]
        return jnp.mean((pred - ys) ** 2)

    return fwd, loss


def lm_loss_fn(cfg: ModelConfig, variant: str):
    fwd = jax.vmap(lambda p, x: model.gpt2_forward(cfg, p, x, variant=variant), in_axes=(None, 0))

    def loss(p, xs, pad_id=0):
        logits = fwd(p, xs)[:, :-1, :]
        targets = xs[:, 1:]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        mask = (targets != pad_id).astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    return fwd, loss


# ---------------------------------------------------------------------
# Metrics (match the paper's per-task choices where meaningful)
# ---------------------------------------------------------------------


def accuracy(fwd, p, xs, ys, bs=64):
    hits, n = 0, 0
    for i in range(0, len(xs), bs):
        logits = fwd(p, xs[i : i + bs])
        hits += int(jnp.sum(jnp.argmax(logits, -1) == ys[i : i + bs]))
        n += len(xs[i : i + bs])
    return 100.0 * hits / n


def f1_score(fwd, p, xs, ys, bs=64):
    tp = fp = fn = 0
    for i in range(0, len(xs), bs):
        pred = np.array(jnp.argmax(fwd(p, xs[i : i + bs]), -1))
        y = np.array(ys[i : i + bs])
        tp += int(((pred == 1) & (y == 1)).sum())
        fp += int(((pred == 1) & (y == 0)).sum())
        fn += int(((pred == 0) & (y == 1)).sum())
    prec = tp / max(1, tp + fp)
    rec = tp / max(1, tp + fn)
    return 100.0 * 2 * prec * rec / max(1e-9, prec + rec)


def matthews(fwd, p, xs, ys, bs=64):
    tp = fp = fn = tn = 0
    for i in range(0, len(xs), bs):
        pred = np.array(jnp.argmax(fwd(p, xs[i : i + bs]), -1))
        y = np.array(ys[i : i + bs])
        tp += int(((pred == 1) & (y == 1)).sum())
        fp += int(((pred == 1) & (y == 0)).sum())
        fn += int(((pred == 0) & (y == 1)).sum())
        tn += int(((pred == 0) & (y == 0)).sum())
    denom = np.sqrt(float((tp + fp) * (tp + fn) * (tn + fp) * (tn + fn)))
    return 100.0 * (tp * tn - fp * fn) / max(denom, 1e-9)


def pearson_spearman(fwd, p, xs, ys, bs=64):
    preds = []
    for i in range(0, len(xs), bs):
        preds.append(np.array(fwd(p, xs[i : i + bs])[:, 0]))
    pred = np.concatenate(preds)
    y = np.array(ys)
    pearson = np.corrcoef(pred, y)[0, 1]
    ranks = lambda a: np.argsort(np.argsort(a))
    spearman = np.corrcoef(ranks(pred), ranks(y))[0, 1]
    return 100.0 * (pearson + spearman) / 2.0


TASK_METRIC = {"qnli": accuracy, "cola": matthews, "stsb": pearson_spearman, "mrpc": f1_score, "rte": accuracy}


def perplexity(fwd, p, xs, bs=64, pad_id=0):
    tot, cnt = 0.0, 0.0
    for i in range(0, len(xs), bs):
        x = xs[i : i + bs]
        logits = fwd(p, x)[:, :-1, :]
        targets = x[:, 1:]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        mask = (targets != pad_id).astype(jnp.float32)
        tot += float(jnp.sum(nll * mask))
        cnt += float(jnp.sum(mask))
    return float(np.exp(tot / max(cnt, 1.0)))


# ---------------------------------------------------------------------
# CTWB export (rust/src/model/weights.rs is the reader)
# ---------------------------------------------------------------------


def export_ctwb(params: dict, cfg: ModelConfig, tag: str, out_root: str, extra=None):
    out_dir = os.path.join(out_root, tag)
    os.makedirs(out_dir, exist_ok=True)
    tensors, blob = [], bytearray()
    offset = 0
    for name in sorted(params):
        arr = np.asarray(params[name], dtype=np.float32)
        rows, cols = (1, arr.shape[0]) if arr.ndim == 1 else arr.shape
        tensors.append({"name": name, "rows": int(rows), "cols": int(cols), "offset": offset})
        blob += arr.tobytes()  # little-endian f32 row-major
        offset += arr.size
    manifest = {
        "tag": tag,
        "model": cfg.name,
        "kind": cfg.kind,
        "vocab": cfg.vocab,
        "n_ctx": cfg.n_ctx,
        "d": cfg.d,
        "h": cfg.h,
        "layers": cfg.layers,
        "k": cfg.k,
        "n_classes": cfg.n_classes,
        "tensors": tensors,
    }
    if extra:
        manifest.update(extra)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(out_dir, "weights.bin"), "wb") as f:
        f.write(bytes(blob))


# ---------------------------------------------------------------------
# Training loops
# ---------------------------------------------------------------------


def train(cfg, params, loss, xs, ys, steps, bs, lr, seed, log_tag):
    state = adam_init(params)
    step_fn = jax.jit(
        lambda p, s, x, y: (lambda l, g: (l, *adam_update(p, g, s, lr)))(
            *jax.value_and_grad(loss)(p, x, y)
        )
    )
    rng = np.random.default_rng(seed)
    n = len(xs)
    t0 = time.time()
    for step in range(steps):
        idx = rng.integers(0, n, bs)
        x = xs[idx]
        y = ys[idx] if ys is not None else None
        if ys is None:
            l, params, state = jax.jit(
                lambda p, s, x: (lambda l, g: (l, *adam_update(p, g, s, lr)))(
                    *jax.value_and_grad(lambda pp, xx: loss(pp, xx))(p, x)
                )
            )(params, state, x)
        else:
            l, params, state = step_fn(params, state, x, y)
        if step % max(1, steps // 5) == 0:
            print(f"    [{log_tag}] step {step:4d} loss {float(l):.4f} ({time.time()-t0:.0f}s)")
    return params


def load_task(data_dir, task):
    with open(os.path.join(data_dir, f"task_{task}.json")) as f:
        doc = json.load(f)
    to = lambda split: (
        jnp.array(doc[split]["ids"], jnp.int32),
        jnp.array(doc[split]["labels"], jnp.float32 if doc["type"] == "reg" else jnp.int32),
    )
    return doc, to("train"), to("test")


def run_bert(task, data_dir, out_root, steps, metrics):
    doc, (xtr, ytr), (xte, yte) = load_task(data_dir, task)
    cfg = ModelConfig(**{**CONFIGS["bert-tiny"].__dict__, "n_classes": doc["n_classes"]})
    params = model.init_params(cfg, jax.random.PRNGKey(hash(task) % 2**31))
    mk_loss = reg_loss_fn if doc["type"] == "reg" else cls_loss_fn
    metric = TASK_METRIC[task]

    fwd, loss = mk_loss(cfg, "exact")
    params = train(cfg, params, loss, xtr, ytr, steps, 32, 1e-3, 1, f"bert/{task}")
    score = metric(fwd, params, xte, yte)
    print(f"  bert-tiny {task}: plaintext {score:.1f}")
    export_ctwb(params, cfg, f"bert-tiny-{task}", out_root, {"task": task, "type": doc["type"]})
    metrics.setdefault(task, {})["plaintext"] = score

    # substituted variants: "w/o" = no retraining; "w" = brief fine-tune
    for variant in ["mpcformer", "secformer"]:
        vfwd, vloss = mk_loss(cfg, variant)
        metrics[task][f"{variant}_wo"] = metric(vfwd, params, xte, yte)
        vparams = train(cfg, dict(params), vloss, xtr, ytr, max(steps // 2, 50), 32, 5e-4, 2, f"{variant}/{task}")
        score_v = metric(vfwd, vparams, xte, yte)
        metrics[task][variant] = score_v
        export_ctwb(vparams, cfg, f"bert-tiny-{task}-{variant}", out_root, {"task": task, "variant": variant})
        print(f"  bert-tiny {task}: {variant} w/o {metrics[task][f'{variant}_wo']:.1f} | w {score_v:.1f}")


def run_gpt(corpus, data_dir, out_root, steps, metrics):
    with open(os.path.join(data_dir, f"lm_{corpus}.json")) as f:
        doc = json.load(f)
    xtr = jnp.array(doc["train"], jnp.int32)
    xte = jnp.array(doc["test"], jnp.int32)
    cfg = CONFIGS["gpt2-tiny"]
    params = model.init_params(cfg, jax.random.PRNGKey(hash(corpus) % 2**31))
    fwd, loss = lm_loss_fn(cfg, "exact")
    params = train(cfg, params, lambda p, x, _y: loss(p, x), xtr, jnp.zeros(len(xtr), jnp.int32), steps, 16, 1e-3, 3, f"gpt/{corpus}")
    ppl = perplexity(fwd, params, xte)
    print(f"  gpt2-tiny {corpus}: plaintext ppl {ppl:.1f}")
    export_ctwb(params, cfg, f"gpt2-tiny-{corpus}", out_root, {"corpus": corpus})
    metrics.setdefault(corpus, {})["plaintext_ppl"] = ppl

    for variant in ["mpcformer", "secformer"]:
        vfwd, vloss = lm_loss_fn(cfg, variant)
        metrics[corpus][f"{variant}_wo_ppl"] = perplexity(vfwd, params, xte)
        vparams = train(
            cfg, dict(params), lambda p, x, _y: vloss(p, x), xtr, jnp.zeros(len(xtr), jnp.int32),
            max(steps // 2, 50), 16, 5e-4, 4, f"{variant}/{corpus}"
        )
        ppl_v = perplexity(vfwd, vparams, xte)
        metrics[corpus][f"{variant}_ppl"] = ppl_v
        export_ctwb(vparams, cfg, f"gpt2-tiny-{corpus}-{variant}", out_root, {"corpus": corpus, "variant": variant})
        print(f"  gpt2-tiny {corpus}: {variant} w/o {metrics[corpus][f'{variant}_wo_ppl']:.1f} | w {ppl_v:.1f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/weights")
    ap.add_argument("--data", default="../artifacts/data")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--tasks", default="qnli,cola,stsb,mrpc,rte")
    ap.add_argument("--corpora", default="wikitext2,wikitext103")
    args = ap.parse_args()
    out_root = os.path.abspath(args.out)
    data_dir = os.path.abspath(args.data)
    os.makedirs(out_root, exist_ok=True)

    metrics = {}
    for task in [t for t in args.tasks.split(",") if t]:
        run_bert(task, data_dir, out_root, args.steps, metrics)
    for corpus in [c for c in args.corpora.split(",") if c]:
        run_gpt(corpus, data_dir, out_root, args.steps, metrics)
    with open(os.path.join(out_root, "metrics.json"), "w") as f:
        json.dump(metrics, f, indent=2)
    print("metrics:", json.dumps(metrics, indent=2))


if __name__ == "__main__":
    main()
