"""L2: JAX Transformer forward passes (paper §2.1), composed from the L1
Pallas kernels, plus the per-op entry points that aot.py lowers to HLO.

Two execution modes:

* ``use_pallas=True`` — every op routes through ``kernels/`` (the AOT path;
  what the Rust runtime executes).
* ``use_pallas=False`` — pure jnp via ``kernels/ref.py`` (fast path for
  build-time training and the pytest oracle).

Python only ever runs at build time; the request path loads the lowered
artifacts through PJRT.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import gelu as gelu_k
from .kernels import layernorm as ln_k
from .kernels import matmul as mm_k
from .kernels import ref
from .kernels import softmax as sm_k

LN_EPS = 1e-5


# ---------------------------------------------------------------------
# Parameter initialization (flat dict of named arrays; names are the
# cross-language weight contract — rust/src/model/weights.rs reads them).
# ---------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> dict:
    p = {}
    std = 0.02

    def nrm(key, shape):
        return jax.random.normal(key, shape, jnp.float32) * std

    keys = iter(jax.random.split(key, 8 + 16 * cfg.layers))
    p["emb.word"] = nrm(next(keys), (cfg.vocab, cfg.d))
    p["emb.pos"] = nrm(next(keys), (cfg.n_ctx, cfg.d))
    p["emb.ln.gamma"] = jnp.ones(cfg.d, jnp.float32)
    p["emb.ln.beta"] = jnp.zeros(cfg.d, jnp.float32)
    for i in range(cfg.layers):
        pre = f"layer{i}."
        for nm in ["wq", "wk", "wv", "wo"]:
            p[pre + "attn." + nm] = nrm(next(keys), (cfg.d, cfg.d))
        for nm in ["bq", "bk", "bv", "bo"]:
            p[pre + "attn." + nm] = jnp.zeros(cfg.d, jnp.float32)
        p[pre + "ln1.gamma"] = jnp.ones(cfg.d, jnp.float32)
        p[pre + "ln1.beta"] = jnp.zeros(cfg.d, jnp.float32)
        p[pre + "ffn.w1"] = nrm(next(keys), (cfg.k, cfg.d))
        p[pre + "ffn.b1"] = jnp.zeros(cfg.k, jnp.float32)
        p[pre + "ffn.w2"] = nrm(next(keys), (cfg.d, cfg.k))
        p[pre + "ffn.b2"] = jnp.zeros(cfg.d, jnp.float32)
        p[pre + "ln2.gamma"] = jnp.ones(cfg.d, jnp.float32)
        p[pre + "ln2.beta"] = jnp.zeros(cfg.d, jnp.float32)
    if cfg.kind == "bert":
        p["pooler.w"] = nrm(next(keys), (cfg.d, cfg.d))
        p["pooler.b"] = jnp.zeros(cfg.d, jnp.float32)
        p["cls.w"] = nrm(next(keys), (cfg.n_classes, cfg.d))
        p["cls.b"] = jnp.zeros(cfg.n_classes, jnp.float32)
    else:
        p["final_ln.gamma"] = jnp.ones(cfg.d, jnp.float32)
        p["final_ln.beta"] = jnp.zeros(cfg.d, jnp.float32)
    return p


# ---------------------------------------------------------------------
# Op dispatch (ref vs pallas)
# ---------------------------------------------------------------------


def softmax_2quad(x, c=5.0):
    """MPCFormer's 2Quad substitute (paper Eq. 8).

    Masked positions (additive -1e9 in the scores) get exactly zero weight —
    the multiplicative-mask semantics the SMPC engine implements.
    """
    s = jnp.where(x > -1e8, (x + c) ** 2, 0.0)
    return s / jnp.sum(s, axis=-1, keepdims=True)


def gelu_quad(x):
    """MPCFormer's Quad GeLU substitute: 0.125x^2 + 0.25x + 0.5."""
    return 0.125 * x * x + 0.25 * x + 0.5


# variant -> (softmax, gelu) substitutions; "exact" is the unmodified model.
VARIANTS = {
    "exact": (None, None),
    "mpcformer": (softmax_2quad, gelu_quad),
    "secformer": (softmax_2quad, None),
}


def _ops(use_pallas: bool, variant: str = "exact"):
    if use_pallas:
        ops = dict(
            linear=mm_k.linear,
            softmax=sm_k.softmax_rows,
            gelu=gelu_k.gelu,
            tanh=gelu_k.tanh,
            layernorm=lambda x, g, b: ln_k.layernorm_rows(x, g, b, eps=LN_EPS),
        )
    else:
        ops = dict(
            linear=ref.linear,
            softmax=ref.softmax_rows,
            gelu=ref.gelu,
            tanh=ref.tanh_rows,
            layernorm=lambda x, g, b: ref.layernorm_rows(x, g, b, eps=LN_EPS),
        )
    sm, gl = VARIANTS[variant]
    if sm is not None:
        ops["softmax"] = sm
    if gl is not None:
        ops["gelu"] = gl
    return ops


# ---------------------------------------------------------------------
# Forward passes (single sequence (n,) -> logits). vmap for batches.
# ---------------------------------------------------------------------


def embed(cfg: ModelConfig, p: dict, ids, ops) -> jnp.ndarray:
    """Embedding layer: lookup + positional + LayerNorm (paper §2.1)."""
    x = p["emb.word"][ids] + p["emb.pos"][: ids.shape[0]]
    return ops["layernorm"](x, p["emb.ln.gamma"], p["emb.ln.beta"])


def attention(cfg: ModelConfig, lp: dict, x, mask, ops) -> jnp.ndarray:
    """Multi-head attention (paper Eq. 2); column-block head slicing to
    match the Rust protocol implementation exactly."""
    n = x.shape[0]
    q = ops["linear"](x, lp["attn.wq"], lp["attn.bq"])
    k = ops["linear"](x, lp["attn.wk"], lp["attn.bk"])
    v = ops["linear"](x, lp["attn.wv"], lp["attn.bv"])
    dh = cfg.dh
    heads = []
    for hh in range(cfg.h):
        sl = slice(hh * dh, (hh + 1) * dh)
        scores = q[:, sl] @ k[:, sl].T / jnp.sqrt(jnp.float32(dh)) + mask
        probs = ops["softmax"](scores)
        heads.append(probs @ v[:, sl])
    o = jnp.concatenate(heads, axis=1)
    return ops["linear"](o, lp["attn.wo"], lp["attn.bo"])


def transformer_layer(cfg: ModelConfig, p: dict, i: int, x, mask, ops):
    lp = {k.removeprefix(f"layer{i}."): v for k, v in p.items() if k.startswith(f"layer{i}.")}
    o4 = attention(cfg, lp, x, mask, ops)
    l1 = ops["layernorm"](o4 + x, lp["ln1.gamma"], lp["ln1.beta"])
    o5 = ops["linear"](l1, lp["ffn.w1"], lp["ffn.b1"])
    g = ops["gelu"](o5)
    o6 = ops["linear"](g, lp["ffn.w2"], lp["ffn.b2"])
    return ops["layernorm"](o6 + l1, lp["ln2.gamma"], lp["ln2.beta"])


def causal_mask(n: int) -> jnp.ndarray:
    return jnp.where(jnp.tril(jnp.ones((n, n), bool)), 0.0, -1e9).astype(jnp.float32)


def backbone(cfg: ModelConfig, p: dict, ids, *, use_pallas=False, variant="exact"):
    """Embedding + all transformer layers -> hidden states (n, d)."""
    ops = _ops(use_pallas, variant)
    n = ids.shape[0]
    mask = causal_mask(n) if cfg.kind == "gpt2" else jnp.zeros((n, n), jnp.float32)
    x = embed(cfg, p, ids, ops)
    for i in range(cfg.layers):
        x = transformer_layer(cfg, p, i, x, mask, ops)
    return x


def bert_forward(cfg: ModelConfig, p: dict, ids, *, use_pallas=False, variant="exact"):
    """BERT adaptation (paper §2.1): pooler(tanh) on [CLS] + classifier."""
    ops = _ops(use_pallas, variant)
    hidden = backbone(cfg, p, ids, use_pallas=use_pallas, variant=variant)
    cls = hidden[0:1, :]
    pooled = ops["tanh"](ops["linear"](cls, p["pooler.w"], p["pooler.b"]))
    return ops["linear"](pooled, p["cls.w"], p["cls.b"])[0]


def gpt2_forward(cfg: ModelConfig, p: dict, ids, *, use_pallas=False, variant="exact"):
    """GPT-2 adaptation: final LayerNorm + tied lm head -> (n, vocab) logits."""
    ops = _ops(use_pallas, variant)
    hidden = backbone(cfg, p, ids, use_pallas=use_pallas, variant=variant)
    hidden = ops["layernorm"](hidden, p["final_ln.gamma"], p["final_ln.beta"])
    return hidden @ p["emb.word"].T


def forward(cfg: ModelConfig, p: dict, ids, *, use_pallas=False, variant="exact"):
    if cfg.kind == "bert":
        return bert_forward(cfg, p, ids, use_pallas=use_pallas, variant=variant)
    return gpt2_forward(cfg, p, ids, use_pallas=use_pallas, variant=variant)


# ---------------------------------------------------------------------
# Per-op entry points for AOT lowering (the artifacts the Rust runtime
# executes at P1's plaintext steps). Shapes are fixed per model config by
# aot.py; all use the Pallas kernels.
# ---------------------------------------------------------------------


def op_softmax(x):
    return (sm_k.softmax_rows(x),)


def op_gelu(x):
    return (gelu_k.gelu(x),)


def op_tanh(x):
    return (gelu_k.tanh(x),)


def op_layernorm(x, gamma, beta):
    return (ln_k.layernorm_rows(x, gamma, beta, eps=LN_EPS),)


def op_linear(x, w, b):
    return (mm_k.linear(x, w, b),)


def op_ring_matmul(a, b):
    from .kernels import ring_matmul as rm_k

    return (rm_k.ring_matmul(a, b),)
