"""Model configurations (mirrors rust/src/model/config.rs — keep in sync).

Real paper dimensions for the efficiency experiments (BERT/GPT-2 base &
large, Appendix D) plus tiny trained variants for the accuracy and attack
experiments (DESIGN.md substitution table).
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    kind: str  # "bert" | "gpt2"
    vocab: int
    n_ctx: int  # sequence length used for AOT shapes / experiments
    d: int  # feature dim
    h: int  # attention heads
    layers: int
    k: int  # FFN intermediate dim
    n_classes: int = 2  # bert adaptation output

    @property
    def dh(self) -> int:
        return self.d // self.h


CONFIGS = {
    # trained-from-scratch tiny models (synthetic tasks)
    "bert-tiny": ModelConfig("bert-tiny", "bert", 512, 32, 64, 2, 2, 256),
    "gpt2-tiny": ModelConfig("gpt2-tiny", "gpt2", 512, 32, 64, 2, 2, 256),
    # paper-scale shapes (random weights; efficiency experiments only)
    "bert-base": ModelConfig("bert-base", "bert", 30522, 128, 768, 12, 12, 3072),
    "bert-large": ModelConfig("bert-large", "bert", 30522, 128, 1024, 16, 24, 4096),
    "gpt2-base": ModelConfig("gpt2-base", "gpt2", 50257, 128, 768, 12, 12, 3072),
    "gpt2-large": ModelConfig("gpt2-large", "gpt2", 50257, 128, 1280, 20, 36, 5120),
}
