"""AOT compiler: lower the L2/L1 entry points to HLO **text** artifacts.

Interchange format is HLO text, NOT serialized HloModuleProto — jax >= 0.5
emits protos with 64-bit instruction ids which the runtime's xla_extension
(0.5.1) rejects; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out ../artifacts [--models bert-tiny,gpt2-tiny,...]

Outputs, per model config:
    artifacts/<model>/{softmax,gelu,layernorm,tanh}_RxC.hlo.txt
    artifacts/<model>/manifest.json
plus the ring-matmul ablation kernels under artifacts/ring/ and a global
artifacts/manifest.json index.
"""

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .configs import CONFIGS  # noqa: E402

DEFAULT_MODELS = ["bert-tiny", "gpt2-tiny", "bert-base", "bert-large", "gpt2-base", "gpt2-large"]

# Ring matmul ablation shapes: tiny-model protocol shapes + one bench shape.
RING_SHAPES = [(32, 64, 64), (32, 64, 256), (32, 256, 64), (128, 768, 768)]


def to_hlo_text(fn, *arg_specs) -> str:
    lowered = jax.jit(fn).lower(*arg_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def s64(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int64)


def emit(out_dir, name, text):
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    return path


def model_entries(cfg):
    """The plaintext ops P1 executes in Centaur, at this config's shapes."""
    n, d, k, h = cfg.n_ctx, cfg.d, cfg.k, cfg.h
    entries = [
        # op name, fn, arg specs, shape label
        ("softmax", model.op_softmax, [f32(h * n, n)], (h * n, n)),
        ("gelu", model.op_gelu, [f32(n, k)], (n, k)),
        ("layernorm", model.op_layernorm, [f32(n, d), f32(d), f32(d)], (n, d)),
    ]
    if cfg.kind == "bert":
        entries.append(("tanh", model.op_tanh, [f32(1, d)], (1, d)))
    return entries


def build_model_artifacts(cfg, root):
    out_dir = os.path.join(root, cfg.name)
    os.makedirs(out_dir, exist_ok=True)
    ops = []
    for op, fn, specs, shape in model_entries(cfg):
        name = f"{op}_{shape[0]}x{shape[1]}"
        emit(out_dir, name, to_hlo_text(fn, *specs))
        ops.append(
            {
                "op": op,
                "rows": shape[0],
                "cols": shape[1],
                "file": f"{name}.hlo.txt",
                "args": [list(s.shape) for s in specs],
            }
        )
        print(f"  {cfg.name}/{name}")
    manifest = {
        "model": cfg.name,
        "kind": cfg.kind,
        "d": cfg.d,
        "h": cfg.h,
        "layers": cfg.layers,
        "k": cfg.k,
        "n_ctx": cfg.n_ctx,
        "vocab": cfg.vocab,
        "ops": ops,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def build_ring_artifacts(root):
    out_dir = os.path.join(root, "ring")
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for m, k, n in RING_SHAPES:
        name = f"ring_matmul_{m}x{k}x{n}"
        emit(out_dir, name, to_hlo_text(model.op_ring_matmul, s64(m, k), s64(k, n)))
        entries.append({"m": m, "k": k, "n": n, "file": f"{name}.hlo.txt"})
        print(f"  ring/{name}")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump({"shapes": entries}, f, indent=2)
    return entries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(DEFAULT_MODELS))
    args = ap.parse_args()
    root = os.path.abspath(args.out)
    os.makedirs(root, exist_ok=True)
    models = [m for m in args.models.split(",") if m]
    index = {"models": [], "ring": None}
    for name in models:
        cfg = CONFIGS[name]
        print(f"lowering {name} ...")
        build_model_artifacts(cfg, root)
        index["models"].append(name)
    print("lowering ring matmul ablation kernels ...")
    index["ring"] = build_ring_artifacts(root)
    with open(os.path.join(root, "manifest.json"), "w") as f:
        json.dump(index, f, indent=2)
    print(f"artifacts written to {root}")


if __name__ == "__main__":
    main()
