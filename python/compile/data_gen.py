"""Synthetic dataset generator (build-time; DESIGN.md substitution table).

Offline we cannot download GLUE or Wikitext, so we generate deterministic
synthetic equivalents over a ~500-word vocabulary with entity-rich
templates (dates, cities, names — the content the paper's Fig. 4 DRA
examples recover). Every dataset is written to artifacts/data/ as JSON that
the Rust side loads; the vocabulary is the cross-language contract.

Tasks (GLUE-like):
  qnli  — does the second segment mention the first segment's city?  (cls)
  cola  — is the sentence un-scrambled?                               (cls)
  stsb  — content-word overlap score in [0, 5]                        (reg)
  mrpc  — is the second sentence a synonym-paraphrase of the first?   (cls)
  rte   — is the hypothesis one of the premise's facts?               (cls)
LM corpora: wikitext2 (small) and wikitext103 (larger), plus an
out-of-distribution auxiliary corpus (cnn-dailymail stand-in) for attacks.
"""

import argparse
import json
import os
import random

PAD, CLS, SEP, UNK = 0, 1, 2, 3
SEQ_LEN = 32

MONTHS = "january february march april may june july august september october november december".split()
DAYS = [str(i) for i in range(1, 29)]
YEARS = [str(y) for y in range(1850, 1900)]
CITIES = (
    "london paris calafat vienna berlin moscow madrid rome lisbon dublin athens cairo "
    "oslo bern kyiv sofia prague warsaw belgrade bucharest amsterdam brussels geneva turin"
).split()
NAMES = (
    "omar anna boris clara dmitri elena felix greta henry irene ivan jonas karl lena "
    "marta nikolai olga pavel quentin rosa stefan tanya viktor wilhelm"
).split()
NOUNS = (
    "forces village church palace abbey settlement garden tower site river bridge army "
    "fortress harbor market cathedral museum castle станция railway treaty battle fleet "
    "regiment council parliament university library monastery province border station"
).split()
NOUNS = [n for n in NOUNS if n.isascii()]
VERBS = (
    "moved engaged contains attacked defended crossed reached entered captured signed "
    "declared visited rebuilt established described approached surrounded occupied held left"
).split()
ADJS = (
    "small large old historic famous northern southern eastern western ancient royal "
    "imperial ottoman russian british french grand minor outer inner"
).split()
FILLER = (
    "the a an of at on in against and or near by nine miles north south between world "
    "heritage sites comprising including four five six seven eight ten day year month "
    "which was were is are it its their from to with during after before that this single token"
).split()


def build_vocab():
    words = ["[PAD]", "[CLS]", "[SEP]", "[UNK]"]
    for group in (MONTHS, DAYS, YEARS, CITIES, NAMES, NOUNS, VERBS, ADJS, FILLER):
        for w in group:
            if w not in words:
                words.append(w)
    return words


VOCAB = build_vocab()
W2I = {w: i for i, w in enumerate(VOCAB)}

SYNONYMS = {
    "small": "minor",
    "large": "grand",
    "old": "ancient",
    "moved": "approached",
    "attacked": "engaged",
    "village": "settlement",
    "famous": "historic",
    "captured": "occupied",
    "defended": "held",
}


def ids(tokens):
    return [W2I.get(t, UNK) for t in tokens]


def sent_battle(rng):
    return (
        f"on {rng.choice(DAYS)} {rng.choice(MONTHS)} {rng.choice(YEARS)} the "
        f"{rng.choice(ADJS)} {rng.choice(NOUNS)} at {rng.choice(CITIES)} "
        f"{rng.choice(VERBS)} the {rng.choice(NOUNS)} at {rng.choice(CITIES)}"
    ).split()


def sent_heritage(rng):
    return (
        f"{rng.choice(CITIES)} contains {rng.choice(['four', 'five', 'six'])} world heritage "
        f"sites including the {rng.choice(ADJS)} {rng.choice(NOUNS)} of {rng.choice(CITIES)} "
        f"and the {rng.choice(ADJS)} {rng.choice(NOUNS)}"
    ).split()


def sent_person(rng):
    return (
        f"{rng.choice(NAMES)} {rng.choice(VERBS)} the {rng.choice(ADJS)} {rng.choice(NOUNS)} "
        f"near {rng.choice(CITIES)} in {rng.choice(MONTHS)} {rng.choice(YEARS)}"
    ).split()


SENT_KINDS = [sent_battle, sent_heritage, sent_person]


def sentence(rng):
    return rng.choice(SENT_KINDS)(rng)


def news_sentence(rng):
    """Aux-corpus (cnn-dailymail stand-in): different template family."""
    return (
        f"the {rng.choice(NOUNS)} council of {rng.choice(CITIES)} declared during "
        f"{rng.choice(MONTHS)} that {rng.choice(NAMES)} {rng.choice(VERBS)} the "
        f"{rng.choice(ADJS)} {rng.choice(NOUNS)} between {rng.choice(CITIES)} and {rng.choice(CITIES)}"
    ).split()


def cities_in(toks):
    return [t for t in toks if t in CITIES]


def pad_pair(a, b):
    x = [CLS] + ids(a) + [SEP] + ids(b) + [SEP]
    return (x + [PAD] * SEQ_LEN)[:SEQ_LEN]


def pad_single(a):
    x = [CLS] + ids(a) + [SEP]
    return (x + [PAD] * SEQ_LEN)[:SEQ_LEN]


def gen_qnli(rng, n):
    """Label 1 iff s2 mentions a city from s1.

    Both segments are short person-sentences so the overlap entity always
    fits inside SEQ_LEN (longer templates would truncate the evidence).
    """
    xs, ys = [], []
    for _ in range(n):
        s1 = sent_person(rng)
        s2 = sent_person(rng)
        label = rng.randint(0, 1)
        c1 = cities_in(s1)[0]
        c2_pos = next(i for i, t in enumerate(s2) if t in CITIES)
        if label:
            s2[c2_pos] = c1  # force entity overlap
        elif s2[c2_pos] == c1:
            s2[c2_pos] = rng.choice([c for c in CITIES if c != c1])
        xs.append(pad_pair(s1, s2))
        ys.append(label)
    return xs, ys


def gen_cola(rng, n):
    """Label 1 for intact template sentences; 0 for locally scrambled."""
    xs, ys = [], []
    for _ in range(n):
        s = sentence(rng)
        label = rng.randint(0, 1)
        if not label:
            s = s[:]
            for _ in range(3):
                i, j = rng.randrange(len(s)), rng.randrange(len(s))
                s[i], s[j] = s[j], s[i]
        xs.append(pad_single(s))
        ys.append(label)
    return xs, ys


def gen_stsb(rng, n):
    """Score = 5 * (shared content-word fraction)."""
    xs, ys = [], []
    content = set(CITIES) | set(NAMES) | set(NOUNS) | set(VERBS) | set(ADJS)
    for _ in range(n):
        s1 = sentence(rng)
        keep = rng.random()
        s2 = []
        for t in s1:
            if t in content and rng.random() > keep:
                s2.append(rng.choice(sorted(content)))
            else:
                s2.append(t)
        c1 = [t for t in s1 if t in content]
        shared = sum(1 for a, b in zip(s1, s2) if a == b and a in content)
        score = 5.0 * shared / max(1, len(c1))
        xs.append(pad_pair(s1, s2))
        ys.append(round(score, 3))
    return xs, ys


def gen_mrpc(rng, n):
    """Label 1 for synonym-substituted paraphrases."""
    xs, ys = [], []
    for _ in range(n):
        s1 = sentence(rng)
        label = rng.randint(0, 1)
        if label:
            s2 = [SYNONYMS.get(t, t) for t in s1]
        else:
            s2 = sentence(rng)
            if cities_in(s1):
                # share an entity so the negative is non-trivial
                c = cities_in(s1)[0]
                s2 = s2 + ["near", c]
        xs.append(pad_pair(s1, s2))
        ys.append(label)
    return xs, ys


def fact(rng):
    """Short fact for RTE (fits two facts + hypothesis in SEQ_LEN)."""
    return f"{rng.choice(NAMES)} {rng.choice(VERBS)} the {rng.choice(NOUNS)} near {rng.choice(CITIES)}".split()


def gen_rte(rng, n):
    """Premise = two facts; hypothesis entailed iff it is one of them."""
    xs, ys = [], []
    for _ in range(n):
        f1, f2 = fact(rng), fact(rng)
        premise = f1 + ["and"] + f2
        label = rng.randint(0, 1)
        if label:
            hyp = rng.choice([f1, f2])
        elif rng.random() < 0.5:
            # hard negative: recombine f1's actor with f2's tail (binding)
            hyp = f1[:2] + f2[2:]
            if hyp == f1 or hyp == f2:
                hyp = fact(rng)
        else:
            hyp = fact(rng)
        xs.append(pad_pair(premise, hyp))
        ys.append(label)
    return xs, ys


TASKS = {
    "qnli": (gen_qnli, "cls", 2),
    "cola": (gen_cola, "cls", 2),
    "stsb": (gen_stsb, "reg", 1),
    "mrpc": (gen_mrpc, "cls", 2),
    "rte": (gen_rte, "cls", 2),
}

# train/test sizes roughly proportional to GLUE's relative scales
TASK_SIZES = {"qnli": (4000, 600), "cola": (2000, 400), "stsb": (1500, 300), "mrpc": (1200, 300), "rte": (1000, 250)}


def gen_lm_corpus(rng, n_sents):
    seqs = []
    for _ in range(n_sents):
        toks = []
        while len(toks) < SEQ_LEN - 1:
            toks += sentence(rng) + [W2I["and"] if rng.random() < 0.3 else SEP]
        seqs.append(([CLS] + ids([VOCAB[i] if isinstance(i, int) else i for i in toks]))[:SEQ_LEN])
    return seqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/data")
    args = ap.parse_args()
    out = os.path.abspath(args.out)
    os.makedirs(out, exist_ok=True)

    with open(os.path.join(out, "vocab.json"), "w") as f:
        json.dump(VOCAB, f)
    print(f"vocab: {len(VOCAB)} words")

    for task, (gen, ttype, ncls) in TASKS.items():
        rng = random.Random(hash(task) % 2**31)
        ntr, nte = TASK_SIZES[task]
        xtr, ytr = gen(rng, ntr)
        xte, yte = gen(rng, nte)
        doc = {
            "task": task,
            "type": ttype,
            "n_classes": ncls,
            "seq_len": SEQ_LEN,
            "train": {"ids": xtr, "labels": ytr},
            "test": {"ids": xte, "labels": yte},
        }
        with open(os.path.join(out, f"task_{task}.json"), "w") as f:
            json.dump(doc, f)
        print(f"task {task}: {ntr} train / {nte} test")

    for name, n_sents in [("wikitext2", 3000), ("wikitext103", 9000)]:
        rng = random.Random(hash(name) % 2**31)
        train = gen_lm_corpus(rng, n_sents)
        test = gen_lm_corpus(rng, max(200, n_sents // 10))
        with open(os.path.join(out, f"lm_{name}.json"), "w") as f:
            json.dump({"name": name, "seq_len": SEQ_LEN, "train": train, "test": test}, f)
        print(f"lm {name}: {n_sents} train sents")

    # attack corpora: private targets + two auxiliary sets — an
    # out-of-distribution one (news templates; the paper's CNN-DailyMail
    # stand-in) and an in-distribution one (same template family as the
    # private sentences, disjoint samples).
    rng = random.Random(777)
    private = [pad_single(sentence(rng)) for _ in range(200)]
    seen = {tuple(s) for s in private}
    aux = [pad_single(news_sentence(rng)) for _ in range(3000)]
    aux_indist = []
    while len(aux_indist) < 3000:
        s = pad_single(sentence(rng))
        if tuple(s) not in seen:
            aux_indist.append(s)
    with open(os.path.join(out, "attack_corpora.json"), "w") as f:
        json.dump({"private": private, "aux": aux, "aux_indist": aux_indist, "seq_len": SEQ_LEN}, f)
    print("attack corpora written (aux OOD + in-dist)")


if __name__ == "__main__":
    main()
