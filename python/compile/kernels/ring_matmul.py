"""L1 Pallas kernel: wrapping s64 matmul in Z_{2^64} — the Pi_ScalMul hot op.

Used by the optional ``xla-ring`` backend (ablation (e) in DESIGN.md): the
Rust coordinator can route the secret-share linear algebra through this
AOT-compiled kernel instead of its native blocked i64 matmul. XLA integer
arithmetic is two's-complement wraparound, which *is* the ring semantics.

Requires ``jax_enable_x64`` (set by aot.py / tests before import of jnp use).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _ring_matmul_kernel(a_ref, b_ref, o_ref):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # int64 dot: XLA lowers to wraparound multiply-accumulate.
    o_ref[...] += jax.lax.dot_general(
        a_ref[...],
        b_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int64,
    )


def ring_matmul(a, b, *, bm=None, bn=None, bk=None):
    """Wrapping ``a (m,k) @ b (k,n)`` over int64."""
    assert a.dtype == jnp.int64 and b.dtype == jnp.int64
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    bm = bm or common.pick_block(m, common.TARGET_TILE_M)
    bn = bn or common.pick_block(n, common.TARGET_TILE_N)
    bk = bk or common.pick_block(k, common.TARGET_TILE_K)
    return pl.pallas_call(
        _ring_matmul_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int64),
        interpret=common.interpret_flag(),
    )(a, b)
