"""L1 Pallas kernel: erf GeLU (paper Eq. 5), elementwise row tiles.

erf is expanded to the Abramowitz-Stegun 7.1.26 rational approximation
(|err| <= 1.5e-7, below f32 resolution here) instead of the HLO `erf`
opcode: the runtime's xla_extension 0.5.1 HLO parser predates that opcode,
and this formula matches the Rust NativeBackend bit-for-bit in structure.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def erf_as(x):
    """Abramowitz & Stegun 7.1.26 erf (matches rust/src/runtime/native.rs)."""
    sign = jnp.sign(x)
    ax = jnp.abs(x)
    a1, a2, a3, a4, a5 = 0.254829592, -0.284496736, 1.421413741, -1.453152027, 1.061405429
    p = 0.3275911
    t = 1.0 / (1.0 + p * ax)
    y = 1.0 - (((((a5 * t + a4) * t) + a3) * t + a2) * t + a1) * t * jnp.exp(-ax * ax)
    return sign * y


def _gelu_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    y = 0.5 * x * (1.0 + erf_as(x / jnp.sqrt(2.0).astype(jnp.float32)))
    o_ref[...] = y.astype(o_ref.dtype)


def gelu(x, *, br=None):
    """Elementwise GeLU of a 2-D tensor."""
    m, n = x.shape
    br = br or common.pick_block(m, 8)
    return pl.pallas_call(
        _gelu_kernel,
        grid=(m // br,),
        in_specs=[pl.BlockSpec((br, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=common.interpret_flag(),
    )(x)


def _tanh_kernel(x_ref, o_ref):
    o_ref[...] = jnp.tanh(x_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def tanh(x, *, br=None):
    """Elementwise tanh (BERT pooler / adaptation layer)."""
    m, n = x.shape
    br = br or common.pick_block(m, 8)
    return pl.pallas_call(
        _tanh_kernel,
        grid=(m // br,),
        in_specs=[pl.BlockSpec((br, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=common.interpret_flag(),
    )(x)
