"""L1 Pallas kernel: numerically stable row softmax (paper Eq. 3).

Row-tiled: each grid step normalizes a block of full rows, keeping the
reduction in-registers (f32) — the 8x128-lane friendly layout from
DESIGN.md §Hardware-Adaptation.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    tau = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - tau)
    o_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


def softmax_rows(x, *, br=None):
    """Softmax over the last axis of a 2-D tensor."""
    m, n = x.shape
    br = br or common.pick_block(m, 8)
    return pl.pallas_call(
        _softmax_kernel,
        grid=(m // br,),
        in_specs=[pl.BlockSpec((br, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=common.interpret_flag(),
    )(x)
