"""L1 Pallas kernel: LayerNorm over the last axis (paper Eq. 1)."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    y = (x - mean) / jnp.sqrt(var + eps)
    o_ref[...] = (g_ref[...][None, :] * y + b_ref[...][None, :]).astype(o_ref.dtype)


def layernorm_rows(x, gamma, beta, *, eps=1e-5, br=None):
    """LayerNorm of a 2-D tensor with affine parameters gamma/beta (d,)."""
    m, n = x.shape
    assert gamma.shape == (n,) and beta.shape == (n,)
    br = br or common.pick_block(m, 8)
    import functools

    kern = functools.partial(_layernorm_kernel, eps=eps)
    return pl.pallas_call(
        kern,
        grid=(m // br,),
        in_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=common.interpret_flag(),
    )(x, gamma, beta)
