"""Pure-jnp reference oracle for the Pallas kernels (L1 correctness signal).

Every kernel in this package is checked against these functions by
``python/tests/test_kernels.py`` (hypothesis sweeps shapes and dtypes).
The Rust NativeBackend mirrors these definitions exactly (same GeLU-erf,
same LayerNorm epsilon placement), so the three layers agree numerically.
"""

import jax.numpy as jnp
import jax.scipy.special as jsp


def linear(x, w, b):
    """x (n,k) @ w^T (m,k) + b (m,) — weights stored (out, in)."""
    return jnp.matmul(x, w.T) + b[None, :]


def softmax_rows(x):
    """Numerically stable row softmax (paper Eq. 3)."""
    tau = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - tau)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def gelu(x):
    """Exact erf GeLU (paper Eq. 5)."""
    return 0.5 * x * (1.0 + jsp.erf(x / jnp.sqrt(2.0).astype(x.dtype)))


def layernorm_rows(x, gamma, beta, eps=1e-5):
    """LayerNorm over the last axis (paper Eq. 1)."""
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    return gamma[None, :] * (x - mean) / jnp.sqrt(var + eps) + beta[None, :]


def tanh_rows(x):
    return jnp.tanh(x)


def ring_matmul(a, b):
    """Wrapping s64 matmul in Z_{2^64} (requires jax_enable_x64)."""
    assert a.dtype == jnp.int64 and b.dtype == jnp.int64
    return jnp.matmul(a, b)
