"""L1 Pallas kernel: tiled fused linear layer ``x @ w^T + b``.

The hot matmul of Centaur's plaintext path (P1 applying permuted weights).
Tiling follows DESIGN.md §Hardware-Adaptation: ``bm x bk x bn`` blocks with
the k-grid innermost so the output block stays resident (the accumulator
lives in the revisited output ref), expressing the HBM<->VMEM schedule a GPU
implementation would express with threadblocks.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _linear_kernel(x_ref, wt_ref, b_ref, o_ref):
    """One (i, j, kk) grid step: o[i,j] += x[i,kk] @ wt[kk,j] (+ bias at kk==0)."""
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.broadcast_to(b_ref[...], o_ref.shape)

    o_ref[...] += jnp.dot(x_ref[...], wt_ref[...], preferred_element_type=o_ref.dtype)


def linear(x, w, b, *, bm=None, bn=None, bk=None):
    """Fused ``x (m,k) @ w (n,k)^T + b (n,)`` as a Pallas kernel.

    ``w`` is stored (out_features, in_features), the layout the Rust side
    and the checkpoint format use.
    """
    m, k = x.shape
    n, k2 = w.shape
    assert k == k2, f"linear: inner dim {k} != {k2}"
    assert b.shape == (n,)
    bm = bm or common.pick_block(m, common.TARGET_TILE_M)
    bn = bn or common.pick_block(n, common.TARGET_TILE_N)
    bk = bk or common.pick_block(k, common.TARGET_TILE_K)
    wt = w.T  # (k, n)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _linear_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=common.interpret_flag(),
    )(x, wt, b)
