"""Shared helpers for the Pallas kernels.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers to plain HLO that both the
build-time pytest checks and the Rust runtime can execute (see
DESIGN.md §Hardware-Adaptation for the TPU mapping rationale).
"""

import functools

# Target tile edges for the HBM->VMEM schedule. 128 matches both the MXU
# systolic edge and the lane width; see DESIGN.md §Hardware-Adaptation.
TARGET_TILE_M = 128
TARGET_TILE_N = 128
TARGET_TILE_K = 128


def pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= target.

    Model dimensions in this repo are multiples of 8/64/128, so this finds
    MXU-friendly tiles; odd test shapes degrade gracefully to smaller tiles.
    """
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


def vmem_bytes_matmul(bm: int, bn: int, bk: int, itemsize: int = 4) -> int:
    """Estimated VMEM working set of one matmul grid step (lhs+rhs+acc)."""
    return (bm * bk + bk * bn + bm * bn) * itemsize


def mxu_utilization_estimate(m: int, n: int, k: int, bm: int, bn: int, bk: int) -> float:
    """Analytic MXU utilization estimate for a tiled matmul on a 128x128
    systolic array: fraction of MACs issued in full 128x128x128 blocks."""
    eff_m = min(bm, 128) / 128.0
    eff_n = min(bn, 128) / 128.0
    # k streams through the array; any bk >= 128 saturates the pipeline.
    eff_k = min(bk, 128) / 128.0
    return eff_m * eff_n * eff_k


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


@functools.cache
def interpret_flag() -> bool:
    """Always True in this environment; isolated for future TPU builds."""
    return True
