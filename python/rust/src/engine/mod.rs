//! (under construction)
