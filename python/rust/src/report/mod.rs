//! (under construction)
