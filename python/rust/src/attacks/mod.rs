//! (under construction)
