//! (under construction)
