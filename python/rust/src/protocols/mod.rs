//! (under construction)
