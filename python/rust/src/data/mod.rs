//! (under construction)
