//! (under construction)
