"""L2 correctness: model forward shapes, pallas==ref equality, variants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model
from compile.configs import CONFIGS


@pytest.fixture(scope="module")
def bert():
    cfg = CONFIGS["bert-tiny"]
    return cfg, model.init_params(cfg, jax.random.PRNGKey(1))


@pytest.fixture(scope="module")
def gpt():
    cfg = CONFIGS["gpt2-tiny"]
    return cfg, model.init_params(cfg, jax.random.PRNGKey(2))


def test_param_inventory(bert):
    cfg, p = bert
    assert p["emb.word"].shape == (cfg.vocab, cfg.d)
    assert p["layer0.ffn.w1"].shape == (cfg.k, cfg.d)
    assert p["layer1.attn.wo"].shape == (cfg.d, cfg.d)
    assert p["cls.w"].shape == (cfg.n_classes, cfg.d)
    # 4 emb + 16/layer + 4 head tensors
    assert len(p) == 4 + 16 * cfg.layers + 4


def test_bert_pallas_matches_ref(bert):
    cfg, p = bert
    ids = jnp.arange(cfg.n_ctx, dtype=jnp.int32) % cfg.vocab
    a = model.forward(cfg, p, ids, use_pallas=False)
    b = model.forward(cfg, p, ids, use_pallas=True)
    assert a.shape == (cfg.n_classes,)
    assert_allclose(np.array(a), np.array(b), rtol=1e-4, atol=1e-5)


def test_gpt_pallas_matches_ref(gpt):
    cfg, p = gpt
    ids = (jnp.arange(cfg.n_ctx, dtype=jnp.int32) * 7) % cfg.vocab
    a = model.forward(cfg, p, ids, use_pallas=False)
    b = model.forward(cfg, p, ids, use_pallas=True)
    assert a.shape == (cfg.n_ctx, cfg.vocab)
    assert_allclose(np.array(a), np.array(b), rtol=1e-4, atol=1e-4)


def test_causal_mask_blocks_future(gpt):
    cfg, p = gpt
    ids = jnp.zeros(cfg.n_ctx, jnp.int32)
    base = model.forward(cfg, p, ids)
    # changing a future token must not affect earlier positions' logits
    ids2 = ids.at[-1].set(5)
    pert = model.forward(cfg, p, ids2)
    assert_allclose(np.array(base[:-1]), np.array(pert[:-1]), rtol=1e-5, atol=1e-6)
    assert not np.allclose(np.array(base[-1]), np.array(pert[-1]))


def test_bert_not_causal(bert):
    cfg, p = bert
    ids = jnp.zeros(cfg.n_ctx, jnp.int32)
    h1 = model.backbone(cfg, p, ids)
    h2 = model.backbone(cfg, p, ids.at[-1].set(9))
    # bidirectional attention: early positions DO change
    assert not np.allclose(np.array(h1[0]), np.array(h2[0]))


def test_variants_differ_from_exact(bert):
    cfg, p = bert
    ids = (jnp.arange(cfg.n_ctx, dtype=jnp.int32) * 3) % cfg.vocab
    exact = np.array(model.forward(cfg, p, ids, variant="exact"))
    mpcf = np.array(model.forward(cfg, p, ids, variant="mpcformer"))
    secf = np.array(model.forward(cfg, p, ids, variant="secformer"))
    assert not np.allclose(exact, mpcf)
    assert not np.allclose(exact, secf)
    assert not np.allclose(mpcf, secf)  # gelu substitution differs


def test_2quad_is_distribution():
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 16))
    y = np.array(model.softmax_2quad(x))
    assert (y >= 0).all()
    assert_allclose(y.sum(-1), np.ones(8), rtol=1e-5)


def test_gelu_quad_formula():
    x = jnp.array([-2.0, 0.0, 1.0, 3.0])
    got = np.array(model.gelu_quad(x))
    want = 0.125 * np.array(x) ** 2 + 0.25 * np.array(x) + 0.5
    assert_allclose(got, want, rtol=1e-6)


def test_head_slicing_matches_reshape(bert):
    """Column-block slicing == reshape-based head split (rust contract)."""
    cfg, p = bert
    x = jax.random.normal(jax.random.PRNGKey(4), (cfg.n_ctx, cfg.d))
    dh = cfg.dh
    for h in range(cfg.h):
        a = x[:, h * dh : (h + 1) * dh]
        b = x.reshape(cfg.n_ctx, cfg.h, dh)[:, h, :]
        assert np.array_equal(np.array(a), np.array(b))
