"""AOT path: HLO text generation and manifest structure."""

import json
import os

import jax.numpy as jnp
import pytest

from compile import aot
from compile.configs import CONFIGS


def test_hlo_text_for_softmax():
    text = aot.to_hlo_text(aot.model.op_softmax, aot.f32(8, 16))
    assert text.startswith("HloModule"), text[:80]
    assert "f32[8,16]" in text


def test_hlo_text_for_ring_matmul():
    text = aot.to_hlo_text(aot.model.op_ring_matmul, aot.s64(8, 8), aot.s64(8, 8))
    assert text.startswith("HloModule")
    assert "s64[8,8]" in text


def test_model_entries_cover_centaur_plaintext_ops():
    cfg = CONFIGS["bert-tiny"]
    ops = {e[0] for e in aot.model_entries(cfg)}
    assert ops == {"softmax", "gelu", "layernorm", "tanh"}
    gpt = CONFIGS["gpt2-tiny"]
    assert {e[0] for e in aot.model_entries(gpt)} == {"softmax", "gelu", "layernorm"}


def test_entry_shapes_match_config():
    cfg = CONFIGS["bert-tiny"]
    for op, _fn, _specs, shape in aot.model_entries(cfg):
        if op == "softmax":
            assert shape == (cfg.h * cfg.n_ctx, cfg.n_ctx)
        elif op == "gelu":
            assert shape == (cfg.n_ctx, cfg.k)
        elif op == "layernorm":
            assert shape == (cfg.n_ctx, cfg.d)
        elif op == "tanh":
            assert shape == (1, cfg.d)


def test_build_model_artifacts_roundtrip(tmp_path):
    cfg = CONFIGS["bert-tiny"]
    manifest = aot.build_model_artifacts(cfg, str(tmp_path))
    mpath = tmp_path / cfg.name / "manifest.json"
    assert mpath.exists()
    loaded = json.loads(mpath.read_text())
    assert loaded == manifest
    for op in manifest["ops"]:
        f = tmp_path / cfg.name / op["file"]
        assert f.exists()
        assert f.read_text().startswith("HloModule")


@pytest.mark.slow
def test_build_ring_artifacts(tmp_path):
    entries = aot.build_ring_artifacts(str(tmp_path))
    assert len(entries) == len(aot.RING_SHAPES)
    for e in entries:
        assert (tmp_path / "ring" / e["file"]).exists()
