"""Synthetic data generator invariants."""

import random

from compile import data_gen as dg


def test_vocab_special_tokens():
    assert dg.VOCAB[dg.PAD] == "[PAD]"
    assert dg.VOCAB[dg.CLS] == "[CLS]"
    assert dg.VOCAB[dg.SEP] == "[SEP]"
    assert dg.VOCAB[dg.UNK] == "[UNK]"
    assert len(dg.VOCAB) == len(set(dg.VOCAB)), "duplicate vocab entries"
    assert len(dg.VOCAB) <= 512, "must fit the tiny-model vocab"


def test_all_template_words_in_vocab():
    rng = random.Random(0)
    for _ in range(200):
        for tok in dg.sentence(rng) + dg.news_sentence(rng):
            assert tok in dg.W2I, f"{tok!r} missing from vocab"


def test_sequences_padded_to_len():
    rng = random.Random(1)
    for gen, _t, _n in dg.TASKS.values():
        xs, ys = gen(rng, 20)
        assert len(xs) == len(ys) == 20
        for x in xs:
            assert len(x) == dg.SEQ_LEN
            assert all(0 <= t < len(dg.VOCAB) for t in x)


def test_qnli_labels_follow_rule():
    rng = random.Random(2)
    xs, ys = dg.gen_qnli(rng, 100)
    # decode and re-check the rule for positives
    for x, y in zip(xs, ys):
        toks = [dg.VOCAB[i] for i in x if i not in (dg.PAD,)]
        sep = toks.index("[SEP]")
        s1, s2 = toks[1:sep], toks[sep + 1 :]
        c1 = set(dg.cities_in(s1))
        overlap = bool(c1 & set(dg.cities_in(s2)))
        if y == 1:
            assert overlap, f"positive without overlap: {toks}"


def test_stsb_scores_in_range():
    rng = random.Random(3)
    _xs, ys = dg.gen_stsb(rng, 100)
    assert all(0.0 <= y <= 5.0 for y in ys)
    assert len({round(y, 1) for y in ys}) > 3, "scores should vary"


def test_cola_balanced():
    rng = random.Random(4)
    _xs, ys = dg.gen_cola(rng, 400)
    pos = sum(ys)
    assert 120 < pos < 280


def test_lm_corpus_shapes():
    rng = random.Random(5)
    seqs = dg.gen_lm_corpus(rng, 50)
    assert len(seqs) == 50
    for s in seqs:
        assert len(s) == dg.SEQ_LEN
        assert s[0] == dg.CLS


def test_aux_differs_from_private_templates():
    rng = random.Random(6)
    private = {" ".join(dg.sentence(rng)) for _ in range(50)}
    aux = {" ".join(dg.news_sentence(rng)) for _ in range(50)}
    assert not private & aux
