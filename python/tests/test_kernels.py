"""L1 correctness: every Pallas kernel vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and dtypes; assert_allclose against ref — the core
correctness signal of the compile path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import common, gelu, layernorm, matmul, ref, ring_matmul, softmax

# Shapes: multiples that exercise 1-to-many grid steps without being slow.
dims = st.sampled_from([1, 2, 4, 8, 16, 24, 32, 64])
float_dtypes = st.sampled_from([jnp.float32, jnp.bfloat16])


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


class TestLinear:
    @settings(max_examples=20, deadline=None)
    @given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**30))
    def test_matches_ref(self, m, k, n, seed):
        key = jax.random.PRNGKey(seed)
        k1, k2, k3 = jax.random.split(key, 3)
        x = rand(k1, (m, k))
        w = rand(k2, (n, k), scale=0.3)
        b = rand(k3, (n,))
        assert_allclose(np.array(matmul.linear(x, w, b)), np.array(ref.linear(x, w, b)), rtol=1e-5, atol=1e-5)

    def test_explicit_tiles(self):
        key = jax.random.PRNGKey(0)
        x = rand(key, (64, 128))
        w = rand(key, (96, 128), scale=0.1)
        b = jnp.zeros(96, jnp.float32)
        got = matmul.linear(x, w, b, bm=16, bn=32, bk=64)
        assert_allclose(np.array(got), np.array(ref.linear(x, w, b)), rtol=1e-4, atol=1e-4)

    def test_rejects_bad_inner_dim(self):
        with pytest.raises(AssertionError):
            matmul.linear(jnp.zeros((4, 8)), jnp.zeros((4, 9)), jnp.zeros(4))


class TestSoftmax:
    @settings(max_examples=20, deadline=None)
    @given(m=dims, n=dims, seed=st.integers(0, 2**30), dtype=float_dtypes)
    def test_matches_ref(self, m, n, seed, dtype):
        x = rand(jax.random.PRNGKey(seed), (m, n), dtype, scale=4.0)
        got = np.array(softmax.softmax_rows(x), np.float32)
        want = np.array(ref.softmax_rows(x), np.float32)
        assert_allclose(got, want, rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5, atol=1e-3)

    def test_rows_sum_to_one(self):
        x = rand(jax.random.PRNGKey(1), (16, 32), scale=10.0)
        s = np.array(softmax.softmax_rows(x)).sum(-1)
        assert_allclose(s, np.ones(16), rtol=1e-5)

    def test_extreme_values_stable(self):
        x = jnp.array([[1e4, -1e4, 0.0, 5.0] * 8])
        out = np.array(softmax.softmax_rows(x))
        assert np.isfinite(out).all()


class TestGelu:
    @settings(max_examples=20, deadline=None)
    @given(m=dims, n=dims, seed=st.integers(0, 2**30), dtype=float_dtypes)
    def test_matches_ref(self, m, n, seed, dtype):
        x = rand(jax.random.PRNGKey(seed), (m, n), dtype, scale=3.0)
        got = np.array(gelu.gelu(x), np.float32)
        want = np.array(ref.gelu(x), np.float32)
        if dtype == jnp.bfloat16:
            # both sides round to bf16 at different points; bound abs error
            assert_allclose(got, want, rtol=0.08, atol=0.04)
        else:
            assert_allclose(got, want, rtol=1e-5, atol=1e-3)

    def test_known_values(self):
        x = jnp.array([[0.0, 1.0, -1.0, 2.0]])
        got = np.array(gelu.gelu(x))[0]
        assert_allclose(got, [0.0, 0.84134, -0.15866, 1.95450], atol=1e-4)

    def test_tanh_kernel(self):
        x = rand(jax.random.PRNGKey(3), (8, 16), scale=2.0)
        assert_allclose(np.array(gelu.tanh(x)), np.tanh(np.array(x)), rtol=1e-5, atol=1e-6)


class TestLayerNorm:
    @settings(max_examples=20, deadline=None)
    @given(m=dims, n=st.sampled_from([4, 8, 16, 32, 64]), seed=st.integers(0, 2**30))
    def test_matches_ref(self, m, n, seed):
        key = jax.random.PRNGKey(seed)
        k1, k2, k3 = jax.random.split(key, 3)
        x = rand(k1, (m, n), scale=2.0)
        g = rand(k2, (n,)) + 1.0
        b = rand(k3, (n,))
        assert_allclose(
            np.array(layernorm.layernorm_rows(x, g, b)),
            np.array(ref.layernorm_rows(x, g, b)),
            rtol=1e-4,
            atol=1e-5,
        )

    def test_zero_mean_unit_var(self):
        x = rand(jax.random.PRNGKey(5), (4, 64), scale=7.0)
        out = np.array(layernorm.layernorm_rows(x, jnp.ones(64), jnp.zeros(64)))
        assert_allclose(out.mean(-1), np.zeros(4), atol=1e-5)
        assert_allclose(out.std(-1), np.ones(4), atol=1e-2)


class TestRingMatmul:
    @settings(max_examples=15, deadline=None)
    @given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**30))
    def test_matches_ref_and_wraps(self, m, k, n, seed):
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        a = jax.random.randint(k1, (m, k), -(2**62), 2**62, jnp.int64)
        b = jax.random.randint(k2, (k, n), -(2**62), 2**62, jnp.int64)
        got = np.array(ring_matmul.ring_matmul(a, b), np.int64)
        want = np.array(ref.ring_matmul(a, b), np.int64)
        assert (got == want).all()

    def test_share_distributivity(self):
        # A @ (X0 + X1) == A@X0 + A@X1 mod 2^64 — the Pi_ScalMul identity.
        key = jax.random.PRNGKey(9)
        ks = jax.random.split(key, 3)
        a = jax.random.randint(ks[0], (8, 16), -(2**62), 2**62, jnp.int64)
        x0 = jax.random.randint(ks[1], (16, 8), -(2**62), 2**62, jnp.int64)
        x1 = jax.random.randint(ks[2], (16, 8), -(2**62), 2**62, jnp.int64)
        lhs = np.array(ring_matmul.ring_matmul(a, x0 + x1), np.uint64)
        rhs = np.array(ring_matmul.ring_matmul(a, x0), np.uint64) + np.array(
            ring_matmul.ring_matmul(a, x1), np.uint64
        )
        assert (lhs == rhs).all()


class TestCommon:
    @settings(max_examples=50, deadline=None)
    @given(dim=st.integers(1, 512), target=st.integers(1, 128))
    def test_pick_block_divides(self, dim, target):
        b = common.pick_block(dim, target)
        assert 1 <= b <= min(dim, target)
        assert dim % b == 0

    def test_vmem_estimate(self):
        # 128x128x128 f32 tiles: 3 * 64KiB = 192KiB, within the 16 MiB VMEM
        assert common.vmem_bytes_matmul(128, 128, 128) == 3 * 128 * 128 * 4
        assert common.vmem_bytes_matmul(128, 128, 128) < 16 * 2**20

    def test_mxu_estimate_full_tiles(self):
        assert common.mxu_utilization_estimate(768, 768, 768, 128, 128, 128) == 1.0
        assert common.mxu_utilization_estimate(32, 32, 32, 32, 32, 32) == (32 / 128) ** 3
