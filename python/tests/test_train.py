"""Training utilities: Adam step, losses, metrics, CTWB export contract."""

import json
import struct

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import model, train_tiny as tt
from compile.configs import CONFIGS, ModelConfig


def test_adam_reduces_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = tt.adam_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = tt.adam_update(params, g, state, 0.1)
    assert float(loss(params)) < 1e-3


def test_cls_loss_decreases_on_tiny_problem():
    cfg = ModelConfig(**{**CONFIGS["bert-tiny"].__dict__, "layers": 1})
    p = model.init_params(cfg, jax.random.PRNGKey(0))
    fwd, loss = tt.cls_loss_fn(cfg, "exact")
    # label = 1 iff first real token id is even
    rng = np.random.default_rng(0)
    xs = jnp.array(rng.integers(4, cfg.vocab, (64, cfg.n_ctx)), jnp.int32)
    ys = jnp.array(np.array(xs)[:, 1] % 2, jnp.int32)
    l0 = float(loss(p, xs, ys))
    state = tt.adam_init(p)
    step = jax.jit(lambda p, s: (lambda g: tt.adam_update(p, g, s, 1e-3))(jax.grad(loss)(p, xs, ys)))
    for _ in range(30):
        p, state = step(p, state)
    l1 = float(loss(p, xs, ys))
    assert l1 < l0, f"{l1} !< {l0}"


def test_perplexity_of_uniform_model():
    cfg = ModelConfig(**{**CONFIGS["gpt2-tiny"].__dict__, "layers": 1})
    p = model.init_params(cfg, jax.random.PRNGKey(1))
    fwd, _ = tt.lm_loss_fn(cfg, "exact")
    xs = jnp.ones((8, cfg.n_ctx), jnp.int32) * 7
    ppl = tt.perplexity(fwd, p, xs)
    # untrained model ~ uniform over vocab
    assert 10 < ppl < cfg.vocab * 4


def test_metrics_sanity():
    cfg = ModelConfig(**{**CONFIGS["bert-tiny"].__dict__, "layers": 1})
    # perfect predictor mock: fwd returns one-hot of label parity
    fwd = lambda p, xs: jax.nn.one_hot(xs[:, 1] % 2, 2) * 10.0
    xs = jnp.array(np.random.default_rng(2).integers(4, 100, (50, cfg.n_ctx)), jnp.int32)
    ys = jnp.array(np.array(xs)[:, 1] % 2, jnp.int32)
    assert tt.accuracy(fwd, None, xs, ys) == 100.0
    assert tt.f1_score(fwd, None, xs, ys) == 100.0
    assert tt.matthews(fwd, None, xs, ys) == 100.0


def test_pearson_spearman_perfect_correlation():
    fwd = lambda p, xs: jnp.array(xs[:, 1:2], jnp.float32)
    xs = jnp.array(np.random.default_rng(3).integers(0, 50, (40, 8)), jnp.int32)
    ys = np.array(xs)[:, 1].astype(np.float32)
    score = tt.pearson_spearman(fwd, None, xs, ys)
    assert score > 99.9


def test_ctwb_export_roundtrip(tmp_path):
    cfg = ModelConfig(**{**CONFIGS["bert-tiny"].__dict__, "layers": 1})
    p = model.init_params(cfg, jax.random.PRNGKey(4))
    tt.export_ctwb(p, cfg, "unit-test", str(tmp_path))
    man = json.loads((tmp_path / "unit-test" / "manifest.json").read_text())
    blob = (tmp_path / "unit-test" / "weights.bin").read_bytes()
    assert man["model"] == cfg.name
    names = [t["name"] for t in man["tensors"]]
    assert names == sorted(names), "tensors must be name-sorted (rust contract)"
    total = sum(t["rows"] * t["cols"] for t in man["tensors"])
    assert len(blob) == 4 * total
    # spot-check one tensor's bytes
    t = next(t for t in man["tensors"] if t["name"] == "emb.word")
    off = t["offset"] * 4
    vals = struct.unpack_from(f"<{t['rows']*t['cols']}f", blob, off)
    assert_allclose(
        np.array(vals).reshape(t["rows"], t["cols"]),
        np.asarray(p["emb.word"], np.float32),
        rtol=0,
        atol=0,
    )


def test_vector_tensors_exported_as_single_row(tmp_path):
    cfg = ModelConfig(**{**CONFIGS["bert-tiny"].__dict__, "layers": 1})
    p = model.init_params(cfg, jax.random.PRNGKey(5))
    tt.export_ctwb(p, cfg, "vec-test", str(tmp_path))
    man = json.loads((tmp_path / "vec-test" / "manifest.json").read_text())
    t = next(t for t in man["tensors"] if t["name"] == "emb.ln.gamma")
    assert t["rows"] == 1 and t["cols"] == cfg.d
