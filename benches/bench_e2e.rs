//! End-to-end benchmark (Figs. 7/8 companion): one inference per
//! (framework × model), printing comm volume and simulated wall times —
//! the series the report targets regenerate in table form — plus the
//! per-token decode comparison (full recompute vs incremental KV cache).

use centaur::baselines::FrameworkKind;
use centaur::engine::decoder::DecoderSession;
use centaur::engine::draft::Draft;
use centaur::engine::{CentaurEngine, EngineOptions};
use centaur::model::{ModelConfig, ModelWeights};
use centaur::net::NetworkProfile;
use centaur::report::measure_framework;
use centaur::runtime::NativeBackend;
use centaur::util::bench::Bencher;
use centaur::util::{human_bytes, human_secs};

/// Per-token decode cost, three ways: the pre-KV-cache full-recompute
/// path, the PR 2 plain per-step KV path, and warm correlated decode.
/// Acceptance gates (byte charges are deterministic, so both are exact):
/// full ≥ 3× plain per-step, and plain per-step ≥ 1.8× correlated — the
/// fixed-operand warm-step comm reduction threshold CI smokes on.
/// Plus the ISSUE 5 round gate: the batched opening schedule must cut
/// warm rounds/token ≥40% vs the sequential schedule with identical
/// bytes, reported as WAN decode s/token (where `rounds·RTT` dominates).
fn bench_decode(b: &mut Bencher) {
    let cfg = ModelConfig::gpt2_tiny().with_n_ctx(64);
    let w = ModelWeights::random(&cfg, 7);
    let prompt: Vec<u32> = vec![7, 11, 13, 17];
    let steps = 8usize;

    b.section("gpt2-tiny @ n_ctx=64 — per-token decode: full recompute vs KV cache vs correlations");
    let mut full_cost = None;
    b.bench("full recompute x8 tokens", || {
        let mut e = CentaurEngine::new(&cfg, &w, NetworkProfile::lan(), 8).unwrap();
        let (_, cost) = e.generate_full_recompute(&prompt, steps).unwrap();
        full_cost = Some(cost);
    });
    let run_session = |label: &str, decode_correlations: bool, round_batching: bool, b: &mut Bencher| {
        let mut out = None;
        b.bench(label, || {
            let mut e = CentaurEngine::with_backend(
                &cfg,
                &w,
                Box::new(NativeBackend::new()),
                EngineOptions {
                    profile: NetworkProfile::lan(),
                    seed: 8,
                    decode_correlations,
                    round_batching,
                    ..Default::default()
                },
            )
            .unwrap();
            let mut sess = DecoderSession::new(&mut e, &prompt).unwrap();
            for _ in 0..steps {
                sess.step_greedy().unwrap();
            }
            out = Some((
                sess.setup_cost().clone(),
                sess.prefill_cost().clone(),
                sess.decode_cost().clone(),
            ));
        });
        out.unwrap()
    };
    let (_, plain_prefill, plain_decode) =
        run_session("plain KV decode x8 tokens (PR 2)", false, true, b);
    let (corr_setup, corr_prefill, corr_decode) =
        run_session("correlated KV decode x8 tokens", true, true, b);

    let full = full_cost.unwrap();
    let full_tok = full.bytes_total() / steps as u64;
    let plain_tok = plain_decode.bytes_total() / steps as u64;
    let corr_tok = corr_decode.bytes_total() / steps as u64;
    println!(
        "    -> full recompute  : {}/token | LAN {} WAN1 {} WAN2 {}",
        human_bytes(full_tok),
        human_secs(full.total_time(&NetworkProfile::lan()) / steps as f64),
        human_secs(full.total_time(&NetworkProfile::wan1()) / steps as f64),
        human_secs(full.total_time(&NetworkProfile::wan2()) / steps as f64),
    );
    println!(
        "    -> plain KV decode : {}/token | LAN {} WAN1 {} WAN2 {} | cold prefill {} ({} tokens)",
        human_bytes(plain_tok),
        human_secs(plain_decode.total_time(&NetworkProfile::lan()) / steps as f64),
        human_secs(plain_decode.total_time(&NetworkProfile::wan1()) / steps as f64),
        human_secs(plain_decode.total_time(&NetworkProfile::wan2()) / steps as f64),
        human_bytes(plain_prefill.bytes_total()),
        prompt.len(),
    );
    println!(
        "    -> corr KV decode  : {}/token | LAN {} WAN1 {} WAN2 {} | cold prefill {} | corr setup {} (once/session)",
        human_bytes(corr_tok),
        human_secs(corr_decode.total_time(&NetworkProfile::lan()) / steps as f64),
        human_secs(corr_decode.total_time(&NetworkProfile::wan1()) / steps as f64),
        human_secs(corr_decode.total_time(&NetworkProfile::wan2()) / steps as f64),
        human_bytes(corr_prefill.bytes_total()),
        human_bytes(corr_setup.bytes_total()),
    );
    println!(
        "    -> per-token comm ratios: full/plain {:.2}x (floor 3x) | plain/corr {:.2}x (floor 1.8x) | full/corr {:.2}x",
        full_tok as f64 / plain_tok as f64,
        plain_tok as f64 / corr_tok as f64,
        full_tok as f64 / corr_tok as f64,
    );
    assert!(full_tok >= 3 * plain_tok, "KV-cache decode must be >=3x cheaper per token");
    assert!(
        plain_tok * 10 >= corr_tok * 18,
        "fixed-operand correlations must cut warm-step comm >=1.8x: plain {plain_tok} B vs corr {corr_tok} B"
    );

    // --- Round compression (ISSUE 5): batched vs sequential schedule ----
    b.section("gpt2-tiny @ n_ctx=64 — WAN decode: batched vs sequential opening schedule");
    let (_, _, seq_decode) =
        run_session("sequential-schedule decode x8 tokens (PR 3 baseline)", true, false, b);
    let bat_rounds_tok = corr_decode.rounds_total() / steps as u64;
    let seq_rounds_tok = seq_decode.rounds_total() / steps as u64;
    let seq_bytes_tok = seq_decode.bytes_total() / steps as u64;
    println!(
        "    -> rounds/token: sequential {seq_rounds_tok} -> batched {bat_rounds_tok} \
         ({:.1}% fewer), bytes/token {} -> {} (identical)",
        100.0 * (seq_rounds_tok as f64 - bat_rounds_tok as f64) / seq_rounds_tok as f64,
        human_bytes(seq_bytes_tok),
        human_bytes(corr_tok),
    );
    for name in ["wan1", "wan2", "wan3"] {
        let p = NetworkProfile::by_name(name).unwrap();
        println!(
            "    -> {:<18} decode s/token: sequential {} -> batched {}",
            p.name,
            human_secs(seq_decode.total_time(&p) / steps as f64),
            human_secs(corr_decode.total_time(&p) / steps as f64),
        );
    }
    // CI gates: >=40% fewer warm rounds/token, bytes/token unchanged.
    assert!(
        bat_rounds_tok * 10 <= seq_rounds_tok * 6,
        "batched openings must cut warm rounds/token >=40%: {bat_rounds_tok} vs {seq_rounds_tok}"
    );
    assert_eq!(
        corr_decode.bytes_total(),
        seq_decode.bytes_total(),
        "round batching must not change decode bytes"
    );
}

/// Speculative decode (ISSUE 7): up to k draft tokens verified per
/// 16-round flight chain, output token-identical to plain greedy.
/// Reports acceptance plus rounds and s per *accepted* token over
/// {lan, wan3} × k ∈ {1, 2, 4, 8}, and CI-gates the k=4 amortization
/// floor (≤ 16/2 rounds per accepted token) and the wan3 headline:
/// solo-stream s/token below the 16·RTT flight-chain floor.
fn bench_speculative(b: &mut Bencher) {
    let cfg = ModelConfig::gpt2_tiny().with_n_ctx(64);
    let w = ModelWeights::random(&cfg, 7);
    let prompt: Vec<u32> = vec![7, 11, 13, 17];
    let steps = 8usize;
    // The tiny-model draft shares the serving weights, so disagreements
    // come only from fixed-point noise — the high-acceptance regime.
    let draft = Draft::tiny(&cfg, &w);
    b.section("gpt2-tiny @ n_ctx=64 — speculative decode: rounds and s per ACCEPTED token");
    let mut k4_rounds_per_tok = f64::INFINITY;
    let mut wan3_k4_s_per_tok = f64::INFINITY;
    for profile in ["lan", "wan3"] {
        let p = NetworkProfile::by_name(profile).unwrap();
        for k in [1usize, 2, 4, 8] {
            let mut res = None;
            b.bench(&format!("{profile} spec_k={k} x{steps} tokens"), || {
                let mut e = CentaurEngine::with_backend(
                    &cfg,
                    &w,
                    Box::new(NativeBackend::new()),
                    EngineOptions {
                        profile: p,
                        seed: 8,
                        decode_correlations: true,
                        round_batching: true,
                        ..Default::default()
                    },
                )
                .unwrap();
                res = Some(e.generate_speculative(&prompt, steps, &draft, k).unwrap());
            });
            let (out, spec) = res.unwrap();
            let toks = out.tokens.len() as f64;
            let rpt = out.decode.rounds_total() as f64 / toks;
            let spt = out.decode.total_time(&p) / toks;
            println!(
                "    -> {profile} k={k}: accept {:.0}% ({}/{} proposals, {} verify steps) | \
                 {rpt:.1} rounds/token | {}/token",
                spec.acceptance_rate() * 100.0,
                spec.accepted,
                spec.proposed,
                spec.verify_steps,
                human_secs(spt),
            );
            if k == 4 {
                k4_rounds_per_tok = rpt;
                if profile == "wan3" {
                    wan3_k4_s_per_tok = spt;
                }
            }
        }
    }
    // CI gates (ISSUE 7): the k=4 verify chain must amortize to at most
    // half the 16-round solo schedule per accepted token, which puts
    // wan3 solo-stream decode below the 16·RTT floor a one-token step
    // can never beat.
    assert!(
        k4_rounds_per_tok <= 8.0,
        "spec_k=4 must amortize to <=8 rounds/accepted token, got {k4_rounds_per_tok:.2}"
    );
    let wan3 = NetworkProfile::wan3();
    let floor = 16.0 * wan3.rtt;
    assert!(
        wan3_k4_s_per_tok < floor,
        "wan3 spec_k=4 s/token {wan3_k4_s_per_tok:.3} must beat the 16xRTT floor {floor:.3}"
    );
}

/// Integrity-checked inference (ISSUE 10): the same seeded request with
/// audit off vs on, solo and batched B=4. The audit layer is
/// zero-perturbation — tokens and the protocol ledger are bit-identical
/// — so its entire wire cost is the emulated σ-exchange accounted in
/// [`centaur::mpc::AuditCounters`], reported here per token next to the
/// semi-honest cost. CI gate (EXPERIMENTS.md audit-overhead table):
/// audited total bytes ≤ 2× the semi-honest bytes.
fn bench_audit(b: &mut Bencher) {
    let cfg = ModelConfig::gpt2_tiny().with_n_ctx(64);
    let w = ModelWeights::random(&cfg, 7);
    let prompt: Vec<u32> = vec![7, 11, 13, 17];
    let steps = 8usize;
    b.section("gpt2-tiny @ n_ctx=64 — integrity-checked inference: audit off vs on");

    let mk = |audit: bool| {
        CentaurEngine::with_backend(
            &cfg,
            &w,
            Box::new(NativeBackend::new()),
            EngineOptions { profile: NetworkProfile::lan(), seed: 8, audit, ..Default::default() },
        )
        .unwrap()
    };
    // Solo stream: (tokens, total ledger, counters).
    let run_solo = |audit: bool, b: &mut Bencher| {
        let mut res = None;
        b.bench(&format!("solo x{steps} tokens, audit={}", if audit { "on" } else { "off" }), || {
            let mut e = mk(audit);
            let out = e.generate_streaming(&prompt, steps, &mut |_, _, _| true).unwrap();
            res = Some((out.tokens.clone(), out.total(), e.audit_counters()));
        });
        res.unwrap()
    };
    // Batched B=4: per-session cost summaries summed (lane-attributed).
    let run_batched = |audit: bool, b: &mut Bencher| {
        let mut res = None;
        b.bench(&format!("batched B=4 x{steps} tokens, audit={}", if audit { "on" } else { "off" }), || {
            let mut e = mk(audit);
            let mut batch = centaur::engine::decoder::DecodeBatch::new(&mut e).unwrap();
            let mut ids = Vec::new();
            for i in 0..4u32 {
                ids.push(batch.admit(&[7, 11 + i, 13, 17], steps, None).unwrap());
            }
            while !batch.step().unwrap().is_empty() {}
            let (mut tokens, mut bytes, mut rounds) = (Vec::new(), 0u64, 0u64);
            for id in ids {
                let s = batch.remove(id).unwrap();
                tokens.extend(s.tokens);
                bytes += s.setup_bytes + s.prefill_bytes + s.decode_bytes;
                rounds = rounds.max(s.rounds);
            }
            drop(batch);
            res = Some((tokens, bytes, rounds, e.audit_counters()));
        });
        res.unwrap()
    };

    let (tok_off, total_off, c_off) = run_solo(false, b);
    let (tok_on, total_on, c_on) = run_solo(true, b);
    assert!(c_off.is_none());
    let c = c_on.expect("audit-on counters");
    assert_eq!(tok_on, tok_off, "audit must not perturb tokens");
    assert_eq!(total_on.bytes_total(), total_off.bytes_total(), "audit must not touch the ledger");
    assert_eq!(total_on.rounds_total(), total_off.rounds_total());
    assert_eq!(c.mac_failures, 0, "honest bench run must verify clean");
    assert!(c.mac_checks > 0);
    let solo_bytes = total_on.bytes_total();
    let ntok = (prompt.len() + steps) as u64;
    println!(
        "    -> solo   : {}/token semi-honest + {}/token audit σ-overhead ({} checks, {} openings) | audited/plain {:.4}x",
        human_bytes(solo_bytes / ntok),
        human_bytes(c.overhead_bytes / ntok),
        c.mac_checks,
        c.openings,
        (solo_bytes + c.overhead_bytes) as f64 / solo_bytes as f64,
    );
    assert!(
        c.overhead_bytes <= solo_bytes,
        "audited total must stay <=2x the semi-honest bytes: overhead {} vs protocol {}",
        c.overhead_bytes,
        solo_bytes
    );

    let (btok_off, bbytes_off, _, bc_off) = run_batched(false, b);
    let (btok_on, bbytes_on, brounds_on, bc_on) = run_batched(true, b);
    assert!(bc_off.is_none());
    let bc = bc_on.expect("audit-on counters");
    assert_eq!(btok_on, btok_off, "audit must not perturb batched tokens");
    assert_eq!(bbytes_on, bbytes_off, "audit must not touch batched session ledgers");
    assert_eq!(bc.mac_failures, 0);
    let btok = 4 * (4 + steps) as u64;
    println!(
        "    -> batched: {}/token semi-honest + {}/token audit σ-overhead ({} checks, {} openings) | {} rounds | audited/plain {:.4}x",
        human_bytes(bbytes_on / btok),
        human_bytes(bc.overhead_bytes / btok),
        bc.mac_checks,
        bc.openings,
        brounds_on,
        (bbytes_on + bc.overhead_bytes) as f64 / bbytes_on as f64,
    );
    assert!(
        bc.overhead_bytes <= bbytes_on,
        "batched audited total must stay <=2x the semi-honest bytes: overhead {} vs protocol {}",
        bc.overhead_bytes,
        bbytes_on
    );
}

fn main() {
    let mut b = Bencher::new();
    bench_decode(&mut b);
    bench_speculative(&mut b);
    bench_audit(&mut b);
    // CI smoke mode: assert the decode comm-reduction gates and stop —
    // the framework sweep below is the long part of this bench.
    if std::env::var("CENTAUR_BENCH_DECODE_ONLY").is_ok() {
        println!(
            "CENTAUR_BENCH_DECODE_ONLY set: decode + speculative + audit gates passed, skipping framework sweep"
        );
        return;
    }
    let quick = std::env::var("CENTAUR_BENCH_QUICK").is_ok();
    let models: Vec<&str> =
        if quick { vec!["bert-tiny"] } else { vec!["bert-tiny", "bert-base", "gpt2-base"] };

    for model in models {
        let cfg = ModelConfig::by_name(model).unwrap();
        b.section(&format!("{model} — measure_framework (extrapolated)"));
        for kind in [FrameworkKind::Centaur, FrameworkKind::Puma] {
            let mut last = None;
            b.bench(&format!("{} {model}", kind.name()), || {
                last = Some(measure_framework(kind, &cfg, 3, true).unwrap());
            });
            let ledger = last.unwrap();
            println!(
                "    -> comm {} | LAN {} | WAN1 {} | WAN2 {}",
                human_bytes(ledger.bytes_total()),
                human_secs(ledger.total_time(&NetworkProfile::lan())),
                human_secs(ledger.total_time(&NetworkProfile::wan1())),
                human_secs(ledger.total_time(&NetworkProfile::wan2())),
            );
        }
    }
}
