//! End-to-end benchmark (Figs. 7/8 companion): one inference per
//! (framework × model), printing comm volume and simulated wall times —
//! the series the report targets regenerate in table form.

use centaur::baselines::FrameworkKind;
use centaur::model::ModelConfig;
use centaur::net::NetworkProfile;
use centaur::report::measure_framework;
use centaur::util::bench::Bencher;
use centaur::util::{human_bytes, human_secs};

fn main() {
    let mut b = Bencher::new();
    let quick = std::env::var("CENTAUR_BENCH_QUICK").is_ok();
    let models: Vec<&str> =
        if quick { vec!["bert-tiny"] } else { vec!["bert-tiny", "bert-base", "gpt2-base"] };

    for model in models {
        let cfg = ModelConfig::by_name(model).unwrap();
        b.section(&format!("{model} — measure_framework (extrapolated)"));
        for kind in [FrameworkKind::Centaur, FrameworkKind::Puma] {
            let mut last = None;
            b.bench(&format!("{} {model}", kind.name()), || {
                last = Some(measure_framework(kind, &cfg, 3, true).unwrap());
            });
            let ledger = last.unwrap();
            println!(
                "    -> comm {} | LAN {} | WAN1 {} | WAN2 {}",
                human_bytes(ledger.bytes_total()),
                human_secs(ledger.total_time(&NetworkProfile::lan())),
                human_secs(ledger.total_time(&NetworkProfile::wan1())),
                human_secs(ledger.total_time(&NetworkProfile::wan2())),
            );
        }
    }
}
