//! End-to-end benchmark (Figs. 7/8 companion): one inference per
//! (framework × model), printing comm volume and simulated wall times —
//! the series the report targets regenerate in table form — plus the
//! per-token decode comparison (full recompute vs incremental KV cache).

use centaur::baselines::FrameworkKind;
use centaur::engine::decoder::DecoderSession;
use centaur::engine::CentaurEngine;
use centaur::model::{ModelConfig, ModelWeights};
use centaur::net::NetworkProfile;
use centaur::report::measure_framework;
use centaur::util::bench::Bencher;
use centaur::util::{human_bytes, human_secs};

/// Per-token decode cost: the pre-KV-cache full-recompute path vs warm
/// incremental decode (ISSUE acceptance: ≥3× less comm per token for an
/// 8-step generation at `n_ctx = 64`).
fn bench_decode(b: &mut Bencher) {
    let cfg = ModelConfig::gpt2_tiny().with_n_ctx(64);
    let w = ModelWeights::random(&cfg, 7);
    let prompt: Vec<u32> = vec![7, 11, 13, 17];
    let steps = 8usize;

    b.section("gpt2-tiny @ n_ctx=64 — per-token decode: full recompute vs KV cache");
    let mut full_cost = None;
    b.bench("full recompute x8 tokens", || {
        let mut e = CentaurEngine::new(&cfg, &w, NetworkProfile::lan(), 8).unwrap();
        let (_, cost) = e.generate_full_recompute(&prompt, steps).unwrap();
        full_cost = Some(cost);
    });
    let mut split = None;
    b.bench("incremental decode x8 tokens", || {
        let mut e = CentaurEngine::new(&cfg, &w, NetworkProfile::lan(), 8).unwrap();
        let mut sess = DecoderSession::new(&mut e, &prompt).unwrap();
        for _ in 0..steps {
            sess.step_greedy().unwrap();
        }
        split = Some((sess.prefill_cost().clone(), sess.decode_cost().clone()));
    });
    let full = full_cost.unwrap();
    let (prefill, decode) = split.unwrap();
    let full_tok = full.bytes_total() / steps as u64;
    let warm_tok = decode.bytes_total() / steps as u64;
    println!(
        "    -> full recompute : {}/token | LAN {} WAN1 {} WAN2 {}",
        human_bytes(full_tok),
        human_secs(full.total_time(&NetworkProfile::lan()) / steps as f64),
        human_secs(full.total_time(&NetworkProfile::wan1()) / steps as f64),
        human_secs(full.total_time(&NetworkProfile::wan2()) / steps as f64),
    );
    println!(
        "    -> warm KV decode : {}/token | LAN {} WAN1 {} WAN2 {} | cold prefill {} ({} tokens)",
        human_bytes(warm_tok),
        human_secs(decode.total_time(&NetworkProfile::lan()) / steps as f64),
        human_secs(decode.total_time(&NetworkProfile::wan1()) / steps as f64),
        human_secs(decode.total_time(&NetworkProfile::wan2()) / steps as f64),
        human_bytes(prefill.bytes_total()),
        prompt.len(),
    );
    println!(
        "    -> per-token comm ratio: {:.2}x (acceptance floor: 3x)",
        full_tok as f64 / warm_tok as f64
    );
    assert!(full_tok >= 3 * warm_tok, "KV-cache decode must be >=3x cheaper per token");
}

fn main() {
    let mut b = Bencher::new();
    let quick = std::env::var("CENTAUR_BENCH_QUICK").is_ok();
    let models: Vec<&str> =
        if quick { vec!["bert-tiny"] } else { vec!["bert-tiny", "bert-base", "gpt2-base"] };

    bench_decode(&mut b);

    for model in models {
        let cfg = ModelConfig::by_name(model).unwrap();
        b.section(&format!("{model} — measure_framework (extrapolated)"));
        for kind in [FrameworkKind::Centaur, FrameworkKind::Puma] {
            let mut last = None;
            b.bench(&format!("{} {model}", kind.name()), || {
                last = Some(measure_framework(kind, &cfg, 3, true).unwrap());
            });
            let ledger = last.unwrap();
            println!(
                "    -> comm {} | LAN {} | WAN1 {} | WAN2 {}",
                human_bytes(ledger.bytes_total()),
                human_secs(ledger.total_time(&NetworkProfile::lan())),
                human_secs(ledger.total_time(&NetworkProfile::wan1())),
                human_secs(ledger.total_time(&NetworkProfile::wan2())),
            );
        }
    }
}
