//! Per-layer benchmarks: one full Centaur transformer layer vs the SMPC
//! baselines at tiny and base shapes, plus the Π_PPP-placement ablation
//! (DESIGN ablation a) and backend comparison (ablation e).

use centaur::baselines::{smpc::SmpcEngine, FrameworkKind, PptiFramework};
use centaur::engine::{CentaurEngine, EngineOptions};
use centaur::model::{ModelConfig, ModelWeights};
use centaur::net::NetworkProfile;
use centaur::runtime::NativeBackend;
use centaur::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new();

    // -------- tiny model, full protocol fidelity --------
    let cfg = ModelConfig::bert_tiny();
    let w = ModelWeights::random(&cfg, 7);
    let tokens: Vec<u32> = (0..cfg.n_ctx).map(|i| (4 + i % 100) as u32).collect();

    b.section("full inference, bert-tiny (full-fidelity protocols)");
    let mut cent = CentaurEngine::new(&cfg, &w, NetworkProfile::lan(), 9).unwrap();
    b.bench("centaur", || {
        std::hint::black_box(cent.infer(&tokens).unwrap());
    });
    for kind in FrameworkKind::SMPC_BASELINES {
        let mut eng = SmpcEngine::new(kind, &cfg, &w, NetworkProfile::lan(), 9).unwrap();
        b.bench(kind.name(), || {
            std::hint::black_box(eng.infer(&tokens).unwrap());
        });
    }

    // -------- ablation (e): fast-sim vs full protocols --------
    b.section("ablation: fast-sim (charged-ideal) vs full Beaver, bert-tiny");
    let mut fast = CentaurEngine::with_backend(
        &cfg,
        &w,
        Box::new(NativeBackend::new()),
        EngineOptions { fast_sim: true, seed: 9, ..Default::default() },
    )
    .unwrap();
    b.bench("centaur fast-sim", || {
        std::hint::black_box(fast.infer(&tokens).unwrap());
    });

    // -------- base-scale single layer (fast-sim) --------
    b.section("1-layer bert-base (fast-sim; layer cost for extrapolation)");
    let base1 = ModelConfig::bert_base().with_layers(1);
    let wb = ModelWeights::random(&base1, 11);
    let toks: Vec<u32> = (0..base1.n_ctx).map(|i| (4 + i % 1000) as u32).collect();
    let mut cb = CentaurEngine::with_backend(
        &base1,
        &wb,
        Box::new(NativeBackend::new()),
        EngineOptions { fast_sim: true, seed: 11, ..Default::default() },
    )
    .unwrap();
    b.bench("centaur 1-layer base", || {
        std::hint::black_box(cb.infer(&toks).unwrap());
    });
    let mut pb = SmpcEngine::new(FrameworkKind::Puma, &base1, &wb, NetworkProfile::lan(), 11).unwrap();
    b.bench("puma 1-layer base", || {
        std::hint::black_box(pb.infer(&toks).unwrap());
    });
}
