//! L3 hot-path microbenchmarks: the Z_{2^64} ring matmul (every Π_ScalMul
//! and Beaver product lowers to it) + tile-size ablation (DESIGN ablation d).
//!
//! Run: `cargo bench --bench bench_ring` (CENTAUR_BENCH_QUICK=1 for smoke).

use centaur::ring;
use centaur::runtime::kernel;
use centaur::runtime::RingKernel;
use centaur::tensor::RingTensor;
use centaur::util::bench::Bencher;
use centaur::util::rng::Rng;

fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> RingTensor {
    RingTensor::from_vec(r, c, rng.vec_i64(r * c))
}

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::new(42);

    b.section("kernel dispatch — §Perf iteration 5 (per-kernel A/B, EXPERIMENTS.md)");
    let selected = kernel::selected_name();
    for d in kernel::available_kernels() {
        let mark = if d.name == selected { " <- selected" } else { "" };
        println!("  registry: {:<7} available={:<5} ({}){mark}", d.name, d.available, d.detail);
    }
    // (scalar Gmac/s, selected Gmac/s) on the FFN shape for the smoke gate.
    let mut ffn_scalar = 0.0f64;
    let mut ffn_selected = 0.0f64;
    for (m, k, n, label) in [
        (128usize, 768usize, 768usize, "qkv/wo 128x768x768"),
        (128, 768, 3072, "ffn-up 128x768x3072"),
        (128, 128, 128, "attention 128x128x128"),
    ] {
        let a = rand_mat(&mut rng, m, k);
        let w = rand_mat(&mut rng, n, k); // stored (out,in) for matmul_nt
        let macs = (m * k * n) as f64;
        for d in kernel::available_kernels() {
            if !d.available || d.name == "xla" {
                continue;
            }
            let kern = kernel::kernel_by_name(d.name).expect("probed available");
            let s = b.bench(&format!("{label} [{}]", d.name), || {
                std::hint::black_box(kern.matmul_nt(&a, &w));
            });
            let gmacs = macs / s.median.as_secs_f64() / 1e9;
            println!("    -> {gmacs:.2} Gmac/s [{}]", d.name);
            if label.starts_with("ffn-up") {
                if d.name == "scalar" {
                    ffn_scalar = gmacs;
                }
                if d.name == selected {
                    ffn_selected = gmacs;
                }
            }
        }
    }
    // CI smoke gate: the auto-selected kernel must not be slower than the
    // scalar fallback on the FFN hot shape (0.9 slack for timer noise on
    // shared runners). A SIMD kernel losing to scalar means the dispatch
    // order is lying about this host.
    if selected != "scalar" && ffn_scalar > 0.0 {
        assert!(
            ffn_selected >= 0.9 * ffn_scalar,
            "selected kernel '{selected}' ({ffn_selected:.2} Gmac/s) slower than scalar \
             ({ffn_scalar:.2} Gmac/s) on 128x768x3072"
        );
        println!(
            "  smoke OK: {selected} {:.2}x scalar on 128x768x3072",
            ffn_selected / ffn_scalar
        );
    }

    b.section("ring matmul — Centaur linear-layer shapes (bert-base, n=128)");
    for (m, k, n, label) in [
        (128usize, 768usize, 768usize, "qkv/wo 128x768x768"),
        (128, 768, 3072, "ffn-up 128x768x3072"),
        (128, 3072, 768, "ffn-down 128x3072x768"),
        (128, 128, 128, "attention 128x128x128"),
    ] {
        let a = rand_mat(&mut rng, m, k);
        let w = rand_mat(&mut rng, n, k); // stored (out,in) for matmul_nt
        b.bench(&format!("matmul_nt {label}"), || {
            std::hint::black_box(ring::matmul_nt(&a, &w));
        });
        let macs = (m * k * n) as f64;
        let t = b.results().last().unwrap().median.as_secs_f64();
        println!("    -> {:.2} Gmac/s", macs / t / 1e9);
    }

    b.section("perf iteration 1: bounds-checked indexed loop vs chunks_exact");
    {
        // the pre-optimization inner kernel, kept for the §Perf A/B
        fn dot_indexed(a: &[i64], b: &[i64]) -> i64 {
            let (mut a0, mut a1, mut a2, mut a3) = (0i64, 0i64, 0i64, 0i64);
            let mut i = 0;
            let len = a.len();
            while i + 4 <= len {
                a0 = a0.wrapping_add(a[i].wrapping_mul(b[i]));
                a1 = a1.wrapping_add(a[i + 1].wrapping_mul(b[i + 1]));
                a2 = a2.wrapping_add(a[i + 2].wrapping_mul(b[i + 2]));
                a3 = a3.wrapping_add(a[i + 3].wrapping_mul(b[i + 3]));
                i += 4;
            }
            while i < len {
                a0 = a0.wrapping_add(a[i].wrapping_mul(b[i]));
                i += 1;
            }
            a0.wrapping_add(a1).wrapping_add(a2).wrapping_add(a3)
        }
        let x = rand_mat(&mut rng, 128, 768);
        let w = rand_mat(&mut rng, 768, 768);
        b.bench("indexed dot 128x768x768 (before)", || {
            let mut out = vec![0i64; 128 * 768];
            for r in 0..128 {
                for c in 0..768 {
                    out[r * 768 + c] = dot_indexed(x.row(r), w.row(c));
                }
            }
            std::hint::black_box(out);
        });
        b.bench("matmul_nt 128x768x768 (after)", || {
            std::hint::black_box(ring::matmul_nt(&x, &w));
        });
    }

    b.section("blocked vs naive (256x256x256)");
    let a = rand_mat(&mut rng, 256, 256);
    let bm = rand_mat(&mut rng, 256, 256);
    b.bench("blocked", || {
        std::hint::black_box(ring::matmul(&a, &bm));
    });
    b.bench("naive", || {
        std::hint::black_box(ring::matmul_naive(&a, &bm));
    });

    b.section("elementwise ring ops (128x3072)");
    let x = rand_mat(&mut rng, 128, 3072);
    let y = rand_mat(&mut rng, 128, 3072);
    b.bench("add", || {
        std::hint::black_box(ring::add(&x, &y));
    });
    b.bench("mul_elem", || {
        std::hint::black_box(ring::mul_elem(&x, &y));
    });
}
