//! Protocol-level benchmarks (Table 1 companions): wall cost of each MPC
//! primitive and each Π_PP* conversion at paper-relevant shapes, plus the
//! offline/online split of the Beaver path (EXPERIMENTS.md §Offline-phase
//! reporting).

use std::sync::Arc;
use std::time::Duration;

use centaur::engine::views::Views;
use centaur::fixed;
use centaur::mpc::{nonlin as smpc_nonlin, Mpc, TriplePool, TripleShape};
use centaur::net::{NetSim, NetworkProfile, OpClass};
use centaur::protocols::nonlin;
use centaur::runtime::NativeBackend;
use centaur::tensor::FloatTensor;
use centaur::util::bench::Bencher;

fn mk() -> Mpc {
    Mpc::new(NetSim::new(NetworkProfile::lan()), 7)
}

fn main() {
    let mut b = Bencher::new();
    let x = FloatTensor::from_fn(128, 128, |r, c| ((r + c) % 17) as f32 * 0.1 - 0.8);
    let x_fx = fixed::encode_tensor(&x);

    b.section("MPC primitives on 128x128");
    b.bench("share_local", || {
        let mut mpc = mk();
        std::hint::black_box(mpc.share_local(&x_fx));
    });
    b.bench("Pi_ScalMul", || {
        let mut mpc = mk();
        let a = mpc.share_local(&x_fx);
        std::hint::black_box(mpc.scalmul(&x_fx, &a, OpClass::Linear));
    });
    b.bench("Pi_MatMul (beaver)", || {
        let mut mpc = mk();
        let a = mpc.share_local(&x_fx);
        let y = mpc.share_local(&x_fx);
        std::hint::black_box(mpc.matmul(&a, &y, OpClass::Linear));
    });
    b.bench("Pi_MatMul (charged-ideal)", || {
        let mut mpc = mk();
        let a = mpc.share_local(&x_fx);
        let y = mpc.share_local(&x_fx);
        std::hint::black_box(mpc.matmul_charged_ideal(&a, &y, OpClass::Linear));
    });
    b.bench("square", || {
        let mut mpc = mk();
        let a = mpc.share_local(&x_fx);
        std::hint::black_box(mpc.square(&a, OpClass::Softmax));
    });

    b.section("Centaur Pi_PP* conversions (state switch + plaintext op)");
    b.bench("Pi_PPSM 128x128", || {
        let mut mpc = mk();
        let mut be = NativeBackend::new();
        let mut views = Views::new(false);
        let a = mpc.share_local(&x_fx);
        std::hint::black_box(nonlin::pp_softmax(&mut mpc, &mut be, &mut views, &a, "b").unwrap());
    });
    let big = FloatTensor::from_fn(128, 3072, |r, c| ((r * 7 + c) % 23) as f32 * 0.05 - 0.5);
    let big_fx = fixed::encode_tensor(&big);
    b.bench("Pi_PPGeLU 128x3072", || {
        let mut mpc = mk();
        let mut be = NativeBackend::new();
        let mut views = Views::new(false);
        let a = mpc.share_local(&big_fx);
        std::hint::black_box(nonlin::pp_gelu(&mut mpc, &mut be, &mut views, &a, "b").unwrap());
    });

    b.section("offline/online split of Pi_MatMul 64x64 (Beaver)");
    {
        let y = FloatTensor::from_fn(64, 64, |r, c| ((r * 5 + c) % 13) as f32 * 0.2 - 1.1);
        let y_fx = fixed::encode_tensor(&y);
        // Bounded iterations so the online-only bench cannot outrun the
        // prefilled stock (which would silently re-measure the cold path).
        let mut bs = Bencher::with(Duration::from_millis(300), 48, 1);
        bs.bench("offline only: matmul_triple 64x64x64", || {
            let mut mpc = mk();
            std::hint::black_box(mpc.dealer.matmul_triple(64, 64, 64));
        });
        let offline = bs.results().last().unwrap().median;
        let pool = Arc::new(TriplePool::new(9, 64));
        let _ = pool.take(TripleShape::matmul(64, 64, 64)); // register demand
        pool.fill_to_target(); // stock 64 entries
        bs.bench("online only: Pi_MatMul from prefilled pool", || {
            let mut mpc = mk();
            mpc.dealer.attach_pool(Arc::clone(&pool));
            let sx = mpc.share_local(&y_fx);
            let sy = mpc.share_local(&y_fx);
            std::hint::black_box(mpc.matmul(&sx, &sy, OpClass::Linear));
        });
        let online = bs.results().last().unwrap().median;
        bs.bench("offline+online: Pi_MatMul with cold dealer", || {
            let mut mpc = mk();
            let sx = mpc.share_local(&y_fx);
            let sy = mpc.share_local(&y_fx);
            std::hint::black_box(mpc.matmul(&sx, &sy, OpClass::Linear));
        });
        let cold = bs.results().last().unwrap().median;
        println!(
            "    -> split: offline {} + online {} vs cold {} (pool hits {}, misses {})",
            centaur::util::human_secs(offline.as_secs_f64()),
            centaur::util::human_secs(online.as_secs_f64()),
            centaur::util::human_secs(cold.as_secs_f64()),
            pool.hits(),
            pool.misses(),
        );
    }

    b.section("SMPC baselines' non-linear ops (what PUMA pays)");
    b.bench("smpc softmax 128x128", || {
        let mut mpc = mk();
        let a = mpc.share_local(&x_fx);
        std::hint::black_box(smpc_nonlin::softmax(&mut mpc, &a, OpClass::Softmax));
    });
    let med = FloatTensor::from_fn(128, 768, |r, c| ((r + 3 * c) % 11) as f32 * 0.1 - 0.5);
    let med_fx = fixed::encode_tensor(&med);
    b.bench("smpc gelu 128x768", || {
        let mut mpc = mk();
        let a = mpc.share_local(&med_fx);
        std::hint::black_box(smpc_nonlin::gelu(&mut mpc, &a, OpClass::Gelu));
    });
    b.bench("smpc layernorm 128x768", || {
        let mut mpc = mk();
        let a = mpc.share_local(&med_fx);
        let g = mpc.share_local(&fixed::encode_tensor(&FloatTensor::from_fn(1, 768, |_, _| 1.0)));
        let be = mpc.share_local(&fixed::encode_tensor(&FloatTensor::zeros(1, 768)));
        std::hint::black_box(smpc_nonlin::layernorm(&mut mpc, &a, &g, &be, 1e-5, OpClass::LayerNorm));
    });
}
