//! Serving-layer benchmark: coordinator throughput/latency across batch
//! sizes (DESIGN ablation b: batching policy).

use centaur::baselines::FrameworkKind;
use centaur::coordinator::{Coordinator, ServerConfig};
use centaur::model::{ModelConfig, ModelWeights};
use centaur::util::bench::Bencher;
use std::time::Duration;

fn main() {
    let mut b = Bencher::new();
    let cfg = ModelConfig::bert_tiny();
    let weights = ModelWeights::random(&cfg, 5);
    let n_req = if std::env::var("CENTAUR_BENCH_QUICK").is_ok() { 8 } else { 24 };

    for batch in [1usize, 4, 8] {
        b.section(&format!("coordinator, batch<={batch}, {n_req} requests"));
        let mut sc = ServerConfig::new(cfg.clone(), weights.clone());
        sc.framework = FrameworkKind::Centaur;
        sc.max_batch = batch;
        sc.linger = Duration::from_millis(2);
        let coord = Coordinator::start(sc).unwrap();
        b.bench(&format!("serve {n_req} reqs (batch {batch})"), || {
            let rxs: Vec<_> =
                (0..n_req).map(|i| coord.submit(vec![(4 + i % 100) as u32; cfg.n_ctx])).collect();
            for rx in rxs {
                rx.recv().unwrap().unwrap();
            }
        });
        let snap = coord.shutdown();
        println!("    -> {}", snap.summary());
    }
}
