//! Serving-layer benchmark: coordinator throughput/latency across batch
//! sizes (DESIGN ablation b: batching policy) and the offline-phase
//! amortization of a prefilled TriplePool (cold vs warm requests).

use centaur::baselines::FrameworkKind;
use centaur::coordinator::{Coordinator, MetricsSnapshot, ServerConfig, StreamEvent};
use centaur::model::{ModelConfig, ModelWeights};
use centaur::net::NetworkProfile;
use centaur::util::bench::Bencher;
use std::time::{Duration, Instant};

/// Serve `n_req` sequential requests; returns the final metrics snapshot
/// (per-request latency lives in its p50/p95).
fn serve_sequential(sc: ServerConfig, n_req: usize, n_ctx: usize) -> MetricsSnapshot {
    let coord = Coordinator::start(sc).unwrap();
    for i in 0..n_req {
        coord.infer_blocking(vec![(4 + i % 100) as u32; n_ctx]).unwrap();
    }
    coord.shutdown()
}

/// Serve `sessions` concurrent generate streams through the decode
/// scheduler (gpt2-tiny, all submitted before any are drained so they
/// ride the same continuously-batched steps); returns the snapshot with
/// the batched-decode counters. `spec_k > 1` turns on speculative
/// verify steps (tiny-model draft over the serving weights).
fn serve_batched_decode(
    sessions: usize,
    steps: usize,
    profile: NetworkProfile,
    spec_k: usize,
) -> MetricsSnapshot {
    let cfg = ModelConfig::gpt2_tiny();
    let weights = ModelWeights::random(&cfg, 9);
    let mut sc = ServerConfig::new(cfg, weights);
    sc.framework = FrameworkKind::Centaur;
    sc.max_batch = sessions;
    sc.linger = Duration::from_millis(1);
    sc.profile = profile;
    sc.spec_k = spec_k;
    let coord = Coordinator::start(sc).unwrap();
    let rxs: Vec<_> = (0..sessions as u32)
        .map(|i| coord.submit_generate(vec![5 + i, 9, 13 + i], steps))
        .collect();
    for rx in rxs {
        loop {
            match rx.recv().unwrap().unwrap() {
                StreamEvent::Done(_) => break,
                StreamEvent::Token { .. } => {}
            }
        }
    }
    coord.shutdown()
}

/// Serve `sessions` concurrent generate streams with the offline phase
/// provisioned for exactly that mix (synchronous prefill + background
/// [`centaur::mpc::PoolService`]); returns the final snapshot plus the
/// measured server-start time — probe inference and the synchronous pool
/// fill, i.e. the batched shard-refill path (full per-shape deficit under
/// two lock trips instead of two per triple).
fn serve_offline_streams(sessions: usize, steps: usize) -> (MetricsSnapshot, Duration) {
    let cfg = ModelConfig::gpt2_tiny();
    let weights = ModelWeights::random(&cfg, 9);
    let mut sc = ServerConfig::new(cfg, weights);
    sc.framework = FrameworkKind::Centaur;
    sc.max_batch = sessions;
    sc.linger = Duration::from_millis(1);
    sc.offline_prefill = true;
    sc.pool_depth = 2;
    sc.decode_prefill_steps = 3 + steps; // 3-token prompt + generated steps
    sc.decode_prefill_sessions = sessions;
    let t0 = Instant::now();
    let coord = Coordinator::start(sc).unwrap();
    let started = t0.elapsed();
    let rxs: Vec<_> = (0..sessions as u32)
        .map(|i| coord.submit_generate(vec![5 + i, 9, 13 + i], steps))
        .collect();
    for rx in rxs {
        loop {
            match rx.recv().unwrap().unwrap() {
                StreamEvent::Done(_) => break,
                StreamEvent::Token { .. } => {}
            }
        }
    }
    (coord.shutdown(), started)
}

fn main() {
    // CI smoke gate: only the offline-phase service section, with the
    // starvation acceptance asserted — at B=8 the warm decode path must
    // be entirely pool-served: hit-rate >= 0.99 and zero online-path
    // generation events after the prefill baseline.
    if std::env::var("CENTAUR_BENCH_OFFLINE_ONLY").is_ok() {
        let (snap, started) = serve_offline_streams(8, 3);
        println!(
            "offline-only smoke: warm_hit_rate={:.1}% warm_starved={} \
             offline_triples={} ({:.0}/s) start={}",
            snap.warm_pool_hit_rate() * 100.0,
            snap.warm_pool_starved,
            snap.pool_generated,
            snap.offline_triples_per_sec(),
            centaur::util::human_secs(started.as_secs_f64()),
        );
        assert!(snap.warm_pool_hits > 0, "warm sessions never drew from the pool");
        assert_eq!(
            snap.warm_pool_starved, 0,
            "online-path triple generation on a provisioned shape"
        );
        assert!(
            snap.warm_pool_hit_rate() >= 0.99,
            "warm pool hit-rate {:.3} below 0.99",
            snap.warm_pool_hit_rate()
        );
        println!("offline-only smoke OK");
        return;
    }

    // CI smoke gate: only the continuous-batching section, with the
    // amortization acceptance asserted — B=4 must at least halve the
    // B=1 wire rounds per token (the ideal is solo/4).
    if std::env::var("CENTAUR_BENCH_DECODE_ONLY").is_ok() {
        let steps = 4;
        let solo = serve_batched_decode(1, steps, NetworkProfile::lan(), 1);
        let b4 = serve_batched_decode(4, steps, NetworkProfile::lan(), 1);
        let (r1, r4) = (solo.batched_rounds_per_token(), b4.batched_rounds_per_token());
        println!("decode-only smoke: B=1 rounds/token={r1:.2}, B=4 rounds/token={r4:.2}");
        assert!(r1 > 0.0 && r4 > 0.0, "decode scheduler recorded no batched steps");
        assert!(
            r4 <= 0.5 * r1,
            "B=4 amortized rounds/token {r4:.2} not <= half of B=1 ({r1:.2})"
        );
        assert!(b4.max_batch_sessions >= 2, "sessions never shared a decode step");
        // Speculative smoke: a solo spec_k=4 stream amortizes its verify
        // chains over accepted tokens, landing below the plain solo
        // rounds/token, with acceptance counters in the snapshot.
        let spec = serve_batched_decode(1, steps, NetworkProfile::lan(), 4);
        let rs = spec.batched_rounds_per_token();
        println!(
            "decode-only smoke: spec_k=4 rounds/accepted-token={rs:.2} accept={:.0}%",
            spec.spec_acceptance_rate() * 100.0
        );
        assert!(spec.spec_proposed > 0, "spec_k=4 never proposed a draft token");
        assert!(rs < r1, "speculative rounds/accepted {rs:.2} not below plain solo {r1:.2}");
        println!("decode-only smoke OK");
        return;
    }

    let mut b = Bencher::new();
    let cfg = ModelConfig::bert_tiny();
    let weights = ModelWeights::random(&cfg, 5);
    let n_req = if std::env::var("CENTAUR_BENCH_QUICK").is_ok() { 8 } else { 24 };

    for batch in [1usize, 4, 8] {
        b.section(&format!("coordinator, batch<={batch}, {n_req} requests"));
        let mut sc = ServerConfig::new(cfg.clone(), weights.clone());
        sc.framework = FrameworkKind::Centaur;
        sc.max_batch = batch;
        sc.linger = Duration::from_millis(2);
        let coord = Coordinator::start(sc).unwrap();
        b.bench(&format!("serve {n_req} reqs (batch {batch})"), || {
            let rxs: Vec<_> =
                (0..n_req).map(|i| coord.submit(vec![(4 + i % 100) as u32; cfg.n_ctx])).collect();
            for rx in rxs {
                rx.recv().unwrap().unwrap();
            }
        });
        let snap = coord.shutdown();
        println!("    -> {}", snap.summary());
    }

    // Offline-phase amortization: identical request streams through a cold
    // dealer (triples generated on the request path) vs a coordinator that
    // prefilled a shared TriplePool at start. Warm per-request latency must
    // sit below cold — the offline cost moved to server start + background
    // refill.
    b.section(&format!("offline amortization: cold dealer vs prefilled pool, {n_req} requests"));
    let mk_sc = |prefill: bool| {
        let mut sc = ServerConfig::new(cfg.clone(), weights.clone());
        sc.framework = FrameworkKind::Centaur;
        sc.max_batch = 1;
        sc.linger = Duration::from_millis(1);
        sc.offline_prefill = prefill;
        sc.pool_depth = 2;
        sc
    };
    let cold = serve_sequential(mk_sc(false), n_req, cfg.n_ctx);
    let warm = serve_sequential(mk_sc(true), n_req, cfg.n_ctx);
    println!(
        "cold  (per-request offline+online): p50={} p95={}",
        centaur::util::human_secs(cold.p50.as_secs_f64()),
        centaur::util::human_secs(cold.p95.as_secs_f64()),
    );
    println!(
        "warm  (online only, pool hit-rate {:.1}%): p50={} p95={}",
        warm.pool_hit_rate() * 100.0,
        centaur::util::human_secs(warm.p50.as_secs_f64()),
        centaur::util::human_secs(warm.p95.as_secs_f64()),
    );
    let speedup = cold.p50.as_secs_f64() / warm.p50.as_secs_f64().max(1e-12);
    println!(
        "    -> warm p50 is {:.2}x {} than cold p50",
        if speedup >= 1.0 { speedup } else { 1.0 / speedup },
        if speedup >= 1.0 { "faster" } else { "SLOWER" },
    );
    println!("    -> warm {}", warm.summary());

    // Offline phase as a service (DESIGN.md §Offline phase): B concurrent
    // generate streams against a pool provisioned for exactly that mix.
    // The table is the serving-side acceptance — the warm decode path
    // never waits on triple generation at any request rate, and the
    // dealer's offline throughput (triples/s, bytes/s) and per-shard pool
    // depth are first-class metrics. `start` includes the synchronous
    // prefill, i.e. the batched shard refill: each shape's full deficit
    // is generated under two lock trips instead of two per triple.
    let off_steps = if std::env::var("CENTAUR_BENCH_QUICK").is_ok() { 2 } else { 3 };
    b.section(&format!("offline service: gpt2-tiny, {off_steps}-step generates, B streams"));
    for sessions in [1usize, 2, 4, 8] {
        let (snap, started) = serve_offline_streams(sessions, off_steps);
        let depth_min = snap.pool_shard_depths.iter().min().copied().unwrap_or(0);
        let depth_max = snap.pool_shard_depths.iter().max().copied().unwrap_or(0);
        println!(
            "  B={sessions}: triples/s={:.0} offline={}/s pool_depth={} \
             shard_depth={depth_min}..{depth_max} warm_hit_rate={:.1}% starved={} start={}",
            snap.offline_triples_per_sec(),
            centaur::util::human_bytes(snap.offline_bytes_per_sec() as u64),
            snap.pool_pooled,
            snap.warm_pool_hit_rate() * 100.0,
            snap.warm_pool_starved,
            centaur::util::human_secs(started.as_secs_f64()),
        );
        assert_eq!(
            snap.warm_pool_starved, 0,
            "B={sessions}: warm request generated triples on the online path"
        );
    }

    // Continuous batching (DESIGN.md §Continuous batching): B concurrent
    // generate sessions ride every decode step's shared flights, so wire
    // rounds amortize to (solo rounds)/B per token while bytes/token stay
    // flat (each lane still ships its own payloads). The modeled s/token
    // is rounds·RTT + bytes/bandwidth — on WAN the rounds term dominates,
    // which is exactly what batching divides by B.
    let gen_steps = if std::env::var("CENTAUR_BENCH_QUICK").is_ok() { 3 } else { 8 };
    for pname in ["lan", "wan3"] {
        let profile = NetworkProfile::by_name(pname).unwrap();
        b.section(&format!(
            "continuous batching: gpt2-tiny, {gen_steps}-step generates, {pname}"
        ));
        let mut solo_rpt = 0.0f64;
        for sessions in [1usize, 2, 4, 8] {
            let snap = serve_batched_decode(sessions, gen_steps, profile, 1);
            let rpt = snap.batched_rounds_per_token();
            if sessions == 1 {
                solo_rpt = rpt;
            }
            let bytes_per_token = if snap.tokens_generated == 0 {
                0.0
            } else {
                snap.decode_bytes as f64 / snap.tokens_generated as f64
            };
            let s_per_token = rpt * profile.rtt + bytes_per_token * 8.0 / profile.bandwidth_bps;
            println!(
                "  B={sessions}: rounds/token={rpt:.2} ({:.2}x solo) bytes/token={} \
                 modeled s/token={} max_lanes={} tokens={}",
                if solo_rpt > 0.0 { rpt / solo_rpt } else { 1.0 },
                centaur::util::human_bytes(bytes_per_token as u64),
                centaur::util::human_secs(s_per_token),
                snap.max_batch_sessions,
                snap.tokens_generated,
            );
        }
    }

    // Speculative decode through the serving path (ISSUE 7): a solo
    // stream rides k verify lanes per 16-round flight chain, so the
    // rounds term amortizes over *accepted* tokens — the orthogonal
    // axis to the B-lane batching above (they compose: B sessions × k
    // lanes each).
    for pname in ["lan", "wan3"] {
        let profile = NetworkProfile::by_name(pname).unwrap();
        b.section(&format!("speculative serving: gpt2-tiny solo, {gen_steps}-step generates, {pname}"));
        for spec_k in [1usize, 2, 4, 8] {
            let snap = serve_batched_decode(1, gen_steps, profile, spec_k);
            let rpt = snap.batched_rounds_per_token();
            let bytes_per_token = if snap.tokens_generated == 0 {
                0.0
            } else {
                snap.decode_bytes as f64 / snap.tokens_generated as f64
            };
            let s_per_token = rpt * profile.rtt + bytes_per_token * 8.0 / profile.bandwidth_bps;
            println!(
                "  k={spec_k}: accept={:.0}% ({}/{}) rounds/accepted={rpt:.2} bytes/token={} \
                 modeled s/token={} verify_steps={}",
                snap.spec_acceptance_rate() * 100.0,
                snap.spec_accepted,
                snap.spec_proposed,
                centaur::util::human_bytes(bytes_per_token as u64),
                centaur::util::human_secs(s_per_token),
                snap.batched_decode_steps,
            );
        }
    }
}
