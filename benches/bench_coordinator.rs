//! Serving-layer benchmark: coordinator throughput/latency across batch
//! sizes (DESIGN ablation b: batching policy) and the offline-phase
//! amortization of a prefilled TriplePool (cold vs warm requests).

use centaur::baselines::FrameworkKind;
use centaur::coordinator::{Coordinator, MetricsSnapshot, ServerConfig};
use centaur::model::{ModelConfig, ModelWeights};
use centaur::util::bench::Bencher;
use std::time::Duration;

/// Serve `n_req` sequential requests; returns the final metrics snapshot
/// (per-request latency lives in its p50/p95).
fn serve_sequential(sc: ServerConfig, n_req: usize, n_ctx: usize) -> MetricsSnapshot {
    let coord = Coordinator::start(sc).unwrap();
    for i in 0..n_req {
        coord.infer_blocking(vec![(4 + i % 100) as u32; n_ctx]).unwrap();
    }
    coord.shutdown()
}

fn main() {
    let mut b = Bencher::new();
    let cfg = ModelConfig::bert_tiny();
    let weights = ModelWeights::random(&cfg, 5);
    let n_req = if std::env::var("CENTAUR_BENCH_QUICK").is_ok() { 8 } else { 24 };

    for batch in [1usize, 4, 8] {
        b.section(&format!("coordinator, batch<={batch}, {n_req} requests"));
        let mut sc = ServerConfig::new(cfg.clone(), weights.clone());
        sc.framework = FrameworkKind::Centaur;
        sc.max_batch = batch;
        sc.linger = Duration::from_millis(2);
        let coord = Coordinator::start(sc).unwrap();
        b.bench(&format!("serve {n_req} reqs (batch {batch})"), || {
            let rxs: Vec<_> =
                (0..n_req).map(|i| coord.submit(vec![(4 + i % 100) as u32; cfg.n_ctx])).collect();
            for rx in rxs {
                rx.recv().unwrap().unwrap();
            }
        });
        let snap = coord.shutdown();
        println!("    -> {}", snap.summary());
    }

    // Offline-phase amortization: identical request streams through a cold
    // dealer (triples generated on the request path) vs a coordinator that
    // prefilled a shared TriplePool at start. Warm per-request latency must
    // sit below cold — the offline cost moved to server start + background
    // refill.
    b.section(&format!("offline amortization: cold dealer vs prefilled pool, {n_req} requests"));
    let mk_sc = |prefill: bool| {
        let mut sc = ServerConfig::new(cfg.clone(), weights.clone());
        sc.framework = FrameworkKind::Centaur;
        sc.max_batch = 1;
        sc.linger = Duration::from_millis(1);
        sc.offline_prefill = prefill;
        sc.pool_depth = 2;
        sc
    };
    let cold = serve_sequential(mk_sc(false), n_req, cfg.n_ctx);
    let warm = serve_sequential(mk_sc(true), n_req, cfg.n_ctx);
    println!(
        "cold  (per-request offline+online): p50={} p95={}",
        centaur::util::human_secs(cold.p50.as_secs_f64()),
        centaur::util::human_secs(cold.p95.as_secs_f64()),
    );
    println!(
        "warm  (online only, pool hit-rate {:.1}%): p50={} p95={}",
        warm.pool_hit_rate() * 100.0,
        centaur::util::human_secs(warm.p50.as_secs_f64()),
        centaur::util::human_secs(warm.p95.as_secs_f64()),
    );
    let speedup = cold.p50.as_secs_f64() / warm.p50.as_secs_f64().max(1e-12);
    println!(
        "    -> warm p50 is {:.2}x {} than cold p50",
        if speedup >= 1.0 { speedup } else { 1.0 / speedup },
        if speedup >= 1.0 { "faster" } else { "SLOWER" },
    );
    println!("    -> warm {}", warm.summary());
}
