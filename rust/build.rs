//! Toolchain probe for the SIMD ring kernels.
//!
//! The AVX-512 intrinsics (`_mm512_mullo_epi64` & co) stabilized in rustc
//! 1.89; the crate's MSRV is 1.75. Rather than raise the floor for one
//! optional kernel, probe the compiler version here and expose
//! `cfg(centaur_avx512)` only when the intrinsics exist — older toolchains
//! still build every other kernel and `runtime::kernel` reports the avx512
//! entry as unavailable with this reason.

use std::process::Command;

fn main() {
    println!("cargo:rerun-if-changed=build.rs");
    println!("cargo:rerun-if-env-changed=RUSTC");
    // Declare the custom cfg for rustc's check-cfg (cargo ≥ 1.80 understands
    // the directive; older cargos treat unknown keys as inert metadata).
    println!("cargo:rustc-check-cfg=cfg(centaur_avx512)");
    let rustc = std::env::var("RUSTC").unwrap_or_else(|_| "rustc".to_string());
    let version = Command::new(&rustc)
        .arg("--version")
        .output()
        .ok()
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .unwrap_or_default();
    // Format: "rustc 1.89.0 (…)" or "rustc 1.91.0-nightly (…)".
    if let Some(rest) = version.strip_prefix("rustc ") {
        let mut parts = rest.split(['.', '-', ' ']);
        let major: u64 = parts.next().and_then(|s| s.parse().ok()).unwrap_or(0);
        let minor: u64 = parts.next().and_then(|s| s.parse().ok()).unwrap_or(0);
        if major > 1 || (major == 1 && minor >= 89) {
            println!("cargo:rustc-cfg=centaur_avx512");
        }
    }
}
