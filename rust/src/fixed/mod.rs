//! Fixed-point encoding over the ring `Z_{2^64}` (CrypTen-compatible).
//!
//! Real values are scaled by `2^FRAC_BITS` and rounded to the nearest ring
//! element; the ring is represented by `i64` with **wrapping** arithmetic,
//! so `x = ([x]_0 + [x]_1) mod 2^64` holds exactly (the paper's §2.2, with
//! CrypTen's default 16-bit fixed-point precision).
//!
//! Multiplying two encodings yields scale `2^{2f}`; [`trunc_local`]
//! implements CrypTen's *local probabilistic truncation*: each party right-
//! shifts its own share. With overwhelming probability (values ≪ ring size)
//! the reconstruction is off by at most 1 ULP, which is far below model
//! noise; `fixed::tests` quantifies the error.

use crate::tensor::{FloatTensor, RingTensor};

/// Fractional bits of the fixed-point encoding (CrypTen default).
pub const FRAC_BITS: u32 = 16;
/// Scale factor `2^FRAC_BITS`.
pub const SCALE: i64 = 1 << FRAC_BITS;
/// Bytes per ring element on the wire.
pub const ELEM_BYTES: u64 = 8;

/// Encode one real number.
#[inline]
pub fn encode(x: f64) -> i64 {
    let v = x * SCALE as f64;
    // round-half-away-from-zero, wrapping into the ring
    let r = if v >= 0.0 { (v + 0.5).floor() } else { (v - 0.5).ceil() };
    r as i64
}

/// Decode one ring element back to a real number.
#[inline]
pub fn decode(v: i64) -> f64 {
    v as f64 / SCALE as f64
}

/// Encode an `f32` tensor into a ring tensor.
pub fn encode_tensor(t: &FloatTensor) -> RingTensor {
    t.map(|x| encode(x as f64))
}

/// Decode a ring tensor into `f32`.
pub fn decode_tensor(t: &RingTensor) -> FloatTensor {
    t.map(|v| decode(v) as f32)
}

/// After a fixed×fixed product the scale is `2^{2f}`; rescale a *plaintext*
/// value exactly.
#[inline]
pub fn rescale_plain(v: i64) -> i64 {
    v >> FRAC_BITS
}

/// CrypTen-style local truncation of a *share* by `2^FRAC_BITS`.
///
/// Party 0 computes `floor(s / 2^f)`; party 1 computes `-floor(-s / 2^f)`,
/// i.e. both divide their share as signed integers. The reconstructed value
/// equals the truncated plaintext ±1 with overwhelming probability when the
/// plaintext magnitude is ≪ 2^63 (standard CrypTen assumption).
#[inline]
pub fn trunc_share(share: i64, party: usize) -> i64 {
    debug_assert!(party < 2);
    if party == 0 {
        share >> FRAC_BITS
    } else {
        // -floor(-s / 2^f) == ceil(s / 2^f) for the second share keeps the
        // expected reconstruction unbiased.
        (share >> FRAC_BITS).wrapping_add(if share & (SCALE - 1) != 0 { 1 } else { 0 })
    }
}

/// Truncate a whole share tensor in place.
pub fn trunc_share_tensor(t: &mut RingTensor, party: usize) {
    for v in t.data_mut() {
        *v = trunc_share(*v, party);
    }
}

/// Largest representable magnitude before encode saturating behaviour is
/// meaningless (half ring, at fixed scale).
pub fn max_representable() -> f64 {
    (i64::MAX as f64) / SCALE as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn encode_decode_roundtrip_small() {
        for &x in &[0.0, 1.0, -1.0, 3.14159, -2.71828, 1e-4, -1e-4, 1000.5] {
            let err = (decode(encode(x)) - x).abs();
            assert!(err <= 1.0 / SCALE as f64, "x={x} err={err}");
        }
    }

    #[test]
    fn prop_roundtrip_error_bounded() {
        check("fixed roundtrip", 500, |g| {
            let x = g.f64_in(-1e4, 1e4);
            let err = (decode(encode(x)) - x).abs();
            assert!(err <= 0.5 / SCALE as f64 + 1e-12, "x={x} err={err}");
        });
    }

    #[test]
    fn prop_encode_additive_homomorphic() {
        check("encode additive", 500, |g| {
            let a = g.small_f64();
            let b = g.small_f64();
            let sum = decode(encode(a).wrapping_add(encode(b)));
            assert!((sum - (a + b)).abs() < 2.0 / SCALE as f64);
        });
    }

    #[test]
    fn product_rescale() {
        let a = encode(3.5);
        let b = encode(-2.0);
        let prod = rescale_plain(a.wrapping_mul(b));
        assert!((decode(prod) - (-7.0)).abs() < 1e-3);
    }

    #[test]
    fn share_truncation_error_at_most_one_ulp() {
        let mut rng = Rng::new(99);
        let mut worst = 0i64;
        for _ in 0..20_000 {
            let x = rng.range_i64(-(1 << 40), 1 << 40); // plaintext at scale 2^{2f}
            let s0 = rng.next_i64();
            let s1 = x.wrapping_sub(s0);
            let recon = trunc_share(s0, 0).wrapping_add(trunc_share(s1, 1));
            let truth = x >> FRAC_BITS;
            worst = worst.max((recon - truth).abs());
        }
        assert!(worst <= 1, "worst truncation error {worst} ULP");
    }

    #[test]
    fn tensor_encode_decode() {
        let t = crate::tensor::FloatTensor::from_fn(3, 3, |r, c| (r as f32 - c as f32) * 0.25);
        let rt = encode_tensor(&t);
        let back = decode_tensor(&rt);
        assert!(t.max_abs_diff(&back) <= 1.0 / SCALE as f32);
    }
}
