//! # Centaur — hybrid privacy-preserving transformer inference
//!
//! Reproduction of *"Centaur: Bridging the Impossible Trinity of Privacy,
//! Efficiency, and Performance in Privacy-Preserving Transformer Inference"*
//! (ACL 2025).
//!
//! Centaur protects **model parameters with random permutations** and
//! **inference data with 2-out-of-2 additive secret sharing** over the ring
//! `Z_{2^64}` (CrypTen-compatible fixed-point). Linear layers become
//! communication-free plaintext×share products; non-linear layers run in
//! plaintext on *permuted* data at the cloud party; the two share×share
//! products inside attention use Beaver triples.
//!
//! The crate is the L3 layer of a three-layer stack:
//!
//! * **L3 (this crate)** — protocol engine, three-party simulation, network
//!   cost accounting, serving coordinator, baselines, attacks, reports.
//! * **L2 (python/compile/model.py)** — JAX forward functions AOT-lowered to
//!   HLO text artifacts.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the compute hot
//!   spots, lowered inside the L2 functions.
//!
//! Python never runs at inference time: the [`runtime`] module loads the AOT
//! artifacts through PJRT (`xla` crate) or falls back to a pure-Rust
//! [`runtime::NativeBackend`] with identical semantics.
//!
//! ## Quickstart
//!
//! ```no_run
//! use centaur::engine::CentaurEngine;
//! use centaur::model::{ModelConfig, ModelWeights};
//! use centaur::net::NetworkProfile;
//!
//! let cfg = ModelConfig::bert_tiny();
//! let weights = ModelWeights::random(&cfg, 42);
//! let mut engine = CentaurEngine::new(&cfg, &weights, NetworkProfile::lan(), 7).unwrap();
//! let tokens = vec![5u32, 17, 9, 2];
//! let out = engine.infer(&tokens).unwrap();
//! println!("logits: {:?}", out.logits);
//! println!("comm: {} bytes in {} rounds", out.stats.bytes_total(), out.stats.rounds_total());
//! ```

#![warn(missing_docs)]

pub mod attacks;
pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod fixed;
pub mod model;
pub mod mpc;
pub mod net;
pub mod perm;
pub mod protocols;
pub mod report;
pub mod ring;
pub mod runtime;
pub mod tensor;
pub mod util;

/// Library-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// Crate version string (from Cargo).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
