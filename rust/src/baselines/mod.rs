//! Baseline PPTI frameworks (paper §7.1): the systems Centaur is compared
//! against, implemented operationally on the same MPC engine so their
//! communication costs fall out of actual protocol execution.
//!
//! * [`smpc::SmpcEngine`] — the all-SMPC family, parameterized by the
//!   non-linearity treatment:
//!   - **PUMA** (Dong et al. 2023): accurate SMPC softmax/GeLU/LayerNorm.
//!   - **MPCFormer** (Li et al. 2023): Softmax→2Quad, GeLU→Quad.
//!   - **SecFormer** (Luo et al. 2024): Softmax→2Quad, accurate GeLU.
//! * [`permonly::PermOnlyEngine`] — Yuan et al. 2023: permutation-only
//!   PPTI that exposes intermediate results (the paper's §3 motivation and
//!   Table 2 "W/O" rows).

pub mod permonly;
pub mod smpc;

use crate::engine::decoder::GenOutcome;
use crate::engine::InferenceOutput;
use crate::net::CostLedger;
use crate::Result;

/// A PPTI framework under comparison.
pub trait PptiFramework {
    /// Framework display name.
    fn name(&self) -> &'static str;
    /// Run one private inference.
    fn infer(&mut self, tokens: &[u32]) -> Result<InferenceOutput>;
    /// Incremental streaming generation: `on_token(index, token,
    /// step_cost)` fires per generated token and returns whether to
    /// continue (`false` aborts the remaining steps — e.g. the client
    /// dropped its stream). Only decoder frameworks with a KV-cache path
    /// support this; the default refuses.
    fn generate_stream(
        &mut self,
        _prompt: &[u32],
        _steps: usize,
        _on_token: &mut dyn FnMut(usize, u32, &CostLedger) -> bool,
    ) -> Result<GenOutcome> {
        anyhow::bail!("{} does not support incremental generation", self.name())
    }
    /// Cumulative integrity-audit counters, when the framework runs with
    /// audit mode on (`None` otherwise — the default; only Centaur
    /// engines support the audit layer).
    fn audit_counters(&self) -> Option<crate::mpc::AuditCounters> {
        None
    }
}

impl PptiFramework for crate::engine::CentaurEngine {
    fn name(&self) -> &'static str {
        "Centaur"
    }
    fn infer(&mut self, tokens: &[u32]) -> Result<InferenceOutput> {
        crate::engine::CentaurEngine::infer(self, tokens)
    }
    fn generate_stream(
        &mut self,
        prompt: &[u32],
        steps: usize,
        on_token: &mut dyn FnMut(usize, u32, &CostLedger) -> bool,
    ) -> Result<GenOutcome> {
        self.generate_streaming(prompt, steps, on_token)
    }
    fn audit_counters(&self) -> Option<crate::mpc::AuditCounters> {
        crate::engine::CentaurEngine::audit_counters(self)
    }
}

/// Framework selector used by the CLI / reports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameworkKind {
    /// This paper's hybrid framework.
    Centaur,
    /// PUMA (Dong et al. 2023): accurate all-SMPC.
    Puma,
    /// MPCFormer (Li et al. 2023): Softmax→2Quad, GeLU→Quad.
    MpcFormer,
    /// SecFormer (Luo et al. 2024): Softmax→2Quad only.
    SecFormer,
    /// Permutation-only PPTI (Yuan et al. 2023).
    PermOnly,
}

impl FrameworkKind {
    /// Look up a framework by CLI name.
    pub fn by_name(s: &str) -> Option<Self> {
        match s {
            "centaur" => Some(Self::Centaur),
            "puma" => Some(Self::Puma),
            "mpcformer" => Some(Self::MpcFormer),
            "secformer" => Some(Self::SecFormer),
            "permonly" => Some(Self::PermOnly),
            _ => None,
        }
    }
    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            Self::Centaur => "Centaur",
            Self::Puma => "PUMA",
            Self::MpcFormer => "MPCFormer",
            Self::SecFormer => "SecFormer",
            Self::PermOnly => "PermOnly",
        }
    }
    /// Every framework, in comparison order.
    pub const ALL: [FrameworkKind; 5] =
        [Self::Centaur, Self::Puma, Self::MpcFormer, Self::SecFormer, Self::PermOnly];
    /// The SMPC baselines of Figs. 7/8 (excludes PermOnly).
    pub const SMPC_BASELINES: [FrameworkKind; 3] = [Self::Puma, Self::MpcFormer, Self::SecFormer];
}
