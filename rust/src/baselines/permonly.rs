//! Permutation-only PPTI (Yuan et al. 2023) — the paper's §3 Motivation 2.
//!
//! Plaintext computation on permuted parameters and data: near-plaintext
//! efficiency, but the linear-layer algebra cancels the permutations, so
//! intermediate results (`QKᵀ`, attention scores, FFN activations) are
//! exposed to the cloud in **unpermuted plaintext** — the attack surface
//! Table 2's "W/O" rows quantify. The [`crate::engine::views::Views`]
//! ledger records these exposures with `PermTag::None`, which the leak
//! detector flags (by design, for this baseline).

use crate::engine::views::{PermTag, Views};
use crate::engine::InferenceOutput;
use crate::model::{plaintext, ModelConfig, ModelWeights, Variant};
use crate::net::{NetSim, NetworkProfile, OpClass, PartyId};
use crate::tensor::FloatTensor;
use crate::Result;

use super::PptiFramework;

/// The permutation-only engine.
pub struct PermOnlyEngine {
    cfg: ModelConfig,
    weights: ModelWeights,
    net: NetSim,
    /// Observations the cloud makes (plaintext intermediates!).
    pub views: Views,
}

impl PermOnlyEngine {
    /// Build the engine (plaintext weights; permutation protection only).
    pub fn new(cfg: &ModelConfig, w: &ModelWeights, profile: NetworkProfile, record_views: bool) -> Self {
        PermOnlyEngine {
            cfg: cfg.clone(),
            weights: w.clone(),
            net: NetSim::new(profile),
            views: Views::new(record_views),
        }
    }
}

impl PptiFramework for PermOnlyEngine {
    fn name(&self) -> &'static str {
        "PermOnly"
    }

    fn infer(&mut self, tokens: &[u32]) -> Result<InferenceOutput> {
        self.net.reset();
        self.views.clear();
        // client → cloud: permuted embedding-space input (n×d floats ≈
        // ring elements on the wire), one round; result comes back the
        // same way. That is the entire communication.
        let n = tokens.len();
        let in_bytes = (n * self.cfg.d * 8) as u64;
        self.net.charge_bytes(OpClass::Embedding, in_bytes);
        self.net.round(OpClass::Embedding, 1);

        let t0 = std::time::Instant::now();
        let trace = plaintext::forward_trace(&self.cfg, &self.weights, tokens, Variant::Exact);
        self.net.compute(OpClass::Linear, PartyId::P1, t0.elapsed().as_secs_f64());

        // The §3 analysis: linear cancellation exposes these in plaintext.
        for (i, lt) in trace.layers.iter().enumerate() {
            self.views.observe_p1(format!("O1 layer{i} (exposed)"), &lt.o1, PermTag::None);
            self.views.observe_p1(format!("O4 layer{i} (exposed)"), &lt.o4, PermTag::None);
            self.views.observe_p1(format!("O5 layer{i} (exposed)"), &lt.o5, PermTag::None);
            self.views.observe_p1(format!("O6 layer{i} (exposed)"), &lt.o6, PermTag::None);
        }

        let out_bytes = (trace.logits.len() * 8) as u64;
        self.net.charge_bytes(OpClass::Adaptation, out_bytes);
        self.net.round(OpClass::Adaptation, 1);
        Ok(InferenceOutput { logits: trace.logits, stats: self.net.ledger.clone() })
    }
}

/// Exposed intermediates from a plaintext trace (attack-harness helper:
/// the "W/O" condition of Tables 2/4 without running the engine).
pub fn exposed_intermediates(
    cfg: &ModelConfig,
    w: &ModelWeights,
    tokens: &[u32],
    layer: usize,
) -> (FloatTensor, FloatTensor, FloatTensor, FloatTensor) {
    let t = plaintext::forward_trace(cfg, w, tokens, Variant::Exact);
    let lt = &t.layers[layer];
    (lt.o1.clone(), lt.o4.clone(), lt.o5.clone(), lt.o6.clone())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn permonly_is_exact_but_leaky() {
        let cfg = ModelConfig::bert_tiny();
        let w = ModelWeights::random(&cfg, 95);
        let tokens: Vec<u32> = (0..cfg.n_ctx as u32).map(|i| 4 + (i % 100)).collect();
        let mut eng = PermOnlyEngine::new(&cfg, &w, NetworkProfile::lan(), true);
        let out = eng.infer(&tokens).unwrap();
        // exact plaintext result
        let want = plaintext::forward(&cfg, &w, &tokens, Variant::Exact);
        assert_eq!(out.logits.data(), want.data());
        // leak detector fires: O1/O4/O5/O6 exposed per layer
        assert_eq!(eng.views.leaks().len(), 4 * cfg.layers);
        // near-plaintext communication: orders below any SMPC framework
        assert!(out.stats.bytes_total() < 100_000);
    }

    #[test]
    fn exposed_intermediates_shapes() {
        let cfg = ModelConfig::bert_tiny();
        let w = ModelWeights::random(&cfg, 96);
        let tokens: Vec<u32> = vec![7; cfg.n_ctx];
        let (o1, o4, o5, o6) = exposed_intermediates(&cfg, &w, &tokens, 0);
        assert_eq!(o1.shape(), (cfg.h * cfg.n_ctx, cfg.n_ctx));
        assert_eq!(o4.shape(), (cfg.n_ctx, cfg.d));
        assert_eq!(o5.shape(), (cfg.n_ctx, cfg.k));
        assert_eq!(o6.shape(), (cfg.n_ctx, cfg.d));
    }
}
