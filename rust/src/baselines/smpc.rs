//! All-SMPC PPTI engines (PUMA / MPCFormer / SecFormer).
//!
//! Both model parameters *and* activations are secret-shared; every linear
//! layer is a share×share `Π_MatMul` and every non-linearity runs through
//! the SMPC operator library (`mpc::nonlin`) — this is where the paper's
//! Fig. 3 "90%+ of time in non-linear layers" comes from.
//!
//! Simulator note (DESIGN.md §CostModel): parameter tensors are stored
//! once in fixed point and matmuls against them are *charged* at the full
//! share×share `Π_MatMul` tariff (`2·8·(mk+kn)` bytes, 1 round) while the
//! product is computed directly — storing true parameter shares for a
//! 774M-parameter model and running four Beaver products per matmul would
//! only multiply memory/compute on this 1-core testbed without changing a
//! single reported byte. Activation non-linearities execute for real on
//! shares. Compute time for baselines is therefore a *lower bound* (favors
//! the baselines; Centaur's reported speedups are conservative).

use crate::engine::InferenceOutput;
use crate::fixed;
use crate::model::{LayerWeights, ModelConfig, ModelKind, ModelWeights};
use crate::mpc::{nonlin, Mpc, Share};
use crate::net::{NetSim, NetworkProfile, OpClass};
use crate::protocols::embedding::one_hot_fx;
use crate::ring;
use crate::tensor::RingTensor;
use crate::Result;

use super::{FrameworkKind, PptiFramework};

/// Mask stand-in for −∞ inside SMPC (exp-limit convergence domain).
pub const SMPC_MASK_NEG: f64 = -30.0;

/// Softmax treatment of a baseline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SoftmaxKind {
    /// max + exp + reciprocal (accurate; PUMA).
    Accurate,
    /// MPCFormer / SecFormer's 2Quad substitute.
    TwoQuad,
}

/// GeLU treatment of a baseline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GeluKind {
    /// tanh-form GeLU through SMPC (accurate; PUMA / SecFormer).
    Accurate,
    /// MPCFormer's Quad substitute.
    Quad,
}

/// Fixed-point-encoded parameters (semantically secret-shared; see module
/// docs for the charging model).
struct FxLayer {
    wq: RingTensor,
    bq: Vec<i64>,
    wk: RingTensor,
    bk: Vec<i64>,
    wv: RingTensor,
    bv: Vec<i64>,
    wo: RingTensor,
    bo: Vec<i64>,
    ln1_g: Share,
    ln1_b: Share,
    w1: RingTensor,
    b1: Vec<i64>,
    w2: RingTensor,
    b2: Vec<i64>,
    ln2_g: Share,
    ln2_b: Share,
}

/// The all-SMPC engine.
pub struct SmpcEngine {
    /// Which SMPC baseline this engine emulates.
    pub kind: FrameworkKind,
    cfg: ModelConfig,
    softmax: SoftmaxKind,
    gelu: GeluKind,
    mpc: Mpc,
    emb_word: RingTensor,
    emb_pos: RingTensor,
    emb_ln_g: Share,
    emb_ln_b: Share,
    layers: Vec<FxLayer>,
    pooler_w: Option<RingTensor>,
    pooler_b: Option<Vec<i64>>,
    cls_w: Option<RingTensor>,
    cls_b: Option<Vec<i64>>,
    final_ln_g: Option<Share>,
    final_ln_b: Option<Share>,
    mask_fx: Option<RingTensor>,
}

fn enc(t: &crate::tensor::FloatTensor) -> RingTensor {
    fixed::encode_tensor(t)
}
fn enc_vec(v: &[f32]) -> Vec<i64> {
    v.iter().map(|&x| fixed::encode(x as f64)).collect()
}

impl SmpcEngine {
    /// Build the engine for `kind` (selects softmax/GeLU treatment).
    pub fn new(
        kind: FrameworkKind,
        cfg: &ModelConfig,
        w: &ModelWeights,
        profile: NetworkProfile,
        seed: u64,
    ) -> Result<Self> {
        let (softmax, gelu) = match kind {
            FrameworkKind::Puma => (SoftmaxKind::Accurate, GeluKind::Accurate),
            FrameworkKind::MpcFormer => (SoftmaxKind::TwoQuad, GeluKind::Quad),
            FrameworkKind::SecFormer => (SoftmaxKind::TwoQuad, GeluKind::Accurate),
            other => anyhow::bail!("SmpcEngine does not implement {other:?}"),
        };
        let mut mpc = Mpc::new(NetSim::new(profile), seed);
        let share_vec = |mpc: &mut Mpc, v: &[f32]| {
            let t = RingTensor::from_vec(1, v.len(), enc_vec(v));
            mpc.share_local(&t)
        };
        let layers = w
            .layers
            .iter()
            .map(|l: &LayerWeights| FxLayer {
                wq: enc(&l.wq),
                bq: enc_vec(&l.bq),
                wk: enc(&l.wk),
                bk: enc_vec(&l.bk),
                wv: enc(&l.wv),
                bv: enc_vec(&l.bv),
                wo: enc(&l.wo),
                bo: enc_vec(&l.bo),
                ln1_g: share_vec(&mut mpc, &l.ln1_g),
                ln1_b: share_vec(&mut mpc, &l.ln1_b),
                w1: enc(&l.w1),
                b1: enc_vec(&l.b1),
                w2: enc(&l.w2),
                b2: enc_vec(&l.b2),
                ln2_g: share_vec(&mut mpc, &l.ln2_g),
                ln2_b: share_vec(&mut mpc, &l.ln2_b),
            })
            .collect();
        let emb_ln_g = share_vec(&mut mpc, &w.emb_ln_g);
        let emb_ln_b = share_vec(&mut mpc, &w.emb_ln_b);
        let final_ln_g = w.final_ln_g.as_ref().map(|v| share_vec(&mut mpc, v));
        let final_ln_b = w.final_ln_b.as_ref().map(|v| share_vec(&mut mpc, v));
        // SMPC-safe causal mask: −30 (not −1e5 — the exp limit
        // approximation only converges for inputs above −512; e^{−30} is
        // already below fixed-point resolution). For 2Quad the mask is
        // applied multiplicatively instead (see `transformer_layer`).
        let mask_fx = (cfg.kind == ModelKind::Gpt2).then(|| {
            let neg = fixed::encode(SMPC_MASK_NEG);
            RingTensor::from_fn(cfg.h * cfg.n_ctx, cfg.n_ctx, |r, c| {
                if c > (r % cfg.n_ctx) { neg } else { 0 }
            })
        });
        Ok(SmpcEngine {
            kind,
            cfg: cfg.clone(),
            softmax,
            gelu,
            mpc,
            emb_word: enc(&w.emb_word),
            emb_pos: enc(&w.emb_pos),
            emb_ln_g,
            emb_ln_b,
            layers,
            pooler_w: w.pooler_w.as_ref().map(enc),
            pooler_b: w.pooler_b.as_ref().map(|b| enc_vec(b)),
            cls_w: w.cls_w.as_ref().map(enc),
            cls_b: w.cls_b.as_ref().map(|b| enc_vec(b)),
            final_ln_g,
            final_ln_b,
            mask_fx,
        })
    }

    /// Share×share linear layer `[X] @ [Wᵀ] + [b]`, charged at the Beaver
    /// tariff; product computed directly (module docs).
    fn linear_shared(&mut self, x: &Share, w_fx: &RingTensor, b_fx: &[i64], class: OpClass) -> Share {
        let (m, k) = x.shape();
        let n = w_fx.rows();
        self.mpc.net.charge_bytes(class, (2 * 8 * (m * k + k * n)) as u64);
        self.mpc.net.round(class, 1);
        let mut out = self.mpc.scalmul_nt_ideal(x, w_fx, class);
        // bias is also shared; adding shared bias is local — model as P0 add.
        out = self.mpc.add_plain_row(&out, b_fx);
        out
    }

    /// Share×share matmul of two activation shares (QKᵀ, probs·V).
    fn matmul_shared(&mut self, x: &Share, y: &Share, class: OpClass) -> Share {
        self.mpc.matmul_charged_ideal(x, y, class)
    }

    fn softmax_shared(&mut self, x: &Share) -> Share {
        match self.softmax {
            SoftmaxKind::Accurate => nonlin::softmax(&mut self.mpc, x, OpClass::Softmax),
            SoftmaxKind::TwoQuad => nonlin::softmax_2quad(&mut self.mpc, x, 5.0, OpClass::Softmax),
        }
    }

    fn gelu_shared(&mut self, x: &Share) -> Share {
        match self.gelu {
            GeluKind::Accurate => nonlin::gelu(&mut self.mpc, x, OpClass::Gelu),
            GeluKind::Quad => nonlin::gelu_quad(&mut self.mpc, x, OpClass::Gelu),
        }
    }

    fn layernorm_shared(&mut self, x: &Share, g: &Share, b: &Share, class: OpClass) -> Share {
        let g = g.clone();
        let b = b.clone();
        nonlin::layernorm(&mut self.mpc, x, &g, &b, 1e-5, class)
    }

    fn transformer_layer(&mut self, i: usize, x: &Share) -> Share {
        let n = x.rows();
        let dh = self.cfg.dh();
        let scale = fixed::encode(1.0 / (dh as f64).sqrt());
        let (wq, bq, wk, bk, wv, bv) = {
            let l = &self.layers[i];
            (l.wq.clone(), l.bq.clone(), l.wk.clone(), l.bk.clone(), l.wv.clone(), l.bv.clone())
        };
        let q = self.linear_shared(x, &wq, &bq, OpClass::Linear);
        let k = self.linear_shared(x, &wk, &bk, OpClass::Linear);
        let v = self.linear_shared(x, &wv, &bv, OpClass::Linear);
        let mut heads = Vec::with_capacity(self.cfg.h);
        for h in 0..self.cfg.h {
            let qh = q.col_block(h * dh, (h + 1) * dh);
            let kt = k.col_block(h * dh, (h + 1) * dh).transpose();
            let mut s = self.matmul_shared(&qh, &kt, OpClass::Linear);
            s = self.mpc.scale_fx(&s, scale);
            if self.mask_fx.is_some() {
                match self.softmax {
                    SoftmaxKind::Accurate => {
                        // additive −30 on the masked positions
                        let mh = RingTensor::from_fn(n, n, |r, c| {
                            if c > r { fixed::encode(SMPC_MASK_NEG) } else { 0 }
                        });
                        s = self.mpc.add_plain(&s, &mh);
                    }
                    SoftmaxKind::TwoQuad => {
                        // set masked scores to exactly −c so (x+c)² = 0:
                        // s ← s∘M₀₁ − c·(1−M₀₁)   (both steps local)
                        let keep = RingTensor::from_fn(n, n, |r, c| i64::from(c <= r));
                        s = self.mpc.mul_plain_int(&s, &keep);
                        let fill = RingTensor::from_fn(n, n, |r, c| {
                            if c > r { fixed::encode(-5.0) } else { 0 }
                        });
                        s = self.mpc.add_plain(&s, &fill);
                    }
                }
            }
            let probs = self.softmax_shared(&s);
            let vh = v.col_block(h * dh, (h + 1) * dh);
            heads.push(self.matmul_shared(&probs, &vh, OpClass::Linear));
        }
        let o3 = Share::concat_cols(&heads);
        let (wo, bo) = {
            let l = &self.layers[i];
            (l.wo.clone(), l.bo.clone())
        };
        let o4 = self.linear_shared(&o3, &wo, &bo, OpClass::Linear);
        let res1 = self.mpc.add(&o4, x);
        let (g1, b1ln) = (self.layers[i].ln1_g.clone(), self.layers[i].ln1_b.clone());
        let l1 = self.layernorm_shared(&res1, &g1, &b1ln, OpClass::LayerNorm);
        let (w1, b1, w2, b2) = {
            let l = &self.layers[i];
            (l.w1.clone(), l.b1.clone(), l.w2.clone(), l.b2.clone())
        };
        let o5 = self.linear_shared(&l1, &w1, &b1, OpClass::Linear);
        let g = self.gelu_shared(&o5);
        let o6 = self.linear_shared(&g, &w2, &b2, OpClass::Linear);
        let res2 = self.mpc.add(&o6, &l1);
        let (g2, b2ln) = (self.layers[i].ln2_g.clone(), self.layers[i].ln2_b.clone());
        self.layernorm_shared(&res2, &g2, &b2ln, OpClass::LayerNorm)
    }

    fn embedding(&mut self, tokens: &[u32]) -> Share {
        let onehot = one_hot_fx(tokens, self.cfg.vocab);
        let x_sh = self.mpc.input_share(&onehot, OpClass::Embedding);
        // lookup = ΠMatMul([X], [W_E]) — both shared (charged tariff).
        let (m, k) = x_sh.shape();
        let n = self.cfg.d;
        self.mpc.net.charge_bytes(OpClass::Embedding, (2 * 8 * (m * k + k * n)) as u64);
        self.mpc.net.round(OpClass::Embedding, 1);
        let mut x = self.mpc.scalmul_rhs_ideal(&x_sh, &self.emb_word, OpClass::Embedding);
        // positional (shared param): local add — model as P0 plaintext add.
        let pos = {
            let mut p = RingTensor::zeros(tokens.len(), self.cfg.d);
            for r in 0..tokens.len() {
                p.row_mut(r).copy_from_slice(self.emb_pos.row(r));
            }
            p
        };
        x = self.mpc.add_plain(&x, &pos);
        let (g, b) = (self.emb_ln_g.clone(), self.emb_ln_b.clone());
        self.layernorm_shared(&x, &g, &b, OpClass::Embedding)
    }

    fn adaptation(&mut self, x: &Share) -> Share {
        match self.cfg.kind {
            ModelKind::Bert => {
                let cls = x.row_block(0, 1);
                let (pw, pb) = (self.pooler_w.clone().unwrap(), self.pooler_b.clone().unwrap());
                let pooled = self.linear_shared(&cls, &pw, &pb, OpClass::Adaptation);
                let t = nonlin::tanh(&mut self.mpc, &pooled, OpClass::Adaptation);
                let (cw, cb) = (self.cls_w.clone().unwrap(), self.cls_b.clone().unwrap());
                self.linear_shared(&t, &cw, &cb, OpClass::Adaptation)
            }
            ModelKind::Gpt2 => {
                let (g, b) = (self.final_ln_g.clone().unwrap(), self.final_ln_b.clone().unwrap());
                let h = self.layernorm_shared(x, &g, &b, OpClass::Adaptation);
                // tied lm head: ΠMatMul([H], [W_Eᵀ]) — charged tariff.
                let (m, k) = h.shape();
                let n = self.cfg.vocab;
                self.mpc.net.charge_bytes(OpClass::Adaptation, (2 * 8 * (m * k + k * n)) as u64);
                self.mpc.net.round(OpClass::Adaptation, 1);
                self.mpc.scalmul_nt_ideal(&h, &self.emb_word, OpClass::Adaptation)
            }
        }
    }
}

impl PptiFramework for SmpcEngine {
    fn name(&self) -> &'static str {
        self.kind.name()
    }

    fn infer(&mut self, tokens: &[u32]) -> Result<InferenceOutput> {
        anyhow::ensure!(tokens.len() == self.cfg.n_ctx, "pad input to n_ctx");
        self.mpc.net.reset();
        let mut x = self.embedding(tokens);
        for i in 0..self.layers.len() {
            x = self.transformer_layer(i, &x);
        }
        let logits_sh = self.adaptation(&x);
        // return shares to the client
        let s0 = self.mpc.net.transfer(
            crate::net::PartyId::P0,
            crate::net::PartyId::P2,
            &logits_sh.s0,
            OpClass::Adaptation,
        );
        let s1 = self.mpc.net.transfer(
            crate::net::PartyId::P1,
            crate::net::PartyId::P2,
            &logits_sh.s1,
            OpClass::Adaptation,
        );
        self.mpc.net.round(OpClass::Adaptation, 1);
        let logits = fixed::decode_tensor(&ring::add(&s0, &s1));
        Ok(InferenceOutput { logits, stats: self.mpc.net.ledger.clone() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{plaintext, Variant};

    fn tokens(cfg: &ModelConfig, seed: u64) -> Vec<u32> {
        let mut rng = crate::util::rng::Rng::new(seed);
        (0..cfg.n_ctx).map(|_| (rng.below(cfg.vocab - 4) + 4) as u32).collect()
    }

    #[test]
    fn puma_matches_exact_plaintext() {
        let cfg = ModelConfig::bert_tiny();
        let w = ModelWeights::random(&cfg, 81);
        let toks = tokens(&cfg, 82);
        let mut eng = SmpcEngine::new(FrameworkKind::Puma, &cfg, &w, NetworkProfile::lan(), 83).unwrap();
        let out = eng.infer(&toks).unwrap();
        let want = plaintext::forward(&cfg, &w, &toks, Variant::Exact);
        let diff = out.logits.max_abs_diff(&want);
        // SMPC approximations (exp/recip/rsqrt) add noise on top of fx
        assert!(diff < 0.15, "puma vs plaintext diff {diff}");
    }

    #[test]
    fn mpcformer_matches_substituted_plaintext() {
        let cfg = ModelConfig::bert_tiny();
        let w = ModelWeights::random(&cfg, 84);
        let toks = tokens(&cfg, 85);
        let mut eng = SmpcEngine::new(FrameworkKind::MpcFormer, &cfg, &w, NetworkProfile::lan(), 86).unwrap();
        let out = eng.infer(&toks).unwrap();
        let want = plaintext::forward(&cfg, &w, &toks, Variant::MpcFormer);
        let diff = out.logits.max_abs_diff(&want);
        assert!(diff < 0.15, "mpcformer vs 2quad plaintext diff {diff}");
    }

    #[test]
    fn secformer_matches_its_variant() {
        let cfg = ModelConfig::bert_tiny();
        let w = ModelWeights::random(&cfg, 87);
        let toks = tokens(&cfg, 88);
        let mut eng = SmpcEngine::new(FrameworkKind::SecFormer, &cfg, &w, NetworkProfile::lan(), 89).unwrap();
        let out = eng.infer(&toks).unwrap();
        let want = plaintext::forward(&cfg, &w, &toks, Variant::SecFormer);
        assert!(out.logits.max_abs_diff(&want) < 0.15);
    }

    #[test]
    fn cost_ordering_matches_paper() {
        // PUMA > SecFormer > MPCFormer in non-linear comm; Centaur far less.
        let cfg = ModelConfig::bert_tiny();
        let w = ModelWeights::random(&cfg, 90);
        let toks = tokens(&cfg, 91);
        let bytes = |kind| {
            let mut e = SmpcEngine::new(kind, &cfg, &w, NetworkProfile::lan(), 92).unwrap();
            let out = e.infer(&toks).unwrap();
            (
                out.stats.class(OpClass::Softmax).bytes + out.stats.class(OpClass::Gelu).bytes,
                out.stats.bytes_total(),
            )
        };
        let (puma_nl, puma_tot) = bytes(FrameworkKind::Puma);
        let (mpcf_nl, _) = bytes(FrameworkKind::MpcFormer);
        let (secf_nl, _) = bytes(FrameworkKind::SecFormer);
        assert!(puma_nl > secf_nl, "puma {puma_nl} !> secformer {secf_nl}");
        assert!(secf_nl > mpcf_nl, "secformer {secf_nl} !> mpcformer {mpcf_nl}");

        // Centaur non-linear traffic should be dramatically lower.
        let mut cent = crate::engine::CentaurEngine::new(&cfg, &w, NetworkProfile::lan(), 93).unwrap();
        let cout = cent.infer(&toks).unwrap();
        let cent_nl = cout.stats.class(OpClass::Softmax).bytes + cout.stats.class(OpClass::Gelu).bytes;
        assert!(
            puma_nl as f64 / cent_nl as f64 > 3.0,
            "puma/centaur nonlinear ratio only {:.2}",
            puma_nl as f64 / cent_nl as f64
        );
        assert!(puma_tot > cout.stats.bytes_total());
    }
}
