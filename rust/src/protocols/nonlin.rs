//! `Π_PP*` non-linear protocols (paper Algorithms 1–3): Centaur converts a
//! share of a *permuted* tensor into permuted plaintext at the cloud party
//! `P1`, evaluates the non-linearity exactly (through the [`Backend`] — the
//! AOT Pallas kernels or their native mirror), and re-shares the result.
//!
//! Cost: 2 rounds, `8·(|X| + |Y|)` bytes — the paper's Table 1 row
//! (`128·n²` bits for an n×n input).

use crate::engine::views::{PermTag, Views};
use crate::fixed;
use crate::mpc::{Mpc, Share};
use crate::net::{OpClass, PartyId};
use crate::ring;
use crate::runtime::Backend;
use crate::tensor::FloatTensor;
use crate::Result;

/// Shared implementation of the state-conversion pattern.
///
/// `charge_rounds = false` is the *deferred* form used by the batched
/// decode schedule (DESIGN.md §Batched openings): the two transfers are
/// charged byte-for-byte as usual, but the caller places the round
/// charges — P0's input half rides an already-charged neighbouring flight
/// (its payload is a public-linear function of a value P1 itself
/// reshared, so P1 never waits on it) and P1's output half coalesces with
/// the other reshares of the fused segment into one flush.
#[allow(clippy::too_many_arguments)]
fn pp_apply(
    mpc: &mut Mpc,
    backend: &mut dyn Backend,
    views: &mut Views,
    x: &Share,
    class: OpClass,
    label: &str,
    tag: PermTag,
    charge_rounds: bool,
    f: impl FnOnce(&mut dyn Backend, &FloatTensor) -> Result<FloatTensor>,
) -> Result<Share> {
    // 1. P0 → P1: its input share; P1 reconstructs the permuted plaintext.
    let s0 = mpc.send_share_half(x, PartyId::P0, PartyId::P1, class);
    let xp_ring = ring::add(&s0, &x.s1);
    let xp = fixed::decode_tensor(&xp_ring);
    views.observe_p1(label, &xp, tag);
    // 2. P1 computes the non-linearity in plaintext (timed as P1 compute).
    let t0 = std::time::Instant::now();
    let y = f(backend, &xp)?;
    mpc.net.compute(class, PartyId::P1, t0.elapsed().as_secs_f64());
    // 3. P1 re-shares the permuted output; P0 gets its fresh share.
    let y_ring = fixed::encode_tensor(&y);
    let sh = mpc.reshare_from(&y_ring, PartyId::P1, class);
    if charge_rounds {
        // Two rounds in total (input half + output half).
        mpc.net.round(class, 2);
    }
    Ok(sh)
}

/// `Π_PPSM` (Algorithm 1): softmax over rows of `[Xπ₁]` → `[Softmax(X)π₁]`.
/// Works because row-wise softmax commutes with a column permutation.
pub fn pp_softmax(
    mpc: &mut Mpc,
    backend: &mut dyn Backend,
    views: &mut Views,
    x: &Share,
    label: &str,
) -> Result<Share> {
    pp_apply(mpc, backend, views, x, OpClass::Softmax, label, PermTag::Pi1, true, |b, t| {
        b.softmax(t)
    })
}

/// Deferred-round `Π_PPSM` for the session-batched decode schedule:
/// identical transfers and P1 view to [`pp_softmax`], no round charge —
/// a batch-mate's charged softmax flight carries this lane's halves
/// (the payloads are independent across sessions, so they ship in the
/// same two flights).
pub fn pp_softmax_unrounded(
    mpc: &mut Mpc,
    backend: &mut dyn Backend,
    views: &mut Views,
    x: &Share,
    label: &str,
) -> Result<Share> {
    pp_apply(mpc, backend, views, x, OpClass::Softmax, label, PermTag::Pi1, false, |b, t| {
        b.softmax(t)
    })
}

/// `Π_PPGeLU` (Algorithm 2): elementwise GeLU of `[Xπ₂]` → `[GeLU(X)π₂]`.
pub fn pp_gelu(
    mpc: &mut Mpc,
    backend: &mut dyn Backend,
    views: &mut Views,
    x: &Share,
    label: &str,
) -> Result<Share> {
    pp_apply(mpc, backend, views, x, OpClass::Gelu, label, PermTag::Pi2, true, |b, t| b.gelu(t))
}

/// Deferred-round `Π_PPGeLU` for the batched decode schedule: identical
/// transfers and P1 view, no round charge — the caller's fused segment
/// places the rounds (DESIGN.md §Batched openings).
pub fn pp_gelu_unrounded(
    mpc: &mut Mpc,
    backend: &mut dyn Backend,
    views: &mut Views,
    x: &Share,
    label: &str,
) -> Result<Share> {
    pp_apply(mpc, backend, views, x, OpClass::Gelu, label, PermTag::Pi2, false, |b, t| b.gelu(t))
}

/// `Π_PPLN` (Algorithm 3): LayerNorm of `[Xπ]` with P1-held permuted affine
/// parameters `(γπ, βπ)` → `[LayerNorm(X)π]`. Row statistics are
/// permutation-invariant and the affine part is elementwise.
pub fn pp_layernorm(
    mpc: &mut Mpc,
    backend: &mut dyn Backend,
    views: &mut Views,
    x: &Share,
    gamma_p: &[f32],
    beta_p: &[f32],
    class: OpClass,
    label: &str,
) -> Result<Share> {
    pp_apply(mpc, backend, views, x, class, label, PermTag::Pi, true, |b, t| {
        b.layernorm(t, gamma_p, beta_p)
    })
}

/// Deferred-round `Π_PPLN` (same contract as [`pp_gelu_unrounded`]).
#[allow(clippy::too_many_arguments)]
pub fn pp_layernorm_unrounded(
    mpc: &mut Mpc,
    backend: &mut dyn Backend,
    views: &mut Views,
    x: &Share,
    gamma_p: &[f32],
    beta_p: &[f32],
    class: OpClass,
    label: &str,
) -> Result<Share> {
    pp_apply(mpc, backend, views, x, class, label, PermTag::Pi, false, |b, t| {
        b.layernorm(t, gamma_p, beta_p)
    })
}

/// `Π_PPTanh` (inside Algorithm 5): elementwise tanh of `[Xπ]`.
pub fn pp_tanh(
    mpc: &mut Mpc,
    backend: &mut dyn Backend,
    views: &mut Views,
    x: &Share,
    label: &str,
) -> Result<Share> {
    pp_apply(mpc, backend, views, x, OpClass::Adaptation, label, PermTag::Pi, true, |b, t| b.tanh(t))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{NetSim, NetworkProfile};
    use crate::perm::Perm;
    use crate::runtime::NativeBackend;
    use crate::util::rng::Rng;

    fn setup() -> (Mpc, NativeBackend, Views) {
        (
            Mpc::new(NetSim::new(NetworkProfile::lan()), 77),
            NativeBackend::new(),
            Views::new(true),
        )
    }

    #[test]
    fn ppsm_commutes_with_permutation() {
        let (mut mpc, mut be, mut views) = setup();
        let mut rng = Rng::new(1);
        let n = 8;
        let x = FloatTensor::from_fn(4, n, |r, c| ((r * n + c) as f32 * 0.37).sin() * 2.0);
        let p = Perm::random(n, &mut rng);
        let xp = p.apply_cols(&x);
        let sh = mpc.share_local(&fixed::encode_tensor(&xp));
        let out = pp_softmax(&mut mpc, &mut be, &mut views, &sh, "test O1").unwrap();
        let got = fixed::decode_tensor(&out.reconstruct());
        // expected: softmax(X) then permute
        let mut want = x.clone();
        for r in 0..want.rows() {
            crate::runtime::native::softmax_row(want.row_mut(r));
        }
        let want_p = p.apply_cols(&want);
        assert!(got.max_abs_diff(&want_p) < 1e-3, "diff {}", got.max_abs_diff(&want_p));
        // Table 1 cost: 2 rounds, 128 bits/elem
        assert_eq!(mpc.net.ledger.class(OpClass::Softmax).rounds, 2);
        assert_eq!(mpc.net.ledger.class(OpClass::Softmax).bytes, 2 * (4 * n as u64) * 8);
        // view recorded with the π₁ tag
        assert_eq!(views.p1.len(), 1);
        assert_eq!(views.p1[0].tag, PermTag::Pi1);
    }

    #[test]
    fn ppgelu_matches_plaintext() {
        let (mut mpc, mut be, mut views) = setup();
        let x = FloatTensor::from_fn(3, 16, |r, c| (r as f32 - 1.0) + c as f32 * 0.2 - 1.5);
        let sh = mpc.share_local(&fixed::encode_tensor(&x));
        let out = pp_gelu(&mut mpc, &mut be, &mut views, &sh, "test O5").unwrap();
        let got = fixed::decode_tensor(&out.reconstruct());
        let want = x.map(crate::runtime::native::gelu_scalar);
        assert!(got.max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn ppln_with_permuted_affine() {
        let (mut mpc, mut be, mut views) = setup();
        let mut rng = Rng::new(3);
        let d = 12;
        let p = Perm::random(d, &mut rng);
        let x = FloatTensor::from_fn(2, d, |r, c| ((r + c * 3) % 7) as f32 * 0.5 - 1.0);
        let gamma: Vec<f32> = (0..d).map(|i| 1.0 + i as f32 * 0.05).collect();
        let beta: Vec<f32> = (0..d).map(|i| i as f32 * -0.02).collect();
        // share the permuted input; give P1 permuted affine params
        let sh = mpc.share_local(&fixed::encode_tensor(&p.apply_cols(&x)));
        let out = pp_layernorm(
            &mut mpc, &mut be, &mut views, &sh,
            &p.apply_vec(&gamma), &p.apply_vec(&beta),
            OpClass::LayerNorm, "test LN",
        )
        .unwrap();
        let got = fixed::decode_tensor(&out.reconstruct());
        // want: LN(x, γ, β) π
        let mut nb = NativeBackend::new();
        let want = p.apply_cols(&crate::runtime::Backend::layernorm(&mut nb, &x, &gamma, &beta).unwrap());
        assert!(got.max_abs_diff(&want) < 2e-3, "diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn fresh_resharing_randomizes() {
        // Re-running the same Π_PPGeLU must produce different share halves
        // (fresh randomness) that reconstruct identically.
        let (mut mpc, mut be, mut views) = setup();
        let x = FloatTensor::from_fn(2, 8, |_, c| c as f32 * 0.1);
        let sh = mpc.share_local(&fixed::encode_tensor(&x));
        let a = pp_gelu(&mut mpc, &mut be, &mut views, &sh, "a").unwrap();
        let b = pp_gelu(&mut mpc, &mut be, &mut views, &sh, "b").unwrap();
        assert_ne!(a.s0, b.s0);
        assert_eq!(a.reconstruct(), b.reconstruct());
    }
}
