//! `Π_PPAdaptation` (paper Algorithm 5): the task head.
//!
//! * BERT: pooler (`Π_ScalMul` + `Π_PPTanh`) on the [CLS] position, then a
//!   classifier `Π_ScalMul`; logit shares are returned to the client.
//! * GPT-2: final `Π_PPLN`, then the tied LM head as `Π_ScalMul` against
//!   the (already permuted) embedding table; logit shares go to the client,
//!   which applies the prediction softmax locally in plaintext.

use crate::model::PermutedModel;
use crate::mpc::{Mpc, Share};
use crate::net::{OpClass, PartyId};
use crate::Result;

use super::layer::ProtoCtx;
use super::nonlin::{pp_layernorm, pp_tanh};

/// BERT head: `[L2π] → [logits]` (unpermuted shares, `1×n_classes`).
pub fn pp_adaptation_bert(ctx: &mut ProtoCtx, pm: &PermutedModel, l2_pi: &Share) -> Result<Share> {
    // [CLS] row (position 0).
    let cls_pi = l2_pi.row_block(0, 1);
    // pooled π = Π_ScalMul([cπ], πᵀW_Pπ) + b_Pπ
    let pooler_w = pm.pooler_w.as_ref().expect("bert weights");
    let mut pooled = ctx.scalmul_nt(&cls_pi, pooler_w, OpClass::Adaptation);
    pooled = ctx.mpc.add_plain_row(&pooled, pm.pooler_b.as_ref().unwrap());
    // Π_PPTanh at P1 (sees tanh input in π-permuted state).
    let t_pi = pp_tanh(ctx.mpc, ctx.backend, ctx.views, &pooled, "pooler pre-tanh pi")?;
    // classifier: [tπ](W_Cπ)ᵀ = t W_Cᵀ — logits unpermuted in shares.
    let cls_w = pm.cls_w.as_ref().unwrap();
    let mut logits = ctx.scalmul_nt(&t_pi, cls_w, OpClass::Adaptation);
    logits = ctx.mpc.add_plain_row(&logits, pm.cls_b.as_ref().unwrap());
    Ok(logits)
}

/// GPT-2 head: `[L2π] → [logits]` (`n × vocab` shares).
pub fn pp_adaptation_gpt2(ctx: &mut ProtoCtx, pm: &PermutedModel, l2_pi: &Share) -> Result<Share> {
    let h_pi = pp_layernorm(
        ctx.mpc,
        ctx.backend,
        ctx.views,
        l2_pi,
        pm.final_ln_g.as_ref().expect("gpt weights"),
        pm.final_ln_b.as_ref().unwrap(),
        OpClass::Adaptation,
        "final LN pi",
    )?;
    // tied LM head: [Hπ](W_Eπ)ᵀ = H W_Eᵀ
    Ok(ctx.scalmul_nt(&h_pi, &pm.emb_word, OpClass::Adaptation))
}

/// GPT-2 head from an **already-normalized** `[Hπ]` — the batched decode
/// schedule fuses the final `Π_PPLN` into the last layer's reshare flight
/// (see `transformer_layer_step_final`), leaving only the communication-free
/// tied LM head here.
pub fn pp_lm_head_gpt2(ctx: &mut ProtoCtx, pm: &PermutedModel, h_pi: &Share) -> Result<Share> {
    Ok(ctx.scalmul_nt(h_pi, &pm.emb_word, OpClass::Adaptation))
}

/// Return the inference result to the client: both servers send their
/// logit shares to P2 (1 round). Returns the reconstructed plaintext.
pub fn return_to_client(mpc: &mut Mpc, logits: &Share) -> Result<crate::tensor::FloatTensor> {
    let out = return_to_client_unrounded(mpc, logits)?;
    mpc.net.round(OpClass::Adaptation, 1);
    Ok(out)
}

/// Deferred-round logit return for the session-batched decode schedule:
/// the same two server→client transfers as [`return_to_client`], no round
/// charge — every lane's logits ship in the charging lane's single
/// Adaptation flight (P2 receives B independent payload pairs at once).
pub fn return_to_client_unrounded(mpc: &mut Mpc, logits: &Share) -> Result<crate::tensor::FloatTensor> {
    let s0 = mpc.net.transfer(PartyId::P0, PartyId::P2, &logits.s0, OpClass::Adaptation);
    let s1 = mpc.net.transfer(PartyId::P1, PartyId::P2, &logits.s1, OpClass::Adaptation);
    let recon = crate::ring::add(&s0, &s1);
    Ok(crate::fixed::decode_tensor(&recon))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed;
    use crate::model::{ModelConfig, ModelWeights, PermSet, PermutedModel};
    use crate::net::{NetSim, NetworkProfile};
    use crate::runtime::{Backend, NativeBackend};
    use crate::tensor::FloatTensor;
    use crate::util::rng::Rng;

    #[test]
    fn bert_head_matches_plaintext() {
        let cfg = ModelConfig::bert_tiny();
        let w = ModelWeights::random(&cfg, 51);
        let mut rng = Rng::new(52);
        let perms = PermSet::random(&cfg, &mut rng);
        let pm = PermutedModel::build(&cfg, &w, perms.clone());
        let l2 = FloatTensor::from_fn(cfg.n_ctx, cfg.d, |r, c| ((r * 5 + c * 3) % 11) as f32 * 0.1 - 0.5);
        let l2_pi = perms.pi.apply_cols(&l2);

        let mut mpc = Mpc::new(NetSim::new(NetworkProfile::lan()), 53);
        let mut backend = NativeBackend::new();
        let mut views = crate::engine::views::Views::new(false);
        let sh = mpc.share_local(&fixed::encode_tensor(&l2_pi));
        let mut ctx = ProtoCtx {
            mpc: &mut mpc,
            backend: &mut backend,
            views: &mut views,
            fast_sim: false,
            round_batching: false,
        };
        let logits_sh = pp_adaptation_bert(&mut ctx, &pm, &sh).unwrap();
        let got = return_to_client(&mut mpc, &logits_sh).unwrap();

        // plaintext reference
        let cls = FloatTensor::from_vec(1, cfg.d, l2.row(0).to_vec());
        let pooled = cls
            .matmul_nt(w.pooler_w.as_ref().unwrap())
            .add_row(w.pooler_b.as_ref().unwrap())
            .map(f32::tanh);
        let want = pooled.matmul_nt(w.cls_w.as_ref().unwrap()).add_row(w.cls_b.as_ref().unwrap());
        assert_eq!(got.shape(), (1, cfg.n_classes));
        assert!(got.max_abs_diff(&want) < 0.02, "diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn gpt_head_matches_plaintext() {
        let cfg = ModelConfig::gpt2_tiny();
        let w = ModelWeights::random(&cfg, 54);
        let mut rng = Rng::new(55);
        let perms = PermSet::random(&cfg, &mut rng);
        let pm = PermutedModel::build(&cfg, &w, perms.clone());
        let l2 = FloatTensor::from_fn(cfg.n_ctx, cfg.d, |r, c| ((r + c) % 9) as f32 * 0.2 - 0.8);
        let l2_pi = perms.pi.apply_cols(&l2);

        let mut mpc = Mpc::new(NetSim::new(NetworkProfile::lan()), 56);
        let mut backend = NativeBackend::new();
        let mut views = crate::engine::views::Views::new(false);
        let sh = mpc.share_local(&fixed::encode_tensor(&l2_pi));
        let mut ctx = ProtoCtx {
            mpc: &mut mpc,
            backend: &mut backend,
            views: &mut views,
            fast_sim: false,
            round_batching: false,
        };
        let logits_sh = pp_adaptation_gpt2(&mut ctx, &pm, &sh).unwrap();
        let got = return_to_client(&mut mpc, &logits_sh).unwrap();

        let mut nb = NativeBackend::new();
        let h = nb.layernorm(&l2, w.final_ln_g.as_ref().unwrap(), w.final_ln_b.as_ref().unwrap()).unwrap();
        let want = h.matmul_nt(&w.emb_word);
        assert_eq!(got.shape(), (cfg.n_ctx, cfg.vocab));
        // fixed-point noise accumulates over the vocab matmul; bound loosely
        assert!(got.max_abs_diff(&want) < 0.05, "diff {}", got.max_abs_diff(&want));
    }
}
