//! `Π_PPEmbedding` (paper Algorithm 4): one-hot lookup through the
//! communication-free `Π_ScalMul`, positional embeddings added by P0, then
//! `Π_PPLN` with P1-held permuted affine parameters.

use crate::fixed;
use crate::model::PermutedModel;
use crate::mpc::Share;
use crate::net::OpClass;
use crate::runtime::Backend;
use crate::tensor::RingTensor;
use crate::Result;

use super::layer::ProtoCtx;
use super::nonlin::{pp_layernorm, pp_layernorm_unrounded};

/// Client-side: one-hot encode a token sequence in fixed point `(n, vocab)`.
pub fn one_hot_fx(tokens: &[u32], vocab: usize) -> RingTensor {
    let mut t = RingTensor::zeros(tokens.len(), vocab);
    for (r, &tok) in tokens.iter().enumerate() {
        assert!((tok as usize) < vocab, "token {tok} out of vocab {vocab}");
        t.set(r, tok as usize, fixed::encode(1.0));
    }
    t
}

/// Full embedding layer: token ids → `[X_Eπ]`.
///
/// The client's input sharing (1 round, `2·8·n·vocab` bytes) is charged to
/// the Embedding class, mirroring how the paper accounts the lookup.
pub fn pp_embedding(ctx: &mut ProtoCtx, pm: &PermutedModel, tokens: &[u32]) -> Result<Share> {
    // P2 shares the one-hot input with both servers.
    let onehot = one_hot_fx(tokens, pm.cfg.vocab);
    let x_sh = ctx.mpc.input_share(&onehot, OpClass::Embedding);
    // Lookup: [X]·(W_Eπ) = [X_Mπ] — communication-free.
    let mut x_m = ctx.scalmul_rhs(&x_sh, &pm.emb_word, OpClass::Embedding);
    // P0 adds the permuted positional embeddings to its share.
    let n = tokens.len();
    let pos = {
        let mut p = RingTensor::zeros(n, pm.cfg.d);
        for r in 0..n {
            p.row_mut(r).copy_from_slice(pm.emb_pos.row(r));
        }
        p
    };
    x_m = ctx.mpc.add_plain(&x_m, &pos);
    // LayerNorm in the permuted-plaintext state at P1.
    pp_layernorm(
        ctx.mpc,
        ctx.backend,
        ctx.views,
        &x_m,
        &pm.emb_ln_g,
        &pm.emb_ln_b,
        OpClass::Embedding,
        "X_M pi (embedding)",
    )
}

/// Single-token embedding for incremental decoding: the same protocol as
/// [`pp_embedding`] on one row, with the positional embedding taken at the
/// token's sequence position `pos`. Charged to the Embedding class like the
/// full lookup (input share `2·8·vocab` bytes + a `(1, d)` `Π_PPLN`).
pub fn pp_embedding_at(ctx: &mut ProtoCtx, pm: &PermutedModel, token: u32, pos: usize) -> Result<Share> {
    pp_embedding_at_lane(ctx, pm, token, pos, true, "")
}

/// Lane-aware single-token embedding for the session-batched decode step:
/// the same transfers and P1 view as [`pp_embedding_at`] (labels carry the
/// lane's `prefix`), but only the charging lane (`charge_rounds = true`,
/// exactly one per batch) places the Embedding rounds — the other lanes'
/// input shares and `Π_PPLN` halves ride the charging lane's flights, so
/// the whole batch pays the solo 3-round embedding budget once.
pub fn pp_embedding_at_lane(
    ctx: &mut ProtoCtx,
    pm: &PermutedModel,
    token: u32,
    pos: usize,
    charge_rounds: bool,
    prefix: &str,
) -> Result<Share> {
    assert!(pos < pm.cfg.n_ctx, "position {pos} outside n_ctx {}", pm.cfg.n_ctx);
    let onehot = one_hot_fx(&[token], pm.cfg.vocab);
    let x_sh = if charge_rounds {
        ctx.mpc.input_share(&onehot, OpClass::Embedding)
    } else {
        ctx.mpc.input_share_unrounded(&onehot, OpClass::Embedding)
    };
    let mut x_m = ctx.scalmul_rhs(&x_sh, &pm.emb_word, OpClass::Embedding);
    // P0 adds the permuted positional row for this position to its share.
    let pos_row = {
        let mut p = RingTensor::zeros(1, pm.cfg.d);
        p.row_mut(0).copy_from_slice(pm.emb_pos.row(pos));
        p
    };
    x_m = ctx.mpc.add_plain(&x_m, &pos_row);
    let label = format!("{prefix}X_M pi (embedding) pos{pos}");
    if charge_rounds {
        pp_layernorm(
            ctx.mpc,
            ctx.backend,
            ctx.views,
            &x_m,
            &pm.emb_ln_g,
            &pm.emb_ln_b,
            OpClass::Embedding,
            &label,
        )
    } else {
        pp_layernorm_unrounded(
            ctx.mpc,
            ctx.backend,
            ctx.views,
            &x_m,
            &pm.emb_ln_g,
            &pm.emb_ln_b,
            OpClass::Embedding,
            &label,
        )
    }
}

/// Plaintext reference of the embedding output (unpermuted), for tests.
pub fn embedding_reference(
    pm: &PermutedModel,
    weights_word: &crate::tensor::FloatTensor,
    weights_pos: &crate::tensor::FloatTensor,
    ln_g: &[f32],
    ln_b: &[f32],
    tokens: &[u32],
    backend: &mut dyn Backend,
) -> Result<crate::tensor::FloatTensor> {
    let n = tokens.len();
    let d = pm.cfg.d;
    let x = crate::tensor::FloatTensor::from_fn(n, d, |r, c| {
        weights_word.get(tokens[r] as usize, c) + weights_pos.get(r, c)
    });
    backend.layernorm(&x, ln_g, ln_b)
}

/// Byte cost of the client input sharing for a given config (reports).
pub fn input_share_bytes(n: usize, vocab: usize) -> u64 {
    2 * 8 * (n as u64) * (vocab as u64)
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::views::Views;
    use crate::mpc::Mpc;
    use crate::model::{ModelConfig, ModelWeights, PermSet, PermutedModel};
    use crate::net::{NetSim, NetworkProfile};
    use crate::runtime::NativeBackend;
    use crate::util::rng::Rng;

    #[test]
    fn one_hot_rows_sum_to_one() {
        let t = one_hot_fx(&[3, 0, 7], 8);
        for r in 0..3 {
            let s: i64 = t.row(r).iter().sum();
            assert_eq!(s, fixed::encode(1.0));
        }
        assert_eq!(t.get(0, 3), fixed::encode(1.0));
    }

    #[test]
    #[should_panic(expected = "out of vocab")]
    fn one_hot_rejects_oov() {
        one_hot_fx(&[9], 8);
    }

    #[test]
    fn embedding_matches_reference() {
        let cfg = ModelConfig::bert_tiny();
        let w = ModelWeights::random(&cfg, 41);
        let mut rng = Rng::new(42);
        let perms = PermSet::random(&cfg, &mut rng);
        let pm = PermutedModel::build(&cfg, &w, perms.clone());
        let tokens: Vec<u32> = (0..cfg.n_ctx as u32).map(|i| (i * 13) % cfg.vocab as u32).collect();

        let mut mpc = Mpc::new(NetSim::new(NetworkProfile::lan()), 43);
        let mut backend = NativeBackend::new();
        let mut views = Views::new(false);
        let mut ctx = ProtoCtx {
            mpc: &mut mpc,
            backend: &mut backend,
            views: &mut views,
            fast_sim: false,
            round_batching: false,
        };
        let out = pp_embedding(&mut ctx, &pm, &tokens).unwrap();
        let got = fixed::decode_tensor(&out.reconstruct());

        let mut nb = NativeBackend::new();
        let want = embedding_reference(&pm, &w.emb_word, &w.emb_pos, &w.emb_ln_g, &w.emb_ln_b, &tokens, &mut nb).unwrap();
        let want_pi = perms.pi.apply_cols(&want);
        let diff = got.max_abs_diff(&want_pi);
        assert!(diff < 0.02, "embedding diff {diff}");
        // embedding cost: input share + PPLN — all charged to Embedding
        assert!(mpc.net.ledger.class(OpClass::Embedding).bytes > 0);
        assert_eq!(mpc.net.ledger.class(OpClass::Linear).bytes, 0);
    }

    #[test]
    fn input_share_cost_formula() {
        assert_eq!(input_share_bytes(128, 30522), 2 * 8 * 128 * 30522);
    }

    #[test]
    fn single_token_embedding_matches_full_row() {
        // pp_embedding_at(token, pos) must equal row `pos` of the full
        // pp_embedding over a sequence whose `pos`-th token is `token`.
        let cfg = ModelConfig::gpt2_tiny();
        let w = ModelWeights::random(&cfg, 45);
        let mut rng = Rng::new(46);
        let perms = PermSet::random(&cfg, &mut rng);
        let pm = PermutedModel::build(&cfg, &w, perms.clone());
        let tokens: Vec<u32> = (0..cfg.n_ctx as u32).map(|i| (i * 7 + 5) % cfg.vocab as u32).collect();

        let mut mpc = Mpc::new(NetSim::new(NetworkProfile::lan()), 47);
        let mut backend = NativeBackend::new();
        let mut views = Views::new(false);
        let full = {
            let mut ctx =
                ProtoCtx {
                    mpc: &mut mpc,
                    backend: &mut backend,
                    views: &mut views,
                    fast_sim: false,
                    round_batching: false,
                };
            let out = pp_embedding(&mut ctx, &pm, &tokens).unwrap();
            fixed::decode_tensor(&out.reconstruct())
        };
        for pos in [0usize, 1, cfg.n_ctx - 1] {
            let mut ctx =
                ProtoCtx {
                    mpc: &mut mpc,
                    backend: &mut backend,
                    views: &mut views,
                    fast_sim: false,
                    round_batching: false,
                };
            let out = pp_embedding_at(&mut ctx, &pm, tokens[pos], pos).unwrap();
            let got = fixed::decode_tensor(&out.reconstruct());
            let want = crate::tensor::FloatTensor::from_vec(1, cfg.d, full.row(pos).to_vec());
            let diff = got.max_abs_diff(&want);
            assert!(diff < 0.02, "embedding row {pos} diff {diff}");
        }
    }
}
