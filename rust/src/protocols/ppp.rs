//! `Π_PPP` — privacy-preserving permutation (paper Algorithm 6).
//!
//! When a linear protocol cancels the permutation (e.g. `Q Kᵀ` in
//! attention), the resulting shares `[X]` are unpermuted and therefore
//! cannot be opened at P1 for a plaintext non-linearity. `Π_PPP` restores a
//! permuted state by multiplying with a *secret-shared* permutation matrix:
//! `[Xπ] = Π_MatMul([X], [π])`. The shares of `π` come from the permutation
//! holder (the client in Algorithm 6; equivalently P0/dealer — we follow
//! the algorithm and charge the dealing transfer).

use crate::fixed;
use crate::mpc::{Mpc, Share};
use crate::net::OpClass;
use crate::perm::Perm;
use crate::tensor::RingTensor;

/// Fixed-point encoding of a permutation matrix (column convention matches
/// [`Perm::apply_cols`]: right-multiplying selects `out[:, j] = in[:, idx[j]]`).
pub fn perm_matrix_fx(p: &Perm) -> RingTensor {
    let n = p.n();
    let mut m = RingTensor::zeros(n, n);
    for (j, &i) in p.indices().iter().enumerate() {
        m.set(i, j, fixed::encode(1.0));
    }
    m
}

/// Transposed encoding (`πᵀ`, for row permutations).
pub fn perm_matrix_t_fx(p: &Perm) -> RingTensor {
    perm_matrix_fx(p).transpose()
}

/// Share a permutation matrix (the one-time dealing step of Algorithm 6;
/// the transfer of the two share halves is charged to `class`).
pub fn share_perm(mpc: &mut Mpc, p: &Perm, class: OpClass) -> Share {
    let m = perm_matrix_fx(p);
    let sh = mpc.share_local(&m);
    // dealing: holder sends one half to each server (1 round, 2·|π| bytes)
    mpc.net.charge_bytes(class, 2 * (m.len() as u64) * 8);
    mpc.net.round(class, 1);
    sh
}

/// Share `πᵀ` (for left-multiplication / row permutation).
pub fn share_perm_t(mpc: &mut Mpc, p: &Perm, class: OpClass) -> Share {
    let m = perm_matrix_t_fx(p);
    let sh = mpc.share_local(&m);
    mpc.net.charge_bytes(class, 2 * (m.len() as u64) * 8);
    mpc.net.round(class, 1);
    sh
}

/// `Π_PPP`: `[X] → [Xπ]` via `Π_MatMul([X], [π])`.
pub fn ppp_cols(mpc: &mut Mpc, x: &Share, pi_sh: &Share, class: OpClass) -> Share {
    mpc.matmul(x, pi_sh, class)
}

/// `Π_PPP` against a *session-fixed* `π₁` through its fixed-operand
/// correlation (DESIGN.md §Fixed-operand correlations): the masked opening
/// `f_pi = π₁ − B` happened once at session setup, so each restoration
/// opens only `[X]`'s mask difference — `2·8·|X|` bytes instead of
/// `2·8·(|X| + |π₁|)`, the dominant warm-decode saving.
pub fn ppp_cols_fixed(
    mpc: &mut Mpc,
    x: &Share,
    f_pi: &RingTensor,
    corr: &mut crate::mpc::FixedOperandCorrelation,
    class: OpClass,
) -> crate::Result<Share> {
    mpc.matmul_fixed_rhs(x, f_pi, corr, class)
}

/// Row variant: `[X] → [πᵀX]` via `Π_MatMul([πᵀ], [X])`.
pub fn ppp_rows_t(mpc: &mut Mpc, pi_t_sh: &Share, x: &Share, class: OpClass) -> Share {
    mpc.matmul(pi_t_sh, x, class)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{NetSim, NetworkProfile};
    use crate::tensor::FloatTensor;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn mk() -> Mpc {
        Mpc::new(NetSim::new(NetworkProfile::lan()), 5)
    }

    #[test]
    fn perm_matrix_matches_apply_cols() {
        check("perm matrix == apply_cols", 20, |g| {
            let n = g.dim(10);
            let p = Perm::random(n, g.rng());
            let x = FloatTensor::from_fn(3, n, |r, c| (r * n + c) as f32 * 0.1);
            let dense = fixed::decode_tensor(&perm_matrix_fx(&p));
            let via_matmul = x.matmul(&dense);
            let via_perm = p.apply_cols(&x);
            assert!(via_matmul.max_abs_diff(&via_perm) < 1e-3);
        });
    }

    #[test]
    fn ppp_restores_permuted_state() {
        let mut mpc = mk();
        let mut rng = Rng::new(9);
        let n = 8;
        let p = Perm::random(n, &mut rng);
        let x = FloatTensor::from_fn(4, n, |r, c| ((r + 2 * c) % 5) as f32 * 0.3 - 0.6);
        let x_sh = mpc.share_local(&fixed::encode_tensor(&x));
        let pi_sh = share_perm(&mut mpc, &p, OpClass::Linear);
        let out = ppp_cols(&mut mpc, &x_sh, &pi_sh, OpClass::Linear);
        let got = fixed::decode_tensor(&out.reconstruct());
        let want = p.apply_cols(&x);
        assert!(got.max_abs_diff(&want) < 1e-2, "diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn ppp_rows_permutes_rows() {
        let mut mpc = mk();
        let mut rng = Rng::new(10);
        let n = 6;
        let p = Perm::random(n, &mut rng);
        let x = FloatTensor::from_fn(n, 4, |r, c| (r * 4 + c) as f32 * 0.2);
        let x_sh = mpc.share_local(&fixed::encode_tensor(&x));
        let pit_sh = share_perm_t(&mut mpc, &p, OpClass::Linear);
        let out = ppp_rows_t(&mut mpc, &pit_sh, &x_sh, OpClass::Linear);
        let got = fixed::decode_tensor(&out.reconstruct());
        let want = p.apply_rows_t(&x);
        assert!(got.max_abs_diff(&want) < 1e-2);
    }

    #[test]
    fn ppp_then_inverse_is_identity() {
        let mut mpc = mk();
        let mut rng = Rng::new(11);
        let n = 8;
        let p = Perm::random(n, &mut rng);
        let x = FloatTensor::from_fn(2, n, |r, c| (r + c) as f32 * 0.25);
        let x_sh = mpc.share_local(&fixed::encode_tensor(&x));
        let pi_sh = share_perm(&mut mpc, &p, OpClass::Linear);
        let inv_sh = share_perm(&mut mpc, &p.inverse(), OpClass::Linear);
        let permuted = ppp_cols(&mut mpc, &x_sh, &pi_sh, OpClass::Linear);
        let back = ppp_cols(&mut mpc, &permuted, &inv_sh, OpClass::Linear);
        let got = fixed::decode_tensor(&back.reconstruct());
        assert!(got.max_abs_diff(&x) < 1e-2);
    }

    #[test]
    fn ppp_fixed_matches_plain_ppp_with_one_session_opening() {
        use crate::mpc::TripleShape;
        let mut mpc = mk();
        let mut rng = Rng::new(14);
        let n = 8;
        let p = Perm::random(n, &mut rng);
        let pi_sh = share_perm(&mut mpc, &p, OpClass::Linear);
        let mut corr = mpc.dealer.fixed_correlation(TripleShape::fixed_ppp(3, n, 4));
        let f_pi = mpc.open_fixed_operand(&pi_sh, &mut corr, OpClass::Correlation).unwrap();
        for i in 0..4 {
            let x = FloatTensor::from_fn(3, n, |r, c| ((r + c + i) % 5) as f32 * 0.3 - 0.6);
            let x_sh = mpc.share_local(&fixed::encode_tensor(&x));
            let out = ppp_cols_fixed(&mut mpc, &x_sh, &f_pi, &mut corr, OpClass::Linear).unwrap();
            let got = fixed::decode_tensor(&out.reconstruct());
            let want = p.apply_cols(&x);
            assert!(got.max_abs_diff(&want) < 1e-2, "use {i} diff {}", got.max_abs_diff(&want));
        }
        // π₁-side mask opened exactly once for the whole session
        assert_eq!(corr.openings(), 1);
        assert_eq!(corr.uses_left(), 0);
    }

    #[test]
    fn costs_are_one_round_per_matmul() {
        let mut mpc = mk();
        let mut rng = Rng::new(12);
        let p = Perm::random(8, &mut rng);
        let x = mpc.share_local(&RingTensor::zeros(8, 8));
        let before_rounds = mpc.net.ledger.rounds_total();
        let pi_sh = share_perm(&mut mpc, &p, OpClass::Linear); // 1 round dealing
        let _ = ppp_cols(&mut mpc, &x, &pi_sh, OpClass::Linear); // 1 round matmul
        assert_eq!(mpc.net.ledger.rounds_total() - before_rounds, 2);
    }
}
