//! The full privacy-preserving Transformer layer (paper Fig. 6):
//! multi-head attention + feed-forward, with Centaur's hybrid state
//! management. See `rust/src/model/permute.rs` for the algebra table.
//!
//! Per-layer protocol sequence (classes in parentheses):
//!
//! 1. `[Q],[K],[V] = Π_ScalMul([Xπ], Wπ) + b`            (Linear, 0 comm)
//! 2. per head: `[O1] = Π_MatMul([Q_h],[K_hᵀ])/√dh + M`  (Linear, 1 round batched)
//! 3. `[O1π₁] = Π_PPP([O1], [π₁])`                        (Linear, 1 round)
//! 4. `[O2π₁] = Π_PPSM([O1π₁])`                           (Softmax, 2 rounds)
//! 5. `[Ṽ] = Π_PPP([π₁ᵀ],[V])`                            (Linear, 1 round)
//! 6. per head: `[O3_h] = Π_MatMul([O2π₁]_h,[Ṽ_h])`       (Linear, 1 round batched)
//! 7. `[O4π] = Π_ScalMul([O3], πᵀW_O) + b_Oπ`             (Linear, 0 comm)
//! 8. `[L1π] = Π_PPLN([O4π + Xπ], γ₁π, β₁π)`              (LayerNorm, 2 rounds)
//! 9. `[O5π₂] = Π_ScalMul([L1π], π₂ᵀW₁π) + b₁π₂`          (Linear, 0 comm)
//! 10. `[Gπ₂] = Π_PPGeLU([O5π₂])`                          (GeLU, 2 rounds)
//! 11. `[O6π] = Π_ScalMul([Gπ₂], πᵀW₂π₂) + b₂π`            (Linear, 0 comm)
//! 12. `[L2π] = Π_PPLN([O6π + L1π], γ₂π, β₂π)`             (LayerNorm, 2 rounds)

use crate::engine::views::Views;
use crate::fixed;
use crate::model::{ModelConfig, PermLayer};
use crate::mpc::{FixedOperandCorrelation, Mpc, Share, TripleShape};
use crate::net::OpClass;
use crate::runtime::Backend;
use crate::tensor::RingTensor;
use crate::Result;

use super::nonlin::{
    pp_gelu, pp_gelu_unrounded, pp_layernorm, pp_layernorm_unrounded, pp_softmax,
    pp_softmax_unrounded,
};
use super::ppp;

/// Mask value standing in for −∞ in causal attention (exp(−1e5) == 0 in
/// f32 while staying comfortably inside the fixed-point range).
pub const MASK_NEG: f64 = -1e5;

/// Protocol execution context threaded through the per-layer protocols.
pub struct ProtoCtx<'a> {
    /// MPC context (network + dealer).
    pub mpc: &'a mut Mpc,
    /// P1's plaintext op executor.
    pub backend: &'a mut dyn Backend,
    /// P1 observation ledger.
    pub views: &'a mut Views,
    /// Fast-sim: share×share products via charged-ideal (exact wire costs,
    /// single local product) — used for paper-scale models on this testbed.
    pub fast_sim: bool,
    /// Batched-opening decode schedule (DESIGN.md §Batched openings): the
    /// single-token step coalesces its independent openings into shared
    /// flights — identical transfers and bytes, 6 rounds/layer instead of
    /// 12. Only [`transformer_layer_step`] consults this; the full-sequence
    /// [`transformer_layer`] keeps the sequential schedule.
    pub round_batching: bool,
}

impl<'a> ProtoCtx<'a> {
    /// Batched share×share products (one round), honoring fast-sim.
    pub fn matmul_batch(&mut self, pairs: &[(&Share, &Share)], class: OpClass) -> Vec<Share> {
        if self.fast_sim {
            self.mpc.matmul_charged_ideal_batch(pairs, class)
        } else {
            self.mpc.matmul_batch(pairs, class)
        }
    }

    /// Share×share product, honoring fast-sim.
    pub fn matmul(&mut self, x: &Share, y: &Share, class: OpClass) -> Share {
        if self.fast_sim {
            self.mpc.matmul_charged_ideal(x, y, class)
        } else {
            self.mpc.matmul(x, y, class)
        }
    }

    /// `[X]·Wᵀ` against public weights, honoring fast-sim.
    pub fn scalmul_nt(&mut self, x: &Share, w_fx: &RingTensor, class: OpClass) -> Share {
        if self.fast_sim {
            self.mpc.scalmul_nt_ideal(x, w_fx, class)
        } else {
            self.mpc.scalmul_nt(x, w_fx, class)
        }
    }

    /// `[X]·W` against public weights, honoring fast-sim.
    pub fn scalmul_rhs(&mut self, x: &Share, w_fx: &RingTensor, class: OpClass) -> Share {
        if self.fast_sim {
            self.mpc.scalmul_rhs_ideal(x, w_fx, class)
        } else {
            self.mpc.scalmul_rhs(x, w_fx, class)
        }
    }

    /// `Π_PPP` against the session-fixed π₁ correlation, honoring fast-sim
    /// (identical wire charges and use accounting in both modes).
    pub fn ppp_cols_fixed(
        &mut self,
        x: &Share,
        f_pi: &RingTensor,
        corr: &mut FixedOperandCorrelation,
        class: OpClass,
    ) -> Result<Share> {
        if self.fast_sim {
            self.mpc.matmul_fixed_rhs_ideal(x, f_pi, corr, class)
        } else {
            ppp::ppp_cols_fixed(self.mpc, x, f_pi, corr, class)
        }
    }

    /// Column-per-use fixed-left matmul (the KV outer product), honoring
    /// fast-sim; the round is charged by the caller in both modes.
    pub fn matmul_fixed_lhs_col(
        &mut self,
        f_pub: &RingTensor,
        y: &Share,
        corr: &mut FixedOperandCorrelation,
        pos: usize,
        class: OpClass,
    ) -> Result<Share> {
        if self.fast_sim {
            self.mpc.matmul_fixed_lhs_col_ideal(f_pub, y, corr, pos, class)
        } else {
            self.mpc.matmul_fixed_lhs_col(f_pub, y, corr, pos, class)
        }
    }

    /// Row-grown per-head score products, honoring fast-sim.
    pub fn matmul_fixed_grown_scores(
        &mut self,
        q: &Share,
        f_rows: &RingTensor,
        corr: &mut FixedOperandCorrelation,
        pos: usize,
        n_out: usize,
        class: OpClass,
    ) -> Result<Vec<Share>> {
        if self.fast_sim {
            self.mpc.matmul_fixed_grown_scores_ideal(q, f_rows, corr, pos, n_out, class)
        } else {
            self.mpc.matmul_fixed_grown_scores(q, f_rows, corr, pos, n_out, class)
        }
    }
}

/// Stack shares vertically (head stacking for the Π_PPSM batch).
pub fn stack_rows(blocks: &[Share]) -> Share {
    let cols = blocks[0].cols();
    let rows: usize = blocks.iter().map(|b| b.rows()).sum();
    let f = |pick: fn(&Share) -> &RingTensor| {
        let mut out = RingTensor::zeros(rows, cols);
        let mut r0 = 0;
        for b in blocks {
            let t = pick(b);
            for r in 0..t.rows() {
                out.row_mut(r0 + r).copy_from_slice(t.row(r));
            }
            r0 += t.rows();
        }
        out
    };
    Share { s0: f(|b| &b.s0), s1: f(|b| &b.s1) }
}

/// Causal mask in fixed point, stacked for `h` heads: `(h·n, n)`.
pub fn causal_mask_fx(h: usize, n: usize) -> RingTensor {
    let neg = fixed::encode(MASK_NEG);
    RingTensor::from_fn(h * n, n, |r, c| if c > (r % n) { neg } else { 0 })
}

/// Single-query causal mask for the incremental decode step at position
/// `pos`, stacked per head: `(h, n)` with every column `> pos` masked.
/// Columns `> pos` cover both future positions and the not-yet-written
/// (zero-share) tail of the KV cache, so masked columns end up with
/// softmax weight exactly 0 — the same as the padded full-recompute path.
pub fn causal_mask_row_fx(h: usize, n: usize, pos: usize) -> RingTensor {
    let neg = fixed::encode(MASK_NEG);
    RingTensor::from_fn(h, n, |_, c| if c > pos { neg } else { 0 })
}

/// Secret-shared per-layer KV cache for incremental private decoding.
///
/// Two fixed-shape `(n_ctx, d)` sharings are kept **as shares for the whole
/// session** — neither is ever reconstructed, so P1 still only observes
/// π-permuted plaintext (the same `Π_PPSM`/`Π_PPLN`/`Π_PPGeLU` openings as
/// the full forward pass, now on single-token rows):
///
/// * `[K]` — key rows in natural sequence order; row `t` is written locally
///   by each party when token `t` arrives (a share append costs nothing).
/// * `[Ṽ] = [π₁ᵀ V]` — the value stream pre-permuted by the session's fixed
///   sequence permutation, so the `π₁` riding on the softmax output cancels
///   against it in `Π_MatMul([O2π₁], [Ṽ])` exactly as in the full layer.
///   Appending `v_t` updates it with one outer-product Beaver matmul
///   `[π₁ᵀ e_t] (n×1) @ [v_t] (1×d)` — `π₁ᵀ e_t` is just a column slice of
///   the already-dealt shared permutation matrix, so the mapping `t → π₁(t)`
///   stays secret from both servers.
///
/// Unwritten rows hold zero shares; the decode-step mask gives those
/// columns softmax weight exactly 0, which keeps incremental outputs
/// token-for-token aligned with the padded full-recompute path.
pub struct LayerKvCache {
    /// Context capacity (`n_ctx`).
    cap: usize,
    /// Plain-path `[K]` share cache. In correlated mode this stays empty
    /// (`0 × d`): the K stream then lives as the session mask plus the
    /// public masked rows inside `corr` — keeping a share copy too would
    /// be dead per-session state (2·n_ctx·d·8 bytes per layer).
    k: Share,
    v_tilde: Share,
    len: usize,
    /// Per-append `[Ṽ]` update deltas in append order. `[Ṽ]` is *dense* —
    /// every outer-product append touches all `n_ctx · d` entries — so
    /// speculative rollback cannot zero rows; it subtracts the retained
    /// deltas in reverse (exact in the ring) instead
    /// ([`LayerKvCache::truncate_to`]).
    upds: Vec<Share>,
    /// Session-scoped fixed-operand correlations (`None` = the plain
    /// per-step Beaver path, kept as the pre-correlation baseline).
    corr: Option<KvCorrelations>,
}

/// Session-scoped fixed-operand correlation state for one layer's
/// incremental decode (DESIGN.md §Fixed-operand correlations): the three
/// operands of a decode step that are fixed — or write-once — for the whole
/// session each get one dealer mask, one masked opening, and per-use
/// correlations instead of a fresh Beaver triple per step.
pub struct KvCorrelations {
    /// Right-fixed π₁ correlation for the per-step `Π_PPP`.
    pub ppp: FixedOperandCorrelation,
    /// Public masked opening `π₁ − B` (uniformly random), opened once at
    /// session setup.
    pub f_pi1: RingTensor,
    /// Left-fixed π₁ᵀ correlation for the KV outer-product append
    /// (column `pos` per use keeps the mapping `t → π₁(t)` secret).
    pub append: FixedOperandCorrelation,
    /// Public masked opening `π₁ᵀ − B'`, opened once at session setup.
    pub f_pi1_t: RingTensor,
    /// Row-grown correlation over the write-once `[K]` cache for the
    /// per-step score products.
    pub scores: FixedOperandCorrelation,
    /// Public masked K rows `K[t] − B_K[t]`, opened as rows are written
    /// (each cache entry is masked by its own one-time-pad entry, opened
    /// exactly once — entries never change after their write).
    pub f_k: RingTensor,
}

/// Deal and open the session-scoped fixed-operand correlations for one
/// layer's decode: three dealer bundles (pool-first, generated on demand
/// on a cold start) plus the one-time masked openings of π₁ and π₁ᵀ —
/// `2·8·n²` bytes and 1 round each, charged to [`OpClass::Correlation`] so
/// the amortized setup stays visible and separate from warm-step ledgers.
pub fn deal_kv_correlations(
    mpc: &mut Mpc,
    cfg: &ModelConfig,
    pi1_sh: &Share,
    pi1_t_sh: &Share,
) -> Result<KvCorrelations> {
    let n = cfg.n_ctx;
    let (d, h) = (cfg.d, cfg.h);
    let mut ppp_corr = mpc.dealer.fixed_correlation(TripleShape::fixed_ppp(h, n, n));
    let f_pi1 = mpc.open_fixed_operand(pi1_sh, &mut ppp_corr, OpClass::Correlation)?;
    let mut append = mpc.dealer.fixed_correlation(TripleShape::fixed_append(n, d, n));
    let f_pi1_t = mpc.open_fixed_operand(pi1_t_sh, &mut append, OpClass::Correlation)?;
    let scores = mpc.dealer.fixed_correlation(TripleShape::fixed_scores(h, n, d, n));
    Ok(KvCorrelations {
        ppp: ppp_corr,
        f_pi1,
        append,
        f_pi1_t,
        scores,
        f_k: RingTensor::zeros(n, d),
    })
}

/// Deal and open the fixed-operand correlations for **every** layer of a
/// decode session at once, sharing one dealt π₁ mask (and one π₁ᵀ mask)
/// across the layers: the engine holds a single session permutation used
/// by all layers, so the masked differences `π₁ − B` and `π₁ᵀ − B'` are
/// each opened on the wire once per *session* instead of once per layer —
/// `corr_setup` drops from `2·L·(2·8·n²)` to `2·(2·8·n²)` bytes (an
/// `n_layers×` cut) and from `2·L` to `2` Correlation rounds. The
/// remaining layers adopt the shared opening
/// ([`FixedOperandCorrelation::adopt_shared_opening`]), so the per-layer
/// security census still reports exactly one π₁-side opening per layer,
/// and the per-layer row-grown score correlations stay independent (each
/// layer's K cache is its own write-once stream).
pub fn deal_session_kv_correlations(
    mpc: &mut Mpc,
    cfg: &ModelConfig,
    pi1_sh: &Share,
    pi1_t_sh: &Share,
) -> Result<Vec<KvCorrelations>> {
    let n = cfg.n_ctx;
    let (d, h, l) = (cfg.d, cfg.h, cfg.layers);
    anyhow::ensure!(l > 0, "a decode session needs at least one layer");
    let mut ppps =
        mpc.dealer.fixed_session_correlations(TripleShape::fixed_ppp_session(h, n, n, l));
    let f_pi1 = mpc.open_fixed_operand(pi1_sh, &mut ppps[0], OpClass::Correlation)?;
    for c in ppps.iter_mut().skip(1) {
        c.adopt_shared_opening()?;
    }
    let mut appends =
        mpc.dealer.fixed_session_correlations(TripleShape::fixed_append_session(n, d, n, l));
    let f_pi1_t = mpc.open_fixed_operand(pi1_t_sh, &mut appends[0], OpClass::Correlation)?;
    for c in appends.iter_mut().skip(1) {
        c.adopt_shared_opening()?;
    }
    Ok(ppps
        .into_iter()
        .zip(appends)
        .map(|(ppp, append)| KvCorrelations {
            ppp,
            f_pi1: f_pi1.clone(),
            append,
            f_pi1_t: f_pi1_t.clone(),
            scores: mpc.dealer.fixed_correlation(TripleShape::fixed_scores(h, n, d, n)),
            f_k: RingTensor::zeros(n, d),
        })
        .collect())
}

impl LayerKvCache {
    /// Empty cache for a layer of width `d` and capacity `n_ctx` tokens.
    pub fn new(n_ctx: usize, d: usize) -> Self {
        LayerKvCache {
            cap: n_ctx,
            k: Share { s0: RingTensor::zeros(n_ctx, d), s1: RingTensor::zeros(n_ctx, d) },
            v_tilde: Share { s0: RingTensor::zeros(n_ctx, d), s1: RingTensor::zeros(n_ctx, d) },
            len: 0,
            upds: Vec::new(),
            corr: None,
        }
    }

    /// Empty cache wired to session-scoped fixed-operand correlations:
    /// appends and score/`Π_PPP` products run the amortized protocols.
    /// The `[K]` share cache is not allocated — in this mode the key
    /// stream lives entirely inside the correlation state.
    pub fn with_correlations(n_ctx: usize, d: usize, corr: KvCorrelations) -> Self {
        LayerKvCache {
            cap: n_ctx,
            k: Share { s0: RingTensor::zeros(0, d), s1: RingTensor::zeros(0, d) },
            v_tilde: Share { s0: RingTensor::zeros(n_ctx, d), s1: RingTensor::zeros(n_ctx, d) },
            len: 0,
            upds: Vec::new(),
            corr: Some(corr),
        }
    }

    /// The layer's correlation state, when the amortized path is active.
    pub fn correlations(&self) -> Option<&KvCorrelations> {
        self.corr.as_ref()
    }

    /// Tokens cached so far.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether no token has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum number of cacheable tokens (`n_ctx`).
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Append the `(1, d)` sharings `[k_t]`, `[v_t]` at position `pos`.
    ///
    /// Plain path: local row write into `[K]` plus one outer-product
    /// `Π_MatMul` into `[Ṽ]`. With correlations, the outer product runs
    /// against the session-fixed π₁ᵀ column correlation (only `[v_t]`'s
    /// mask difference is opened) and the new K row is absorbed as a
    /// masked opening extending the grown score correlation (no share
    /// copy kept) — `2·8·2d` bytes, 1 round, exactly like the plain
    /// path's `2·8·(n + d)` at `n = d` but enabling the per-step score
    /// and `Π_PPP` savings.
    pub fn append(
        &mut self,
        ctx: &mut ProtoCtx,
        pi1_t_sh: &Share,
        k_new: &Share,
        v_new: &Share,
        pos: usize,
    ) -> Result<()> {
        assert_eq!(pos, self.len, "KV cache appends must be sequential");
        assert!(pos < self.capacity(), "KV cache full");
        if let Some(c) = self.corr.as_mut() {
            // masked K-row opening + v-side E opening, one parallel round
            let f_row = ctx.mpc.open_fixed_grown_row(k_new, &mut c.scores, pos, OpClass::Linear)?;
            c.f_k.row_mut(pos).copy_from_slice(f_row.row(0));
            let upd = ctx.matmul_fixed_lhs_col(&c.f_pi1_t, v_new, &mut c.append, pos, OpClass::Linear)?;
            ctx.mpc.net.round(OpClass::Linear, 1);
            self.v_tilde = ctx.mpc.add(&self.v_tilde, &upd);
            self.upds.push(upd);
        } else {
            self.k.s0.row_mut(pos).copy_from_slice(k_new.s0.row(0));
            self.k.s1.row_mut(pos).copy_from_slice(k_new.s1.row(0));
            // [Ṽ] += [π₁ᵀ e_pos] @ [v_t] — the column slice keeps π₁ secret.
            let col = pi1_t_sh.col_block(pos, pos + 1);
            let upd = ctx.matmul(&col, v_new, OpClass::Linear);
            self.v_tilde = ctx.mpc.add(&self.v_tilde, &upd);
            self.upds.push(upd);
        }
        self.len = pos + 1;
        Ok(())
    }

    /// Roll the cache back so exactly `pos` tokens remain — the reject
    /// half of speculative decode (DESIGN.md §Speculative decode).
    ///
    /// Everything an append did is undone exactly, locally, with zero
    /// communication:
    /// * `[Ṽ]` — the retained per-append outer-product deltas are
    ///   subtracted in reverse (exact mod 2⁶⁴, since ring addition is
    ///   invertible);
    /// * `[K]` — the plain path re-zeroes the rolled-back rows; the
    ///   correlated path re-zeroes the public masked rows `f_k` and
    ///   rewinds the row-opening counter so the corrected row re-opens at
    ///   the same position;
    /// * fixed-operand correlations — the consumed per-use bundles of all
    ///   three families are restored
    ///   ([`FixedOperandCorrelation::rewind_uses_to`]; every absorb
    ///   consumes exactly one use per family, so `used == len` going in),
    ///   and the matching pool demand is handed back by the caller.
    pub fn truncate_to(&mut self, pos: usize) -> Result<()> {
        anyhow::ensure!(pos <= self.len, "cannot truncate forward (len {}, target {pos})", self.len);
        while self.len > pos {
            let upd = self.upds.pop().expect("one retained delta per append");
            self.v_tilde = Share {
                s0: crate::ring::sub(&self.v_tilde.s0, &upd.s0),
                s1: crate::ring::sub(&self.v_tilde.s1, &upd.s1),
            };
            self.len -= 1;
            let row = self.len;
            if let Some(c) = self.corr.as_mut() {
                c.f_k.row_mut(row).fill(0);
            } else {
                self.k.s0.row_mut(row).fill(0);
                self.k.s1.row_mut(row).fill(0);
            }
        }
        if let Some(c) = self.corr.as_mut() {
            c.ppp.rewind_uses_to(pos)?;
            c.append.rewind_uses_to(pos)?;
            c.scores.rewind_uses_to(pos)?;
            c.scores.rewind_opened_to(pos as u64)?;
        }
        Ok(())
    }

    /// FNV-1a digest over the cache's entire share state (`[K]`/`f_k`,
    /// `[Ṽ]`, length) — lets the rollback property tests assert
    /// share-for-share state identity without exposing the raw cache
    /// sharings in the public API.
    pub fn state_digest(&self) -> u64 {
        let mut h = 0xcbf29ce484222325u64;
        let mut eat = |t: &RingTensor| {
            for &v in t.data() {
                for b in v.to_le_bytes() {
                    h = (h ^ b as u64).wrapping_mul(0x100000001b3);
                }
            }
        };
        eat(&self.k.s0);
        eat(&self.k.s1);
        eat(&self.v_tilde.s0);
        eat(&self.v_tilde.s1);
        if let Some(c) = self.corr.as_ref() {
            eat(&c.f_k);
        }
        h ^ self.len as u64
    }
}

/// The Beaver-triple shape profile one incremental decode step consumes
/// (per model, all layers), with per-step multiplicities — the keys a
/// serving [`crate::mpc::TriplePool`] pre-registers so decode-shape
/// triples are stocked before the first generation request arrives.
///
/// Per layer and step: one `(n,1,d)` Ṽ outer-product update, `h` score
/// products `(1,dh,n)`, one `Π_PPP` re-permutation `(h,n,n)`, and `h`
/// value products `(1,n,dh)`.
pub fn decode_step_shapes(cfg: &ModelConfig) -> Vec<(TripleShape, u64)> {
    let n = cfg.n_ctx;
    let (d, h, dh) = (cfg.d, cfg.h, cfg.dh());
    let l = cfg.layers as u64;
    vec![
        (TripleShape::matmul(n, 1, d), l),
        (TripleShape::matmul(1, dh, n), l * h as u64),
        (TripleShape::matmul(h, n, n), l),
        (TripleShape::matmul(1, n, dh), l * h as u64),
    ]
}

/// Pool demand of one decode session (`steps` absorbs). With fixed-operand
/// correlations the session consumes one shared-mask **session bundle** of
/// the π₁ and π₁ᵀ families (all layers in one entry, dealt for the full
/// `n_ctx` capacity — see [`deal_session_kv_correlations`]), one row-grown
/// score bundle per layer, plus the per-step value products — the only
/// decode matmuls still fed by plain Beaver triples (their `[Ṽ]` operand
/// genuinely changes every step; see DESIGN.md §Fixed-operand
/// correlations). Without correlations it is `steps` times the plain
/// per-step profile of [`decode_step_shapes`].
pub fn decode_pool_shapes(cfg: &ModelConfig, correlations: bool, steps: u64) -> Vec<(TripleShape, u64)> {
    if !correlations {
        return decode_step_shapes(cfg).into_iter().map(|(s, c)| (s, c * steps)).collect();
    }
    let n = cfg.n_ctx;
    let (d, h, dh) = (cfg.d, cfg.h, cfg.dh());
    let l = cfg.layers as u64;
    vec![
        (TripleShape::fixed_ppp_session(h, n, n, cfg.layers), 1),
        (TripleShape::fixed_append_session(n, d, n, cfg.layers), 1),
        (TripleShape::fixed_scores(h, n, d, n), l),
        (TripleShape::matmul(1, n, dh), l * h as u64 * steps),
    ]
}

/// Batch-aware pool demand: `sessions` concurrent decode sessions each
/// deal their own correlation bundles and consume their own per-step
/// value triples. The shape *keys* are shared — every session of the same
/// model deals the same shapes — and the multiplicities add, so B
/// sessions never alias one session's stock (the dealer keys the pool by
/// shape, not by session).
pub fn decode_pool_shapes_batched(
    cfg: &ModelConfig,
    correlations: bool,
    steps: u64,
    sessions: u64,
) -> Vec<(TripleShape, u64)> {
    decode_pool_shapes_speculative(cfg, correlations, steps, sessions, 1)
}

/// Speculative-aware pool demand: each of `sessions` sessions runs up to
/// `steps` verify steps of `spec_k` lanes each. Every lane consumes the
/// per-step *non-fixed* triples (the `[Ṽ]` value products — and, without
/// correlations, the whole plain per-step profile), so those shapes scale
/// by `spec_k`. The fixed-operand correlation bundles do **not** scale:
/// they are dealt once per session for the full `n_ctx` capacity, and
/// rollback rewinds their uses, so net consumption stays bounded by
/// positions regardless of how many rejected lanes were speculated.
pub fn decode_pool_shapes_speculative(
    cfg: &ModelConfig,
    correlations: bool,
    steps: u64,
    sessions: u64,
    spec_k: u64,
) -> Vec<(TripleShape, u64)> {
    decode_pool_shapes(cfg, correlations, steps)
        .into_iter()
        .map(|(s, c)| {
            let lanes = if s.is_fixed() { 1 } else { spec_k.max(1) };
            (s, c * lanes * sessions.max(1))
        })
        .collect()
}

/// One `(session, position)` lane inside a [`StepLaneGroup`]: the
/// activation row being advanced and the sequence position it lives at.
pub struct SpecLane {
    /// The lane's current `(1, d)` activation `[xπ]`, updated in place by
    /// each batched layer step.
    pub x_pi: Share,
    /// The sequence position this lane's row occupies (ragged across the
    /// batch: every lane attends over its own prefix length).
    pub pos: usize,
    /// Online bytes attributed to this lane so far this step (every
    /// byte-moving op in the step is per-lane, so the lanes' sums equal
    /// the whole-step ledger).
    pub bytes: u64,
}

/// One session's slot in a session-batched decode step (the batch axis of
/// DESIGN.md §Continuous batching, generalized for speculative decode):
/// the session's private per-layer KV caches plus one or more lanes at
/// **successive positions** (`pos`, `pos+1`, …). Continuous batching uses
/// B single-lane groups; speculative decode puts a session's k draft
/// verify positions into one group, and the two compose freely (B groups
/// × k lanes, all in one flight schedule).
///
/// Within a group the lanes must be ordered by ascending position: lane
/// `j`'s score products read the masked K rows lanes `0..j` just wrote
/// (valid flight-sharing — every opening is an independent mask
/// difference formed from local state).
pub struct StepLaneGroup<'a> {
    /// The session's per-layer KV caches (one entry per model layer) —
    /// per-session state, never shared across groups, shared by the
    /// group's own lanes.
    pub kv: &'a mut Vec<LayerKvCache>,
    /// View-label prefix identifying the session in P1's census (`""` for
    /// the first session, `"s{id} "` after — keeps the B=1 census
    /// bit-identical to the solo path). Lanes are told apart by their
    /// `pos{p}` label suffix, exactly like successive solo steps.
    pub prefix: &'a str,
    /// The group's lanes at successive positions (`lanes[j].pos ==
    /// lanes[0].pos + j`). A plain batched decode step has exactly one.
    pub lanes: Vec<SpecLane>,
}

/// Session-batched decode step: one transformer layer advanced for B
/// lanes at once, sharing the solo step's round schedule (DESIGN.md
/// §Continuous batching). The lanes' payloads are mutually independent —
/// each is formed from that session's own shares, caches, and
/// correlations — so where the solo schedule ships one session's opening
/// in a flight, the batched schedule ships B sessions' openings in the
/// *same* flight: lane 0 runs the charged protocol variants, lanes 1+ run
/// the deferred-round twins, and every dependency chain aligns
/// flight-for-flight. Rounds per token amortize to (solo rounds)/B;
/// bytes, transfers, per-session P1 views, and share algebra are exactly
/// B solo steps' worth.
///
/// With one lane this is transfer-, ledger-, and PRG-identical to
/// [`transformer_layer_step`] under the batched schedule (the parity
/// tests in `rust/tests/batched_decode.rs` pin that bit-exactly).
///
/// `final_ln` fuses the final LayerNorm into the last layer's reshare
/// flight (see [`transformer_layer_step_final`]) and returns every lane's
/// `[Hπ]`. Requires [`ProtoCtx::round_batching`].
#[allow(clippy::too_many_arguments)]
pub fn transformer_layer_step_batch(
    ctx: &mut ProtoCtx,
    cfg: &ModelConfig,
    pl: &PermLayer,
    pi1_sh: &Share,
    pi1_t_sh: &Share,
    groups: &mut [StepLaneGroup],
    layer_idx: usize,
    final_ln: Option<(&[f32], &[f32])>,
) -> Result<Option<Vec<Vec<Share>>>> {
    anyhow::ensure!(ctx.round_batching, "session batching needs the batched decode schedule");
    anyhow::ensure!(!groups.is_empty(), "empty decode batch");
    for g in groups.iter() {
        anyhow::ensure!(!g.lanes.is_empty(), "empty lane group");
        for (j, lane) in g.lanes.iter().enumerate() {
            anyhow::ensure!(
                lane.pos == g.lanes[0].pos + j,
                "group lanes must sit at successive positions"
            );
        }
    }
    let dh = cfg.dh();
    let scale = fixed::encode(1.0 / (dh as f64).sqrt());

    // 1. q/k/v rows per lane (Π_ScalMul + bias, 0 comm).
    let mut qkv: Vec<Vec<(Share, Share, Share)>> = Vec::with_capacity(groups.len());
    for g in groups.iter() {
        let mut rows = Vec::with_capacity(g.lanes.len());
        for lane in &g.lanes {
            let q = {
                let s = ctx.scalmul_nt(&lane.x_pi, &pl.wq, OpClass::Linear);
                ctx.mpc.add_plain_row(&s, &pl.bq)
            };
            let k = {
                let s = ctx.scalmul_nt(&lane.x_pi, &pl.wk, OpClass::Linear);
                ctx.mpc.add_plain_row(&s, &pl.bk)
            };
            let v = {
                let s = ctx.scalmul_nt(&lane.x_pi, &pl.wv, OpClass::Linear);
                ctx.mpc.add_plain_row(&s, &pl.bv)
            };
            rows.push((q, k, v));
        }
        qkv.push(rows);
    }

    // 2+3. Every lane's cache append and score products share ONE Linear
    // flight: each lane's openings are mask differences over its own
    // session state, independent of every other lane's. Within a group the
    // lanes run in ascending position order, so lane j's score products
    // read the masked K rows lanes 0..j just wrote (the batch defers only
    // rounds — values are computed eagerly). Each lane also snapshots the
    // group's `[Ṽ]` right after its own append: its stage-5 value products
    // must see exactly its own prefix, not the dense updates of the
    // group's later (possibly rejected) lanes.
    ctx.mpc.begin_batch();
    let mut o1_head_sets: Vec<Vec<Vec<Share>>> = Vec::with_capacity(groups.len());
    let mut v_snaps: Vec<Vec<Option<Share>>> = Vec::with_capacity(groups.len());
    for (g, rows) in groups.iter_mut().zip(&qkv) {
        let mut head_sets = Vec::with_capacity(g.lanes.len());
        let mut snaps = Vec::with_capacity(g.lanes.len());
        let n_lanes = g.lanes.len();
        for (j, (lane, (q, k, v))) in g.lanes.iter_mut().zip(rows).enumerate() {
            let b0 = ctx.mpc.net.ledger.bytes_total();
            let kvc = &mut g.kv[layer_idx];
            let n = kvc.capacity();
            kvc.append(ctx, pi1_t_sh, k, v, lane.pos)?;
            let o1_heads = if let Some(c) = kvc.corr.as_mut() {
                ctx.matmul_fixed_grown_scores(q, &c.f_k, &mut c.scores, lane.pos, n, OpClass::Linear)?
            } else {
                let kt: Vec<Share> =
                    (0..cfg.h).map(|h| kvc.k.col_block(h * dh, (h + 1) * dh).transpose()).collect();
                let qh: Vec<Share> = (0..cfg.h).map(|h| q.col_block(h * dh, (h + 1) * dh)).collect();
                let pairs: Vec<(&Share, &Share)> = qh.iter().zip(kt.iter()).collect();
                ctx.matmul_batch(&pairs, OpClass::Linear)
            };
            // Only non-final lanes need the clone — the last lane's live
            // [Ṽ] *is* its snapshot, which keeps single-lane groups (and
            // so the pinned B=1 parity) byte- and allocation-identical.
            snaps.push(if j + 1 < n_lanes { Some(kvc.v_tilde.clone()) } else { None });
            lane.bytes += ctx.mpc.net.ledger.bytes_total() - b0;
            head_sets.push(o1_heads);
        }
        o1_head_sets.push(head_sets);
        v_snaps.push(snaps);
    }
    ctx.mpc.flush_batch(OpClass::Linear);
    let mut o1s: Vec<Vec<Share>> = Vec::with_capacity(groups.len());
    for (g, head_sets) in groups.iter().zip(&o1_head_sets) {
        let n = g.kv[layer_idx].capacity();
        let mut group_o1s = Vec::with_capacity(g.lanes.len());
        for (lane, heads) in g.lanes.iter().zip(head_sets) {
            let mut o1 = stack_rows(heads); // (h, n)
            o1 = ctx.mpc.scale_fx(&o1, scale);
            o1 = ctx.mpc.add_plain(&o1, &causal_mask_row_fx(cfg.h, n, lane.pos));
            group_o1s.push(o1);
        }
        o1s.push(group_o1s);
    }

    // 4a. Π_PPP per lane, one shared Linear flight (each lane's opening
    // depends only on its own score results; at B=1 the flush charges the
    // same single round the solo schedule charges inside the protocol).
    ctx.mpc.begin_batch();
    let mut o1_p1s: Vec<Vec<Share>> = Vec::with_capacity(groups.len());
    for (g, group_o1s) in groups.iter_mut().zip(&o1s) {
        let mut outs = Vec::with_capacity(g.lanes.len());
        for (lane, o1) in g.lanes.iter_mut().zip(group_o1s) {
            let b0 = ctx.mpc.net.ledger.bytes_total();
            let kvc = &mut g.kv[layer_idx];
            let o1_p1 = if let Some(c) = kvc.corr.as_mut() {
                ctx.ppp_cols_fixed(o1, &c.f_pi1, &mut c.ppp, OpClass::Linear)?
            } else {
                ctx.matmul(o1, pi1_sh, OpClass::Linear)
            };
            lane.bytes += ctx.mpc.net.ledger.bytes_total() - b0;
            outs.push(o1_p1);
        }
        o1_p1s.push(outs);
    }
    ctx.mpc.flush_batch(OpClass::Linear);

    // 4b. Π_PPSM: the first lane pays the two softmax rounds; every other
    // lane's conversion rides the same two flights (independent `(h, n)`
    // rows, each observed by P1 under its own session label and position).
    let mut o2s: Vec<Vec<Share>> = Vec::with_capacity(groups.len());
    let mut first = true;
    for (g, group_o1_p1s) in groups.iter_mut().zip(&o1_p1s) {
        let mut outs = Vec::with_capacity(g.lanes.len());
        for (lane, o1_p1) in g.lanes.iter_mut().zip(group_o1_p1s) {
            let label = format!("{}decode O1pi1 layer{layer_idx} pos{}", g.prefix, lane.pos);
            let b0 = ctx.mpc.net.ledger.bytes_total();
            let o2 = if first {
                pp_softmax(ctx.mpc, ctx.backend, ctx.views, o1_p1, &label)?
            } else {
                pp_softmax_unrounded(ctx.mpc, ctx.backend, ctx.views, o1_p1, &label)?
            };
            first = false;
            lane.bytes += ctx.mpc.net.ledger.bytes_total() - b0;
            outs.push(o2);
        }
        o2s.push(outs);
    }

    // 5-7. Value products + output projection + residual per lane, one
    // coalesced Linear flight (the batched twin of the fused tail's first
    // flush). Each lane attends over its own `[Ṽ]` snapshot.
    ctx.mpc.begin_batch();
    let mut res1s: Vec<Vec<Share>> = Vec::with_capacity(groups.len());
    for ((g, group_o2s), snaps) in groups.iter_mut().zip(&o2s).zip(&v_snaps) {
        let mut outs = Vec::with_capacity(g.lanes.len());
        for ((lane, o2_p1), snap) in g.lanes.iter_mut().zip(group_o2s).zip(snaps) {
            let b0 = ctx.mpc.net.ledger.bytes_total();
            let v_tilde = snap.as_ref().unwrap_or(&g.kv[layer_idx].v_tilde);
            let o2h: Vec<Share> = (0..cfg.h).map(|h| o2_p1.row_block(h, h + 1)).collect();
            let vth: Vec<Share> =
                (0..cfg.h).map(|h| v_tilde.col_block(h * dh, (h + 1) * dh)).collect();
            let pairs3: Vec<(&Share, &Share)> = o2h.iter().zip(vth.iter()).collect();
            let o3_heads = ctx.matmul_batch(&pairs3, OpClass::Linear);
            let o3 = Share::concat_cols(&o3_heads); // (1, d)
            let o4_pi = {
                let s = ctx.scalmul_nt(&o3, &pl.wo, OpClass::Linear);
                ctx.mpc.add_plain_row(&s, &pl.bo)
            };
            let res1 = ctx.mpc.add(&o4_pi, &lane.x_pi);
            lane.bytes += ctx.mpc.net.ledger.bytes_total() - b0;
            outs.push(res1);
        }
        res1s.push(outs);
    }
    ctx.mpc.flush_batch(OpClass::Linear);

    // 8-12. P1-plaintext FFN segment per lane — all lanes' output reshares
    // coalesce into ONE LayerNorm round (the batched twin of the fused
    // tail's closing flight), with the optional final LN fused in.
    let mut h_out = final_ln.map(|_| Vec::with_capacity(groups.len()));
    for (g, group_res1s) in groups.iter_mut().zip(&res1s) {
        let mut group_h = final_ln.map(|_| Vec::with_capacity(g.lanes.len()));
        for (lane, res1) in g.lanes.iter_mut().zip(group_res1s) {
            let b0 = ctx.mpc.net.ledger.bytes_total();
            let l1_pi = pp_layernorm_unrounded(
                ctx.mpc,
                ctx.backend,
                ctx.views,
                res1,
                &pl.ln1_g,
                &pl.ln1_b,
                OpClass::LayerNorm,
                &format!("{}decode O4+X pi layer{layer_idx} pos{}", g.prefix, lane.pos),
            )?;
            let o5_pi2 = {
                let s = ctx.scalmul_nt(&l1_pi, &pl.w1, OpClass::Linear);
                ctx.mpc.add_plain_row(&s, &pl.b1)
            };
            let g_pi2 = pp_gelu_unrounded(
                ctx.mpc,
                ctx.backend,
                ctx.views,
                &o5_pi2,
                &format!("{}decode O5pi2 layer{layer_idx} pos{}", g.prefix, lane.pos),
            )?;
            let o6_pi = {
                let s = ctx.scalmul_nt(&g_pi2, &pl.w2, OpClass::Linear);
                ctx.mpc.add_plain_row(&s, &pl.b2)
            };
            let res2 = ctx.mpc.add(&o6_pi, &l1_pi);
            let l2_pi = pp_layernorm_unrounded(
                ctx.mpc,
                ctx.backend,
                ctx.views,
                &res2,
                &pl.ln2_g,
                &pl.ln2_b,
                OpClass::LayerNorm,
                &format!("{}decode O6+L1 pi layer{layer_idx} pos{}", g.prefix, lane.pos),
            )?;
            if let (Some(hs), Some((gamma, beta))) = (group_h.as_mut(), final_ln) {
                hs.push(pp_layernorm_unrounded(
                    ctx.mpc,
                    ctx.backend,
                    ctx.views,
                    &l2_pi,
                    gamma,
                    beta,
                    OpClass::Adaptation,
                    &format!("{}final LN pi", g.prefix),
                )?);
            }
            lane.x_pi = l2_pi;
            lane.bytes += ctx.mpc.net.ledger.bytes_total() - b0;
        }
        if let (Some(all), Some(gh)) = (h_out.as_mut(), group_h) {
            all.push(gh);
        }
    }
    ctx.mpc.net.round(OpClass::LayerNorm, 1);
    Ok(h_out)
}

/// Single-token variant of [`transformer_layer`] for incremental decoding:
/// `[x_pi]` is the current token's `(1, d)` activation row at position
/// `pos`; attention attends over the cached prefix held in `kv` (extended
/// with this token's k/v first). Protocol sequence and openings match the
/// full layer — every P1 observation is a `(h, n)`, `(1, d)` or `(1, k)`
/// permuted row, never a cache tensor. Returns the token's `(1, d)` output.
///
/// With [`ProtoCtx::round_batching`] the step runs the **batched-opening
/// schedule** (DESIGN.md §Batched openings): identical transfers, bytes,
/// and P1 views, but the independent openings share flights — 6 rounds
/// per layer instead of 12.
#[allow(clippy::too_many_arguments)]
pub fn transformer_layer_step(
    ctx: &mut ProtoCtx,
    cfg: &ModelConfig,
    pl: &PermLayer,
    pi1_sh: &Share,
    pi1_t_sh: &Share,
    x_pi: &Share,
    kv: &mut LayerKvCache,
    pos: usize,
    layer_idx: usize,
) -> Result<Share> {
    step_impl(ctx, cfg, pl, pi1_sh, pi1_t_sh, x_pi, kv, pos, layer_idx, None).map(|(out, _)| out)
}

/// Last-layer variant for the batched schedule: the P1-plaintext FFN
/// segment is extended through the **final LayerNorm**, whose output
/// reshare coalesces into the same flight as the layer's other reshares
/// (saving the adaptation conversion's two rounds). Returns the layer
/// output `[L2π]` and the final-LN output `[Hπ]` ready for the tied LM
/// head. Requires [`ProtoCtx::round_batching`].
#[allow(clippy::too_many_arguments)]
pub fn transformer_layer_step_final(
    ctx: &mut ProtoCtx,
    cfg: &ModelConfig,
    pl: &PermLayer,
    pi1_sh: &Share,
    pi1_t_sh: &Share,
    x_pi: &Share,
    kv: &mut LayerKvCache,
    pos: usize,
    layer_idx: usize,
    final_ln_g: &[f32],
    final_ln_b: &[f32],
) -> Result<(Share, Share)> {
    anyhow::ensure!(ctx.round_batching, "final-LN fusion needs the batched decode schedule");
    let (out, h) = step_impl(
        ctx,
        cfg,
        pl,
        pi1_sh,
        pi1_t_sh,
        x_pi,
        kv,
        pos,
        layer_idx,
        Some((final_ln_g, final_ln_b)),
    )?;
    Ok((out, h.expect("fused final tail returns the final-LN share")))
}

/// Shared body of the two step entry points; `final_ln` carries the
/// final-LN parameters when the last layer should fuse the adaptation
/// conversion into its reshare flight (batched schedule only).
#[allow(clippy::too_many_arguments)]
fn step_impl(
    ctx: &mut ProtoCtx,
    cfg: &ModelConfig,
    pl: &PermLayer,
    pi1_sh: &Share,
    pi1_t_sh: &Share,
    x_pi: &Share,
    kv: &mut LayerKvCache,
    pos: usize,
    layer_idx: usize,
    final_ln: Option<(&[f32], &[f32])>,
) -> Result<(Share, Option<Share>)> {
    let n = kv.capacity();
    let dh = cfg.dh();
    let scale = fixed::encode(1.0 / (dh as f64).sqrt());

    // 1. q/k/v rows for this token (Π_ScalMul + bias, 0 comm).
    let q = {
        let s = ctx.scalmul_nt(x_pi, &pl.wq, OpClass::Linear);
        ctx.mpc.add_plain_row(&s, &pl.bq)
    };
    let k = {
        let s = ctx.scalmul_nt(x_pi, &pl.wk, OpClass::Linear);
        ctx.mpc.add_plain_row(&s, &pl.bk)
    };
    let v = {
        let s = ctx.scalmul_nt(x_pi, &pl.wv, OpClass::Linear);
        ctx.mpc.add_plain_row(&s, &pl.bv)
    };

    // 2+3. Cache append ([K] row write + [Ṽ] PPP update) and the score
    //    products against the whole cached prefix: q_h (1×dh) @ K_hᵀ
    //    (dh×n) → (1×n) per head. With correlations the K side rides its
    //    session mask (rows opened at append time), so only q's mask
    //    difference moves per step.
    //
    //    Batched schedule: the append's K-row/v-side openings and the
    //    per-head q openings are mutually independent mask differences
    //    (each payload is formed from local state, never from another
    //    batched exchange's opened value), so they share one flight.
    if ctx.round_batching {
        ctx.mpc.begin_batch();
    }
    kv.append(ctx, pi1_t_sh, &k, &v, pos)?;
    let o1_heads = if let Some(c) = kv.corr.as_mut() {
        ctx.matmul_fixed_grown_scores(&q, &c.f_k, &mut c.scores, pos, n, OpClass::Linear)?
    } else {
        let kt: Vec<Share> =
            (0..cfg.h).map(|h| kv.k.col_block(h * dh, (h + 1) * dh).transpose()).collect();
        let qh: Vec<Share> = (0..cfg.h).map(|h| q.col_block(h * dh, (h + 1) * dh)).collect();
        let pairs: Vec<(&Share, &Share)> = qh.iter().zip(kt.iter()).collect();
        ctx.matmul_batch(&pairs, OpClass::Linear)
    };
    if ctx.round_batching {
        ctx.mpc.flush_batch(OpClass::Linear);
    }
    let mut o1 = stack_rows(&o1_heads); // (h, n)
    o1 = ctx.mpc.scale_fx(&o1, scale);
    o1 = ctx.mpc.add_plain(&o1, &causal_mask_row_fx(cfg.h, n, pos));

    // 4. Π_PPP then Π_PPSM: P1 opens one π₁-permuted score row per head.
    //    With correlations, the π₁-side mask was opened once at session
    //    setup — per step only [O1]'s mask difference is opened. The Π_PPP
    //    opening depends on the score results, so it is its own flight in
    //    both schedules.
    let o1_p1 = if let Some(c) = kv.corr.as_mut() {
        ctx.ppp_cols_fixed(&o1, &c.f_pi1, &mut c.ppp, OpClass::Linear)?
    } else {
        ctx.matmul(&o1, pi1_sh, OpClass::Linear)
    };
    let o2_p1 = pp_softmax(
        ctx.mpc,
        ctx.backend,
        ctx.views,
        &o1_p1,
        &format!("decode O1pi1 layer{layer_idx} pos{pos}"),
    )?;

    if ctx.round_batching {
        return fused_value_ffn_tail(ctx, cfg, pl, &o2_p1, x_pi, kv, pos, layer_idx, final_ln);
    }
    anyhow::ensure!(final_ln.is_none(), "final-LN fusion needs the batched decode schedule");

    // 5. Attend over the cached [Ṽ]: the π₁ in O2π₁ cancels against π₁ᵀV.
    let o2h: Vec<Share> = (0..cfg.h).map(|h| o2_p1.row_block(h, h + 1)).collect();
    let vth: Vec<Share> = (0..cfg.h).map(|h| kv.v_tilde.col_block(h * dh, (h + 1) * dh)).collect();
    let pairs3: Vec<(&Share, &Share)> = o2h.iter().zip(vth.iter()).collect();
    let o3_heads = ctx.matmul_batch(&pairs3, OpClass::Linear);
    let o3 = Share::concat_cols(&o3_heads); // (1, d)

    // 6-12. Output projection, residuals, LayerNorms, FFN on (1, d) rows —
    // identical protocols to the full layer.
    let o4_pi = {
        let s = ctx.scalmul_nt(&o3, &pl.wo, OpClass::Linear);
        ctx.mpc.add_plain_row(&s, &pl.bo)
    };
    let res1 = ctx.mpc.add(&o4_pi, x_pi);
    let l1_pi = pp_layernorm(
        ctx.mpc,
        ctx.backend,
        ctx.views,
        &res1,
        &pl.ln1_g,
        &pl.ln1_b,
        OpClass::LayerNorm,
        &format!("decode O4+X pi layer{layer_idx} pos{pos}"),
    )?;
    let o5_pi2 = {
        let s = ctx.scalmul_nt(&l1_pi, &pl.w1, OpClass::Linear);
        ctx.mpc.add_plain_row(&s, &pl.b1)
    };
    let g_pi2 = pp_gelu(
        ctx.mpc,
        ctx.backend,
        ctx.views,
        &o5_pi2,
        &format!("decode O5pi2 layer{layer_idx} pos{pos}"),
    )?;
    let o6_pi = {
        let s = ctx.scalmul_nt(&g_pi2, &pl.w2, OpClass::Linear);
        ctx.mpc.add_plain_row(&s, &pl.b2)
    };
    let res2 = ctx.mpc.add(&o6_pi, &l1_pi);
    let l2_pi = pp_layernorm(
        ctx.mpc,
        ctx.backend,
        ctx.views,
        &res2,
        &pl.ln2_g,
        &pl.ln2_b,
        OpClass::LayerNorm,
        &format!("decode O6+L1 pi layer{layer_idx} pos{pos}"),
    )?;
    Ok((l2_pi, None))
}

/// The batched-schedule tail of a decode step: per-head value products +
/// the P1-plaintext FFN segment (DESIGN.md §Batched openings).
///
/// Flight structure after the softmax conversion's two rounds:
/// * the value-product openings ride the softmax-reshare flight (P1's
///   halves — P1 holds `O2π₁` in plaintext, so its mask differences need
///   no further input) and one `Linear` flush (P0's halves travelling
///   with the `res1` residual delivery);
/// * P1 then computes LN1 → W₁/GeLU → W₂/LN2 (→ final LN) entirely on the
///   plaintext it reconstructed — every intermediate it would have seen
///   under the sequential schedule, and nothing else — and all of its
///   output reshares coalesce into one `LayerNorm` round;
/// * P0's dependent input halves (`O5π₂`, `O6+L1`, and the last layer's
///   `L2π` for the final LN) are still transferred with identical bytes
///   for share consistency, but ride the next charged flight (the next
///   layer's append/score flush, or the logits-return round), so they
///   cost no extra round.
#[allow(clippy::too_many_arguments)]
fn fused_value_ffn_tail(
    ctx: &mut ProtoCtx,
    cfg: &ModelConfig,
    pl: &PermLayer,
    o2_p1: &Share,
    x_pi: &Share,
    kv: &LayerKvCache,
    pos: usize,
    layer_idx: usize,
    final_ln: Option<(&[f32], &[f32])>,
) -> Result<(Share, Option<Share>)> {
    let dh = cfg.dh();
    // Value products + residual, one coalesced Linear flight.
    ctx.mpc.begin_batch();
    let o2h: Vec<Share> = (0..cfg.h).map(|h| o2_p1.row_block(h, h + 1)).collect();
    let vth: Vec<Share> = (0..cfg.h).map(|h| kv.v_tilde.col_block(h * dh, (h + 1) * dh)).collect();
    let pairs3: Vec<(&Share, &Share)> = o2h.iter().zip(vth.iter()).collect();
    let o3_heads = ctx.matmul_batch(&pairs3, OpClass::Linear);
    let o3 = Share::concat_cols(&o3_heads); // (1, d)
    let o4_pi = {
        let s = ctx.scalmul_nt(&o3, &pl.wo, OpClass::Linear);
        ctx.mpc.add_plain_row(&s, &pl.bo)
    };
    let res1 = ctx.mpc.add(&o4_pi, x_pi);
    ctx.mpc.flush_batch(OpClass::Linear);

    // P1-plaintext FFN segment: same transfers, views, and share algebra
    // as the sequential LN1/GeLU/LN2 conversions, rounds coalesced below.
    let l1_pi = pp_layernorm_unrounded(
        ctx.mpc,
        ctx.backend,
        ctx.views,
        &res1,
        &pl.ln1_g,
        &pl.ln1_b,
        OpClass::LayerNorm,
        &format!("decode O4+X pi layer{layer_idx} pos{pos}"),
    )?;
    let o5_pi2 = {
        let s = ctx.scalmul_nt(&l1_pi, &pl.w1, OpClass::Linear);
        ctx.mpc.add_plain_row(&s, &pl.b1)
    };
    let g_pi2 = pp_gelu_unrounded(
        ctx.mpc,
        ctx.backend,
        ctx.views,
        &o5_pi2,
        &format!("decode O5pi2 layer{layer_idx} pos{pos}"),
    )?;
    let o6_pi = {
        let s = ctx.scalmul_nt(&g_pi2, &pl.w2, OpClass::Linear);
        ctx.mpc.add_plain_row(&s, &pl.b2)
    };
    let res2 = ctx.mpc.add(&o6_pi, &l1_pi);
    let l2_pi = pp_layernorm_unrounded(
        ctx.mpc,
        ctx.backend,
        ctx.views,
        &res2,
        &pl.ln2_g,
        &pl.ln2_b,
        OpClass::LayerNorm,
        &format!("decode O6+L1 pi layer{layer_idx} pos{pos}"),
    )?;
    let h_pi = match final_ln {
        Some((g, b)) => Some(pp_layernorm_unrounded(
            ctx.mpc,
            ctx.backend,
            ctx.views,
            &l2_pi,
            g,
            b,
            OpClass::Adaptation,
            "final LN pi",
        )?),
        None => None,
    };
    // The coalesced reshare flight (L1π ∥ Gπ₂ ∥ L2π ∥ optionally Hπ).
    ctx.mpc.net.round(OpClass::LayerNorm, 1);
    Ok((l2_pi, h_pi))
}

/// Multi-head attention + FFN for one layer: `[Xπ] → [L2π]`.
#[allow(clippy::too_many_arguments)]
pub fn transformer_layer(
    ctx: &mut ProtoCtx,
    cfg: &ModelConfig,
    pl: &PermLayer,
    pi1_sh: &Share,
    pi1_t_sh: &Share,
    x_pi: &Share,
    mask_fx: Option<&RingTensor>,
    layer_idx: usize,
) -> Result<Share> {
    let n = x_pi.rows();
    let dh = cfg.dh();
    let scale = fixed::encode(1.0 / (dh as f64).sqrt());

    // 1. Q, K, V (shares, unpermuted): Π_ScalMul + bias via P0.
    let q = {
        let s = ctx.scalmul_nt(x_pi, &pl.wq, OpClass::Linear);
        ctx.mpc.add_plain_row(&s, &pl.bq)
    };
    let k = {
        let s = ctx.scalmul_nt(x_pi, &pl.wk, OpClass::Linear);
        ctx.mpc.add_plain_row(&s, &pl.bk)
    };
    let v = {
        let s = ctx.scalmul_nt(x_pi, &pl.wv, OpClass::Linear);
        ctx.mpc.add_plain_row(&s, &pl.bv)
    };

    // 2. O1 per head = Q_h K_hᵀ (one batched round).
    let kt: Vec<Share> = (0..cfg.h).map(|h| k.col_block(h * dh, (h + 1) * dh).transpose()).collect();
    let qh: Vec<Share> = (0..cfg.h).map(|h| q.col_block(h * dh, (h + 1) * dh)).collect();
    let pairs: Vec<(&Share, &Share)> = qh.iter().zip(kt.iter()).collect();
    let o1_heads = ctx.matmul_batch(&pairs, OpClass::Linear);
    let mut o1 = stack_rows(&o1_heads); // (h·n, n)
    o1 = ctx.mpc.scale_fx(&o1, scale);
    if let Some(m) = mask_fx {
        o1 = ctx.mpc.add_plain(&o1, m);
    }

    // 3. Π_PPP: restore a permuted state for the softmax opening.
    let o1_p1 = ctx.matmul(&o1, pi1_sh, OpClass::Linear);

    // 4. Π_PPSM at P1 (sees O1π₁ — the paper's Table 2 attack surface).
    let o2_p1 = pp_softmax(
        ctx.mpc,
        ctx.backend,
        ctx.views,
        &o1_p1,
        &format!("O1pi1 layer{layer_idx}"),
    )?;

    // 5. Ṽ = π₁ᵀ V so the π₁ in O2π₁ cancels.
    let v_tilde = ctx.matmul(pi1_t_sh, &v, OpClass::Linear);

    // 6. O3 per head (one batched round), then concat heads.
    let o2h: Vec<Share> = (0..cfg.h).map(|h| o2_p1.row_block(h * n, (h + 1) * n)).collect();
    let vth: Vec<Share> = (0..cfg.h).map(|h| v_tilde.col_block(h * dh, (h + 1) * dh)).collect();
    let pairs3: Vec<(&Share, &Share)> = o2h.iter().zip(vth.iter()).collect();
    let o3_heads = ctx.matmul_batch(&pairs3, OpClass::Linear);
    let o3 = Share::concat_cols(&o3_heads); // (n, d)

    // 7. O4π = Π_ScalMul([O3], πᵀW_O) + b_Oπ.
    let o4_pi = {
        let s = ctx.scalmul_nt(&o3, &pl.wo, OpClass::Linear);
        ctx.mpc.add_plain_row(&s, &pl.bo)
    };

    // 8. residual + Π_PPLN (P1 holds γ₁π, β₁π).
    let res1 = ctx.mpc.add(&o4_pi, x_pi);
    let l1_pi = pp_layernorm(
        ctx.mpc,
        ctx.backend,
        ctx.views,
        &res1,
        &pl.ln1_g,
        &pl.ln1_b,
        OpClass::LayerNorm,
        &format!("O4+X pi layer{layer_idx}"),
    )?;

    // 9-12. FFN.
    let o5_pi2 = {
        let s = ctx.scalmul_nt(&l1_pi, &pl.w1, OpClass::Linear);
        ctx.mpc.add_plain_row(&s, &pl.b1)
    };
    let g_pi2 = pp_gelu(
        ctx.mpc,
        ctx.backend,
        ctx.views,
        &o5_pi2,
        &format!("O5pi2 layer{layer_idx}"),
    )?;
    let o6_pi = {
        let s = ctx.scalmul_nt(&g_pi2, &pl.w2, OpClass::Linear);
        ctx.mpc.add_plain_row(&s, &pl.b2)
    };
    let res2 = ctx.mpc.add(&o6_pi, &l1_pi);
    let l2_pi = pp_layernorm(
        ctx.mpc,
        ctx.backend,
        ctx.views,
        &res2,
        &pl.ln2_g,
        &pl.ln2_b,
        OpClass::LayerNorm,
        &format!("O6+L1 pi layer{layer_idx}"),
    )?;
    Ok(l2_pi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::views::Views;
    use crate::model::{ModelConfig, ModelWeights, PermSet, PermutedModel};
    use crate::protocols::ppp;
    use crate::net::{NetSim, NetworkProfile};
    use crate::runtime::NativeBackend;
    use crate::tensor::FloatTensor;
    use crate::util::rng::Rng;

    /// One full layer through the protocols vs the plaintext reference.
    fn run_layer(fast_sim: bool) {
        let mut cfg = ModelConfig::bert_tiny();
        cfg.layers = 1;
        let w = ModelWeights::random(&cfg, 31);
        let mut rng = Rng::new(32);
        let perms = PermSet::random(&cfg, &mut rng);
        let pm = PermutedModel::build(&cfg, &w, perms.clone());

        // random activations standing in for X_E
        let x = FloatTensor::from_fn(cfg.n_ctx, cfg.d, |r, c| ((r * 31 + c * 7) % 23) as f32 * 0.08 - 0.8);
        let x_pi = perms.pi.apply_cols(&x);

        let mut mpc = Mpc::new(NetSim::new(NetworkProfile::lan()), 33);
        let mut backend = NativeBackend::new();
        let mut views = Views::new(false);
        let x_sh = mpc.share_local(&fixed::encode_tensor(&x_pi));
        let pi1_sh = ppp::share_perm(&mut mpc, &perms.pi1, OpClass::Linear);
        let pi1_t_sh = ppp::share_perm_t(&mut mpc, &perms.pi1, OpClass::Linear);
        let mut ctx = ProtoCtx {
            mpc: &mut mpc,
            backend: &mut backend,
            views: &mut views,
            fast_sim,
            round_batching: false,
        };
        let out = transformer_layer(&mut ctx, &cfg, &pm.layers[0], &pi1_sh, &pi1_t_sh, &x_sh, None, 0).unwrap();

        // plaintext reference: build a pseudo-model that starts from x
        // directly (reuse forward_trace by setting embeddings to x rows).
        let got = fixed::decode_tensor(&out.reconstruct());
        let want_pi = {
            // compute reference layer on x with plaintext ops
            use crate::model::plaintext;
            // quick manual reference using the same weights
            let mut w1 = w.clone();
            w1.layers.truncate(1);
            // manual: reuse forward internals via a tiny embedding hack is
            // messier than just recomputing here:
            let _ = plaintext::Variant::Exact;
            let l = &w.layers[0];
            let q = x.matmul_nt(&l.wq).add_row(&l.bq);
            let k = x.matmul_nt(&l.wk).add_row(&l.bk);
            let v = x.matmul_nt(&l.wv).add_row(&l.bv);
            let dh = cfg.dh();
            let mut o3 = FloatTensor::zeros(cfg.n_ctx, cfg.d);
            for h in 0..cfg.h {
                let qh = q.col_block(h * dh, (h + 1) * dh);
                let kh = k.col_block(h * dh, (h + 1) * dh);
                let vh = v.col_block(h * dh, (h + 1) * dh);
                let mut s = qh.matmul_nt(&kh);
                s.map_inplace(|v| v / (dh as f32).sqrt());
                for r in 0..s.rows() {
                    crate::runtime::native::softmax_row(s.row_mut(r));
                }
                o3.set_col_block(h * dh, &s.matmul(&vh));
            }
            let o4 = o3.matmul_nt(&l.wo).add_row(&l.bo);
            let mut nb = NativeBackend::new();
            use crate::runtime::Backend as _;
            let l1 = nb.layernorm(&o4.zip_with(&x, |a, b| a + b), &l.ln1_g, &l.ln1_b).unwrap();
            let o5 = l1.matmul_nt(&l.w1).add_row(&l.b1);
            let g = o5.map(crate::runtime::native::gelu_scalar);
            let o6 = g.matmul_nt(&l.w2).add_row(&l.b2);
            let l2 = nb.layernorm(&o6.zip_with(&l1, |a, b| a + b), &l.ln2_g, &l.ln2_b).unwrap();
            perms.pi.apply_cols(&l2)
        };
        let diff = got.max_abs_diff(&want_pi);
        assert!(diff < 0.05, "layer output diff {diff} (fast_sim={fast_sim})");
    }

    #[test]
    fn layer_matches_plaintext_full() {
        run_layer(false);
    }

    #[test]
    fn layer_matches_plaintext_fast_sim() {
        run_layer(true);
    }

    #[test]
    fn causal_mask_shape_and_values() {
        let m = causal_mask_fx(2, 4);
        assert_eq!(m.shape(), (8, 4));
        assert_eq!(m.get(0, 0), 0);
        assert_eq!(m.get(0, 3), fixed::encode(MASK_NEG));
        assert_eq!(m.get(3, 3), 0); // row 3 of head 0 sees everything
        assert_eq!(m.get(4, 1), fixed::encode(MASK_NEG)); // head 1, row 0
    }

    #[test]
    fn single_token_step_matches_full_layer_row() {
        // Drive the same activations through the full causal layer and the
        // incremental KV-cache path; the step output at each position must
        // match the corresponding row of the full layer output.
        let mut cfg = ModelConfig::gpt2_tiny();
        cfg.layers = 1;
        let w = ModelWeights::random(&cfg, 131);
        let mut rng = Rng::new(132);
        let perms = PermSet::random(&cfg, &mut rng);
        let pm = PermutedModel::build(&cfg, &w, perms.clone());
        let n = cfg.n_ctx;

        let x = FloatTensor::from_fn(n, cfg.d, |r, c| ((r * 17 + c * 5) % 23) as f32 * 0.07 - 0.7);
        let x_pi = perms.pi.apply_cols(&x);

        let mut mpc = Mpc::new(NetSim::new(NetworkProfile::lan()), 133);
        let mut backend = NativeBackend::new();
        let mut views = Views::new(false);
        let pi1_sh = ppp::share_perm(&mut mpc, &perms.pi1, OpClass::Linear);
        let pi1_t_sh = ppp::share_perm_t(&mut mpc, &perms.pi1, OpClass::Linear);

        // Full causal layer over all n positions.
        let full_out = {
            let x_sh = mpc.share_local(&fixed::encode_tensor(&x_pi));
            let mask = causal_mask_fx(cfg.h, n);
            let mut ctx =
                ProtoCtx {
                    mpc: &mut mpc,
                    backend: &mut backend,
                    views: &mut views,
                    fast_sim: false,
                    round_batching: false,
                };
            let out = transformer_layer(
                &mut ctx, &cfg, &pm.layers[0], &pi1_sh, &pi1_t_sh, &x_sh, Some(&mask), 0,
            )
            .unwrap();
            fixed::decode_tensor(&out.reconstruct())
        };

        // Incremental: one token at a time through the shared KV cache.
        let mut kv = LayerKvCache::new(n, cfg.d);
        for t in 0..n {
            let row = FloatTensor::from_vec(1, cfg.d, x_pi.row(t).to_vec());
            let row_sh = mpc.share_local(&fixed::encode_tensor(&row));
            let mut ctx =
                ProtoCtx {
                    mpc: &mut mpc,
                    backend: &mut backend,
                    views: &mut views,
                    fast_sim: false,
                    round_batching: false,
                };
            let out = transformer_layer_step(
                &mut ctx, &cfg, &pm.layers[0], &pi1_sh, &pi1_t_sh, &row_sh, &mut kv, t, 0,
            )
            .unwrap();
            let got = fixed::decode_tensor(&out.reconstruct());
            let want = FloatTensor::from_vec(1, cfg.d, full_out.row(t).to_vec());
            let diff = got.max_abs_diff(&want);
            assert!(diff < 0.05, "incremental row {t} diverges from full layer: diff {diff}");
        }
        assert_eq!(kv.len(), n);
    }

    #[test]
    fn kv_append_is_cheap_and_stays_shared() {
        let cfg = ModelConfig::gpt2_tiny();
        let mut rng = Rng::new(141);
        let perms = PermSet::random(&cfg, &mut rng);
        let n = cfg.n_ctx;
        let mut mpc = Mpc::new(NetSim::new(NetworkProfile::lan()), 142);
        let mut backend = NativeBackend::new();
        let mut views = Views::new(true);
        let pi1_t_sh = ppp::share_perm_t(&mut mpc, &perms.pi1, OpClass::Linear);
        let before = mpc.net.ledger.bytes_total();
        let k_new = mpc.share_local(&RingTensor::from_fn(1, cfg.d, |_, c| c as i64));
        let v_new = mpc.share_local(&RingTensor::from_fn(1, cfg.d, |_, c| 3 * c as i64));
        let mut kv = LayerKvCache::new(n, cfg.d);
        {
            let mut ctx =
                ProtoCtx {
                    mpc: &mut mpc,
                    backend: &mut backend,
                    views: &mut views,
                    fast_sim: false,
                    round_batching: false,
                };
            kv.append(&mut ctx, &pi1_t_sh, &k_new, &v_new, 0).unwrap();
        }
        // One outer-product Beaver matmul: 2·8·(n·1 + 1·d) bytes, 1 round.
        let appended = mpc.net.ledger.bytes_total() - before;
        assert_eq!(appended, 2 * 8 * (n as u64 + cfg.d as u64));
        // The cache never opens anything at P1: no new views recorded.
        assert!(views.p1.is_empty(), "KV append must not reveal plaintext to P1");
        assert_eq!(kv.len(), 1);
        assert!(!kv.is_empty());
        assert_eq!(kv.capacity(), n);
    }

    /// Correlated single-token steps must match the plain per-step path
    /// byte-for-byte in *results* while moving strictly fewer bytes: the
    /// structure-aware specialization may not change the computed layer
    /// output (within fixed-point noise) or the round count.
    #[test]
    fn correlated_step_matches_plain_step_with_fewer_bytes_same_rounds() {
        let mut cfg = ModelConfig::gpt2_tiny();
        cfg.layers = 1;
        let w = ModelWeights::random(&cfg, 151);
        let mut rng = Rng::new(152);
        let perms = PermSet::random(&cfg, &mut rng);
        let pm = PermutedModel::build(&cfg, &w, perms.clone());
        let n = cfg.n_ctx;

        let x = FloatTensor::from_fn(n, cfg.d, |r, c| ((r * 13 + c * 3) % 17) as f32 * 0.08 - 0.6);
        let x_pi = perms.pi.apply_cols(&x);

        let mut mpc = Mpc::new(NetSim::new(NetworkProfile::lan()), 153);
        let mut backend = NativeBackend::new();
        let mut views = Views::new(false);
        let pi1_sh = ppp::share_perm(&mut mpc, &perms.pi1, OpClass::Linear);
        let pi1_t_sh = ppp::share_perm_t(&mut mpc, &perms.pi1, OpClass::Linear);

        let corr = deal_kv_correlations(&mut mpc, &cfg, &pi1_sh, &pi1_t_sh).unwrap();
        let mut kv_corr = LayerKvCache::with_correlations(n, cfg.d, corr);
        let mut kv_plain = LayerKvCache::new(n, cfg.d);

        let steps = 4usize;
        let mut corr_bytes = 0u64;
        let mut plain_bytes = 0u64;
        for t in 0..steps {
            let row = FloatTensor::from_vec(1, cfg.d, x_pi.row(t).to_vec());
            let row_sh = mpc.share_local(&fixed::encode_tensor(&row));
            let (got_corr, got_plain, cb, pb, cr, pr) = {
                let mut run = |kv: &mut LayerKvCache| {
                    let before_b = mpc.net.ledger.bytes_total();
                    let before_r = mpc.net.ledger.rounds_total();
                    let mut ctx = ProtoCtx {
                        mpc: &mut mpc,
                        backend: &mut backend,
                        views: &mut views,
                        fast_sim: false,
                        round_batching: false,
                    };
                    let out = transformer_layer_step(
                        &mut ctx, &cfg, &pm.layers[0], &pi1_sh, &pi1_t_sh, &row_sh, kv, t, 0,
                    )
                    .unwrap();
                    (
                        fixed::decode_tensor(&out.reconstruct()),
                        mpc.net.ledger.bytes_total() - before_b,
                        mpc.net.ledger.rounds_total() - before_r,
                    )
                };
                let (gc, cb, cr) = run(&mut kv_corr);
                let (gp, pb, pr) = run(&mut kv_plain);
                (gc, gp, cb, pb, cr, pr)
            };
            let diff = got_corr.max_abs_diff(&got_plain);
            assert!(diff < 0.05, "step {t}: correlated vs plain diff {diff}");
            assert_eq!(cr, pr, "step {t}: correlated path must not change the round count");
            assert!(cb < pb, "step {t}: correlated path must move fewer bytes ({cb} vs {pb})");
            corr_bytes += cb;
            plain_bytes += pb;
        }
        // π₁-side masks opened exactly once, K rows once per append.
        let c = kv_corr.correlations().unwrap();
        assert_eq!(c.ppp.openings(), 1);
        assert_eq!(c.append.openings(), 1);
        assert_eq!(c.scores.openings(), steps as u64);
        assert!(plain_bytes > corr_bytes * 2, "per-layer warm saving should exceed 2x");
    }

    /// The shared-π₁ session deal opens each fixed operand once for the
    /// whole session: exactly two wire openings (π₁ − B, π₁ᵀ − B'), every
    /// layer adopting the same mask and reporting one opening to the
    /// census, and `corr_setup` exactly `n_layers×` below the per-layer
    /// dealing it replaces.
    #[test]
    fn session_deal_opens_each_pi1_mask_once_for_all_layers() {
        let cfg = ModelConfig::gpt2_tiny();
        let mut rng = Rng::new(181);
        let perms = PermSet::random(&cfg, &mut rng);
        let n = cfg.n_ctx;
        let l = cfg.layers;
        assert!(l >= 2, "needs a multi-layer model to exercise mask sharing");

        let mut mpc = Mpc::new(NetSim::new(NetworkProfile::lan()), 182);
        let pi1_sh = ppp::share_perm(&mut mpc, &perms.pi1, OpClass::Linear);
        let pi1_t_sh = ppp::share_perm_t(&mut mpc, &perms.pi1, OpClass::Linear);
        let before_b = mpc.net.ledger.bytes_total();
        let before_r = mpc.net.ledger.rounds_total();
        let corrs = deal_session_kv_correlations(&mut mpc, &cfg, &pi1_sh, &pi1_t_sh).unwrap();
        let setup_bytes = mpc.net.ledger.bytes_total() - before_b;
        let setup_rounds = mpc.net.ledger.rounds_total() - before_r;
        assert_eq!(corrs.len(), l);
        assert_eq!(setup_bytes, 2 * (2 * 8 * (n * n) as u64), "two wire openings per session");
        assert_eq!(setup_rounds, 2);

        let pi1 = pi1_sh.reconstruct();
        let pi1_t = pi1_t_sh.reconstruct();
        for c in &corrs {
            assert_eq!(c.ppp.openings(), 1, "census: one π₁ opening per layer");
            assert_eq!(c.append.openings(), 1);
            assert_eq!(c.scores.openings(), 0);
            assert_eq!(c.ppp.mask, corrs[0].ppp.mask, "one shared π₁ mask");
            assert_eq!(c.append.mask, corrs[0].append.mask, "one shared π₁ᵀ mask");
            // The adopted public opening is valid for every layer.
            assert_eq!(crate::ring::sub(&pi1, &c.ppp.mask.reconstruct()), c.f_pi1);
            assert_eq!(crate::ring::sub(&pi1_t, &c.append.mask.reconstruct()), c.f_pi1_t);
        }

        // The per-layer dealing pays the opening L times over.
        let mut mpc2 = Mpc::new(NetSim::new(NetworkProfile::lan()), 183);
        let pi1_sh2 = ppp::share_perm(&mut mpc2, &perms.pi1, OpClass::Linear);
        let pi1_t_sh2 = ppp::share_perm_t(&mut mpc2, &perms.pi1, OpClass::Linear);
        let before2 = mpc2.net.ledger.bytes_total();
        for _ in 0..l {
            let _ = deal_kv_correlations(&mut mpc2, &cfg, &pi1_sh2, &pi1_t_sh2).unwrap();
        }
        let per_layer_bytes = mpc2.net.ledger.bytes_total() - before2;
        assert_eq!(per_layer_bytes, setup_bytes * l as u64, "corr_setup cut exactly n_layers x");
    }

    /// The batched schedule must be a pure re-scheduling: identically
    /// seeded stacks produce **bit-identical** output shares (same PRG and
    /// dealer consumption order), identical bytes, and 6 rounds per layer
    /// step instead of 12 (DESIGN.md §Batched openings).
    #[test]
    fn batched_step_is_bit_identical_to_sequential_at_half_the_rounds() {
        let mut cfg = ModelConfig::gpt2_tiny();
        cfg.layers = 1;
        let w = ModelWeights::random(&cfg, 171);
        let mut rng = Rng::new(172);
        let perms = PermSet::random(&cfg, &mut rng);
        let pm = PermutedModel::build(&cfg, &w, perms.clone());
        let n = cfg.n_ctx;
        let x = FloatTensor::from_fn(n, cfg.d, |r, c| ((r * 11 + c * 7) % 19) as f32 * 0.07 - 0.6);
        let x_pi = perms.pi.apply_cols(&x);
        let steps = 3usize;

        let run = |round_batching: bool| {
            let mut mpc = Mpc::new(NetSim::new(NetworkProfile::lan()), 173);
            let mut backend = NativeBackend::new();
            let mut views = Views::new(false);
            let pi1_sh = ppp::share_perm(&mut mpc, &perms.pi1, OpClass::Linear);
            let pi1_t_sh = ppp::share_perm_t(&mut mpc, &perms.pi1, OpClass::Linear);
            let corr = deal_kv_correlations(&mut mpc, &cfg, &pi1_sh, &pi1_t_sh).unwrap();
            let mut kv = LayerKvCache::with_correlations(n, cfg.d, corr);
            let before_b = mpc.net.ledger.bytes_total();
            let before_r = mpc.net.ledger.rounds_total();
            let mut outs = Vec::new();
            for t in 0..steps {
                let row = FloatTensor::from_vec(1, cfg.d, x_pi.row(t).to_vec());
                let row_sh = mpc.share_local(&fixed::encode_tensor(&row));
                let mut ctx = ProtoCtx {
                    mpc: &mut mpc,
                    backend: &mut backend,
                    views: &mut views,
                    fast_sim: false,
                    round_batching,
                };
                outs.push(
                    transformer_layer_step(
                        &mut ctx, &cfg, &pm.layers[0], &pi1_sh, &pi1_t_sh, &row_sh, &mut kv, t, 0,
                    )
                    .unwrap(),
                );
            }
            (
                outs,
                mpc.net.ledger.bytes_total() - before_b,
                mpc.net.ledger.rounds_total() - before_r,
            )
        };
        let (bat, bat_bytes, bat_rounds) = run(true);
        let (seq, seq_bytes, seq_rounds) = run(false);
        for (t, (a, b)) in bat.iter().zip(seq.iter()).enumerate() {
            assert_eq!(a.s0, b.s0, "step {t}: P0 output share differs under batching");
            assert_eq!(a.s1, b.s1, "step {t}: P1 output share differs under batching");
        }
        assert_eq!(bat_bytes, seq_bytes, "round batching must not move a single byte");
        assert_eq!(seq_rounds, steps as u64 * 12, "sequential layer step is 12 rounds");
        assert_eq!(bat_rounds, steps as u64 * 6, "batched layer step is 6 rounds");
    }

    #[test]
    fn decode_pool_shapes_cover_both_modes() {
        let cfg = ModelConfig::gpt2_tiny();
        let l = cfg.layers as u64;
        // correlations on: one shared-mask session bundle per open-once
        // family, per-layer score bundles, plus the value triples
        let with = decode_pool_shapes(&cfg, true, 6);
        assert_eq!(with.len(), 4);
        assert!(with.iter().any(|(s, c)| *s
            == TripleShape::fixed_ppp_session(cfg.h, cfg.n_ctx, cfg.n_ctx, cfg.layers)
            && *c == 1));
        assert!(with.iter().any(|(s, c)| *s
            == TripleShape::fixed_append_session(cfg.n_ctx, cfg.d, cfg.n_ctx, cfg.layers)
            && *c == 1));
        assert!(with
            .iter()
            .any(|(s, c)| *s == TripleShape::fixed_scores(cfg.h, cfg.n_ctx, cfg.d, cfg.n_ctx) && *c == l));
        assert!(with
            .iter()
            .any(|(s, c)| *s == TripleShape::matmul(1, cfg.n_ctx, cfg.dh())
                && *c == l * cfg.h as u64 * 6));
        // correlations off: the plain per-step profile times steps
        let without = decode_pool_shapes(&cfg, false, 6);
        let plain = decode_step_shapes(&cfg);
        assert_eq!(without.len(), plain.len());
        for ((s, c), (ps, pc)) in without.iter().zip(plain.iter()) {
            assert_eq!(s, ps);
            assert_eq!(*c, pc * 6);
        }
    }

    #[test]
    fn batched_pool_shapes_scale_per_session_without_aliasing_keys() {
        let cfg = ModelConfig::gpt2_tiny();
        for correlations in [true, false] {
            let solo = decode_pool_shapes(&cfg, correlations, 6);
            let quad = decode_pool_shapes_batched(&cfg, correlations, 6, 4);
            assert_eq!(solo.len(), quad.len(), "batching must not invent or drop shape keys");
            for ((s, c), (qs, qc)) in solo.iter().zip(quad.iter()) {
                assert_eq!(s, qs, "shape keys are per-model, not per-session");
                assert_eq!(*qc, c * 4, "multiplicities add across sessions");
            }
        }
        // sessions = 0 is clamped: demand for at least one session
        assert_eq!(decode_pool_shapes_batched(&cfg, true, 6, 0), decode_pool_shapes(&cfg, true, 6));
    }

    #[test]
    fn decode_shape_profile_covers_all_step_products() {
        let cfg = ModelConfig::gpt2_tiny();
        let shapes = decode_step_shapes(&cfg);
        assert_eq!(shapes.len(), 4);
        let total: u64 = shapes.iter().map(|(_, c)| c).sum();
        // per layer: 1 Ṽ update + h score products + 1 PPP + h value products
        assert_eq!(total, (cfg.layers * (2 + 2 * cfg.h)) as u64);
        assert!(shapes.iter().any(|(s, c)| *s == TripleShape::matmul(cfg.n_ctx, 1, cfg.d)
            && *c == cfg.layers as u64));
        assert!(shapes.iter().any(|(s, _)| *s == TripleShape::matmul(cfg.h, cfg.n_ctx, cfg.n_ctx)));
    }

    #[test]
    fn causal_mask_row_masks_strict_future() {
        let m = causal_mask_row_fx(2, 8, 3);
        assert_eq!(m.shape(), (2, 8));
        for h in 0..2 {
            for c in 0..8 {
                let want = if c > 3 { fixed::encode(MASK_NEG) } else { 0 };
                assert_eq!(m.get(h, c), want, "head {h} col {c}");
            }
        }
    }

    #[test]
    fn stack_rows_roundtrip() {
        let mut mpc = Mpc::new(NetSim::new(NetworkProfile::lan()), 3);
        let a = mpc.share_local(&RingTensor::from_fn(2, 3, |r, c| (r * 3 + c) as i64));
        let b = mpc.share_local(&RingTensor::from_fn(2, 3, |r, c| (100 + r * 3 + c) as i64));
        let s = stack_rows(&[a.clone(), b.clone()]);
        assert_eq!(s.rows(), 4);
        assert_eq!(s.row_block(0, 2).reconstruct(), a.reconstruct());
        assert_eq!(s.row_block(2, 4).reconstruct(), b.reconstruct());
    }

    /// Rolling back speculative rows must restore the cache and the
    /// correlation state exactly: share digest, `uses_left`, and opening
    /// counters all return to their pre-speculation values, and decoding
    /// continues through the rewound positions on the restored bundles.
    #[test]
    fn truncate_to_restores_cache_digest_and_correlation_uses() {
        let mut cfg = ModelConfig::gpt2_tiny();
        cfg.layers = 1;
        let w = ModelWeights::random(&cfg, 191);
        let mut rng = Rng::new(192);
        let perms = PermSet::random(&cfg, &mut rng);
        let pm = PermutedModel::build(&cfg, &w, perms.clone());
        let n = cfg.n_ctx;
        let x = FloatTensor::from_fn(n, cfg.d, |r, c| ((r * 19 + c * 3) % 17) as f32 * 0.05 - 0.4);
        let x_pi = perms.pi.apply_cols(&x);

        let mut mpc = Mpc::new(NetSim::new(NetworkProfile::lan()), 193);
        let mut backend = NativeBackend::new();
        let mut views = Views::new(false);
        let pi1_sh = ppp::share_perm(&mut mpc, &perms.pi1, OpClass::Linear);
        let pi1_t_sh = ppp::share_perm_t(&mut mpc, &perms.pi1, OpClass::Linear);
        let corr = deal_kv_correlations(&mut mpc, &cfg, &pi1_sh, &pi1_t_sh).unwrap();
        let mut kv = LayerKvCache::with_correlations(n, cfg.d, corr);
        let run_step =
            |mpc: &mut Mpc, backend: &mut NativeBackend, views: &mut Views, kv: &mut LayerKvCache, t| {
                let row = FloatTensor::from_vec(1, cfg.d, x_pi.row(t).to_vec());
                let row_sh = mpc.share_local(&fixed::encode_tensor(&row));
                let mut ctx =
                    ProtoCtx { mpc, backend, views, fast_sim: false, round_batching: true };
                transformer_layer_step(
                    &mut ctx, &cfg, &pm.layers[0], &pi1_sh, &pi1_t_sh, &row_sh, kv, t, 0,
                )
                .unwrap();
            };
        for t in 0..3 {
            run_step(&mut mpc, &mut backend, &mut views, &mut kv, t);
        }
        let digest3 = kv.state_digest();
        let (u3, o3) = {
            let c = kv.correlations().unwrap();
            (
                (c.ppp.uses_left(), c.append.uses_left(), c.scores.uses_left()),
                (c.ppp.openings(), c.append.openings(), c.scores.openings()),
            )
        };
        // Two speculative rows, both rejected.
        for t in 3..5 {
            run_step(&mut mpc, &mut backend, &mut views, &mut kv, t);
        }
        assert_ne!(kv.state_digest(), digest3, "speculative rows must change the cache state");
        kv.truncate_to(3).unwrap();
        assert_eq!(kv.len(), 3);
        assert_eq!(kv.state_digest(), digest3, "rollback must restore the share state exactly");
        let c = kv.correlations().unwrap();
        assert_eq!((c.ppp.uses_left(), c.append.uses_left(), c.scores.uses_left()), u3);
        assert_eq!((c.ppp.openings(), c.append.openings(), c.scores.openings()), o3);
        // Forward truncation is refused; truncating to the current length
        // is a no-op.
        assert!(kv.truncate_to(4).is_err());
        kv.truncate_to(3).unwrap();
        assert_eq!(kv.state_digest(), digest3);
        // The restored bundles serve the corrected rows without exhausting.
        for t in 3..5 {
            run_step(&mut mpc, &mut backend, &mut views, &mut kv, t);
        }
        assert_eq!(kv.len(), 5);
    }

    /// k verify lanes through ONE batched flight chain must compute the
    /// same per-position outputs as k sequential single-token steps (the
    /// speculative correctness core: per-lane causal masking + per-lane
    /// `[Ṽ]` snapshots) at the round cost of ONE step, regardless of k.
    #[test]
    fn multi_lane_group_matches_sequential_steps_at_single_step_rounds() {
        let mut cfg = ModelConfig::gpt2_tiny();
        cfg.layers = 1;
        let w = ModelWeights::random(&cfg, 181);
        let mut rng = Rng::new(182);
        let perms = PermSet::random(&cfg, &mut rng);
        let pm = PermutedModel::build(&cfg, &w, perms.clone());
        let n = cfg.n_ctx;
        let x = FloatTensor::from_fn(n, cfg.d, |r, c| ((r * 7 + c * 5) % 21) as f32 * 0.06 - 0.55);
        let x_pi = perms.pi.apply_cols(&x);
        let k = 3usize;

        // Sequential reference: k single-token steps.
        let seq = {
            let mut mpc = Mpc::new(NetSim::new(NetworkProfile::lan()), 183);
            let mut backend = NativeBackend::new();
            let mut views = Views::new(false);
            let pi1_sh = ppp::share_perm(&mut mpc, &perms.pi1, OpClass::Linear);
            let pi1_t_sh = ppp::share_perm_t(&mut mpc, &perms.pi1, OpClass::Linear);
            let corr = deal_kv_correlations(&mut mpc, &cfg, &pi1_sh, &pi1_t_sh).unwrap();
            let mut kv = LayerKvCache::with_correlations(n, cfg.d, corr);
            let mut outs = Vec::new();
            for t in 0..k {
                let row = FloatTensor::from_vec(1, cfg.d, x_pi.row(t).to_vec());
                let row_sh = mpc.share_local(&fixed::encode_tensor(&row));
                let mut ctx = ProtoCtx {
                    mpc: &mut mpc,
                    backend: &mut backend,
                    views: &mut views,
                    fast_sim: false,
                    round_batching: true,
                };
                let out = transformer_layer_step(
                    &mut ctx, &cfg, &pm.layers[0], &pi1_sh, &pi1_t_sh, &row_sh, &mut kv, t, 0,
                )
                .unwrap();
                outs.push(fixed::decode_tensor(&out.reconstruct()));
            }
            outs
        };

        // Speculative: the same k tokens as lanes of ONE batch call.
        let mut mpc = Mpc::new(NetSim::new(NetworkProfile::lan()), 183);
        let mut backend = NativeBackend::new();
        let mut views = Views::new(false);
        let pi1_sh = ppp::share_perm(&mut mpc, &perms.pi1, OpClass::Linear);
        let pi1_t_sh = ppp::share_perm_t(&mut mpc, &perms.pi1, OpClass::Linear);
        let corr = deal_kv_correlations(&mut mpc, &cfg, &pi1_sh, &pi1_t_sh).unwrap();
        let mut kv = vec![LayerKvCache::with_correlations(n, cfg.d, corr)];
        let lanes: Vec<SpecLane> = (0..k)
            .map(|t| {
                let row = FloatTensor::from_vec(1, cfg.d, x_pi.row(t).to_vec());
                SpecLane { x_pi: mpc.share_local(&fixed::encode_tensor(&row)), pos: t, bytes: 0 }
            })
            .collect();
        let before_r = mpc.net.ledger.rounds_total();
        let mut groups = [StepLaneGroup { kv: &mut kv, prefix: "", lanes }];
        {
            let mut ctx = ProtoCtx {
                mpc: &mut mpc,
                backend: &mut backend,
                views: &mut views,
                fast_sim: false,
                round_batching: true,
            };
            transformer_layer_step_batch(
                &mut ctx, &cfg, &pm.layers[0], &pi1_sh, &pi1_t_sh, &mut groups, 0, None,
            )
            .unwrap();
        }
        let batch_rounds = mpc.net.ledger.rounds_total() - before_r;
        assert_eq!(batch_rounds, 6, "k lanes must ride one 6-round layer flight chain");
        for (t, want) in seq.iter().enumerate() {
            let got = fixed::decode_tensor(&groups[0].lanes[t].x_pi.reconstruct());
            let diff = got.max_abs_diff(want);
            assert!(diff < 0.05, "lane {t} diverges from its sequential step: diff {diff}");
        }
        assert_eq!(groups[0].kv[0].len(), k, "every lane's row must be appended");
    }

    #[test]
    fn speculative_pool_shapes_scale_verify_lanes_not_session_bundles() {
        let cfg = ModelConfig::gpt2_tiny();
        for correlations in [true, false] {
            let base = decode_pool_shapes(&cfg, correlations, 6);
            let spec = decode_pool_shapes_speculative(&cfg, correlations, 6, 2, 4);
            assert_eq!(base.len(), spec.len(), "speculation must not invent or drop shape keys");
            for ((s, c), (ss, sc)) in base.iter().zip(spec.iter()) {
                assert_eq!(s, ss, "shape keys are per-model");
                let lanes = if s.is_fixed() { 1 } else { 4 };
                assert_eq!(*sc, c * lanes * 2, "sessions × verify lanes, session bundles exempt");
            }
        }
        // spec_k = 1 degenerates to the batched profile exactly.
        assert_eq!(
            decode_pool_shapes_speculative(&cfg, true, 6, 3, 1),
            decode_pool_shapes_batched(&cfg, true, 6, 3)
        );
    }
}
