//! The full privacy-preserving Transformer layer (paper Fig. 6):
//! multi-head attention + feed-forward, with Centaur's hybrid state
//! management. See `rust/src/model/permute.rs` for the algebra table.
//!
//! Per-layer protocol sequence (classes in parentheses):
//!
//! 1. `[Q],[K],[V] = Π_ScalMul([Xπ], Wπ) + b`            (Linear, 0 comm)
//! 2. per head: `[O1] = Π_MatMul([Q_h],[K_hᵀ])/√dh + M`  (Linear, 1 round batched)
//! 3. `[O1π₁] = Π_PPP([O1], [π₁])`                        (Linear, 1 round)
//! 4. `[O2π₁] = Π_PPSM([O1π₁])`                           (Softmax, 2 rounds)
//! 5. `[Ṽ] = Π_PPP([π₁ᵀ],[V])`                            (Linear, 1 round)
//! 6. per head: `[O3_h] = Π_MatMul([O2π₁]_h,[Ṽ_h])`       (Linear, 1 round batched)
//! 7. `[O4π] = Π_ScalMul([O3], πᵀW_O) + b_Oπ`             (Linear, 0 comm)
//! 8. `[L1π] = Π_PPLN([O4π + Xπ], γ₁π, β₁π)`              (LayerNorm, 2 rounds)
//! 9. `[O5π₂] = Π_ScalMul([L1π], π₂ᵀW₁π) + b₁π₂`          (Linear, 0 comm)
//! 10. `[Gπ₂] = Π_PPGeLU([O5π₂])`                          (GeLU, 2 rounds)
//! 11. `[O6π] = Π_ScalMul([Gπ₂], πᵀW₂π₂) + b₂π`            (Linear, 0 comm)
//! 12. `[L2π] = Π_PPLN([O6π + L1π], γ₂π, β₂π)`             (LayerNorm, 2 rounds)

use crate::engine::views::Views;
use crate::fixed;
use crate::model::{ModelConfig, PermLayer};
use crate::mpc::{Mpc, Share};
use crate::net::OpClass;
use crate::runtime::Backend;
use crate::tensor::RingTensor;
use crate::Result;

use super::nonlin::{pp_gelu, pp_layernorm, pp_softmax};

/// Mask value standing in for −∞ in causal attention (exp(−1e5) == 0 in
/// f32 while staying comfortably inside the fixed-point range).
pub const MASK_NEG: f64 = -1e5;

/// Protocol execution context threaded through the per-layer protocols.
pub struct ProtoCtx<'a> {
    /// MPC context (network + dealer).
    pub mpc: &'a mut Mpc,
    /// P1's plaintext op executor.
    pub backend: &'a mut dyn Backend,
    /// P1 observation ledger.
    pub views: &'a mut Views,
    /// Fast-sim: share×share products via charged-ideal (exact wire costs,
    /// single local product) — used for paper-scale models on this testbed.
    pub fast_sim: bool,
}

impl<'a> ProtoCtx<'a> {
    /// Batched share×share products (one round), honoring fast-sim.
    pub fn matmul_batch(&mut self, pairs: &[(&Share, &Share)], class: OpClass) -> Vec<Share> {
        if self.fast_sim {
            self.mpc.matmul_charged_ideal_batch(pairs, class)
        } else {
            self.mpc.matmul_batch(pairs, class)
        }
    }

    /// Share×share product, honoring fast-sim.
    pub fn matmul(&mut self, x: &Share, y: &Share, class: OpClass) -> Share {
        if self.fast_sim {
            self.mpc.matmul_charged_ideal(x, y, class)
        } else {
            self.mpc.matmul(x, y, class)
        }
    }

    /// `[X]·Wᵀ` against public weights, honoring fast-sim.
    pub fn scalmul_nt(&mut self, x: &Share, w_fx: &RingTensor, class: OpClass) -> Share {
        if self.fast_sim {
            self.mpc.scalmul_nt_ideal(x, w_fx, class)
        } else {
            self.mpc.scalmul_nt(x, w_fx, class)
        }
    }

    /// `[X]·W` against public weights, honoring fast-sim.
    pub fn scalmul_rhs(&mut self, x: &Share, w_fx: &RingTensor, class: OpClass) -> Share {
        if self.fast_sim {
            self.mpc.scalmul_rhs_ideal(x, w_fx, class)
        } else {
            self.mpc.scalmul_rhs(x, w_fx, class)
        }
    }
}

/// Stack shares vertically (head stacking for the Π_PPSM batch).
pub fn stack_rows(blocks: &[Share]) -> Share {
    let cols = blocks[0].cols();
    let rows: usize = blocks.iter().map(|b| b.rows()).sum();
    let f = |pick: fn(&Share) -> &RingTensor| {
        let mut out = RingTensor::zeros(rows, cols);
        let mut r0 = 0;
        for b in blocks {
            let t = pick(b);
            for r in 0..t.rows() {
                out.row_mut(r0 + r).copy_from_slice(t.row(r));
            }
            r0 += t.rows();
        }
        out
    };
    Share { s0: f(|b| &b.s0), s1: f(|b| &b.s1) }
}

/// Causal mask in fixed point, stacked for `h` heads: `(h·n, n)`.
pub fn causal_mask_fx(h: usize, n: usize) -> RingTensor {
    let neg = fixed::encode(MASK_NEG);
    RingTensor::from_fn(h * n, n, |r, c| if c > (r % n) { neg } else { 0 })
}

/// Multi-head attention + FFN for one layer: `[Xπ] → [L2π]`.
#[allow(clippy::too_many_arguments)]
pub fn transformer_layer(
    ctx: &mut ProtoCtx,
    cfg: &ModelConfig,
    pl: &PermLayer,
    pi1_sh: &Share,
    pi1_t_sh: &Share,
    x_pi: &Share,
    mask_fx: Option<&RingTensor>,
    layer_idx: usize,
) -> Result<Share> {
    let n = x_pi.rows();
    let dh = cfg.dh();
    let scale = fixed::encode(1.0 / (dh as f64).sqrt());

    // 1. Q, K, V (shares, unpermuted): Π_ScalMul + bias via P0.
    let q = {
        let s = ctx.scalmul_nt(x_pi, &pl.wq, OpClass::Linear);
        ctx.mpc.add_plain_row(&s, &pl.bq)
    };
    let k = {
        let s = ctx.scalmul_nt(x_pi, &pl.wk, OpClass::Linear);
        ctx.mpc.add_plain_row(&s, &pl.bk)
    };
    let v = {
        let s = ctx.scalmul_nt(x_pi, &pl.wv, OpClass::Linear);
        ctx.mpc.add_plain_row(&s, &pl.bv)
    };

    // 2. O1 per head = Q_h K_hᵀ (one batched round).
    let kt: Vec<Share> = (0..cfg.h).map(|h| k.col_block(h * dh, (h + 1) * dh).transpose()).collect();
    let qh: Vec<Share> = (0..cfg.h).map(|h| q.col_block(h * dh, (h + 1) * dh)).collect();
    let pairs: Vec<(&Share, &Share)> = qh.iter().zip(kt.iter()).collect();
    let o1_heads = ctx.matmul_batch(&pairs, OpClass::Linear);
    let mut o1 = stack_rows(&o1_heads); // (h·n, n)
    o1 = ctx.mpc.scale_fx(&o1, scale);
    if let Some(m) = mask_fx {
        o1 = ctx.mpc.add_plain(&o1, m);
    }

    // 3. Π_PPP: restore a permuted state for the softmax opening.
    let o1_p1 = ctx.matmul(&o1, pi1_sh, OpClass::Linear);

    // 4. Π_PPSM at P1 (sees O1π₁ — the paper's Table 2 attack surface).
    let o2_p1 = pp_softmax(
        ctx.mpc,
        ctx.backend,
        ctx.views,
        &o1_p1,
        &format!("O1pi1 layer{layer_idx}"),
    )?;

    // 5. Ṽ = π₁ᵀ V so the π₁ in O2π₁ cancels.
    let v_tilde = ctx.matmul(pi1_t_sh, &v, OpClass::Linear);

    // 6. O3 per head (one batched round), then concat heads.
    let o2h: Vec<Share> = (0..cfg.h).map(|h| o2_p1.row_block(h * n, (h + 1) * n)).collect();
    let vth: Vec<Share> = (0..cfg.h).map(|h| v_tilde.col_block(h * dh, (h + 1) * dh)).collect();
    let pairs3: Vec<(&Share, &Share)> = o2h.iter().zip(vth.iter()).collect();
    let o3_heads = ctx.matmul_batch(&pairs3, OpClass::Linear);
    let o3 = Share::concat_cols(&o3_heads); // (n, d)

    // 7. O4π = Π_ScalMul([O3], πᵀW_O) + b_Oπ.
    let o4_pi = {
        let s = ctx.scalmul_nt(&o3, &pl.wo, OpClass::Linear);
        ctx.mpc.add_plain_row(&s, &pl.bo)
    };

    // 8. residual + Π_PPLN (P1 holds γ₁π, β₁π).
    let res1 = ctx.mpc.add(&o4_pi, x_pi);
    let l1_pi = pp_layernorm(
        ctx.mpc,
        ctx.backend,
        ctx.views,
        &res1,
        &pl.ln1_g,
        &pl.ln1_b,
        OpClass::LayerNorm,
        &format!("O4+X pi layer{layer_idx}"),
    )?;

    // 9-12. FFN.
    let o5_pi2 = {
        let s = ctx.scalmul_nt(&l1_pi, &pl.w1, OpClass::Linear);
        ctx.mpc.add_plain_row(&s, &pl.b1)
    };
    let g_pi2 = pp_gelu(
        ctx.mpc,
        ctx.backend,
        ctx.views,
        &o5_pi2,
        &format!("O5pi2 layer{layer_idx}"),
    )?;
    let o6_pi = {
        let s = ctx.scalmul_nt(&g_pi2, &pl.w2, OpClass::Linear);
        ctx.mpc.add_plain_row(&s, &pl.b2)
    };
    let res2 = ctx.mpc.add(&o6_pi, &l1_pi);
    let l2_pi = pp_layernorm(
        ctx.mpc,
        ctx.backend,
        ctx.views,
        &res2,
        &pl.ln2_g,
        &pl.ln2_b,
        OpClass::LayerNorm,
        &format!("O6+L1 pi layer{layer_idx}"),
    )?;
    Ok(l2_pi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::views::Views;
    use crate::model::{ModelConfig, ModelWeights, PermSet, PermutedModel};
    use crate::protocols::ppp;
    use crate::net::{NetSim, NetworkProfile};
    use crate::runtime::NativeBackend;
    use crate::tensor::FloatTensor;
    use crate::util::rng::Rng;

    /// One full layer through the protocols vs the plaintext reference.
    fn run_layer(fast_sim: bool) {
        let mut cfg = ModelConfig::bert_tiny();
        cfg.layers = 1;
        let w = ModelWeights::random(&cfg, 31);
        let mut rng = Rng::new(32);
        let perms = PermSet::random(&cfg, &mut rng);
        let pm = PermutedModel::build(&cfg, &w, perms.clone());

        // random activations standing in for X_E
        let x = FloatTensor::from_fn(cfg.n_ctx, cfg.d, |r, c| ((r * 31 + c * 7) % 23) as f32 * 0.08 - 0.8);
        let x_pi = perms.pi.apply_cols(&x);

        let mut mpc = Mpc::new(NetSim::new(NetworkProfile::lan()), 33);
        let mut backend = NativeBackend::new();
        let mut views = Views::new(false);
        let x_sh = mpc.share_local(&fixed::encode_tensor(&x_pi));
        let pi1_sh = ppp::share_perm(&mut mpc, &perms.pi1, OpClass::Linear);
        let pi1_t_sh = ppp::share_perm_t(&mut mpc, &perms.pi1, OpClass::Linear);
        let mut ctx = ProtoCtx { mpc: &mut mpc, backend: &mut backend, views: &mut views, fast_sim };
        let out = transformer_layer(&mut ctx, &cfg, &pm.layers[0], &pi1_sh, &pi1_t_sh, &x_sh, None, 0).unwrap();

        // plaintext reference: build a pseudo-model that starts from x
        // directly (reuse forward_trace by setting embeddings to x rows).
        let got = fixed::decode_tensor(&out.reconstruct());
        let want_pi = {
            // compute reference layer on x with plaintext ops
            use crate::model::plaintext;
            // quick manual reference using the same weights
            let mut w1 = w.clone();
            w1.layers.truncate(1);
            // manual: reuse forward internals via a tiny embedding hack is
            // messier than just recomputing here:
            let _ = plaintext::Variant::Exact;
            let l = &w.layers[0];
            let q = x.matmul_nt(&l.wq).add_row(&l.bq);
            let k = x.matmul_nt(&l.wk).add_row(&l.bk);
            let v = x.matmul_nt(&l.wv).add_row(&l.bv);
            let dh = cfg.dh();
            let mut o3 = FloatTensor::zeros(cfg.n_ctx, cfg.d);
            for h in 0..cfg.h {
                let qh = q.col_block(h * dh, (h + 1) * dh);
                let kh = k.col_block(h * dh, (h + 1) * dh);
                let vh = v.col_block(h * dh, (h + 1) * dh);
                let mut s = qh.matmul_nt(&kh);
                s.map_inplace(|v| v / (dh as f32).sqrt());
                for r in 0..s.rows() {
                    crate::runtime::native::softmax_row(s.row_mut(r));
                }
                o3.set_col_block(h * dh, &s.matmul(&vh));
            }
            let o4 = o3.matmul_nt(&l.wo).add_row(&l.bo);
            let mut nb = NativeBackend::new();
            use crate::runtime::Backend as _;
            let l1 = nb.layernorm(&o4.zip_with(&x, |a, b| a + b), &l.ln1_g, &l.ln1_b).unwrap();
            let o5 = l1.matmul_nt(&l.w1).add_row(&l.b1);
            let g = o5.map(crate::runtime::native::gelu_scalar);
            let o6 = g.matmul_nt(&l.w2).add_row(&l.b2);
            let l2 = nb.layernorm(&o6.zip_with(&l1, |a, b| a + b), &l.ln2_g, &l.ln2_b).unwrap();
            perms.pi.apply_cols(&l2)
        };
        let diff = got.max_abs_diff(&want_pi);
        assert!(diff < 0.05, "layer output diff {diff} (fast_sim={fast_sim})");
    }

    #[test]
    fn layer_matches_plaintext_full() {
        run_layer(false);
    }

    #[test]
    fn layer_matches_plaintext_fast_sim() {
        run_layer(true);
    }

    #[test]
    fn causal_mask_shape_and_values() {
        let m = causal_mask_fx(2, 4);
        assert_eq!(m.shape(), (8, 4));
        assert_eq!(m.get(0, 0), 0);
        assert_eq!(m.get(0, 3), fixed::encode(MASK_NEG));
        assert_eq!(m.get(3, 3), 0); // row 3 of head 0 sees everything
        assert_eq!(m.get(4, 1), fixed::encode(MASK_NEG)); // head 1, row 0
    }

    #[test]
    fn stack_rows_roundtrip() {
        let mut mpc = Mpc::new(NetSim::new(NetworkProfile::lan()), 3);
        let a = mpc.share_local(&RingTensor::from_fn(2, 3, |r, c| (r * 3 + c) as i64));
        let b = mpc.share_local(&RingTensor::from_fn(2, 3, |r, c| (100 + r * 3 + c) as i64));
        let s = stack_rows(&[a.clone(), b.clone()]);
        assert_eq!(s.rows(), 4);
        assert_eq!(s.row_block(0, 2).reconstruct(), a.reconstruct());
        assert_eq!(s.row_block(2, 4).reconstruct(), b.reconstruct());
    }
}
