//! Centaur's privacy-preserving protocols (paper §5.2 + Appendix A).
//!
//! * [`nonlin`] — `Π_PPSM`, `Π_PPGeLU`, `Π_PPLN`, `Π_PPTanh`: the
//!   share → permuted-plaintext → share conversion pattern (Algorithms 1-3).
//! * [`ppp`] — `Π_PPP` (Algorithm 6): re-permuting shares whose permutation
//!   was cancelled by a linear protocol.
//! * [`embedding`] — `Π_PPEmbedding` (Algorithm 4).
//! * [`layer`] — the full Transformer layer (attention + FFN) from Fig. 6.
//! * [`adaptation`] — `Π_PPAdaptation` (Algorithm 5) for BERT and the GPT-2
//!   LM-head variant.

pub mod adaptation;
pub mod embedding;
pub mod layer;
pub mod nonlin;
pub mod ppp;
