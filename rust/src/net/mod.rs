//! Deterministic network simulator and communication-cost ledger.
//!
//! The paper's efficiency claims are communication-bound, so the simulator's
//! job is to account **exactly** for every byte and round each protocol
//! moves, per *operation class* (the paper's breakdown axes: Linear,
//! Softmax, GeLU, LayerNorm, Embedding, Adaptation), and to convert those
//! into wall time under the three network profiles of §7.1:
//! LAN {3 Gbps, 0.8 ms}, WAN1 {200 Mbps, 40 ms}, WAN2 {100 Mbps, 80 ms}.
//!
//! Wall-time model (DESIGN.md §CostModel):
//! `T = T_compute(measured) + rounds·RTT + bytes·8/bandwidth`.
//!
//! Parties are simulated in-process; a "transfer" physically clones the
//! tensor (so protocols cannot accidentally alias plaintext) and charges
//! its serialized size.

use crate::tensor::RingTensor;
use std::time::Duration;

/// Identities of the protocol participants (paper Fig. 5).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PartyId {
    /// Model developer (holds permutations, acts as compute server 0).
    P0,
    /// Cloud platform (compute server 1; sees permuted plaintext).
    P1,
    /// Client (data owner).
    P2,
    /// Trusted dealer for correlated randomness (CrypTen TTP model).
    Dealer,
}

impl PartyId {
    /// Dense index (ledger slot).
    pub fn index(self) -> usize {
        match self {
            PartyId::P0 => 0,
            PartyId::P1 => 1,
            PartyId::P2 => 2,
            PartyId::Dealer => 3,
        }
    }
    /// Display label.
    pub fn name(self) -> &'static str {
        match self {
            PartyId::P0 => "P0(developer)",
            PartyId::P1 => "P1(cloud)",
            PartyId::P2 => "P2(client)",
            PartyId::Dealer => "dealer",
        }
    }
}

/// Operation classes used by the paper's per-layer breakdowns (Figs. 3/7/8/10).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpClass {
    /// Projections and attention share×share products.
    Linear,
    /// Softmax (scores → probabilities).
    Softmax,
    /// GeLU activation.
    Gelu,
    /// LayerNorm.
    LayerNorm,
    /// Input embedding lookup.
    Embedding,
    /// Task head (pooler/classifier or LM head).
    Adaptation,
    /// Session-scoped correlation setup: the once-per-session masked
    /// openings of fixed-operand correlated triples (DESIGN.md
    /// §Fixed-operand correlations). Split out so warm-step ledgers stay
    /// clean and the amortized cost is visible in breakdowns.
    Correlation,
    /// Everything else (setup, opens, PPP dealing).
    Other,
}

impl OpClass {
    /// Every class, in ledger order.
    pub const ALL: [OpClass; 8] = [
        OpClass::Linear,
        OpClass::Softmax,
        OpClass::Gelu,
        OpClass::LayerNorm,
        OpClass::Embedding,
        OpClass::Adaptation,
        OpClass::Correlation,
        OpClass::Other,
    ];
    /// Dense index (ledger slot).
    pub fn index(self) -> usize {
        match self {
            OpClass::Linear => 0,
            OpClass::Softmax => 1,
            OpClass::Gelu => 2,
            OpClass::LayerNorm => 3,
            OpClass::Embedding => 4,
            OpClass::Adaptation => 5,
            OpClass::Correlation => 6,
            OpClass::Other => 7,
        }
    }
    /// Display label.
    pub fn name(self) -> &'static str {
        match self {
            OpClass::Linear => "Linear",
            OpClass::Softmax => "Softmax",
            OpClass::Gelu => "GeLU",
            OpClass::LayerNorm => "LayerNorm",
            OpClass::Embedding => "Embedding",
            OpClass::Adaptation => "Adaptation",
            OpClass::Correlation => "Correlation",
            OpClass::Other => "Other",
        }
    }
}

/// A bandwidth/latency profile (paper §7.1 experimental setup).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NetworkProfile {
    /// Display label (includes bandwidth/RTT).
    pub name: &'static str,
    /// Link bandwidth in bits/second.
    pub bandwidth_bps: f64,
    /// Round-trip time in seconds.
    pub rtt: f64,
}

impl NetworkProfile {
    /// LAN: 3 Gbps, 0.8 ms RTT.
    pub fn lan() -> Self {
        NetworkProfile { name: "LAN(3Gbps,0.8ms)", bandwidth_bps: 3e9, rtt: 0.8e-3 }
    }
    /// WAN: 200 Mbps, 40 ms RTT.
    pub fn wan1() -> Self {
        NetworkProfile { name: "WAN(200Mbps,40ms)", bandwidth_bps: 200e6, rtt: 40e-3 }
    }
    /// WAN: 100 Mbps, 80 ms RTT.
    pub fn wan2() -> Self {
        NetworkProfile { name: "WAN(100Mbps,80ms)", bandwidth_bps: 100e6, rtt: 80e-3 }
    }
    /// High-bandwidth WAN: 1 Gbps, 80 ms RTT — the decode-latency preset.
    /// At these rates the byte term of the cost model is negligible for
    /// single-token decode steps, so per-token latency is essentially
    /// `rounds · 80 ms`: the profile that makes round compression (batched
    /// openings, DESIGN.md §Batched openings) directly visible.
    pub fn wan3() -> Self {
        NetworkProfile { name: "WAN(1Gbps,80ms)", bandwidth_bps: 1e9, rtt: 80e-3 }
    }
    /// Look up a profile by CLI name.
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "lan" => Some(Self::lan()),
            "wan1" => Some(Self::wan1()),
            "wan2" => Some(Self::wan2()),
            "wan3" => Some(Self::wan3()),
            _ => None,
        }
    }
    /// CLI names of the available profiles.
    pub const ALL_NAMES: [&'static str; 4] = ["lan", "wan1", "wan2", "wan3"];

    /// Time to complete `rounds` rounds moving `bytes` in total.
    pub fn time_for(&self, rounds: u64, bytes: u64) -> f64 {
        rounds as f64 * self.rtt + (bytes as f64 * 8.0) / self.bandwidth_bps
    }
}

/// Per-op-class accumulated cost.
#[derive(Clone, Copy, Debug, Default)]
pub struct ClassCost {
    /// Bytes transferred.
    pub bytes: u64,
    /// Communication rounds.
    pub rounds: u64,
    /// Measured local compute per party (seconds).
    pub compute: [f64; 4],
}

impl ClassCost {
    /// Compute time assuming parties run concurrently (max across parties).
    pub fn compute_critical_path(&self) -> f64 {
        self.compute.iter().cloned().fold(0.0, f64::max)
    }
}

/// Ledger of all communication + compute per op class.
#[derive(Clone, Debug, Default)]
pub struct CostLedger {
    per_class: [ClassCost; 8],
}

impl CostLedger {
    /// Empty ledger.
    pub fn new() -> Self {
        Self::default()
    }

    /// Accumulated cost of one class.
    pub fn class(&self, c: OpClass) -> &ClassCost {
        &self.per_class[c.index()]
    }

    /// Charge bytes to a class.
    pub fn add_bytes(&mut self, c: OpClass, bytes: u64) {
        self.per_class[c.index()].bytes += bytes;
    }

    /// Charge rounds to a class.
    pub fn add_rounds(&mut self, c: OpClass, rounds: u64) {
        self.per_class[c.index()].rounds += rounds;
    }

    /// Record measured local compute for one party.
    pub fn add_compute(&mut self, c: OpClass, party: PartyId, secs: f64) {
        self.per_class[c.index()].compute[party.index()] += secs;
    }

    /// Total bytes across classes.
    pub fn bytes_total(&self) -> u64 {
        self.per_class.iter().map(|c| c.bytes).sum()
    }

    /// Total rounds across classes.
    pub fn rounds_total(&self) -> u64 {
        self.per_class.iter().map(|c| c.rounds).sum()
    }

    /// Per-class round counts in ledger order — the first-class
    /// rounds/token breakdown the round-budget harness pins.
    pub fn rounds_by_class(&self) -> [(OpClass, u64); 8] {
        let mut out = [(OpClass::Other, 0u64); 8];
        for (i, &c) in OpClass::ALL.iter().enumerate() {
            out[i] = (c, self.class(c).rounds);
        }
        out
    }

    /// Per-class byte counts in ledger order (the byte-parity twin of
    /// [`CostLedger::rounds_by_class`]).
    pub fn bytes_by_class(&self) -> [(OpClass, u64); 8] {
        let mut out = [(OpClass::Other, 0u64); 8];
        for (i, &c) in OpClass::ALL.iter().enumerate() {
            out[i] = (c, self.class(c).bytes);
        }
        out
    }

    /// Total per-class critical-path compute.
    pub fn compute_total(&self) -> f64 {
        self.per_class.iter().map(|c| c.compute_critical_path()).sum()
    }

    /// Wall time for one class under a profile.
    pub fn class_time(&self, c: OpClass, p: &NetworkProfile) -> f64 {
        let cc = self.class(c);
        cc.compute_critical_path() + p.time_for(cc.rounds, cc.bytes)
    }

    /// Total wall time under a profile.
    pub fn total_time(&self, p: &NetworkProfile) -> f64 {
        OpClass::ALL.iter().map(|&c| self.class_time(c, p)).sum()
    }

    /// `self` and `other` merged into a fresh ledger (phase-split
    /// reporting: prefill + decode totals without mutating either phase).
    pub fn merged(&self, other: &CostLedger) -> CostLedger {
        let mut t = self.clone();
        t.merge(other);
        t
    }

    /// Merge another ledger into this one.
    pub fn merge(&mut self, other: &CostLedger) {
        for i in 0..self.per_class.len() {
            self.per_class[i].bytes += other.per_class[i].bytes;
            self.per_class[i].rounds += other.per_class[i].rounds;
            for p in 0..4 {
                self.per_class[i].compute[p] += other.per_class[i].compute[p];
            }
        }
    }

    /// Per-class difference (`self − other`), saturating at zero — used by
    /// the layer-extrapolation in `report::measure_framework`.
    pub fn delta(&self, other: &CostLedger) -> CostLedger {
        let mut out = CostLedger::new();
        for i in 0..self.per_class.len() {
            out.per_class[i].bytes = self.per_class[i].bytes.saturating_sub(other.per_class[i].bytes);
            out.per_class[i].rounds = self.per_class[i].rounds.saturating_sub(other.per_class[i].rounds);
            for p in 0..4 {
                out.per_class[i].compute[p] =
                    (self.per_class[i].compute[p] - other.per_class[i].compute[p]).max(0.0);
            }
        }
        out
    }

    /// Scale all quantities by an integer factor (layer replication).
    pub fn scaled(&self, factor: u64) -> CostLedger {
        let mut out = CostLedger::new();
        for i in 0..self.per_class.len() {
            out.per_class[i].bytes = self.per_class[i].bytes * factor;
            out.per_class[i].rounds = self.per_class[i].rounds * factor;
            for p in 0..4 {
                out.per_class[i].compute[p] = self.per_class[i].compute[p] * factor as f64;
            }
        }
        out
    }

    /// Pretty per-class breakdown table.
    pub fn breakdown(&self, profile: &NetworkProfile) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<12} {:>14} {:>8} {:>12} {:>12}\n",
            "class", "bytes", "rounds", "compute", "wall"
        ));
        for &c in OpClass::ALL.iter() {
            let cc = self.class(c);
            if cc.bytes == 0 && cc.rounds == 0 && cc.compute_critical_path() == 0.0 {
                continue;
            }
            out.push_str(&format!(
                "{:<12} {:>14} {:>8} {:>12} {:>12}\n",
                c.name(),
                crate::util::human_bytes(cc.bytes),
                cc.rounds,
                crate::util::human_secs(cc.compute_critical_path()),
                crate::util::human_secs(self.class_time(c, profile)),
            ));
        }
        out.push_str(&format!(
            "{:<12} {:>14} {:>8} {:>12} {:>12}\n",
            "TOTAL",
            crate::util::human_bytes(self.bytes_total()),
            self.rounds_total(),
            crate::util::human_secs(self.compute_total()),
            crate::util::human_secs(self.total_time(profile)),
        ));
        out
    }
}

/// One message recorded by the transfer census (see
/// [`NetSim::record_transfers`]): enough to compare the *multiset* of
/// payloads two protocol schedules move — the security invariant of
/// round batching (DESIGN.md §Batched openings) is that merging rounds
/// never adds, drops, or alters a transferred payload.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct TransferRecord {
    /// Sender slot index ([`PartyId::index`]).
    pub from: usize,
    /// Receiver slot index.
    pub to: usize,
    /// Op class the bytes were charged to.
    pub class_idx: usize,
    /// Payload size in bytes.
    pub bytes: u64,
    /// Context-hardened digest: commits to direction, tensor shape, the
    /// global transfer sequence number, and the payload digest, so equal
    /// payloads moved in different contexts — or a stale message replayed
    /// later — no longer collide (see [`transfer_digest`]).
    pub digest: u64,
    /// FNV-1a digest of the payload words alone (order-sensitive within
    /// the tensor, so equal payloads mean equal values w.h.p.) — the
    /// context-free component used for cross-schedule multiset checks.
    pub payload: u64,
}

/// FNV-1a offset basis (shared by every digest in this module).
pub const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// Fold 64-bit words into an FNV-1a chain, little-endian byte order —
/// the same byte walk as [`fnv1a_tensor`], so composed digests stay
/// stable across refactors of either.
pub fn fnv1a_fold(mut h: u64, words: &[u64]) -> u64 {
    for w in words {
        for b in w.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// FNV-1a digest of a ring tensor's payload words (context-free).
pub fn fnv1a_tensor(t: &RingTensor) -> u64 {
    let mut h = FNV_OFFSET;
    for &v in t.data() {
        h = fnv1a_fold(h, &[v as u64]);
    }
    h
}

/// Context-hardened census digest of one transfer: folds the direction,
/// the tensor shape, the global sequence number, and the payload digest.
/// Any bit of context or content changing changes the digest, so replayed
/// or re-routed copies of an identical payload are distinguishable — the
/// property the audit transcript chain relies on.
pub fn transfer_digest(from: PartyId, to: PartyId, t: &RingTensor, seq: u64, payload: u64) -> u64 {
    fnv1a_fold(
        FNV_OFFSET,
        &[from.index() as u64, to.index() as u64, t.rows() as u64, t.cols() as u64, seq, payload],
    )
}

/// The kind of single-shot wire fault the tamper-injection harness can
/// schedule against a [`NetSim`] (see [`NetSim::schedule_tamper`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TamperKind {
    /// XOR one bit of one payload word of the delivered clone
    /// (`word`/`bit` are reduced modulo the payload dimensions).
    BitFlip {
        /// Flat word index into the payload (mod `len`).
        word: usize,
        /// Bit position within the word (mod 64).
        bit: u32,
    },
    /// Deliver the *previous* transfer's payload instead (a stale-message
    /// replay). Degrades to a bit flip when the previous payload has a
    /// different shape or is bit-identical, so a scheduled fault always
    /// corrupts something.
    ReplayStale,
}

/// A scheduled single-shot wire fault: corrupt the delivered clone of
/// global transfer number `at_seq`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TamperPlan {
    /// 0-based global transfer sequence number to corrupt
    /// ([`NetSim::transfer_seq`] counts every transfer since construction,
    /// across ledger resets).
    pub at_seq: u64,
    /// What to do to the delivered payload.
    pub kind: TamperKind,
}

/// The in-process network simulator handed to every protocol.
#[derive(Debug)]
pub struct NetSim {
    /// Simulated link parameters.
    pub profile: NetworkProfile,
    /// Accumulated costs of the current inference.
    pub ledger: CostLedger,
    /// When true, optionally sleep to emulate latency in live demos.
    pub realtime: bool,
    /// Count of individual messages (diagnostics).
    pub messages: u64,
    /// Keep a [`TransferRecord`] per message (census tests); off by
    /// default. The log survives [`NetSim::reset`] so a multi-step decode
    /// session can be audited end to end — clear it explicitly with
    /// [`NetSim::clear_transfer_log`].
    pub record_transfers: bool,
    /// Recorded transfers (empty unless `record_transfers`).
    pub transfer_log: Vec<TransferRecord>,
    /// Global transfer sequence number: increments on **every** transfer
    /// since construction, across ledger resets — the per-message
    /// uniqueness the hardened census digests fold in.
    pub transfer_seq: u64,
    /// Rolling FNV-1a chain over the contextual digests of every recorded
    /// transfer (the wire component of the audit transcript). Survives
    /// [`NetSim::reset`] like the census itself; rewound by
    /// [`NetSim::clear_transfer_log`].
    pub wire_digest: u64,
    /// Wire faults actually applied so far (lets the tamper harness
    /// assert the scheduled fault landed on a real message).
    pub faults_applied: u64,
    /// Scheduled single-shot wire fault (tamper-injection test hook);
    /// consumed when its target transfer happens.
    tamper: Option<TamperPlan>,
    /// Stash of the payload immediately preceding a pending
    /// [`TamperKind::ReplayStale`] target — the stale message to replay.
    stale: Option<RingTensor>,
    /// Open-batch state: rounds suppressed since `begin_batch` (`None`
    /// when no batch is active).
    batched_rounds: Option<u64>,
}

impl NetSim {
    /// Simulator with an empty ledger.
    pub fn new(profile: NetworkProfile) -> Self {
        NetSim {
            profile,
            ledger: CostLedger::new(),
            realtime: false,
            messages: 0,
            record_transfers: false,
            transfer_log: Vec::new(),
            transfer_seq: 0,
            wire_digest: FNV_OFFSET,
            faults_applied: 0,
            tamper: None,
            stale: None,
            batched_rounds: None,
        }
    }

    /// Schedule a single-shot wire fault against global transfer
    /// `plan.at_seq` (tamper-injection harness — see
    /// `rust/tests/audit.rs`). Replaces any pending plan. The fault
    /// mutates the *delivered* clone only: the sender's tensor is
    /// untouched, exactly like a message corrupted in flight.
    pub fn schedule_tamper(&mut self, plan: TamperPlan) {
        self.tamper = Some(plan);
    }

    /// Whether a scheduled wire fault has not yet fired.
    pub fn tamper_pending(&self) -> bool {
        self.tamper.is_some()
    }

    fn apply_tamper(&mut self, kind: TamperKind, delivered: &mut RingTensor) {
        let flip = |t: &mut RingTensor, word: usize, bit: u32| {
            if t.len() > 0 {
                let i = word % t.len();
                t.data_mut()[i] ^= 1i64 << (bit % 64);
                true
            } else {
                false
            }
        };
        let landed = match kind {
            TamperKind::BitFlip { word, bit } => flip(delivered, word, bit),
            TamperKind::ReplayStale => match self.stale.take() {
                Some(prev) if prev.shape() == delivered.shape() && prev != *delivered => {
                    *delivered = prev;
                    true
                }
                // No usable stale message (first transfer, shape change,
                // or identical payload): degrade to a bit flip so the
                // scheduled fault still corrupts something.
                _ => flip(delivered, 0, 0),
            },
        };
        if landed {
            self.faults_applied += 1;
        }
    }

    /// Transfer a ring tensor between parties as part of the *current*
    /// round: clones the payload and charges its serialized size.
    /// Rounds are charged separately with [`NetSim::round`] so that
    /// messages sent in parallel count as one round. Returns the
    /// *delivered* clone — which a scheduled [`TamperPlan`] may have
    /// corrupted — so protocols reconstruct from what actually arrived.
    pub fn transfer(&mut self, from: PartyId, to: PartyId, t: &RingTensor, class: OpClass) -> RingTensor {
        let bytes = (t.len() as u64) * crate::fixed::ELEM_BYTES;
        self.ledger.add_bytes(class, bytes);
        self.messages += 1;
        let seq = self.transfer_seq;
        self.transfer_seq += 1;
        let mut delivered = t.clone();
        if let Some(plan) = self.tamper {
            if plan.at_seq == seq {
                self.tamper = None;
                self.apply_tamper(plan.kind, &mut delivered);
            } else if plan.kind == TamperKind::ReplayStale && plan.at_seq == seq + 1 {
                self.stale = Some(delivered.clone());
            }
        }
        if self.record_transfers {
            let payload = fnv1a_tensor(&delivered);
            let digest = transfer_digest(from, to, &delivered, seq, payload);
            self.wire_digest = fnv1a_fold(self.wire_digest, &[digest]);
            self.transfer_log.push(TransferRecord {
                from: from.index(),
                to: to.index(),
                class_idx: class.index(),
                bytes,
                digest,
                payload,
            });
        }
        if self.realtime {
            std::thread::sleep(Duration::from_secs_f64(
                (bytes as f64 * 8.0) / self.profile.bandwidth_bps,
            ));
        }
        delivered
    }

    /// Charge raw bytes without a payload (e.g. cost-model charges for the
    /// dealer-assisted comparison, scalar side-channels).
    pub fn charge_bytes(&mut self, class: OpClass, bytes: u64) {
        self.ledger.add_bytes(class, bytes);
    }

    /// Mark the completion of `n` communication rounds in `class`.
    ///
    /// Inside an open batch ([`NetSim::begin_batch`]) the charge is
    /// deferred: the batched rounds coalesce into the single round charged
    /// at [`NetSim::flush_batch`].
    pub fn round(&mut self, class: OpClass, n: u64) {
        if let Some(deferred) = self.batched_rounds.as_mut() {
            *deferred += n;
            return;
        }
        self.ledger.add_rounds(class, n);
        if self.realtime {
            std::thread::sleep(Duration::from_secs_f64(self.profile.rtt * n as f64));
        }
    }

    // ------------------------------------------------------------------
    // Deferred/batched opening rounds (DESIGN.md §Batched openings)
    // ------------------------------------------------------------------

    /// Start an open batch: subsequent [`NetSim::round`] charges are
    /// deferred until [`NetSim::flush_batch`]. Callers must only batch
    /// *independent* openings — exchanges whose payloads do not depend on
    /// another batched exchange's opened value — so that all of them can
    /// genuinely travel in one parallel round. Bytes are charged at
    /// transfer time as usual; only round accounting is deferred.
    ///
    /// Nesting is a bug: a second `begin_batch` before the flush panics.
    pub fn begin_batch(&mut self) {
        assert!(self.batched_rounds.is_none(), "open batch already active (no nesting)");
        self.batched_rounds = Some(0);
    }

    /// End the open batch: if any rounds were deferred, charge exactly one
    /// round to `class` (the concatenated flush) and return 1; flushing an
    /// empty batch charges nothing and returns 0.
    pub fn flush_batch(&mut self, class: OpClass) -> u64 {
        let deferred = self.batched_rounds.take().expect("flush_batch without begin_batch");
        if deferred == 0 {
            return 0;
        }
        self.round(class, 1);
        1
    }

    /// Whether an open batch is currently active.
    pub fn batching(&self) -> bool {
        self.batched_rounds.is_some()
    }

    /// Drop the recorded transfer census and rewind the wire-digest chain
    /// (the global sequence counter keeps counting: census digests stay
    /// unique for the simulator's whole lifetime).
    pub fn clear_transfer_log(&mut self) {
        self.transfer_log.clear();
        self.wire_digest = FNV_OFFSET;
    }

    /// Record measured local compute.
    pub fn compute(&mut self, class: OpClass, party: PartyId, secs: f64) {
        self.ledger.add_compute(class, party, secs);
    }

    /// Run `f` and attribute its wall time to `(class, party)` compute.
    pub fn timed<T>(&mut self, class: OpClass, party: PartyId, f: impl FnOnce() -> T) -> T {
        let t0 = std::time::Instant::now();
        let out = f();
        self.compute(class, party, t0.elapsed().as_secs_f64());
        out
    }

    /// Reset the ledger (keep the profile; the transfer census, if
    /// recording, is kept so multi-step sessions can be audited — see
    /// [`NetSim::clear_transfer_log`]). Any open batch is discarded: a
    /// reset marks a new protocol run, and a batch can only still be open
    /// here if the previous run errored out between begin and flush.
    pub fn reset(&mut self) {
        self.batched_rounds = None;
        self.ledger = CostLedger::new();
        self.messages = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_times() {
        let wan = NetworkProfile::wan1();
        // 1 round of 1 MB: 40ms + 8e6/200e6 s = 40ms + 40ms
        let t = wan.time_for(1, 1_000_000);
        assert!((t - 0.08).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn transfer_charges_bytes() {
        let mut net = NetSim::new(NetworkProfile::lan());
        let t = RingTensor::zeros(4, 8);
        let got = net.transfer(PartyId::P0, PartyId::P1, &t, OpClass::Softmax);
        assert_eq!(got, t);
        assert_eq!(net.ledger.class(OpClass::Softmax).bytes, 32 * 8);
        assert_eq!(net.ledger.bytes_total(), 256);
    }

    #[test]
    fn rounds_accumulate_per_class() {
        let mut net = NetSim::new(NetworkProfile::wan2());
        net.round(OpClass::Linear, 1);
        net.round(OpClass::Linear, 2);
        net.round(OpClass::Gelu, 2);
        assert_eq!(net.ledger.class(OpClass::Linear).rounds, 3);
        assert_eq!(net.ledger.rounds_total(), 5);
    }

    #[test]
    fn ledger_merge_and_time() {
        let mut a = CostLedger::new();
        a.add_bytes(OpClass::Linear, 100);
        a.add_rounds(OpClass::Linear, 1);
        let mut b = CostLedger::new();
        b.add_bytes(OpClass::Linear, 50);
        b.add_compute(OpClass::Linear, PartyId::P0, 0.25);
        b.add_compute(OpClass::Linear, PartyId::P1, 0.75);
        a.merge(&b);
        assert_eq!(a.class(OpClass::Linear).bytes, 150);
        // critical path takes the max across parties
        assert!((a.class(OpClass::Linear).compute_critical_path() - 0.75).abs() < 1e-12);
        let p = NetworkProfile::lan();
        let expect = 0.75 + p.time_for(1, 150);
        assert!((a.total_time(&p) - expect).abs() < 1e-12);
    }

    #[test]
    fn open_batch_coalesces_rounds_and_keeps_bytes() {
        let mut net = NetSim::new(NetworkProfile::lan());
        net.begin_batch();
        assert!(net.batching());
        let t = RingTensor::zeros(2, 4);
        net.transfer(PartyId::P0, PartyId::P1, &t, OpClass::Linear);
        net.round(OpClass::Linear, 1);
        net.transfer(PartyId::P1, PartyId::P0, &t, OpClass::Softmax);
        net.round(OpClass::Softmax, 1);
        // nothing charged yet
        assert_eq!(net.ledger.rounds_total(), 0);
        assert_eq!(net.flush_batch(OpClass::Linear), 1);
        assert_eq!(net.ledger.rounds_total(), 1);
        assert_eq!(net.ledger.class(OpClass::Linear).rounds, 1);
        // bytes were charged at transfer time, per class
        assert_eq!(net.ledger.class(OpClass::Linear).bytes, 64);
        assert_eq!(net.ledger.class(OpClass::Softmax).bytes, 64);
    }

    #[test]
    fn empty_batch_flush_is_noop() {
        let mut net = NetSim::new(NetworkProfile::lan());
        net.begin_batch();
        assert_eq!(net.flush_batch(OpClass::Linear), 0);
        assert_eq!(net.ledger.rounds_total(), 0);
    }

    #[test]
    #[should_panic(expected = "no nesting")]
    fn nested_batches_panic() {
        let mut net = NetSim::new(NetworkProfile::lan());
        net.begin_batch();
        net.begin_batch();
    }

    #[test]
    fn transfer_census_records_payload_digests() {
        let mut net = NetSim::new(NetworkProfile::lan());
        net.record_transfers = true;
        let a = RingTensor::from_vec(1, 2, vec![1, 2]);
        let b = RingTensor::from_vec(1, 2, vec![1, 3]);
        net.transfer(PartyId::P0, PartyId::P1, &a, OpClass::Linear);
        net.transfer(PartyId::P0, PartyId::P1, &b, OpClass::Linear);
        net.transfer(PartyId::P1, PartyId::P0, &a, OpClass::Linear);
        assert_eq!(net.transfer_log.len(), 3);
        assert_ne!(net.transfer_log[0].payload, net.transfer_log[1].payload);
        assert_eq!(net.transfer_log[0].payload, net.transfer_log[2].payload);
        // Context-hardened digests: the SAME payload moved in a different
        // direction at a different sequence number must not collide.
        assert_ne!(net.transfer_log[0].digest, net.transfer_log[2].digest);
        // the census survives a ledger reset (session-long audits)
        net.reset();
        assert_eq!(net.transfer_log.len(), 3);
        assert_eq!(net.transfer_seq, 3, "the sequence counter survives resets");
        net.clear_transfer_log();
        assert!(net.transfer_log.is_empty());
        assert_eq!(net.wire_digest, FNV_OFFSET);
    }

    /// Golden-value pin of the census digest format: `payload` is FNV-1a
    /// over the little-endian payload words; `digest` folds
    /// `[from, to, rows, cols, seq, payload]` from the FNV offset basis.
    /// An accidental format change (field order, width, byte order) fails
    /// here loudly instead of silently invalidating recorded transcripts.
    #[test]
    fn census_digest_format_is_pinned() {
        let mut net = NetSim::new(NetworkProfile::lan());
        net.record_transfers = true;
        let t = RingTensor::from_vec(2, 2, vec![1, -2, 3, -4]);
        net.transfer(PartyId::P0, PartyId::P1, &t, OpClass::Linear);
        net.transfer(PartyId::P1, PartyId::P0, &t, OpClass::Linear);
        assert_eq!(net.transfer_log[0].payload, 0x1bdaa41b3e2bf895);
        assert_eq!(net.transfer_log[0].digest, 0x56227a27a8929d4c);
        assert_eq!(net.transfer_log[1].payload, 0x1bdaa41b3e2bf895);
        assert_eq!(net.transfer_log[1].digest, 0x982f83bf6a28a471);
        // and the rolling wire chain is the fold of the two digests
        let want = fnv1a_fold(FNV_OFFSET, &[0x56227a27a8929d4c, 0x982f83bf6a28a471]);
        assert_eq!(net.wire_digest, want);
    }

    #[test]
    fn scheduled_bit_flip_corrupts_only_the_delivered_clone() {
        let mut net = NetSim::new(NetworkProfile::lan());
        let t = RingTensor::from_vec(1, 4, vec![10, 20, 30, 40]);
        // fault targets the second transfer, word 2, bit 5
        net.schedule_tamper(TamperPlan { at_seq: 1, kind: TamperKind::BitFlip { word: 2, bit: 5 } });
        let first = net.transfer(PartyId::P0, PartyId::P1, &t, OpClass::Other);
        assert_eq!(first, t, "fault must not fire early");
        assert!(net.tamper_pending());
        let second = net.transfer(PartyId::P0, PartyId::P1, &t, OpClass::Other);
        assert_eq!(net.faults_applied, 1);
        assert!(!net.tamper_pending(), "single-shot: the plan is consumed");
        assert_eq!(t.data()[2], 30, "sender copy untouched");
        assert_eq!(second.data()[2], 30 ^ (1 << 5));
        let third = net.transfer(PartyId::P0, PartyId::P1, &t, OpClass::Other);
        assert_eq!(third, t, "later transfers are clean again");
    }

    #[test]
    fn stale_replay_substitutes_the_previous_payload() {
        let mut net = NetSim::new(NetworkProfile::lan());
        let a = RingTensor::from_vec(1, 3, vec![1, 2, 3]);
        let b = RingTensor::from_vec(1, 3, vec![4, 5, 6]);
        net.schedule_tamper(TamperPlan { at_seq: 1, kind: TamperKind::ReplayStale });
        net.transfer(PartyId::P0, PartyId::P1, &a, OpClass::Other);
        let got = net.transfer(PartyId::P0, PartyId::P1, &b, OpClass::Other);
        assert_eq!(got, a, "the stale message must be delivered instead");
        assert_eq!(net.faults_applied, 1);
    }

    #[test]
    fn stale_replay_degrades_to_a_flip_without_a_usable_predecessor() {
        let mut net = NetSim::new(NetworkProfile::lan());
        let t = RingTensor::from_vec(1, 2, vec![7, 8]);
        // target the FIRST transfer: there is no predecessor to replay
        net.schedule_tamper(TamperPlan { at_seq: 0, kind: TamperKind::ReplayStale });
        let got = net.transfer(PartyId::P0, PartyId::P1, &t, OpClass::Other);
        assert_eq!(net.faults_applied, 1);
        assert_ne!(got, t, "a scheduled fault must still corrupt something");
        assert_eq!(got.data()[0], 7 ^ 1);
    }

    #[test]
    fn wan3_is_rtt_bound_for_small_payloads() {
        let p = NetworkProfile::wan3();
        // 16 rounds of 200 KB total: byte term ~1.6 ms vs 1.28 s of RTT.
        let t = p.time_for(16, 200_000);
        assert!((t - (16.0 * 0.08 + 200_000.0 * 8.0 / 1e9)).abs() < 1e-9);
        assert!(NetworkProfile::by_name("wan3").is_some());
        assert_eq!(NetworkProfile::ALL_NAMES.len(), 4);
    }

    #[test]
    fn timed_attributes_compute() {
        let mut net = NetSim::new(NetworkProfile::lan());
        let v = net.timed(OpClass::Other, PartyId::P1, || {
            std::thread::sleep(Duration::from_millis(3));
            42
        });
        assert_eq!(v, 42);
        assert!(net.ledger.class(OpClass::Other).compute[1] >= 0.002);
    }
}
