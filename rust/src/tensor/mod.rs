//! Dense row-major tensors for the protocol engine.
//!
//! Two element domains are used throughout the crate:
//! * [`RingTensor`] — `i64` elements interpreted in `Z_{2^64}` (secret
//!   shares, fixed-point encodings). All arithmetic wraps.
//! * [`FloatTensor`] — `f32` elements (plaintext weights, permuted
//!   plaintext activations at the cloud party, reference model).
//!
//! Tensors are logically 2-D (`rows × cols`); attention treats the head
//! dimension by slicing column blocks, which keeps the protocol code close
//! to the paper's matrix notation.

use std::fmt;

/// Generic dense 2-D tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor<T> {
    rows: usize,
    cols: usize,
    data: Vec<T>,
}

/// `Z_{2^64}` tensor (shares / fixed-point values).
pub type RingTensor = Tensor<i64>;
/// `f32` tensor (plaintext values).
pub type FloatTensor = Tensor<f32>;

impl<T: Copy + Default> Tensor<T> {
    /// All-default (zero) tensor.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Tensor { rows, cols, data: vec![T::default(); rows * cols] }
    }

    /// Build from a row-major vector. Panics if the length mismatches.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), rows * cols, "tensor shape {}x{} != data len {}", rows, cols, data.len());
        Tensor { rows, cols, data }
    }

    /// Build by evaluating `f(r, c)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> T) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Tensor { rows, cols, data }
    }

    /// Row count.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }
    /// Column count.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }
    /// Total number of elements.
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }
    /// Whether the tensor has zero elements.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
    /// `(rows, cols)` pair.
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Element at `(r, c)`.
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> T {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }
    /// Write element at `(r, c)`.
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: T) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Row slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[T] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }
    /// Mutable row slice.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [T] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Raw row-major data.
    #[inline]
    pub fn data(&self) -> &[T] {
        &self.data
    }
    /// Mutable raw row-major data.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }
    /// Consume into the raw buffer.
    pub fn into_data(self) -> Vec<T> {
        self.data
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Self {
        let mut out = Tensor::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on large matrices.
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    /// Copy of a contiguous column block `[c0, c1)` (used for head slicing).
    pub fn col_block(&self, c0: usize, c1: usize) -> Self {
        assert!(c0 <= c1 && c1 <= self.cols);
        let w = c1 - c0;
        let mut out = Tensor::zeros(self.rows, w);
        for r in 0..self.rows {
            out.data[r * w..(r + 1) * w].copy_from_slice(&self.data[r * self.cols + c0..r * self.cols + c1]);
        }
        out
    }

    /// Write `block` into columns `[c0, c0+block.cols)`.
    pub fn set_col_block(&mut self, c0: usize, block: &Tensor<T>) {
        assert_eq!(self.rows, block.rows);
        assert!(c0 + block.cols <= self.cols);
        for r in 0..self.rows {
            let dst = r * self.cols + c0;
            self.data[dst..dst + block.cols].copy_from_slice(block.row(r));
        }
    }

    /// Horizontal concatenation of equal-height tensors.
    pub fn concat_cols(blocks: &[Tensor<T>]) -> Self {
        assert!(!blocks.is_empty());
        let rows = blocks[0].rows;
        let cols: usize = blocks.iter().map(|b| b.cols).sum();
        let mut out = Tensor::zeros(rows, cols);
        let mut c0 = 0;
        for b in blocks {
            assert_eq!(b.rows, rows);
            out.set_col_block(c0, b);
            c0 += b.cols;
        }
        out
    }

    /// Apply `f` elementwise in place.
    pub fn map_inplace(&mut self, mut f: impl FnMut(T) -> T) {
        for v in self.data.iter_mut() {
            *v = f(*v);
        }
    }

    /// New tensor with `f` applied elementwise.
    pub fn map<U: Copy + Default>(&self, mut f: impl FnMut(T) -> U) -> Tensor<U> {
        Tensor { rows: self.rows, cols: self.cols, data: self.data.iter().map(|&v| f(v)).collect() }
    }

    /// Zip two same-shape tensors elementwise.
    pub fn zip_with(&self, other: &Tensor<T>, mut f: impl FnMut(T, T) -> T) -> Tensor<T> {
        assert_eq!(self.shape(), other.shape(), "zip_with shape mismatch");
        Tensor {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect(),
        }
    }
}

impl FloatTensor {
    /// Max |a - b| over all elements.
    pub fn max_abs_diff(&self, other: &FloatTensor) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32
    }

    /// Plaintext f32 matmul: `self (m×k) @ other (k×n)`.
    pub fn matmul(&self, other: &FloatTensor) -> FloatTensor {
        assert_eq!(self.cols, other.rows, "matmul inner dim");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let bt = other.transpose();
        let mut out = FloatTensor::zeros(m, n);
        for r in 0..m {
            let arow = self.row(r);
            for c in 0..n {
                let brow = bt.row(c);
                let mut acc = 0.0f32;
                for i in 0..k {
                    acc += arow[i] * brow[i];
                }
                out.data[r * n + c] = acc;
            }
        }
        out
    }

    /// `self (m×k) @ other^T (n×k)` — weights stored (out, in).
    pub fn matmul_nt(&self, other: &FloatTensor) -> FloatTensor {
        assert_eq!(self.cols, other.cols, "matmul_nt inner dim");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let mut out = FloatTensor::zeros(m, n);
        for r in 0..m {
            let arow = self.row(r);
            for c in 0..n {
                let brow = other.row(c);
                let mut acc = 0.0f32;
                for i in 0..k {
                    acc += arow[i] * brow[i];
                }
                out.data[r * n + c] = acc;
            }
        }
        out
    }

    /// Add a broadcast row vector.
    pub fn add_row(&self, bias: &[f32]) -> FloatTensor {
        assert_eq!(bias.len(), self.cols);
        let mut out = self.clone();
        for r in 0..out.rows {
            for (v, b) in out.row_mut(r).iter_mut().zip(bias) {
                *v += *b;
            }
        }
        out
    }
}

impl<T: fmt::Debug + Copy + Default> fmt::Debug for Tensor<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Tensor {}x{} [", self.rows, self.cols)?;
        let show_rows = self.rows.min(4);
        for r in 0..show_rows {
            let row = self.row(r);
            let shown: Vec<_> = row.iter().take(6).collect();
            writeln!(f, "  {:?}{}", shown, if self.cols > 6 { " ..." } else { "" })?;
        }
        if self.rows > show_rows {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_index() {
        let t = RingTensor::from_fn(3, 4, |r, c| (r * 10 + c) as i64);
        assert_eq!(t.shape(), (3, 4));
        assert_eq!(t.get(2, 3), 23);
        assert_eq!(t.row(1), &[10, 11, 12, 13]);
    }

    #[test]
    fn transpose_involution() {
        let t = RingTensor::from_fn(5, 7, |r, c| (r * 100 + c) as i64);
        assert_eq!(t.transpose().transpose(), t);
        assert_eq!(t.transpose().get(3, 4), t.get(4, 3));
    }

    #[test]
    fn col_blocks_roundtrip() {
        let t = RingTensor::from_fn(4, 6, |r, c| (r * 6 + c) as i64);
        let b0 = t.col_block(0, 3);
        let b1 = t.col_block(3, 6);
        assert_eq!(RingTensor::concat_cols(&[b0, b1]), t);
    }

    #[test]
    fn float_matmul_matches_manual() {
        let a = FloatTensor::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = FloatTensor::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
        // matmul_nt with transposed rhs gives the same result
        let c2 = a.matmul_nt(&b.transpose());
        assert_eq!(c.data(), c2.data());
    }

    #[test]
    fn add_row_broadcasts() {
        let a = FloatTensor::zeros(2, 3).add_row(&[1., 2., 3.]);
        assert_eq!(a.row(0), &[1., 2., 3.]);
        assert_eq!(a.row(1), &[1., 2., 3.]);
    }

    #[test]
    #[should_panic]
    fn matmul_dim_mismatch_panics() {
        let a = FloatTensor::zeros(2, 3);
        let b = FloatTensor::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
