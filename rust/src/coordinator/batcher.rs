//! Dynamic batcher: groups queued requests into batches bounded by
//! `max_batch` and a linger window, the standard serving trade-off
//! (throughput vs tail latency). Generic over the request type so it is
//! unit-testable without engines.

use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Maximum requests per batch.
    pub max_batch: usize,
    /// How long to wait for more requests once one is pending.
    pub linger: Duration,
}

/// A formed batch.
pub struct Batch<T> {
    /// Requests in arrival order.
    pub items: Vec<T>,
    /// When the first item of the batch arrived.
    pub opened: Instant,
}

/// Run the batching loop until the input channel disconnects.
pub fn run<T: Send>(rx: Receiver<T>, tx: Sender<Batch<T>>, cfg: BatcherConfig) {
    // No side route: every item is batchable.
    let (side_tx, _side_rx) = std::sync::mpsc::channel();
    run_routed(rx, tx, side_tx, |_| false, cfg);
}

/// Batching loop with a side route: items matching `is_side` bypass
/// batching and are forwarded to `side_tx` immediately (the decode
/// scheduler does its own continuous admission, so lingering generate
/// requests here would only add head-of-line latency). Everything else is
/// grouped into [`Batch`]es exactly as [`run`] does. Side-send failures
/// are ignored — dropping the request drops its embedded stream sender,
/// which the client observes as a disconnected stream.
pub fn run_routed<T: Send>(
    rx: Receiver<T>,
    tx: Sender<Batch<T>>,
    side_tx: Sender<T>,
    is_side: impl Fn(&T) -> bool,
    cfg: BatcherConfig,
) {
    loop {
        // Block for the first batchable item of the next batch.
        let first = loop {
            match rx.recv() {
                Ok(item) if is_side(&item) => {
                    let _ = side_tx.send(item);
                }
                Ok(item) => break item,
                Err(_) => return,
            }
        };
        let opened = Instant::now();
        let mut items = vec![first];
        // Fill until max_batch or linger expiry.
        while items.len() < cfg.max_batch.max(1) {
            let left = cfg.linger.saturating_sub(opened.elapsed());
            if left.is_zero() {
                break;
            }
            match rx.recv_timeout(left) {
                Ok(item) if is_side(&item) => {
                    let _ = side_tx.send(item);
                }
                Ok(item) => items.push(item),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    let _ = tx.send(Batch { items, opened });
                    return;
                }
            }
        }
        if tx.send(Batch { items, opened }).is_err() {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    #[test]
    fn batches_cap_at_max() {
        let (in_tx, in_rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::channel();
        for i in 0..10 {
            in_tx.send(i).unwrap();
        }
        drop(in_tx);
        run(in_rx, out_tx, BatcherConfig { max_batch: 4, linger: Duration::from_millis(50) });
        let sizes: Vec<usize> = out_rx.iter().map(|b: Batch<i32>| b.items.len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert!(sizes.iter().all(|&s| s <= 4));
        assert_eq!(sizes[0], 4);
    }

    #[test]
    fn linger_flushes_partial_batches() {
        let (in_tx, in_rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::channel();
        let h = std::thread::spawn(move || {
            run(in_rx, out_tx, BatcherConfig { max_batch: 100, linger: Duration::from_millis(5) })
        });
        in_tx.send(1).unwrap();
        let b = out_rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(b.items, vec![1]);
        drop(in_tx);
        let _ = h.join();
    }

    #[test]
    fn exact_max_batch_does_not_wait_for_linger() {
        // With exactly max_batch items queued, the batch must close at the
        // boundary immediately instead of sleeping out the linger window.
        let (in_tx, in_rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::channel();
        for i in 0..4 {
            in_tx.send(i).unwrap();
        }
        let t0 = std::time::Instant::now();
        let h = std::thread::spawn(move || {
            run(in_rx, out_tx, BatcherConfig { max_batch: 4, linger: Duration::from_secs(30) })
        });
        let b = out_rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(b.items, vec![0, 1, 2, 3]);
        assert!(t0.elapsed() < Duration::from_secs(5), "must not sleep out the linger");
        drop(in_tx);
        let _ = h.join();
    }

    #[test]
    fn max_batch_one_never_groups() {
        let (in_tx, in_rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::channel();
        for i in 0..5 {
            in_tx.send(i).unwrap();
        }
        drop(in_tx);
        run(in_rx, out_tx, BatcherConfig { max_batch: 1, linger: Duration::from_millis(50) });
        let sizes: Vec<usize> = out_rx.iter().map(|b: Batch<i32>| b.items.len()).collect();
        assert_eq!(sizes, vec![1; 5]);
    }

    #[test]
    fn zero_max_batch_is_clamped_to_one() {
        let (in_tx, in_rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::channel();
        in_tx.send(7).unwrap();
        drop(in_tx);
        run(in_rx, out_tx, BatcherConfig { max_batch: 0, linger: Duration::from_millis(1) });
        let b: Batch<i32> = out_rx.recv().unwrap();
        assert_eq!(b.items, vec![7]);
    }

    #[test]
    fn disconnect_mid_batch_flushes_partial_and_exits() {
        // Clients vanish while a batch is filling: the partial batch must
        // still be dispatched and the loop must terminate.
        let (in_tx, in_rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::channel();
        let h = std::thread::spawn(move || {
            run(in_rx, out_tx, BatcherConfig { max_batch: 100, linger: Duration::from_secs(30) })
        });
        for i in 0..3 {
            in_tx.send(i).unwrap();
        }
        // Give the batcher a moment to pull the items into the open batch,
        // then sever the channel mid-linger.
        std::thread::sleep(Duration::from_millis(20));
        drop(in_tx);
        let b = out_rx.recv_timeout(Duration::from_secs(2)).expect("partial batch flushed");
        assert_eq!(b.items, vec![0, 1, 2]);
        assert!(out_rx.recv().is_err(), "batcher must exit after disconnect");
        h.join().unwrap();
    }

    #[test]
    fn routed_items_bypass_batching_and_keep_order() {
        // Odd items take the side route immediately; evens batch as usual.
        let (in_tx, in_rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::channel();
        let (side_tx, side_rx) = mpsc::channel();
        for i in 0..8 {
            in_tx.send(i).unwrap();
        }
        drop(in_tx);
        run_routed(
            in_rx,
            out_tx,
            side_tx,
            |&i: &i32| i % 2 == 1,
            BatcherConfig { max_batch: 16, linger: Duration::from_millis(5) },
        );
        let side: Vec<i32> = side_rx.iter().collect();
        assert_eq!(side, vec![1, 3, 5, 7]);
        let batched: Vec<i32> = out_rx.iter().flat_map(|b: Batch<i32>| b.items).collect();
        assert_eq!(batched, vec![0, 2, 4, 6]);
    }

    #[test]
    fn routed_side_disconnect_does_not_stall_batches() {
        // The side receiver is gone; side items are dropped, batch items
        // still flow and the loop still terminates on input disconnect.
        let (in_tx, in_rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::channel();
        let (side_tx, side_rx) = mpsc::channel();
        drop(side_rx);
        for i in 0..4 {
            in_tx.send(i).unwrap();
        }
        drop(in_tx);
        run_routed(
            in_rx,
            out_tx,
            side_tx,
            |&i: &i32| i >= 2,
            BatcherConfig { max_batch: 16, linger: Duration::from_millis(5) },
        );
        let batched: Vec<i32> = out_rx.iter().flat_map(|b: Batch<i32>| b.items).collect();
        assert_eq!(batched, vec![0, 1]);
    }

    #[test]
    fn preserves_order_within_batch() {
        let (in_tx, in_rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::channel();
        for i in 0..5 {
            in_tx.send(i).unwrap();
        }
        drop(in_tx);
        run(in_rx, out_tx, BatcherConfig { max_batch: 16, linger: Duration::from_millis(1) });
        let b = out_rx.recv().unwrap();
        assert_eq!(b.items, vec![0, 1, 2, 3, 4]);
    }
}
