//! Dynamic batcher: groups queued requests into batches bounded by
//! `max_batch` and a linger window, the standard serving trade-off
//! (throughput vs tail latency). Generic over the request type so it is
//! unit-testable without engines.

use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Maximum requests per batch.
    pub max_batch: usize,
    /// How long to wait for more requests once one is pending.
    pub linger: Duration,
}

/// A formed batch.
pub struct Batch<T> {
    /// Requests in arrival order.
    pub items: Vec<T>,
    /// When the first item of the batch arrived.
    pub opened: Instant,
}

/// Run the batching loop until the input channel disconnects.
pub fn run<T: Send>(rx: Receiver<T>, tx: Sender<Batch<T>>, cfg: BatcherConfig) {
    loop {
        // Block for the first item of the next batch.
        let first = match rx.recv() {
            Ok(item) => item,
            Err(_) => return,
        };
        let opened = Instant::now();
        let mut items = vec![first];
        // Fill until max_batch or linger expiry.
        while items.len() < cfg.max_batch.max(1) {
            let left = cfg.linger.saturating_sub(opened.elapsed());
            if left.is_zero() {
                break;
            }
            match rx.recv_timeout(left) {
                Ok(item) => items.push(item),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    let _ = tx.send(Batch { items, opened });
                    return;
                }
            }
        }
        if tx.send(Batch { items, opened }).is_err() {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    #[test]
    fn batches_cap_at_max() {
        let (in_tx, in_rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::channel();
        for i in 0..10 {
            in_tx.send(i).unwrap();
        }
        drop(in_tx);
        run(in_rx, out_tx, BatcherConfig { max_batch: 4, linger: Duration::from_millis(50) });
        let sizes: Vec<usize> = out_rx.iter().map(|b: Batch<i32>| b.items.len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert!(sizes.iter().all(|&s| s <= 4));
        assert_eq!(sizes[0], 4);
    }

    #[test]
    fn linger_flushes_partial_batches() {
        let (in_tx, in_rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::channel();
        let h = std::thread::spawn(move || {
            run(in_rx, out_tx, BatcherConfig { max_batch: 100, linger: Duration::from_millis(5) })
        });
        in_tx.send(1).unwrap();
        let b = out_rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(b.items, vec![1]);
        drop(in_tx);
        let _ = h.join();
    }

    #[test]
    fn exact_max_batch_does_not_wait_for_linger() {
        // With exactly max_batch items queued, the batch must close at the
        // boundary immediately instead of sleeping out the linger window.
        let (in_tx, in_rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::channel();
        for i in 0..4 {
            in_tx.send(i).unwrap();
        }
        let t0 = std::time::Instant::now();
        let h = std::thread::spawn(move || {
            run(in_rx, out_tx, BatcherConfig { max_batch: 4, linger: Duration::from_secs(30) })
        });
        let b = out_rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(b.items, vec![0, 1, 2, 3]);
        assert!(t0.elapsed() < Duration::from_secs(5), "must not sleep out the linger");
        drop(in_tx);
        let _ = h.join();
    }

    #[test]
    fn max_batch_one_never_groups() {
        let (in_tx, in_rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::channel();
        for i in 0..5 {
            in_tx.send(i).unwrap();
        }
        drop(in_tx);
        run(in_rx, out_tx, BatcherConfig { max_batch: 1, linger: Duration::from_millis(50) });
        let sizes: Vec<usize> = out_rx.iter().map(|b: Batch<i32>| b.items.len()).collect();
        assert_eq!(sizes, vec![1; 5]);
    }

    #[test]
    fn zero_max_batch_is_clamped_to_one() {
        let (in_tx, in_rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::channel();
        in_tx.send(7).unwrap();
        drop(in_tx);
        run(in_rx, out_tx, BatcherConfig { max_batch: 0, linger: Duration::from_millis(1) });
        let b: Batch<i32> = out_rx.recv().unwrap();
        assert_eq!(b.items, vec![7]);
    }

    #[test]
    fn disconnect_mid_batch_flushes_partial_and_exits() {
        // Clients vanish while a batch is filling: the partial batch must
        // still be dispatched and the loop must terminate.
        let (in_tx, in_rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::channel();
        let h = std::thread::spawn(move || {
            run(in_rx, out_tx, BatcherConfig { max_batch: 100, linger: Duration::from_secs(30) })
        });
        for i in 0..3 {
            in_tx.send(i).unwrap();
        }
        // Give the batcher a moment to pull the items into the open batch,
        // then sever the channel mid-linger.
        std::thread::sleep(Duration::from_millis(20));
        drop(in_tx);
        let b = out_rx.recv_timeout(Duration::from_secs(2)).expect("partial batch flushed");
        assert_eq!(b.items, vec![0, 1, 2]);
        assert!(out_rx.recv().is_err(), "batcher must exit after disconnect");
        h.join().unwrap();
    }

    #[test]
    fn preserves_order_within_batch() {
        let (in_tx, in_rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::channel();
        for i in 0..5 {
            in_tx.send(i).unwrap();
        }
        drop(in_tx);
        run(in_rx, out_tx, BatcherConfig { max_batch: 16, linger: Duration::from_millis(1) });
        let b = out_rx.recv().unwrap();
        assert_eq!(b.items, vec![0, 1, 2, 3, 4]);
    }
}
