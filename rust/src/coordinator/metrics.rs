//! Serving metrics: latency quantiles, throughput, protocol totals, and
//! first-class offline-phase counters (triples/s, offline bytes/s,
//! per-shard pool depth, starvation events) when a dealer pool is active.

use std::time::{Duration, Instant};

use crate::mpc::PoolStats;

/// Accumulating metrics (guarded by a mutex in the coordinator).
pub struct Metrics {
    started: Instant,
    latencies: Vec<Duration>,
    service_times: Vec<Duration>,
    /// Requests completed.
    pub completed: u64,
    /// Batches dispatched.
    pub batches: u64,
    /// Online communication across requests.
    pub bytes_total: u64,
    /// Protocol rounds across requests.
    pub rounds_total: u64,
    /// Generation requests completed.
    pub generations: u64,
    /// Tokens produced by generation requests.
    pub tokens_generated: u64,
    /// One-time correlation-setup bytes across generation requests.
    pub corr_setup_bytes: u64,
    /// Online bytes of the cold-prefill phases (prompt absorption).
    pub prefill_bytes: u64,
    /// Online bytes of the warm-decode phases (generated tokens).
    pub decode_bytes: u64,
    /// Protocol rounds of the warm-decode phases (generated tokens) — the
    /// WAN latency driver (`rounds · RTT`).
    pub decode_rounds: u64,
    /// Batched decode steps executed by the decode scheduler.
    pub batched_decode_steps: u64,
    /// Wire rounds across batched decode steps (counted once per step —
    /// the whole batch shares each flight).
    pub batch_wire_rounds: u64,
    /// Tokens emitted through batched decode steps.
    pub batch_tokens: u64,
    /// Largest number of sessions that shared one decode step.
    pub max_batch_sessions: u64,
    /// Draft tokens proposed by speculative verify steps.
    pub spec_proposed: u64,
    /// Draft tokens (draft hits) the private greedy choices accepted.
    pub spec_accepted: u64,
    /// Deferred MAC batch checks flushed across audited engines.
    pub mac_checks: u64,
    /// Extra communication the audit layer would add (MAC-check openings;
    /// accounted here, never in the protocol ledgers).
    pub audit_overhead_bytes: u64,
    /// MAC batch checks that failed — any nonzero value means tampering
    /// (or corruption) was detected and the affected requests were failed.
    pub audit_failures: u64,
}

impl Metrics {
    /// Empty metrics, clock started now.
    pub fn new() -> Self {
        Metrics {
            started: Instant::now(),
            latencies: Vec::new(),
            service_times: Vec::new(),
            completed: 0,
            batches: 0,
            bytes_total: 0,
            rounds_total: 0,
            generations: 0,
            tokens_generated: 0,
            corr_setup_bytes: 0,
            prefill_bytes: 0,
            decode_bytes: 0,
            decode_rounds: 0,
            batched_decode_steps: 0,
            batch_wire_rounds: 0,
            batch_tokens: 0,
            max_batch_sessions: 0,
            spec_proposed: 0,
            spec_accepted: 0,
            mac_checks: 0,
            audit_overhead_bytes: 0,
            audit_failures: 0,
        }
    }

    /// Record one completed request.
    pub fn record(&mut self, latency: Duration, service: Duration, bytes: u64, rounds: u64) {
        self.latencies.push(latency);
        self.service_times.push(service);
        self.completed += 1;
        self.bytes_total += bytes;
        self.rounds_total += rounds;
    }

    /// Record one completed generation request with its correlation-setup /
    /// cold-prefill / warm-decode communication split.
    #[allow(clippy::too_many_arguments)]
    pub fn record_generate(
        &mut self,
        latency: Duration,
        service: Duration,
        tokens: u64,
        setup_bytes: u64,
        prefill_bytes: u64,
        decode_bytes: u64,
        rounds: u64,
        decode_rounds: u64,
    ) {
        self.record(latency, service, setup_bytes + prefill_bytes + decode_bytes, rounds);
        self.generations += 1;
        self.tokens_generated += tokens;
        self.corr_setup_bytes += setup_bytes;
        self.prefill_bytes += prefill_bytes;
        self.decode_bytes += decode_bytes;
        self.decode_rounds += decode_rounds;
    }

    /// Record one batched decode step: the wire rounds the whole batch
    /// shared and the number of session lanes that rode them. Amortized
    /// rounds/token falls out as `batch_wire_rounds / batch_tokens`.
    pub fn record_batch_step(&mut self, rounds: u64, lanes: u64) {
        self.record_spec_step(rounds, lanes, lanes, 0, 0);
    }

    /// Record one (possibly speculative) batched decode step: `sessions`
    /// lanes shared `rounds` wire rounds and emitted `tokens` accepted
    /// tokens, with `proposed`/`accepted` draft bookkeeping. Plain steps
    /// are the `tokens == sessions, proposed == 0` special case.
    pub fn record_spec_step(
        &mut self,
        rounds: u64,
        sessions: u64,
        tokens: u64,
        proposed: u64,
        accepted: u64,
    ) {
        self.batched_decode_steps += 1;
        self.batch_wire_rounds += rounds;
        self.batch_tokens += tokens;
        self.max_batch_sessions = self.max_batch_sessions.max(sessions);
        self.spec_proposed += proposed;
        self.spec_accepted += accepted;
    }

    /// Fold one engine's audit-counter *delta* into the serving totals
    /// (workers and the decode scheduler harvest their engines'
    /// cumulative [`crate::mpc::AuditCounters`] and report increments).
    pub fn record_audit(&mut self, delta: &crate::mpc::AuditCounters) {
        self.mac_checks += delta.mac_checks;
        self.audit_overhead_bytes += delta.overhead_bytes;
        self.audit_failures += delta.mac_failures;
    }

    /// Compute quantiles and totals so far.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut lats = self.latencies.clone();
        lats.sort_unstable();
        let q = |p: f64| -> Duration {
            if lats.is_empty() {
                Duration::ZERO
            } else {
                lats[((lats.len() as f64 - 1.0) * p) as usize]
            }
        };
        let elapsed = self.started.elapsed();
        MetricsSnapshot {
            completed: self.completed,
            batches: self.batches,
            pool_hits: 0,
            pool_misses: 0,
            pool_starved: 0,
            pool_generated: 0,
            pool_offline_bytes: 0,
            pool_pooled: 0,
            pool_shard_depths: Vec::new(),
            warm_pool_hits: 0,
            warm_pool_misses: 0,
            warm_pool_starved: 0,
            p50: q(0.50),
            p95: q(0.95),
            p99: q(0.99),
            mean_service: if self.service_times.is_empty() {
                Duration::ZERO
            } else {
                self.service_times.iter().sum::<Duration>() / self.service_times.len() as u32
            },
            throughput_rps: self.completed as f64 / elapsed.as_secs_f64().max(1e-9),
            bytes_total: self.bytes_total,
            rounds_total: self.rounds_total,
            generations: self.generations,
            tokens_generated: self.tokens_generated,
            corr_setup_bytes: self.corr_setup_bytes,
            prefill_bytes: self.prefill_bytes,
            decode_bytes: self.decode_bytes,
            decode_rounds: self.decode_rounds,
            batched_decode_steps: self.batched_decode_steps,
            batch_wire_rounds: self.batch_wire_rounds,
            batch_tokens: self.batch_tokens,
            max_batch_sessions: self.max_batch_sessions,
            spec_proposed: self.spec_proposed,
            spec_accepted: self.spec_accepted,
            mac_checks: self.mac_checks,
            audit_overhead_bytes: self.audit_overhead_bytes,
            audit_failures: self.audit_failures,
            pool_mac_rejected: 0,
            ring_kernel: crate::runtime::kernel::selected_name().to_string(),
            elapsed,
        }
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

/// Point-in-time metrics view.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Requests completed.
    pub completed: u64,
    /// Batches dispatched to workers.
    pub batches: u64,
    /// Offline-pool hits (triples served from pre-generated randomness).
    pub pool_hits: u64,
    /// Offline-pool misses (triples generated on the request path).
    pub pool_misses: u64,
    /// Offline-pool starvation events: misses on shapes the offline phase
    /// knew about — the failure mode the service exists to prevent.
    pub pool_starved: u64,
    /// Triples generated into the pool over the coordinator's lifetime
    /// (prefill + background service).
    pub pool_generated: u64,
    /// Bytes of correlated randomness generated into the pool — divide by
    /// `elapsed` for the offline-phase dealer bandwidth.
    pub pool_offline_bytes: u64,
    /// Entries currently pooled across all shapes.
    pub pool_pooled: u64,
    /// Entries currently pooled per shard slot (empty without a pool).
    pub pool_shard_depths: Vec<usize>,
    /// Pool hits after the prefill baseline (warm requests only).
    pub warm_pool_hits: u64,
    /// Pool misses after the prefill baseline (warm requests only; the
    /// shape-learning probe's cold misses are excluded).
    pub warm_pool_misses: u64,
    /// Starvation events after the prefill baseline — nonzero means a
    /// warm request waited on online-path triple generation.
    pub warm_pool_starved: u64,
    /// Median end-to-end request latency.
    pub p50: Duration,
    /// 95th-percentile end-to-end request latency.
    pub p95: Duration,
    /// 99th-percentile end-to-end request latency.
    pub p99: Duration,
    /// Mean worker service time (excludes queueing).
    pub mean_service: Duration,
    /// Completed requests per wall-clock second.
    pub throughput_rps: f64,
    /// Online communication across all requests.
    pub bytes_total: u64,
    /// Protocol rounds across all requests.
    pub rounds_total: u64,
    /// Generation requests completed.
    pub generations: u64,
    /// Tokens produced by generation requests.
    pub tokens_generated: u64,
    /// One-time correlation-setup communication across generation requests
    /// (fixed-operand mask openings; 0 with correlations disabled).
    pub corr_setup_bytes: u64,
    /// Cold-prefill communication across generation requests.
    pub prefill_bytes: u64,
    /// Warm-decode communication across generation requests.
    pub decode_bytes: u64,
    /// Warm-decode protocol rounds across generation requests.
    pub decode_rounds: u64,
    /// Batched decode steps executed by the decode scheduler.
    pub batched_decode_steps: u64,
    /// Wire rounds across batched decode steps (once per step, shared by
    /// every lane riding it).
    pub batch_wire_rounds: u64,
    /// Tokens emitted through batched decode steps.
    pub batch_tokens: u64,
    /// Largest number of sessions that shared one decode step.
    pub max_batch_sessions: u64,
    /// Draft tokens proposed by speculative verify steps.
    pub spec_proposed: u64,
    /// Draft tokens (draft hits) the private greedy choices accepted.
    pub spec_accepted: u64,
    /// Deferred MAC batch checks flushed across audited engines.
    pub mac_checks: u64,
    /// Audit-layer communication overhead (MAC-check openings; kept out of
    /// the protocol ledgers so every byte pin holds with audit on).
    pub audit_overhead_bytes: u64,
    /// Failed MAC batch checks — nonzero means tampering was detected.
    pub audit_failures: u64,
    /// Pooled triples quarantined by the pool's MAC verification at take.
    pub pool_mac_rejected: u64,
    /// Ring matmul kernel the dispatch layer selected for this process
    /// (see [`crate::runtime::kernel`]): `scalar`, `avx2`, `avx512`,
    /// `neon`, or `xla`.
    pub ring_kernel: String,
    /// Wall-clock time since the coordinator started.
    pub elapsed: Duration,
}

impl MetricsSnapshot {
    /// Record offline-pool counters from a [`PoolStats`] snapshot (called
    /// by the coordinator when a [`crate::mpc::TriplePool`] is active).
    /// `baseline` is the stats captured right after the prefill finished:
    /// subtracting it isolates the warm-serving counters from the
    /// shape-learning probe's inevitable cold misses.
    pub fn set_pool(&mut self, stats: &PoolStats, baseline: Option<&PoolStats>) {
        self.pool_hits = stats.hits;
        self.pool_misses = stats.misses;
        self.pool_starved = stats.starved;
        self.pool_generated = stats.generated;
        self.pool_offline_bytes = stats.offline_bytes;
        self.pool_pooled = stats.pooled;
        self.pool_shard_depths = stats.shard_depths.clone();
        self.pool_mac_rejected = stats.mac_rejected;
        let base = baseline.cloned().unwrap_or_default();
        self.warm_pool_hits = stats.hits.saturating_sub(base.hits);
        self.warm_pool_misses = stats.misses.saturating_sub(base.misses);
        self.warm_pool_starved = stats.starved.saturating_sub(base.starved);
    }

    /// Fraction of dealer triple requests served from the offline pool
    /// (0.0 when no pool was active or nothing was requested).
    pub fn pool_hit_rate(&self) -> f64 {
        let total = self.pool_hits + self.pool_misses;
        if total == 0 {
            0.0
        } else {
            self.pool_hits as f64 / total as f64
        }
    }

    /// Fraction of *warm* dealer triple requests (after the prefill
    /// baseline) served from the offline pool. 1.0 when no warm take
    /// happened — nothing missed; pair with a `warm_pool_hits > 0` check
    /// when asserting a load test actually exercised the pool.
    pub fn warm_pool_hit_rate(&self) -> f64 {
        let total = self.warm_pool_hits + self.warm_pool_misses;
        if total == 0 {
            1.0
        } else {
            self.warm_pool_hits as f64 / total as f64
        }
    }

    /// Offline-phase throughput: triples generated into the pool per
    /// wall-clock second since the coordinator started.
    pub fn offline_triples_per_sec(&self) -> f64 {
        self.pool_generated as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }

    /// Offline-phase dealer bandwidth: bytes of correlated randomness
    /// generated into the pool per wall-clock second.
    pub fn offline_bytes_per_sec(&self) -> f64 {
        self.pool_offline_bytes as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }

    /// Warm-decode communication per generated token (0 when no tokens
    /// were generated) — the serving-side view of the KV-cache win.
    pub fn decode_bytes_per_token(&self) -> u64 {
        if self.tokens_generated == 0 {
            0
        } else {
            self.decode_bytes / self.tokens_generated
        }
    }

    /// Warm-decode protocol rounds per generated token (0 when no tokens
    /// were generated) — the serving-side view of the round-compression
    /// win: WAN decode latency is essentially this number times the RTT.
    pub fn decode_rounds_per_token(&self) -> u64 {
        if self.tokens_generated == 0 {
            0
        } else {
            self.decode_rounds / self.tokens_generated
        }
    }

    /// Fraction of speculative draft proposals the private greedy choices
    /// accepted — the draft-hit rate (1.0 before any proposal, matching
    /// [`crate::engine::decoder::SpeculativeState::acceptance_rate`]).
    pub fn spec_acceptance_rate(&self) -> f64 {
        if self.spec_proposed == 0 {
            1.0
        } else {
            self.spec_accepted as f64 / self.spec_proposed as f64
        }
    }

    /// Amortized wire rounds per token across batched decode steps (0.0
    /// when the decode scheduler ran no batched steps) — the
    /// continuous-batching headline: B lanes sharing the solo 16-flight
    /// schedule pay 16/B rounds per token. Speculative steps count
    /// *accepted* tokens, so acceptance drives this below the solo floor
    /// even at B = 1.
    pub fn batched_rounds_per_token(&self) -> f64 {
        if self.batch_tokens == 0 {
            0.0
        } else {
            self.batch_wire_rounds as f64 / self.batch_tokens as f64
        }
    }

    /// Human-readable summary block.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "ring_kernel={} requests={} batches={} p50={} p95={} p99={} mean_service={} \
             throughput={:.2} req/s comm={} rounds={} elapsed={}",
            self.ring_kernel,
            self.completed,
            self.batches,
            crate::util::human_secs(self.p50.as_secs_f64()),
            crate::util::human_secs(self.p95.as_secs_f64()),
            crate::util::human_secs(self.p99.as_secs_f64()),
            crate::util::human_secs(self.mean_service.as_secs_f64()),
            self.throughput_rps,
            crate::util::human_bytes(self.bytes_total),
            self.rounds_total,
            crate::util::human_secs(self.elapsed.as_secs_f64()),
        );
        if self.pool_hits + self.pool_misses > 0 {
            s.push_str(&format!(
                " pool_hits={} pool_misses={} pool_hit_rate={:.1}% warm_pool_hit_rate={:.1}%",
                self.pool_hits,
                self.pool_misses,
                self.pool_hit_rate() * 100.0,
                self.warm_pool_hit_rate() * 100.0
            ));
        }
        if self.pool_generated > 0 {
            let depth_min = self.pool_shard_depths.iter().min().copied().unwrap_or(0);
            let depth_max = self.pool_shard_depths.iter().max().copied().unwrap_or(0);
            s.push_str(&format!(
                " offline_triples={} offline_triples_per_sec={:.0} offline_bytes_per_sec={}/s \
                 pool_depth={} shard_depth={}..{} starvation_events={} warm_starved={}",
                self.pool_generated,
                self.offline_triples_per_sec(),
                crate::util::human_bytes(self.offline_bytes_per_sec() as u64),
                self.pool_pooled,
                depth_min,
                depth_max,
                self.pool_starved,
                self.warm_pool_starved,
            ));
        }
        // Gate on generations (not tokens): a zero-token generation still
        // records setup/prefill bytes that must reconcile with the totals.
        if self.generations > 0 {
            s.push_str(&format!(
                " generations={} tokens={} corr_setup={} prefill_comm={} decode_comm={} \
                 decode_per_token={} decode_rounds_per_token={}",
                self.generations,
                self.tokens_generated,
                crate::util::human_bytes(self.corr_setup_bytes),
                crate::util::human_bytes(self.prefill_bytes),
                crate::util::human_bytes(self.decode_bytes),
                crate::util::human_bytes(self.decode_bytes_per_token()),
                self.decode_rounds_per_token(),
            ));
        }
        if self.batched_decode_steps > 0 {
            s.push_str(&format!(
                " batch_steps={} batch_max={} batch_rounds_per_token={:.2}",
                self.batched_decode_steps,
                self.max_batch_sessions,
                self.batched_rounds_per_token(),
            ));
        }
        if self.spec_proposed > 0 {
            s.push_str(&format!(
                " spec_proposed={} spec_accepted={} spec_accept_rate={:.1}%",
                self.spec_proposed,
                self.spec_accepted,
                self.spec_acceptance_rate() * 100.0
            ));
        }
        if self.mac_checks > 0 || self.audit_failures > 0 || self.pool_mac_rejected > 0 {
            s.push_str(&format!(
                " mac_checks={} audit_overhead={} audit_failures={} pool_mac_rejected={}",
                self.mac_checks,
                crate::util::human_bytes(self.audit_overhead_bytes),
                self.audit_failures,
                self.pool_mac_rejected,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reports_ring_kernel() {
        let s = Metrics::new().snapshot();
        assert!(
            crate::runtime::kernel::KERNEL_NAMES.contains(&s.ring_kernel.as_str()),
            "unexpected kernel name {:?}",
            s.ring_kernel
        );
        assert!(s.summary().contains(&format!("ring_kernel={}", s.ring_kernel)));
    }

    #[test]
    fn quantiles_ordered() {
        let mut m = Metrics::new();
        for i in 1..=100u64 {
            m.record(Duration::from_millis(i), Duration::from_millis(i / 2), 10, 1);
        }
        let s = m.snapshot();
        assert_eq!(s.completed, 100);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99);
        assert_eq!(s.bytes_total, 1000);
        assert!(s.throughput_rps > 0.0);
    }

    #[test]
    fn empty_snapshot_is_zeroed() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.p99, Duration::ZERO);
        assert_eq!(s.tokens_generated, 0);
        assert_eq!(s.decode_bytes_per_token(), 0);
        assert_eq!((s.pool_starved, s.pool_generated, s.pool_offline_bytes), (0, 0, 0));
        assert!(s.pool_shard_depths.is_empty());
        assert_eq!((s.warm_pool_hits, s.warm_pool_misses, s.warm_pool_starved), (0, 0, 0));
        // No warm take happened → nothing missed.
        assert_eq!(s.warm_pool_hit_rate(), 1.0);
        assert!(!s.summary().contains("decode_per_token"));
        assert!(!s.summary().contains("pool_hit_rate"));
        assert!(!s.summary().contains("offline_triples"));
    }

    #[test]
    fn pool_stats_feed_offline_serving_counters() {
        let mut m = Metrics::new();
        m.record(Duration::from_millis(5), Duration::from_millis(4), 10, 1);
        let mut s = m.snapshot();
        // Prefill baseline: the shape-learning probe's 3 cold misses plus
        // the synchronous fill; then a serving window of 41 warm takes.
        let baseline = PoolStats {
            hits: 0,
            misses: 3,
            starved: 0,
            generated: 12,
            offline_bytes: 1 << 20,
            pooled: 12,
            shapes: 3,
            shard_depths: vec![2; 8],
            mac_rejected: 0,
        };
        let now = PoolStats {
            hits: 40,
            misses: 4,
            starved: 1,
            generated: 52,
            offline_bytes: 5 << 20,
            pooled: 12,
            shapes: 3,
            shard_depths: vec![1, 2, 2, 2, 1, 2, 2, 0],
            mac_rejected: 0,
        };
        s.set_pool(&now, Some(&baseline));
        assert_eq!((s.pool_hits, s.pool_misses, s.pool_starved), (40, 4, 1));
        assert_eq!((s.warm_pool_hits, s.warm_pool_misses, s.warm_pool_starved), (40, 1, 1));
        assert!((s.warm_pool_hit_rate() - 40.0 / 41.0).abs() < 1e-9);
        assert_eq!(s.pool_generated, 52);
        assert_eq!(s.pool_offline_bytes, 5 << 20);
        assert!(s.offline_triples_per_sec() > 0.0);
        assert!(s.offline_bytes_per_sec() > 0.0);
        assert_eq!(s.pool_shard_depths.len(), 8);
        let sum = s.summary();
        assert!(sum.contains("pool_hit_rate"));
        assert!(sum.contains("offline_triples_per_sec"));
        assert!(sum.contains("starvation_events=1"));
        assert!(sum.contains("shard_depth=0..2"));
        // Without a baseline, warm counters equal the raw totals.
        let mut raw = m.snapshot();
        raw.set_pool(&now, None);
        assert_eq!((raw.warm_pool_hits, raw.warm_pool_misses), (40, 4));
    }

    #[test]
    fn generation_split_is_tracked() {
        let mut m = Metrics::new();
        m.record_generate(
            Duration::from_millis(10),
            Duration::from_millis(8),
            4,
            500,
            1000,
            2000,
            40,
            32,
        );
        let s = m.snapshot();
        assert_eq!(s.completed, 1);
        assert_eq!(s.generations, 1);
        assert_eq!(s.tokens_generated, 4);
        assert_eq!(s.bytes_total, 3500);
        assert_eq!((s.corr_setup_bytes, s.prefill_bytes, s.decode_bytes), (500, 1000, 2000));
        assert_eq!(s.decode_bytes_per_token(), 500);
        assert_eq!(s.decode_rounds, 32);
        assert_eq!(s.decode_rounds_per_token(), 8);
        assert!(s.summary().contains("decode_per_token"));
        assert!(s.summary().contains("decode_rounds_per_token=8"));
        assert!(s.summary().contains("corr_setup"));
    }

    #[test]
    fn batch_counters_amortize_rounds_over_lanes() {
        let mut m = Metrics::new();
        // Three batched steps at widths 1, 4, 4: 48 wire rounds, 9 tokens.
        m.record_batch_step(16, 1);
        m.record_batch_step(16, 4);
        m.record_batch_step(16, 4);
        let s = m.snapshot();
        assert_eq!(s.batched_decode_steps, 3);
        assert_eq!(s.batch_wire_rounds, 48);
        assert_eq!(s.batch_tokens, 9);
        assert_eq!(s.max_batch_sessions, 4);
        assert!((s.batched_rounds_per_token() - 48.0 / 9.0).abs() < 1e-9);
        assert!(s.summary().contains("batch_max=4"));
        // No batched steps → the summary block stays out entirely.
        assert!(!Metrics::new().snapshot().summary().contains("batch_steps"));
    }

    #[test]
    fn audit_deltas_accumulate_and_print() {
        let mut m = Metrics::new();
        m.record_audit(&crate::mpc::AuditCounters {
            mac_checks: 3,
            mac_failures: 0,
            overhead_bytes: 96,
            overhead_rounds: 6,
            openings: 9,
            share_faults_applied: 0,
        });
        m.record_audit(&crate::mpc::AuditCounters {
            mac_checks: 1,
            mac_failures: 1,
            overhead_bytes: 32,
            overhead_rounds: 2,
            openings: 2,
            share_faults_applied: 1,
        });
        let s = m.snapshot();
        assert_eq!((s.mac_checks, s.audit_overhead_bytes, s.audit_failures), (4, 128, 1));
        assert!(s.summary().contains("mac_checks=4"));
        assert!(s.summary().contains("audit_failures=1"));
        // Audit off → the block stays out of the summary entirely.
        assert!(!Metrics::new().snapshot().summary().contains("mac_checks"));
    }

    #[test]
    fn speculative_steps_count_accepted_tokens() {
        let mut m = Metrics::new();
        // Two solo verify steps at k=4, 16 rounds each: 4 then 2 accepted.
        m.record_spec_step(16, 1, 4, 3, 3);
        m.record_spec_step(16, 1, 2, 3, 1);
        let s = m.snapshot();
        assert_eq!(s.batched_decode_steps, 2);
        assert_eq!(s.batch_tokens, 6);
        assert_eq!(s.max_batch_sessions, 1);
        assert_eq!((s.spec_proposed, s.spec_accepted), (6, 4));
        assert!((s.spec_acceptance_rate() - 4.0 / 6.0).abs() < 1e-9);
        // Amortized rounds per *accepted* token dips below the 16 floor.
        assert!((s.batched_rounds_per_token() - 32.0 / 6.0).abs() < 1e-9);
        assert!(s.summary().contains("spec_accept_rate"));
        // Plain batched runs never print the speculative block.
        let mut p = Metrics::new();
        p.record_batch_step(16, 4);
        assert!(!p.snapshot().summary().contains("spec_proposed"));
        assert_eq!(p.snapshot().spec_acceptance_rate(), 1.0);
    }
}
