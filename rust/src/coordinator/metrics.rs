//! Serving metrics: latency quantiles, throughput, protocol totals.

use std::time::{Duration, Instant};

/// Accumulating metrics (guarded by a mutex in the coordinator).
pub struct Metrics {
    started: Instant,
    latencies: Vec<Duration>,
    service_times: Vec<Duration>,
    pub completed: u64,
    pub batches: u64,
    pub bytes_total: u64,
    pub rounds_total: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            started: Instant::now(),
            latencies: Vec::new(),
            service_times: Vec::new(),
            completed: 0,
            batches: 0,
            bytes_total: 0,
            rounds_total: 0,
        }
    }

    pub fn record(&mut self, latency: Duration, service: Duration, bytes: u64, rounds: u64) {
        self.latencies.push(latency);
        self.service_times.push(service);
        self.completed += 1;
        self.bytes_total += bytes;
        self.rounds_total += rounds;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut lats = self.latencies.clone();
        lats.sort_unstable();
        let q = |p: f64| -> Duration {
            if lats.is_empty() {
                Duration::ZERO
            } else {
                lats[((lats.len() as f64 - 1.0) * p) as usize]
            }
        };
        let elapsed = self.started.elapsed();
        MetricsSnapshot {
            completed: self.completed,
            batches: self.batches,
            p50: q(0.50),
            p95: q(0.95),
            p99: q(0.99),
            mean_service: if self.service_times.is_empty() {
                Duration::ZERO
            } else {
                self.service_times.iter().sum::<Duration>() / self.service_times.len() as u32
            },
            throughput_rps: self.completed as f64 / elapsed.as_secs_f64().max(1e-9),
            bytes_total: self.bytes_total,
            rounds_total: self.rounds_total,
            elapsed,
        }
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

/// Point-in-time metrics view.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub completed: u64,
    pub batches: u64,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub mean_service: Duration,
    pub throughput_rps: f64,
    pub bytes_total: u64,
    pub rounds_total: u64,
    pub elapsed: Duration,
}

impl MetricsSnapshot {
    /// Human-readable summary block.
    pub fn summary(&self) -> String {
        format!(
            "requests={} batches={} p50={} p95={} p99={} mean_service={} \
             throughput={:.2} req/s comm={} rounds={} elapsed={}",
            self.completed,
            self.batches,
            crate::util::human_secs(self.p50.as_secs_f64()),
            crate::util::human_secs(self.p95.as_secs_f64()),
            crate::util::human_secs(self.p99.as_secs_f64()),
            crate::util::human_secs(self.mean_service.as_secs_f64()),
            self.throughput_rps,
            crate::util::human_bytes(self.bytes_total),
            self.rounds_total,
            crate::util::human_secs(self.elapsed.as_secs_f64()),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_ordered() {
        let mut m = Metrics::new();
        for i in 1..=100u64 {
            m.record(Duration::from_millis(i), Duration::from_millis(i / 2), 10, 1);
        }
        let s = m.snapshot();
        assert_eq!(s.completed, 100);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99);
        assert_eq!(s.bytes_total, 1000);
        assert!(s.throughput_rps > 0.0);
    }

    #[test]
    fn empty_snapshot_is_zeroed() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.p99, Duration::ZERO);
    }
}
