//! L3 serving coordinator: request router, dynamic batcher, worker pool,
//! and metrics — the deployment layer a cloud platform would run Centaur
//! behind (vLLM-router-style, adapted to three-party PPTI sessions).
//!
//! Threading model (`std::thread` + channels; DESIGN.md substitutions):
//!
//! ```text
//!  clients ──submit──▶ batcher ──Batch──▶ router ──▶ worker 0 (engine)
//!                       (linger/max)        └──────▶ worker 1 (engine)
//! ```
//!
//! Each worker owns a full protocol engine (PJRT clients are not `Send`,
//! so engines are constructed *inside* the worker thread from a spec).

mod batcher;
mod metrics;

pub use batcher::{Batch, BatcherConfig};
pub use metrics::{Metrics, MetricsSnapshot};

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::baselines::{permonly::PermOnlyEngine, smpc::SmpcEngine, FrameworkKind, PptiFramework};
use crate::engine::{CentaurEngine, EngineOptions};
use crate::model::{ModelConfig, ModelWeights};
use crate::net::NetworkProfile;
use crate::runtime::{backend_by_name, NativeBackend};
use crate::Result;

/// Serving configuration.
#[derive(Clone)]
pub struct ServerConfig {
    pub cfg: ModelConfig,
    pub weights: ModelWeights,
    pub framework: FrameworkKind,
    /// `"native"` or `"xla"` (Centaur only).
    pub backend: String,
    pub artifacts_dir: String,
    pub profile: NetworkProfile,
    pub workers: usize,
    pub max_batch: usize,
    pub linger: Duration,
    pub fast_sim: bool,
    pub seed: u64,
}

impl ServerConfig {
    pub fn new(cfg: ModelConfig, weights: ModelWeights) -> Self {
        ServerConfig {
            cfg,
            weights,
            framework: FrameworkKind::Centaur,
            backend: "native".into(),
            artifacts_dir: crate::data::artifacts_dir(),
            profile: NetworkProfile::lan(),
            workers: 1,
            max_batch: 8,
            linger: Duration::from_millis(2),
            fast_sim: false,
            seed: 11,
        }
    }
}

/// One inference response.
#[derive(Clone, Debug)]
pub struct Response {
    /// Flattened logits with shape.
    pub logits: Vec<f32>,
    pub rows: usize,
    pub cols: usize,
    /// End-to-end latency (queue + protocol), wall clock.
    pub latency: Duration,
    /// Simulated-network portion of the protocol time.
    pub simulated_net: f64,
    pub bytes: u64,
    pub rounds: u64,
}

struct Request {
    tokens: Vec<u32>,
    enqueued: Instant,
    respond: mpsc::Sender<Result<Response>>,
}

/// Build the framework engine inside a worker thread.
fn build_engine(cfg: &ServerConfig) -> Result<Box<dyn PptiFramework>> {
    match cfg.framework {
        FrameworkKind::Centaur => {
            let backend = if cfg.backend == "native" {
                Box::new(NativeBackend::new()) as Box<dyn crate::runtime::Backend>
            } else {
                backend_by_name(&cfg.backend, &cfg.cfg.name, &cfg.artifacts_dir)?
            };
            let eng = CentaurEngine::with_backend(
                &cfg.cfg,
                &cfg.weights,
                backend,
                EngineOptions {
                    profile: cfg.profile,
                    seed: cfg.seed,
                    record_views: false,
                    fast_sim: cfg.fast_sim,
                },
            )?;
            Ok(Box::new(eng))
        }
        FrameworkKind::PermOnly => {
            Ok(Box::new(PermOnlyEngine::new(&cfg.cfg, &cfg.weights, cfg.profile, false)))
        }
        smpc => Ok(Box::new(SmpcEngine::new(smpc, &cfg.cfg, &cfg.weights, cfg.profile, cfg.seed)?)),
    }
}

/// The running coordinator.
pub struct Coordinator {
    submit_tx: mpsc::Sender<Request>,
    metrics: Arc<Mutex<Metrics>>,
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Coordinator {
    /// Start the batcher and worker threads.
    pub fn start(config: ServerConfig) -> Result<Self> {
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (submit_tx, submit_rx) = mpsc::channel::<Request>();

        // Workers: one engine each, fed by a shared work queue guarded by a
        // mutex-wrapped receiver (simple m:n fan-out).
        let (work_tx, work_rx) = mpsc::channel::<Batch<Request>>();
        let work_rx = Arc::new(Mutex::new(work_rx));
        let mut workers = Vec::new();
        for wid in 0..config.workers.max(1) {
            let cfg = config.clone();
            let rx = Arc::clone(&work_rx);
            let m = Arc::clone(&metrics);
            workers.push(std::thread::spawn(move || {
                let mut engine = match build_engine(&cfg) {
                    Ok(e) => e,
                    Err(e) => {
                        eprintln!("worker {wid}: engine init failed: {e}");
                        return;
                    }
                };
                loop {
                    let batch = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    let Ok(batch) = batch else { break };
                    m.lock().unwrap().batches += 1;
                    for req in batch.items {
                        let t0 = Instant::now();
                        let outcome = engine.infer(&req.tokens);
                        let latency = req.enqueued.elapsed();
                        let resp = outcome.map(|out| {
                            let sim = out.stats.total_time(&cfg.profile) - out.stats.compute_total();
                            Response {
                                rows: out.logits.rows(),
                                cols: out.logits.cols(),
                                logits: out.logits.data().to_vec(),
                                latency,
                                simulated_net: sim,
                                bytes: out.stats.bytes_total(),
                                rounds: out.stats.rounds_total(),
                            }
                        });
                        if let Ok(r) = &resp {
                            m.lock().unwrap().record(latency, t0.elapsed(), r.bytes, r.rounds);
                        }
                        let _ = req.respond.send(resp);
                    }
                }
            }));
        }

        // Batcher thread.
        let bconf = BatcherConfig { max_batch: config.max_batch, linger: config.linger };
        let batcher = std::thread::spawn(move || {
            batcher::run(submit_rx, work_tx, bconf);
        });

        Ok(Coordinator { submit_tx, metrics, batcher: Some(batcher), workers })
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, tokens: Vec<u32>) -> mpsc::Receiver<Result<Response>> {
        let (tx, rx) = mpsc::channel();
        let req = Request { tokens, enqueued: Instant::now(), respond: tx };
        // If the batcher is gone the receiver will simply report disconnect.
        let _ = self.submit_tx.send(req);
        rx
    }

    /// Convenience: submit and wait.
    pub fn infer_blocking(&self, tokens: Vec<u32>) -> Result<Response> {
        self.submit(tokens)
            .recv()
            .map_err(|_| anyhow::anyhow!("coordinator shut down"))?
    }

    /// Snapshot of metrics so far.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.lock().unwrap().snapshot()
    }

    /// Graceful shutdown: stop accepting, drain workers, return metrics.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        drop(self.submit_tx);
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        let snap = self.metrics.lock().unwrap().snapshot();
        snap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn tiny_config(framework: FrameworkKind) -> ServerConfig {
        let cfg = ModelConfig::bert_tiny();
        let weights = ModelWeights::random(&cfg, 101);
        let mut sc = ServerConfig::new(cfg, weights);
        sc.framework = framework;
        sc.max_batch = 4;
        sc.linger = Duration::from_millis(1);
        sc
    }

    #[test]
    fn serve_roundtrip_centaur() {
        let sc = tiny_config(FrameworkKind::Centaur);
        let n_ctx = sc.cfg.n_ctx;
        let coord = Coordinator::start(sc).unwrap();
        let resp = coord.infer_blocking(vec![5; n_ctx]).unwrap();
        assert_eq!((resp.rows, resp.cols), (1, 2));
        assert!(resp.bytes > 0);
        let snap = coord.shutdown();
        assert_eq!(snap.completed, 1);
        assert!(snap.p50 > Duration::ZERO);
    }

    #[test]
    fn batching_groups_requests() {
        let mut sc = tiny_config(FrameworkKind::Centaur);
        sc.linger = Duration::from_millis(30);
        sc.max_batch = 8;
        let n_ctx = sc.cfg.n_ctx;
        let coord = Coordinator::start(sc).unwrap();
        let rxs: Vec<_> = (0..6).map(|_| coord.submit(vec![7; n_ctx])).collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
        let snap = coord.shutdown();
        assert_eq!(snap.completed, 6);
        // 6 requests within one linger window → far fewer batches
        assert!(snap.batches <= 3, "batches={}", snap.batches);
    }

    #[test]
    fn serve_permonly_framework() {
        let sc = tiny_config(FrameworkKind::PermOnly);
        let n_ctx = sc.cfg.n_ctx;
        let coord = Coordinator::start(sc).unwrap();
        let resp = coord.infer_blocking(vec![9; n_ctx]).unwrap();
        assert!(resp.bytes < 100_000); // near-plaintext
        coord.shutdown();
    }

    #[test]
    fn bad_input_is_reported_not_fatal() {
        let sc = tiny_config(FrameworkKind::Centaur);
        let coord = Coordinator::start(sc).unwrap();
        let err = coord.infer_blocking(vec![5; 3]); // wrong length
        assert!(err.is_err());
        // server still alive
        let ok = coord.infer_blocking(vec![5; 32]);
        assert!(ok.is_ok());
        coord.shutdown();
    }
}
