//! L3 serving coordinator: request router, dynamic batcher, worker pool,
//! and metrics — the deployment layer a cloud platform would run Centaur
//! behind (vLLM-router-style, adapted to three-party PPTI sessions).
//!
//! Threading model (`std::thread` + channels; DESIGN.md substitutions):
//!
//! ```text
//!  clients ──submit──▶ batcher ──Batch──▶ router ──▶ worker 0 (engine)
//!                       (linger/max)        └──────▶ worker 1 (engine)
//! ```
//!
//! Each worker owns a full protocol engine (PJRT clients are not `Send`,
//! so engines are constructed *inside* the worker thread from a spec).

mod batcher;
mod metrics;

pub use batcher::{Batch, BatcherConfig};
pub use metrics::{Metrics, MetricsSnapshot};

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::baselines::{permonly::PermOnlyEngine, smpc::SmpcEngine, FrameworkKind, PptiFramework};
use crate::engine::decoder::DecodeBatch;
use crate::engine::{CentaurEngine, EngineOptions};
use crate::model::{ModelConfig, ModelKind, ModelWeights};
use crate::mpc::{PoolService, PoolStats, TriplePool, TripleShape};
use crate::net::NetworkProfile;
use crate::runtime::{backend_by_name, NativeBackend};
use crate::Result;

/// Serving configuration.
#[derive(Clone)]
pub struct ServerConfig {
    /// Model architecture served.
    pub cfg: ModelConfig,
    /// Model weights served.
    pub weights: ModelWeights,
    /// Which PPTI framework executes requests.
    pub framework: FrameworkKind,
    /// `"native"` or `"xla"` (Centaur only).
    pub backend: String,
    /// Directory holding AOT artifacts / task data.
    pub artifacts_dir: String,
    /// Simulated network conditions.
    pub profile: NetworkProfile,
    /// Worker threads (one engine each).
    pub workers: usize,
    /// Dynamic-batcher capacity bound.
    pub max_batch: usize,
    /// Dynamic-batcher linger window.
    pub linger: Duration,
    /// Charged-ideal share×share products (paper-scale efficiency runs).
    pub fast_sim: bool,
    /// Base seed for the per-worker engines.
    pub seed: u64,
    /// Run the dealer's offline phase at server start: a [`TriplePool`]
    /// shared across workers is primed with the request's Beaver-triple
    /// shape profile and kept topped up by a background thread, so warm
    /// requests skip triple generation (Centaur framework only).
    pub offline_prefill: bool,
    /// Requests' worth of triples to keep pooled per shape.
    pub pool_depth: usize,
    /// With `offline_prefill` on a decoder model: also provision this many
    /// single-token absorbs' worth of incremental-decode triple shapes per
    /// request (prompt + generated tokens), so the streaming generate path
    /// is warm from the first request. 0 disables decode provisioning.
    pub decode_prefill_steps: usize,
    /// Fixed-operand correlated triples for decode sessions (on by
    /// default): session-fixed operands ride one mask per session instead
    /// of a fresh Beaver triple per step, cutting warm-step decode
    /// communication ~2.5× (DESIGN.md §Fixed-operand correlations).
    pub decode_correlations: bool,
    /// Batched-opening decode schedule (on by default): each decode
    /// step's independent openings share flights, cutting warm-step
    /// rounds/token ~47% with identical bytes (DESIGN.md §Batched
    /// openings) — the WAN serving latency lever.
    pub round_batching: bool,
    /// Concurrent decode sessions the offline prefill provisions for:
    /// each decode shape's demand is multiplied by this, so B
    /// simultaneously admitted sessions find their correlation bundles
    /// and per-step triples stocked (shape keys are shared across
    /// sessions; only multiplicities scale — see
    /// [`crate::protocols::layer::decode_pool_shapes_batched`]).
    pub decode_prefill_sessions: usize,
    /// Speculative decode width (DESIGN.md §Speculative decode): when
    /// > 1, the decode scheduler drives [`DecodeBatch::step_spec`] with a
    /// public tiny-model draft built from the serving weights — up to
    /// `spec_k` tokens verified per flight chain, output token-identical
    /// to plain greedy. 1 (the default) keeps the plain one-token step.
    pub spec_k: usize,
    /// Offline-service worker threads keeping the triple pool topped up
    /// (with `offline_prefill`): shards are owned round-robin, so extra
    /// workers regenerate depleted shards concurrently under load.
    pub offline_workers: usize,
    /// Integrity-checked serving (DESIGN.md §Integrity-checked inference):
    /// every engine runs with SPDZ-style share MACs and replayable
    /// transcript digests, the offline pool authenticates its stock, and
    /// the snapshot reports `mac_checks` / `audit_failures`. Defaults to
    /// the `CENTAUR_AUDIT` environment toggle.
    pub audit: bool,
    /// Tamper-injection smoke (needs `audit`): the decode scheduler arms
    /// one share fault on its engine, so the first generate request must
    /// fail its MAC batch check — `audit_failures > 0` proves the
    /// detection path end to end. Never set in real serving.
    pub audit_tamper: bool,
}

impl ServerConfig {
    /// Defaults: Centaur framework, native backend, 1 worker, batch ≤ 8,
    /// no offline prefill.
    pub fn new(cfg: ModelConfig, weights: ModelWeights) -> Self {
        ServerConfig {
            cfg,
            weights,
            framework: FrameworkKind::Centaur,
            backend: "native".into(),
            artifacts_dir: crate::data::artifacts_dir(),
            profile: NetworkProfile::lan(),
            workers: 1,
            max_batch: 8,
            linger: Duration::from_millis(2),
            fast_sim: false,
            seed: 11,
            offline_prefill: false,
            pool_depth: 2,
            decode_prefill_steps: 0,
            decode_correlations: true,
            round_batching: true,
            decode_prefill_sessions: 1,
            spec_k: 1,
            offline_workers: 2,
            audit: crate::engine::audit_env_default(),
            audit_tamper: false,
        }
    }
}

/// One inference response.
#[derive(Clone, Debug)]
pub struct Response {
    /// Flattened logits with shape.
    pub logits: Vec<f32>,
    /// Logit row count.
    pub rows: usize,
    /// Logit column count.
    pub cols: usize,
    /// End-to-end latency (queue + protocol), wall clock.
    pub latency: Duration,
    /// Simulated-network portion of the protocol time.
    pub simulated_net: f64,
    /// Online communication of this inference.
    pub bytes: u64,
    /// Protocol rounds of this inference.
    pub rounds: u64,
}

/// One event on a streaming generation response channel.
#[derive(Clone, Debug)]
pub enum StreamEvent {
    /// One generated token, with that step's online cost.
    Token {
        /// 0-based index within the generated continuation.
        index: usize,
        /// The generated token id.
        token: u32,
        /// Online bytes of this decode step.
        step_bytes: u64,
        /// Protocol rounds of this decode step.
        step_rounds: u64,
    },
    /// Generation finished.
    Done(GenSummary),
}

/// Final summary of one streamed generation request.
#[derive(Clone, Debug)]
pub struct GenSummary {
    /// Generated continuation (prompt excluded).
    pub tokens: Vec<u32>,
    /// One-time correlation-setup online bytes (fixed-operand mask
    /// openings; 0 when correlations are disabled).
    pub setup_bytes: u64,
    /// Cold-prefill online bytes (prompt absorption).
    pub prefill_bytes: u64,
    /// Warm-decode online bytes (generated tokens).
    pub decode_bytes: u64,
    /// Total protocol rounds (setup + prefill + decode).
    pub rounds: u64,
    /// Warm-decode protocol rounds (generated tokens only) — divide by
    /// `tokens.len()` for the rounds/token the WAN latency model charges.
    pub decode_rounds: u64,
    /// Core transcript digest of the request's replayable audit
    /// transcript (batched sessions report the batch-level digest; always
    /// populated — the transcript is recorded audit on or off).
    pub transcript_digest: u64,
    /// End-to-end latency (queue + protocol), wall clock.
    pub latency: Duration,
}

enum Request {
    Infer {
        tokens: Vec<u32>,
        enqueued: Instant,
        respond: mpsc::Sender<Result<Response>>,
    },
    Generate {
        prompt: Vec<u32>,
        steps: usize,
        enqueued: Instant,
        stream: mpsc::Sender<Result<StreamEvent>>,
    },
}

/// Build a concrete Centaur engine for a serving thread (workers use it
/// boxed behind [`PptiFramework`]; the decode scheduler needs the
/// concrete type to drive a [`DecodeBatch`]).
fn build_centaur_engine(cfg: &ServerConfig, pool: Option<Arc<TriplePool>>) -> Result<CentaurEngine> {
    let backend = if cfg.backend == "native" {
        Box::new(NativeBackend::new()) as Box<dyn crate::runtime::Backend>
    } else {
        backend_by_name(&cfg.backend, &cfg.cfg.name, &cfg.artifacts_dir)?
    };
    CentaurEngine::with_backend(
        &cfg.cfg,
        &cfg.weights,
        backend,
        EngineOptions {
            profile: cfg.profile,
            seed: cfg.seed,
            record_views: false,
            fast_sim: cfg.fast_sim,
            triple_pool: pool,
            decode_correlations: cfg.decode_correlations,
            round_batching: cfg.round_batching,
            audit: cfg.audit,
            ..Default::default()
        },
    )
}

/// Build the framework engine inside a worker thread.
fn build_engine(cfg: &ServerConfig, pool: Option<Arc<TriplePool>>) -> Result<Box<dyn PptiFramework>> {
    match cfg.framework {
        FrameworkKind::Centaur => Ok(Box::new(build_centaur_engine(cfg, pool)?)),
        FrameworkKind::PermOnly => {
            Ok(Box::new(PermOnlyEngine::new(&cfg.cfg, &cfg.weights, cfg.profile, false)))
        }
        smpc => Ok(Box::new(SmpcEngine::new(smpc, &cfg.cfg, &cfg.weights, cfg.profile, cfg.seed)?)),
    }
}

/// Per-session bookkeeping the decode scheduler keeps alongside the
/// [`DecodeBatch`] lane state.
struct SchedLane {
    stream: mpsc::Sender<Result<StreamEvent>>,
    enqueued: Instant,
    admitted: Instant,
    /// Cleared when a stream send fails (client dropped the receiver) —
    /// the session is evicted at the next step boundary instead of
    /// burning shared-flight work nobody reads.
    connected: bool,
}

/// Return the pool demand an early-evicted session will never consume:
/// `steps_unconsumed` decode steps' worth of per-step triples, times the
/// configured speculative width (verify lanes consume per-step triples
/// lane-by-lane, and provisioning scaled them the same way). The
/// session's correlation bundles are NOT released — those were dealt at
/// admission, so their demand is genuinely spent.
fn release_unconsumed_demand(pool: Option<&TriplePool>, cfg: &ServerConfig, steps_unconsumed: u64) {
    let Some(pool) = pool else { return };
    if steps_unconsumed == 0 {
        return;
    }
    let lanes = cfg.spec_k.max(1) as u64;
    let mc = &cfg.cfg;
    if cfg.decode_correlations {
        let count = mc.layers as u64 * mc.h as u64 * steps_unconsumed * lanes;
        pool.release_demand(TripleShape::matmul(1, mc.n_ctx, mc.dh()), count);
    } else {
        for (shape, count) in crate::protocols::layer::decode_step_shapes(mc) {
            pool.release_demand(shape, count * steps_unconsumed * lanes);
        }
    }
}

/// Fold an audited engine's cumulative counters into the serving metrics
/// as a delta against what this thread last reported (`seen`). No-op with
/// audit off (`now` is `None`).
fn harvest_audit(
    metrics: &Mutex<Metrics>,
    seen: &mut crate::mpc::AuditCounters,
    now: Option<crate::mpc::AuditCounters>,
) {
    let Some(now) = now else { return };
    let delta = crate::mpc::AuditCounters {
        mac_checks: now.mac_checks - seen.mac_checks,
        mac_failures: now.mac_failures - seen.mac_failures,
        overhead_bytes: now.overhead_bytes - seen.overhead_bytes,
        overhead_rounds: now.overhead_rounds - seen.overhead_rounds,
        openings: now.openings - seen.openings,
        share_faults_applied: now.share_faults_applied - seen.share_faults_applied,
    };
    *seen = now;
    if delta != crate::mpc::AuditCounters::default() {
        metrics.lock().unwrap().record_audit(&delta);
    }
}

/// Finalize one scheduler session: harvest its summary from the batch,
/// record metrics, send `Done` when the client is still listening, and
/// release phantom pool demand when it is not.
fn finalize_session(
    batch: &mut DecodeBatch<'_>,
    lanes: &mut std::collections::HashMap<usize, SchedLane>,
    metrics: &Mutex<Metrics>,
    pool: Option<&TriplePool>,
    cfg: &ServerConfig,
    id: usize,
) {
    let Some(sum) = batch.remove(id) else { return };
    let Some(lane) = lanes.remove(&id) else { return };
    let latency = lane.enqueued.elapsed();
    metrics.lock().unwrap().record_generate(
        latency,
        lane.admitted.elapsed(),
        sum.tokens.len() as u64,
        sum.setup_bytes,
        sum.prefill_bytes,
        sum.decode_bytes,
        sum.rounds,
        sum.decode_rounds,
    );
    if lane.connected {
        let _ = lane.stream.send(Ok(StreamEvent::Done(GenSummary {
            tokens: sum.tokens,
            setup_bytes: sum.setup_bytes,
            prefill_bytes: sum.prefill_bytes,
            decode_bytes: sum.decode_bytes,
            rounds: sum.rounds,
            decode_rounds: sum.decode_rounds,
            transcript_digest: sum.transcript_digest,
            latency,
        })));
    } else {
        release_unconsumed_demand(pool, cfg, sum.steps_unconsumed);
    }
}

/// The decode scheduler: one engine, one long-lived [`DecodeBatch`],
/// continuous admission. Generate requests routed here by the batcher
/// join the running batch at step boundaries; every active session rides
/// the same per-step flight schedule, so wire rounds amortize to
/// (solo rounds)/B per token (DESIGN.md §Continuous batching). Sessions
/// leave on step-budget exhaustion, context exhaustion, or client
/// disconnect; the scheduler exits once the request channel closes and
/// the batch drains.
fn decode_scheduler(
    cfg: ServerConfig,
    pool: Option<Arc<TriplePool>>,
    metrics: Arc<Mutex<Metrics>>,
    rx: mpsc::Receiver<Request>,
) {
    // A dead engine must not strand clients: fail every queued request.
    let fail_all = |rx: &mpsc::Receiver<Request>, why: &str| {
        for req in rx.iter() {
            match req {
                Request::Generate { stream, .. } => {
                    let _ = stream.send(Err(anyhow::anyhow!("decode scheduler unavailable: {why}")));
                }
                Request::Infer { .. } => {} // dropped responder reports disconnect
            }
        }
    };
    let mut engine = match build_centaur_engine(&cfg, pool.clone()) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("decode scheduler: engine init failed: {e}");
            fail_all(&rx, &format!("engine init failed: {e}"));
            return;
        }
    };
    // Deliberate-tamper smoke (--audit-tamper): arm one share fault a few
    // covered openings in, so the first request's MAC batch check must
    // reject — proving the detection path end to end in a live server.
    if cfg.audit_tamper {
        let armed = engine
            .inject_share_fault(crate::mpc::ShareFault { at_open: 8, word: 3, mask: 0b10 });
        if !armed {
            eprintln!("decode scheduler: --audit-tamper has no effect without --audit");
        }
    }
    let mut batch = match DecodeBatch::new(&mut engine) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("decode scheduler: batch init failed: {e}");
            fail_all(&rx, &format!("batch init failed: {e}"));
            return;
        }
    };
    let mut audit_seen = crate::mpc::AuditCounters::default();
    let mut lanes: std::collections::HashMap<usize, SchedLane> = std::collections::HashMap::new();
    let mut disconnected = false;
    // Speculative decode (--spec-k > 1): a public tiny-model draft built
    // from the serving weights proposes follow-up tokens; each shared
    // step verifies them as extra lanes (DESIGN.md §Speculative decode).
    let draft = if cfg.spec_k > 1 {
        Some(crate::engine::draft::Draft::tiny(&cfg.cfg, &cfg.weights))
    } else {
        None
    };

    loop {
        // Admission: block when the batch is idle, otherwise drain
        // whatever is already queued — sessions join only at step
        // boundaries, up to `max_batch` concurrent lanes.
        while batch.len() < cfg.max_batch.max(1) && !disconnected {
            let req = if batch.is_empty() {
                match rx.recv() {
                    Ok(r) => r,
                    Err(_) => {
                        disconnected = true;
                        break;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(r) => r,
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        disconnected = true;
                        break;
                    }
                }
            };
            let Request::Generate { prompt, steps, enqueued, stream } = req else {
                continue; // router predicate sends only Generate here
            };
            let admitted = Instant::now();
            match batch.admit(&prompt, steps, None) {
                Ok(id) => {
                    lanes.insert(id, SchedLane { stream, enqueued, admitted, connected: true });
                    // Prefill-only request (steps == 0): done before the
                    // first shared step — finalize immediately.
                    if batch.session(id).map(|s| s.is_done()).unwrap_or(false) {
                        finalize_session(&mut batch, &mut lanes, &metrics, pool.as_deref(), &cfg, id);
                    }
                }
                Err(e) => {
                    let _ = stream.send(Err(e));
                }
            }
        }
        // Admission can fail a MAC flush too — report before stepping.
        harvest_audit(&metrics, &mut audit_seen, batch.audit_counters());
        if batch.is_empty() {
            if disconnected {
                return;
            }
            continue;
        }

        // One shared step for every active lane — a speculative verify
        // step when a draft is configured, plain greedy otherwise.
        let width = batch.active() as u64;
        let spec0 = (batch.spec_proposed(), batch.spec_accepted());
        let stepped = match &draft {
            Some(d) => batch.step_spec(d, cfg.spec_k),
            None => batch.step(),
        };
        match stepped {
            Ok(emissions) => {
                if let Some(first) = emissions.first() {
                    metrics.lock().unwrap().record_spec_step(
                        first.step_rounds,
                        width,
                        emissions.len() as u64,
                        batch.spec_proposed() - spec0.0,
                        batch.spec_accepted() - spec0.1,
                    );
                }
                for em in &emissions {
                    let Some(lane) = lanes.get_mut(&em.session) else { continue };
                    if lane.connected {
                        let sent = lane
                            .stream
                            .send(Ok(StreamEvent::Token {
                                index: em.index,
                                token: em.token,
                                step_bytes: em.step_bytes,
                                step_rounds: em.step_rounds,
                            }))
                            .is_ok();
                        if !sent {
                            lane.connected = false;
                        }
                    }
                }
                // Eviction sweep: finished sessions and abandoned streams
                // leave at the step boundary.
                for id in batch.session_ids() {
                    let done = batch.session(id).map(|s| s.is_done()).unwrap_or(true);
                    let connected = lanes.get(&id).map(|l| l.connected).unwrap_or(false);
                    if done || !connected {
                        finalize_session(&mut batch, &mut lanes, &metrics, pool.as_deref(), &cfg, id);
                    }
                }
            }
            Err(e) => {
                // A failed shared step fails every rider: the engine's
                // transcript state is no longer trustworthy mid-step.
                let msg = format!("batched decode step failed: {e}");
                for id in batch.session_ids() {
                    if let Some(sum) = batch.remove(id) {
                        if let Some(lane) = lanes.remove(&id) {
                            if lane.connected {
                                let _ = lane.stream.send(Err(anyhow::anyhow!("{msg}")));
                            }
                            release_unconsumed_demand(pool.as_deref(), &cfg, sum.steps_unconsumed);
                        }
                    }
                }
            }
        }
        harvest_audit(&metrics, &mut audit_seen, batch.audit_counters());
    }
}

/// The running coordinator.
pub struct Coordinator {
    submit_tx: mpsc::Sender<Request>,
    metrics: Arc<Mutex<Metrics>>,
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    scheduler: Option<JoinHandle<()>>,
    /// Shared offline-phase pool (Some when `offline_prefill` was set).
    pool: Option<Arc<TriplePool>>,
    /// Background offline service keeping the pool topped up.
    service: Option<PoolService>,
    /// Pool counters right after the synchronous prefill: the warm-serving
    /// hit/miss/starvation metrics are measured against this baseline, so
    /// the shape-learning probe's cold misses don't pollute them.
    pool_baseline: Option<PoolStats>,
}

impl Coordinator {
    /// Start the batcher and worker threads.
    ///
    /// With [`ServerConfig::offline_prefill`] set (Centaur framework), the
    /// offline phase runs first: one profiling inference teaches a shared
    /// [`TriplePool`] the request's triple-shape demand, the pool is filled
    /// to target synchronously, and a background thread keeps it topped up
    /// while the server runs — so requests pay online cost only.
    pub fn start(config: ServerConfig) -> Result<Self> {
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (submit_tx, submit_rx) = mpsc::channel::<Request>();

        // Offline phase (optional): learn the shape profile, then prefill.
        let pool = if config.offline_prefill && config.framework == FrameworkKind::Centaur {
            let pool = Arc::new(TriplePool::new(config.seed ^ 0x0FF1, config.pool_depth));
            // Audit mode authenticates the pool's stock: the MAC key must
            // be live before the probe/prefill generate a single entry,
            // or fail-closed verification would quarantine all of them.
            if config.audit {
                pool.enable_mac(config.seed ^ 0xA0D1_7000);
            }
            let mut probe = build_engine(&config, Some(Arc::clone(&pool)))?;
            let dummy = vec![4u32; config.cfg.n_ctx];
            probe
                .infer(&dummy)
                .map_err(|e| anyhow::anyhow!("offline-prefill probe inference failed: {e}"))?;
            // Decoder models: a full-inference probe never touches the
            // incremental-decode shapes, so register them directly — the
            // session-scoped fixed-operand bundles plus per-step value
            // triples (or the plain per-step profile with correlations
            // off), sized for the expected absorbs per request.
            if config.decode_prefill_steps > 0 && config.cfg.kind == ModelKind::Gpt2 {
                for (shape, count) in crate::protocols::layer::decode_pool_shapes_speculative(
                    &config.cfg,
                    config.decode_correlations,
                    config.decode_prefill_steps as u64,
                    config.decode_prefill_sessions as u64,
                    config.spec_k.max(1) as u64,
                ) {
                    pool.register_demand(shape, count);
                }
            }
            pool.fill_to_target();
            Some(pool)
        } else {
            None
        };

        // Warm baseline: everything on the counters so far is the probe's
        // cold misses plus the synchronous prefill. Serving metrics report
        // warm hit/starvation rates relative to this snapshot.
        let pool_baseline = pool.as_ref().map(|p| p.stats());

        // Offline service: shard-owning worker threads regenerate consumed
        // triples off the request path (DESIGN.md §Offline phase). The
        // workers hold only `Weak` pool references, so they also exit when
        // the coordinator is dropped without `shutdown()`.
        let service =
            pool.as_ref().map(|p| TriplePool::start_service(p, config.offline_workers.max(1)));

        // Workers: one engine each, fed by a shared work queue guarded by a
        // mutex-wrapped receiver (simple m:n fan-out).
        let (work_tx, work_rx) = mpsc::channel::<Batch<Request>>();
        let work_rx = Arc::new(Mutex::new(work_rx));
        let mut workers = Vec::new();
        for wid in 0..config.workers.max(1) {
            let cfg = config.clone();
            let worker_pool = pool.clone();
            let rx = Arc::clone(&work_rx);
            let m = Arc::clone(&metrics);
            workers.push(std::thread::spawn(move || {
                let mut engine = match build_engine(&cfg, worker_pool) {
                    Ok(e) => e,
                    Err(e) => {
                        eprintln!("worker {wid}: engine init failed: {e}");
                        return;
                    }
                };
                let mut audit_seen = crate::mpc::AuditCounters::default();
                loop {
                    let batch = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    let Ok(batch) = batch else { break };
                    m.lock().unwrap().batches += 1;
                    for req in batch.items {
                        match req {
                            Request::Infer { tokens, enqueued, respond } => {
                                let t0 = Instant::now();
                                let outcome = engine.infer(&tokens);
                                let latency = enqueued.elapsed();
                                let resp = outcome.map(|out| {
                                    let sim =
                                        out.stats.total_time(&cfg.profile) - out.stats.compute_total();
                                    Response {
                                        rows: out.logits.rows(),
                                        cols: out.logits.cols(),
                                        logits: out.logits.data().to_vec(),
                                        latency,
                                        simulated_net: sim,
                                        bytes: out.stats.bytes_total(),
                                        rounds: out.stats.rounds_total(),
                                    }
                                });
                                if let Ok(r) = &resp {
                                    m.lock().unwrap().record(latency, t0.elapsed(), r.bytes, r.rounds);
                                }
                                let _ = respond.send(resp);
                            }
                            Request::Generate { prompt, steps, enqueued, stream } => {
                                let t0 = Instant::now();
                                // A failed send means the client dropped its
                                // receiver — abort the remaining steps rather
                                // than burning protocol work nobody reads.
                                let outcome =
                                    engine.generate_stream(&prompt, steps, &mut |i, tok, step| {
                                        stream
                                            .send(Ok(StreamEvent::Token {
                                                index: i,
                                                token: tok,
                                                step_bytes: step.bytes_total(),
                                                step_rounds: step.rounds_total(),
                                            }))
                                            .is_ok()
                                    });
                                let latency = enqueued.elapsed();
                                match outcome {
                                    Ok(out) => {
                                        let total = out.total();
                                        m.lock().unwrap().record_generate(
                                            latency,
                                            t0.elapsed(),
                                            out.tokens.len() as u64,
                                            out.setup.bytes_total(),
                                            out.prefill.bytes_total(),
                                            out.decode.bytes_total(),
                                            total.rounds_total(),
                                            out.decode.rounds_total(),
                                        );
                                        let _ = stream.send(Ok(StreamEvent::Done(GenSummary {
                                            tokens: out.tokens,
                                            setup_bytes: out.setup.bytes_total(),
                                            prefill_bytes: out.prefill.bytes_total(),
                                            decode_bytes: out.decode.bytes_total(),
                                            rounds: total.rounds_total(),
                                            decode_rounds: out.decode.rounds_total(),
                                            transcript_digest: out.transcript.core_digest(),
                                            latency,
                                        })));
                                    }
                                    Err(e) => {
                                        let _ = stream.send(Err(e));
                                    }
                                }
                            }
                        }
                    }
                    harvest_audit(&m, &mut audit_seen, engine.audit_counters());
                }
            }));
        }

        // Decode scheduler (Centaur decoder models with round batching):
        // generate requests bypass the batcher's linger window and join a
        // continuously-batched DecodeBatch, sharing each step's flights
        // across sessions. Other configurations keep the legacy
        // one-session-per-worker generate path.
        let scheduler_enabled = config.framework == FrameworkKind::Centaur
            && config.cfg.kind == ModelKind::Gpt2
            && config.round_batching;
        let (gen_tx, gen_rx) = mpsc::channel::<Request>();
        let scheduler = if scheduler_enabled {
            let cfg = config.clone();
            let sched_pool = pool.clone();
            let m = Arc::clone(&metrics);
            Some(std::thread::spawn(move || decode_scheduler(cfg, sched_pool, m, gen_rx)))
        } else {
            None
        };

        // Batcher thread. With the scheduler up, generate requests take
        // the side route to it; inference requests batch as before. The
        // batcher owns `gen_tx`, so its exit (submit channel closed)
        // disconnects the scheduler, which drains its batch and exits.
        let bconf = BatcherConfig { max_batch: config.max_batch, linger: config.linger };
        let batcher = std::thread::spawn(move || {
            if scheduler_enabled {
                batcher::run_routed(
                    submit_rx,
                    work_tx,
                    gen_tx,
                    |r| matches!(r, Request::Generate { .. }),
                    bconf,
                );
            } else {
                drop(gen_tx);
                batcher::run(submit_rx, work_tx, bconf);
            }
        });

        Ok(Coordinator {
            submit_tx,
            metrics,
            batcher: Some(batcher),
            workers,
            scheduler,
            pool,
            service,
            pool_baseline,
        })
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, tokens: Vec<u32>) -> mpsc::Receiver<Result<Response>> {
        let (tx, rx) = mpsc::channel();
        let req = Request::Infer { tokens, enqueued: Instant::now(), respond: tx };
        // If the batcher is gone the receiver will simply report disconnect.
        let _ = self.submit_tx.send(req);
        rx
    }

    /// Submit a streaming generation request (decoder frameworks): the
    /// receiver yields one [`StreamEvent::Token`] per generated token as
    /// the protocol produces it, then [`StreamEvent::Done`] with the
    /// cold-prefill / warm-decode split.
    pub fn submit_generate(&self, prompt: Vec<u32>, steps: usize) -> mpsc::Receiver<Result<StreamEvent>> {
        let (tx, rx) = mpsc::channel();
        let req = Request::Generate { prompt, steps, enqueued: Instant::now(), stream: tx };
        let _ = self.submit_tx.send(req);
        rx
    }

    /// Convenience: submit a generation request and wait for completion,
    /// discarding the intermediate token events.
    pub fn generate_blocking(&self, prompt: Vec<u32>, steps: usize) -> Result<GenSummary> {
        let rx = self.submit_generate(prompt, steps);
        loop {
            match rx.recv() {
                Ok(Ok(StreamEvent::Done(summary))) => return Ok(summary),
                Ok(Ok(StreamEvent::Token { .. })) => continue,
                Ok(Err(e)) => return Err(e),
                Err(_) => anyhow::bail!("coordinator shut down"),
            }
        }
    }

    /// Convenience: submit and wait.
    pub fn infer_blocking(&self, tokens: Vec<u32>) -> Result<Response> {
        self.submit(tokens)
            .recv()
            .map_err(|_| anyhow::anyhow!("coordinator shut down"))?
    }

    /// Snapshot of metrics so far (includes the offline-phase counters —
    /// hits/misses, starvation events, triples/s, per-shard depth — when
    /// an offline prefill pool is active).
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut snap = self.metrics.lock().unwrap().snapshot();
        if let Some(p) = &self.pool {
            snap.set_pool(&p.stats(), self.pool_baseline.as_ref());
        }
        snap
    }

    /// The shared offline pool, when `offline_prefill` was configured.
    pub fn triple_pool(&self) -> Option<&Arc<TriplePool>> {
        self.pool.as_ref()
    }

    /// Graceful shutdown: stop accepting, drain workers, return metrics.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        drop(self.submit_tx);
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if let Some(sch) = self.scheduler.take() {
            let _ = sch.join();
        }
        if let Some(s) = self.service.take() {
            s.stop();
        }
        let mut snap = self.metrics.lock().unwrap().snapshot();
        if let Some(p) = &self.pool {
            snap.set_pool(&p.stats(), self.pool_baseline.as_ref());
        }
        snap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn tiny_config(framework: FrameworkKind) -> ServerConfig {
        let cfg = ModelConfig::bert_tiny();
        let weights = ModelWeights::random(&cfg, 101);
        let mut sc = ServerConfig::new(cfg, weights);
        sc.framework = framework;
        sc.max_batch = 4;
        sc.linger = Duration::from_millis(1);
        sc
    }

    #[test]
    fn serve_roundtrip_centaur() {
        let sc = tiny_config(FrameworkKind::Centaur);
        let n_ctx = sc.cfg.n_ctx;
        let coord = Coordinator::start(sc).unwrap();
        let resp = coord.infer_blocking(vec![5; n_ctx]).unwrap();
        assert_eq!((resp.rows, resp.cols), (1, 2));
        assert!(resp.bytes > 0);
        let snap = coord.shutdown();
        assert_eq!(snap.completed, 1);
        assert!(snap.p50 > Duration::ZERO);
    }

    #[test]
    fn batching_groups_requests() {
        let mut sc = tiny_config(FrameworkKind::Centaur);
        sc.linger = Duration::from_millis(30);
        sc.max_batch = 8;
        let n_ctx = sc.cfg.n_ctx;
        let coord = Coordinator::start(sc).unwrap();
        let rxs: Vec<_> = (0..6).map(|_| coord.submit(vec![7; n_ctx])).collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
        let snap = coord.shutdown();
        assert_eq!(snap.completed, 6);
        // 6 requests within one linger window → far fewer batches
        assert!(snap.batches <= 3, "batches={}", snap.batches);
    }

    #[test]
    fn offline_prefill_pool_serves_warm_requests() {
        let mut sc = tiny_config(FrameworkKind::Centaur);
        sc.offline_prefill = true;
        sc.pool_depth = 2;
        let n_ctx = sc.cfg.n_ctx;
        let coord = Coordinator::start(sc).unwrap();
        let pool = Arc::clone(coord.triple_pool().expect("offline_prefill must create a pool"));
        assert!(pool.pooled_total() > 0, "prefill must stock the pool");
        assert!(pool.shapes_known() > 0);
        for _ in 0..2 {
            coord.infer_blocking(vec![5; n_ctx]).unwrap();
        }
        let snap = coord.shutdown();
        assert_eq!(snap.completed, 2);
        assert!(snap.pool_hits > 0, "warm requests must be served from the pool");
        // The only misses should be the shape-learning probe at startup.
        assert!(
            snap.pool_hit_rate() > 0.5,
            "hit rate {:.2} (hits={} misses={})",
            snap.pool_hit_rate(),
            snap.pool_hits,
            snap.pool_misses
        );
        assert!(snap.summary().contains("pool_hit_rate"));
    }

    #[test]
    fn no_pool_without_prefill_flag() {
        let sc = tiny_config(FrameworkKind::Centaur);
        let n_ctx = sc.cfg.n_ctx;
        let coord = Coordinator::start(sc).unwrap();
        assert!(coord.triple_pool().is_none());
        coord.infer_blocking(vec![5; n_ctx]).unwrap();
        let snap = coord.shutdown();
        assert_eq!(snap.pool_hits + snap.pool_misses, 0);
        assert!(!snap.summary().contains("pool_hit_rate"));
    }

    fn tiny_gpt_config() -> ServerConfig {
        let cfg = ModelConfig::gpt2_tiny();
        let weights = ModelWeights::random(&cfg, 103);
        let mut sc = ServerConfig::new(cfg, weights);
        sc.max_batch = 2;
        sc.linger = Duration::from_millis(1);
        sc
    }

    #[test]
    fn streaming_generate_over_the_coordinator() {
        let sc = tiny_gpt_config();
        let coord = Coordinator::start(sc).unwrap();
        let rx = coord.submit_generate(vec![7, 11, 13], 3);
        let mut tokens = Vec::new();
        let mut done = None;
        for ev in rx.iter() {
            match ev.unwrap() {
                StreamEvent::Token { index, token, step_bytes, step_rounds } => {
                    assert_eq!(index, tokens.len(), "tokens must stream in order");
                    assert!(step_bytes > 0 && step_rounds > 0);
                    tokens.push(token);
                }
                StreamEvent::Done(s) => {
                    done = Some(s);
                    break;
                }
            }
        }
        let s = done.expect("stream must end with Done");
        assert_eq!(s.tokens, tokens);
        assert_eq!(tokens.len(), 3);
        assert!(s.prefill_bytes > 0 && s.decode_bytes > 0);
        // correlations are on by default: the one-time setup is reported
        // separately so the warm decode_per_token number stays clean
        assert!(s.setup_bytes > 0);
        let snap = coord.shutdown();
        assert_eq!(snap.generations, 1);
        assert_eq!(snap.tokens_generated, 3);
        assert_eq!(snap.corr_setup_bytes, s.setup_bytes);
        assert!(snap.decode_bytes_per_token() > 0);
        // rounds/token is a first-class serving metric (ISSUE 5): the
        // summary reports it and it reconciles with the Done event.
        assert!(s.decode_rounds > 0);
        assert_eq!(snap.decode_rounds, s.decode_rounds);
        assert_eq!(snap.decode_rounds_per_token(), s.decode_rounds / s.tokens.len() as u64);
        assert!(snap.summary().contains("decode_per_token"));
        assert!(snap.summary().contains("decode_rounds_per_token"));
        assert!(snap.summary().contains("corr_setup"));
    }

    #[test]
    fn decode_correlations_off_serves_plain_sessions_with_more_decode_comm() {
        // The serving-level view of the warm-step saving: identical
        // generate requests through a correlated and a plain coordinator;
        // the correlated one reports setup bytes and strictly less decode
        // communication per token.
        let run = |decode_correlations: bool| {
            let mut sc = tiny_gpt_config();
            sc.decode_correlations = decode_correlations;
            let coord = Coordinator::start(sc).unwrap();
            let s = coord.generate_blocking(vec![7, 11, 13], 3).unwrap();
            coord.shutdown();
            s
        };
        let corr = run(true);
        let plain = run(false);
        // (token-level parity between the two paths is margin-gated in
        // tests/e2e_pipeline.rs; byte charges here are shape-deterministic)
        assert_eq!(corr.tokens.len(), plain.tokens.len());
        assert_eq!(plain.setup_bytes, 0);
        assert!(corr.setup_bytes > 0);
        assert!(
            plain.decode_bytes > corr.decode_bytes,
            "correlated decode must move fewer warm bytes ({} vs {})",
            plain.decode_bytes,
            corr.decode_bytes
        );
    }

    #[test]
    fn decode_prefill_stocks_decode_shapes() {
        let mut sc = tiny_gpt_config();
        sc.offline_prefill = true;
        sc.pool_depth = 1;
        // prompt 3 + steps 3 = 6 absorbs per request
        sc.decode_prefill_steps = 6;
        let coord = Coordinator::start(sc).unwrap();
        let pool = Arc::clone(coord.triple_pool().expect("offline_prefill must create a pool"));
        assert!(pool.pooled_total() > 0);
        let hits_before = pool.hits();
        let summary = coord.generate_blocking(vec![7, 11, 13], 3).unwrap();
        assert_eq!(summary.tokens.len(), 3);
        assert!(pool.hits() > hits_before, "decode-shape triples must come from the pool");
        coord.shutdown();
    }

    #[test]
    fn concurrent_generates_share_batched_decode_steps() {
        // Three streams admitted into one DecodeBatch: every request
        // completes with its full continuation, and the batch counters
        // show shared steps (≤ the 12 a sequential run would take).
        let mut sc = tiny_gpt_config();
        sc.max_batch = 4;
        let coord = Coordinator::start(sc).unwrap();
        let rxs: Vec<_> = (0..3).map(|i| coord.submit_generate(vec![7, 11 + i as u32], 4)).collect();
        let mut finished = 0;
        for rx in rxs {
            let mut tokens = Vec::new();
            for ev in rx.iter() {
                match ev.unwrap() {
                    StreamEvent::Token { index, token, step_bytes, step_rounds } => {
                        assert_eq!(index, tokens.len(), "tokens must stream in order");
                        assert!(step_bytes > 0 && step_rounds > 0);
                        tokens.push(token);
                    }
                    StreamEvent::Done(s) => {
                        assert_eq!(s.tokens, tokens);
                        assert_eq!(tokens.len(), 4);
                        assert!(s.decode_rounds > 0);
                        finished += 1;
                        break;
                    }
                }
            }
        }
        assert_eq!(finished, 3);
        let snap = coord.shutdown();
        assert_eq!(snap.generations, 3);
        assert_eq!(snap.tokens_generated, 12);
        assert_eq!(snap.batch_tokens, 12);
        // 12 tokens over ≥ 4 shared steps (admission timing decides how
        // many actually ride together; never more than one step/token).
        assert!(
            (4..=12).contains(&snap.batched_decode_steps),
            "batched steps {}",
            snap.batched_decode_steps
        );
        assert!(snap.summary().contains("batch_steps"));
    }

    #[test]
    fn speculative_scheduler_keeps_greedy_parity_and_reports_acceptance() {
        // Same request through a plain (spec_k = 1) and a speculative
        // (spec_k = 4, tiny-model draft) coordinator: identical token
        // stream, fewer shared steps, acceptance metrics in the summary.
        let run = |spec_k: usize| {
            let mut sc = tiny_gpt_config();
            sc.spec_k = spec_k;
            let coord = Coordinator::start(sc).unwrap();
            let s = coord.generate_blocking(vec![7, 11, 13], 6).unwrap();
            let snap = coord.shutdown();
            (s.tokens, snap)
        };
        let (plain, _) = run(1);
        let (spec, snap) = run(4);
        assert_eq!(spec, plain, "speculative serving must keep greedy parity");
        assert_eq!(snap.tokens_generated, 6);
        // The draft shares the serving weights, so at least one proposal
        // rides every verify step (and the summary reports the rate).
        assert!(snap.spec_proposed > 0);
        assert!(snap.batched_decode_steps <= 6);
        assert!(snap.summary().contains("spec_accept_rate"));
    }

    #[test]
    fn dropped_stream_evicts_session_and_frees_the_batch() {
        // A client that walks away mid-generation must not wedge the
        // scheduler or leak phantom pool demand: the next request still
        // completes over the same batch.
        let mut sc = tiny_gpt_config();
        sc.offline_prefill = true;
        sc.pool_depth = 1;
        sc.decode_prefill_steps = 6;
        let coord = Coordinator::start(sc).unwrap();
        drop(coord.submit_generate(vec![7, 11, 13], 3));
        let s = coord.generate_blocking(vec![7, 11, 13], 3).unwrap();
        assert_eq!(s.tokens.len(), 3);
        let snap = coord.shutdown();
        // Both sessions finalize through the scheduler's metrics path.
        assert_eq!(snap.generations, 2);
        assert!(snap.tokens_generated >= 3);
    }

    #[test]
    fn offline_service_reports_warm_metrics_without_starvation() {
        // The tentpole end-to-end: with the offline phase provisioned for
        // the request mix, warm serving never generates triples on the
        // online path — the snapshot's warm counters (measured against the
        // post-prefill baseline, so the probe's cold misses don't count)
        // show a perfect hit rate and zero starvation events.
        let mut sc = tiny_gpt_config();
        sc.offline_prefill = true;
        sc.pool_depth = 2;
        sc.decode_prefill_steps = 6; // prompt 3 + steps 3
        sc.decode_prefill_sessions = 2;
        let coord = Coordinator::start(sc).unwrap();
        let pool = Arc::clone(coord.triple_pool().expect("offline_prefill must create a pool"));
        let rxs: Vec<_> = (0..2).map(|i| coord.submit_generate(vec![7, 11 + i as u32, 13], 3)).collect();
        for rx in rxs {
            loop {
                match rx.recv().unwrap().unwrap() {
                    StreamEvent::Done(s) => {
                        assert_eq!(s.tokens.len(), 3);
                        break;
                    }
                    StreamEvent::Token { .. } => continue,
                }
            }
        }
        let snap = coord.shutdown();
        assert!(snap.warm_pool_hits > 0, "warm sessions must draw from the pool");
        assert_eq!(snap.warm_pool_misses, 0, "offline phase must cover the warm request mix");
        assert_eq!(snap.warm_pool_starved, 0, "no online-path triple generation allowed");
        assert!(snap.warm_pool_hit_rate() >= 0.99);
        assert!(snap.pool_generated > 0);
        assert!(snap.pool_offline_bytes > 0);
        assert_eq!(snap.pool_shard_depths.len(), pool.shard_count());
        assert!(snap.summary().contains("offline_triples_per_sec"));
        assert!(snap.summary().contains("warm_pool_hit_rate"));
    }

    #[test]
    fn audited_serving_verifies_clean_and_reports_checks() {
        // Honest audited serving end to end: pool MACs live before the
        // prefill stocks a single entry, per-step flushes all clean, and
        // the snapshot reports checks + overhead with zero failures.
        let mut sc = tiny_gpt_config();
        sc.audit = true;
        sc.offline_prefill = true;
        sc.pool_depth = 1;
        sc.decode_prefill_steps = 6;
        let coord = Coordinator::start(sc).unwrap();
        let s = coord.generate_blocking(vec![7, 11, 13], 3).unwrap();
        assert_eq!(s.tokens.len(), 3);
        assert_ne!(s.transcript_digest, 0, "the transcript must have commitments");
        let snap = coord.shutdown();
        assert!(snap.mac_checks > 0, "audited decode must flush MAC batches");
        assert_eq!(snap.audit_failures, 0, "honest serving must verify clean");
        assert!(snap.audit_overhead_bytes > 0);
        assert_eq!(snap.pool_mac_rejected, 0, "honest pool stock must all verify");
        assert!(snap.summary().contains("mac_checks"));
        assert!(snap.summary().contains("audit_failures=0"));
    }

    #[test]
    fn audited_serving_keeps_token_parity_with_audit_off() {
        // The zero-perturbation invariant at the serving layer: audit on
        // vs off moves not one token, byte, or transcript commitment (the
        // MAC overhead lives in the audit counters, never the ledgers).
        let run = |audit: bool| {
            let mut sc = tiny_gpt_config();
            sc.audit = audit;
            let coord = Coordinator::start(sc).unwrap();
            let s = coord.generate_blocking(vec![7, 11, 13], 4).unwrap();
            let snap = coord.shutdown();
            (s, snap)
        };
        let (on, snap_on) = run(true);
        let (off, snap_off) = run(false);
        assert_eq!(on.tokens, off.tokens, "audit must not perturb a single token");
        assert_eq!(on.transcript_digest, off.transcript_digest);
        assert_eq!(
            (on.setup_bytes, on.prefill_bytes, on.decode_bytes, on.rounds),
            (off.setup_bytes, off.prefill_bytes, off.decode_bytes, off.rounds)
        );
        assert!(snap_on.mac_checks > 0);
        assert_eq!((snap_off.mac_checks, snap_off.audit_overhead_bytes), (0, 0));
    }

    #[test]
    fn tamper_injection_is_detected_and_reported() {
        // --audit-tamper smoke: one share fault armed a few openings in;
        // the request must fail with a MAC error and the failure must
        // surface in the snapshot. The server keeps serving afterwards.
        let mut sc = tiny_gpt_config();
        sc.audit = true;
        sc.audit_tamper = true;
        let coord = Coordinator::start(sc).unwrap();
        let res = coord.generate_blocking(vec![7, 11, 13], 3);
        let err = format!("{:#}", res.expect_err("a tampered share must fail the request"));
        assert!(err.contains("MAC check failed"), "unexpected error: {err}");
        // Single-shot fault: the next request over the same scheduler is
        // honest again and completes.
        let s = coord.generate_blocking(vec![7, 11, 13], 3).unwrap();
        assert_eq!(s.tokens.len(), 3);
        let snap = coord.shutdown();
        assert!(snap.audit_failures > 0, "detection must surface in metrics");
        assert!(snap.summary().contains("audit_failures"));
    }

    #[test]
    fn generate_on_non_decoder_framework_reports_error() {
        let sc = tiny_config(FrameworkKind::PermOnly);
        let coord = Coordinator::start(sc).unwrap();
        assert!(coord.generate_blocking(vec![5, 6], 2).is_err());
        // server still alive for plain inference
        let ok = coord.infer_blocking(vec![5; 32]);
        assert!(ok.is_ok());
        coord.shutdown();
    }

    #[test]
    fn serve_permonly_framework() {
        let sc = tiny_config(FrameworkKind::PermOnly);
        let n_ctx = sc.cfg.n_ctx;
        let coord = Coordinator::start(sc).unwrap();
        let resp = coord.infer_blocking(vec![9; n_ctx]).unwrap();
        assert!(resp.bytes < 100_000); // near-plaintext
        coord.shutdown();
    }

    #[test]
    fn bad_input_is_reported_not_fatal() {
        let sc = tiny_config(FrameworkKind::Centaur);
        let coord = Coordinator::start(sc).unwrap();
        let err = coord.infer_blocking(vec![5; 3]); // wrong length
        assert!(err.is_err());
        // server still alive
        let ok = coord.infer_blocking(vec![5; 32]);
        assert!(ok.is_ok());
        coord.shutdown();
    }
}
