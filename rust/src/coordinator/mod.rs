//! L3 serving coordinator: request router, dynamic batcher, worker pool,
//! and metrics — the deployment layer a cloud platform would run Centaur
//! behind (vLLM-router-style, adapted to three-party PPTI sessions).
//!
//! Threading model (`std::thread` + channels; DESIGN.md substitutions):
//!
//! ```text
//!  clients ──submit──▶ batcher ──Batch──▶ router ──▶ worker 0 (engine)
//!                       (linger/max)        └──────▶ worker 1 (engine)
//! ```
//!
//! Each worker owns a full protocol engine (PJRT clients are not `Send`,
//! so engines are constructed *inside* the worker thread from a spec).

mod batcher;
mod metrics;

pub use batcher::{Batch, BatcherConfig};
pub use metrics::{Metrics, MetricsSnapshot};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::baselines::{permonly::PermOnlyEngine, smpc::SmpcEngine, FrameworkKind, PptiFramework};
use crate::engine::{CentaurEngine, EngineOptions};
use crate::model::{ModelConfig, ModelWeights};
use crate::mpc::TriplePool;
use crate::net::NetworkProfile;
use crate::runtime::{backend_by_name, NativeBackend};
use crate::Result;

/// Serving configuration.
#[derive(Clone)]
pub struct ServerConfig {
    /// Model architecture served.
    pub cfg: ModelConfig,
    /// Model weights served.
    pub weights: ModelWeights,
    /// Which PPTI framework executes requests.
    pub framework: FrameworkKind,
    /// `"native"` or `"xla"` (Centaur only).
    pub backend: String,
    /// Directory holding AOT artifacts / task data.
    pub artifacts_dir: String,
    /// Simulated network conditions.
    pub profile: NetworkProfile,
    /// Worker threads (one engine each).
    pub workers: usize,
    /// Dynamic-batcher capacity bound.
    pub max_batch: usize,
    /// Dynamic-batcher linger window.
    pub linger: Duration,
    /// Charged-ideal share×share products (paper-scale efficiency runs).
    pub fast_sim: bool,
    /// Base seed for the per-worker engines.
    pub seed: u64,
    /// Run the dealer's offline phase at server start: a [`TriplePool`]
    /// shared across workers is primed with the request's Beaver-triple
    /// shape profile and kept topped up by a background thread, so warm
    /// requests skip triple generation (Centaur framework only).
    pub offline_prefill: bool,
    /// Requests' worth of triples to keep pooled per shape.
    pub pool_depth: usize,
}

impl ServerConfig {
    /// Defaults: Centaur framework, native backend, 1 worker, batch ≤ 8,
    /// no offline prefill.
    pub fn new(cfg: ModelConfig, weights: ModelWeights) -> Self {
        ServerConfig {
            cfg,
            weights,
            framework: FrameworkKind::Centaur,
            backend: "native".into(),
            artifacts_dir: crate::data::artifacts_dir(),
            profile: NetworkProfile::lan(),
            workers: 1,
            max_batch: 8,
            linger: Duration::from_millis(2),
            fast_sim: false,
            seed: 11,
            offline_prefill: false,
            pool_depth: 2,
        }
    }
}

/// One inference response.
#[derive(Clone, Debug)]
pub struct Response {
    /// Flattened logits with shape.
    pub logits: Vec<f32>,
    /// Logit row count.
    pub rows: usize,
    /// Logit column count.
    pub cols: usize,
    /// End-to-end latency (queue + protocol), wall clock.
    pub latency: Duration,
    /// Simulated-network portion of the protocol time.
    pub simulated_net: f64,
    /// Online communication of this inference.
    pub bytes: u64,
    /// Protocol rounds of this inference.
    pub rounds: u64,
}

struct Request {
    tokens: Vec<u32>,
    enqueued: Instant,
    respond: mpsc::Sender<Result<Response>>,
}

/// Build the framework engine inside a worker thread.
fn build_engine(cfg: &ServerConfig, pool: Option<Arc<TriplePool>>) -> Result<Box<dyn PptiFramework>> {
    match cfg.framework {
        FrameworkKind::Centaur => {
            let backend = if cfg.backend == "native" {
                Box::new(NativeBackend::new()) as Box<dyn crate::runtime::Backend>
            } else {
                backend_by_name(&cfg.backend, &cfg.cfg.name, &cfg.artifacts_dir)?
            };
            let eng = CentaurEngine::with_backend(
                &cfg.cfg,
                &cfg.weights,
                backend,
                EngineOptions {
                    profile: cfg.profile,
                    seed: cfg.seed,
                    record_views: false,
                    fast_sim: cfg.fast_sim,
                    triple_pool: pool,
                },
            )?;
            Ok(Box::new(eng))
        }
        FrameworkKind::PermOnly => {
            Ok(Box::new(PermOnlyEngine::new(&cfg.cfg, &cfg.weights, cfg.profile, false)))
        }
        smpc => Ok(Box::new(SmpcEngine::new(smpc, &cfg.cfg, &cfg.weights, cfg.profile, cfg.seed)?)),
    }
}

/// The running coordinator.
pub struct Coordinator {
    submit_tx: mpsc::Sender<Request>,
    metrics: Arc<Mutex<Metrics>>,
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    /// Shared offline-phase pool (Some when `offline_prefill` was set).
    pool: Option<Arc<TriplePool>>,
    refill: Option<JoinHandle<()>>,
    refill_stop: Arc<AtomicBool>,
}

impl Coordinator {
    /// Start the batcher and worker threads.
    ///
    /// With [`ServerConfig::offline_prefill`] set (Centaur framework), the
    /// offline phase runs first: one profiling inference teaches a shared
    /// [`TriplePool`] the request's triple-shape demand, the pool is filled
    /// to target synchronously, and a background thread keeps it topped up
    /// while the server runs — so requests pay online cost only.
    pub fn start(config: ServerConfig) -> Result<Self> {
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (submit_tx, submit_rx) = mpsc::channel::<Request>();

        // Offline phase (optional): learn the shape profile, then prefill.
        let pool = if config.offline_prefill && config.framework == FrameworkKind::Centaur {
            let pool = Arc::new(TriplePool::new(config.seed ^ 0x0FF1, config.pool_depth));
            let mut probe = build_engine(&config, Some(Arc::clone(&pool)))?;
            let dummy = vec![4u32; config.cfg.n_ctx];
            probe
                .infer(&dummy)
                .map_err(|e| anyhow::anyhow!("offline-prefill probe inference failed: {e}"))?;
            pool.fill_to_target();
            Some(pool)
        } else {
            None
        };

        // Background refill: regenerate consumed triples off the request
        // path. Parked with a short sleep when the pool is at target. Holds
        // only a Weak reference so the thread also exits when the
        // coordinator (and its workers) are dropped without `shutdown()` —
        // the stop flag covers the graceful path.
        let refill_stop = Arc::new(AtomicBool::new(false));
        let refill = pool.as_ref().map(|p| {
            let weak = Arc::downgrade(p);
            let stop = Arc::clone(&refill_stop);
            std::thread::spawn(move || loop {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                let Some(p) = weak.upgrade() else { break };
                if !p.refill_once() {
                    drop(p);
                    std::thread::sleep(Duration::from_millis(1));
                }
            })
        });

        // Workers: one engine each, fed by a shared work queue guarded by a
        // mutex-wrapped receiver (simple m:n fan-out).
        let (work_tx, work_rx) = mpsc::channel::<Batch<Request>>();
        let work_rx = Arc::new(Mutex::new(work_rx));
        let mut workers = Vec::new();
        for wid in 0..config.workers.max(1) {
            let cfg = config.clone();
            let worker_pool = pool.clone();
            let rx = Arc::clone(&work_rx);
            let m = Arc::clone(&metrics);
            workers.push(std::thread::spawn(move || {
                let mut engine = match build_engine(&cfg, worker_pool) {
                    Ok(e) => e,
                    Err(e) => {
                        eprintln!("worker {wid}: engine init failed: {e}");
                        return;
                    }
                };
                loop {
                    let batch = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    let Ok(batch) = batch else { break };
                    m.lock().unwrap().batches += 1;
                    for req in batch.items {
                        let t0 = Instant::now();
                        let outcome = engine.infer(&req.tokens);
                        let latency = req.enqueued.elapsed();
                        let resp = outcome.map(|out| {
                            let sim = out.stats.total_time(&cfg.profile) - out.stats.compute_total();
                            Response {
                                rows: out.logits.rows(),
                                cols: out.logits.cols(),
                                logits: out.logits.data().to_vec(),
                                latency,
                                simulated_net: sim,
                                bytes: out.stats.bytes_total(),
                                rounds: out.stats.rounds_total(),
                            }
                        });
                        if let Ok(r) = &resp {
                            m.lock().unwrap().record(latency, t0.elapsed(), r.bytes, r.rounds);
                        }
                        let _ = req.respond.send(resp);
                    }
                }
            }));
        }

        // Batcher thread.
        let bconf = BatcherConfig { max_batch: config.max_batch, linger: config.linger };
        let batcher = std::thread::spawn(move || {
            batcher::run(submit_rx, work_tx, bconf);
        });

        Ok(Coordinator {
            submit_tx,
            metrics,
            batcher: Some(batcher),
            workers,
            pool,
            refill,
            refill_stop,
        })
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, tokens: Vec<u32>) -> mpsc::Receiver<Result<Response>> {
        let (tx, rx) = mpsc::channel();
        let req = Request { tokens, enqueued: Instant::now(), respond: tx };
        // If the batcher is gone the receiver will simply report disconnect.
        let _ = self.submit_tx.send(req);
        rx
    }

    /// Convenience: submit and wait.
    pub fn infer_blocking(&self, tokens: Vec<u32>) -> Result<Response> {
        self.submit(tokens)
            .recv()
            .map_err(|_| anyhow::anyhow!("coordinator shut down"))?
    }

    /// Snapshot of metrics so far (includes offline-pool hit/miss counters
    /// when an offline prefill pool is active).
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut snap = self.metrics.lock().unwrap().snapshot();
        if let Some(p) = &self.pool {
            snap.set_pool(p.hits(), p.misses());
        }
        snap
    }

    /// The shared offline pool, when `offline_prefill` was configured.
    pub fn triple_pool(&self) -> Option<&Arc<TriplePool>> {
        self.pool.as_ref()
    }

    /// Graceful shutdown: stop accepting, drain workers, return metrics.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        drop(self.submit_tx);
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.refill_stop.store(true, Ordering::Relaxed);
        if let Some(r) = self.refill.take() {
            let _ = r.join();
        }
        let mut snap = self.metrics.lock().unwrap().snapshot();
        if let Some(p) = &self.pool {
            snap.set_pool(p.hits(), p.misses());
        }
        snap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn tiny_config(framework: FrameworkKind) -> ServerConfig {
        let cfg = ModelConfig::bert_tiny();
        let weights = ModelWeights::random(&cfg, 101);
        let mut sc = ServerConfig::new(cfg, weights);
        sc.framework = framework;
        sc.max_batch = 4;
        sc.linger = Duration::from_millis(1);
        sc
    }

    #[test]
    fn serve_roundtrip_centaur() {
        let sc = tiny_config(FrameworkKind::Centaur);
        let n_ctx = sc.cfg.n_ctx;
        let coord = Coordinator::start(sc).unwrap();
        let resp = coord.infer_blocking(vec![5; n_ctx]).unwrap();
        assert_eq!((resp.rows, resp.cols), (1, 2));
        assert!(resp.bytes > 0);
        let snap = coord.shutdown();
        assert_eq!(snap.completed, 1);
        assert!(snap.p50 > Duration::ZERO);
    }

    #[test]
    fn batching_groups_requests() {
        let mut sc = tiny_config(FrameworkKind::Centaur);
        sc.linger = Duration::from_millis(30);
        sc.max_batch = 8;
        let n_ctx = sc.cfg.n_ctx;
        let coord = Coordinator::start(sc).unwrap();
        let rxs: Vec<_> = (0..6).map(|_| coord.submit(vec![7; n_ctx])).collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
        let snap = coord.shutdown();
        assert_eq!(snap.completed, 6);
        // 6 requests within one linger window → far fewer batches
        assert!(snap.batches <= 3, "batches={}", snap.batches);
    }

    #[test]
    fn offline_prefill_pool_serves_warm_requests() {
        let mut sc = tiny_config(FrameworkKind::Centaur);
        sc.offline_prefill = true;
        sc.pool_depth = 2;
        let n_ctx = sc.cfg.n_ctx;
        let coord = Coordinator::start(sc).unwrap();
        let pool = Arc::clone(coord.triple_pool().expect("offline_prefill must create a pool"));
        assert!(pool.pooled_total() > 0, "prefill must stock the pool");
        assert!(pool.shapes_known() > 0);
        for _ in 0..2 {
            coord.infer_blocking(vec![5; n_ctx]).unwrap();
        }
        let snap = coord.shutdown();
        assert_eq!(snap.completed, 2);
        assert!(snap.pool_hits > 0, "warm requests must be served from the pool");
        // The only misses should be the shape-learning probe at startup.
        assert!(
            snap.pool_hit_rate() > 0.5,
            "hit rate {:.2} (hits={} misses={})",
            snap.pool_hit_rate(),
            snap.pool_hits,
            snap.pool_misses
        );
        assert!(snap.summary().contains("pool_hit_rate"));
    }

    #[test]
    fn no_pool_without_prefill_flag() {
        let sc = tiny_config(FrameworkKind::Centaur);
        let n_ctx = sc.cfg.n_ctx;
        let coord = Coordinator::start(sc).unwrap();
        assert!(coord.triple_pool().is_none());
        coord.infer_blocking(vec![5; n_ctx]).unwrap();
        let snap = coord.shutdown();
        assert_eq!(snap.pool_hits + snap.pool_misses, 0);
        assert!(!snap.summary().contains("pool_hit_rate"));
    }

    #[test]
    fn serve_permonly_framework() {
        let sc = tiny_config(FrameworkKind::PermOnly);
        let n_ctx = sc.cfg.n_ctx;
        let coord = Coordinator::start(sc).unwrap();
        let resp = coord.infer_blocking(vec![9; n_ctx]).unwrap();
        assert!(resp.bytes < 100_000); // near-plaintext
        coord.shutdown();
    }

    #[test]
    fn bad_input_is_reported_not_fatal() {
        let sc = tiny_config(FrameworkKind::Centaur);
        let coord = Coordinator::start(sc).unwrap();
        let err = coord.infer_blocking(vec![5; 3]); // wrong length
        assert!(err.is_err());
        // server still alive
        let ok = coord.infer_blocking(vec![5; 32]);
        assert!(ok.is_ok());
        coord.shutdown();
    }
}
