//! Random permutations — the parameter-privacy mechanism (paper §2.3).
//!
//! A permutation matrix `π` of order `n` is stored as an index vector
//! (`idx[j] = i` means output column `j` takes input column `i`), so
//! applying `Xπ` is `O(rows·n)` instead of a dense matmul. The module
//! provides the three permutations Centaur's initialization generates:
//! `π ∈ R^{d×d}` (feature dim), `π₁ ∈ R^{n×n}` (sequence dim) and
//! `π₂ ∈ R^{k×k}` (FFN intermediate dim).

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// A permutation of `0..n`, representing the permutation matrix whose
/// column `j` has its 1 in row `idx[j]`: right-multiplying `X · π` yields
/// `Y[:, j] = X[:, idx[j]]`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Perm {
    idx: Vec<usize>,
}

impl Perm {
    /// Identity permutation.
    pub fn identity(n: usize) -> Perm {
        Perm { idx: (0..n).collect() }
    }

    /// Uniformly random permutation.
    pub fn random(n: usize, rng: &mut Rng) -> Perm {
        Perm { idx: rng.permutation(n) }
    }

    /// Build from an index vector (must be a bijection of `0..n`).
    pub fn from_indices(idx: Vec<usize>) -> Perm {
        let mut seen = vec![false; idx.len()];
        for &i in &idx {
            assert!(i < idx.len() && !seen[i], "not a permutation");
            seen[i] = true;
        }
        Perm { idx }
    }

    /// Order of the permutation.
    pub fn n(&self) -> usize {
        self.idx.len()
    }

    /// Index vector accessor.
    pub fn indices(&self) -> &[usize] {
        &self.idx
    }

    /// Inverse permutation (`π · π⁻¹ = I`, orthogonality: `π⁻¹ = πᵀ`).
    pub fn inverse(&self) -> Perm {
        let mut inv = vec![0usize; self.idx.len()];
        for (j, &i) in self.idx.iter().enumerate() {
            inv[i] = j;
        }
        Perm { idx: inv }
    }

    /// Composition `self ∘ other`: applying the result equals applying
    /// `other` then `self` on columns.
    pub fn compose(&self, other: &Perm) -> Perm {
        assert_eq!(self.n(), other.n());
        Perm { idx: self.idx.iter().map(|&i| other.idx[i]).collect() }
    }

    /// `X · π` — permute **columns** (feature permutation of activations,
    /// the common case in Centaur).
    pub fn apply_cols<T: Copy + Default>(&self, x: &Tensor<T>) -> Tensor<T> {
        assert_eq!(x.cols(), self.n(), "perm order != cols");
        Tensor::from_fn(x.rows(), x.cols(), |r, c| x.get(r, self.idx[c]))
    }

    /// `πᵀ · X` — permute **rows** with the transpose; combined with
    /// [`Self::apply_cols`] this expresses `πᵀ W π`-style weight hiding.
    pub fn apply_rows_t<T: Copy + Default>(&self, x: &Tensor<T>) -> Tensor<T> {
        assert_eq!(x.rows(), self.n(), "perm order != rows");
        Tensor::from_fn(x.rows(), x.cols(), |r, c| x.get(self.idx[r], c))
    }

    /// `π · X` — permute rows (for left-multiplication by π itself).
    pub fn apply_rows<T: Copy + Default>(&self, x: &Tensor<T>) -> Tensor<T> {
        let inv = self.inverse();
        assert_eq!(x.rows(), self.n(), "perm order != rows");
        Tensor::from_fn(x.rows(), x.cols(), |r, c| x.get(inv.idx[r], c))
    }

    /// Permute a flat vector as columns of a 1×n tensor (biases, γ/β).
    pub fn apply_vec<T: Copy + Default>(&self, v: &[T]) -> Vec<T> {
        assert_eq!(v.len(), self.n());
        self.idx.iter().map(|&i| v[i]).collect()
    }

    /// Dense 0/1 matrix representation (tests / didactic only).
    pub fn to_matrix(&self) -> Tensor<f32> {
        Tensor::from_fn(self.n(), self.n(), |r, c| if self.idx[c] == r { 1.0 } else { 0.0 })
    }

    /// log2(n!) — the brute-force security bits quoted in the paper (§2.3:
    /// n=1280 → ≈ 2^11372 possibilities).
    pub fn security_bits(n: usize) -> f64 {
        // Stirling-corrected exact sum of log2(i)
        (2..=n).map(|i| (i as f64).log2()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::FloatTensor;
    use crate::util::prop::check;

    #[test]
    fn inverse_roundtrip_cols() {
        check("perm inverse roundtrip", 50, |g| {
            let n = g.dim(64);
            let p = Perm::random(n, g.rng());
            let x = FloatTensor::from_fn(3, n, |r, c| (r * n + c) as f32);
            let y = p.apply_cols(&x);
            let back = p.inverse().apply_cols(&y);
            assert_eq!(back.data(), x.data());
        });
    }

    #[test]
    fn matches_dense_matrix_product() {
        check("perm == dense π", 20, |g| {
            let n = g.dim(16);
            let p = Perm::random(n, g.rng());
            let x = FloatTensor::from_fn(4, n, |r, c| (r as f32) * 0.5 + c as f32);
            let fast = p.apply_cols(&x);
            let dense = x.matmul(&p.to_matrix());
            assert!(fast.max_abs_diff(&dense) == 0.0);
        });
    }

    #[test]
    fn orthogonality_pi_pit_identity() {
        check("π πᵀ = I", 30, |g| {
            let n = g.dim(32);
            let p = Perm::random(n, g.rng());
            assert_eq!(p.compose(&p.inverse()), Perm::identity(n));
            assert_eq!(p.inverse().compose(&p), Perm::identity(n));
        });
    }

    #[test]
    fn elementwise_commutes_with_perm() {
        // f_e(Xπ) = f_e(X)π — Eq. (7) of the paper.
        check("elementwise commutes", 30, |g| {
            let n = g.dim(32);
            let p = Perm::random(n, g.rng());
            let x = FloatTensor::from_fn(2, n, |r, c| (r + c) as f32 - 3.0);
            let f = |v: f32| 0.5 * v * (1.0 + (v * 0.7978845608).tanh()); // gelu-ish
            let lhs = p.apply_cols(&x).map(f);
            let rhs = p.apply_cols(&x.map(f));
            assert_eq!(lhs.data(), rhs.data());
        });
    }

    #[test]
    fn linear_layer_cancellation() {
        // X π (W π)ᵀ = X Wᵀ — Eq. (6) of the paper.
        check("Xπ(Wπ)ᵀ = XWᵀ", 20, |g| {
            let n = g.dim(12);
            let m = g.dim(6);
            let p = Perm::random(n, g.rng());
            let x = FloatTensor::from_fn(m, n, |r, c| ((r * 7 + c * 3) % 11) as f32 - 5.0);
            let w = FloatTensor::from_fn(m, n, |r, c| ((r * 5 + c) % 7) as f32 - 3.0);
            let lhs = p.apply_cols(&x).matmul_nt(&p.apply_cols(&w));
            let rhs = x.matmul_nt(&w);
            assert!(lhs.max_abs_diff(&rhs) < 1e-4);
        });
    }

    #[test]
    fn rows_and_cols_consistent() {
        check("apply_rows == (apply_cols on transpose)", 20, |g| {
            let n = g.dim(16);
            let p = Perm::random(n, g.rng());
            let x = FloatTensor::from_fn(n, 5, |r, c| (r * 5 + c) as f32);
            let via_t = p.apply_cols(&x.transpose()).transpose();
            // X·π on Xᵀ equals πᵀ·X... verify consistency definitionally:
            let direct = p.apply_rows_t(&x);
            // apply_rows_t picks row idx[r]; apply_cols on transpose picks col idx[c].
            assert_eq!(via_t.data(), direct.data());
        });
    }

    #[test]
    fn security_bits_match_paper() {
        // paper: n=1280 → ~2^11372
        let bits = Perm::security_bits(1280);
        assert!((bits - 11372.0).abs() < 60.0, "bits={bits}");
    }

    #[test]
    #[should_panic(expected = "not a permutation")]
    fn rejects_non_bijection() {
        Perm::from_indices(vec![0, 0, 2]);
    }
}
