//! Synthetic datasets (generated at build time by
//! `python/compile/data_gen.py` into `artifacts/data/`) — the Rust-side
//! loaders. The vocabulary is the cross-language contract; token ids in
//! checkpoints, tasks, and attack corpora all refer to it.

use std::path::Path;

use crate::util::json::{self, Json};
use crate::Result;

/// Special token ids (fixed by data_gen.py).
pub const PAD: u32 = 0;
/// `[CLS]` sentence-start marker.
pub const CLS: u32 = 1;
/// `[SEP]` sentence-end marker.
pub const SEP: u32 = 2;
/// `[UNK]` out-of-vocabulary token.
pub const UNK: u32 = 3;
/// Number of reserved special-token ids ([`PAD`], [`CLS`], [`SEP`],
/// [`UNK`]) at the bottom of the vocabulary. Generation must never emit a
/// special, so greedy selection skips exactly this many leading ids.
pub const NUM_SPECIAL_TOKENS: usize = 4;

/// Greedy next-token selection over one logits row, never emitting a
/// special token: argmax over ids `>= NUM_SPECIAL_TOKENS`. Ties resolve to
/// the highest id (iterator `max_by` semantics), matching the plaintext
/// greedy reference used by the decode parity tests.
pub fn greedy_regular_token(row: &[f32]) -> u32 {
    row.iter()
        .enumerate()
        .skip(NUM_SPECIAL_TOKENS)
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i as u32)
        .expect("logits row must cover at least one regular token")
}

/// The shared word-level vocabulary.
#[derive(Clone, Debug)]
pub struct Vocab {
    /// Words by token id.
    pub words: Vec<String>,
}

impl Vocab {
    /// Load `data/vocab.json` from the artifact directory.
    pub fn load(artifacts_dir: &str) -> Result<Self> {
        let path = Path::new(artifacts_dir).join("data").join("vocab.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("read {}: {e} (run `make artifacts`)", path.display()))?;
        let doc = json::parse(&text).map_err(|e| anyhow::anyhow!("vocab: {e}"))?;
        let words = doc
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("vocab must be an array"))?
            .iter()
            .map(|w| w.as_str().unwrap_or("?").to_string())
            .collect();
        Ok(Vocab { words })
    }

    /// Vocabulary size.
    pub fn len(&self) -> usize {
        self.words.len()
    }
    /// Whether the vocabulary is empty.
    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Token id for a word (UNK when unknown).
    pub fn id(&self, word: &str) -> u32 {
        self.words.iter().position(|w| w == word).map(|i| i as u32).unwrap_or(UNK)
    }

    /// Tokenize a whitespace-separated sentence with [CLS]/[SEP] framing,
    /// padded/truncated to `seq_len`.
    pub fn encode(&self, text: &str, seq_len: usize) -> Vec<u32> {
        let mut ids = vec![CLS];
        ids.extend(text.split_whitespace().map(|w| self.id(w)));
        ids.push(SEP);
        ids.resize(seq_len, PAD);
        ids.truncate(seq_len);
        ids
    }

    /// Decode ids to text, dropping specials.
    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter()
            .filter(|&&i| i > SEP)
            .map(|&i| self.words.get(i as usize).map(|s| s.as_str()).unwrap_or("?"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Task type (classification / regression).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskType {
    /// Classification (argmax over logits).
    Cls,
    /// Regression (scalar output).
    Reg,
}

/// One split of a GLUE-like task.
#[derive(Clone, Debug, Default)]
pub struct Split {
    /// Token sequences.
    pub ids: Vec<Vec<u32>>,
    /// Gold labels (class index or regression value).
    pub labels: Vec<f32>,
}

/// A GLUE-like synthetic task.
#[derive(Clone, Debug)]
pub struct TaskData {
    /// Task name (`qnli`, `cola`, …).
    pub task: String,
    /// Classification or regression.
    pub ttype: TaskType,
    /// Number of classes (classification).
    pub n_classes: usize,
    /// Fixed sequence length of the examples.
    pub seq_len: usize,
    /// Training split.
    pub train: Split,
    /// Test split.
    pub test: Split,
}

fn parse_split(doc: &Json) -> Split {
    let ids = doc
        .get("ids")
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .map(|row| row.as_arr().unwrap_or(&[]).iter().map(|v| v.as_f64().unwrap_or(0.0) as u32).collect())
        .collect();
    let labels = doc
        .get("labels")
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .map(|v| v.as_f64().unwrap_or(0.0) as f32)
        .collect();
    Split { ids, labels }
}

impl TaskData {
    /// Load `data/task_<task>.json` from the artifact directory.
    pub fn load(artifacts_dir: &str, task: &str) -> Result<Self> {
        let path = Path::new(artifacts_dir).join("data").join(format!("task_{task}.json"));
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("read {}: {e} (run `make artifacts`)", path.display()))?;
        let doc = json::parse(&text).map_err(|e| anyhow::anyhow!("task {task}: {e}"))?;
        Ok(TaskData {
            task: task.to_string(),
            ttype: if doc.get("type").as_str() == Some("reg") { TaskType::Reg } else { TaskType::Cls },
            n_classes: doc.get("n_classes").as_usize().unwrap_or(2),
            seq_len: doc.get("seq_len").as_usize().unwrap_or(32),
            train: parse_split(doc.get("train")),
            test: parse_split(doc.get("test")),
        })
    }

    /// Every synthetic GLUE-like task shipped by data_gen.py.
    pub const ALL_TASKS: [&'static str; 5] = ["qnli", "cola", "stsb", "mrpc", "rte"];
}

/// A Wikitext-like LM corpus.
#[derive(Clone, Debug)]
pub struct LmData {
    /// Corpus name (`wikitext2`, `wikitext103`).
    pub name: String,
    /// Fixed sequence length of the examples.
    pub seq_len: usize,
    /// Training sequences.
    pub train: Vec<Vec<u32>>,
    /// Held-out sequences.
    pub test: Vec<Vec<u32>>,
}

impl LmData {
    /// Load `data/lm_<name>.json` from the artifact directory.
    pub fn load(artifacts_dir: &str, name: &str) -> Result<Self> {
        let path = Path::new(artifacts_dir).join("data").join(format!("lm_{name}.json"));
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("read {}: {e} (run `make artifacts`)", path.display()))?;
        let doc = json::parse(&text).map_err(|e| anyhow::anyhow!("lm {name}: {e}"))?;
        let seqs = |key: &str| -> Vec<Vec<u32>> {
            doc.get(key)
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|row| row.as_arr().unwrap_or(&[]).iter().map(|v| v.as_f64().unwrap_or(0.0) as u32).collect())
                .collect()
        };
        Ok(LmData {
            name: name.to_string(),
            seq_len: doc.get("seq_len").as_usize().unwrap_or(32),
            train: seqs("train"),
            test: seqs("test"),
        })
    }

    /// Every LM corpus shipped by data_gen.py.
    pub const ALL_CORPORA: [&'static str; 2] = ["wikitext2", "wikitext103"];
}

/// Attack corpora: in-distribution private targets + OOD auxiliary data.
#[derive(Clone, Debug)]
pub struct AttackCorpora {
    /// Victim sentences the attacks try to reconstruct.
    pub private: Vec<Vec<u32>>,
    /// Out-of-distribution auxiliary corpus (news templates).
    pub aux: Vec<Vec<u32>>,
    /// In-distribution auxiliary corpus (same template family as private).
    pub aux_indist: Vec<Vec<u32>>,
    /// Fixed sequence length of the sentences.
    pub seq_len: usize,
}

impl AttackCorpora {
    /// Load `data/attack_corpora.json` from the artifact directory.
    pub fn load(artifacts_dir: &str) -> Result<Self> {
        let path = Path::new(artifacts_dir).join("data").join("attack_corpora.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("read {}: {e} (run `make artifacts`)", path.display()))?;
        let doc = json::parse(&text).map_err(|e| anyhow::anyhow!("attack corpora: {e}"))?;
        let seqs = |key: &str| -> Vec<Vec<u32>> {
            doc.get(key)
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|row| row.as_arr().unwrap_or(&[]).iter().map(|v| v.as_f64().unwrap_or(0.0) as u32).collect())
                .collect()
        };
        Ok(AttackCorpora {
            private: seqs("private"),
            aux: seqs("aux"),
            aux_indist: seqs("aux_indist"),
            seq_len: doc.get("seq_len").as_usize().unwrap_or(32),
        })
    }
}

/// Default artifacts directory (overridable with CENTAUR_ARTIFACTS).
pub fn artifacts_dir() -> String {
    std::env::var("CENTAUR_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture() -> String {
        let tmp = std::env::temp_dir().join(format!("centaur_data_{}", std::process::id()));
        let dd = tmp.join("data");
        std::fs::create_dir_all(&dd).unwrap();
        std::fs::write(dd.join("vocab.json"), r#"["[PAD]","[CLS]","[SEP]","[UNK]","london","paris","moved"]"#).unwrap();
        std::fs::write(
            dd.join("task_toy.json"),
            r#"{"task":"toy","type":"cls","n_classes":2,"seq_len":8,
                "train":{"ids":[[1,4,2,0,0,0,0,0]],"labels":[1]},
                "test":{"ids":[[1,5,2,0,0,0,0,0]],"labels":[0]}}"#,
        )
        .unwrap();
        tmp.to_str().unwrap().to_string()
    }

    #[test]
    fn vocab_encode_decode_roundtrip() {
        let dir = write_fixture();
        let v = Vocab::load(&dir).unwrap();
        let ids = v.encode("london moved paris", 8);
        assert_eq!(ids.len(), 8);
        assert_eq!(ids[0], CLS);
        assert_eq!(v.decode(&ids), "london moved paris");
        assert_eq!(v.id("nonexistent-word"), UNK);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn task_load() {
        let dir = write_fixture();
        let t = TaskData::load(&dir, "toy").unwrap();
        assert_eq!(t.ttype, TaskType::Cls);
        assert_eq!(t.train.ids.len(), 1);
        assert_eq!(t.train.labels, vec![1.0]);
        assert_eq!(t.test.ids[0][1], 5);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_artifacts_error_is_actionable() {
        let err = Vocab::load("/definitely/missing").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn specials_are_never_emitted_by_greedy_selection() {
        // Even when every special id dominates the logits, greedy selection
        // must pick a regular token.
        let mut row = vec![0.0f32; 16];
        row[PAD as usize] = 100.0;
        row[CLS as usize] = 99.0;
        row[SEP as usize] = 98.0;
        row[UNK as usize] = 97.0;
        row[9] = 1.0;
        assert_eq!(greedy_regular_token(&row), 9);
        // The constant covers exactly the reserved ids.
        assert_eq!(NUM_SPECIAL_TOKENS, UNK as usize + 1);
        assert!(greedy_regular_token(&row) as usize >= NUM_SPECIAL_TOKENS);
    }

    #[test]
    fn greedy_ties_resolve_to_highest_id() {
        // Matches `Iterator::max_by`: the last maximal element wins — the
        // exact semantics generate() has always used.
        let row = vec![0.0f32; 8];
        assert_eq!(greedy_regular_token(&row), 7);
    }
}
