//! Arithmetic in the ring `Z_{2^64}` on [`RingTensor`]s.
//!
//! Everything here is **wrapping** two's-complement arithmetic — exactly the
//! ring used by CrypTen and the paper (§2.2). The blocked, multi-threaded
//! [`matmul`] is the L3 performance hot spot: every `Π_ScalMul` (plaintext
//! weights × share) and every Beaver-triple `Π_MatMul` lowers to it. Tile
//! sizes were tuned in EXPERIMENTS.md §Perf.
//!
//! The inner kernel is dispatched through
//! [`runtime::kernel`](crate::runtime::kernel) (§Perf iteration 5): SIMD
//! implementations (AVX2/AVX-512/NEON) are runtime-detected and selectable
//! via `CENTAUR_RING_KERNEL`, with [`dot_wrapping`] as the guaranteed
//! bit-identical scalar fallback — wrapping addition commutes, so every
//! kernel produces the same ring element.

use crate::runtime::kernel::{self, RingKernel};
use crate::tensor::RingTensor;

/// Elementwise wrapping addition.
pub fn add(a: &RingTensor, b: &RingTensor) -> RingTensor {
    a.zip_with(b, |x, y| x.wrapping_add(y))
}

/// Elementwise wrapping subtraction.
pub fn sub(a: &RingTensor, b: &RingTensor) -> RingTensor {
    a.zip_with(b, |x, y| x.wrapping_sub(y))
}

/// Elementwise wrapping negation.
pub fn neg(a: &RingTensor) -> RingTensor {
    a.map(|x| x.wrapping_neg())
}

/// Elementwise wrapping Hadamard product.
pub fn mul_elem(a: &RingTensor, b: &RingTensor) -> RingTensor {
    a.zip_with(b, |x, y| x.wrapping_mul(y))
}

/// Multiply every element by a ring scalar.
pub fn scale(a: &RingTensor, s: i64) -> RingTensor {
    a.map(|x| x.wrapping_mul(s))
}

/// Add a broadcast row vector (wrapping).
pub fn add_row(a: &RingTensor, bias: &[i64]) -> RingTensor {
    assert_eq!(bias.len(), a.cols());
    let mut out = a.clone();
    for r in 0..out.rows() {
        for (v, b) in out.row_mut(r).iter_mut().zip(bias) {
            *v = v.wrapping_add(*b);
        }
    }
    out
}

/// In-place `a += b` (wrapping).
pub fn add_assign(a: &mut RingTensor, b: &RingTensor) {
    assert_eq!(a.shape(), b.shape());
    for (x, y) in a.data_mut().iter_mut().zip(b.data()) {
        *x = x.wrapping_add(*y);
    }
}

/// Wrapping dot product, 4-lane unrolled with chunked iterators so the
/// compiler drops all bounds checks (EXPERIMENTS.md §Perf iteration 1:
/// indexed `while` loop → chunks_exact, ~1.2-1.4× on the hot shapes).
/// This is the scalar reference kernel; SIMD variants live in
/// [`runtime::kernel`](crate::runtime::kernel) and must match it bit-exactly.
#[inline]
pub fn dot_wrapping(a: &[i64], b: &[i64]) -> i64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0i64; 4];
    let mut ac = a.chunks_exact(4);
    let mut bc = b.chunks_exact(4);
    for (ca, cb) in (&mut ac).zip(&mut bc) {
        acc[0] = acc[0].wrapping_add(ca[0].wrapping_mul(cb[0]));
        acc[1] = acc[1].wrapping_add(ca[1].wrapping_mul(cb[1]));
        acc[2] = acc[2].wrapping_add(ca[2].wrapping_mul(cb[2]));
        acc[3] = acc[3].wrapping_add(ca[3].wrapping_mul(cb[3]));
    }
    let mut tail = 0i64;
    for (&x, &y) in ac.remainder().iter().zip(bc.remainder()) {
        tail = tail.wrapping_add(x.wrapping_mul(y));
    }
    acc[0]
        .wrapping_add(acc[1])
        .wrapping_add(acc[2])
        .wrapping_add(acc[3])
        .wrapping_add(tail)
}

/// Wrapping matrix product `A (m×k) @ B (k×n)`.
///
/// Implementation notes (perf):
/// * `B` is transposed once so both operands stream row-major.
/// * The inner kernel comes from the [`runtime::kernel`](crate::runtime::kernel)
///   dispatch — explicit-width SIMD where the host supports it, the 4-lane
///   ILP scalar kernel otherwise.
/// * Rows are distributed over the thread pool in contiguous chunks.
pub fn matmul(a: &RingTensor, b: &RingTensor) -> RingTensor {
    assert_eq!(a.cols(), b.rows(), "ring matmul inner dim");
    let bt = b.transpose();
    matmul_nt(a, &bt)
}

/// Wrapping `A (m×k) @ B^T` where `B` is given as `(n×k)` (row-major), the
/// natural layout for weights stored (out_features, in_features).
///
/// Dispatches through the selected [`runtime::kernel`](crate::runtime::kernel)
/// implementation (scalar/AVX2/AVX-512/NEON/xla); rows are distributed over
/// the thread pool in contiguous chunks, so the split is bit-exact by
/// construction and the result is kernel-independent.
pub fn matmul_nt(a: &RingTensor, bt: &RingTensor) -> RingTensor {
    kernel::selected().matmul_nt(a, bt)
}

/// Reference (naive) matmul for testing the blocked kernel.
pub fn matmul_naive(a: &RingTensor, b: &RingTensor) -> RingTensor {
    assert_eq!(a.cols(), b.rows());
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut out = RingTensor::zeros(m, n);
    for r in 0..m {
        for c in 0..n {
            let mut acc = 0i64;
            for i in 0..k {
                acc = acc.wrapping_add(a.get(r, i).wrapping_mul(b.get(i, c)));
            }
            out.set(r, c, acc);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn blocked_matches_naive() {
        check("ring matmul blocked==naive", 25, |g| {
            let m = g.dim(17);
            let k = g.dim(40);
            let n = g.dim(23);
            let a = RingTensor::from_vec(m, k, g.vec_i64(m * k));
            let b = RingTensor::from_vec(k, n, g.vec_i64(k * n));
            assert_eq!(matmul(&a, &b), matmul_naive(&a, &b));
        });
    }

    #[test]
    fn matmul_distributes_over_share_split() {
        // (A @ X0) + (A @ X1) == A @ (X0 + X1) — the algebraic fact behind
        // Π_ScalMul being communication-free.
        check("matmul distributes", 20, |g| {
            let m = g.dim(8);
            let k = g.dim(12);
            let n = g.dim(8);
            let a = RingTensor::from_vec(m, k, g.vec_i64(m * k));
            let x0 = RingTensor::from_vec(k, n, g.vec_i64(k * n));
            let x1 = RingTensor::from_vec(k, n, g.vec_i64(k * n));
            let lhs = add(&matmul(&a, &x0), &matmul(&a, &x1));
            let rhs = matmul(&a, &add(&x0, &x1));
            assert_eq!(lhs, rhs);
        });
    }

    #[test]
    fn add_sub_inverse() {
        check("add/sub inverse", 100, |g| {
            let n = g.dim(32);
            let a = RingTensor::from_vec(1, n, g.vec_i64(n));
            let b = RingTensor::from_vec(1, n, g.vec_i64(n));
            assert_eq!(sub(&add(&a, &b), &b), a);
            assert_eq!(add(&sub(&a, &b), &b), a);
        });
    }

    #[test]
    fn neg_is_additive_inverse() {
        check("neg inverse", 100, |g| {
            let n = g.dim(32);
            let a = RingTensor::from_vec(1, n, g.vec_i64(n));
            let z = add(&a, &neg(&a));
            assert!(z.data().iter().all(|&v| v == 0));
        });
    }

    #[test]
    fn matmul_nt_consistent() {
        check("matmul_nt == matmul(bT)", 20, |g| {
            let m = g.dim(9);
            let k = g.dim(9);
            let n = g.dim(9);
            let a = RingTensor::from_vec(m, k, g.vec_i64(m * k));
            let bt = RingTensor::from_vec(n, k, g.vec_i64(n * k));
            assert_eq!(matmul_nt(&a, &bt), matmul(&a, &bt.transpose()));
        });
    }

    #[test]
    fn wrapping_behaviour_is_modular() {
        let a = RingTensor::from_vec(1, 1, vec![i64::MAX]);
        let b = RingTensor::from_vec(1, 1, vec![1]);
        assert_eq!(add(&a, &b).get(0, 0), i64::MIN); // 2^63-1 + 1 wraps
    }
}
