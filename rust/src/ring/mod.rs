//! Arithmetic in the ring `Z_{2^64}` on [`RingTensor`]s.
//!
//! Everything here is **wrapping** two's-complement arithmetic — exactly the
//! ring used by CrypTen and the paper (§2.2). The blocked, multi-threaded
//! [`matmul`] is the L3 performance hot spot: every `Π_ScalMul` (plaintext
//! weights × share) and every Beaver-triple `Π_MatMul` lowers to it. Tile
//! sizes were tuned in EXPERIMENTS.md §Perf.

use crate::tensor::RingTensor;
use crate::util::pool;

/// Elementwise wrapping addition.
pub fn add(a: &RingTensor, b: &RingTensor) -> RingTensor {
    a.zip_with(b, |x, y| x.wrapping_add(y))
}

/// Elementwise wrapping subtraction.
pub fn sub(a: &RingTensor, b: &RingTensor) -> RingTensor {
    a.zip_with(b, |x, y| x.wrapping_sub(y))
}

/// Elementwise wrapping negation.
pub fn neg(a: &RingTensor) -> RingTensor {
    a.map(|x| x.wrapping_neg())
}

/// Elementwise wrapping Hadamard product.
pub fn mul_elem(a: &RingTensor, b: &RingTensor) -> RingTensor {
    a.zip_with(b, |x, y| x.wrapping_mul(y))
}

/// Multiply every element by a ring scalar.
pub fn scale(a: &RingTensor, s: i64) -> RingTensor {
    a.map(|x| x.wrapping_mul(s))
}

/// Add a broadcast row vector (wrapping).
pub fn add_row(a: &RingTensor, bias: &[i64]) -> RingTensor {
    assert_eq!(bias.len(), a.cols());
    let mut out = a.clone();
    for r in 0..out.rows() {
        for (v, b) in out.row_mut(r).iter_mut().zip(bias) {
            *v = v.wrapping_add(*b);
        }
    }
    out
}

/// In-place `a += b` (wrapping).
pub fn add_assign(a: &mut RingTensor, b: &RingTensor) {
    assert_eq!(a.shape(), b.shape());
    for (x, y) in a.data_mut().iter_mut().zip(b.data()) {
        *x = x.wrapping_add(*y);
    }
}

/// k-tile edge for the blocked matmul. §Perf iteration 2/3: the model
/// dims (d ≤ 1280, k ≤ 5120) run fastest untiled — re-walking the output
/// row per tile cost more than the L1 reuse bought — so the tile only
/// engages for vocabulary-sized inner dims (embedding lookups, k ≈ 50k).
const TILE_K: usize = 4096;

/// Wrapping dot product, 4-lane unrolled with chunked iterators so the
/// compiler drops all bounds checks (EXPERIMENTS.md §Perf iteration 1:
/// indexed `while` loop → chunks_exact, ~1.2-1.4× on the hot shapes).
#[inline]
fn dot_wrapping(a: &[i64], b: &[i64]) -> i64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0i64; 4];
    let mut ac = a.chunks_exact(4);
    let mut bc = b.chunks_exact(4);
    for (ca, cb) in (&mut ac).zip(&mut bc) {
        acc[0] = acc[0].wrapping_add(ca[0].wrapping_mul(cb[0]));
        acc[1] = acc[1].wrapping_add(ca[1].wrapping_mul(cb[1]));
        acc[2] = acc[2].wrapping_add(ca[2].wrapping_mul(cb[2]));
        acc[3] = acc[3].wrapping_add(ca[3].wrapping_mul(cb[3]));
    }
    let mut tail = 0i64;
    for (&x, &y) in ac.remainder().iter().zip(bc.remainder()) {
        tail = tail.wrapping_add(x.wrapping_mul(y));
    }
    acc[0]
        .wrapping_add(acc[1])
        .wrapping_add(acc[2])
        .wrapping_add(acc[3])
        .wrapping_add(tail)
}

/// Wrapping matrix product `A (m×k) @ B (k×n)`.
///
/// Implementation notes (perf):
/// * `B` is transposed once so both operands stream row-major.
/// * The inner kernel accumulates in four independent lanes to expose ILP —
///   wrapping i64 mul/add vectorize on AVX2 (`vpmullq` fallback is fine).
/// * Rows are distributed over the thread pool in contiguous chunks.
pub fn matmul(a: &RingTensor, b: &RingTensor) -> RingTensor {
    assert_eq!(a.cols(), b.rows(), "ring matmul inner dim");
    let bt = b.transpose();
    matmul_nt(a, &bt)
}

/// Wrapping `A (m×k) @ B^T` where `B` is given as `(n×k)` (row-major), the
/// natural layout for weights stored (out_features, in_features).
pub fn matmul_nt(a: &RingTensor, bt: &RingTensor) -> RingTensor {
    assert_eq!(a.cols(), bt.cols(), "ring matmul_nt inner dim");
    let (m, k, n) = (a.rows(), a.cols(), bt.rows());
    let mut out = RingTensor::zeros(m, n);
    let rows_per_chunk = 1usize.max(m.div_ceil(pool::num_threads() * 2));
    let chunk_elems = rows_per_chunk * n;
    let a_data = a.data();
    let bt_data = bt.data();
    pool::par_chunks_mut(out.data_mut(), chunk_elems, |ci, chunk| {
        let r0 = ci * rows_per_chunk;
        let rows_here = chunk.len() / n;
        for dr in 0..rows_here {
            let r = r0 + dr;
            let arow = &a_data[r * k..(r + 1) * k];
            let orow = &mut chunk[dr * n..(dr + 1) * n];
            // k-tiling keeps arow tile in L1 across all n columns.
            for k0 in (0..k).step_by(TILE_K) {
                let k1 = (k0 + TILE_K).min(k);
                for c in 0..n {
                    let brow = &bt_data[c * k + k0..c * k + k1];
                    let atile = &arow[k0..k1];
                    orow[c] = orow[c].wrapping_add(dot_wrapping(atile, brow));
                }
            }
        }
    });
    out
}

/// Reference (naive) matmul for testing the blocked kernel.
pub fn matmul_naive(a: &RingTensor, b: &RingTensor) -> RingTensor {
    assert_eq!(a.cols(), b.rows());
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut out = RingTensor::zeros(m, n);
    for r in 0..m {
        for c in 0..n {
            let mut acc = 0i64;
            for i in 0..k {
                acc = acc.wrapping_add(a.get(r, i).wrapping_mul(b.get(i, c)));
            }
            out.set(r, c, acc);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn blocked_matches_naive() {
        check("ring matmul blocked==naive", 25, |g| {
            let m = g.dim(17);
            let k = g.dim(40);
            let n = g.dim(23);
            let a = RingTensor::from_vec(m, k, g.vec_i64(m * k));
            let b = RingTensor::from_vec(k, n, g.vec_i64(k * n));
            assert_eq!(matmul(&a, &b), matmul_naive(&a, &b));
        });
    }

    #[test]
    fn matmul_distributes_over_share_split() {
        // (A @ X0) + (A @ X1) == A @ (X0 + X1) — the algebraic fact behind
        // Π_ScalMul being communication-free.
        check("matmul distributes", 20, |g| {
            let m = g.dim(8);
            let k = g.dim(12);
            let n = g.dim(8);
            let a = RingTensor::from_vec(m, k, g.vec_i64(m * k));
            let x0 = RingTensor::from_vec(k, n, g.vec_i64(k * n));
            let x1 = RingTensor::from_vec(k, n, g.vec_i64(k * n));
            let lhs = add(&matmul(&a, &x0), &matmul(&a, &x1));
            let rhs = matmul(&a, &add(&x0, &x1));
            assert_eq!(lhs, rhs);
        });
    }

    #[test]
    fn add_sub_inverse() {
        check("add/sub inverse", 100, |g| {
            let n = g.dim(32);
            let a = RingTensor::from_vec(1, n, g.vec_i64(n));
            let b = RingTensor::from_vec(1, n, g.vec_i64(n));
            assert_eq!(sub(&add(&a, &b), &b), a);
            assert_eq!(add(&sub(&a, &b), &b), a);
        });
    }

    #[test]
    fn neg_is_additive_inverse() {
        check("neg inverse", 100, |g| {
            let n = g.dim(32);
            let a = RingTensor::from_vec(1, n, g.vec_i64(n));
            let z = add(&a, &neg(&a));
            assert!(z.data().iter().all(|&v| v == 0));
        });
    }

    #[test]
    fn matmul_nt_consistent() {
        check("matmul_nt == matmul(bT)", 20, |g| {
            let m = g.dim(9);
            let k = g.dim(9);
            let n = g.dim(9);
            let a = RingTensor::from_vec(m, k, g.vec_i64(m * k));
            let bt = RingTensor::from_vec(n, k, g.vec_i64(n * k));
            assert_eq!(matmul_nt(&a, &bt), matmul(&a, &bt.transpose()));
        });
    }

    #[test]
    fn wrapping_behaviour_is_modular() {
        let a = RingTensor::from_vec(1, 1, vec![i64::MAX]);
        let b = RingTensor::from_vec(1, 1, vec![1]);
        assert_eq!(add(&a, &b).get(0, 0), i64::MIN); // 2^63-1 + 1 wraps
    }
}
