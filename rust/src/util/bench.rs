//! Micro-benchmark harness (offline mirror has no `criterion`).
//!
//! Used by the `benches/*.rs` targets (built with `harness = false`).
//! Each benchmark runs a warmup phase, then timed iterations until a
//! minimum wall budget is reached, and reports min/median/p95/mean.

use std::time::{Duration, Instant};

/// Result statistics for one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    /// Benchmark label.
    pub name: String,
    /// Timed iterations taken.
    pub iters: usize,
    /// Fastest iteration.
    pub min: Duration,
    /// Median iteration.
    pub median: Duration,
    /// 95th-percentile iteration.
    pub p95: Duration,
    /// Mean iteration.
    pub mean: Duration,
}

impl BenchStats {
    /// One formatted result row.
    pub fn line(&self) -> String {
        format!(
            "{:<44} iters={:<6} min={:>12} med={:>12} p95={:>12} mean={:>12}",
            self.name,
            self.iters,
            crate::util::human_secs(self.min.as_secs_f64()),
            crate::util::human_secs(self.median.as_secs_f64()),
            crate::util::human_secs(self.p95.as_secs_f64()),
            crate::util::human_secs(self.mean.as_secs_f64()),
        )
    }
}

/// Benchmark runner with a fixed time budget per benchmark.
pub struct Bencher {
    /// Minimum total measured time before stopping.
    pub budget: Duration,
    /// Maximum number of iterations regardless of budget.
    pub max_iters: usize,
    /// Warmup iterations.
    pub warmup: usize,
    results: Vec<BenchStats>,
}

impl Default for Bencher {
    fn default() -> Self {
        let quick = std::env::var("CENTAUR_BENCH_QUICK").is_ok();
        Bencher {
            budget: if quick { Duration::from_millis(200) } else { Duration::from_secs(2) },
            max_iters: if quick { 20 } else { 1000 },
            warmup: if quick { 1 } else { 3 },
            results: Vec::new(),
        }
    }
}

impl Bencher {
    /// Bencher with the default (env-sensitive) budget.
    pub fn new() -> Self {
        Self::default()
    }

    /// Bencher with explicit limits — for workloads where the iteration
    /// count matters (e.g. draining a prefilled triple pool).
    pub fn with(budget: Duration, max_iters: usize, warmup: usize) -> Self {
        Bencher { budget, max_iters, warmup, results: Vec::new() }
    }

    /// Time `f`, which should perform one full iteration of the workload.
    /// Use `std::hint::black_box` inside `f` to defeat DCE.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> BenchStats {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while (start.elapsed() < self.budget && samples.len() < self.max_iters)
            || samples.len() < 3
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        samples.sort_unstable();
        let iters = samples.len();
        let stats = BenchStats {
            name: name.to_string(),
            iters,
            min: samples[0],
            median: samples[iters / 2],
            p95: samples[(iters * 95 / 100).min(iters - 1)],
            mean: samples.iter().sum::<Duration>() / iters as u32,
        };
        println!("{}", stats.line());
        self.results.push(stats.clone());
        stats
    }

    /// All results gathered so far.
    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }

    /// Print a section header.
    pub fn section(&self, title: &str) {
        println!("\n== {title} ==");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let mut b = Bencher { budget: Duration::from_millis(5), max_iters: 50, warmup: 1, results: vec![] };
        let s = b.bench("noop-spin", || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(std::hint::black_box(i));
            }
            std::hint::black_box(acc);
        });
        assert!(s.iters >= 3);
        assert!(s.min <= s.median && s.median <= s.p95);
    }
}
