//! Deterministic pseudo-random number generation.
//!
//! The offline crate mirror has no `rand`, so we implement xoshiro256++
//! (Blackman & Vigna) seeded through SplitMix64. This is used for
//! *simulation* randomness (weights, synthetic data, share masks in the
//! simulator). A production deployment would replace share-mask generation
//! with an OS CSPRNG; the protocol logic is agnostic to the source.

/// SplitMix64 step — used to expand a single `u64` seed into a full state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Not cryptographically secure; deterministic and fast.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child generator (for per-party / per-op seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut seed = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        let mut sm = splitmix64(&mut seed);
        Rng::new(splitmix64(&mut sm))
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next value as a signed ring element (uniform over `Z_{2^64}`).
    #[inline]
    pub fn next_i64(&mut self) -> i64 {
        self.next_u64() as i64
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our use).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_f64() * n as f64) as usize % n
    }

    /// Uniform in the inclusive range `[lo, hi]`.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    /// Standard normal via Box-Muller.
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 > 1e-300 {
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fill a slice with uniform ring elements.
    pub fn fill_i64(&mut self, out: &mut [i64]) {
        for v in out.iter_mut() {
            *v = self.next_i64();
        }
    }

    /// Vector of `n` uniform ring elements.
    pub fn vec_i64(&mut self, n: usize) -> Vec<i64> {
        (0..n).map(|_| self.next_i64()).collect()
    }

    /// Vector of `n` gaussian f32 scaled by `std`.
    pub fn vec_gaussian_f32(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.next_gaussian() as f32 * std).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n` (as index vector).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Choose one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(123);
        let mut b = Rng::new(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for n in [1usize, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn permutation_is_bijection() {
        let mut r = Rng::new(13);
        let p = r.permutation(257);
        let mut seen = vec![false; 257];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn fork_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
