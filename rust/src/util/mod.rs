//! Small self-contained utilities replacing crates unavailable in the
//! offline mirror (see DESIGN.md §Offline-dependency substitutions).

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;

/// Format a byte count as a human-readable string (e.g. `94.0 GB`).
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KB", "MB", "GB", "TB", "PB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1000.0 && u + 1 < UNITS.len() {
        v /= 1000.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", bytes, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Format a duration in seconds with adaptive units.
pub fn human_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{:.2} s", s)
    } else {
        format!("{:.1} min", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(0), "0 B");
        assert_eq!(human_bytes(999), "999 B");
        assert_eq!(human_bytes(1500), "1.50 KB");
        assert_eq!(human_bytes(94_000_000_000), "94.00 GB");
    }

    #[test]
    fn human_secs_units() {
        assert_eq!(human_secs(0.5e-9 * 100.0), "50.0 ns");
        assert_eq!(human_secs(2e-3), "2.0 ms");
        assert_eq!(human_secs(3.0), "3.00 s");
        assert_eq!(human_secs(600.0), "10.0 min");
    }
}
