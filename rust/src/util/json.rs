//! Minimal JSON reader/writer (offline mirror has no `serde`).
//!
//! Supports the full JSON grammar needed by our manifests and config files:
//! objects, arrays, strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (f64, as in JavaScript).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Json>),
    /// JSON object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    /// Numeric value as `usize` — strict: `None` unless this is a finite,
    /// non-negative number with zero fractional part that fits in `usize`.
    /// (The old lossy version truncated `-1` and `2.7` to something
    /// plausible, which is how malformed manifests silently became
    /// zero-sized models.)
    pub fn as_usize(&self) -> Option<usize> {
        match self.as_f64() {
            Some(n) if n.is_finite() && n.fract() == 0.0 && n >= 0.0 && n < usize::MAX as f64 => {
                Some(n as usize)
            }
            _ => None,
        }
    }
    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    /// Boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// Element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// Key→value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; returns `Json::Null` when missing.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(a: Vec<Json>) -> Self {
        Json::Arr(a)
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    /// Byte offset of the failure in the input.
    pub pos: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, JsonError> {
        Err(JsonError { pos: self.pos, msg: msg.to_string() })
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(&format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("unexpected character"),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            self.err(&format!("expected '{word}'"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return self.err("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| JsonError { pos: self.pos, msg: "bad utf8".into() })?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError { pos: self.pos, msg: "bad hex".into() })?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.pos += 1;
                }
                Some(c) => {
                    // Consume one UTF-8 code point.
                    let len = match c {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (self.pos + len).min(self.b.len());
                    s.push_str(std::str::from_utf8(&self.b[self.pos..end]).unwrap_or("\u{FFFD}"));
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { pos: start, msg: format!("bad number '{txt}'") })
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return p.err("trailing characters");
    }
    Ok(v)
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_value(self, &mut s, 0, false);
        f.write_str(&s)
    }
}

impl Json {
    /// Pretty-printed (2-space indented) serialization.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        write_value(self, &mut s, 0, true);
        s
    }
}

fn write_value(v: &Json, out: &mut String, indent: usize, pretty: bool) {
    let pad = |out: &mut String, n: usize| {
        if pretty {
            out.push('\n');
            for _ in 0..n {
                out.push_str("  ");
            }
        }
    };
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{}", n));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                    if pretty {
                        out.push(' ');
                    }
                }
                write_value(x, out, indent + 1, false);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, x)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                pad(out, indent + 1);
                escape(k, out);
                out.push(':');
                if pretty {
                    out.push(' ');
                }
                write_value(x, out, indent + 1, pretty);
            }
            pad(out, indent);
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let doc = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("a").as_f64(), Some(1.0));
        assert_eq!(v.get("b").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").get("d").as_f64(), Some(-2500.0));
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""A\t\"q\"""#).unwrap();
        assert_eq!(v.as_str(), Some("A\t\"q\""));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![
            ("name", "bert-tiny".into()),
            ("layers", 2usize.into()),
            ("arr", Json::Arr(vec![1usize.into(), 2usize.into()])),
        ]);
        assert_eq!(parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn as_usize_is_strict() {
        assert_eq!(parse("17").unwrap().as_usize(), Some(17));
        assert_eq!(parse("0").unwrap().as_usize(), Some(0));
        assert_eq!(parse("-1").unwrap().as_usize(), None, "negatives must not truncate to 0");
        assert_eq!(parse("2.7").unwrap().as_usize(), None, "fractions must not truncate");
        assert_eq!(parse("1e300").unwrap().as_usize(), None, "overflow must not saturate");
        assert_eq!(parse("\"12\"").unwrap().as_usize(), None, "strings are not numbers");
        assert_eq!(Json::Null.as_usize(), None);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ✓"));
    }
}
