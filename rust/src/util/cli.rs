//! Tiny command-line parser (offline mirror has no `clap`).
//!
//! Grammar: `prog <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (subcommand), if any.
    pub command: Option<String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
    /// `--key value` options.
    pub options: BTreeMap<String, String>,
    /// `--flag` booleans (a `--key` with no value).
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let toks: Vec<String> = tokens.into_iter().collect();
        let mut args = Args::default();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(name) = t.strip_prefix("--") {
                // `--key=value` or `--key value` or bare flag.
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    args.options.insert(name.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(t.clone());
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        args
    }

    /// Parse the process command line.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Whether `--name` was passed as a bare flag.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Value of `--name value`, if present.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Value of `--name`, or `default`.
    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    /// `--name` parsed as `usize`, or `default`.
    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// `--name` parsed as `u64`, or `default`.
    pub fn opt_u64(&self, name: &str, default: u64) -> u64 {
        self.opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// `--name` parsed as `f64`, or `default`.
    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("report table1 --model bert-base --net wan1 --verbose");
        assert_eq!(a.command.as_deref(), Some("report"));
        assert_eq!(a.positional, vec!["table1"]);
        assert_eq!(a.opt("model"), Some("bert-base"));
        assert_eq!(a.opt("net"), Some("wan1"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = parse("serve --port=8080 --batch=16");
        assert_eq!(a.opt_usize("port", 0), 8080);
        assert_eq!(a.opt_usize("batch", 0), 16);
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.opt_or("missing", "dflt"), "dflt");
        assert_eq!(a.opt_f64("nope", 1.5), 1.5);
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn flag_before_value_option() {
        let a = parse("run --fast --model tiny");
        assert!(a.flag("fast"));
        assert_eq!(a.opt("model"), Some("tiny"));
    }
}
