//! Scoped data-parallel helpers over `std::thread` (no `rayon` offline).
//!
//! The ring matmul and Beaver generation use [`par_chunks_mut`] to split an
//! output buffer across OS threads. Thread count defaults to the host
//! parallelism and can be capped with the `CENTAUR_THREADS` env var. The
//! cap is cached after the first read; benches/tests that vary it
//! mid-process must call [`refresh_threads`] (or [`set_num_threads`]) —
//! without that, a `set_var` after the first parallel loop is silently
//! ignored and everything keeps running at the stale width.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Cached worker count; 0 = not yet resolved.
static CACHED: AtomicUsize = AtomicUsize::new(0);

/// Number of worker threads to use for data-parallel loops.
pub fn num_threads() -> usize {
    let c = CACHED.load(Ordering::Relaxed);
    if c != 0 {
        return c;
    }
    let n = threads_from_env();
    CACHED.store(n, Ordering::Relaxed);
    n
}

/// Resolve the worker count from `CENTAUR_THREADS` / host parallelism
/// (no caching — [`num_threads`] wraps this).
fn threads_from_env() -> usize {
    std::env::var("CENTAUR_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4))
}

/// Override the worker count programmatically (clamped to ≥ 1). Takes
/// precedence over `CENTAUR_THREADS` until [`refresh_threads`] is called.
pub fn set_num_threads(n: usize) {
    CACHED.store(n.max(1), Ordering::Relaxed);
}

/// Drop the cached worker count so the next [`num_threads`] call re-reads
/// `CENTAUR_THREADS` — the documented path for benches/tests that vary the
/// cap mid-process.
pub fn refresh_threads() {
    CACHED.store(0, Ordering::Relaxed);
}

/// Run `f(chunk_index, chunk)` over disjoint mutable chunks of `data`,
/// one task per chunk, across up to [`num_threads`] threads. `chunk_len`
/// is expressed in *elements*; the final chunk may be shorter.
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], chunk_len: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = data.len();
    if n == 0 {
        return;
    }
    let chunk_len = chunk_len.max(1);
    let n_chunks = n.div_ceil(chunk_len);
    let threads = num_threads().min(n_chunks);
    if threads <= 1 {
        for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(i, chunk);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    // Pre-split into chunk pointers so each worker can claim chunks by index.
    let chunks: Vec<&mut [T]> = data.chunks_mut(chunk_len).collect();
    // SAFETY-free approach: wrap in Mutex-free claim-by-index using raw parts
    // is unnecessary — std::thread::scope + a Vec of Mutex<Option<&mut [T]>>
    // would serialize. Instead hand each worker an interleaved set.
    let chunks: Vec<std::sync::Mutex<Option<&mut [T]>>> =
        chunks.into_iter().map(|c| std::sync::Mutex::new(Some(c))).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= chunks.len() {
                    break;
                }
                let chunk = chunks[i].lock().unwrap().take();
                if let Some(chunk) = chunk {
                    f(i, chunk);
                }
            });
        }
    });
}

/// Parallel map over indices `0..n` collecting results in order.
pub fn par_map<T: Send, F>(n: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Sync,
    T: Default + Clone,
{
    let mut out = vec![T::default(); n];
    // One binding for the chunk length: the base-index computation below
    // must use the *same* value par_chunks_mut splits with — recomputing
    // it from num_threads() in two places drifted when the cached width
    // changed between the two reads (refresh_threads from another thread),
    // scattering results to wrong indices.
    let chunk_len = 1usize.max(n.div_ceil(num_threads() * 4));
    par_chunks_mut(&mut out, chunk_len, |ci, chunk| {
        let base = ci * chunk_len;
        for (j, slot) in chunk.iter_mut().enumerate() {
            *slot = f(base + j);
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    /// Serialize tests that mutate the global thread-count cache.
    static CACHE_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn chunks_cover_everything() {
        let mut v = vec![0u64; 10_007];
        par_chunks_mut(&mut v, 128, |ci, chunk| {
            for (j, x) in chunk.iter_mut().enumerate() {
                *x = (ci * 128 + j) as u64 + 1;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i as u64 + 1);
        }
    }

    #[test]
    fn empty_ok() {
        let mut v: Vec<u8> = vec![];
        par_chunks_mut(&mut v, 8, |_, _| panic!("should not be called"));
    }

    #[test]
    fn par_map_order() {
        let out = par_map(1000, |i| i * 3);
        for (i, &x) in out.iter().enumerate() {
            assert_eq!(x, i * 3);
        }
    }

    #[test]
    fn par_map_non_divisible_across_widths() {
        // Regression for the chunk-length drift: non-divisible n over odd
        // widths must land every result at its own index, at every width.
        let _g = CACHE_LOCK.lock().unwrap();
        for width in [1usize, 2, 3, 5, 7] {
            set_num_threads(width);
            for n in [1usize, 9, 10, 97, 10_007] {
                let out = par_map(n, |i| i as u64 * 7 + 1);
                for (i, &x) in out.iter().enumerate() {
                    assert_eq!(x, i as u64 * 7 + 1, "width={width} n={n} i={i}");
                }
            }
        }
        refresh_threads();
    }

    #[test]
    fn thread_cap_refresh_is_honored() {
        // Regression: the first read used to be cached forever, so a
        // mid-process CENTAUR_THREADS change was silently ignored.
        let _g = CACHE_LOCK.lock().unwrap();
        let before = std::env::var("CENTAUR_THREADS").ok();
        let _ = num_threads(); // populate the cache
        std::env::set_var("CENTAUR_THREADS", "3");
        refresh_threads();
        assert_eq!(num_threads(), 3);
        std::env::set_var("CENTAUR_THREADS", "5");
        assert_eq!(num_threads(), 3, "without refresh the cache must hold");
        refresh_threads();
        assert_eq!(num_threads(), 5);
        set_num_threads(2);
        assert_eq!(num_threads(), 2, "programmatic override wins");
        match before {
            Some(v) => std::env::set_var("CENTAUR_THREADS", v),
            None => std::env::remove_var("CENTAUR_THREADS"),
        }
        refresh_threads();
    }
}
