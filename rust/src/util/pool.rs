//! Scoped data-parallel helpers over `std::thread` (no `rayon` offline).
//!
//! The ring matmul and Beaver generation use [`par_chunks_mut`] to split an
//! output buffer across OS threads. Thread count defaults to the host
//! parallelism and can be capped with the `CENTAUR_THREADS` env var.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use for data-parallel loops.
pub fn num_threads() -> usize {
    static CACHED: AtomicUsize = AtomicUsize::new(0);
    let c = CACHED.load(Ordering::Relaxed);
    if c != 0 {
        return c;
    }
    let n = std::env::var("CENTAUR_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        });
    CACHED.store(n, Ordering::Relaxed);
    n
}

/// Run `f(chunk_index, chunk)` over disjoint mutable chunks of `data`,
/// one task per chunk, across up to [`num_threads`] threads. `chunk_rows`
/// is expressed in *elements*; the final chunk may be shorter.
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], chunk_len: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = data.len();
    if n == 0 {
        return;
    }
    let chunk_len = chunk_len.max(1);
    let n_chunks = n.div_ceil(chunk_len);
    let threads = num_threads().min(n_chunks);
    if threads <= 1 {
        for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(i, chunk);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    // Pre-split into chunk pointers so each worker can claim chunks by index.
    let chunks: Vec<&mut [T]> = data.chunks_mut(chunk_len).collect();
    // SAFETY-free approach: wrap in Mutex-free claim-by-index using raw parts
    // is unnecessary — std::thread::scope + a Vec of Mutex<Option<&mut [T]>>
    // would serialize. Instead hand each worker an interleaved set.
    let chunks: Vec<std::sync::Mutex<Option<&mut [T]>>> =
        chunks.into_iter().map(|c| std::sync::Mutex::new(Some(c))).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= chunks.len() {
                    break;
                }
                let chunk = chunks[i].lock().unwrap().take();
                if let Some(chunk) = chunk {
                    f(i, chunk);
                }
            });
        }
    });
}

/// Parallel map over indices `0..n` collecting results in order.
pub fn par_map<T: Send, F>(n: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Sync,
    T: Default + Clone,
{
    let mut out = vec![T::default(); n];
    par_chunks_mut(&mut out, 1usize.max(n.div_ceil(num_threads() * 4)), |ci, chunk| {
        let base = ci * 1usize.max(n.div_ceil(num_threads() * 4));
        for (j, slot) in chunk.iter_mut().enumerate() {
            *slot = f(base + j);
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything() {
        let mut v = vec![0u64; 10_007];
        par_chunks_mut(&mut v, 128, |ci, chunk| {
            for (j, x) in chunk.iter_mut().enumerate() {
                *x = (ci * 128 + j) as u64 + 1;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i as u64 + 1);
        }
    }

    #[test]
    fn empty_ok() {
        let mut v: Vec<u8> = vec![];
        par_chunks_mut(&mut v, 8, |_, _| panic!("should not be called"));
    }

    #[test]
    fn par_map_order() {
        let out = par_map(1000, |i| i * 3);
        for (i, &x) in out.iter().enumerate() {
            assert_eq!(x, i * 3);
        }
    }
}
