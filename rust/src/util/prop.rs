//! Lightweight property-based testing harness (offline mirror has no
//! `proptest`). Provides seeded case generation with failure reporting and
//! a simple halving shrinker for numeric sizes.
//!
//! Usage:
//! ```no_run
//! use centaur::util::prop::{check, Gen};
//! check("add commutes", 100, |g: &mut Gen| {
//!     let a = g.i64();
//!     let b = g.i64();
//!     assert_eq!(a.wrapping_add(b), b.wrapping_add(a));
//! });
//! ```

use crate::util::rng::Rng;

/// Per-case generator handed to the property closure.
pub struct Gen {
    rng: Rng,
    /// Case index (0-based) — useful for size scaling.
    pub case: usize,
}

impl Gen {
    /// Uniform ring element.
    pub fn i64(&mut self) -> i64 {
        self.rng.next_i64()
    }
    /// Uniform 64-bit value.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }
    /// Small magnitude value — typical fixed-point-safe activation range.
    pub fn small_f64(&mut self) -> f64 {
        (self.rng.next_f64() - 0.5) * 16.0
    }
    /// Uniform in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }
    /// Dimension in `[1, max]`, biased toward small and boundary values.
    pub fn dim(&mut self, max: usize) -> usize {
        match self.rng.below(10) {
            0 => 1,
            1 => max,
            2 => 2,
            _ => 1 + self.rng.below(max),
        }
    }
    /// Uniform index in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        self.rng.below(n)
    }
    /// Fair coin.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
    /// `n` uniform ring elements.
    pub fn vec_i64(&mut self, n: usize) -> Vec<i64> {
        self.rng.vec_i64(n)
    }
    /// `n` small-magnitude values (see [`Gen::small_f64`]).
    pub fn vec_small_f64(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.small_f64()).collect()
    }
    /// Access the underlying RNG (e.g. for shuffles).
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Seed for the whole property run; override with `CENTAUR_PROP_SEED` to
/// reproduce a CI failure locally.
fn base_seed() -> u64 {
    std::env::var("CENTAUR_PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xC3A7A0Fu64)
}

/// Run `cases` random cases of the property. The property signals failure by
/// panicking (use `assert!`); on failure we re-raise with the case seed so
/// the exact case can be replayed.
pub fn check<F: Fn(&mut Gen)>(name: &str, cases: usize, prop: F) {
    let seed0 = base_seed();
    for case in 0..cases {
        let seed = seed0 ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen { rng: Rng::new(seed), case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}, \
                 set CENTAUR_PROP_SEED={seed0} to replay): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("wrapping add commutes", 200, |g| {
            let (a, b) = (g.i64(), g.i64());
            assert_eq!(a.wrapping_add(b), b.wrapping_add(a));
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failures() {
        check("always fails", 5, |_g| panic!("boom"));
    }

    #[test]
    fn dims_in_range() {
        check("dim bounds", 300, |g| {
            let d = g.dim(64);
            assert!((1..=64).contains(&d));
        });
    }
}
