//! Model weights: CTWB checkpoint loading (written by
//! `python/compile/train_tiny.py::export_ctwb`) and seeded random
//! initialization for the paper-scale efficiency experiments.

use std::collections::BTreeMap;
use std::path::Path;

use super::config::{ModelConfig, ModelKind};
use crate::tensor::FloatTensor;
use crate::util::json;
use crate::util::rng::Rng;
use crate::Result;

/// One transformer layer's parameters (storage layout (out, in), matching
/// `python/compile/model.py::init_params`).
#[derive(Clone, Debug)]
pub struct LayerWeights {
    /// Query projection `(d, d)`.
    pub wq: FloatTensor,
    /// Query bias.
    pub bq: Vec<f32>,
    /// Key projection `(d, d)`.
    pub wk: FloatTensor,
    /// Key bias.
    pub bk: Vec<f32>,
    /// Value projection `(d, d)`.
    pub wv: FloatTensor,
    /// Value bias.
    pub bv: Vec<f32>,
    /// Attention output projection `(d, d)`.
    pub wo: FloatTensor,
    /// Output bias.
    pub bo: Vec<f32>,
    /// First LayerNorm gain.
    pub ln1_g: Vec<f32>,
    /// First LayerNorm bias.
    pub ln1_b: Vec<f32>,
    /// FFN up-projection `(k, d)`.
    pub w1: FloatTensor,
    /// FFN up bias.
    pub b1: Vec<f32>,
    /// FFN down-projection `(d, k)`.
    pub w2: FloatTensor,
    /// FFN down bias.
    pub b2: Vec<f32>,
    /// Second LayerNorm gain.
    pub ln2_g: Vec<f32>,
    /// Second LayerNorm bias.
    pub ln2_b: Vec<f32>,
}

/// Full parameter set of a model.
#[derive(Clone, Debug)]
pub struct ModelWeights {
    /// Word embedding table `(vocab, d)`.
    pub emb_word: FloatTensor, // (vocab, d)
    /// Position embedding table `(n_ctx, d)`.
    pub emb_pos: FloatTensor,  // (n_ctx, d)
    /// Embedding LayerNorm gain.
    pub emb_ln_g: Vec<f32>,
    /// Embedding LayerNorm bias.
    pub emb_ln_b: Vec<f32>,
    /// Transformer layers.
    pub layers: Vec<LayerWeights>,
    /// BERT adaptation (None for GPT-2).
    pub pooler_w: Option<FloatTensor>,
    /// BERT pooler bias.
    pub pooler_b: Option<Vec<f32>>,
    /// BERT classifier weight `(n_classes, d)`.
    pub cls_w: Option<FloatTensor>,
    /// BERT classifier bias.
    pub cls_b: Option<Vec<f32>>,
    /// GPT-2 final LayerNorm (None for BERT).
    pub final_ln_g: Option<Vec<f32>>,
    /// GPT-2 final LayerNorm bias.
    pub final_ln_b: Option<Vec<f32>>,
}

impl ModelWeights {
    /// Seeded gaussian init (std 0.02), mirroring the python initializer.
    pub fn random(cfg: &ModelConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut mat = |r: usize, c: usize| {
            FloatTensor::from_vec(r, c, rng.vec_gaussian_f32(r * c, 0.02))
        };
        let layers = (0..cfg.layers)
            .map(|_| LayerWeights {
                wq: mat(cfg.d, cfg.d),
                bq: vec![0.0; cfg.d],
                wk: mat(cfg.d, cfg.d),
                bk: vec![0.0; cfg.d],
                wv: mat(cfg.d, cfg.d),
                bv: vec![0.0; cfg.d],
                wo: mat(cfg.d, cfg.d),
                bo: vec![0.0; cfg.d],
                ln1_g: vec![1.0; cfg.d],
                ln1_b: vec![0.0; cfg.d],
                w1: mat(cfg.k, cfg.d),
                b1: vec![0.0; cfg.k],
                w2: mat(cfg.d, cfg.k),
                b2: vec![0.0; cfg.d],
                ln2_g: vec![1.0; cfg.d],
                ln2_b: vec![0.0; cfg.d],
            })
            .collect();
        let is_bert = cfg.kind == ModelKind::Bert;
        ModelWeights {
            emb_word: mat(cfg.vocab, cfg.d),
            emb_pos: mat(cfg.n_ctx, cfg.d),
            emb_ln_g: vec![1.0; cfg.d],
            emb_ln_b: vec![0.0; cfg.d],
            layers,
            pooler_w: is_bert.then(|| mat(cfg.d, cfg.d)),
            pooler_b: is_bert.then(|| vec![0.0; cfg.d]),
            cls_w: is_bert.then(|| mat(cfg.n_classes, cfg.d)),
            cls_b: is_bert.then(|| vec![0.0; cfg.n_classes]),
            final_ln_g: (!is_bert).then(|| vec![1.0; cfg.d]),
            final_ln_b: (!is_bert).then(|| vec![0.0; cfg.d]),
        }
    }

    /// Load a CTWB checkpoint directory (`manifest.json` + `weights.bin`).
    /// Returns the (possibly task-specific) config together with weights.
    pub fn load(dir: &Path) -> Result<(ModelConfig, Self)> {
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| anyhow::anyhow!("read {}/manifest.json: {e}", dir.display()))?;
        let man = json::parse(&manifest_text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let kind = match man.get("kind").as_str() {
            Some("bert") => ModelKind::Bert,
            Some("gpt2") => ModelKind::Gpt2,
            other => anyhow::bail!("bad kind {other:?}"),
        };
        // Required numeric fields fail loudly: a missing or malformed "d"
        // used to default to 0 and surface much later as an empty model or
        // an out-of-range panic with no hint which manifest field was bad.
        let req = |field: &str| -> Result<usize> {
            match man.get(field) {
                json::Json::Null => anyhow::bail!(
                    "manifest {}/manifest.json: missing required field '{field}'",
                    dir.display()
                ),
                v => v.as_usize().ok_or_else(|| {
                    anyhow::anyhow!(
                        "manifest {}/manifest.json: field '{field}' is {v}, expected a non-negative integer",
                        dir.display()
                    )
                }),
            }
        };
        let cfg = ModelConfig {
            name: man.get("model").as_str().unwrap_or("?").to_string(),
            kind,
            vocab: req("vocab")?,
            n_ctx: req("n_ctx")?,
            d: req("d")?,
            h: req("h")?,
            layers: req("layers")?,
            k: req("k")?,
            // Optional with a default, but present-and-malformed still errors.
            n_classes: match man.get("n_classes") {
                json::Json::Null => 2,
                _ => req("n_classes")?,
            },
        };
        let blob = std::fs::read(dir.join("weights.bin"))
            .map_err(|e| anyhow::anyhow!("read weights.bin: {e}"))?;
        let mut tensors: BTreeMap<String, FloatTensor> = BTreeMap::new();
        for t in man.get("tensors").as_arr().unwrap_or(&[]) {
            let name = match t.get("name").as_str() {
                Some(n) if !n.is_empty() => n.to_string(),
                _ => anyhow::bail!(
                    "manifest {}/manifest.json: tensor entry {t} has no 'name'",
                    dir.display()
                ),
            };
            let treq = |field: &str| -> Result<usize> {
                t.get(field).as_usize().ok_or_else(|| {
                    anyhow::anyhow!(
                        "manifest {}/manifest.json: tensor '{name}' field '{field}' is {}, \
                         expected a non-negative integer",
                        dir.display(),
                        t.get(field)
                    )
                })
            };
            let rows = treq("rows")?;
            let cols = treq("cols")?;
            let off = treq("offset")? * 4;
            let need = rows * cols * 4;
            anyhow::ensure!(off + need <= blob.len(), "tensor {name} out of range");
            let mut data = Vec::with_capacity(rows * cols);
            for i in 0..rows * cols {
                let b = &blob[off + 4 * i..off + 4 * i + 4];
                data.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
            }
            tensors.insert(name, FloatTensor::from_vec(rows, cols, data));
        }
        let get = |n: &str| -> Result<FloatTensor> {
            tensors.get(n).cloned().ok_or_else(|| anyhow::anyhow!("missing tensor {n}"))
        };
        let vec = |n: &str| -> Result<Vec<f32>> { Ok(get(n)?.into_data()) };

        let mut layers = Vec::with_capacity(cfg.layers);
        for i in 0..cfg.layers {
            let p = |s: &str| format!("layer{i}.{s}");
            layers.push(LayerWeights {
                wq: get(&p("attn.wq"))?,
                bq: vec(&p("attn.bq"))?,
                wk: get(&p("attn.wk"))?,
                bk: vec(&p("attn.bk"))?,
                wv: get(&p("attn.wv"))?,
                bv: vec(&p("attn.bv"))?,
                wo: get(&p("attn.wo"))?,
                bo: vec(&p("attn.bo"))?,
                ln1_g: vec(&p("ln1.gamma"))?,
                ln1_b: vec(&p("ln1.beta"))?,
                w1: get(&p("ffn.w1"))?,
                b1: vec(&p("ffn.b1"))?,
                w2: get(&p("ffn.w2"))?,
                b2: vec(&p("ffn.b2"))?,
                ln2_g: vec(&p("ln2.gamma"))?,
                ln2_b: vec(&p("ln2.beta"))?,
            });
        }
        let is_bert = kind == ModelKind::Bert;
        Ok((
            cfg,
            ModelWeights {
                emb_word: get("emb.word")?,
                emb_pos: get("emb.pos")?,
                emb_ln_g: vec("emb.ln.gamma")?,
                emb_ln_b: vec("emb.ln.beta")?,
                layers,
                pooler_w: if is_bert { Some(get("pooler.w")?) } else { None },
                pooler_b: if is_bert { Some(vec("pooler.b")?) } else { None },
                cls_w: if is_bert { Some(get("cls.w")?) } else { None },
                cls_b: if is_bert { Some(vec("cls.b")?) } else { None },
                final_ln_g: if is_bert { None } else { Some(vec("final_ln.gamma")?) },
                final_ln_b: if is_bert { None } else { Some(vec("final_ln.beta")?) },
            },
        ))
    }

    /// Load `artifacts/weights/<tag>` relative to an artifacts dir.
    pub fn load_tag(artifacts_dir: &str, tag: &str) -> Result<(ModelConfig, Self)> {
        Self::load(&Path::new(artifacts_dir).join("weights").join(tag))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_weights_have_config_shapes() {
        let cfg = ModelConfig::bert_tiny();
        let w = ModelWeights::random(&cfg, 1);
        assert_eq!(w.emb_word.shape(), (cfg.vocab, cfg.d));
        assert_eq!(w.layers.len(), cfg.layers);
        assert_eq!(w.layers[0].w1.shape(), (cfg.k, cfg.d));
        assert_eq!(w.layers[0].w2.shape(), (cfg.d, cfg.k));
        assert!(w.pooler_w.is_some());
        assert!(w.final_ln_g.is_none());
    }

    #[test]
    fn gpt_weights_have_final_ln() {
        let cfg = ModelConfig::gpt2_tiny();
        let w = ModelWeights::random(&cfg, 2);
        assert!(w.pooler_w.is_none());
        assert!(w.final_ln_g.is_some());
    }

    #[test]
    fn random_is_deterministic() {
        let cfg = ModelConfig::bert_tiny();
        let a = ModelWeights::random(&cfg, 7);
        let b = ModelWeights::random(&cfg, 7);
        assert_eq!(a.emb_word.data(), b.emb_word.data());
        let c = ModelWeights::random(&cfg, 8);
        assert_ne!(a.emb_word.data(), c.emb_word.data());
    }

    #[test]
    fn ctwb_load_roundtrip() {
        // Write a minimal CTWB checkpoint by hand and read it back.
        let cfg = ModelConfig { layers: 1, ..ModelConfig::bert_tiny() };
        let tmp = std::env::temp_dir().join(format!("centaur_ctwb_{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        // build tensors in sorted-name order like export_ctwb
        let names: Vec<(String, usize, usize)> = {
            let mut v = vec![
                ("cls.b".into(), 1, cfg.n_classes),
                ("cls.w".into(), cfg.n_classes, cfg.d),
                ("emb.ln.beta".into(), 1, cfg.d),
                ("emb.ln.gamma".into(), 1, cfg.d),
                ("emb.pos".into(), cfg.n_ctx, cfg.d),
                ("emb.word".into(), cfg.vocab, cfg.d),
                ("pooler.b".into(), 1, cfg.d),
                ("pooler.w".into(), cfg.d, cfg.d),
            ];
            for s in ["attn.bk", "attn.bo", "attn.bq", "attn.bv"] {
                v.push((format!("layer0.{s}"), 1, cfg.d));
            }
            for s in ["attn.wk", "attn.wo", "attn.wq", "attn.wv"] {
                v.push((format!("layer0.{s}"), cfg.d, cfg.d));
            }
            v.push(("layer0.ffn.b1".into(), 1, cfg.k));
            v.push(("layer0.ffn.b2".into(), 1, cfg.d));
            v.push(("layer0.ffn.w1".into(), cfg.k, cfg.d));
            v.push(("layer0.ffn.w2".into(), cfg.d, cfg.k));
            for s in ["ln1.beta", "ln1.gamma", "ln2.beta", "ln2.gamma"] {
                v.push((format!("layer0.{s}"), 1, cfg.d));
            }
            v.sort();
            v
        };
        let mut blob: Vec<u8> = vec![];
        let mut entries = vec![];
        let mut off = 0usize;
        for (name, r, c) in &names {
            for i in 0..r * c {
                blob.extend_from_slice(&((i % 97) as f32 * 0.01).to_le_bytes());
            }
            entries.push(format!(
                r#"{{"name":"{name}","rows":{r},"cols":{c},"offset":{off}}}"#
            ));
            off += r * c;
        }
        let manifest = format!(
            r#"{{"tag":"t","model":"bert-tiny","kind":"bert","vocab":{},"n_ctx":{},"d":{},"h":{},"layers":1,"k":{},"n_classes":{},"tensors":[{}]}}"#,
            cfg.vocab, cfg.n_ctx, cfg.d, cfg.h, cfg.k, cfg.n_classes,
            entries.join(",")
        );
        std::fs::write(tmp.join("manifest.json"), manifest).unwrap();
        std::fs::write(tmp.join("weights.bin"), &blob).unwrap();
        let (lcfg, w) = ModelWeights::load(&tmp).unwrap();
        assert_eq!(lcfg.layers, 1);
        assert_eq!(w.emb_word.shape(), (cfg.vocab, cfg.d));
        assert_eq!(w.layers[0].wq.get(0, 1), 0.01);
        std::fs::remove_dir_all(&tmp).ok();
    }

    fn write_checkpoint(tag: &str, manifest: &str) -> std::path::PathBuf {
        let tmp = std::env::temp_dir().join(format!("centaur_ctwb_{}_{tag}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(tmp.join("manifest.json"), manifest).unwrap();
        std::fs::write(tmp.join("weights.bin"), b"").unwrap();
        tmp
    }

    #[test]
    fn missing_field_names_the_field() {
        // "d" absent — must not silently become a 0-dim model.
        let tmp = write_checkpoint(
            "missing_d",
            r#"{"model":"m","kind":"bert","vocab":8,"n_ctx":4,"h":2,"layers":0,"k":8,"tensors":[]}"#,
        );
        let err = ModelWeights::load(&tmp).unwrap_err().to_string();
        assert!(err.contains("'d'"), "error should name the field: {err}");
        assert!(err.contains("missing"), "error should say it is missing: {err}");
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn malformed_field_names_the_field() {
        // "layers" is a string — the old loader truncated it to 0 layers.
        let tmp = write_checkpoint(
            "bad_layers",
            r#"{"model":"m","kind":"bert","vocab":8,"n_ctx":4,"d":4,"h":2,"layers":"two","k":8,"tensors":[]}"#,
        );
        let err = ModelWeights::load(&tmp).unwrap_err().to_string();
        assert!(err.contains("'layers'"), "error should name the field: {err}");
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn malformed_tensor_entry_names_tensor_and_field() {
        let tmp = write_checkpoint(
            "bad_tensor",
            r#"{"model":"m","kind":"bert","vocab":8,"n_ctx":4,"d":4,"h":2,"layers":0,"k":8,
                "tensors":[{"name":"emb.word","rows":-8,"cols":4,"offset":0}]}"#,
        );
        let err = ModelWeights::load(&tmp).unwrap_err().to_string();
        assert!(err.contains("emb.word") && err.contains("'rows'"), "got: {err}");
        std::fs::remove_dir_all(&tmp).ok();
    }
}
