//! Plaintext reference forward pass in Rust — semantics identical to
//! `python/compile/model.py` (same post-LN architecture, erf GeLU, eps).
//!
//! Used for (a) correctness oracles in integration tests (Centaur output
//! must match this up to fixed-point noise), (b) the Table 3 accuracy
//! evaluation of the substituted baselines, and (c) producing the
//! intermediate tensors `O1/O4/O5/O6` that the DRA attack harness targets.

use super::config::{ModelConfig, ModelKind};
use super::weights::ModelWeights;
use crate::runtime::native::{gelu_scalar, softmax_row};
use crate::runtime::LN_EPS;
use crate::tensor::FloatTensor;

/// Non-linearity substitution variants (paper §3, Table 3 markers).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// Unmodified model (plaintext / PUMA / Centaur semantics).
    Exact,
    /// MPCFormer: Softmax→2Quad, GeLU→Quad.
    MpcFormer,
    /// SecFormer: Softmax→2Quad only.
    SecFormer,
}

impl Variant {
    /// Parse a CLI variant name.
    pub fn by_name(s: &str) -> Option<Variant> {
        match s {
            "exact" => Some(Variant::Exact),
            "mpcformer" => Some(Variant::MpcFormer),
            "secformer" => Some(Variant::SecFormer),
            _ => None,
        }
    }
}

/// `2Quad` softmax substitute (paper Eq. 8), c = 5.
pub fn softmax_2quad_row(row: &mut [f32]) {
    let mut sum = 0.0;
    for v in row.iter_mut() {
        // masked positions (additive -1e9) get exactly zero weight,
        // matching the SMPC engine's multiplicative mask semantics
        *v = if *v < -1e8 { 0.0 } else { (*v + 5.0) * (*v + 5.0) };
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// `Quad` GeLU substitute.
pub fn gelu_quad_scalar(x: f32) -> f32 {
    0.125 * x * x + 0.25 * x + 0.5
}

fn softmax_variant(x: &mut FloatTensor, v: Variant) {
    for r in 0..x.rows() {
        match v {
            Variant::Exact => softmax_row(x.row_mut(r)),
            _ => softmax_2quad_row(x.row_mut(r)),
        }
    }
}

fn gelu_variant(x: &FloatTensor, v: Variant) -> FloatTensor {
    match v {
        Variant::MpcFormer => x.map(gelu_quad_scalar),
        _ => x.map(gelu_scalar),
    }
}

fn layernorm(x: &FloatTensor, g: &[f32], b: &[f32]) -> FloatTensor {
    let d = x.cols();
    let mut out = x.clone();
    for r in 0..out.rows() {
        let row = out.row_mut(r);
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let rstd = 1.0 / (var + LN_EPS).sqrt();
        for c in 0..d {
            row[c] = g[c] * (row[c] - mean) * rstd + b[c];
        }
    }
    out
}

/// Intermediates of one layer (the paper's attack targets, Table 2).
pub struct LayerTrace {
    /// `QKᵀ/√dh + M`, heads stacked to `(h·n, n)`.
    pub o1: FloatTensor,
    /// Attention output after W_O: `(n, d)`.
    pub o4: FloatTensor,
    /// FFN up-projection (pre-GeLU): `(n, k)`.
    pub o5: FloatTensor,
    /// FFN down-projection: `(n, d)`.
    pub o6: FloatTensor,
    /// Layer output after the second LayerNorm.
    pub l2: FloatTensor,
}

/// Full forward trace.
pub struct Trace {
    /// Embedded input `(n, d)` (after embedding LayerNorm).
    pub embedded: FloatTensor,
    /// Per-layer intermediates.
    pub layers: Vec<LayerTrace>,
    /// Final hidden states `(n, d)` (after GPT-2 final LN when applicable).
    pub hidden: FloatTensor,
    /// BERT: `(1, n_classes)` logits; GPT-2: `(n, vocab)` logits.
    pub logits: FloatTensor,
}

/// Run the model over a token sequence, recording intermediates.
pub fn forward_trace(cfg: &ModelConfig, w: &ModelWeights, ids: &[u32], variant: Variant) -> Trace {
    let n = ids.len();
    assert!(n <= cfg.n_ctx, "sequence longer than n_ctx");
    // Embedding: lookup + positional + LayerNorm.
    let mut x = FloatTensor::from_fn(n, cfg.d, |r, c| {
        w.emb_word.get(ids[r] as usize, c) + w.emb_pos.get(r, c)
    });
    x = layernorm(&x, &w.emb_ln_g, &w.emb_ln_b);
    let embedded = x.clone();

    let causal = cfg.kind == ModelKind::Gpt2;
    let dh = cfg.dh();
    let scale = 1.0 / (dh as f32).sqrt();
    let mut layers = Vec::with_capacity(cfg.layers);
    for l in &w.layers {
        // attention
        let q = x.matmul_nt(&l.wq).add_row(&l.bq);
        let k = x.matmul_nt(&l.wk).add_row(&l.bk);
        let v = x.matmul_nt(&l.wv).add_row(&l.bv);
        let mut o1_stack = FloatTensor::zeros(cfg.h * n, n);
        let mut o3 = FloatTensor::zeros(n, cfg.d);
        for h in 0..cfg.h {
            let qh = q.col_block(h * dh, (h + 1) * dh);
            let kh = k.col_block(h * dh, (h + 1) * dh);
            let vh = v.col_block(h * dh, (h + 1) * dh);
            let mut scores = qh.matmul_nt(&kh);
            scores.map_inplace(|s| s * scale);
            if causal {
                for r in 0..n {
                    for c in (r + 1)..n {
                        scores.set(r, c, scores.get(r, c) - 1e9);
                    }
                }
            }
            // record O1 before softmax
            for r in 0..n {
                o1_stack.row_mut(h * n + r).copy_from_slice(scores.row(r));
            }
            softmax_variant(&mut scores, variant);
            let oh = scores.matmul(&vh);
            o3.set_col_block(h * dh, &oh);
        }
        let o4 = o3.matmul_nt(&l.wo).add_row(&l.bo);
        let res1 = o4.zip_with(&x, |a, b| a + b);
        let l1 = layernorm(&res1, &l.ln1_g, &l.ln1_b);
        let o5 = l1.matmul_nt(&l.w1).add_row(&l.b1);
        let g = gelu_variant(&o5, variant);
        let o6 = g.matmul_nt(&l.w2).add_row(&l.b2);
        let res2 = o6.zip_with(&l1, |a, b| a + b);
        let l2 = layernorm(&res2, &l.ln2_g, &l.ln2_b);
        x = l2.clone();
        layers.push(LayerTrace { o1: o1_stack, o4, o5, o6, l2 });
    }

    // adaptation
    let (hidden, logits) = match cfg.kind {
        ModelKind::Bert => {
            let cls = x.col_block(0, cfg.d).row(0).to_vec(); // row 0
            let cls_t = FloatTensor::from_vec(1, cfg.d, cls);
            let pooled = cls_t
                .matmul_nt(w.pooler_w.as_ref().unwrap())
                .add_row(w.pooler_b.as_ref().unwrap())
                .map(f32::tanh);
            let logits = pooled
                .matmul_nt(w.cls_w.as_ref().unwrap())
                .add_row(w.cls_b.as_ref().unwrap());
            (x, logits)
        }
        ModelKind::Gpt2 => {
            let h = layernorm(&x, w.final_ln_g.as_ref().unwrap(), w.final_ln_b.as_ref().unwrap());
            let logits = h.matmul_nt(&w.emb_word); // tied head: H @ W_Eᵀ
            (h, logits)
        }
    };
    Trace { embedded, layers, hidden, logits }
}

/// Logits only (convenience).
pub fn forward(cfg: &ModelConfig, w: &ModelWeights, ids: &[u32], variant: Variant) -> FloatTensor {
    forward_trace(cfg, w, ids, variant).logits
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> (ModelConfig, ModelWeights) {
        let cfg = ModelConfig::bert_tiny();
        let w = ModelWeights::random(&cfg, 21);
        (cfg, w)
    }

    #[test]
    fn bert_logit_shape_and_determinism() {
        let (cfg, w) = tiny();
        let ids: Vec<u32> = (0..cfg.n_ctx as u32).collect();
        let a = forward(&cfg, &w, &ids, Variant::Exact);
        let b = forward(&cfg, &w, &ids, Variant::Exact);
        assert_eq!(a.shape(), (1, cfg.n_classes));
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn gpt_logits_and_causality() {
        let cfg = ModelConfig::gpt2_tiny();
        let w = ModelWeights::random(&cfg, 22);
        let ids: Vec<u32> = vec![5; cfg.n_ctx];
        let base = forward(&cfg, &w, &ids, Variant::Exact);
        assert_eq!(base.shape(), (cfg.n_ctx, cfg.vocab));
        let mut ids2 = ids.clone();
        *ids2.last_mut().unwrap() = 9;
        let pert = forward(&cfg, &w, &ids2, Variant::Exact);
        // earlier rows unchanged (causal), last row changed
        for r in 0..cfg.n_ctx - 1 {
            for c in 0..8 {
                assert!((base.get(r, c) - pert.get(r, c)).abs() < 1e-5);
            }
        }
        assert!((0..8).any(|c| (base.get(cfg.n_ctx - 1, c) - pert.get(cfg.n_ctx - 1, c)).abs() > 1e-6));
    }

    #[test]
    fn variants_change_output() {
        let (cfg, w) = tiny();
        let ids: Vec<u32> = (0..cfg.n_ctx as u32).map(|i| (i * 3) % 500).collect();
        let e = forward(&cfg, &w, &ids, Variant::Exact);
        let m = forward(&cfg, &w, &ids, Variant::MpcFormer);
        let s = forward(&cfg, &w, &ids, Variant::SecFormer);
        assert!(e.max_abs_diff(&m) > 1e-6);
        assert!(e.max_abs_diff(&s) > 1e-6);
        assert!(m.max_abs_diff(&s) > 1e-6);
    }

    #[test]
    fn trace_shapes() {
        let (cfg, w) = tiny();
        let ids: Vec<u32> = (0..cfg.n_ctx as u32).collect();
        let t = forward_trace(&cfg, &w, &ids, Variant::Exact);
        assert_eq!(t.layers.len(), cfg.layers);
        let lt = &t.layers[0];
        assert_eq!(lt.o1.shape(), (cfg.h * cfg.n_ctx, cfg.n_ctx));
        assert_eq!(lt.o4.shape(), (cfg.n_ctx, cfg.d));
        assert_eq!(lt.o5.shape(), (cfg.n_ctx, cfg.k));
        assert_eq!(lt.o6.shape(), (cfg.n_ctx, cfg.d));
    }

    #[test]
    fn softmax_2quad_row_normalizes() {
        let mut row = vec![0.5f32, -1.0, 2.0, 0.0];
        softmax_2quad_row(&mut row);
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(row.iter().all(|&v| v >= 0.0));
    }
}
