//! Model zoo: configurations, weights (CTWB checkpoints / seeded random),
//! permuted parameter sets (Θ′), and the plaintext reference forward.

mod config;
mod permute;
pub mod plaintext;
mod weights;

pub use config::{ModelConfig, ModelKind};
pub use permute::{PermLayer, PermSet, PermutedModel};
pub use plaintext::{forward, forward_trace, Trace, Variant};
pub use weights::{LayerWeights, ModelWeights};
