//! Model configurations — mirrors `python/compile/configs.py` (the AOT
//! side); keep the two in sync.

/// Transformer family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    /// Encoder (NLU; classification adaptation with pooler+tanh).
    Bert,
    /// Decoder (NLG; causal mask, final LayerNorm, tied LM head).
    Gpt2,
}

/// Static shape description of a model.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    /// Canonical model tag (e.g. `bert-tiny`).
    pub name: String,
    /// Encoder or decoder family.
    pub kind: ModelKind,
    /// Vocabulary size.
    pub vocab: usize,
    /// Sequence length used for experiments/AOT shapes.
    pub n_ctx: usize,
    /// Feature dimension `d`.
    pub d: usize,
    /// Attention heads `h`.
    pub h: usize,
    /// Transformer layer count `L`.
    pub layers: usize,
    /// FFN intermediate dimension `k` (4d in all configs).
    pub k: usize,
    /// Classifier width (BERT adaptation).
    pub n_classes: usize,
}

impl ModelConfig {
    fn new(name: &str, kind: ModelKind, vocab: usize, n_ctx: usize, d: usize, h: usize, layers: usize, k: usize) -> Self {
        ModelConfig { name: name.into(), kind, vocab, n_ctx, d, h, layers, k, n_classes: 2 }
    }

    /// Tiny trained variant (synthetic tasks; accuracy & attack experiments).
    pub fn bert_tiny() -> Self {
        Self::new("bert-tiny", ModelKind::Bert, 512, 32, 64, 2, 2, 256)
    }
    /// Tiny trained decoder variant (synthetic LM tasks).
    pub fn gpt2_tiny() -> Self {
        Self::new("gpt2-tiny", ModelKind::Gpt2, 512, 32, 64, 2, 2, 256)
    }
    /// Paper Appendix D shapes (efficiency experiments).
    pub fn bert_base() -> Self {
        Self::new("bert-base", ModelKind::Bert, 30522, 128, 768, 12, 12, 3072)
    }
    /// BERT-large shape.
    pub fn bert_large() -> Self {
        Self::new("bert-large", ModelKind::Bert, 30522, 128, 1024, 16, 24, 4096)
    }
    /// GPT-2 base (117M-class) shape.
    pub fn gpt2_base() -> Self {
        Self::new("gpt2-base", ModelKind::Gpt2, 50257, 128, 768, 12, 12, 3072)
    }
    /// GPT-2 large (774M-class) shape.
    pub fn gpt2_large() -> Self {
        Self::new("gpt2-large", ModelKind::Gpt2, 50257, 128, 1280, 20, 36, 5120)
    }

    /// Look up a config by canonical tag.
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "bert-tiny" => Some(Self::bert_tiny()),
            "gpt2-tiny" => Some(Self::gpt2_tiny()),
            "bert-base" => Some(Self::bert_base()),
            "bert-large" => Some(Self::bert_large()),
            "gpt2-base" => Some(Self::gpt2_base()),
            "gpt2-large" => Some(Self::gpt2_large()),
            _ => None,
        }
    }

    /// Every canonical model tag.
    pub const ALL_NAMES: [&'static str; 6] =
        ["bert-tiny", "gpt2-tiny", "bert-base", "bert-large", "gpt2-base", "gpt2-large"];

    /// Per-head dimension.
    pub fn dh(&self) -> usize {
        self.d / self.h
    }

    /// Total parameter count (for reports).
    pub fn param_count(&self) -> usize {
        let per_layer = 4 * self.d * self.d + 4 * self.d // attn weights+biases
            + 2 * self.d * self.k + self.k + self.d // ffn
            + 4 * self.d; // 2 layernorms
        let emb = self.vocab * self.d + self.n_ctx * self.d + 2 * self.d;
        let head = match self.kind {
            ModelKind::Bert => self.d * self.d + self.d + self.n_classes * self.d + self.n_classes,
            ModelKind::Gpt2 => 2 * self.d,
        };
        emb + self.layers * per_layer + head
    }

    /// Scale the config down to `layers` layers (bench extrapolation).
    pub fn with_layers(&self, layers: usize) -> Self {
        let mut c = self.clone();
        c.layers = layers;
        c
    }

    /// Scale to a different sequence length.
    pub fn with_n_ctx(&self, n_ctx: usize) -> Self {
        let mut c = self.clone();
        c.n_ctx = n_ctx;
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_appendix_d_dims() {
        assert_eq!(ModelConfig::bert_base().d, 768);
        assert_eq!(ModelConfig::bert_large().d, 1024);
        assert_eq!(ModelConfig::bert_large().layers, 24);
        assert_eq!(ModelConfig::gpt2_large().d, 1280);
        assert_eq!(ModelConfig::gpt2_large().layers, 36);
        assert_eq!(ModelConfig::gpt2_large().h, 20);
    }

    #[test]
    fn param_counts_match_paper_magnitudes() {
        // paper: BERT_BASE 110M, BERT_LARGE 340M, GPT2_BASE 117M, GPT2_LARGE 774M
        let approx = |c: ModelConfig| c.param_count() as f64 / 1e6;
        assert!((approx(ModelConfig::bert_base()) - 110.0).abs() < 15.0);
        assert!((approx(ModelConfig::bert_large()) - 340.0).abs() < 30.0);
        assert!((approx(ModelConfig::gpt2_base()) - 117.0).abs() < 15.0);
        assert!((approx(ModelConfig::gpt2_large()) - 774.0).abs() < 60.0);
    }

    #[test]
    fn head_dim_divides() {
        for name in ModelConfig::ALL_NAMES {
            let c = ModelConfig::by_name(name).unwrap();
            assert_eq!(c.d % c.h, 0, "{name}");
            assert_eq!(c.k, 4 * c.d, "{name}");
        }
    }

    #[test]
    fn by_name_roundtrip() {
        for name in ModelConfig::ALL_NAMES {
            assert_eq!(ModelConfig::by_name(name).unwrap().name, name);
        }
        assert!(ModelConfig::by_name("nope").is_none());
    }
}
