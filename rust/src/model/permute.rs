//! Parameter permutation — Centaur's initialization phase (paper §5.1).
//!
//! The model developer `P0` draws `Π = {π (d×d), π₁ (n×n), π₂ (k×k)}` and
//! ships the cloud `P1` only permuted parameters. This module computes the
//! permuted set Θ′ in **our storage convention** (`W (out,in)`, activations
//! `(n, d)`, `Y = X Wᵀ + b`):
//!
//! | layer | Θ′ held by the servers | algebra |
//! |---|---|---|
//! | embedding    | `W_E π` (vocab,d)          | `[X]·(W_Eπ) = X_Mπ` |
//! | Q/K/V        | `W π` (in-perm only)       | `[Xπ](Wπ)ᵀ = XWᵀ` (shares, unpermuted → heads sliceable) |
//! | attn out     | `πᵀ W_O` (out-perm)        | `[O₃](πᵀW_O)ᵀ = O₄π` |
//! | FFN up       | `π₂ᵀ W₁ π`                 | `[L₁π](π₂ᵀW₁π)ᵀ = O₅π₂` |
//! | FFN down     | `πᵀ W₂ π₂`                 | `[Gπ₂](πᵀW₂π₂)ᵀ = O₆π` |
//! | LayerNorms   | `γπ, βπ` (f32, at P1)      | `LN(xπ, γπ, βπ) = LN(x)π` |
//! | pooler       | `πᵀ W_P π`                 | `[cπ](πᵀW_Pπ)ᵀ = pπ` |
//! | classifier   | `W_C π`                    | `[tπ](W_Cπ)ᵀ = logits` (unpermuted) |
//!
//! Biases consumed inside a permuted stream are permuted accordingly and
//! held by `P0`, who adds them to its own share (`Π_Add` with plaintext —
//! reveals nothing). Matrix weights used in `Π_ScalMul` are fixed-point
//! encoded once here.

use super::config::{ModelConfig, ModelKind};
use super::weights::ModelWeights;
use crate::fixed;
use crate::perm::Perm;
use crate::tensor::{FloatTensor, RingTensor};
use crate::util::rng::Rng;

/// The permutations drawn at initialization.
#[derive(Clone, Debug)]
pub struct PermSet {
    /// Feature-dim permutation (d×d) — also sent to the client.
    pub pi: Perm,
    /// Sequence-dim permutation (n×n) — protects attention scores.
    pub pi1: Perm,
    /// FFN-intermediate permutation (k×k).
    pub pi2: Perm,
}

impl PermSet {
    /// Draw all three permutations uniformly at random.
    pub fn random(cfg: &ModelConfig, rng: &mut Rng) -> Self {
        PermSet {
            pi: Perm::random(cfg.d, rng),
            pi1: Perm::random(cfg.n_ctx, rng),
            pi2: Perm::random(cfg.k, rng),
        }
    }

    /// Identity permutations (ablation: permutation disabled).
    pub fn identity(cfg: &ModelConfig) -> Self {
        PermSet {
            pi: Perm::identity(cfg.d),
            pi1: Perm::identity(cfg.n_ctx),
            pi2: Perm::identity(cfg.k),
        }
    }
}

/// One layer of Θ′ (fixed-point for Π_ScalMul; f32 affine for Π_PPLN at P1).
#[derive(Clone)]
pub struct PermLayer {
    /// Query projection `(d,d)` = enc(Wq π).
    pub wq: RingTensor, // (d,d) = enc(Wq π)
    /// Key projection (same layout as `wq`).
    pub wk: RingTensor,
    /// Value projection (same layout as `wq`).
    pub wv: RingTensor,
    /// Query bias enc(bq) — unpermuted stream (held by P0).
    pub bq: Vec<i64>, // enc(bq) — unpermuted stream (held by P0)
    /// Key bias.
    pub bk: Vec<i64>,
    /// Value bias.
    pub bv: Vec<i64>,
    /// Output projection `(d,d)` = enc(πᵀ Wo).
    pub wo: RingTensor, // (d,d) = enc(πᵀ Wo)
    /// Output bias enc(bo π).
    pub bo: Vec<i64>,   // enc(bo π)
    /// First LayerNorm gain γ₁π (P1 plaintext).
    pub ln1_g: Vec<f32>, // γ₁π (P1 plaintext)
    /// First LayerNorm bias β₁π.
    pub ln1_b: Vec<f32>,
    /// FFN up-projection `(k,d)` = enc(π₂ᵀ W₁ π).
    pub w1: RingTensor, // (k,d) = enc(π₂ᵀ W₁ π)
    /// FFN up bias enc(b₁ π₂).
    pub b1: Vec<i64>,   // enc(b₁ π₂)
    /// FFN down-projection `(d,k)` = enc(πᵀ W₂ π₂).
    pub w2: RingTensor, // (d,k) = enc(πᵀ W₂ π₂)
    /// FFN down bias enc(b₂ π).
    pub b2: Vec<i64>,   // enc(b₂ π)
    /// Second LayerNorm gain γ₂π.
    pub ln2_g: Vec<f32>,
    /// Second LayerNorm bias β₂π.
    pub ln2_b: Vec<f32>,
}

/// Θ′ — everything the compute servers hold.
#[derive(Clone)]
pub struct PermutedModel {
    /// Model shape.
    pub cfg: ModelConfig,
    /// The drawn permutations (developer-side secret).
    pub perms: PermSet,
    /// Word embeddings `(vocab,d)` = enc(W_E π).
    pub emb_word: RingTensor, // (vocab,d) = enc(W_E π)
    /// Position embeddings `(n,d)` = enc(P π), added by P0.
    pub emb_pos: RingTensor,  // (n,d) = enc(P π), added by P0
    /// Embedding LayerNorm gain γπ.
    pub emb_ln_g: Vec<f32>,
    /// Embedding LayerNorm bias βπ.
    pub emb_ln_b: Vec<f32>,
    /// Per-layer permuted parameters.
    pub layers: Vec<PermLayer>,
    // BERT adaptation
    /// BERT pooler weight enc(πᵀ W_P π).
    pub pooler_w: Option<RingTensor>, // enc(πᵀ W_P π)
    /// BERT pooler bias enc(b_P π).
    pub pooler_b: Option<Vec<i64>>,   // enc(b_P π)
    /// BERT classifier weight enc(W_C π).
    pub cls_w: Option<RingTensor>,    // enc(W_C π)
    /// BERT classifier bias enc(b_C).
    pub cls_b: Option<Vec<i64>>,      // enc(b_C)
    // GPT-2 final LN (γπ, βπ)
    /// GPT-2 final LayerNorm gain γπ.
    pub final_ln_g: Option<Vec<f32>>,
    /// GPT-2 final LayerNorm bias βπ.
    pub final_ln_b: Option<Vec<f32>>,
}

fn enc(t: &FloatTensor) -> RingTensor {
    fixed::encode_tensor(t)
}

fn enc_vec(v: &[f32]) -> Vec<i64> {
    v.iter().map(|&x| fixed::encode(x as f64)).collect()
}

impl PermutedModel {
    /// P0's initialization: permute + encode all parameters.
    pub fn build(cfg: &ModelConfig, w: &ModelWeights, perms: PermSet) -> Self {
        let pi = &perms.pi;
        let pi2 = &perms.pi2;
        let layers = w
            .layers
            .iter()
            .map(|l| PermLayer {
                wq: enc(&pi.apply_cols(&l.wq)),
                wk: enc(&pi.apply_cols(&l.wk)),
                wv: enc(&pi.apply_cols(&l.wv)),
                bq: enc_vec(&l.bq),
                bk: enc_vec(&l.bk),
                bv: enc_vec(&l.bv),
                wo: enc(&pi.apply_rows_t(&l.wo)),
                bo: enc_vec(&pi.apply_vec(&l.bo)),
                ln1_g: pi.apply_vec(&l.ln1_g),
                ln1_b: pi.apply_vec(&l.ln1_b),
                w1: enc(&pi2.apply_rows_t(&pi.apply_cols(&l.w1))),
                b1: enc_vec(&pi2.apply_vec(&l.b1)),
                w2: enc(&pi.apply_rows_t(&pi2.apply_cols(&l.w2))),
                b2: enc_vec(&pi.apply_vec(&l.b2)),
                ln2_g: pi.apply_vec(&l.ln2_g),
                ln2_b: pi.apply_vec(&l.ln2_b),
            })
            .collect();
        PermutedModel {
            cfg: cfg.clone(),
            emb_word: enc(&pi.apply_cols(&w.emb_word)),
            emb_pos: enc(&pi.apply_cols(&w.emb_pos)),
            emb_ln_g: pi.apply_vec(&w.emb_ln_g),
            emb_ln_b: pi.apply_vec(&w.emb_ln_b),
            layers,
            pooler_w: w.pooler_w.as_ref().map(|p| enc(&pi.apply_rows_t(&pi.apply_cols(p)))),
            pooler_b: w.pooler_b.as_ref().map(|b| enc_vec(&pi.apply_vec(b))),
            cls_w: w.cls_w.as_ref().map(|c| enc(&pi.apply_cols(c))),
            cls_b: w.cls_b.as_ref().map(|b| enc_vec(b)),
            final_ln_g: w.final_ln_g.as_ref().map(|g| pi.apply_vec(g)),
            final_ln_b: w.final_ln_b.as_ref().map(|b| pi.apply_vec(b)),
            perms,
        }
    }

    /// Total bytes of permuted parameters shipped to P1 (reports).
    pub fn bytes(&self) -> u64 {
        let mut n = self.emb_word.len() + self.emb_pos.len();
        for l in &self.layers {
            n += l.wq.len() + l.wk.len() + l.wv.len() + l.wo.len() + l.w1.len() + l.w2.len();
        }
        if let Some(p) = &self.pooler_w {
            n += p.len();
        }
        if let Some(c) = &self.cls_w {
            n += c.len();
        }
        (n as u64) * 8
    }

    /// Whether this is an encoder (BERT) model.
    pub fn is_bert(&self) -> bool {
        self.cfg.kind == ModelKind::Bert
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::FloatTensor;

    /// The central algebraic fact: permuted weights cancel against permuted
    /// activations exactly as the module docs claim.
    #[test]
    fn qkv_cancellation() {
        let cfg = ModelConfig::bert_tiny();
        let w = ModelWeights::random(&cfg, 3);
        let mut rng = Rng::new(4);
        let perms = PermSet::random(&cfg, &mut rng);
        let x = FloatTensor::from_fn(cfg.n_ctx, cfg.d, |r, c| ((r * 13 + c * 7) % 19) as f32 * 0.1 - 0.9);
        let xp = perms.pi.apply_cols(&x);
        // Xπ (Wqπ)ᵀ == X Wqᵀ
        let wqp = perms.pi.apply_cols(&w.layers[0].wq);
        let got = xp.matmul_nt(&wqp);
        let want = x.matmul_nt(&w.layers[0].wq);
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn wo_produces_pi_permuted_output() {
        let cfg = ModelConfig::bert_tiny();
        let w = ModelWeights::random(&cfg, 5);
        let mut rng = Rng::new(6);
        let perms = PermSet::random(&cfg, &mut rng);
        let o3 = FloatTensor::from_fn(cfg.n_ctx, cfg.d, |r, c| ((r + c) % 13) as f32 * 0.2 - 1.0);
        let wop = perms.pi.apply_rows_t(&w.layers[0].wo);
        let got = o3.matmul_nt(&wop); // [O3](πᵀWo)ᵀ
        let want = perms.pi.apply_cols(&o3.matmul_nt(&w.layers[0].wo)); // (O3 Woᵀ)π
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn ffn_chain_permutations() {
        let cfg = ModelConfig::bert_tiny();
        let w = ModelWeights::random(&cfg, 7);
        let mut rng = Rng::new(8);
        let perms = PermSet::random(&cfg, &mut rng);
        let l1 = FloatTensor::from_fn(cfg.n_ctx, cfg.d, |r, c| ((r * 3 + c) % 17) as f32 * 0.1 - 0.8);
        let l1p = perms.pi.apply_cols(&l1);
        // up: [L1π](π2ᵀW1π)ᵀ == (L1 W1ᵀ)π2
        let w1p = perms.pi2.apply_rows_t(&perms.pi.apply_cols(&w.layers[0].w1));
        let o5p2 = l1p.matmul_nt(&w1p);
        let want_up = perms.pi2.apply_cols(&l1.matmul_nt(&w.layers[0].w1));
        assert!(o5p2.max_abs_diff(&want_up) < 1e-4);
        // down: [Gπ2](πᵀW2π2)ᵀ == (G W2ᵀ)π
        let g = o5p2; // reuse as arbitrary activations in π2 space
        let w2p = perms.pi.apply_rows_t(&perms.pi2.apply_cols(&w.layers[0].w2));
        let o6p = g.matmul_nt(&w2p);
        let g_unperm = perms.pi2.inverse().apply_cols(&g);
        let want_down = perms.pi.apply_cols(&g_unperm.matmul_nt(&w.layers[0].w2));
        assert!(o6p.max_abs_diff(&want_down) < 1e-3);
    }

    #[test]
    fn embedding_lookup_permutes_features() {
        let cfg = ModelConfig::bert_tiny();
        let w = ModelWeights::random(&cfg, 9);
        let mut rng = Rng::new(10);
        let perms = PermSet::random(&cfg, &mut rng);
        // one-hot row selects a row of W_E π == (row of W_E) π
        let token = 42usize;
        let wep = perms.pi.apply_cols(&w.emb_word);
        let direct: Vec<f32> = wep.row(token).to_vec();
        let want = perms.pi.apply_vec(w.emb_word.row(token));
        assert_eq!(direct, want);
    }

    #[test]
    fn identity_perms_are_noop() {
        let cfg = ModelConfig::bert_tiny();
        let w = ModelWeights::random(&cfg, 11);
        let pm = PermutedModel::build(&cfg, &w, PermSet::identity(&cfg));
        let dec = fixed::decode_tensor(&pm.layers[0].wq);
        assert!(dec.max_abs_diff(&w.layers[0].wq) < 2e-5);
    }

    #[test]
    fn permuted_bytes_positive() {
        let cfg = ModelConfig::bert_tiny();
        let w = ModelWeights::random(&cfg, 12);
        let mut rng = Rng::new(13);
        let pm = PermutedModel::build(&cfg, &w, PermSet::random(&cfg, &mut rng));
        assert!(pm.bytes() > (cfg.vocab * cfg.d * 8) as u64);
    }
}
