//! Build-time stub for [`XlaBackend`](crate::runtime::XlaBackend) when the
//! `xla` cargo feature is disabled (the offline mirror has no `xla` crate;
//! DESIGN.md §Offline-dependency substitutions).
//!
//! The stub keeps the public API identical — `backend_by_name("xla")`, the
//! artifact-gated integration tests, and the serving coordinator all compile
//! unchanged — but the type is uninhabitable: [`XlaBackend::new`] always
//! reports the missing feature, so the method bodies are unreachable by
//! construction.

use super::Backend;
use crate::tensor::{FloatTensor, RingTensor};
use crate::Result;

/// Uninhabitable placeholder (mirrors the API of the real PJRT backend).
pub struct XlaBackend {
    never: Never,
}

enum Never {}

impl XlaBackend {
    /// Always fails: the crate was built without the `xla` feature.
    pub fn new(_artifacts_dir: &str, _model: &str) -> Result<Self> {
        anyhow::bail!(
            "this build has no PJRT support: rebuild with `--features xla` \
             (and the `xla` crate available) to load AOT artifacts"
        )
    }

    /// Ring matmul through an AOT artifact (unreachable in stub builds).
    pub fn ring_matmul(&mut self, _a: &RingTensor, _b: &RingTensor) -> Result<Option<RingTensor>> {
        match self.never {}
    }

    /// Number of distinct compiled executables held (unreachable in stub builds).
    pub fn compiled_count(&self) -> usize {
        match self.never {}
    }
}

impl Backend for XlaBackend {
    fn softmax(&mut self, _x: &FloatTensor) -> Result<FloatTensor> {
        match self.never {}
    }

    fn gelu(&mut self, _x: &FloatTensor) -> Result<FloatTensor> {
        match self.never {}
    }

    fn layernorm(&mut self, _x: &FloatTensor, _gamma: &[f32], _beta: &[f32]) -> Result<FloatTensor> {
        match self.never {}
    }

    fn tanh(&mut self, _x: &FloatTensor) -> Result<FloatTensor> {
        match self.never {}
    }

    fn name(&self) -> &'static str {
        match self.never {}
    }
}
