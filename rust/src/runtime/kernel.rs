//! Ring-kernel dispatch: explicit-width SIMD inner kernels for the
//! `Z_{2^64}` matmul, selected at runtime (EXPERIMENTS.md §Perf iteration 5).
//!
//! [`ring::matmul_nt`](crate::ring::matmul_nt) — the L3 compute hot spot
//! every `Π_ScalMul`, Beaver product, and dealer triple generation lowers
//! to — routes through the [`RingKernel`] trait here, the integer sibling
//! of the float [`Backend`](super::Backend) dispatch. Registered kernels:
//!
//! * `scalar` — the 4-lane unrolled `chunks_exact` kernel (§Perf
//!   iteration 1), always available; the guaranteed-identical fallback.
//! * `avx2` — 4×i64 lanes via `core::arch` intrinsics; the 64-bit wrapping
//!   product is synthesized from three 32×32→64 multiplies (AVX2 has no
//!   `vpmullq`). Four output columns are blocked per pass so each loaded
//!   `A` vector is reused 4×.
//! * `avx512` — 8×i64 lanes with the native `vpmullq`
//!   (`_mm512_mullo_epi64`, AVX-512F+DQ). Compiled only on rustc ≥ 1.89
//!   (`build.rs` probe; the intrinsics stabilized there).
//! * `neon` — 2×i64 lanes on aarch64, same three-multiply synthesis
//!   (NEON has no 64-bit vector multiply either).
//! * `xla` — the AOT ring artifacts (`artifacts/ring/manifest.json`)
//!   through PJRT; one registered implementation like any other, present
//!   only with the off-by-default `xla` cargo feature and never
//!   auto-selected.
//!
//! Every kernel is **bit-exact** by construction: wrapping addition in
//! `Z_{2^64}` is associative and commutative, so lane order cannot change
//! the sum, and the property suite (`rust/tests/ring_kernels.rs`) pins all
//! host-available kernels against `matmul_naive` on degenerate and
//! lane-width ± 1 shapes.
//!
//! Selection mirrors `CENTAUR_THREADS`: the `CENTAUR_RING_KERNEL` env var
//! (`auto`, `scalar`, `avx2`, `avx512`, `neon`, `xla`) or the `centaur
//! --ring-kernel <name>` CLI flag ([`set_override`]). `auto` (the default)
//! picks the widest kernel the host CPU supports. The choice is cached
//! after the first [`selected`] call; [`refresh`] drops the cache for
//! benches/tests that vary the env var mid-process.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::tensor::RingTensor;
use crate::util::pool;
use crate::Result;

/// k-tile edge for the blocked matmul (moved from `ring`; §Perf iteration
/// 2/3): model dims (k ≤ 5120) run untiled, vocabulary-sized inner dims
/// (k ≈ 50k) get blocked so the `A` row tile stays in L1.
pub const TILE_K: usize = 4096;

/// Inner-kernel interface for wrapping `Z_{2^64}` matrix products.
///
/// Implementations must be bit-exact with [`ScalarKernel`] (wrapping i64
/// semantics; any summation order is identical in the ring). `matmul_nt`
/// has a provided row-parallel driver over the shared thread pool; only
/// whole-matrix backends (the `xla` artifact path) override it.
pub trait RingKernel: Send + Sync {
    /// Registry name (`scalar`, `avx2`, …), reported in metrics/benches.
    fn name(&self) -> &'static str;

    /// Wrapping dot product over `Z_{2^64}`. Slices must be equal length.
    fn dot(&self, a: &[i64], b: &[i64]) -> i64;

    /// Accumulate `out += A_rows @ Bt^T` for a contiguous band of output
    /// rows: `a_rows` is `(rows × k)` row-major, `bt` the full `(n × k)`
    /// transposed right operand, `out` the `(rows × n)` output band.
    fn matmul_nt_chunk(&self, a_rows: &[i64], bt: &[i64], out: &mut [i64], k: usize, n: usize);

    /// Full wrapping `A (m×k) @ B^T` with `B` given `(n×k)` row-major,
    /// distributed over the thread pool in contiguous row bands.
    fn matmul_nt(&self, a: &RingTensor, bt: &RingTensor) -> RingTensor {
        assert_eq!(a.cols(), bt.cols(), "ring matmul_nt inner dim");
        let (m, k, n) = (a.rows(), a.cols(), bt.rows());
        let mut out = RingTensor::zeros(m, n);
        if m == 0 || n == 0 {
            return out;
        }
        let rows_per_chunk = 1usize.max(m.div_ceil(pool::num_threads() * 2));
        let chunk_elems = rows_per_chunk * n;
        let a_data = a.data();
        let bt_data = bt.data();
        pool::par_chunks_mut(out.data_mut(), chunk_elems, |ci, chunk| {
            let r0 = ci * rows_per_chunk;
            let rows_here = chunk.len() / n;
            self.matmul_nt_chunk(&a_data[r0 * k..(r0 + rows_here) * k], bt_data, chunk, k, n);
        });
        out
    }
}

/// The §Perf iteration-1 scalar kernel: 4-lane unrolled `chunks_exact`
/// dot product ([`crate::ring::dot_wrapping`]). Always available — the
/// reference every SIMD kernel is pinned against.
pub struct ScalarKernel;

static SCALAR: ScalarKernel = ScalarKernel;

impl RingKernel for ScalarKernel {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn dot(&self, a: &[i64], b: &[i64]) -> i64 {
        crate::ring::dot_wrapping(a, b)
    }

    fn matmul_nt_chunk(&self, a_rows: &[i64], bt: &[i64], out: &mut [i64], k: usize, n: usize) {
        let rows = if n == 0 { 0 } else { out.len() / n };
        for dr in 0..rows {
            let arow = &a_rows[dr * k..(dr + 1) * k];
            let orow = &mut out[dr * n..(dr + 1) * n];
            // k-tiling keeps the arow tile in L1 across all n columns.
            for k0 in (0..k).step_by(TILE_K) {
                let k1 = (k0 + TILE_K).min(k);
                let atile = &arow[k0..k1];
                for c in 0..n {
                    let btile = &bt[c * k + k0..c * k + k1];
                    orow[c] = orow[c].wrapping_add(crate::ring::dot_wrapping(atile, btile));
                }
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod x86_avx2 {
    //! AVX2 kernel: 4×i64 lanes, wrapping 64-bit product synthesized as
    //! `lo·lo + ((hi·lo + lo·hi) << 32)` from `vpmuludq` (exact mod 2^64;
    //! signedness is immaterial in the ring).

    use core::arch::x86_64::*;

    use super::{RingKernel, TILE_K};

    pub(super) static AVX2: Avx2Kernel = Avx2Kernel;

    /// 4-lane AVX2 kernel (runtime-detected; only reachable through the
    /// registry probe, which guarantees the `avx2` CPU feature).
    pub struct Avx2Kernel;

    pub(super) fn available() -> bool {
        std::arch::is_x86_feature_detected!("avx2")
    }

    /// Lane-wise wrapping i64 multiply (no `vpmullq` below AVX-512).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn mul64(a: __m256i, b: __m256i) -> __m256i {
        let a_hi = _mm256_srli_epi64::<32>(a);
        let b_hi = _mm256_srli_epi64::<32>(b);
        let lo = _mm256_mul_epu32(a, b);
        let cross = _mm256_add_epi64(_mm256_mul_epu32(a_hi, b), _mm256_mul_epu32(a, b_hi));
        _mm256_add_epi64(lo, _mm256_slli_epi64::<32>(cross))
    }

    /// Wrapping horizontal sum of the 4 lanes.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum(v: __m256i) -> i64 {
        let mut t = [0i64; 4];
        _mm256_storeu_si256(t.as_mut_ptr() as *mut __m256i, v);
        t[0].wrapping_add(t[1]).wrapping_add(t[2]).wrapping_add(t[3])
    }

    /// One `A` tile against four `B^T` rows (equal lengths): the loaded
    /// `A` vector is reused across all four accumulator chains.
    #[target_feature(enable = "avx2")]
    unsafe fn dot4(a: &[i64], b0: &[i64], b1: &[i64], b2: &[i64], b3: &[i64]) -> [i64; 4] {
        let len = a.len();
        let mut acc0 = _mm256_setzero_si256();
        let mut acc1 = _mm256_setzero_si256();
        let mut acc2 = _mm256_setzero_si256();
        let mut acc3 = _mm256_setzero_si256();
        let mut i = 0;
        while i + 4 <= len {
            let va = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
            let v0 = _mm256_loadu_si256(b0.as_ptr().add(i) as *const __m256i);
            let v1 = _mm256_loadu_si256(b1.as_ptr().add(i) as *const __m256i);
            let v2 = _mm256_loadu_si256(b2.as_ptr().add(i) as *const __m256i);
            let v3 = _mm256_loadu_si256(b3.as_ptr().add(i) as *const __m256i);
            acc0 = _mm256_add_epi64(acc0, mul64(va, v0));
            acc1 = _mm256_add_epi64(acc1, mul64(va, v1));
            acc2 = _mm256_add_epi64(acc2, mul64(va, v2));
            acc3 = _mm256_add_epi64(acc3, mul64(va, v3));
            i += 4;
        }
        let mut out = [hsum(acc0), hsum(acc1), hsum(acc2), hsum(acc3)];
        while i < len {
            let x = *a.get_unchecked(i);
            out[0] = out[0].wrapping_add(x.wrapping_mul(*b0.get_unchecked(i)));
            out[1] = out[1].wrapping_add(x.wrapping_mul(*b1.get_unchecked(i)));
            out[2] = out[2].wrapping_add(x.wrapping_mul(*b2.get_unchecked(i)));
            out[3] = out[3].wrapping_add(x.wrapping_mul(*b3.get_unchecked(i)));
            i += 1;
        }
        out
    }

    /// Single-column vector dot (column-block tail).
    #[target_feature(enable = "avx2")]
    unsafe fn dot1(a: &[i64], b: &[i64]) -> i64 {
        let len = a.len();
        let mut acc = _mm256_setzero_si256();
        let mut i = 0;
        while i + 4 <= len {
            let va = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
            let vb = _mm256_loadu_si256(b.as_ptr().add(i) as *const __m256i);
            acc = _mm256_add_epi64(acc, mul64(va, vb));
            i += 4;
        }
        let mut out = hsum(acc);
        while i < len {
            out = out.wrapping_add(a.get_unchecked(i).wrapping_mul(*b.get_unchecked(i)));
            i += 1;
        }
        out
    }

    #[target_feature(enable = "avx2")]
    unsafe fn chunk(a_rows: &[i64], bt: &[i64], out: &mut [i64], k: usize, n: usize) {
        let rows = if n == 0 { 0 } else { out.len() / n };
        for dr in 0..rows {
            let arow = &a_rows[dr * k..(dr + 1) * k];
            let orow = &mut out[dr * n..(dr + 1) * n];
            for k0 in (0..k).step_by(TILE_K) {
                let k1 = (k0 + TILE_K).min(k);
                let atile = &arow[k0..k1];
                let mut c = 0;
                while c + 4 <= n {
                    let d = dot4(
                        atile,
                        &bt[c * k + k0..c * k + k1],
                        &bt[(c + 1) * k + k0..(c + 1) * k + k1],
                        &bt[(c + 2) * k + k0..(c + 2) * k + k1],
                        &bt[(c + 3) * k + k0..(c + 3) * k + k1],
                    );
                    orow[c] = orow[c].wrapping_add(d[0]);
                    orow[c + 1] = orow[c + 1].wrapping_add(d[1]);
                    orow[c + 2] = orow[c + 2].wrapping_add(d[2]);
                    orow[c + 3] = orow[c + 3].wrapping_add(d[3]);
                    c += 4;
                }
                while c < n {
                    let btile = &bt[c * k + k0..c * k + k1];
                    orow[c] = orow[c].wrapping_add(dot1(atile, btile));
                    c += 1;
                }
            }
        }
    }

    impl RingKernel for Avx2Kernel {
        fn name(&self) -> &'static str {
            "avx2"
        }

        fn dot(&self, a: &[i64], b: &[i64]) -> i64 {
            debug_assert_eq!(a.len(), b.len());
            // SAFETY: the registry only hands this kernel out when the host
            // advertises avx2 (`available()` above).
            unsafe { dot1(a, b) }
        }

        fn matmul_nt_chunk(&self, a_rows: &[i64], bt: &[i64], out: &mut [i64], k: usize, n: usize) {
            // SAFETY: see `dot` — avx2 is guaranteed by the registry probe.
            unsafe { chunk(a_rows, bt, out, k, n) }
        }
    }
}

#[cfg(all(target_arch = "x86_64", centaur_avx512))]
mod x86_avx512 {
    //! AVX-512 kernel: 8×i64 lanes with the native 64-bit `vpmullq`
    //! (AVX-512DQ). Gated on rustc ≥ 1.89 by the `build.rs` probe.

    use core::arch::x86_64::*;

    use super::{RingKernel, TILE_K};

    pub(super) static AVX512: Avx512Kernel = Avx512Kernel;

    /// 8-lane AVX-512F/DQ kernel (runtime-detected via the registry probe).
    pub struct Avx512Kernel;

    pub(super) fn available() -> bool {
        std::arch::is_x86_feature_detected!("avx512f") && std::arch::is_x86_feature_detected!("avx512dq")
    }

    /// Wrapping horizontal sum of the 8 lanes.
    #[inline]
    #[target_feature(enable = "avx512f,avx512dq")]
    unsafe fn hsum(v: __m512i) -> i64 {
        let mut t = [0i64; 8];
        _mm512_storeu_epi64(t.as_mut_ptr(), v);
        t.iter().fold(0i64, |s, &x| s.wrapping_add(x))
    }

    /// One `A` tile against four `B^T` rows (equal lengths).
    #[target_feature(enable = "avx512f,avx512dq")]
    unsafe fn dot4(a: &[i64], b0: &[i64], b1: &[i64], b2: &[i64], b3: &[i64]) -> [i64; 4] {
        let len = a.len();
        let mut acc0 = _mm512_setzero_si512();
        let mut acc1 = _mm512_setzero_si512();
        let mut acc2 = _mm512_setzero_si512();
        let mut acc3 = _mm512_setzero_si512();
        let mut i = 0;
        while i + 8 <= len {
            let va = _mm512_loadu_epi64(a.as_ptr().add(i));
            let v0 = _mm512_loadu_epi64(b0.as_ptr().add(i));
            let v1 = _mm512_loadu_epi64(b1.as_ptr().add(i));
            let v2 = _mm512_loadu_epi64(b2.as_ptr().add(i));
            let v3 = _mm512_loadu_epi64(b3.as_ptr().add(i));
            acc0 = _mm512_add_epi64(acc0, _mm512_mullo_epi64(va, v0));
            acc1 = _mm512_add_epi64(acc1, _mm512_mullo_epi64(va, v1));
            acc2 = _mm512_add_epi64(acc2, _mm512_mullo_epi64(va, v2));
            acc3 = _mm512_add_epi64(acc3, _mm512_mullo_epi64(va, v3));
            i += 8;
        }
        let mut out = [hsum(acc0), hsum(acc1), hsum(acc2), hsum(acc3)];
        while i < len {
            let x = *a.get_unchecked(i);
            out[0] = out[0].wrapping_add(x.wrapping_mul(*b0.get_unchecked(i)));
            out[1] = out[1].wrapping_add(x.wrapping_mul(*b1.get_unchecked(i)));
            out[2] = out[2].wrapping_add(x.wrapping_mul(*b2.get_unchecked(i)));
            out[3] = out[3].wrapping_add(x.wrapping_mul(*b3.get_unchecked(i)));
            i += 1;
        }
        out
    }

    /// Single-column vector dot (column-block tail).
    #[target_feature(enable = "avx512f,avx512dq")]
    unsafe fn dot1(a: &[i64], b: &[i64]) -> i64 {
        let len = a.len();
        let mut acc = _mm512_setzero_si512();
        let mut i = 0;
        while i + 8 <= len {
            let va = _mm512_loadu_epi64(a.as_ptr().add(i));
            let vb = _mm512_loadu_epi64(b.as_ptr().add(i));
            acc = _mm512_add_epi64(acc, _mm512_mullo_epi64(va, vb));
            i += 8;
        }
        let mut out = hsum(acc);
        while i < len {
            out = out.wrapping_add(a.get_unchecked(i).wrapping_mul(*b.get_unchecked(i)));
            i += 1;
        }
        out
    }

    #[target_feature(enable = "avx512f,avx512dq")]
    unsafe fn chunk(a_rows: &[i64], bt: &[i64], out: &mut [i64], k: usize, n: usize) {
        let rows = if n == 0 { 0 } else { out.len() / n };
        for dr in 0..rows {
            let arow = &a_rows[dr * k..(dr + 1) * k];
            let orow = &mut out[dr * n..(dr + 1) * n];
            for k0 in (0..k).step_by(TILE_K) {
                let k1 = (k0 + TILE_K).min(k);
                let atile = &arow[k0..k1];
                let mut c = 0;
                while c + 4 <= n {
                    let d = dot4(
                        atile,
                        &bt[c * k + k0..c * k + k1],
                        &bt[(c + 1) * k + k0..(c + 1) * k + k1],
                        &bt[(c + 2) * k + k0..(c + 2) * k + k1],
                        &bt[(c + 3) * k + k0..(c + 3) * k + k1],
                    );
                    orow[c] = orow[c].wrapping_add(d[0]);
                    orow[c + 1] = orow[c + 1].wrapping_add(d[1]);
                    orow[c + 2] = orow[c + 2].wrapping_add(d[2]);
                    orow[c + 3] = orow[c + 3].wrapping_add(d[3]);
                    c += 4;
                }
                while c < n {
                    let btile = &bt[c * k + k0..c * k + k1];
                    orow[c] = orow[c].wrapping_add(dot1(atile, btile));
                    c += 1;
                }
            }
        }
    }

    impl RingKernel for Avx512Kernel {
        fn name(&self) -> &'static str {
            "avx512"
        }

        fn dot(&self, a: &[i64], b: &[i64]) -> i64 {
            debug_assert_eq!(a.len(), b.len());
            // SAFETY: registry probe guarantees avx512f+avx512dq.
            unsafe { dot1(a, b) }
        }

        fn matmul_nt_chunk(&self, a_rows: &[i64], bt: &[i64], out: &mut [i64], k: usize, n: usize) {
            // SAFETY: registry probe guarantees avx512f+avx512dq.
            unsafe { chunk(a_rows, bt, out, k, n) }
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod arm_neon {
    //! NEON kernel: 2×i64 lanes. Like AVX2, NEON has no 64-bit vector
    //! multiply, so the wrapping product is synthesized from `vmull_u32`.

    use core::arch::aarch64::*;

    use super::{RingKernel, TILE_K};

    pub(super) static NEON: NeonKernel = NeonKernel;

    /// 2-lane NEON kernel (aarch64; runtime-detected for form's sake —
    /// NEON is baseline on every aarch64 target this crate builds for).
    pub struct NeonKernel;

    pub(super) fn available() -> bool {
        std::arch::is_aarch64_feature_detected!("neon")
    }

    /// Lane-wise wrapping i64 multiply: `lo·lo + ((hi·lo + lo·hi) << 32)`.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn mul64(a: int64x2_t, b: int64x2_t) -> int64x2_t {
        let au = vreinterpretq_u64_s64(a);
        let bu = vreinterpretq_u64_s64(b);
        let a_lo = vmovn_u64(au);
        let b_lo = vmovn_u64(bu);
        let a_hi = vmovn_u64(vshrq_n_u64::<32>(au));
        let b_hi = vmovn_u64(vshrq_n_u64::<32>(bu));
        let lo = vmull_u32(a_lo, b_lo);
        let cross = vaddq_u64(vmull_u32(a_hi, b_lo), vmull_u32(a_lo, b_hi));
        vreinterpretq_s64_u64(vaddq_u64(lo, vshlq_n_u64::<32>(cross)))
    }

    /// Wrapping horizontal sum of the 2 lanes.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn hsum(v: int64x2_t) -> i64 {
        vgetq_lane_s64::<0>(v).wrapping_add(vgetq_lane_s64::<1>(v))
    }

    /// One `A` tile against four `B^T` rows (equal lengths).
    #[target_feature(enable = "neon")]
    unsafe fn dot4(a: &[i64], b0: &[i64], b1: &[i64], b2: &[i64], b3: &[i64]) -> [i64; 4] {
        let len = a.len();
        let mut acc0 = vdupq_n_s64(0);
        let mut acc1 = vdupq_n_s64(0);
        let mut acc2 = vdupq_n_s64(0);
        let mut acc3 = vdupq_n_s64(0);
        let mut i = 0;
        while i + 2 <= len {
            let va = vld1q_s64(a.as_ptr().add(i));
            acc0 = vaddq_s64(acc0, mul64(va, vld1q_s64(b0.as_ptr().add(i))));
            acc1 = vaddq_s64(acc1, mul64(va, vld1q_s64(b1.as_ptr().add(i))));
            acc2 = vaddq_s64(acc2, mul64(va, vld1q_s64(b2.as_ptr().add(i))));
            acc3 = vaddq_s64(acc3, mul64(va, vld1q_s64(b3.as_ptr().add(i))));
            i += 2;
        }
        let mut out = [hsum(acc0), hsum(acc1), hsum(acc2), hsum(acc3)];
        if i < len {
            let x = *a.get_unchecked(i);
            out[0] = out[0].wrapping_add(x.wrapping_mul(*b0.get_unchecked(i)));
            out[1] = out[1].wrapping_add(x.wrapping_mul(*b1.get_unchecked(i)));
            out[2] = out[2].wrapping_add(x.wrapping_mul(*b2.get_unchecked(i)));
            out[3] = out[3].wrapping_add(x.wrapping_mul(*b3.get_unchecked(i)));
        }
        out
    }

    /// Single-column vector dot (column-block tail).
    #[target_feature(enable = "neon")]
    unsafe fn dot1(a: &[i64], b: &[i64]) -> i64 {
        let len = a.len();
        let mut acc = vdupq_n_s64(0);
        let mut i = 0;
        while i + 2 <= len {
            let va = vld1q_s64(a.as_ptr().add(i));
            let vb = vld1q_s64(b.as_ptr().add(i));
            acc = vaddq_s64(acc, mul64(va, vb));
            i += 2;
        }
        let mut out = hsum(acc);
        if i < len {
            out = out.wrapping_add(a.get_unchecked(i).wrapping_mul(*b.get_unchecked(i)));
        }
        out
    }

    #[target_feature(enable = "neon")]
    unsafe fn chunk(a_rows: &[i64], bt: &[i64], out: &mut [i64], k: usize, n: usize) {
        let rows = if n == 0 { 0 } else { out.len() / n };
        for dr in 0..rows {
            let arow = &a_rows[dr * k..(dr + 1) * k];
            let orow = &mut out[dr * n..(dr + 1) * n];
            for k0 in (0..k).step_by(TILE_K) {
                let k1 = (k0 + TILE_K).min(k);
                let atile = &arow[k0..k1];
                let mut c = 0;
                while c + 4 <= n {
                    let d = dot4(
                        atile,
                        &bt[c * k + k0..c * k + k1],
                        &bt[(c + 1) * k + k0..(c + 1) * k + k1],
                        &bt[(c + 2) * k + k0..(c + 2) * k + k1],
                        &bt[(c + 3) * k + k0..(c + 3) * k + k1],
                    );
                    orow[c] = orow[c].wrapping_add(d[0]);
                    orow[c + 1] = orow[c + 1].wrapping_add(d[1]);
                    orow[c + 2] = orow[c + 2].wrapping_add(d[2]);
                    orow[c + 3] = orow[c + 3].wrapping_add(d[3]);
                    c += 4;
                }
                while c < n {
                    let btile = &bt[c * k + k0..c * k + k1];
                    orow[c] = orow[c].wrapping_add(dot1(atile, btile));
                    c += 1;
                }
            }
        }
    }

    impl RingKernel for NeonKernel {
        fn name(&self) -> &'static str {
            "neon"
        }

        fn dot(&self, a: &[i64], b: &[i64]) -> i64 {
            debug_assert_eq!(a.len(), b.len());
            // SAFETY: registry probe guarantees neon.
            unsafe { dot1(a, b) }
        }

        fn matmul_nt_chunk(&self, a_rows: &[i64], bt: &[i64], out: &mut [i64], k: usize, n: usize) {
            // SAFETY: registry probe guarantees neon.
            unsafe { chunk(a_rows, bt, out, k, n) }
        }
    }
}

#[cfg(feature = "xla")]
mod xla_ring {
    //! The AOT ring-artifact path as a registered kernel: selecting
    //! `CENTAUR_RING_KERNEL=xla` routes every `ring::matmul_nt` through
    //! `artifacts/ring/manifest.json` (PJRT execution), falling back to the
    //! scalar kernel for shapes with no compiled artifact (counted).

    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Mutex;

    use super::{RingKernel, SCALAR};
    use crate::tensor::RingTensor;

    pub(super) static XLA: XlaRingKernel =
        XlaRingKernel { backend: Mutex::new(None), fallbacks: AtomicU64::new(0) };

    /// Lazy PJRT-backed ring kernel. Artifacts dir comes from
    /// `CENTAUR_ARTIFACTS` (default `data::artifacts_dir()`), the model tag
    /// from `CENTAUR_XLA_MODEL` (default `bert-tiny`).
    pub struct XlaRingKernel {
        backend: Mutex<Option<crate::runtime::XlaBackend>>,
        fallbacks: AtomicU64,
    }

    impl XlaRingKernel {
        /// Matmuls served by the scalar fallback (no artifact for shape).
        pub fn fallbacks(&self) -> u64 {
            self.fallbacks.load(Ordering::Relaxed)
        }
    }

    impl RingKernel for XlaRingKernel {
        fn name(&self) -> &'static str {
            "xla"
        }

        fn dot(&self, a: &[i64], b: &[i64]) -> i64 {
            // No dot artifacts are lowered; the ring set is matmul-only.
            crate::ring::dot_wrapping(a, b)
        }

        fn matmul_nt_chunk(&self, a_rows: &[i64], bt: &[i64], out: &mut [i64], k: usize, n: usize) {
            SCALAR.matmul_nt_chunk(a_rows, bt, out, k, n)
        }

        fn matmul_nt(&self, a: &RingTensor, bt: &RingTensor) -> RingTensor {
            let mut guard = self.backend.lock().unwrap();
            if guard.is_none() {
                let dir = std::env::var("CENTAUR_ARTIFACTS")
                    .unwrap_or_else(|_| crate::data::artifacts_dir());
                let model =
                    std::env::var("CENTAUR_XLA_MODEL").unwrap_or_else(|_| "bert-tiny".to_string());
                match crate::runtime::XlaBackend::new(&dir, &model) {
                    Ok(b) => *guard = Some(b),
                    // An explicitly selected kernel must not silently
                    // degrade — fail as loudly as a bad kernel name does.
                    Err(e) => panic!("CENTAUR_RING_KERNEL=xla: cannot start PJRT backend: {e:#}"),
                }
            }
            let backend = guard.as_mut().unwrap();
            let b = bt.transpose();
            match backend.ring_matmul(a, &b) {
                Ok(Some(c)) => c,
                Ok(None) | Err(_) => {
                    self.fallbacks.fetch_add(1, Ordering::Relaxed);
                    drop(guard);
                    (&SCALAR as &dyn RingKernel).matmul_nt(a, bt)
                }
            }
        }
    }
}

/// Registry order — also the documentation order. `auto` resolution
/// probes `AUTO_ORDER` instead (widest first, never `xla`).
pub const KERNEL_NAMES: &[&str] = &["scalar", "avx2", "avx512", "neon", "xla"];

const AUTO_ORDER: &[&str] = &["avx512", "avx2", "neon", "scalar"];

/// `usize::MAX` = no cached selection.
static SELECTED: AtomicUsize = AtomicUsize::new(usize::MAX);

/// One registry row: a kernel name plus whether this host/build can run it.
#[derive(Clone, Debug)]
pub struct KernelDesc {
    /// Registry name (`scalar`, `avx2`, `avx512`, `neon`, `xla`).
    pub name: &'static str,
    /// Whether [`kernel_by_name`] would succeed for it here.
    pub available: bool,
    /// `"ok"`, or the reason the kernel is unavailable.
    pub detail: String,
}

fn probe_scalar() -> std::result::Result<&'static dyn RingKernel, String> {
    Ok(&SCALAR)
}

#[cfg(target_arch = "x86_64")]
fn probe_avx2() -> std::result::Result<&'static dyn RingKernel, String> {
    if x86_avx2::available() {
        Ok(&x86_avx2::AVX2)
    } else {
        Err("host CPU does not advertise avx2".to_string())
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn probe_avx2() -> std::result::Result<&'static dyn RingKernel, String> {
    Err("avx2 kernel requires an x86_64 host".to_string())
}

#[cfg(all(target_arch = "x86_64", centaur_avx512))]
fn probe_avx512() -> std::result::Result<&'static dyn RingKernel, String> {
    if x86_avx512::available() {
        Ok(&x86_avx512::AVX512)
    } else {
        Err("host CPU does not advertise avx512f+avx512dq".to_string())
    }
}

#[cfg(all(target_arch = "x86_64", not(centaur_avx512)))]
fn probe_avx512() -> std::result::Result<&'static dyn RingKernel, String> {
    Err("built without AVX-512 support (needs rustc >= 1.89; see build.rs)".to_string())
}

#[cfg(not(target_arch = "x86_64"))]
fn probe_avx512() -> std::result::Result<&'static dyn RingKernel, String> {
    Err("avx512 kernel requires an x86_64 host".to_string())
}

#[cfg(target_arch = "aarch64")]
fn probe_neon() -> std::result::Result<&'static dyn RingKernel, String> {
    if arm_neon::available() {
        Ok(&arm_neon::NEON)
    } else {
        Err("host CPU does not advertise neon".to_string())
    }
}

#[cfg(not(target_arch = "aarch64"))]
fn probe_neon() -> std::result::Result<&'static dyn RingKernel, String> {
    Err("neon kernel requires an aarch64 host".to_string())
}

#[cfg(feature = "xla")]
fn probe_xla() -> std::result::Result<&'static dyn RingKernel, String> {
    Ok(&xla_ring::XLA)
}

#[cfg(not(feature = "xla"))]
fn probe_xla() -> std::result::Result<&'static dyn RingKernel, String> {
    Err("built without the `xla` feature (rebuild with --features xla)".to_string())
}

fn probe(name: &str) -> std::result::Result<&'static dyn RingKernel, String> {
    match name {
        "scalar" => probe_scalar(),
        "avx2" => probe_avx2(),
        "avx512" => probe_avx512(),
        "neon" => probe_neon(),
        "xla" => probe_xla(),
        other => {
            Err(format!("unknown ring kernel '{other}' (expected one of {KERNEL_NAMES:?} or auto)"))
        }
    }
}

/// Describe every registered kernel and its availability on this
/// host/build (diagnostics, benches, `--ring-kernel` error messages).
pub fn available_kernels() -> Vec<KernelDesc> {
    KERNEL_NAMES
        .iter()
        .map(|&name| match probe(name) {
            Ok(_) => KernelDesc { name, available: true, detail: "ok".to_string() },
            Err(why) => KernelDesc { name, available: false, detail: why },
        })
        .collect()
}

/// Resolve a kernel by registry name, erroring with the reason when the
/// host/build cannot run it. Does not change the dispatched selection.
pub fn kernel_by_name(name: &str) -> Result<&'static dyn RingKernel> {
    probe(name).map_err(|why| anyhow::anyhow!("ring kernel '{name}': {why}"))
}

fn auto_kernel() -> (usize, &'static dyn RingKernel) {
    for &name in AUTO_ORDER {
        if let Ok(k) = probe(name) {
            let idx = KERNEL_NAMES.iter().position(|&n| n == name).unwrap();
            return (idx, k);
        }
    }
    unreachable!("scalar kernel is always available")
}

/// The kernel every `ring::matmul_nt` dispatches through.
///
/// Resolution order: a programmatic [`set_override`] or cached prior
/// selection; else `CENTAUR_RING_KERNEL` (a name, or `auto`/empty); else
/// auto-detection (widest host kernel). An explicitly named kernel that is
/// unknown or unavailable **panics** — a forced kernel silently degrading
/// to another would make every A/B number dishonest.
pub fn selected() -> &'static dyn RingKernel {
    let idx = SELECTED.load(Ordering::Relaxed);
    if idx != usize::MAX {
        return probe(KERNEL_NAMES[idx]).expect("cached ring kernel no longer available");
    }
    let (idx, kern) = match std::env::var("CENTAUR_RING_KERNEL") {
        Ok(name) if !name.is_empty() && name != "auto" => match probe(&name) {
            Ok(k) => (KERNEL_NAMES.iter().position(|&n| n == name.as_str()).unwrap(), k),
            Err(why) => panic!("CENTAUR_RING_KERNEL={name}: {why}"),
        },
        _ => auto_kernel(),
    };
    SELECTED.store(idx, Ordering::Relaxed);
    kern
}

/// Name of the currently dispatched kernel (resolving it if needed).
pub fn selected_name() -> &'static str {
    selected().name()
}

/// Force the dispatched kernel (`Some(name)`) or clear the cache and fall
/// back to env/auto resolution (`None`). The CLI's `--ring-kernel` flag
/// lands here; errors (with the availability reason) instead of panicking
/// so callers can report nicely.
pub fn set_override(name: Option<&str>) -> Result<()> {
    match name {
        None => {
            SELECTED.store(usize::MAX, Ordering::Relaxed);
            Ok(())
        }
        Some(name) => {
            let _ = kernel_by_name(name).map_err(|e| {
                let avail: Vec<&str> =
                    available_kernels().iter().filter(|d| d.available).map(|d| d.name).collect();
                anyhow::anyhow!("{e} (available here: {avail:?})")
            })?;
            let idx = KERNEL_NAMES.iter().position(|&n| n == name).unwrap();
            SELECTED.store(idx, Ordering::Relaxed);
            Ok(())
        }
    }
}

/// Drop the cached selection so the next [`selected`] re-reads
/// `CENTAUR_RING_KERNEL` — for benches/tests that vary the env var
/// mid-process (mirrors [`pool::refresh_threads`]).
pub fn refresh() {
    SELECTED.store(usize::MAX, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rt(r: usize, c: usize, rng: &mut Rng) -> RingTensor {
        RingTensor::from_vec(r, c, rng.vec_i64(r * c))
    }

    #[test]
    fn scalar_always_probes() {
        assert_eq!(kernel_by_name("scalar").unwrap().name(), "scalar");
        assert!(available_kernels().iter().any(|d| d.name == "scalar" && d.available));
    }

    #[test]
    fn unknown_kernel_is_descriptive_error() {
        let err = kernel_by_name("warp9").unwrap_err().to_string();
        assert!(err.contains("warp9") && err.contains("scalar"), "{err}");
    }

    #[test]
    fn override_roundtrip() {
        set_override(Some("scalar")).unwrap();
        assert_eq!(selected_name(), "scalar");
        assert!(set_override(Some("warp9")).is_err());
        // a failed override must not clobber the previous selection
        assert_eq!(selected_name(), "scalar");
        set_override(None).unwrap();
        let auto = selected_name();
        assert!(KERNEL_NAMES.contains(&auto));
    }

    #[test]
    fn every_available_kernel_matches_scalar() {
        let mut rng = Rng::new(0xC0FFEE);
        let a = rt(5, 67, &mut rng);
        let bt = rt(9, 67, &mut rng);
        let want = (&ScalarKernel as &dyn RingKernel).matmul_nt(&a, &bt);
        for desc in available_kernels() {
            if !desc.available || desc.name == "xla" {
                continue;
            }
            let k = kernel_by_name(desc.name).unwrap();
            assert_eq!(k.matmul_nt(&a, &bt), want, "kernel {}", desc.name);
            let x = rng.vec_i64(33);
            let y = rng.vec_i64(33);
            assert_eq!(k.dot(&x, &y), crate::ring::dot_wrapping(&x, &y), "dot {}", desc.name);
        }
    }
}
