//! PJRT execution of the AOT artifacts (the production request path).
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Executables are compiled once per
//! (op, shape) and cached for the life of the backend.

use std::collections::BTreeMap;
use std::path::Path;

use super::{ArtifactRegistry, Backend, NativeBackend};
use crate::tensor::{FloatTensor, RingTensor};
use crate::Result;

/// Backend running the Pallas-lowered HLO artifacts through PJRT.
pub struct XlaBackend {
    client: xla::PjRtClient,
    registry: ArtifactRegistry,
    cache: BTreeMap<String, xla::PjRtLoadedExecutable>,
    native: NativeBackend,
    fallbacks: u64,
    /// Executions served from artifacts (diagnostics).
    pub hits: u64,
}

impl XlaBackend {
    /// Load the model's artifact registry and start a PJRT CPU client.
    pub fn new(artifacts_dir: &str, model: &str) -> Result<Self> {
        let registry = ArtifactRegistry::load(artifacts_dir, model)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(XlaBackend {
            client,
            registry,
            cache: BTreeMap::new(),
            native: NativeBackend::new(),
            fallbacks: 0,
            hits: 0,
        })
    }

    fn executable(&mut self, key: String, path: &Path) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(&key) {
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {}: {e:?}", path.display()))?;
            self.cache.insert(key.clone(), exe);
        }
        Ok(self.cache.get(&key).unwrap())
    }

    fn lit_f32(t: &FloatTensor) -> Result<xla::Literal> {
        xla::Literal::vec1(t.data())
            .reshape(&[t.rows() as i64, t.cols() as i64])
            .map_err(|e| anyhow::anyhow!("literal reshape: {e:?}"))
    }

    fn lit_vec_f32(v: &[f32]) -> xla::Literal {
        xla::Literal::vec1(v)
    }

    fn run(&mut self, key: String, path: &Path, args: &[xla::Literal], rows: usize, cols: usize) -> Result<FloatTensor> {
        let exe = self.executable(key, path)?;
        let result = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        // aot.py lowers with return_tuple=True → 1-tuple.
        let out = result.to_tuple1().map_err(|e| anyhow::anyhow!("tuple1: {e:?}"))?;
        let values = out.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
        anyhow::ensure!(values.len() == rows * cols, "xla output size {} != {rows}x{cols}", values.len());
        self.hits += 1;
        Ok(FloatTensor::from_vec(rows, cols, values))
    }

    fn unary(&mut self, op: &str, x: &FloatTensor) -> Result<Option<FloatTensor>> {
        let (rows, cols) = x.shape();
        let Some(path) = self.registry.lookup(op, rows, cols).cloned() else {
            self.fallbacks += 1;
            return Ok(None);
        };
        let key = format!("{op}_{rows}x{cols}");
        let arg = Self::lit_f32(x)?;
        Ok(Some(self.run(key, &path, &[arg], rows, cols)?))
    }

    /// Ring matmul through the AOT s64 Pallas kernel (ablation path).
    /// Returns None when no artifact exists for this shape.
    pub fn ring_matmul(&mut self, a: &RingTensor, b: &RingTensor) -> Result<Option<RingTensor>> {
        let (m, k) = a.shape();
        let (k2, n) = b.shape();
        anyhow::ensure!(k == k2, "ring matmul inner dim");
        let Some(path) = self.registry.lookup_ring(m, k, n).cloned() else {
            return Ok(None);
        };
        let key = format!("ring_{m}x{k}x{n}");
        let la = xla::Literal::vec1(a.data())
            .reshape(&[m as i64, k as i64])
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let lb = xla::Literal::vec1(b.data())
            .reshape(&[k as i64, n as i64])
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let exe = self.executable(key, &path)?;
        let result = exe
            .execute::<xla::Literal>(&[la, lb])
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let values = out.to_vec::<i64>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        self.hits += 1;
        Ok(Some(RingTensor::from_vec(m, n, values)))
    }

    /// Number of distinct compiled executables held.
    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }
}

impl Backend for XlaBackend {
    fn softmax(&mut self, x: &FloatTensor) -> Result<FloatTensor> {
        match self.unary("softmax", x)? {
            Some(y) => Ok(y),
            None => self.native.softmax(x),
        }
    }

    fn gelu(&mut self, x: &FloatTensor) -> Result<FloatTensor> {
        match self.unary("gelu", x)? {
            Some(y) => Ok(y),
            None => self.native.gelu(x),
        }
    }

    fn layernorm(&mut self, x: &FloatTensor, gamma: &[f32], beta: &[f32]) -> Result<FloatTensor> {
        let (rows, cols) = x.shape();
        let Some(path) = self.registry.lookup("layernorm", rows, cols).cloned() else {
            self.fallbacks += 1;
            return self.native.layernorm(x, gamma, beta);
        };
        let key = format!("layernorm_{rows}x{cols}");
        let args = [Self::lit_f32(x)?, Self::lit_vec_f32(gamma), Self::lit_vec_f32(beta)];
        self.run(key, &path, &args, rows, cols)
    }

    fn tanh(&mut self, x: &FloatTensor) -> Result<FloatTensor> {
        match self.unary("tanh", x)? {
            Some(y) => Ok(y),
            None => self.native.tanh(x),
        }
    }

    fn name(&self) -> &'static str {
        "xla"
    }

    fn fallbacks(&self) -> u64 {
        self.fallbacks
    }
}
