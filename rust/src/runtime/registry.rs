//! Artifact registry: reads the manifests emitted by `python/compile/aot.py`
//! and resolves (op, shape) → HLO text file.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json;
use crate::Result;

/// Key identifying one lowered op artifact.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct OpKey {
    /// Op name (`softmax`, `gelu`, `layernorm`, …).
    pub op: String,
    /// Operand row count the artifact was lowered for.
    pub rows: usize,
    /// Operand column count the artifact was lowered for.
    pub cols: usize,
}

/// Parsed per-model artifact manifest.
#[derive(Debug)]
pub struct ArtifactRegistry {
    /// Model tag this registry serves.
    pub model: String,
    dir: PathBuf,
    ops: BTreeMap<OpKey, PathBuf>,
    /// Ring-matmul ablation kernels: (m, k, n) → file.
    ring: BTreeMap<(usize, usize, usize), PathBuf>,
}

impl ArtifactRegistry {
    /// Load `artifacts/<model>/manifest.json` (and the shared ring set).
    pub fn load(artifacts_dir: &str, model: &str) -> Result<Self> {
        let dir = Path::new(artifacts_dir).join(model);
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .map_err(|e| anyhow::anyhow!("cannot read {}: {e} (run `make artifacts`)", manifest_path.display()))?;
        let doc = json::parse(&text).map_err(|e| anyhow::anyhow!("bad manifest: {e}"))?;
        let mut ops = BTreeMap::new();
        for op in doc.get("ops").as_arr().unwrap_or(&[]) {
            let key = OpKey {
                op: op.get("op").as_str().unwrap_or_default().to_string(),
                rows: op.get("rows").as_usize().unwrap_or(0),
                cols: op.get("cols").as_usize().unwrap_or(0),
            };
            let file = dir.join(op.get("file").as_str().unwrap_or_default());
            anyhow::ensure!(file.exists(), "missing artifact {}", file.display());
            ops.insert(key, file);
        }
        let mut ring = BTreeMap::new();
        let ring_manifest = Path::new(artifacts_dir).join("ring").join("manifest.json");
        if let Ok(rt) = std::fs::read_to_string(&ring_manifest) {
            if let Ok(rdoc) = json::parse(&rt) {
                for e in rdoc.get("shapes").as_arr().unwrap_or(&[]) {
                    let key = (
                        e.get("m").as_usize().unwrap_or(0),
                        e.get("k").as_usize().unwrap_or(0),
                        e.get("n").as_usize().unwrap_or(0),
                    );
                    ring.insert(
                        key,
                        Path::new(artifacts_dir).join("ring").join(e.get("file").as_str().unwrap_or_default()),
                    );
                }
            }
        }
        Ok(ArtifactRegistry { model: model.to_string(), dir, ops, ring })
    }

    /// Resolve an op artifact path.
    pub fn lookup(&self, op: &str, rows: usize, cols: usize) -> Option<&PathBuf> {
        self.ops.get(&OpKey { op: op.to_string(), rows, cols })
    }

    /// Resolve a ring-matmul artifact path.
    pub fn lookup_ring(&self, m: usize, k: usize, n: usize) -> Option<&PathBuf> {
        self.ring.get(&(m, k, n))
    }

    /// All op keys (diagnostics).
    pub fn keys(&self) -> impl Iterator<Item = &OpKey> {
        self.ops.keys()
    }

    /// Base directory of this model's artifacts.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Build a registry from an in-memory manifest (tests).
    pub fn from_parts(model: &str, dir: PathBuf, ops: BTreeMap<OpKey, PathBuf>) -> Self {
        ArtifactRegistry { model: model.to_string(), dir, ops, ring: BTreeMap::new() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parse_and_lookup() {
        let tmp = std::env::temp_dir().join(format!("centaur_reg_{}", std::process::id()));
        let mdir = tmp.join("toy");
        std::fs::create_dir_all(&mdir).unwrap();
        std::fs::write(mdir.join("softmax_4x4.hlo.txt"), "HloModule x").unwrap();
        std::fs::write(
            mdir.join("manifest.json"),
            r#"{"model":"toy","ops":[{"op":"softmax","rows":4,"cols":4,"file":"softmax_4x4.hlo.txt"}]}"#,
        )
        .unwrap();
        let reg = ArtifactRegistry::load(tmp.to_str().unwrap(), "toy").unwrap();
        assert!(reg.lookup("softmax", 4, 4).is_some());
        assert!(reg.lookup("softmax", 8, 4).is_none());
        assert!(reg.lookup("gelu", 4, 4).is_none());
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn missing_manifest_is_helpful_error() {
        let err = ArtifactRegistry::load("/nonexistent", "toy").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
