//! Artifact registry: reads the manifests emitted by `python/compile/aot.py`
//! and resolves (op, shape) → HLO text file.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json;
use crate::Result;

/// Key identifying one lowered op artifact.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct OpKey {
    /// Op name (`softmax`, `gelu`, `layernorm`, …).
    pub op: String,
    /// Operand row count the artifact was lowered for.
    pub rows: usize,
    /// Operand column count the artifact was lowered for.
    pub cols: usize,
}

/// Parsed per-model artifact manifest.
#[derive(Debug)]
pub struct ArtifactRegistry {
    /// Model tag this registry serves.
    pub model: String,
    dir: PathBuf,
    ops: BTreeMap<OpKey, PathBuf>,
    /// Ring-matmul ablation kernels: (m, k, n) → file.
    ring: BTreeMap<(usize, usize, usize), PathBuf>,
}

impl ArtifactRegistry {
    /// Load `artifacts/<model>/manifest.json` (and the shared ring set).
    pub fn load(artifacts_dir: &str, model: &str) -> Result<Self> {
        let dir = Path::new(artifacts_dir).join(model);
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .map_err(|e| anyhow::anyhow!("cannot read {}: {e} (run `make artifacts`)", manifest_path.display()))?;
        let doc = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("bad manifest {}: {e}", manifest_path.display()))?;
        // Field accessors fail loudly with the entry and field name — the
        // old `unwrap_or(0)` / `unwrap_or_default()` turned a typo'd
        // manifest into a registry full of 0×0 keys that silently never
        // matched, so every op fell back to native with no diagnostic.
        fn str_field(path: &Path, entry: &json::Json, field: &str) -> Result<String> {
            match entry.get(field).as_str() {
                Some(s) if !s.is_empty() => Ok(s.to_string()),
                _ => anyhow::bail!(
                    "manifest {}: entry {entry} field '{field}' missing or not a non-empty string",
                    path.display()
                ),
            }
        }
        fn usize_field(path: &Path, entry: &json::Json, field: &str) -> Result<usize> {
            entry.get(field).as_usize().ok_or_else(|| {
                anyhow::anyhow!(
                    "manifest {}: entry {entry} field '{field}' is {}, expected a non-negative integer",
                    path.display(),
                    entry.get(field)
                )
            })
        }
        let mut ops = BTreeMap::new();
        for op in doc.get("ops").as_arr().unwrap_or(&[]) {
            let key = OpKey {
                op: str_field(&manifest_path, op, "op")?,
                rows: usize_field(&manifest_path, op, "rows")?,
                cols: usize_field(&manifest_path, op, "cols")?,
            };
            let file = dir.join(str_field(&manifest_path, op, "file")?);
            anyhow::ensure!(file.exists(), "missing artifact {}", file.display());
            ops.insert(key, file);
        }
        let mut ring = BTreeMap::new();
        let ring_dir = Path::new(artifacts_dir).join("ring");
        let ring_manifest = ring_dir.join("manifest.json");
        // Absent ring manifest is fine (the ring set is optional); any other
        // read or parse failure is a real error — the old `if let Ok` chain
        // swallowed corrupt manifests and the registry quietly had no ring
        // kernels.
        match std::fs::read_to_string(&ring_manifest) {
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => anyhow::bail!("cannot read {}: {e}", ring_manifest.display()),
            Ok(rt) => {
                let rdoc = json::parse(&rt)
                    .map_err(|e| anyhow::anyhow!("bad ring manifest {}: {e}", ring_manifest.display()))?;
                for e in rdoc.get("shapes").as_arr().unwrap_or(&[]) {
                    let key = (
                        usize_field(&ring_manifest, e, "m")?,
                        usize_field(&ring_manifest, e, "k")?,
                        usize_field(&ring_manifest, e, "n")?,
                    );
                    ring.insert(key, ring_dir.join(str_field(&ring_manifest, e, "file")?));
                }
            }
        }
        Ok(ArtifactRegistry { model: model.to_string(), dir, ops, ring })
    }

    /// Resolve an op artifact path.
    pub fn lookup(&self, op: &str, rows: usize, cols: usize) -> Option<&PathBuf> {
        self.ops.get(&OpKey { op: op.to_string(), rows, cols })
    }

    /// Resolve a ring-matmul artifact path.
    pub fn lookup_ring(&self, m: usize, k: usize, n: usize) -> Option<&PathBuf> {
        self.ring.get(&(m, k, n))
    }

    /// All op keys (diagnostics).
    pub fn keys(&self) -> impl Iterator<Item = &OpKey> {
        self.ops.keys()
    }

    /// Base directory of this model's artifacts.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Build a registry from an in-memory manifest (tests).
    pub fn from_parts(model: &str, dir: PathBuf, ops: BTreeMap<OpKey, PathBuf>) -> Self {
        ArtifactRegistry { model: model.to_string(), dir, ops, ring: BTreeMap::new() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parse_and_lookup() {
        let tmp = std::env::temp_dir().join(format!("centaur_reg_{}", std::process::id()));
        let mdir = tmp.join("toy");
        std::fs::create_dir_all(&mdir).unwrap();
        std::fs::write(mdir.join("softmax_4x4.hlo.txt"), "HloModule x").unwrap();
        std::fs::write(
            mdir.join("manifest.json"),
            r#"{"model":"toy","ops":[{"op":"softmax","rows":4,"cols":4,"file":"softmax_4x4.hlo.txt"}]}"#,
        )
        .unwrap();
        let reg = ArtifactRegistry::load(tmp.to_str().unwrap(), "toy").unwrap();
        assert!(reg.lookup("softmax", 4, 4).is_some());
        assert!(reg.lookup("softmax", 8, 4).is_none());
        assert!(reg.lookup("gelu", 4, 4).is_none());
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn missing_manifest_is_helpful_error() {
        let err = ArtifactRegistry::load("/nonexistent", "toy").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    fn toy_dir(tag: &str) -> std::path::PathBuf {
        let tmp = std::env::temp_dir().join(format!("centaur_reg_{}_{tag}", std::process::id()));
        let mdir = tmp.join("toy");
        std::fs::create_dir_all(&mdir).unwrap();
        std::fs::write(mdir.join("manifest.json"), r#"{"model":"toy","ops":[]}"#).unwrap();
        tmp
    }

    #[test]
    fn corrupt_ring_manifest_is_an_error_not_silence() {
        // A parse failure in ring/manifest.json used to be swallowed by an
        // `if let Ok` chain, leaving the registry with zero ring kernels.
        let tmp = toy_dir("ring_corrupt");
        let rdir = tmp.join("ring");
        std::fs::create_dir_all(&rdir).unwrap();
        std::fs::write(rdir.join("manifest.json"), "{not json").unwrap();
        let err = ArtifactRegistry::load(tmp.to_str().unwrap(), "toy").unwrap_err().to_string();
        assert!(err.contains("ring") && err.contains("manifest"), "got: {err}");
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn absent_ring_manifest_is_fine() {
        let tmp = toy_dir("ring_absent");
        let reg = ArtifactRegistry::load(tmp.to_str().unwrap(), "toy").unwrap();
        assert!(reg.lookup_ring(128, 768, 768).is_none());
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn malformed_op_field_names_the_field() {
        let tmp = std::env::temp_dir().join(format!("centaur_reg_{}_badop", std::process::id()));
        let mdir = tmp.join("toy");
        std::fs::create_dir_all(&mdir).unwrap();
        std::fs::write(mdir.join("softmax_4x4.hlo.txt"), "HloModule x").unwrap();
        std::fs::write(
            mdir.join("manifest.json"),
            r#"{"model":"toy","ops":[{"op":"softmax","rows":"four","cols":4,"file":"softmax_4x4.hlo.txt"}]}"#,
        )
        .unwrap();
        let err = ArtifactRegistry::load(tmp.to_str().unwrap(), "toy").unwrap_err().to_string();
        assert!(err.contains("'rows'"), "error should name the field: {err}");
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn malformed_ring_shape_names_the_field() {
        let tmp = toy_dir("ring_badshape");
        let rdir = tmp.join("ring");
        std::fs::create_dir_all(&rdir).unwrap();
        std::fs::write(
            rdir.join("manifest.json"),
            r#"{"shapes":[{"m":128,"k":-768,"n":768,"file":"rm.hlo.txt"}]}"#,
        )
        .unwrap();
        let err = ArtifactRegistry::load(tmp.to_str().unwrap(), "toy").unwrap_err().to_string();
        assert!(err.contains("'k'"), "error should name the field: {err}");
        std::fs::remove_dir_all(&tmp).ok();
    }
}
