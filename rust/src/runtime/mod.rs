//! Execution backends for the plaintext compute Centaur's cloud party (P1)
//! performs on permuted data.
//!
//! Two interchangeable backends implement [`Backend`]:
//!
//! * [`NativeBackend`] — pure Rust, semantics mirrored 1:1 from the pure-jnp
//!   oracle `python/compile/kernels/ref.py`. Always available; `cargo test`
//!   never needs artifacts.
//! * [`XlaBackend`] — loads the AOT artifacts produced by
//!   `python/compile/aot.py` (HLO text lowered from the L1 Pallas kernels)
//!   and executes them on the PJRT CPU client via the `xla` crate. This is
//!   the production path: Python never runs at request time.
//!
//! The engine asks for ops by shape; `XlaBackend` dispatches to a compiled
//! executable when the model's manifest has that shape and falls back to
//! native otherwise (counted, so benches can assert zero fallbacks).
//!
//! The integer side has its own dispatch layer: [`kernel`] selects the
//! `Z_{2^64}` matmul inner kernel ([`kernel::RingKernel`] — scalar, AVX2,
//! AVX-512, NEON, or the `xla` ring artifacts) at runtime, the way
//! [`Backend`] selects the float op executor.

pub mod kernel;
pub mod native;
mod registry;
#[cfg(feature = "xla")]
mod xla_backend;
#[cfg(not(feature = "xla"))]
mod xla_stub;

pub use kernel::RingKernel;
pub use native::NativeBackend;
pub use registry::{ArtifactRegistry, OpKey};
#[cfg(feature = "xla")]
pub use xla_backend::XlaBackend;
#[cfg(not(feature = "xla"))]
pub use xla_stub::XlaBackend;

use crate::tensor::FloatTensor;
use crate::Result;

/// Plaintext op executor used by the Centaur engine at P1.
pub trait Backend {
    /// Row-softmax (paper Eq. 3) over a 2-D tensor.
    fn softmax(&mut self, x: &FloatTensor) -> Result<FloatTensor>;
    /// Exact erf-GeLU (paper Eq. 5), elementwise.
    fn gelu(&mut self, x: &FloatTensor) -> Result<FloatTensor>;
    /// LayerNorm over rows with affine γ/β (paper Eq. 1), eps = 1e-5.
    fn layernorm(&mut self, x: &FloatTensor, gamma: &[f32], beta: &[f32]) -> Result<FloatTensor>;
    /// Elementwise tanh (BERT pooler).
    fn tanh(&mut self, x: &FloatTensor) -> Result<FloatTensor>;
    /// Backend label for reports.
    fn name(&self) -> &'static str;
    /// How many op calls could not be served by AOT artifacts (native = 0).
    fn fallbacks(&self) -> u64 {
        0
    }
}

/// Construct a backend by name: `"native"` or `"xla"` (requires artifacts).
pub fn backend_by_name(name: &str, model: &str, artifacts_dir: &str) -> Result<Box<dyn Backend>> {
    match name {
        "native" => Ok(Box::new(NativeBackend::new())),
        "xla" => Ok(Box::new(XlaBackend::new(artifacts_dir, model)?)),
        other => anyhow::bail!("unknown backend '{other}' (expected native|xla)"),
    }
}

/// LayerNorm epsilon — keep in sync with python/compile/model.py LN_EPS.
pub const LN_EPS: f32 = 1e-5;
