//! Pure-Rust backend — semantics mirror `python/compile/kernels/ref.py`.
//!
//! This backend covers the *float* ops P1 runs on permuted data. The
//! integer ring matmuls are dispatched separately through
//! [`kernel`](super::kernel) — see [`kernel::RingKernel`](super::kernel::RingKernel).

use super::{Backend, LN_EPS};
use crate::tensor::FloatTensor;
use crate::Result;

/// erf via Abramowitz & Stegun 7.1.26 (|err| ≤ 1.5e-7 — below f32 ULP for
/// the GeLU use). Matches jax's erf to f32 precision on the tested domain.
pub fn erf(x: f32) -> f32 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    const A1: f32 = 0.254829592;
    const A2: f32 = -0.284496736;
    const A3: f32 = 1.421413741;
    const A4: f32 = -1.453152027;
    const A5: f32 = 1.061405429;
    const P: f32 = 0.3275911;
    let t = 1.0 / (1.0 + P * x);
    let y = 1.0 - (((((A5 * t + A4) * t) + A3) * t + A2) * t + A1) * t * (-x * x).exp();
    sign * y
}

/// Exact-formula GeLU (paper Eq. 5).
pub fn gelu_scalar(x: f32) -> f32 {
    0.5 * x * (1.0 + erf(x / std::f32::consts::SQRT_2))
}

/// Row softmax on a slice.
pub fn softmax_row(row: &mut [f32]) {
    let tau = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in row.iter_mut() {
        *v = (*v - tau).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// Pure-Rust plaintext op executor.
#[derive(Default)]
pub struct NativeBackend;

impl NativeBackend {
    /// Construct the (stateless) native backend.
    pub fn new() -> Self {
        NativeBackend
    }
}

impl Backend for NativeBackend {
    fn softmax(&mut self, x: &FloatTensor) -> Result<FloatTensor> {
        let mut out = x.clone();
        for r in 0..out.rows() {
            softmax_row(out.row_mut(r));
        }
        Ok(out)
    }

    fn gelu(&mut self, x: &FloatTensor) -> Result<FloatTensor> {
        Ok(x.map(gelu_scalar))
    }

    fn layernorm(&mut self, x: &FloatTensor, gamma: &[f32], beta: &[f32]) -> Result<FloatTensor> {
        anyhow::ensure!(gamma.len() == x.cols() && beta.len() == x.cols(), "ln affine dims");
        let d = x.cols();
        let mut out = x.clone();
        for r in 0..out.rows() {
            let row = out.row_mut(r);
            let mean = row.iter().sum::<f32>() / d as f32;
            let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
            let rstd = 1.0 / (var + LN_EPS).sqrt();
            for c in 0..d {
                row[c] = gamma[c] * (row[c] - mean) * rstd + beta[c];
            }
        }
        Ok(out)
    }

    fn tanh(&mut self, x: &FloatTensor) -> Result<FloatTensor> {
        Ok(x.map(f32::tanh))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_known_values() {
        // table values of erf
        for (x, want) in [(0.0, 0.0), (0.5, 0.5204999), (1.0, 0.8427008), (2.0, 0.9953223)] {
            assert!((erf(x) - want).abs() < 2e-6, "erf({x})={}", erf(x));
            assert!((erf(-x) + want).abs() < 2e-6);
        }
    }

    #[test]
    fn gelu_matches_ref_values() {
        // same table as python/tests/test_kernels.py::test_known_values
        for (x, want) in [(0.0, 0.0), (1.0, 0.84134), (-1.0, -0.15866), (2.0, 1.95450)] {
            assert!((gelu_scalar(x) - want).abs() < 1e-4, "gelu({x})={}", gelu_scalar(x));
        }
    }

    #[test]
    fn softmax_rows_normalized_and_stable() {
        let mut b = NativeBackend::new();
        let x = FloatTensor::from_vec(2, 4, vec![1e4, 0.0, -1e4, 5.0, 0.1, 0.2, 0.3, 0.4]);
        let y = b.softmax(&x).unwrap();
        for r in 0..2 {
            let s: f32 = y.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(y.row(r).iter().all(|v| v.is_finite()));
        }
        assert!(y.get(0, 0) > 0.999); // dominant logit
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let mut b = NativeBackend::new();
        let d = 64;
        let x = FloatTensor::from_fn(3, d, |r, c| ((r * d + c) as f32 * 0.1).sin() * 7.0);
        let y = b.layernorm(&x, &vec![1.0; d], &vec![0.0; d]).unwrap();
        for r in 0..3 {
            let mean: f32 = y.row(r).iter().sum::<f32>() / d as f32;
            let var: f32 = y.row(r).iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
            assert!(mean.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-2);
        }
    }

    #[test]
    fn layernorm_rejects_bad_affine() {
        let mut b = NativeBackend::new();
        let x = FloatTensor::zeros(2, 4);
        assert!(b.layernorm(&x, &[1.0; 3], &[0.0; 4]).is_err());
    }
}
