//! 2-out-of-2 additive secret sharing over `Z_{2^64}` with a trusted dealer
//! (the CrypTen model the paper builds on, §2.2).
//!
//! [`Share`] holds both parties' shares inside the simulator; protocol code
//! only ever combines them through the [`Mpc`] context, which charges every
//! transfer to the [`crate::net::NetSim`] ledger. The primitive costs match
//! the paper's Table 1 exactly (see module tests).

pub mod dealer;
pub mod nonlin;

use crate::fixed;
use crate::net::{NetSim, OpClass, PartyId};
use crate::ring;
use crate::tensor::RingTensor;
use crate::util::rng::Rng;
use dealer::Dealer;

pub use dealer::{
    FixedOperandCorrelation, FixedUse, PoolService, PoolStats, TripleKind, TriplePool, TripleShape,
};

/// A 2-party additive sharing of a ring tensor: `x = s0 + s1 (mod 2^64)`.
#[derive(Clone, Debug, PartialEq)]
pub struct Share {
    /// Party 0's additive share.
    pub s0: RingTensor,
    /// Party 1's additive share.
    pub s1: RingTensor,
}

impl Share {
    /// Row count of the shared tensor.
    pub fn rows(&self) -> usize {
        self.s0.rows()
    }
    /// Column count of the shared tensor.
    pub fn cols(&self) -> usize {
        self.s0.cols()
    }
    /// `(rows, cols)` of the shared tensor.
    pub fn shape(&self) -> (usize, usize) {
        self.s0.shape()
    }

    /// Simulator-internal reconstruction (no communication charged) — for
    /// tests and the ideal-functionality fallbacks documented in DESIGN.md.
    pub fn reconstruct(&self) -> RingTensor {
        ring::add(&self.s0, &self.s1)
    }

    /// Access one party's share.
    pub fn of(&self, party: PartyId) -> &RingTensor {
        match party {
            PartyId::P0 => &self.s0,
            PartyId::P1 => &self.s1,
            _ => panic!("only compute servers hold shares"),
        }
    }

    /// Local transpose of both shares.
    pub fn transpose(&self) -> Share {
        Share { s0: self.s0.transpose(), s1: self.s1.transpose() }
    }

    /// Local column-block slice of both shares.
    pub fn col_block(&self, c0: usize, c1: usize) -> Share {
        Share { s0: self.s0.col_block(c0, c1), s1: self.s1.col_block(c0, c1) }
    }

    /// Horizontal concatenation of shares.
    pub fn concat_cols(blocks: &[Share]) -> Share {
        Share {
            s0: RingTensor::concat_cols(&blocks.iter().map(|b| b.s0.clone()).collect::<Vec<_>>()),
            s1: RingTensor::concat_cols(&blocks.iter().map(|b| b.s1.clone()).collect::<Vec<_>>()),
        }
    }

    /// Local row-block slice (rows `[r0, r1)`).
    pub fn row_block(&self, r0: usize, r1: usize) -> Share {
        let f = |t: &RingTensor| {
            let mut out = RingTensor::zeros(r1 - r0, t.cols());
            for r in r0..r1 {
                out.row_mut(r - r0).copy_from_slice(t.row(r));
            }
            out
        };
        Share { s0: f(&self.s0), s1: f(&self.s1) }
    }
}

/// Point-in-time audit counters of an [`Mpc`] context (see
/// [`Mpc::enable_audit`]): MAC-check traffic is accounted here, **never**
/// in the protocol [`crate::net::CostLedger`], so every byte/round-exact
/// pin in the test suite holds identically with audit on.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AuditCounters {
    /// Batched σ-checks performed (step/request boundaries).
    pub mac_checks: u64,
    /// σ-checks that rejected (nonzero accumulator or a MAC-corrupted
    /// pooled item surfaced since the last flush).
    pub mac_failures: u64,
    /// Audit-only wire bytes (σ-share commit/open per flush).
    pub overhead_bytes: u64,
    /// Audit-only wire rounds (commit + reveal per flush).
    pub overhead_rounds: u64,
    /// Openings the σ-accumulator has covered so far.
    pub openings: u64,
    /// Share faults the tamper harness actually injected.
    pub share_faults_applied: u64,
}

/// A scheduled single-shot *share* fault (tamper-injection harness): at
/// covered opening number `at_open`, party 1 sends a perturbed share —
/// `word` (mod len) XORed with `mask | 1` — instead of its true one.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShareFault {
    /// 0-based index into the MAC-covered openings of this context
    /// (see [`Mpc::audit_open_count`]).
    pub at_open: u64,
    /// Flat word index into the share tensor (mod len).
    pub word: usize,
    /// XOR mask; bit 0 is forced so the fault always changes the value.
    pub mask: u64,
}

/// Deferred SPDZ-style MAC state: a per-session information-theoretic key
/// `α` (odd, derived from the session seed without touching any protocol
/// PRG stream) and a running accumulator
/// `σ += c_j · α · (delivered_j − expected_j)` over every element of
/// every covered opening, with per-element odd coefficients `c_j`. Honest
/// runs keep `σ = 0` without evaluating a single coefficient; any
/// single-element corruption contributes `odd·odd·d ≠ 0 (mod 2^64)`, so
/// one flipped bit anywhere is detected with certainty at the next
/// [`Mpc::flush_mac_checks`].
struct AuditState {
    alpha: u64,
    sigma: u64,
    /// Covered openings so far (σ coefficient domain separator).
    open_seq: u64,
    /// Openings accumulated since the last flush.
    pending: u64,
    /// Pool `mac_rejected` watermark at the last flush.
    pool_rejected_seen: u64,
    fault: Option<ShareFault>,
    counters: AuditCounters,
}

/// MPC execution context: network simulator + dealer + share randomness.
pub struct Mpc {
    /// Network simulator charging every transfer.
    pub net: NetSim,
    /// Trusted dealer for correlated randomness.
    pub dealer: Dealer,
    rng: Rng,
    /// Deferred MAC-check state (`None` = semi-honest mode).
    audit: Option<AuditState>,
}

impl Mpc {
    /// Fresh context over `net`; the dealer PRG forks from `seed`.
    pub fn new(net: NetSim, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let dealer = Dealer::new(rng.fork(0xDEA1));
        Mpc { net, dealer, rng, audit: None }
    }

    // ------------------------------------------------------------------
    // Integrity-checked mode (DESIGN.md §Integrity-checked inference)
    // ------------------------------------------------------------------

    /// Switch on integrity-checked mode: every subsequent opening is
    /// covered by the deferred σ-accumulator, batch-verified at
    /// [`Mpc::flush_mac_checks`]. The MAC key `α` is derived from `seed`
    /// by splitmix64 — **not** by forking a protocol PRG, which would
    /// desynchronize share randomness — so shares, payloads, views, and
    /// tokens stay bit-identical to an audit-off run of the same seed.
    pub fn enable_audit(&mut self, seed: u64) {
        let mut st = seed ^ 0xA0D1_7C0D_E5ED_BEEF;
        let alpha = crate::util::rng::splitmix64(&mut st) | 1;
        let pool_rejected_seen = self.dealer.pool().map_or(0, |p| p.mac_rejected());
        self.audit = Some(AuditState {
            alpha,
            sigma: 0,
            open_seq: 0,
            pending: 0,
            pool_rejected_seen,
            fault: None,
            counters: AuditCounters::default(),
        });
    }

    /// Whether integrity-checked mode is on.
    pub fn audit_enabled(&self) -> bool {
        self.audit.is_some()
    }

    /// Current audit counters (`None` when audit is off).
    pub fn audit_counters(&self) -> Option<AuditCounters> {
        self.audit.as_ref().map(|a| a.counters)
    }

    /// Number of MAC-covered openings so far (the index domain of
    /// [`ShareFault::at_open`]). 0 when audit is off.
    pub fn audit_open_count(&self) -> u64 {
        self.audit.as_ref().map_or(0, |a| a.open_seq)
    }

    /// Schedule a single-shot share fault (tamper harness). Returns false
    /// when audit mode is off — there is no covered opening to target.
    pub fn inject_share_fault(&mut self, fault: ShareFault) -> bool {
        match self.audit.as_mut() {
            Some(a) => {
                a.fault = Some(fault);
                true
            }
            None => false,
        }
    }

    /// Batch-verify every opening accumulated since the last flush: the
    /// parties commit and reveal their σ-shares (32 audit-only bytes, 2
    /// audit-only rounds — charged to [`AuditCounters`], never the
    /// protocol ledger) and reject unless `σ = 0` and no MAC-corrupted
    /// pooled item surfaced since the last flush. A no-op returning
    /// `Ok(0)` when audit is off or nothing is pending; `Ok(1)` after a
    /// clean check; an error after a failed one (the failure stays
    /// counted, so serving metrics survive the bail).
    pub fn flush_mac_checks(&mut self) -> crate::Result<u64> {
        let pool_rejected_now = self.dealer.pool().map_or(0, |p| p.mac_rejected());
        let Some(a) = self.audit.as_mut() else { return Ok(0) };
        let pool_delta = pool_rejected_now.saturating_sub(a.pool_rejected_seen);
        a.pool_rejected_seen = pool_rejected_now;
        if a.pending == 0 && pool_delta == 0 {
            return Ok(0);
        }
        let pending = std::mem::take(&mut a.pending);
        let sigma = std::mem::take(&mut a.sigma);
        a.counters.mac_checks += 1;
        a.counters.overhead_bytes += 32;
        a.counters.overhead_rounds += 2;
        if sigma != 0 || pool_delta > 0 {
            a.counters.mac_failures += 1;
            anyhow::bail!(
                "audit MAC check failed: sigma = {sigma:#018x}, corrupted pool items = \
                 {pool_delta} ({pending} openings in the batch)"
            );
        }
        Ok(1)
    }

    /// Snapshot the honest reconstruction of a share about to be opened
    /// (`None` when audit is off — zero work on the semi-honest path).
    fn audit_expected(&self, s: &Share) -> Option<RingTensor> {
        self.audit.as_ref().map(|_| s.reconstruct())
    }

    /// Fold one covered opening into σ: any element where the delivered
    /// reconstruction differs from the expected one contributes
    /// `c_j · α · (delivered_j − expected_j)` with a per-(opening, element)
    /// odd coefficient. Honest openings cost one comparison per element.
    fn audit_accumulate(&mut self, expected: Option<RingTensor>, actual: &RingTensor) {
        let (Some(exp), Some(a)) = (expected, self.audit.as_mut()) else { return };
        let base = a.alpha ^ a.open_seq.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        for (j, (&e, &g)) in exp.data().iter().zip(actual.data().iter()).enumerate() {
            if e != g {
                let mut st = base ^ j as u64;
                let c = crate::util::rng::splitmix64(&mut st) | 1;
                let diff = (g as u64).wrapping_sub(e as u64);
                a.sigma = a.sigma.wrapping_add(c.wrapping_mul(a.alpha).wrapping_mul(diff));
            }
        }
        a.open_seq += 1;
        a.pending += 1;
        a.counters.openings += 1;
    }

    /// The canonical two-way exchange behind every full opening: P0 sends
    /// its half to P1, P1 sends its half to P0 (in that order — the
    /// census-pinned schedule), and both reconstruct from the *delivered*
    /// halves. Under audit the honest value is snapshotted first and the
    /// delivered reconstruction folded into σ; a due [`ShareFault`]
    /// perturbs the copy of P1's half that goes on the wire (the sender's
    /// state, like the snapshot, is untouched — a cheating party, not a
    /// broken one). Rounds are charged by the caller.
    fn exchange_halves(&mut self, s: &Share, class: OpClass) -> RingTensor {
        let expected = self.audit_expected(s);
        let faulty_s1 = match self.audit.as_mut() {
            Some(a) if a.fault.is_some_and(|f| f.at_open == a.open_seq) => {
                let f = a.fault.take().expect("checked above");
                let mut t = s.s1.clone();
                if t.len() > 0 {
                    let i = f.word % t.len();
                    t.data_mut()[i] = (t.data()[i] as u64 ^ (f.mask | 1)) as i64;
                    a.counters.share_faults_applied += 1;
                    Some(t)
                } else {
                    None
                }
            }
            _ => None,
        };
        let d0 = self.net.transfer(PartyId::P0, PartyId::P1, &s.s0, class);
        let d1 = self.net.transfer(PartyId::P1, PartyId::P0, faulty_s1.as_ref().unwrap_or(&s.s1), class);
        let actual = ring::add(&d0, &d1);
        self.audit_accumulate(expected, &actual);
        actual
    }

    // ------------------------------------------------------------------
    // Deferred/batched openings (DESIGN.md §Batched openings)
    // ------------------------------------------------------------------

    /// Start an open batch: every opening protocol executed until
    /// [`Mpc::flush_batch`] has its round charge deferred, and the flush
    /// charges exactly **one** round for all of them — the concatenated
    /// single-flight exchange of all queued mask differences. Bytes are
    /// charged per transfer exactly as in the sequential schedule, so
    /// batching merges rounds without moving a single extra byte.
    ///
    /// The caller is responsible for batching only *independent* openings
    /// (no queued exchange may need another queued exchange's opened value
    /// to form its own payload); `rust/tests/prop_invariants.rs` checks
    /// that batched and sequential schedules are share-for-share
    /// identical, and the security census in
    /// `rust/tests/security_views.rs` checks the transferred-payload
    /// multiset is unchanged.
    ///
    /// The mechanism lives in [`NetSim`], so fast-sim charged-ideal twins
    /// (which charge rounds through the same `net.round`) batch
    /// identically and ledgers stay mode-independent.
    pub fn begin_batch(&mut self) {
        self.net.begin_batch();
    }

    /// Flush the open batch begun with [`Mpc::begin_batch`]: one round is
    /// charged to `class` when anything was queued (returns 1); flushing
    /// an empty batch is a no-op (returns 0).
    pub fn flush_batch(&mut self, class: OpClass) -> u64 {
        self.net.flush_batch(class)
    }

    // ------------------------------------------------------------------
    // Sharing / opening
    // ------------------------------------------------------------------

    /// Split a plaintext into a fresh random sharing (no comm — used by the
    /// party that owns the value; the transfer of shares to the compute
    /// servers is charged by the caller via [`Mpc::input_share`]).
    pub fn share_local(&mut self, x: &RingTensor) -> Share {
        let s0 = RingTensor::from_vec(x.rows(), x.cols(), self.rng.vec_i64(x.len()));
        let s1 = ring::sub(x, &s0);
        Share { s0, s1 }
    }

    /// Client-side input sharing: generate shares and send `[x]_j` to each
    /// compute server (1 round, `2·8·|x|` bytes — both messages in parallel).
    pub fn input_share(&mut self, x: &RingTensor, class: OpClass) -> Share {
        let sh = self.input_share_unrounded(x, class);
        self.net.round(class, 1);
        sh
    }

    /// Deferred-round input sharing for the session-batched decode
    /// schedule: identical share generation and transfers to
    /// [`Mpc::input_share`], no round charge — a batch-mate's charged
    /// input flight carries this lane's two messages (independent
    /// payloads from the same client round trip). Under audit the
    /// delivered shares are checked against the client's plaintext.
    pub fn input_share_unrounded(&mut self, x: &RingTensor, class: OpClass) -> Share {
        let sh = self.share_local(x);
        let expected = self.audit.as_ref().map(|_| x.clone());
        let s0 = self.net.transfer(PartyId::P2, PartyId::P0, &sh.s0, class);
        let s1 = self.net.transfer(PartyId::P2, PartyId::P1, &sh.s1, class);
        let out = Share { s0, s1 };
        if expected.is_some() {
            let actual = out.reconstruct();
            self.audit_accumulate(expected, &actual);
        }
        out
    }

    /// Open a sharing to both parties (1 round, each party sends its share
    /// to the other: `2·8·|x|` bytes).
    pub fn open(&mut self, s: &Share, class: OpClass) -> RingTensor {
        let opened = self.exchange_halves(s, class);
        self.net.round(class, 1);
        opened
    }

    /// Open to a single party (half the traffic, 1 round).
    pub fn open_to(&mut self, s: &Share, to: PartyId, class: OpClass) -> RingTensor {
        let from = if to == PartyId::P0 { PartyId::P1 } else { PartyId::P0 };
        let expected = self.audit_expected(s);
        let other = self.net.transfer(from, to, s.of(from), class);
        self.net.round(class, 1);
        let actual = ring::add(s.of(to), &other);
        self.audit_accumulate(expected, &actual);
        actual
    }

    /// Send an existing share tensor from one server to the other (e.g. the
    /// `Π_PP*` state conversion) — charged, no round bookkeeping (caller
    /// groups rounds).
    pub fn send_share_half(&mut self, s: &Share, from: PartyId, to: PartyId, class: OpClass) -> RingTensor {
        self.net.transfer(from, to, s.of(from), class)
    }

    // ------------------------------------------------------------------
    // Linear (communication-free) protocols — Π_Add, Π_ScalMul
    // ------------------------------------------------------------------

    /// `Π_Add`: elementwise share addition (local).
    pub fn add(&self, a: &Share, b: &Share) -> Share {
        Share { s0: ring::add(&a.s0, &b.s0), s1: ring::add(&a.s1, &b.s1) }
    }

    /// Share subtraction (local).
    pub fn sub(&self, a: &Share, b: &Share) -> Share {
        Share { s0: ring::sub(&a.s0, &b.s0), s1: ring::sub(&a.s1, &b.s1) }
    }

    /// Add a public constant (P0 adjusts its share).
    pub fn add_plain(&self, a: &Share, p: &RingTensor) -> Share {
        Share { s0: ring::add(&a.s0, p), s1: a.s1.clone() }
    }

    /// Add a public broadcast row (P0 adjusts its share).
    pub fn add_plain_row(&self, a: &Share, bias: &[i64]) -> Share {
        Share { s0: ring::add_row(&a.s0, bias), s1: a.s1.clone() }
    }

    /// Elementwise multiply by a public *integer* matrix (e.g. a 0/1 mask)
    /// — local, no truncation (the plaintext is not fixed-point scaled).
    pub fn mul_plain_int(&self, a: &Share, m: &RingTensor) -> Share {
        Share { s0: ring::mul_elem(&a.s0, m), s1: ring::mul_elem(&a.s1, m) }
    }

    /// Multiply by a public fixed-point scalar, with share truncation.
    pub fn scale_fx(&self, a: &Share, scalar_fx: i64) -> Share {
        let mut s0 = ring::scale(&a.s0, scalar_fx);
        let mut s1 = ring::scale(&a.s1, scalar_fx);
        fixed::trunc_share_tensor(&mut s0, 0);
        fixed::trunc_share_tensor(&mut s1, 1);
        Share { s0, s1 }
    }

    /// `Π_ScalMul` (matrix form): public fixed-point `A (m×k)` times shared
    /// `[X] (k×n)` → `[A·X]`, communication-free; includes fixed-point
    /// truncation. Each party's local matmul is timed separately.
    pub fn scalmul(&mut self, a_fx: &RingTensor, x: &Share, class: OpClass) -> Share {
        let mut s0 = self.net.timed(class, PartyId::P0, || ring::matmul(a_fx, &x.s0));
        let mut s1 = self.net.timed(class, PartyId::P1, || ring::matmul(a_fx, &x.s1));
        fixed::trunc_share_tensor(&mut s0, 0);
        fixed::trunc_share_tensor(&mut s1, 1);
        Share { s0, s1 }
    }

    /// `Π_ScalMul` with the shared operand on the left: `[X] (m×k)` times
    /// public `Wᵀ` given as `W (n×k)` → `[X·Wᵀ] (m×n)`.
    pub fn scalmul_nt(&mut self, x: &Share, w_fx: &RingTensor, class: OpClass) -> Share {
        let mut s0 = self.net.timed(class, PartyId::P0, || ring::matmul_nt(&x.s0, w_fx));
        let mut s1 = self.net.timed(class, PartyId::P1, || ring::matmul_nt(&x.s1, w_fx));
        fixed::trunc_share_tensor(&mut s0, 0);
        fixed::trunc_share_tensor(&mut s1, 1);
        Share { s0, s1 }
    }

    // ------------------------------------------------------------------
    // Π_MatMul / Π_Mul — Beaver-triple share×share products
    // ------------------------------------------------------------------

    /// `Π_ScalMul` with the plaintext on the right: `[X] (m×k)` times
    /// public `W (k×n)` → `[X·W]` (embedding lookup), communication-free.
    pub fn scalmul_rhs(&mut self, x: &Share, w_fx: &RingTensor, class: OpClass) -> Share {
        let mut s0 = self.net.timed(class, PartyId::P0, || ring::matmul(&x.s0, w_fx));
        let mut s1 = self.net.timed(class, PartyId::P1, || ring::matmul(&x.s1, w_fx));
        fixed::trunc_share_tensor(&mut s0, 0);
        fixed::trunc_share_tensor(&mut s1, 1);
        Share { s0, s1 }
    }

    /// `Π_MatMul` with identical communication charges but the product
    /// computed directly (ideal functionality) — the *fast-sim* execution
    /// mode for paper-scale models on this 1-core testbed, and for very
    /// large operands (embedding tables) where materializing Beaver
    /// triples would need gigabytes. Wire costs are exact; local compute
    /// is the single plaintext product (the per-op compute for the time
    /// model is measured separately by full-mode microbenches).
    /// DESIGN.md §CostModel documents this.
    pub fn matmul_charged_ideal(&mut self, x: &Share, y: &Share, class: OpClass) -> Share {
        let out = self.matmul_charged_ideal_core(x, y, class);
        self.net.round(class, 1);
        out
    }

    fn matmul_charged_ideal_core(&mut self, x: &Share, y: &Share, class: OpClass) -> Share {
        let (m, k) = x.shape();
        let (k2, n) = y.shape();
        assert_eq!(k, k2);
        // identical wire cost to the Beaver path: open E (m×k) + F (k×n)
        // in both directions.
        self.net.charge_bytes(class, (2 * 8 * (m * k + k * n)) as u64);
        let prod = self.net.timed(class, PartyId::P1, || {
            ring::matmul(&x.reconstruct(), &y.reconstruct())
        });
        self.reshare_ideal(prod, 0x1DEA)
    }

    /// Batched charged-ideal matmul (single round, like [`Mpc::matmul_batch`]).
    pub fn matmul_charged_ideal_batch(&mut self, pairs: &[(&Share, &Share)], class: OpClass) -> Vec<Share> {
        let outs = pairs.iter().map(|(x, y)| self.matmul_charged_ideal_core(x, y, class)).collect();
        self.net.round(class, 1);
        outs
    }

    /// `Π_ScalMul` as a charged-ideal (fast-sim): one plaintext product
    /// instead of one per party; zero communication, same as the real
    /// protocol.
    pub fn scalmul_nt_ideal(&mut self, x: &Share, w_fx: &RingTensor, class: OpClass) -> Share {
        let prod = self.net.timed(class, PartyId::P1, || ring::matmul_nt(&x.reconstruct(), w_fx));
        self.reshare_ideal(prod, 0x5CA1)
    }

    /// Right-plaintext variant of [`Mpc::scalmul_nt_ideal`].
    pub fn scalmul_rhs_ideal(&mut self, x: &Share, w_fx: &RingTensor, class: OpClass) -> Share {
        let prod = self.net.timed(class, PartyId::P1, || ring::matmul(&x.reconstruct(), w_fx));
        self.reshare_ideal(prod, 0x5CA2)
    }

    /// `Π_MatMul`: `[X] (m×k) @ [Y] (k×n)` via a Beaver matrix triple.
    /// 1 round; traffic `2·8·(mk + kn)` bytes (= 256·n² bits when m=k=n,
    /// matching Table 1). Includes fixed-point truncation.
    pub fn matmul(&mut self, x: &Share, y: &Share, class: OpClass) -> Share {
        let out = self.matmul_core(x, y, class);
        self.net.round(class, 1);
        out
    }

    /// Batched `Π_MatMul`: all products exchanged in a single parallel
    /// round (the per-head attention products).
    pub fn matmul_batch(&mut self, pairs: &[(&Share, &Share)], class: OpClass) -> Vec<Share> {
        let outs: Vec<Share> = pairs.iter().map(|(x, y)| self.matmul_core(x, y, class)).collect();
        self.net.round(class, 1);
        outs
    }

    fn matmul_core(&mut self, x: &Share, y: &Share, class: OpClass) -> Share {
        let (m, k) = x.shape();
        let (k2, n) = y.shape();
        assert_eq!(k, k2, "Π_MatMul inner dim");
        let trip = self.dealer.matmul_triple(m, k, n);
        // E = X - A, F = Y - B, opened in one parallel round.
        let e_sh = self.sub(x, &trip.a);
        let f_sh = self.sub(y, &trip.b);
        let e = self.exchange_halves(&e_sh, class);
        let f = self.exchange_halves(&f_sh, class);
        // (round charged by the caller: matmul/matmul_batch)
        // [Z] = [C] + E·[B] + [A]·F + E·F (P0 adds the public term).
        let mut s0 = self.net.timed(class, PartyId::P0, || {
            let mut z = ring::matmul(&e, &trip.b.s0);
            ring::add_assign(&mut z, &ring::matmul(&trip.a.s0, &f));
            ring::add_assign(&mut z, &trip.c.s0);
            ring::add_assign(&mut z, &ring::matmul(&e, &f));
            z
        });
        let mut s1 = self.net.timed(class, PartyId::P1, || {
            let mut z = ring::matmul(&e, &trip.b.s1);
            ring::add_assign(&mut z, &ring::matmul(&trip.a.s1, &f));
            ring::add_assign(&mut z, &trip.c.s1);
            z
        });
        fixed::trunc_share_tensor(&mut s0, 0);
        fixed::trunc_share_tensor(&mut s1, 1);
        Share { s0, s1 }
    }

    /// `Π_Mul`: elementwise share×share product (Beaver), 1 round,
    /// `2·2·8·N` bytes (256·N bits). Includes truncation.
    pub fn mul_elem(&mut self, x: &Share, y: &Share, class: OpClass) -> Share {
        assert_eq!(x.shape(), y.shape());
        let trip = self.dealer.elem_triple(x.rows(), x.cols());
        let e_sh = self.sub(x, &trip.a);
        let f_sh = self.sub(y, &trip.b);
        let e = self.exchange_halves(&e_sh, class);
        let f = self.exchange_halves(&f_sh, class);
        self.net.round(class, 1);
        let mut s0 = ring::add(
            &ring::add(&ring::mul_elem(&e, &trip.b.s0), &ring::mul_elem(&trip.a.s0, &f)),
            &ring::add(&trip.c.s0, &ring::mul_elem(&e, &f)),
        );
        let mut s1 = ring::add(
            &ring::add(&ring::mul_elem(&e, &trip.b.s1), &ring::mul_elem(&trip.a.s1, &f)),
            &trip.c.s1,
        );
        fixed::trunc_share_tensor(&mut s0, 0);
        fixed::trunc_share_tensor(&mut s1, 1);
        Share { s0, s1 }
    }

    /// Elementwise square with a square triple `(A, A²)` — CrypTen's cheap
    /// square: only `E = X − A` is opened (1 round, `2·8·N` bytes =
    /// 128·N bits; 8 squarings of a scalar = 1024 bits, Table 1's `exp`).
    pub fn square(&mut self, x: &Share, class: OpClass) -> Share {
        let trip = self.dealer.square_pair(x.rows(), x.cols());
        let e_sh = self.sub(x, &trip.a);
        let e = self.exchange_halves(&e_sh, class);
        self.net.round(class, 1);
        // X² = E² + 2·E·A + A² → [X²] = E² (public, P0) + 2E·[A] + [C]
        let two_e = ring::scale(&e, 2);
        let mut s0 = ring::add(
            &ring::add(&ring::mul_elem(&two_e, &trip.a.s0), &trip.c.s0),
            &ring::mul_elem(&e, &e),
        );
        let mut s1 = ring::add(&ring::mul_elem(&two_e, &trip.a.s1), &trip.c.s1);
        fixed::trunc_share_tensor(&mut s0, 0);
        fixed::trunc_share_tensor(&mut s1, 1);
        Share { s0, s1 }
    }

    // ------------------------------------------------------------------
    // Fixed-operand correlated triples (DESIGN.md §Fixed-operand
    // correlations): Π_MatMul specializations for operands that are fixed
    // (or write-once) for a whole decode session. The fixed operand's mask
    // difference is opened ONCE per session; each use then opens only the
    // varying operand's mask difference.
    // ------------------------------------------------------------------

    /// One-time masked opening of a session-fixed operand: both parties
    /// exchange halves of `[fixed] − [B]` (1 round, `2·8·|B|` bytes). The
    /// result `F = fixed − B` is uniform (one-time pad) and is the only
    /// opening the fixed operand ever gets — a second call errors, and the
    /// `openings()` counter lets the security census assert exactly one.
    pub fn open_fixed_operand(
        &mut self,
        fixed: &Share,
        corr: &mut dealer::FixedOperandCorrelation,
        class: OpClass,
    ) -> crate::Result<RingTensor> {
        anyhow::ensure!(
            matches!(
                corr.shape.kind,
                dealer::TripleKind::FixedPppRight | dealer::TripleKind::FixedAppendLeft
            ),
            "one-time opening needs a whole-operand correlation family, got {:?}",
            corr.shape.kind
        );
        anyhow::ensure!(
            corr.openings() == 0,
            "fixed-operand mask already opened — the session opening must happen exactly once"
        );
        anyhow::ensure!(fixed.shape() == corr.mask.shape(), "fixed operand / mask shape mismatch");
        let diff = self.sub(fixed, &corr.mask);
        let opened = self.exchange_halves(&diff, class);
        self.net.round(class, 1);
        corr.opened = 1;
        Ok(opened)
    }

    /// Extend the masked opening of a *write-once row-grown* operand (the
    /// K cache) by its newly written row `pos`: parties exchange halves of
    /// `[row] − [B[pos]]` (`2·8·cols` bytes; the round is charged by the
    /// caller so it can group this with the append's other opening). Rows
    /// must be opened sequentially, each exactly once.
    pub fn open_fixed_grown_row(
        &mut self,
        row: &Share,
        corr: &mut dealer::FixedOperandCorrelation,
        pos: usize,
        class: OpClass,
    ) -> crate::Result<RingTensor> {
        anyhow::ensure!(
            corr.shape.kind == dealer::TripleKind::FixedScoresGrown,
            "row-grown opening needs a FixedScoresGrown correlation, got {:?}",
            corr.shape.kind
        );
        anyhow::ensure!(
            corr.openings() == pos as u64,
            "grown-operand rows must be opened sequentially, once each (row {pos}, opened {})",
            corr.openings()
        );
        anyhow::ensure!(pos < corr.mask.rows(), "row {pos} outside the dealt mask");
        let b_row = corr.mask.row_block(pos, pos + 1);
        let diff = self.sub(row, &b_row);
        let opened = self.exchange_halves(&diff, class);
        corr.opened = pos as u64 + 1;
        Ok(opened)
    }

    /// `Π_MatMul` with a session-fixed RIGHT operand whose masked opening
    /// `f_pub = Y − B` already happened: per use only `E = X − A` is opened
    /// (1 round, `2·8·m·k` bytes instead of `2·8·(mk + kn)`), then
    /// `[Z] = E·F (public) + E·[B] + [A]·F + [C]` with `C = A·B` dealt.
    /// Includes fixed-point truncation, like [`Mpc::matmul`].
    pub fn matmul_fixed_rhs(
        &mut self,
        x: &Share,
        f_pub: &RingTensor,
        corr: &mut dealer::FixedOperandCorrelation,
        class: OpClass,
    ) -> crate::Result<Share> {
        anyhow::ensure!(
            corr.shape.kind == dealer::TripleKind::FixedPppRight,
            "matmul_fixed_rhs needs a FixedPppRight correlation, got {:?}",
            corr.shape.kind
        );
        anyhow::ensure!(corr.openings() >= 1, "fixed operand must be opened before use");
        anyhow::ensure!(x.cols() == f_pub.rows(), "Π_MatMul inner dim");
        let (_, fu) = corr.take_use()?;
        anyhow::ensure!(fu.blocks.len() == 1, "right-fixed correlation has one block per use");
        let (a, c) = &fu.blocks[0];
        anyhow::ensure!(a.shape() == x.shape(), "per-use mask shape mismatch");
        let e_sh = self.sub(x, a);
        let e = self.exchange_halves(&e_sh, class);
        self.net.round(class, 1);
        let b = &corr.mask;
        let mut s0 = self.net.timed(class, PartyId::P0, || {
            let mut z = ring::matmul(&e, &b.s0);
            ring::add_assign(&mut z, &ring::matmul(&a.s0, f_pub));
            ring::add_assign(&mut z, &c.s0);
            ring::add_assign(&mut z, &ring::matmul(&e, f_pub));
            z
        });
        let mut s1 = self.net.timed(class, PartyId::P1, || {
            let mut z = ring::matmul(&e, &b.s1);
            ring::add_assign(&mut z, &ring::matmul(&a.s1, f_pub));
            ring::add_assign(&mut z, &c.s1);
            z
        });
        fixed::trunc_share_tensor(&mut s0, 0);
        fixed::trunc_share_tensor(&mut s1, 1);
        Ok(Share { s0, s1 })
    }

    /// `Π_MatMul` with a session-fixed LEFT operand used one *column per
    /// use* (the KV outer product `[π₁ᵀ e_pos] @ [v]`): use `pos` meets
    /// column `pos` of the opened `f_pub = X − B`. Opens only `E = y − A`
    /// (`2·8·|y|` bytes; the round is charged by the caller so the append
    /// can group it with the K-row opening). `C = B[:,pos]·A` is dealt.
    pub fn matmul_fixed_lhs_col(
        &mut self,
        f_pub: &RingTensor,
        y: &Share,
        corr: &mut dealer::FixedOperandCorrelation,
        pos: usize,
        class: OpClass,
    ) -> crate::Result<Share> {
        anyhow::ensure!(
            corr.shape.kind == dealer::TripleKind::FixedAppendLeft,
            "matmul_fixed_lhs_col needs a FixedAppendLeft correlation, got {:?}",
            corr.shape.kind
        );
        anyhow::ensure!(corr.openings() >= 1, "fixed operand must be opened before use");
        let (idx, fu) = corr.take_use()?;
        anyhow::ensure!(
            idx == pos,
            "column-per-use correlation consumed out of order (use {idx}, position {pos})"
        );
        let (a, c) = &fu.blocks[0];
        anyhow::ensure!(a.shape() == y.shape(), "per-use mask shape mismatch");
        let e_sh = self.sub(y, a);
        let e = self.exchange_halves(&e_sh, class);
        let f_col = f_pub.col_block(pos, pos + 1);
        let b_col = corr.mask.col_block(pos, pos + 1);
        let mut s0 = self.net.timed(class, PartyId::P0, || {
            let mut z = ring::matmul(&b_col.s0, &e);
            ring::add_assign(&mut z, &ring::matmul(&f_col, &a.s0));
            ring::add_assign(&mut z, &c.s0);
            ring::add_assign(&mut z, &ring::matmul(&f_col, &e));
            z
        });
        let mut s1 = self.net.timed(class, PartyId::P1, || {
            let mut z = ring::matmul(&b_col.s1, &e);
            ring::add_assign(&mut z, &ring::matmul(&f_col, &a.s1));
            ring::add_assign(&mut z, &c.s1);
            z
        });
        fixed::trunc_share_tensor(&mut s0, 0);
        fixed::trunc_share_tensor(&mut s1, 1);
        Ok(Share { s0, s1 })
    }

    /// Per-head score products against a row-grown fixed operand (the K
    /// cache, masked rows opened via [`Mpc::open_fixed_grown_row`]): use
    /// `pos` multiplies each head's `[q_h] (1, dh)` against the transposed
    /// written block `rows 0..=pos`, and pads the unwritten columns with
    /// zero shares (those cache rows are publicly zero — the causal mask
    /// zeroes them after softmax either way). One round for all head
    /// openings, `2·8·|q|` bytes total.
    pub fn matmul_fixed_grown_scores(
        &mut self,
        q: &Share,
        f_rows: &RingTensor,
        corr: &mut dealer::FixedOperandCorrelation,
        pos: usize,
        n_out: usize,
        class: OpClass,
    ) -> crate::Result<Vec<Share>> {
        anyhow::ensure!(
            corr.shape.kind == dealer::TripleKind::FixedScoresGrown,
            "matmul_fixed_grown_scores needs a FixedScoresGrown correlation, got {:?}",
            corr.shape.kind
        );
        anyhow::ensure!(
            corr.openings() as usize > pos,
            "K row {pos} must be opened before the score product"
        );
        let (idx, fu) = corr.take_use()?;
        anyhow::ensure!(
            idx == pos,
            "row-grown correlation consumed out of order (use {idx}, position {pos})"
        );
        let heads = fu.blocks.len();
        let dh = q.cols() / heads;
        let written = pos + 1;
        // E_h = q_h − A_h for every head, all exchanged in one round.
        let mut es = Vec::with_capacity(heads);
        for (h, (a, _)) in fu.blocks.iter().enumerate() {
            let qh = q.col_block(h * dh, (h + 1) * dh);
            anyhow::ensure!(a.shape() == (1, dh), "per-use head mask shape mismatch");
            let e_sh = self.sub(&qh, a);
            es.push(self.exchange_halves(&e_sh, class));
        }
        self.net.round(class, 1);
        let mut outs = Vec::with_capacity(heads);
        for (h, (a, c)) in fu.blocks.iter().enumerate() {
            let e = &es[h];
            // Public and masked K blocks, transposed: (dh, written) — the
            // same layout the dealer used for `C = A·B_blockᵀ`.
            let f_bt = dealer::head_block_t(f_rows, h, dh, written);
            let b0t = dealer::head_block_t(&corr.mask.s0, h, dh, written);
            let b1t = dealer::head_block_t(&corr.mask.s1, h, dh, written);
            let mut z0 = self.net.timed(class, PartyId::P0, || {
                let mut z = ring::matmul(e, &b0t);
                ring::add_assign(&mut z, &ring::matmul(&a.s0, &f_bt));
                ring::add_assign(&mut z, &c.s0);
                ring::add_assign(&mut z, &ring::matmul(e, &f_bt));
                z
            });
            let mut z1 = self.net.timed(class, PartyId::P1, || {
                let mut z = ring::matmul(e, &b1t);
                ring::add_assign(&mut z, &ring::matmul(&a.s1, &f_bt));
                ring::add_assign(&mut z, &c.s1);
                z
            });
            fixed::trunc_share_tensor(&mut z0, 0);
            fixed::trunc_share_tensor(&mut z1, 1);
            let pad = |z: RingTensor| {
                let mut out = RingTensor::zeros(1, n_out);
                out.row_mut(0)[..written].copy_from_slice(z.row(0));
                out
            };
            outs.push(Share { s0: pad(z0), s1: pad(z1) });
        }
        Ok(outs)
    }

    /// Truncate an ideal (fast-sim) product and split it into a fresh
    /// dealer-seeded sharing — the single resharing convention behind
    /// every charged-ideal op (`matmul_charged_ideal*`, `scalmul_*_ideal`,
    /// and the fixed-operand `*_ideal` twins).
    fn reshare_ideal(&mut self, prod: RingTensor, tag: u64) -> Share {
        let truncated = prod.map(|v| v >> crate::fixed::FRAC_BITS);
        let (m, n) = truncated.shape();
        let mut rng = self.dealer.fork_rng(tag ^ (m * n) as u64);
        let s0 = RingTensor::from_vec(m, n, rng.vec_i64(m * n));
        let s1 = ring::sub(&truncated, &s0);
        Share { s0, s1 }
    }

    /// Charged-ideal variant of [`Mpc::matmul_fixed_rhs`] (fast-sim): the
    /// same wire charges, use consumption, and opening discipline, with
    /// the product computed directly (the fixed operand is recovered as
    /// `F + B`). DESIGN.md §CostModel — ledgers agree across modes.
    pub fn matmul_fixed_rhs_ideal(
        &mut self,
        x: &Share,
        f_pub: &RingTensor,
        corr: &mut dealer::FixedOperandCorrelation,
        class: OpClass,
    ) -> crate::Result<Share> {
        anyhow::ensure!(
            corr.shape.kind == dealer::TripleKind::FixedPppRight,
            "matmul_fixed_rhs needs a FixedPppRight correlation, got {:?}",
            corr.shape.kind
        );
        anyhow::ensure!(corr.openings() >= 1, "fixed operand must be opened before use");
        anyhow::ensure!(x.cols() == f_pub.rows(), "Π_MatMul inner dim");
        let (_, fu) = corr.take_use()?;
        anyhow::ensure!(fu.blocks[0].0.shape() == x.shape(), "per-use mask shape mismatch");
        let (m, k) = x.shape();
        self.net.charge_bytes(class, (2 * 8 * m * k) as u64);
        self.net.round(class, 1);
        let y = ring::add(f_pub, &corr.mask.reconstruct());
        let prod = self.net.timed(class, PartyId::P1, || ring::matmul(&x.reconstruct(), &y));
        Ok(self.reshare_ideal(prod, 0xF1D0))
    }

    /// Charged-ideal variant of [`Mpc::matmul_fixed_lhs_col`] (fast-sim):
    /// same charges and column-order use accounting; round charged by the
    /// caller, like the real protocol.
    pub fn matmul_fixed_lhs_col_ideal(
        &mut self,
        f_pub: &RingTensor,
        y: &Share,
        corr: &mut dealer::FixedOperandCorrelation,
        pos: usize,
        class: OpClass,
    ) -> crate::Result<Share> {
        anyhow::ensure!(
            corr.shape.kind == dealer::TripleKind::FixedAppendLeft,
            "matmul_fixed_lhs_col needs a FixedAppendLeft correlation, got {:?}",
            corr.shape.kind
        );
        anyhow::ensure!(corr.openings() >= 1, "fixed operand must be opened before use");
        let (idx, fu) = corr.take_use()?;
        anyhow::ensure!(
            idx == pos,
            "column-per-use correlation consumed out of order (use {idx}, position {pos})"
        );
        anyhow::ensure!(fu.blocks[0].0.shape() == y.shape(), "per-use mask shape mismatch");
        self.net.charge_bytes(class, (2 * 8 * y.cols()) as u64);
        let b_col = corr.mask.col_block(pos, pos + 1).reconstruct();
        let x_col = ring::add(&f_pub.col_block(pos, pos + 1), &b_col);
        let prod = self.net.timed(class, PartyId::P1, || ring::matmul(&x_col, &y.reconstruct()));
        Ok(self.reshare_ideal(prod, 0xF1D1))
    }

    /// Charged-ideal variant of [`Mpc::matmul_fixed_grown_scores`]
    /// (fast-sim): same charges, row-opening discipline, and zero-padded
    /// output layout; the written K block is recovered as `F + B`.
    pub fn matmul_fixed_grown_scores_ideal(
        &mut self,
        q: &Share,
        f_rows: &RingTensor,
        corr: &mut dealer::FixedOperandCorrelation,
        pos: usize,
        n_out: usize,
        class: OpClass,
    ) -> crate::Result<Vec<Share>> {
        anyhow::ensure!(
            corr.shape.kind == dealer::TripleKind::FixedScoresGrown,
            "matmul_fixed_grown_scores needs a FixedScoresGrown correlation, got {:?}",
            corr.shape.kind
        );
        anyhow::ensure!(
            corr.openings() as usize > pos,
            "K row {pos} must be opened before the score product"
        );
        let (idx, fu) = corr.take_use()?;
        anyhow::ensure!(
            idx == pos,
            "row-grown correlation consumed out of order (use {idx}, position {pos})"
        );
        let heads = fu.blocks.len();
        let dh = q.cols() / heads;
        let written = pos + 1;
        self.net.charge_bytes(class, (2 * 8 * heads * dh) as u64);
        self.net.round(class, 1);
        let mask_plain = corr.mask.reconstruct();
        let q_plain = q.reconstruct();
        let mut outs = Vec::with_capacity(heads);
        for h in 0..heads {
            let f_bt = dealer::head_block_t(f_rows, h, dh, written);
            let b_bt = dealer::head_block_t(&mask_plain, h, dh, written);
            let kt = ring::add(&f_bt, &b_bt);
            let qh = q_plain.col_block(h * dh, (h + 1) * dh);
            let prod = self.net.timed(class, PartyId::P1, || ring::matmul(&qh, &kt));
            let z = self.reshare_ideal(prod, 0xF1D2 ^ h as u64);
            let pad = |t: &RingTensor| {
                let mut out = RingTensor::zeros(1, n_out);
                out.row_mut(0)[..written].copy_from_slice(t.row(0));
                out
            };
            outs.push(Share { s0: pad(&z.s0), s1: pad(&z.s1) });
        }
        Ok(outs)
    }

    /// Fresh re-sharing of a plaintext known to one party (that party
    /// splits and sends the counter-share: 1 transfer; round charged by the
    /// caller as part of the enclosing protocol step).
    pub fn reshare_from(&mut self, x: &RingTensor, holder: PartyId, class: OpClass) -> Share {
        let mask = RingTensor::from_vec(x.rows(), x.cols(), self.rng.vec_i64(x.len()));
        let other_share = ring::sub(x, &mask);
        let to = if holder == PartyId::P0 { PartyId::P1 } else { PartyId::P0 };
        let sent = self.net.transfer(holder, to, &other_share, class);
        if holder == PartyId::P1 {
            Share { s0: sent, s1: mask }
        } else {
            Share { s0: mask, s1: sent }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::NetworkProfile;
    use crate::tensor::FloatTensor;
    use crate::util::prop::check;

    fn mk() -> Mpc {
        Mpc::new(NetSim::new(NetworkProfile::lan()), 42)
    }

    fn enc(t: &FloatTensor) -> RingTensor {
        fixed::encode_tensor(t)
    }
    fn dec(t: &RingTensor) -> FloatTensor {
        fixed::decode_tensor(t)
    }

    #[test]
    fn share_reconstruct_identity() {
        check("share/reconstruct", 100, |g| {
            let mut mpc = mk();
            let n = g.dim(16);
            let x = RingTensor::from_vec(1, n, g.vec_i64(n));
            let sh = mpc.share_local(&x);
            assert_eq!(sh.reconstruct(), x);
        });
    }

    #[test]
    fn shares_are_uniformly_masked() {
        // Each individual share of a constant tensor should look random:
        // its values must not equal the plaintext (w.h.p.) and two sharings
        // must differ.
        let mut mpc = mk();
        let x = RingTensor::from_vec(1, 64, vec![fixed::encode(1.0); 64]);
        let a = mpc.share_local(&x);
        let b = mpc.share_local(&x);
        assert_ne!(a.s0, b.s0);
        let hits = a.s0.data().iter().filter(|&&v| v == fixed::encode(1.0)).count();
        assert!(hits <= 1);
    }

    #[test]
    fn add_matches_plaintext() {
        check("Π_Add", 50, |g| {
            let mut mpc = mk();
            let n = g.dim(12);
            let x = RingTensor::from_vec(1, n, g.vec_i64(n));
            let y = RingTensor::from_vec(1, n, g.vec_i64(n));
            let sx = mpc.share_local(&x);
            let sy = mpc.share_local(&y);
            assert_eq!(mpc.add(&sx, &sy).reconstruct(), ring::add(&x, &y));
        });
    }

    #[test]
    fn scalmul_matches_float_matmul() {
        check("Π_ScalMul", 25, |g| {
            let mut mpc = mk();
            let (m, k, n) = (g.dim(6), g.dim(8), g.dim(6));
            let a = FloatTensor::from_vec(m, k, g.vec_small_f64(m * k).iter().map(|&v| v as f32 * 0.2).collect());
            let x = FloatTensor::from_vec(k, n, g.vec_small_f64(k * n).iter().map(|&v| v as f32 * 0.2).collect());
            let sx = mpc.share_local(&enc(&x));
            let out = mpc.scalmul(&enc(&a), &sx, OpClass::Linear);
            let got = dec(&out.reconstruct());
            let want = a.matmul(&x);
            assert!(got.max_abs_diff(&want) < 1e-2, "diff {}", got.max_abs_diff(&want));
            // communication-free:
            assert_eq!(mpc.net.ledger.bytes_total(), 0);
            assert_eq!(mpc.net.ledger.rounds_total(), 0);
        });
    }

    #[test]
    fn matmul_beaver_correct_and_costed() {
        let mut mpc = mk();
        let n = 8usize;
        let x = FloatTensor::from_fn(n, n, |r, c| ((r + 2 * c) % 5) as f32 * 0.3 - 0.5);
        let y = FloatTensor::from_fn(n, n, |r, c| ((3 * r + c) % 7) as f32 * 0.2 - 0.4);
        let sx = mpc.share_local(&enc(&x));
        let sy = mpc.share_local(&enc(&y));
        let out = mpc.matmul(&sx, &sy, OpClass::Linear);
        let got = dec(&out.reconstruct());
        let want = x.matmul(&y);
        assert!(got.max_abs_diff(&want) < 1e-2, "diff {}", got.max_abs_diff(&want));
        // Table 1: 256·n² bits for n×n (two opened n×n matrices, both directions)
        let bits = mpc.net.ledger.bytes_total() * 8;
        assert_eq!(bits, 256 * (n as u64) * (n as u64));
        assert_eq!(mpc.net.ledger.rounds_total(), 1);
    }

    #[test]
    fn mul_elem_cost_matches_table1() {
        let mut mpc = mk();
        let x = FloatTensor::from_fn(4, 8, |r, c| (r as f32 - c as f32) * 0.1);
        let sx = mpc.share_local(&enc(&x));
        let sy = mpc.share_local(&enc(&x));
        let out = mpc.mul_elem(&sx, &sy, OpClass::Gelu);
        let got = dec(&out.reconstruct());
        let want = x.zip_with(&x, |a, b| a * b);
        assert!(got.max_abs_diff(&want) < 1e-2);
        assert_eq!(mpc.net.ledger.bytes_total() * 8, 256 * 32);
    }

    #[test]
    fn square_half_traffic() {
        let mut mpc = mk();
        let x = FloatTensor::from_fn(1, 16, |_, c| c as f32 * 0.25 - 2.0);
        let sx = mpc.share_local(&enc(&x));
        let out = mpc.square(&sx, OpClass::Softmax);
        let got = dec(&out.reconstruct());
        let want = x.map(|v| v * v);
        assert!(got.max_abs_diff(&want) < 1e-2, "diff={}", got.max_abs_diff(&want));
        // 128·N bits
        assert_eq!(mpc.net.ledger.bytes_total() * 8, 128 * 16);
        assert_eq!(mpc.net.ledger.rounds_total(), 1);
    }

    #[test]
    fn open_costs_one_round() {
        let mut mpc = mk();
        let x = RingTensor::zeros(4, 4);
        let sx = mpc.share_local(&x);
        let opened = mpc.open(&sx, OpClass::Other);
        assert_eq!(opened, x);
        assert_eq!(mpc.net.ledger.rounds_total(), 1);
        assert_eq!(mpc.net.ledger.bytes_total(), 2 * 16 * 8);
    }

    #[test]
    fn reshare_hides_and_reconstructs() {
        check("reshare", 30, |g| {
            let mut mpc = mk();
            let n = g.dim(10);
            let x = RingTensor::from_vec(1, n, g.vec_i64(n));
            let sh = mpc.reshare_from(&x, PartyId::P1, OpClass::Other);
            assert_eq!(sh.reconstruct(), x);
        });
    }

    #[test]
    fn fixed_rhs_matmul_matches_plain_and_halves_traffic() {
        let mut mpc = mk();
        let n = 8usize;
        let y = FloatTensor::from_fn(n, n, |r, c| ((r * 3 + c) % 5) as f32 * 0.25 - 0.5);
        let sy = mpc.share_local(&enc(&y));
        let mut corr = mpc.dealer.fixed_correlation(TripleShape::fixed_ppp(2, n, 3));
        let before = mpc.net.ledger.bytes_total();
        let f = mpc.open_fixed_operand(&sy, &mut corr, OpClass::Correlation).unwrap();
        // one-time opening: 2·8·n² bytes, 1 round, Correlation class
        assert_eq!(mpc.net.ledger.bytes_total() - before, 2 * 8 * (n * n) as u64);
        assert_eq!(mpc.net.ledger.class(OpClass::Correlation).rounds, 1);
        assert_eq!(corr.openings(), 1);
        assert!(
            mpc.open_fixed_operand(&sy, &mut corr, OpClass::Correlation).is_err(),
            "the session mask must open exactly once"
        );
        for i in 0..3 {
            let x = FloatTensor::from_fn(2, n, |r, c| ((r + c * 2 + i) % 7) as f32 * 0.2 - 0.6);
            let sx = mpc.share_local(&enc(&x));
            let before = mpc.net.ledger.class(OpClass::Linear).bytes;
            let out = mpc.matmul_fixed_rhs(&sx, &f, &mut corr, OpClass::Linear).unwrap();
            // per use: only E (2×n) opened — vs 2·8·(2n + n²) for Π_MatMul
            assert_eq!(mpc.net.ledger.class(OpClass::Linear).bytes - before, 2 * 8 * (2 * n) as u64);
            let got = dec(&out.reconstruct());
            let want = x.matmul(&y);
            assert!(got.max_abs_diff(&want) < 1e-2, "use {i} diff {}", got.max_abs_diff(&want));
        }
        let spare = mpc.share_local(&RingTensor::zeros(2, n));
        assert!(
            mpc.matmul_fixed_rhs(&spare, &f, &mut corr, OpClass::Linear).is_err(),
            "reuse beyond the dealt use count must error"
        );
    }

    #[test]
    fn fixed_lhs_col_matches_sliced_plain_matmul() {
        let mut mpc = mk();
        let (n, d) = (6usize, 5usize);
        let x = FloatTensor::from_fn(n, n, |r, c| ((r * 2 + c) % 4) as f32 * 0.3 - 0.4);
        let sx = mpc.share_local(&enc(&x));
        let mut corr = mpc.dealer.fixed_correlation(TripleShape::fixed_append(n, d, n));
        let f = mpc.open_fixed_operand(&sx, &mut corr, OpClass::Correlation).unwrap();
        for pos in 0..3 {
            let y = FloatTensor::from_fn(1, d, |_, c| (c + pos) as f32 * 0.15 - 0.3);
            let sy = mpc.share_local(&enc(&y));
            let out = mpc.matmul_fixed_lhs_col(&f, &sy, &mut corr, pos, OpClass::Linear).unwrap();
            let col = FloatTensor::from_fn(n, 1, |r, _| x.get(r, pos));
            let want = col.matmul(&y);
            let got = dec(&out.reconstruct());
            assert!(got.max_abs_diff(&want) < 1e-2, "pos {pos} diff {}", got.max_abs_diff(&want));
        }
        // out-of-order consumption is rejected
        let sy = mpc.share_local(&enc(&FloatTensor::zeros(1, d)));
        assert!(mpc.matmul_fixed_lhs_col(&f, &sy, &mut corr, 5, OpClass::Linear).is_err());
    }

    #[test]
    fn fixed_grown_scores_match_plain_per_head_products() {
        let mut mpc = mk();
        let (heads, n, d) = (2usize, 6usize, 8usize);
        let dh = d / heads;
        let mut corr = mpc.dealer.fixed_correlation(TripleShape::fixed_scores(heads, n, d, n));
        // simulate the write-once cache: rows written and opened one by one
        let mut k_cache = Share { s0: RingTensor::zeros(n, d), s1: RingTensor::zeros(n, d) };
        let mut f_rows = RingTensor::zeros(n, d);
        for pos in 0..4 {
            let row = FloatTensor::from_fn(1, d, |_, c| ((c * 3 + pos) % 5) as f32 * 0.2 - 0.4);
            let row_sh = mpc.share_local(&enc(&row));
            k_cache.s0.row_mut(pos).copy_from_slice(row_sh.s0.row(0));
            k_cache.s1.row_mut(pos).copy_from_slice(row_sh.s1.row(0));
            let opened = mpc.open_fixed_grown_row(&row_sh, &mut corr, pos, OpClass::Linear).unwrap();
            f_rows.row_mut(pos).copy_from_slice(opened.row(0));
            assert_eq!(corr.openings(), pos as u64 + 1);

            let q = FloatTensor::from_fn(1, d, |_, c| ((c + 2 * pos) % 7) as f32 * 0.1 - 0.3);
            let sq = mpc.share_local(&enc(&q));
            let outs = mpc
                .matmul_fixed_grown_scores(&sq, &f_rows, &mut corr, pos, n, OpClass::Linear)
                .unwrap();
            assert_eq!(outs.len(), heads);
            // reference: q_h against the FULL zero-padded cache, per head
            let k_plain = dec(&k_cache.reconstruct());
            for (h, out) in outs.iter().enumerate() {
                assert_eq!(out.shape(), (1, n));
                let qh = FloatTensor::from_fn(1, dh, |_, c| q.get(0, h * dh + c));
                let kht = FloatTensor::from_fn(dh, n, |r, c| k_plain.get(c, h * dh + r));
                let want = qh.matmul(&kht);
                let got = dec(&out.reconstruct());
                let diff = got.max_abs_diff(&want);
                assert!(diff < 1e-2, "pos {pos} head {h} diff {diff}");
            }
        }
        // a score product for an unopened row is rejected
        let sq = mpc.share_local(&RingTensor::zeros(1, d));
        assert!(mpc.matmul_fixed_grown_scores(&sq, &f_rows, &mut corr, 5, OpClass::Linear).is_err());
    }

    #[test]
    fn scale_fx_matches_plaintext() {
        let mut mpc = mk();
        let x = FloatTensor::from_fn(2, 8, |r, c| (r + c) as f32 * 0.5 - 1.0);
        let sx = mpc.share_local(&enc(&x));
        let out = mpc.scale_fx(&sx, fixed::encode(0.125));
        let got = dec(&out.reconstruct());
        let want = x.map(|v| v * 0.125);
        assert!(got.max_abs_diff(&want) < 1e-3);
    }

    // ------------------------------------------------------------------
    // Integrity-checked mode
    // ------------------------------------------------------------------

    /// input_share (1 covered opening) + matmul (E and F: 2 more).
    fn audited_workload(mpc: &mut Mpc) -> RingTensor {
        let x = FloatTensor::from_fn(4, 4, |r, c| ((r * 3 + c) % 5) as f32 * 0.2 - 0.7);
        let y = FloatTensor::from_fn(4, 4, |r, c| ((r + 2 * c) % 7) as f32 * 0.1 - 0.3);
        let sx = mpc.input_share(&enc(&x), OpClass::Embed);
        let sy = mpc.share_local(&enc(&y));
        mpc.matmul(&sx, &sy, OpClass::Linear).reconstruct()
    }

    #[test]
    fn audit_honest_run_is_bit_identical_and_flushes_clean() {
        let mut plain = mk();
        let plain_out = audited_workload(&mut plain);

        let mut mpc = mk();
        mpc.enable_audit(42);
        let audited_out = audited_workload(&mut mpc);
        assert_eq!(audited_out, plain_out, "audit must not perturb a single output bit");
        assert_eq!(
            (mpc.net.ledger.bytes_total(), mpc.net.ledger.rounds_total()),
            (plain.net.ledger.bytes_total(), plain.net.ledger.rounds_total()),
            "audit traffic never reaches the protocol ledger"
        );

        assert_eq!(mpc.flush_mac_checks().unwrap(), 1);
        let c = mpc.audit_counters().unwrap();
        assert_eq!(c.mac_failures, 0);
        assert_eq!(c.mac_checks, 1);
        assert_eq!((c.overhead_bytes, c.overhead_rounds), (32, 2));
        assert_eq!(c.openings, 3);
        assert_eq!(c.share_faults_applied, 0);
        // Nothing pending → the next flush is a free no-op.
        assert_eq!(mpc.flush_mac_checks().unwrap(), 0);
    }

    #[test]
    fn audit_detects_an_injected_share_fault() {
        let mut mpc = mk();
        mpc.enable_audit(7);
        assert!(mpc.inject_share_fault(ShareFault { at_open: 1, word: 5, mask: 1 << 17 }));
        audited_workload(&mut mpc);
        let err = mpc.flush_mac_checks().unwrap_err();
        assert!(err.to_string().contains("MAC check failed"), "unexpected error: {err}");
        let c = mpc.audit_counters().unwrap();
        assert_eq!(c.share_faults_applied, 1);
        assert_eq!(c.mac_failures, 1);
        // The failed batch was consumed; the context is clean again.
        assert_eq!(mpc.flush_mac_checks().unwrap(), 0);
    }

    #[test]
    fn audit_detects_a_wire_bit_flip() {
        use crate::net::{TamperKind, TamperPlan};
        let mut mpc = mk();
        mpc.enable_audit(9);
        // input_share is transfers 0–1; the matmul E exchange is 2–3.
        mpc.net
            .schedule_tamper(TamperPlan { at_seq: 2, kind: TamperKind::BitFlip { word: 3, bit: 41 } });
        audited_workload(&mut mpc);
        assert_eq!(mpc.net.faults_applied, 1, "the scheduled flip must have landed");
        let err = mpc.flush_mac_checks().unwrap_err();
        assert!(err.to_string().contains("MAC check failed"), "unexpected error: {err}");
        assert_eq!(mpc.audit_counters().unwrap().mac_failures, 1);
    }

    #[test]
    fn audit_detects_a_stale_replay() {
        use crate::net::{TamperKind, TamperPlan};
        let mut mpc = mk();
        mpc.enable_audit(11);
        // Within one open: seq 0 is P0's half (stashed), seq 1 is P1's —
        // replaying the stale P0 payload as P1's makes the sum 2·s0 ≠ x.
        mpc.net.schedule_tamper(TamperPlan { at_seq: 1, kind: TamperKind::ReplayStale });
        let x = RingTensor::from_vec(2, 3, vec![1, -2, 3, -4, 5, -6]);
        let sx = mpc.share_local(&x);
        let opened = mpc.open(&sx, OpClass::Other);
        assert_ne!(opened, x, "the replayed stale half must corrupt the opening");
        assert_eq!(mpc.net.faults_applied, 1);
        let err = mpc.flush_mac_checks().unwrap_err();
        assert!(err.to_string().contains("MAC check failed"), "unexpected error: {err}");
    }

    #[test]
    fn audit_covers_fixed_operand_openings() {
        let mut mpc = mk();
        mpc.enable_audit(13);
        let n = 4usize;
        let y = FloatTensor::from_fn(n, n, |r, c| ((r + c) % 3) as f32 * 0.25 - 0.25);
        let sy = mpc.share_local(&enc(&y));
        let mut corr = mpc.dealer.fixed_correlation(TripleShape::fixed_ppp(2, n, 1));
        // Honest fixed-operand open + use flushes clean…
        let f = mpc.open_fixed_operand(&sy, &mut corr, OpClass::Correlation).unwrap();
        let sx = mpc.share_local(&enc(&FloatTensor::from_fn(2, n, |r, c| (r + c) as f32 * 0.1)));
        mpc.matmul_fixed_rhs(&sx, &f, &mut corr, OpClass::Linear).unwrap();
        assert_eq!(mpc.flush_mac_checks().unwrap(), 1);
        // …and a share fault on the very next covered opening is caught.
        let open_now = mpc.audit_open_count();
        assert!(mpc.inject_share_fault(ShareFault { at_open: open_now, word: 0, mask: 2 }));
        let sz = mpc.share_local(&RingTensor::zeros(3, 3));
        mpc.open(&sz, OpClass::Other);
        assert!(mpc.flush_mac_checks().is_err());
    }
}
