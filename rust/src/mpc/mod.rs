//! 2-out-of-2 additive secret sharing over `Z_{2^64}` with a trusted dealer
//! (the CrypTen model the paper builds on, §2.2).
//!
//! [`Share`] holds both parties' shares inside the simulator; protocol code
//! only ever combines them through the [`Mpc`] context, which charges every
//! transfer to the [`crate::net::NetSim`] ledger. The primitive costs match
//! the paper's Table 1 exactly (see module tests).

pub mod dealer;
pub mod nonlin;

use crate::fixed;
use crate::net::{NetSim, OpClass, PartyId};
use crate::ring;
use crate::tensor::RingTensor;
use crate::util::rng::Rng;
use dealer::Dealer;

pub use dealer::{TriplePool, TripleShape};

/// A 2-party additive sharing of a ring tensor: `x = s0 + s1 (mod 2^64)`.
#[derive(Clone, Debug, PartialEq)]
pub struct Share {
    /// Party 0's additive share.
    pub s0: RingTensor,
    /// Party 1's additive share.
    pub s1: RingTensor,
}

impl Share {
    /// Row count of the shared tensor.
    pub fn rows(&self) -> usize {
        self.s0.rows()
    }
    /// Column count of the shared tensor.
    pub fn cols(&self) -> usize {
        self.s0.cols()
    }
    /// `(rows, cols)` of the shared tensor.
    pub fn shape(&self) -> (usize, usize) {
        self.s0.shape()
    }

    /// Simulator-internal reconstruction (no communication charged) — for
    /// tests and the ideal-functionality fallbacks documented in DESIGN.md.
    pub fn reconstruct(&self) -> RingTensor {
        ring::add(&self.s0, &self.s1)
    }

    /// Access one party's share.
    pub fn of(&self, party: PartyId) -> &RingTensor {
        match party {
            PartyId::P0 => &self.s0,
            PartyId::P1 => &self.s1,
            _ => panic!("only compute servers hold shares"),
        }
    }

    /// Local transpose of both shares.
    pub fn transpose(&self) -> Share {
        Share { s0: self.s0.transpose(), s1: self.s1.transpose() }
    }

    /// Local column-block slice of both shares.
    pub fn col_block(&self, c0: usize, c1: usize) -> Share {
        Share { s0: self.s0.col_block(c0, c1), s1: self.s1.col_block(c0, c1) }
    }

    /// Horizontal concatenation of shares.
    pub fn concat_cols(blocks: &[Share]) -> Share {
        Share {
            s0: RingTensor::concat_cols(&blocks.iter().map(|b| b.s0.clone()).collect::<Vec<_>>()),
            s1: RingTensor::concat_cols(&blocks.iter().map(|b| b.s1.clone()).collect::<Vec<_>>()),
        }
    }

    /// Local row-block slice (rows `[r0, r1)`).
    pub fn row_block(&self, r0: usize, r1: usize) -> Share {
        let f = |t: &RingTensor| {
            let mut out = RingTensor::zeros(r1 - r0, t.cols());
            for r in r0..r1 {
                out.row_mut(r - r0).copy_from_slice(t.row(r));
            }
            out
        };
        Share { s0: f(&self.s0), s1: f(&self.s1) }
    }
}

/// MPC execution context: network simulator + dealer + share randomness.
pub struct Mpc {
    /// Network simulator charging every transfer.
    pub net: NetSim,
    /// Trusted dealer for correlated randomness.
    pub dealer: Dealer,
    rng: Rng,
}

impl Mpc {
    /// Fresh context over `net`; the dealer PRG forks from `seed`.
    pub fn new(net: NetSim, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let dealer = Dealer::new(rng.fork(0xDEA1));
        Mpc { net, dealer, rng }
    }

    // ------------------------------------------------------------------
    // Sharing / opening
    // ------------------------------------------------------------------

    /// Split a plaintext into a fresh random sharing (no comm — used by the
    /// party that owns the value; the transfer of shares to the compute
    /// servers is charged by the caller via [`Mpc::input_share`]).
    pub fn share_local(&mut self, x: &RingTensor) -> Share {
        let s0 = RingTensor::from_vec(x.rows(), x.cols(), self.rng.vec_i64(x.len()));
        let s1 = ring::sub(x, &s0);
        Share { s0, s1 }
    }

    /// Client-side input sharing: generate shares and send `[x]_j` to each
    /// compute server (1 round, `2·8·|x|` bytes — both messages in parallel).
    pub fn input_share(&mut self, x: &RingTensor, class: OpClass) -> Share {
        let sh = self.share_local(x);
        let s0 = self.net.transfer(PartyId::P2, PartyId::P0, &sh.s0, class);
        let s1 = self.net.transfer(PartyId::P2, PartyId::P1, &sh.s1, class);
        self.net.round(class, 1);
        Share { s0, s1 }
    }

    /// Open a sharing to both parties (1 round, each party sends its share
    /// to the other: `2·8·|x|` bytes).
    pub fn open(&mut self, s: &Share, class: OpClass) -> RingTensor {
        let a = self.net.transfer(PartyId::P0, PartyId::P1, &s.s0, class);
        let b = self.net.transfer(PartyId::P1, PartyId::P0, &s.s1, class);
        self.net.round(class, 1);
        ring::add(&a, &b)
    }

    /// Open to a single party (half the traffic, 1 round).
    pub fn open_to(&mut self, s: &Share, to: PartyId, class: OpClass) -> RingTensor {
        let from = if to == PartyId::P0 { PartyId::P1 } else { PartyId::P0 };
        let other = self.net.transfer(from, to, s.of(from), class);
        self.net.round(class, 1);
        ring::add(s.of(to), &other)
    }

    /// Send an existing share tensor from one server to the other (e.g. the
    /// `Π_PP*` state conversion) — charged, no round bookkeeping (caller
    /// groups rounds).
    pub fn send_share_half(&mut self, s: &Share, from: PartyId, to: PartyId, class: OpClass) -> RingTensor {
        self.net.transfer(from, to, s.of(from), class)
    }

    // ------------------------------------------------------------------
    // Linear (communication-free) protocols — Π_Add, Π_ScalMul
    // ------------------------------------------------------------------

    /// `Π_Add`: elementwise share addition (local).
    pub fn add(&self, a: &Share, b: &Share) -> Share {
        Share { s0: ring::add(&a.s0, &b.s0), s1: ring::add(&a.s1, &b.s1) }
    }

    /// Share subtraction (local).
    pub fn sub(&self, a: &Share, b: &Share) -> Share {
        Share { s0: ring::sub(&a.s0, &b.s0), s1: ring::sub(&a.s1, &b.s1) }
    }

    /// Add a public constant (P0 adjusts its share).
    pub fn add_plain(&self, a: &Share, p: &RingTensor) -> Share {
        Share { s0: ring::add(&a.s0, p), s1: a.s1.clone() }
    }

    /// Add a public broadcast row (P0 adjusts its share).
    pub fn add_plain_row(&self, a: &Share, bias: &[i64]) -> Share {
        Share { s0: ring::add_row(&a.s0, bias), s1: a.s1.clone() }
    }

    /// Elementwise multiply by a public *integer* matrix (e.g. a 0/1 mask)
    /// — local, no truncation (the plaintext is not fixed-point scaled).
    pub fn mul_plain_int(&self, a: &Share, m: &RingTensor) -> Share {
        Share { s0: ring::mul_elem(&a.s0, m), s1: ring::mul_elem(&a.s1, m) }
    }

    /// Multiply by a public fixed-point scalar, with share truncation.
    pub fn scale_fx(&self, a: &Share, scalar_fx: i64) -> Share {
        let mut s0 = ring::scale(&a.s0, scalar_fx);
        let mut s1 = ring::scale(&a.s1, scalar_fx);
        fixed::trunc_share_tensor(&mut s0, 0);
        fixed::trunc_share_tensor(&mut s1, 1);
        Share { s0, s1 }
    }

    /// `Π_ScalMul` (matrix form): public fixed-point `A (m×k)` times shared
    /// `[X] (k×n)` → `[A·X]`, communication-free; includes fixed-point
    /// truncation. Each party's local matmul is timed separately.
    pub fn scalmul(&mut self, a_fx: &RingTensor, x: &Share, class: OpClass) -> Share {
        let mut s0 = self.net.timed(class, PartyId::P0, || ring::matmul(a_fx, &x.s0));
        let mut s1 = self.net.timed(class, PartyId::P1, || ring::matmul(a_fx, &x.s1));
        fixed::trunc_share_tensor(&mut s0, 0);
        fixed::trunc_share_tensor(&mut s1, 1);
        Share { s0, s1 }
    }

    /// `Π_ScalMul` with the shared operand on the left: `[X] (m×k)` times
    /// public `Wᵀ` given as `W (n×k)` → `[X·Wᵀ] (m×n)`.
    pub fn scalmul_nt(&mut self, x: &Share, w_fx: &RingTensor, class: OpClass) -> Share {
        let mut s0 = self.net.timed(class, PartyId::P0, || ring::matmul_nt(&x.s0, w_fx));
        let mut s1 = self.net.timed(class, PartyId::P1, || ring::matmul_nt(&x.s1, w_fx));
        fixed::trunc_share_tensor(&mut s0, 0);
        fixed::trunc_share_tensor(&mut s1, 1);
        Share { s0, s1 }
    }

    // ------------------------------------------------------------------
    // Π_MatMul / Π_Mul — Beaver-triple share×share products
    // ------------------------------------------------------------------

    /// `Π_ScalMul` with the plaintext on the right: `[X] (m×k)` times
    /// public `W (k×n)` → `[X·W]` (embedding lookup), communication-free.
    pub fn scalmul_rhs(&mut self, x: &Share, w_fx: &RingTensor, class: OpClass) -> Share {
        let mut s0 = self.net.timed(class, PartyId::P0, || ring::matmul(&x.s0, w_fx));
        let mut s1 = self.net.timed(class, PartyId::P1, || ring::matmul(&x.s1, w_fx));
        fixed::trunc_share_tensor(&mut s0, 0);
        fixed::trunc_share_tensor(&mut s1, 1);
        Share { s0, s1 }
    }

    /// `Π_MatMul` with identical communication charges but the product
    /// computed directly (ideal functionality) — the *fast-sim* execution
    /// mode for paper-scale models on this 1-core testbed, and for very
    /// large operands (embedding tables) where materializing Beaver
    /// triples would need gigabytes. Wire costs are exact; local compute
    /// is the single plaintext product (the per-op compute for the time
    /// model is measured separately by full-mode microbenches).
    /// DESIGN.md §CostModel documents this.
    pub fn matmul_charged_ideal(&mut self, x: &Share, y: &Share, class: OpClass) -> Share {
        let out = self.matmul_charged_ideal_core(x, y, class);
        self.net.round(class, 1);
        out
    }

    fn matmul_charged_ideal_core(&mut self, x: &Share, y: &Share, class: OpClass) -> Share {
        let (m, k) = x.shape();
        let (k2, n) = y.shape();
        assert_eq!(k, k2);
        // identical wire cost to the Beaver path: open E (m×k) + F (k×n)
        // in both directions.
        self.net.charge_bytes(class, (2 * 8 * (m * k + k * n)) as u64);
        let prod = self.net.timed(class, PartyId::P1, || {
            ring::matmul(&x.reconstruct(), &y.reconstruct())
        });
        let truncated = prod.map(|v| v >> crate::fixed::FRAC_BITS);
        let mut rng = self.dealer.fork_rng(0x1DEA ^ (m * n) as u64);
        let s0 = RingTensor::from_vec(m, n, rng.vec_i64(m * n));
        let s1 = ring::sub(&truncated, &s0);
        Share { s0, s1 }
    }

    /// Batched charged-ideal matmul (single round, like [`Mpc::matmul_batch`]).
    pub fn matmul_charged_ideal_batch(&mut self, pairs: &[(&Share, &Share)], class: OpClass) -> Vec<Share> {
        let outs = pairs.iter().map(|(x, y)| self.matmul_charged_ideal_core(x, y, class)).collect();
        self.net.round(class, 1);
        outs
    }

    /// `Π_ScalMul` as a charged-ideal (fast-sim): one plaintext product
    /// instead of one per party; zero communication, same as the real
    /// protocol.
    pub fn scalmul_nt_ideal(&mut self, x: &Share, w_fx: &RingTensor, class: OpClass) -> Share {
        let prod = self.net.timed(class, PartyId::P1, || ring::matmul_nt(&x.reconstruct(), w_fx));
        let truncated = prod.map(|v| v >> crate::fixed::FRAC_BITS);
        let (m, n) = truncated.shape();
        let mut rng = self.dealer.fork_rng(0x5CA1 ^ (m * n) as u64);
        let s0 = RingTensor::from_vec(m, n, rng.vec_i64(m * n));
        let s1 = ring::sub(&truncated, &s0);
        Share { s0, s1 }
    }

    /// Right-plaintext variant of [`Mpc::scalmul_nt_ideal`].
    pub fn scalmul_rhs_ideal(&mut self, x: &Share, w_fx: &RingTensor, class: OpClass) -> Share {
        let prod = self.net.timed(class, PartyId::P1, || ring::matmul(&x.reconstruct(), w_fx));
        let truncated = prod.map(|v| v >> crate::fixed::FRAC_BITS);
        let (m, n) = truncated.shape();
        let mut rng = self.dealer.fork_rng(0x5CA2 ^ (m * n) as u64);
        let s0 = RingTensor::from_vec(m, n, rng.vec_i64(m * n));
        let s1 = ring::sub(&truncated, &s0);
        Share { s0, s1 }
    }

    /// `Π_MatMul`: `[X] (m×k) @ [Y] (k×n)` via a Beaver matrix triple.
    /// 1 round; traffic `2·8·(mk + kn)` bytes (= 256·n² bits when m=k=n,
    /// matching Table 1). Includes fixed-point truncation.
    pub fn matmul(&mut self, x: &Share, y: &Share, class: OpClass) -> Share {
        let out = self.matmul_core(x, y, class);
        self.net.round(class, 1);
        out
    }

    /// Batched `Π_MatMul`: all products exchanged in a single parallel
    /// round (the per-head attention products).
    pub fn matmul_batch(&mut self, pairs: &[(&Share, &Share)], class: OpClass) -> Vec<Share> {
        let outs: Vec<Share> = pairs.iter().map(|(x, y)| self.matmul_core(x, y, class)).collect();
        self.net.round(class, 1);
        outs
    }

    fn matmul_core(&mut self, x: &Share, y: &Share, class: OpClass) -> Share {
        let (m, k) = x.shape();
        let (k2, n) = y.shape();
        assert_eq!(k, k2, "Π_MatMul inner dim");
        let trip = self.dealer.matmul_triple(m, k, n);
        // E = X - A, F = Y - B, opened in one parallel round.
        let e_sh = self.sub(x, &trip.a);
        let f_sh = self.sub(y, &trip.b);
        let e0 = self.net.transfer(PartyId::P0, PartyId::P1, &e_sh.s0, class);
        let e1 = self.net.transfer(PartyId::P1, PartyId::P0, &e_sh.s1, class);
        let f0 = self.net.transfer(PartyId::P0, PartyId::P1, &f_sh.s0, class);
        let f1 = self.net.transfer(PartyId::P1, PartyId::P0, &f_sh.s1, class);
        // (round charged by the caller: matmul/matmul_batch)
        let e = ring::add(&e0, &e1);
        let f = ring::add(&f0, &f1);
        // [Z] = [C] + E·[B] + [A]·F + E·F (P0 adds the public term).
        let mut s0 = self.net.timed(class, PartyId::P0, || {
            let mut z = ring::matmul(&e, &trip.b.s0);
            ring::add_assign(&mut z, &ring::matmul(&trip.a.s0, &f));
            ring::add_assign(&mut z, &trip.c.s0);
            ring::add_assign(&mut z, &ring::matmul(&e, &f));
            z
        });
        let mut s1 = self.net.timed(class, PartyId::P1, || {
            let mut z = ring::matmul(&e, &trip.b.s1);
            ring::add_assign(&mut z, &ring::matmul(&trip.a.s1, &f));
            ring::add_assign(&mut z, &trip.c.s1);
            z
        });
        fixed::trunc_share_tensor(&mut s0, 0);
        fixed::trunc_share_tensor(&mut s1, 1);
        Share { s0, s1 }
    }

    /// `Π_Mul`: elementwise share×share product (Beaver), 1 round,
    /// `2·2·8·N` bytes (256·N bits). Includes truncation.
    pub fn mul_elem(&mut self, x: &Share, y: &Share, class: OpClass) -> Share {
        assert_eq!(x.shape(), y.shape());
        let trip = self.dealer.elem_triple(x.rows(), x.cols());
        let e_sh = self.sub(x, &trip.a);
        let f_sh = self.sub(y, &trip.b);
        let e0 = self.net.transfer(PartyId::P0, PartyId::P1, &e_sh.s0, class);
        let e1 = self.net.transfer(PartyId::P1, PartyId::P0, &e_sh.s1, class);
        let f0 = self.net.transfer(PartyId::P0, PartyId::P1, &f_sh.s0, class);
        let f1 = self.net.transfer(PartyId::P1, PartyId::P0, &f_sh.s1, class);
        self.net.round(class, 1);
        let e = ring::add(&e0, &e1);
        let f = ring::add(&f0, &f1);
        let mut s0 = ring::add(
            &ring::add(&ring::mul_elem(&e, &trip.b.s0), &ring::mul_elem(&trip.a.s0, &f)),
            &ring::add(&trip.c.s0, &ring::mul_elem(&e, &f)),
        );
        let mut s1 = ring::add(
            &ring::add(&ring::mul_elem(&e, &trip.b.s1), &ring::mul_elem(&trip.a.s1, &f)),
            &trip.c.s1,
        );
        fixed::trunc_share_tensor(&mut s0, 0);
        fixed::trunc_share_tensor(&mut s1, 1);
        Share { s0, s1 }
    }

    /// Elementwise square with a square triple `(A, A²)` — CrypTen's cheap
    /// square: only `E = X − A` is opened (1 round, `2·8·N` bytes =
    /// 128·N bits; 8 squarings of a scalar = 1024 bits, Table 1's `exp`).
    pub fn square(&mut self, x: &Share, class: OpClass) -> Share {
        let trip = self.dealer.square_pair(x.rows(), x.cols());
        let e_sh = self.sub(x, &trip.a);
        let e0 = self.net.transfer(PartyId::P0, PartyId::P1, &e_sh.s0, class);
        let e1 = self.net.transfer(PartyId::P1, PartyId::P0, &e_sh.s1, class);
        self.net.round(class, 1);
        let e = ring::add(&e0, &e1);
        // X² = E² + 2·E·A + A² → [X²] = E² (public, P0) + 2E·[A] + [C]
        let two_e = ring::scale(&e, 2);
        let mut s0 = ring::add(
            &ring::add(&ring::mul_elem(&two_e, &trip.a.s0), &trip.c.s0),
            &ring::mul_elem(&e, &e),
        );
        let mut s1 = ring::add(&ring::mul_elem(&two_e, &trip.a.s1), &trip.c.s1);
        fixed::trunc_share_tensor(&mut s0, 0);
        fixed::trunc_share_tensor(&mut s1, 1);
        Share { s0, s1 }
    }

    /// Fresh re-sharing of a plaintext known to one party (that party
    /// splits and sends the counter-share: 1 transfer; round charged by the
    /// caller as part of the enclosing protocol step).
    pub fn reshare_from(&mut self, x: &RingTensor, holder: PartyId, class: OpClass) -> Share {
        let mask = RingTensor::from_vec(x.rows(), x.cols(), self.rng.vec_i64(x.len()));
        let other_share = ring::sub(x, &mask);
        let to = if holder == PartyId::P0 { PartyId::P1 } else { PartyId::P0 };
        let sent = self.net.transfer(holder, to, &other_share, class);
        if holder == PartyId::P1 {
            Share { s0: sent, s1: mask }
        } else {
            Share { s0: mask, s1: sent }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::NetworkProfile;
    use crate::tensor::FloatTensor;
    use crate::util::prop::check;

    fn mk() -> Mpc {
        Mpc::new(NetSim::new(NetworkProfile::lan()), 42)
    }

    fn enc(t: &FloatTensor) -> RingTensor {
        fixed::encode_tensor(t)
    }
    fn dec(t: &RingTensor) -> FloatTensor {
        fixed::decode_tensor(t)
    }

    #[test]
    fn share_reconstruct_identity() {
        check("share/reconstruct", 100, |g| {
            let mut mpc = mk();
            let n = g.dim(16);
            let x = RingTensor::from_vec(1, n, g.vec_i64(n));
            let sh = mpc.share_local(&x);
            assert_eq!(sh.reconstruct(), x);
        });
    }

    #[test]
    fn shares_are_uniformly_masked() {
        // Each individual share of a constant tensor should look random:
        // its values must not equal the plaintext (w.h.p.) and two sharings
        // must differ.
        let mut mpc = mk();
        let x = RingTensor::from_vec(1, 64, vec![fixed::encode(1.0); 64]);
        let a = mpc.share_local(&x);
        let b = mpc.share_local(&x);
        assert_ne!(a.s0, b.s0);
        let hits = a.s0.data().iter().filter(|&&v| v == fixed::encode(1.0)).count();
        assert!(hits <= 1);
    }

    #[test]
    fn add_matches_plaintext() {
        check("Π_Add", 50, |g| {
            let mut mpc = mk();
            let n = g.dim(12);
            let x = RingTensor::from_vec(1, n, g.vec_i64(n));
            let y = RingTensor::from_vec(1, n, g.vec_i64(n));
            let sx = mpc.share_local(&x);
            let sy = mpc.share_local(&y);
            assert_eq!(mpc.add(&sx, &sy).reconstruct(), ring::add(&x, &y));
        });
    }

    #[test]
    fn scalmul_matches_float_matmul() {
        check("Π_ScalMul", 25, |g| {
            let mut mpc = mk();
            let (m, k, n) = (g.dim(6), g.dim(8), g.dim(6));
            let a = FloatTensor::from_vec(m, k, g.vec_small_f64(m * k).iter().map(|&v| v as f32 * 0.2).collect());
            let x = FloatTensor::from_vec(k, n, g.vec_small_f64(k * n).iter().map(|&v| v as f32 * 0.2).collect());
            let sx = mpc.share_local(&enc(&x));
            let out = mpc.scalmul(&enc(&a), &sx, OpClass::Linear);
            let got = dec(&out.reconstruct());
            let want = a.matmul(&x);
            assert!(got.max_abs_diff(&want) < 1e-2, "diff {}", got.max_abs_diff(&want));
            // communication-free:
            assert_eq!(mpc.net.ledger.bytes_total(), 0);
            assert_eq!(mpc.net.ledger.rounds_total(), 0);
        });
    }

    #[test]
    fn matmul_beaver_correct_and_costed() {
        let mut mpc = mk();
        let n = 8usize;
        let x = FloatTensor::from_fn(n, n, |r, c| ((r + 2 * c) % 5) as f32 * 0.3 - 0.5);
        let y = FloatTensor::from_fn(n, n, |r, c| ((3 * r + c) % 7) as f32 * 0.2 - 0.4);
        let sx = mpc.share_local(&enc(&x));
        let sy = mpc.share_local(&enc(&y));
        let out = mpc.matmul(&sx, &sy, OpClass::Linear);
        let got = dec(&out.reconstruct());
        let want = x.matmul(&y);
        assert!(got.max_abs_diff(&want) < 1e-2, "diff {}", got.max_abs_diff(&want));
        // Table 1: 256·n² bits for n×n (two opened n×n matrices, both directions)
        let bits = mpc.net.ledger.bytes_total() * 8;
        assert_eq!(bits, 256 * (n as u64) * (n as u64));
        assert_eq!(mpc.net.ledger.rounds_total(), 1);
    }

    #[test]
    fn mul_elem_cost_matches_table1() {
        let mut mpc = mk();
        let x = FloatTensor::from_fn(4, 8, |r, c| (r as f32 - c as f32) * 0.1);
        let sx = mpc.share_local(&enc(&x));
        let sy = mpc.share_local(&enc(&x));
        let out = mpc.mul_elem(&sx, &sy, OpClass::Gelu);
        let got = dec(&out.reconstruct());
        let want = x.zip_with(&x, |a, b| a * b);
        assert!(got.max_abs_diff(&want) < 1e-2);
        assert_eq!(mpc.net.ledger.bytes_total() * 8, 256 * 32);
    }

    #[test]
    fn square_half_traffic() {
        let mut mpc = mk();
        let x = FloatTensor::from_fn(1, 16, |_, c| c as f32 * 0.25 - 2.0);
        let sx = mpc.share_local(&enc(&x));
        let out = mpc.square(&sx, OpClass::Softmax);
        let got = dec(&out.reconstruct());
        let want = x.map(|v| v * v);
        assert!(got.max_abs_diff(&want) < 1e-2, "diff={}", got.max_abs_diff(&want));
        // 128·N bits
        assert_eq!(mpc.net.ledger.bytes_total() * 8, 128 * 16);
        assert_eq!(mpc.net.ledger.rounds_total(), 1);
    }

    #[test]
    fn open_costs_one_round() {
        let mut mpc = mk();
        let x = RingTensor::zeros(4, 4);
        let sx = mpc.share_local(&x);
        let opened = mpc.open(&sx, OpClass::Other);
        assert_eq!(opened, x);
        assert_eq!(mpc.net.ledger.rounds_total(), 1);
        assert_eq!(mpc.net.ledger.bytes_total(), 2 * 16 * 8);
    }

    #[test]
    fn reshare_hides_and_reconstructs() {
        check("reshare", 30, |g| {
            let mut mpc = mk();
            let n = g.dim(10);
            let x = RingTensor::from_vec(1, n, g.vec_i64(n));
            let sh = mpc.reshare_from(&x, PartyId::P1, OpClass::Other);
            assert_eq!(sh.reconstruct(), x);
        });
    }

    #[test]
    fn scale_fx_matches_plaintext() {
        let mut mpc = mk();
        let x = FloatTensor::from_fn(2, 8, |r, c| (r + c) as f32 * 0.5 - 1.0);
        let sx = mpc.share_local(&enc(&x));
        let out = mpc.scale_fx(&sx, fixed::encode(0.125));
        let got = dec(&out.reconstruct());
        let want = x.map(|v| v * 0.125);
        assert!(got.max_abs_diff(&want) < 1e-3);
    }
}
