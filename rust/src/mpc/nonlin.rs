//! SMPC non-linear operator library — the machinery the **baseline** PPTI
//! frameworks (MPCFormer / PUMA / SecFormer) spend their communication on.
//!
//! Centaur itself never calls these during Transformer layers (it converts
//! to the permuted-plaintext state instead); they exist so the baselines are
//! *operationally real*: every exp/reciprocal/rsqrt/compare below computes
//! correct shares through the primitive protocols and therefore charges the
//! ledger its true communication (DESIGN.md §CostModel).
//!
//! Methods follow CrypTen's approximations:
//! * `exp`: limit approximation `(1 + x/2^8)^{2^8}` — 8 cheap squarings
//!   (8 rounds, 1024 bits/scalar; paper §2.2).
//! * `reciprocal`: Newton–Raphson `y ← y(2 − xy)` with `y₀ = 3e^{0.5−x} + 0.003`.
//! * `inv_sqrt`: NR `y ← y(3 − xy²)/2` with CrypTen's exp-based init.
//! * `ltz` (secure comparison): dealer-assisted ideal functionality charged
//!   at 7 rounds / 384 bits per element (A2B + adder tree, CrypTen-style).

use crate::fixed::encode;
use crate::net::OpClass;
use crate::ring;
use crate::tensor::RingTensor;

use super::{Mpc, Share};

/// Newton iterations for `reciprocal` (CrypTen default is 10).
pub const RECIP_ITERS: usize = 10;
/// Newton iterations for `inv_sqrt` (CrypTen uses 3 on a narrow domain; we
/// use 12 to cover LayerNorm variances in `[1e-4, 100]`, see tests).
pub const RSQRT_ITERS: usize = 12;
/// Squarings in the exp limit approximation (2^8 = 256).
pub const EXP_ITERS: usize = 8;

/// Charged cost of one secure comparison, per element (DESIGN.md §CostModel).
pub const LTZ_ROUNDS: u64 = 7;
/// Charged traffic of one secure comparison, per element (384 bits).
pub const LTZ_BYTES_PER_ELEM: u64 = 48; // 384 bits

// ---------------------------------------------------------------------
// Broadcast / reduction helpers (all local)
// ---------------------------------------------------------------------

/// Expand a `n×1` share column to `n×m` by repetition (local).
pub fn expand_col(s: &Share, m: usize) -> Share {
    let f = |t: &RingTensor| {
        RingTensor::from_fn(t.rows(), m, |r, _| t.get(r, 0))
    };
    Share { s0: f(&s.s0), s1: f(&s.s1) }
}

/// Expand a `1×d` share row to `n×d` by repetition (local).
pub fn expand_row(s: &Share, n: usize) -> Share {
    let f = |t: &RingTensor| RingTensor::from_fn(n, t.cols(), |_, c| t.get(0, c));
    Share { s0: f(&s.s0), s1: f(&s.s1) }
}

/// Row-wise sum → `n×1` (local).
pub fn sum_rows(s: &Share) -> Share {
    let f = |t: &RingTensor| {
        RingTensor::from_fn(t.rows(), 1, |r, _| {
            t.row(r).iter().fold(0i64, |acc, &v| acc.wrapping_add(v))
        })
    };
    Share { s0: f(&s.s0), s1: f(&s.s1) }
}

// ---------------------------------------------------------------------
// Exponential / reciprocal / inverse sqrt
// ---------------------------------------------------------------------

/// SMPC `exp(x)` via the limit approximation (accurate for `x ≤ 0`, the
/// post-max-subtraction softmax domain). 8 rounds, 128 bits/elem/round.
pub fn exp(mpc: &mut Mpc, x: &Share, class: OpClass) -> Share {
    // y = 1 + x / 2^8   (local: public scalar multiply + public add)
    let mut y = mpc.scale_fx(x, encode(1.0 / 256.0));
    let one = RingTensor::from_fn(x.rows(), x.cols(), |_, _| encode(1.0));
    y = mpc.add_plain(&y, &one);
    for _ in 0..EXP_ITERS {
        y = mpc.square(&y, class);
    }
    y
}

/// One Newton refinement shared by [`reciprocal`] (`y ← y(2 − x·y)`) and
/// [`inv_sqrt`] (`y ← y(3 − x·y²)/2`): form `p = x·y` (or `x·y²`), the
/// public-constant complement `c − p` (the caller builds the constant
/// tensor once, outside its iteration loop), and the refined `y·(c − p)`
/// (optionally halved). Every per-iteration opening of both routines —
/// the `square`/`mul_elem` mask differences — flows through this single
/// helper, so a deferred-opening batch (`Mpc::begin_batch`) around a
/// Newton chain wraps them in one place instead of two copies of the
/// loop body.
fn newton_refine(
    mpc: &mut Mpc,
    x: &Share,
    y: &Share,
    c_fx: &RingTensor,
    square_y: bool,
    halve: bool,
    class: OpClass,
) -> Share {
    let p = if square_y {
        let y2 = mpc.square(y, class);
        mpc.mul_elem(x, &y2, class)
    } else {
        mpc.mul_elem(x, y, class)
    };
    let neg_p = Share { s0: ring::neg(&p.s0), s1: ring::neg(&p.s1) };
    let t = mpc.add_plain(&neg_p, c_fx);
    let ty = mpc.mul_elem(y, &t, class);
    if halve {
        mpc.scale_fx(&ty, encode(0.5))
    } else {
        ty
    }
}

/// SMPC reciprocal `1/x` for `x > 0` (softmax denominators, variances).
pub fn reciprocal(mpc: &mut Mpc, x: &Share, class: OpClass) -> Share {
    // y0 = 3·exp(0.5 − x) + 0.003
    let neg_x = Share { s0: ring::neg(&x.s0), s1: ring::neg(&x.s1) };
    let half = RingTensor::from_fn(x.rows(), x.cols(), |_, _| encode(0.5));
    let shifted = mpc.add_plain(&neg_x, &half);
    let e = exp(mpc, &shifted, class);
    let mut y = mpc.scale_fx(&e, encode(3.0));
    let c = RingTensor::from_fn(x.rows(), x.cols(), |_, _| encode(0.003));
    y = mpc.add_plain(&y, &c);
    // Newton: y ← y (2 − x y)
    let two = RingTensor::from_fn(x.rows(), x.cols(), |_, _| encode(2.0));
    for _ in 0..RECIP_ITERS {
        y = newton_refine(mpc, x, &y, &two, false, false, class);
    }
    y
}

/// SMPC `1/sqrt(x)` for `x ∈ [1e-4, 100]` (LayerNorm variances).
pub fn inv_sqrt(mpc: &mut Mpc, x: &Share, class: OpClass) -> Share {
    // y0 = 2.2·exp(−(x/2 + 0.2)) + 0.2 − x/1024  (CrypTen init)
    let neg_half_x = mpc.scale_fx(x, encode(-0.5));
    let c02 = RingTensor::from_fn(x.rows(), x.cols(), |_, _| encode(-0.2));
    let e = exp(mpc, &mpc.add_plain(&neg_half_x, &c02), class);
    let mut y = mpc.scale_fx(&e, encode(2.2));
    let c = RingTensor::from_fn(x.rows(), x.cols(), |_, _| encode(0.2));
    y = mpc.add_plain(&y, &c);
    let corr = mpc.scale_fx(x, encode(-1.0 / 1024.0));
    y = mpc.add(&y, &corr);
    // Newton: y ← y (3 − x y²) / 2
    let three = RingTensor::from_fn(x.rows(), x.cols(), |_, _| encode(3.0));
    for _ in 0..RSQRT_ITERS {
        y = newton_refine(mpc, x, &y, &three, true, true, class);
    }
    y
}

// ---------------------------------------------------------------------
// Secure comparison (charged ideal functionality) and derived ops
// ---------------------------------------------------------------------

/// `ltz(x)` → fixed-point share of the indicator `1{x < 0}`.
///
/// Implemented as a dealer-assisted ideal functionality whose communication
/// is *charged* at the documented CrypTen-style cost (7 rounds, 384
/// bits/element); see DESIGN.md §CostModel for the justification.
pub fn ltz(mpc: &mut Mpc, x: &Share, class: OpClass) -> Share {
    let n = x.s0.len() as u64;
    mpc.net.charge_bytes(class, n * LTZ_BYTES_PER_ELEM);
    mpc.net.round(class, LTZ_ROUNDS);
    let plain = x.reconstruct(); // simulator-internal
    let ind = plain.map(|v| if v < 0 { encode(1.0) } else { 0 });
    // fresh dealer-randomness sharing
    let mut rng = mpc.dealer.fork_rng(0x17Cu64 ^ n);
    let s0 = RingTensor::from_vec(ind.rows(), ind.cols(), rng.vec_i64(ind.len()));
    let s1 = ring::sub(&ind, &s0);
    Share { s0, s1 }
}

/// `select(c, a, b) = b + c·(a − b)` where `c` is a 0/1 fixed-point share.
pub fn select(mpc: &mut Mpc, c: &Share, a: &Share, b: &Share, class: OpClass) -> Share {
    let diff = mpc.sub(a, b);
    let picked = mpc.mul_elem(c, &diff, class);
    mpc.add(b, &picked)
}

/// Elementwise max of two shares: `max(a,b) = select(b−a < 0, a, b)`.
pub fn max_pair(mpc: &mut Mpc, a: &Share, b: &Share, class: OpClass) -> Share {
    let d = mpc.sub(b, a);
    let c = ltz(mpc, &d, class);
    select(mpc, &c, a, b, class)
}

/// Row-wise max over columns → `n×1`, by tournament reduction
/// (⌈log₂ m⌉ compare+select stages, the PUMA/CrypTen softmax-τ pattern).
pub fn max_rows(mpc: &mut Mpc, x: &Share, class: OpClass) -> Share {
    let (_n, m) = x.shape();
    let col = |s: &Share, c: usize| Share {
        s0: s.s0.col_block(c, c + 1),
        s1: s.s1.col_block(c, c + 1),
    };
    let mut cols: Vec<Share> = (0..m).map(|c| col(x, c)).collect();
    while cols.len() > 1 {
        let mut next = Vec::with_capacity(cols.len().div_ceil(2));
        // One tournament stage: all pairs compared in parallel → a single
        // round of ltz cost for the whole stage. We batch them into one
        // concatenated tensor so the charge reflects the parallelism.
        let pairs: Vec<(Share, Share)> = cols
            .chunks(2)
            .filter(|ch| ch.len() == 2)
            .map(|ch| (ch[0].clone(), ch[1].clone()))
            .collect();
        if !pairs.is_empty() {
            let a = Share {
                s0: RingTensor::concat_cols(&pairs.iter().map(|p| p.0.s0.clone()).collect::<Vec<_>>()),
                s1: RingTensor::concat_cols(&pairs.iter().map(|p| p.0.s1.clone()).collect::<Vec<_>>()),
            };
            let b = Share {
                s0: RingTensor::concat_cols(&pairs.iter().map(|p| p.1.s0.clone()).collect::<Vec<_>>()),
                s1: RingTensor::concat_cols(&pairs.iter().map(|p| p.1.s1.clone()).collect::<Vec<_>>()),
            };
            let m = max_pair(mpc, &a, &b, class);
            for (i, _) in pairs.iter().enumerate() {
                next.push(Share {
                    s0: m.s0.col_block(i, i + 1),
                    s1: m.s1.col_block(i, i + 1),
                });
            }
        }
        if cols.len() % 2 == 1 {
            next.push(cols.last().unwrap().clone());
        }
        cols = next;
    }
    cols.pop().unwrap()
}

// ---------------------------------------------------------------------
// Composite layers used by the SMPC baselines
// ---------------------------------------------------------------------

/// Reciprocal of a row-sum with a public `1/m` pre-scale so the Newton
/// iteration stays inside the exp-init's convergence domain even when the
/// sum is large (`recip(x) = (1/m)·recip(x/m)`).
fn reciprocal_scaled(mpc: &mut Mpc, x: &Share, m: f64, class: OpClass) -> Share {
    let scaled = mpc.scale_fx(x, encode(1.0 / m));
    let inv = reciprocal(mpc, &scaled, class);
    mpc.scale_fx(&inv, encode(1.0 / m))
}

/// Accurate SMPC softmax over rows (PUMA-style): max-stabilized exp +
/// reciprocal of the row sum.
pub fn softmax(mpc: &mut Mpc, x: &Share, class: OpClass) -> Share {
    let (_n, m) = x.shape();
    let tau = max_rows(mpc, x, class);
    let tau_b = expand_col(&tau, m);
    let centered = mpc.sub(x, &tau_b);
    let e = exp(mpc, &centered, class);
    let denom = sum_rows(&e);
    // Σexp ∈ [1, m]; scale into the reciprocal's sweet spot.
    let inv = reciprocal_scaled(mpc, &denom, (m as f64 / 8.0).max(1.0), class);
    let inv_b = expand_col(&inv, m);
    mpc.mul_elem(&e, &inv_b, class)
}

/// MPCFormer's `2Quad` softmax substitute: `(x+c)² / Σ(x+c)²` (Eq. 8).
pub fn softmax_2quad(mpc: &mut Mpc, x: &Share, c: f64, class: OpClass) -> Share {
    let (_n, m) = x.shape();
    let cc = RingTensor::from_fn(x.rows(), x.cols(), |_, _| encode(c));
    let shifted = mpc.add_plain(x, &cc);
    let sq = mpc.square(&shifted, class);
    let denom = sum_rows(&sq);
    // Σ(x+c)² ~ m·c²: rescale so exp-init converges (DESIGN.md §CostModel).
    let inv = reciprocal_scaled(mpc, &denom, m as f64 * c * c / 4.0, class);
    let inv_b = expand_col(&inv, m);
    mpc.mul_elem(&sq, &inv_b, class)
}

/// SMPC tanh via `tanh(z) = sign(z)·(1 − 2/(e^{2|z|} + 1))`.
pub fn tanh(mpc: &mut Mpc, x: &Share, class: OpClass) -> Share {
    let neg = ltz(mpc, x, class); // 1{x<0}
    // |x| = x − 2·x·1{x<0}
    let nx = mpc.mul_elem(&neg, x, class);
    let abs = mpc.sub(x, &mpc.scale_fx(&nx, encode(2.0)));
    // e^{-2|x|} ∈ (0,1]; tanh(|x|) = (1 − e^{−2|x|}) / (1 + e^{−2|x|})
    let m2abs = mpc.scale_fx(&abs, encode(-2.0));
    let e = exp(mpc, &m2abs, class);
    let one = RingTensor::from_fn(x.rows(), x.cols(), |_, _| encode(1.0));
    let denom = mpc.add_plain(&e, &one);
    let inv = reciprocal(mpc, &denom, class);
    let neg_e = Share { s0: ring::neg(&e.s0), s1: ring::neg(&e.s1) };
    let num = mpc.add_plain(&neg_e, &one);
    let t_abs = mpc.mul_elem(&num, &inv, class);
    // restore sign: t = t_abs · (1 − 2·1{x<0})
    let sign = {
        let m2 = mpc.scale_fx(&neg, encode(-2.0));
        let one2 = RingTensor::from_fn(x.rows(), x.cols(), |_, _| encode(1.0));
        mpc.add_plain(&m2, &one2)
    };
    mpc.mul_elem(&t_abs, &sign, class)
}

/// Accurate SMPC GeLU (PUMA-style cost structure): the tanh formulation
/// `0.5x(1 + tanh(√(2/π)(x + 0.044715x³)))`.
pub fn gelu(mpc: &mut Mpc, x: &Share, class: OpClass) -> Share {
    let x2 = mpc.square(x, class);
    let x3 = mpc.mul_elem(&x2, x, class);
    let inner = mpc.add(x, &mpc.scale_fx(&x3, encode(0.044715)));
    let scaled = mpc.scale_fx(&inner, encode(0.7978845608028654));
    let t = tanh(mpc, &scaled, class);
    let one = RingTensor::from_fn(x.rows(), x.cols(), |_, _| encode(1.0));
    let g = mpc.add_plain(&t, &one);
    let xg = mpc.mul_elem(x, &g, class);
    mpc.scale_fx(&xg, encode(0.5))
}

/// MPCFormer's `Quad` GeLU substitute: `0.125x² + 0.25x + 0.5`.
pub fn gelu_quad(mpc: &mut Mpc, x: &Share, class: OpClass) -> Share {
    let x2 = mpc.square(x, class);
    let a = mpc.scale_fx(&x2, encode(0.125));
    let b = mpc.scale_fx(x, encode(0.25));
    let half = RingTensor::from_fn(x.rows(), x.cols(), |_, _| encode(0.5));
    mpc.add_plain(&mpc.add(&a, &b), &half)
}

/// SMPC LayerNorm over rows with **shared** affine parameters γ, β (the
/// all-SMPC baselines keep parameters secret-shared).
pub fn layernorm(
    mpc: &mut Mpc,
    x: &Share,
    gamma: &Share, // 1×d
    beta: &Share,  // 1×d
    eps: f64,
    class: OpClass,
) -> Share {
    let (n, d) = x.shape();
    // mean over columns (local)
    let mean = mpc.scale_fx(&sum_rows(x), encode(1.0 / d as f64));
    let centered = mpc.sub(x, &expand_col(&mean, d));
    // variance
    let sq = mpc.square(&centered, class);
    let var = mpc.scale_fx(&sum_rows(&sq), encode(1.0 / d as f64));
    let epsc = RingTensor::from_fn(n, 1, |_, _| encode(eps));
    let var_eps = mpc.add_plain(&var, &epsc);
    let rstd = inv_sqrt(mpc, &var_eps, class);
    let normed = mpc.mul_elem(&centered, &expand_col(&rstd, d), class);
    let scaled = mpc.mul_elem(&normed, &expand_row(gamma, n), class);
    mpc.add(&scaled, &expand_row(beta, n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed;
    use crate::net::{NetSim, NetworkProfile};
    use crate::tensor::FloatTensor;

    fn mk() -> Mpc {
        Mpc::new(NetSim::new(NetworkProfile::lan()), 1234)
    }
    fn enc(t: &FloatTensor) -> RingTensor {
        fixed::encode_tensor(t)
    }
    fn dec(s: &Share) -> FloatTensor {
        fixed::decode_tensor(&s.reconstruct())
    }

    #[test]
    fn exp_accurate_on_negative_domain() {
        let mut mpc = mk();
        let xs = FloatTensor::from_vec(1, 6, vec![0.0, -0.5, -1.0, -2.0, -5.0, -10.0]);
        let sh = mpc.share_local(&enc(&xs));
        let got = dec(&exp(&mut mpc, &sh, OpClass::Softmax));
        for (i, &x) in xs.data().iter().enumerate() {
            let want = (x as f64).exp();
            let err = (got.data()[i] as f64 - want).abs();
            assert!(err < 0.02 * want.max(0.02), "exp({x}) got {} want {want}", got.data()[i]);
        }
    }

    #[test]
    fn reciprocal_accurate() {
        let mut mpc = mk();
        let xs = FloatTensor::from_vec(1, 5, vec![0.5, 1.0, 3.0, 17.0, 96.0]);
        let sh = mpc.share_local(&enc(&xs));
        let got = dec(&reciprocal(&mut mpc, &sh, OpClass::Softmax));
        for (i, &x) in xs.data().iter().enumerate() {
            let want = 1.0 / x as f64;
            let rel = ((got.data()[i] as f64 - want) / want).abs();
            assert!(rel < 0.01, "1/{x}: got {} want {want}", got.data()[i]);
        }
    }

    #[test]
    fn inv_sqrt_accurate_over_layernorm_domain() {
        let mut mpc = mk();
        let xs = FloatTensor::from_vec(1, 6, vec![1e-3, 0.01, 0.25, 1.0, 9.0, 64.0]);
        let sh = mpc.share_local(&enc(&xs));
        let got = dec(&inv_sqrt(&mut mpc, &sh, OpClass::LayerNorm));
        for (i, &x) in xs.data().iter().enumerate() {
            let want = 1.0 / (x as f64).sqrt();
            let rel = ((got.data()[i] as f64 - want) / want).abs();
            assert!(rel < 0.03, "rsqrt({x}): got {} want {want}", got.data()[i]);
        }
    }

    #[test]
    fn ltz_and_select() {
        let mut mpc = mk();
        let xs = FloatTensor::from_vec(1, 4, vec![-2.0, -0.001, 0.0, 3.0]);
        let sh = mpc.share_local(&enc(&xs));
        let c = dec(&ltz(&mut mpc, &sh, OpClass::Other));
        assert_eq!(c.data(), &[1.0, 1.0, 0.0, 0.0]);
        // cost: 7 rounds, 384 bits/elem charged (plus select's mul)
        assert_eq!(mpc.net.ledger.class(OpClass::Other).rounds, 7);
        assert_eq!(mpc.net.ledger.class(OpClass::Other).bytes, 4 * 48);
    }

    #[test]
    fn max_rows_matches_plaintext() {
        let mut mpc = mk();
        let x = FloatTensor::from_vec(2, 5, vec![0.5, -1.0, 2.25, 0.0, 1.0, -3.0, -0.5, -2.0, -0.25, -1.5]);
        let sh = mpc.share_local(&enc(&x));
        let got = dec(&max_rows(&mut mpc, &sh, OpClass::Softmax));
        assert!((got.get(0, 0) - 2.25).abs() < 1e-2);
        assert!((got.get(1, 0) - -0.25).abs() < 1e-2);
    }

    #[test]
    fn softmax_matches_plaintext() {
        let mut mpc = mk();
        let x = FloatTensor::from_vec(2, 4, vec![1.0, 2.0, 0.5, -1.0, 0.0, 0.1, -0.2, 0.3]);
        let sh = mpc.share_local(&enc(&x));
        let got = dec(&softmax(&mut mpc, &sh, OpClass::Softmax));
        for r in 0..2 {
            let row: Vec<f64> = x.row(r).iter().map(|&v| v as f64).collect();
            let m = row.iter().cloned().fold(f64::MIN, f64::max);
            let es: Vec<f64> = row.iter().map(|v| (v - m).exp()).collect();
            let s: f64 = es.iter().sum();
            for c in 0..4 {
                let want = es[c] / s;
                assert!(
                    (got.get(r, c) as f64 - want).abs() < 0.02,
                    "softmax[{r},{c}] got {} want {want}",
                    got.get(r, c)
                );
            }
            let rowsum: f32 = (0..4).map(|c| got.get(r, c)).sum();
            assert!((rowsum - 1.0).abs() < 0.05);
        }
    }

    #[test]
    fn tanh_and_gelu_accurate() {
        let mut mpc = mk();
        let xs = FloatTensor::from_vec(1, 7, vec![-3.0, -1.0, -0.5, 0.0, 0.5, 1.0, 3.0]);
        let sh = mpc.share_local(&enc(&xs));
        let t = dec(&tanh(&mut mpc, &sh, OpClass::Adaptation));
        for (i, &x) in xs.data().iter().enumerate() {
            let want = (x as f64).tanh();
            assert!((t.data()[i] as f64 - want).abs() < 0.02, "tanh({x})={} want {want}", t.data()[i]);
        }
        let g = dec(&gelu(&mut mpc, &sh, OpClass::Gelu));
        for (i, &x) in xs.data().iter().enumerate() {
            let xf = x as f64;
            let want = 0.5 * xf * (1.0 + (0.7978845608 * (xf + 0.044715 * xf.powi(3))).tanh());
            assert!((g.data()[i] as f64 - want).abs() < 0.03, "gelu({x})={} want {want}", g.data()[i]);
        }
    }

    #[test]
    fn quad_substitutes_match_their_formulas() {
        let mut mpc = mk();
        let x = FloatTensor::from_vec(1, 4, vec![-1.0, 0.0, 1.0, 2.0]);
        let sh = mpc.share_local(&enc(&x));
        let q = dec(&gelu_quad(&mut mpc, &sh, OpClass::Gelu));
        for (i, &v) in x.data().iter().enumerate() {
            let want = 0.125 * v * v + 0.25 * v + 0.5;
            assert!((q.data()[i] - want).abs() < 1e-2);
        }
        let sm = dec(&softmax_2quad(&mut mpc, &sh, 5.0, OpClass::Softmax));
        let shifted: Vec<f64> = x.data().iter().map(|&v| ((v + 5.0) as f64).powi(2)).collect();
        let s: f64 = shifted.iter().sum();
        for (i, &v) in shifted.iter().enumerate() {
            assert!((sm.data()[i] as f64 - v / s).abs() < 0.01);
        }
    }

    #[test]
    fn layernorm_matches_plaintext() {
        let mut mpc = mk();
        let d = 8;
        let x = FloatTensor::from_fn(3, d, |r, c| ((r * d + c) as f32 * 0.37).sin());
        let gamma = FloatTensor::from_fn(1, d, |_, c| 1.0 + 0.1 * c as f32);
        let beta = FloatTensor::from_fn(1, d, |_, c| -0.05 * c as f32);
        let sx = mpc.share_local(&enc(&x));
        let sg = mpc.share_local(&enc(&gamma));
        let sb = mpc.share_local(&enc(&beta));
        let got = dec(&layernorm(&mut mpc, &sx, &sg, &sb, 1e-5, OpClass::LayerNorm));
        for r in 0..3 {
            let row: Vec<f64> = x.row(r).iter().map(|&v| v as f64).collect();
            let mean = row.iter().sum::<f64>() / d as f64;
            let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / d as f64;
            for c in 0..d {
                let want = (row[c] - mean) / (var + 1e-5).sqrt() * gamma.get(0, c) as f64
                    + beta.get(0, c) as f64;
                assert!(
                    (got.get(r, c) as f64 - want).abs() < 0.05,
                    "ln[{r},{c}] got {} want {want}",
                    got.get(r, c)
                );
            }
        }
    }
}
