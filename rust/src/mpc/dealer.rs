//! Trusted dealer for correlated randomness (Beaver triples), with an
//! explicit offline/online split.
//!
//! CrypTen's TTP ("trusted first party") provider model: during an offline
//! phase, a dealer generates multiplication triples and distributes shares.
//! Like the paper (and CrypTen's cost reporting), dealer↔party traffic is
//! **not** charged against the online communication ledger; it is tracked
//! separately in [`Dealer::offline_bytes`] so the offline/online split can
//! be reported (EXPERIMENTS.md §Offline-phase reporting).
//!
//! Serving deployments amortize the offline phase across requests through a
//! [`TriplePool`]: a shape-keyed store of pre-generated triples owned by the
//! serving coordinator and shared (via [`Arc`]) across its worker engines.
//! A dealer with an attached pool pops pre-generated triples in O(1) on the
//! request path (a *pool hit*) and only falls back to on-demand generation —
//! a plaintext [`ring::matmul`] per triple, the dominant offline cost —
//! when the pool is dry (a *pool miss*). The pool learns its shape profile
//! from misses and per-session demand registrations, so one cold inference
//! teaches it exactly what a request consumes; the offline phase then runs
//! as a *service* ([`TriplePool::start_service`]): the pool is sharded by
//! shape key across independently locked slots, refill workers partition
//! the slots and stream correlations ahead of demand, and drained misses
//! under live load ratchet the per-shape target up so the service catches
//! up instead of starving (DESIGN.md §Offline phase).
//!
//! Triple generation lowers to [`ring::matmul`], so it rides the same
//! [`RingKernel`](crate::runtime::kernel::RingKernel) dispatch as the
//! online phase — a host with AVX-512/AVX2/NEON refills pools with the
//! SIMD kernel automatically, and the shares it deals are bit-identical
//! to scalar output (wrapping ring addition is order-independent).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::ring;
use crate::tensor::RingTensor;
use crate::util::rng::Rng;

use super::Share;

/// A matrix Beaver triple `C = A·B` in shares.
pub struct MatTriple {
    /// Sharing of the random left factor `A`.
    pub a: Share,
    /// Sharing of the random right factor `B`.
    pub b: Share,
    /// Sharing of the product `C = A·B`.
    pub c: Share,
}

/// A square pair `C = A∘A` in shares (for the cheap square protocol).
pub struct SquarePair {
    /// Sharing of the random mask `A`.
    pub a: Share,
    /// Sharing of the elementwise square `C = A∘A`.
    pub c: Share,
}

/// Which correlated-randomness primitive a pooled entry feeds.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum TripleKind {
    /// Matrix Beaver triple for `Π_MatMul`.
    Matmul,
    /// Elementwise Beaver triple for `Π_Mul`.
    Elem,
    /// Square pair for the cheap `Π_Square`.
    Square,
    /// Fixed-operand correlation: session-fixed RIGHT operand (the `Π_PPP`
    /// π₁ matrix). `(m, k, n)` = per-use left rows × fixed rows × fixed
    /// cols; `uses` per-use bundles are dealt up front.
    FixedPppRight,
    /// Fixed-operand correlation: session-fixed LEFT operand used one
    /// *column per use* (the KV outer-product π₁ᵀ slices). `(m, k, n)` =
    /// fixed rows × fixed cols × per-use right cols.
    FixedAppendLeft,
    /// Fixed-operand correlation for a *write-once row-grown* RIGHT
    /// operand (the secret-shared K cache): `(m, k, n)` = attention heads
    /// × cache rows × cache cols; use `i` multiplies each head's
    /// `(1, n/m)` query block against the transposed written block
    /// `rows 0..=i`.
    FixedScoresGrown,
}

/// Shape key for pooled correlated randomness: the op kind plus the
/// `(m, k, n)` operand shape (`Elem`/`Square` use `(rows, cols, 0)`) and,
/// for the session-scoped fixed-operand families, the dealt use count. A
/// non-zero `layers` marks a *session bundle* key: `layers` per-layer
/// correlations sharing **one** mask (DESIGN.md §Offline phase — the
/// shared π₁ session mask, opened once for the whole session).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct TripleShape {
    /// Primitive this entry feeds.
    pub kind: TripleKind,
    /// Rows of the left operand (kind-specific, see [`TripleKind`]).
    pub m: usize,
    /// Inner dimension (columns for `Elem`/`Square`).
    pub k: usize,
    /// Columns of the right operand (0 for `Elem`/`Square`).
    pub n: usize,
    /// Per-use bundles dealt for a fixed-operand correlation (0 for the
    /// per-use triple kinds). For a session-bundle key this is the use
    /// count of **each** per-layer correlation.
    pub uses: usize,
    /// Per-layer correlations in a shared-mask session bundle (0 for a
    /// plain single-correlation key).
    pub layers: usize,
}

impl TripleShape {
    /// Key for a `Π_MatMul` triple `X (m×k) @ Y (k×n)`.
    pub fn matmul(m: usize, k: usize, n: usize) -> Self {
        TripleShape { kind: TripleKind::Matmul, m, k, n, uses: 0, layers: 0 }
    }
    /// Key for an elementwise triple of shape `rows×cols`.
    pub fn elem(rows: usize, cols: usize) -> Self {
        TripleShape { kind: TripleKind::Elem, m: rows, k: cols, n: 0, uses: 0, layers: 0 }
    }
    /// Key for a square pair of shape `rows×cols`.
    pub fn square(rows: usize, cols: usize) -> Self {
        TripleShape { kind: TripleKind::Square, m: rows, k: cols, n: 0, uses: 0, layers: 0 }
    }
    /// Key for a right-fixed `Π_PPP` correlation: per-use `X (m×n)` against
    /// the session-fixed `π₁ (n×n)`, with `uses` dealt uses.
    pub fn fixed_ppp(m: usize, n: usize, uses: usize) -> Self {
        TripleShape { kind: TripleKind::FixedPppRight, m, k: n, n, uses, layers: 0 }
    }
    /// Key for a left-fixed column-per-use correlation: session-fixed
    /// `π₁ᵀ (n×n)`, use `i` multiplies column `i` by a fresh `(1, d)` row.
    pub fn fixed_append(n: usize, d: usize, uses: usize) -> Self {
        TripleShape { kind: TripleKind::FixedAppendLeft, m: n, k: n, n: d, uses, layers: 0 }
    }
    /// Key for a row-grown score correlation over a `(n, d)` write-once
    /// cache with `h` attention heads.
    pub fn fixed_scores(h: usize, n: usize, d: usize, uses: usize) -> Self {
        TripleShape { kind: TripleKind::FixedScoresGrown, m: h, k: n, n: d, uses, layers: 0 }
    }
    /// Session-bundle key: `layers` [`TripleShape::fixed_ppp`]-style
    /// correlations (each with `uses` uses) sharing **one** π₁ mask, so
    /// the masked opening happens once per session instead of once per
    /// layer.
    pub fn fixed_ppp_session(m: usize, n: usize, uses: usize, layers: usize) -> Self {
        TripleShape { kind: TripleKind::FixedPppRight, m, k: n, n, uses, layers }
    }
    /// Session-bundle key: `layers` [`TripleShape::fixed_append`]-style
    /// correlations sharing one π₁ᵀ mask (one opening per session).
    pub fn fixed_append_session(n: usize, d: usize, uses: usize, layers: usize) -> Self {
        TripleShape { kind: TripleKind::FixedAppendLeft, m: n, k: n, n: d, uses, layers }
    }

    /// Whether this key names a session-scoped fixed-operand correlation.
    pub fn is_fixed(&self) -> bool {
        matches!(
            self.kind,
            TripleKind::FixedPppRight | TripleKind::FixedAppendLeft | TripleKind::FixedScoresGrown
        )
    }

    /// Whether this key names a shared-mask session bundle
    /// ([`TripleShape::fixed_ppp_session`] /
    /// [`TripleShape::fixed_append_session`]).
    pub fn is_session_bundle(&self) -> bool {
        self.layers > 0
    }

    /// Bytes of correlated randomness the dealer distributes for one entry
    /// of this shape (both parties' shares of every tensor). For the
    /// fixed-operand families this covers the whole session bundle — one
    /// mask plus `uses` per-use correlations per layer (a shared-mask
    /// bundle distributes the mask sharing **once** for all its layers) —
    /// and is charged **once** per session, never once per use (the
    /// session-amortized mask must not be double-counted per take).
    pub fn offline_bytes(&self) -> u64 {
        let l = self.layers.max(1) as u64;
        match self.kind {
            TripleKind::Matmul => 8 * 2 * (self.m * self.k + self.k * self.n + self.m * self.n) as u64,
            TripleKind::Elem => 8 * 2 * 3 * (self.m * self.k) as u64,
            TripleKind::Square => 8 * 2 * 2 * (self.m * self.k) as u64,
            // mask (k×n) + layers × uses × (A (m×k) + C (m×n))
            TripleKind::FixedPppRight => {
                8 * 2
                    * ((self.k * self.n) as u64
                        + l * (self.uses * (self.m * self.k + self.m * self.n)) as u64)
            }
            // mask (m×k) + layers × uses × (A (1×n) + C (m×n))
            TripleKind::FixedAppendLeft => {
                8 * 2
                    * ((self.m * self.k) as u64 + l * (self.uses * (self.n + self.m * self.n)) as u64)
            }
            // mask (k×n) + Σ_{i<uses} m × (A (1×n/m) + C (1×(i+1)))
            TripleKind::FixedScoresGrown => {
                8 * 2
                    * (self.k * self.n
                        + self.uses * self.n
                        + self.m * self.uses * (self.uses + 1) / 2) as u64
            }
        }
    }
}

/// One dealt use of a fixed-operand correlation: for each varying-operand
/// block (one per attention head for [`TripleKind::FixedScoresGrown`],
/// exactly one otherwise), a fresh mask sharing `[A]` and the correlation
/// `[C]` against the session mask `B` (`C = A·B`, `B_col·A`, or
/// `A·B_blockᵀ` depending on the family).
#[derive(Clone)]
pub struct FixedUse {
    /// `([A], [C])` per varying-operand block.
    pub blocks: Vec<(Share, Share)>,
}

/// Session-scoped correlated randomness for `Π_MatMul` against an operand
/// that is fixed (or write-once) for a whole decode session — the paper's
/// structure-aware specialization applied to the offline phase: instead of
/// a fresh [`MatTriple`] (and a fresh masked opening of the fixed operand)
/// per matmul, the dealer emits **one mask `[B]`** whose masked opening
/// happens once per session, plus a cheap per-use correlation. Per use the
/// parties then open only the *varying* operand's mask difference.
pub struct FixedOperandCorrelation {
    /// The shape key this correlation was dealt for.
    pub shape: TripleShape,
    /// Sharing of the session mask `B` over the fixed operand.
    pub mask: Share,
    /// Pre-dealt per-use bundles, consumed strictly in order.
    uses: VecDeque<FixedUse>,
    /// Consumed bundles, retained in consumption order so speculative
    /// rollback can restore them ([`FixedOperandCorrelation::rewind_uses_to`]):
    /// a rolled-back use must come back as the *same* bundle, or the
    /// re-verified position would silently switch masks and break the
    /// share-for-share rollback identity the tests pin.
    consumed: Vec<FixedUse>,
    /// Bundles dealt in total (for exhaustion diagnostics).
    dealt: usize,
    /// Uses consumed so far (use index of the next [`FixedUse`]).
    used: usize,
    /// Masked openings of the fixed operand so far: 1 after the one-time
    /// opening for the fixed families; rows opened so far for the
    /// row-grown family.
    pub(crate) opened: u64,
}

impl FixedOperandCorrelation {
    /// Consume the next per-use bundle, returning its 0-based use index.
    /// Errors — rather than silently reusing a mask — once the dealt use
    /// count is exhausted.
    pub fn take_use(&mut self) -> crate::Result<(usize, FixedUse)> {
        let Some(u) = self.uses.pop_front() else {
            anyhow::bail!(
                "fixed-operand correlation exhausted after {} dealt uses — refusing to reuse a mask",
                self.dealt
            );
        };
        let idx = self.used;
        self.used += 1;
        self.consumed.push(u.clone());
        Ok((idx, u))
    }

    /// Rewind the use counter to `target_used`, restoring the consumed
    /// bundles in order so the next [`FixedOperandCorrelation::take_use`]
    /// returns exactly the bundle that use index was originally dealt.
    ///
    /// Speculative decode calls this when rejected draft positions are
    /// rolled back: the position-keyed families (`FixedAppendLeft`,
    /// `FixedScoresGrown`) *must* rewind or the next append would find its
    /// use index ahead of its position, and rewinding all families keeps
    /// `uses_left` equal to a session that never ran the rejected lanes.
    /// Reusing a restored mask for the corrected row reveals only the
    /// masked *difference* of the two candidate rows — see DESIGN.md
    /// §Speculative decode for why that stays inside the π-permuted
    /// protection class.
    pub fn rewind_uses_to(&mut self, target_used: usize) -> crate::Result<()> {
        anyhow::ensure!(
            target_used <= self.used,
            "cannot rewind forward: {} uses consumed, target {target_used}",
            self.used
        );
        while self.used > target_used {
            let u = self.consumed.pop().expect("one retained bundle per consumed use");
            self.uses.push_front(u);
            self.used -= 1;
        }
        Ok(())
    }

    /// Rewind the masked-opening counter (row-grown family only): after a
    /// rollback to `rows` written rows, the next
    /// [`super::Mpc::open_fixed_grown_row`] re-opens row `rows`.
    pub fn rewind_opened_to(&mut self, rows: u64) -> crate::Result<()> {
        anyhow::ensure!(
            rows <= self.opened,
            "cannot rewind openings forward: {} opened, target {rows}",
            self.opened
        );
        self.opened = rows;
        Ok(())
    }

    /// Per-use bundles still available.
    pub fn uses_left(&self) -> usize {
        self.uses.len()
    }

    /// Per-use bundles dealt in total.
    pub fn dealt(&self) -> usize {
        self.dealt
    }

    /// Masked openings of the fixed operand so far (security census: the
    /// fixed families must report exactly 1 per session; the row-grown
    /// family reports the number of written rows).
    pub fn openings(&self) -> u64 {
        self.opened
    }

    /// Adopt the session's shared-mask opening: in a shared-π₁ session
    /// bundle every per-layer correlation holds the **same** mask sharing
    /// `[B]`, so the masked difference `fixed − B` is opened on the wire
    /// once (for the first layer) and the remaining layers adopt that
    /// public value without a second transfer. This marks the correlation
    /// opened so the per-layer security census still reports exactly one
    /// opening per session per layer, and so a second (real) opening is
    /// rejected exactly as it is after [`super::Mpc::open_fixed_operand`].
    pub fn adopt_shared_opening(&mut self) -> crate::Result<()> {
        anyhow::ensure!(
            matches!(self.shape.kind, TripleKind::FixedPppRight | TripleKind::FixedAppendLeft),
            "adopt_shared_opening is for the open-once fixed families, got {:?}",
            self.shape.kind
        );
        anyhow::ensure!(
            self.opened == 0,
            "fixed operand already opened for this correlation — refusing a second opening"
        );
        self.opened = 1;
        Ok(())
    }
}

/// One pooled entry (kind matches the [`TripleShape`] it is stored under).
pub enum PoolItem {
    /// A matrix or elementwise Beaver triple.
    Mat(MatTriple),
    /// A square pair.
    Square(SquarePair),
    /// A session-scoped fixed-operand correlation bundle.
    Fixed(FixedOperandCorrelation),
    /// A shared-mask session bundle: one per-layer correlation per entry,
    /// all holding the **same** mask sharing (stored under a
    /// [`TripleShape`] with `layers > 0`).
    FixedSession(Vec<FixedOperandCorrelation>),
}

// ---------------------------------------------------------------------
// Pool MACs (DESIGN.md §Integrity-checked inference): under audit mode
// the dealer authenticates every pooled item at generation time with a
// keyed digest over its entire share state; `take` re-verifies, so an
// item corrupted while it sat in the pool is quarantined *before* the
// consuming open ever sees it — and counted, so the session's next
// `Mpc::flush_mac_checks` rejects. On-demand (cold-fallback) generation
// stays unauthenticated: it happens in-process at the consuming call
// site, so there is no storage window to protect.
// ---------------------------------------------------------------------

fn tag_fold_tensor(mut h: u64, t: &RingTensor) -> u64 {
    h = crate::net::fnv1a_fold(h, &[t.rows() as u64, t.cols() as u64]);
    for &v in t.data() {
        h = crate::net::fnv1a_fold(h, &[v as u64]);
    }
    h
}

fn tag_fold_share(h: u64, s: &Share) -> u64 {
    tag_fold_tensor(tag_fold_tensor(h, &s.s0), &s.s1)
}

fn tag_fold_fixed(mut h: u64, c: &FixedOperandCorrelation) -> u64 {
    h = tag_fold_share(h, &c.mask);
    for fu in &c.uses {
        for (a, cc) in &fu.blocks {
            h = tag_fold_share(h, a);
            h = tag_fold_share(h, cc);
        }
    }
    h
}

/// Keyed MAC tag over a pooled item's entire share state (every tensor of
/// every share, shapes included). With an odd `key` folded in at both
/// ends, any single-bit corruption of any stored word changes the tag.
fn item_tag(key: u64, item: &PoolItem) -> u64 {
    let mut h = crate::net::fnv1a_fold(crate::net::FNV_OFFSET, &[key]);
    match item {
        PoolItem::Mat(t) => {
            h = tag_fold_share(h, &t.a);
            h = tag_fold_share(h, &t.b);
            h = tag_fold_share(h, &t.c);
        }
        PoolItem::Square(p) => {
            h = tag_fold_share(h, &p.a);
            h = tag_fold_share(h, &p.c);
        }
        PoolItem::Fixed(c) => h = tag_fold_fixed(h, c),
        PoolItem::FixedSession(cs) => {
            for c in cs {
                h = tag_fold_fixed(h, c);
            }
        }
    }
    h.wrapping_mul(key | 1)
}

/// A pooled item plus the MAC tag it was stocked with (0 when the pool's
/// MAC key was unset at push time).
struct PoolEntry {
    item: PoolItem,
    tag: u64,
}

// ---------------------------------------------------------------------
// Generation (shared by the on-demand dealer path and the pool)
// ---------------------------------------------------------------------

fn rand_tensor(rng: &mut Rng, rows: usize, cols: usize) -> RingTensor {
    RingTensor::from_vec(rows, cols, rng.vec_i64(rows * cols))
}

/// Transposed per-head block of a `(rows, heads·dh)` tensor: columns
/// `head·dh..(head+1)·dh` of rows `0..written`, transposed to
/// `(dh, written)`. The dealer's `C = A·B_blockᵀ` layout and the online
/// score protocol must agree element-for-element, so both sides build
/// their blocks through this one helper.
pub(crate) fn head_block_t(t: &RingTensor, head: usize, dh: usize, written: usize) -> RingTensor {
    RingTensor::from_fn(dh, written, |r, c| t.get(c, head * dh + r))
}

fn share_with(rng: &mut Rng, x: RingTensor) -> Share {
    let s0 = RingTensor::from_vec(x.rows(), x.cols(), rng.vec_i64(x.len()));
    let s1 = ring::sub(&x, &s0);
    Share { s0, s1 }
}

fn generate_item(rng: &mut Rng, shape: TripleShape) -> PoolItem {
    match shape.kind {
        TripleKind::Matmul => {
            let a = rand_tensor(rng, shape.m, shape.k);
            let b = rand_tensor(rng, shape.k, shape.n);
            let c = ring::matmul(&a, &b);
            PoolItem::Mat(MatTriple {
                a: share_with(rng, a),
                b: share_with(rng, b),
                c: share_with(rng, c),
            })
        }
        TripleKind::Elem => {
            let a = rand_tensor(rng, shape.m, shape.k);
            let b = rand_tensor(rng, shape.m, shape.k);
            let c = ring::mul_elem(&a, &b);
            PoolItem::Mat(MatTriple {
                a: share_with(rng, a),
                b: share_with(rng, b),
                c: share_with(rng, c),
            })
        }
        TripleKind::Square => {
            let a = rand_tensor(rng, shape.m, shape.k);
            let c = ring::mul_elem(&a, &a);
            PoolItem::Square(SquarePair { a: share_with(rng, a), c: share_with(rng, c) })
        }
        TripleKind::FixedPppRight | TripleKind::FixedAppendLeft | TripleKind::FixedScoresGrown => {
            if shape.is_session_bundle() {
                PoolItem::FixedSession(generate_fixed_session(rng, shape))
            } else {
                PoolItem::Fixed(generate_fixed(rng, shape))
            }
        }
    }
}

/// Dimensions of the fixed-operand mask for a fixed-family shape.
fn fixed_mask_dims(shape: &TripleShape) -> (usize, usize) {
    match shape.kind {
        TripleKind::FixedPppRight | TripleKind::FixedScoresGrown => (shape.k, shape.n),
        TripleKind::FixedAppendLeft => (shape.m, shape.k),
        _ => unreachable!("fixed_mask_dims called for a per-use triple kind"),
    }
}

/// Deal `shape.uses` per-use `([A], [C])` correlations against the fixed
/// mask `b` (known to the dealer in plaintext, exactly as it knows `A·B`
/// for a plain Beaver triple).
fn deal_fixed_uses(rng: &mut Rng, shape: &TripleShape, b: &RingTensor) -> VecDeque<FixedUse> {
    let mut uses = VecDeque::with_capacity(shape.uses);
    match shape.kind {
        TripleKind::FixedPppRight => {
            // fixed right operand (k×n); per-use left X (m×k), C = A·B.
            for _ in 0..shape.uses {
                let a = rand_tensor(rng, shape.m, shape.k);
                let c = ring::matmul(&a, b);
                uses.push_back(FixedUse {
                    blocks: vec![(share_with(rng, a), share_with(rng, c))],
                });
            }
        }
        TripleKind::FixedAppendLeft => {
            // fixed left operand (m×k), one column per use; per-use right
            // Y (1×n), C = B[:,i]·A.
            for i in 0..shape.uses {
                let a = rand_tensor(rng, 1, shape.n);
                let c = ring::matmul(&b.col_block(i, i + 1), &a);
                uses.push_back(FixedUse {
                    blocks: vec![(share_with(rng, a), share_with(rng, c))],
                });
            }
        }
        TripleKind::FixedScoresGrown => {
            // write-once right operand (k×n) with m head blocks of width
            // n/m; use i deals, per head, A (1×dh) and C = A·B_blockᵀ over
            // the written rows 0..=i.
            let (heads, cols) = (shape.m, shape.n);
            let dh = cols / heads;
            for i in 0..shape.uses {
                let written = i + 1;
                let mut blocks = Vec::with_capacity(heads);
                for h in 0..heads {
                    let a = rand_tensor(rng, 1, dh);
                    let bt = head_block_t(b, h, dh, written);
                    let c = ring::matmul(&a, &bt);
                    blocks.push((share_with(rng, a), share_with(rng, c)));
                }
                uses.push_back(FixedUse { blocks });
            }
        }
        _ => unreachable!("deal_fixed_uses called for a per-use triple kind"),
    }
    uses
}

/// Generate a whole fixed-operand session bundle: the session mask `B`
/// plus `shape.uses` per-use `([A], [C])` correlations (the dealer knows
/// `B` in plaintext while dealing, exactly as it knows `A·B` for a plain
/// Beaver triple).
fn generate_fixed(rng: &mut Rng, shape: TripleShape) -> FixedOperandCorrelation {
    debug_assert!(!shape.is_session_bundle(), "session bundles go through generate_fixed_session");
    let (rows, cols) = fixed_mask_dims(&shape);
    let b = rand_tensor(rng, rows, cols);
    let uses = deal_fixed_uses(rng, &shape, &b);
    FixedOperandCorrelation {
        shape,
        mask: share_with(rng, b),
        uses,
        consumed: Vec::new(),
        dealt: shape.uses,
        used: 0,
        opened: 0,
    }
}

/// Generate a shared-mask session bundle: ONE mask `B` (and one sharing of
/// it) serving `shape.layers` per-layer correlations, each with its own
/// `shape.uses` fresh per-use bundles dealt against that same `B`. Every
/// per-layer correlation carries the *per-layer* key (`layers = 0`) so all
/// downstream per-use machinery — openings, rewind, the security census —
/// is oblivious to how the mask was amortized.
fn generate_fixed_session(rng: &mut Rng, shape: TripleShape) -> Vec<FixedOperandCorrelation> {
    debug_assert!(shape.is_session_bundle(), "per-layer shapes go through generate_fixed");
    let per_layer = TripleShape { layers: 0, ..shape };
    let (rows, cols) = fixed_mask_dims(&shape);
    let b = rand_tensor(rng, rows, cols);
    let mask = share_with(rng, b.clone());
    (0..shape.layers)
        .map(|_| FixedOperandCorrelation {
            shape: per_layer,
            mask: mask.clone(),
            uses: deal_fixed_uses(rng, &per_layer, &b),
            consumed: Vec::new(),
            dealt: per_layer.uses,
            used: 0,
            opened: 0,
        })
        .collect()
}

// ---------------------------------------------------------------------
// TriplePool
// ---------------------------------------------------------------------

#[derive(Default)]
struct ShapeQueue {
    q: VecDeque<PoolEntry>,
    /// Misses recorded *before this shape was ever stocked* plus demand
    /// registered by sessions up front — after one cold inference (or one
    /// `register_demand` pass) this is exactly the per-request demand,
    /// which sizes the refill target together with `surge`.
    demand: u64,
    /// Load-adaptive ratchet: drained misses while registered demand is
    /// live (the pool stocked this shape, sessions still want it, and the
    /// service fell behind) raise the target by one request-equivalent
    /// each, so the service catches up instead of starving forever at the
    /// cold-start target. Retired (reset to zero) when the last registered
    /// session releases its demand, so dead shapes are not restocked.
    surge: u64,
    /// Entries ever pushed for this shape (gates demand learning).
    stocked: u64,
}

/// One independently locked slot of the sharded pool: a shape→queue map
/// plus a shard-local dealer PRG (forked per generated item, so any shard
/// can deterministically generate any shape without a global lock).
struct ShardInner {
    shapes: HashMap<TripleShape, ShapeQueue>,
    rng: Rng,
}

/// Shard slots in the pool. Shapes hash to a fixed slot, so an online
/// `take` of one shape class never contends with generation (or takes) of
/// another; the offline service partitions slots across its workers.
const POOL_SHARDS: usize = 8;

/// Shape-keyed store of pre-generated correlated randomness, shared across
/// a coordinator's worker engines (offline-phase amortization).
///
/// Sharded: shapes hash (FNV-1a over the shape key) to one of
/// [`POOL_SHARDS`] independently locked slots, so an online `take` only
/// ever contends with activity on its own shape class — never with
/// generation or takes elsewhere. Generation always happens *outside* the
/// shard lock (the lock covers a pop/push plus counters), so workers are
/// never blocked behind a plaintext matmul.
pub struct TriplePool {
    shards: Vec<Mutex<ShardInner>>,
    hits: AtomicU64,
    misses: AtomicU64,
    /// Misses on shapes the offline phase knew about (stocked before, or
    /// registered demand outstanding): the online path had to generate
    /// on demand because the service fell behind. The serve-bench gate
    /// asserts this stays zero during warm decode.
    starved: AtomicU64,
    /// Entries ever generated into the pool (offline-throughput metric;
    /// also the per-item PRG fork tag).
    generated: AtomicU64,
    /// Bytes of correlated randomness generated into the pool.
    offline_bytes: AtomicU64,
    /// Refill target per shape, in units of observed per-request demand.
    depth: usize,
    /// Hard cap on pooled entries per shape (memory guard).
    max_per_shape: usize,
    /// MAC key authenticating pooled items (0 = MACs off). Set **before**
    /// the pool is stocked ([`TriplePool::enable_mac`]): entries pushed
    /// while the key was unset carry tag 0 and are rejected fail-closed
    /// once verification is on.
    mac_key: AtomicU64,
    /// Pooled items rejected at [`TriplePool::take`] because their stored
    /// state no longer matches their MAC tag.
    mac_rejected: AtomicU64,
}

/// Point-in-time statistics of a [`TriplePool`] (one lock round-trip over
/// the shards; feeds the serving metrics snapshot).
#[derive(Clone, Debug, Default)]
pub struct PoolStats {
    /// Takes served from pre-generated randomness.
    pub hits: u64,
    /// Takes that fell back to on-demand generation.
    pub misses: u64,
    /// Misses on shapes the offline phase knew about (see
    /// [`TriplePool::starvation_events`]).
    pub starved: u64,
    /// Entries ever generated into the pool.
    pub generated: u64,
    /// Bytes of correlated randomness generated into the pool.
    pub offline_bytes: u64,
    /// Entries currently pooled across all shapes.
    pub pooled: u64,
    /// Distinct shapes the pool has learned.
    pub shapes: u64,
    /// Entries currently pooled per shard slot (length
    /// [`TriplePool::shard_count`]).
    pub shard_depths: Vec<usize>,
    /// Pooled items rejected at take for a MAC mismatch (audit mode).
    pub mac_rejected: u64,
}

impl TriplePool {
    /// Pool keeping `depth` requests' worth of triples per shape.
    pub fn new(seed: u64, depth: usize) -> Self {
        let shards = (0..POOL_SHARDS)
            .map(|i| {
                Mutex::new(ShardInner {
                    shapes: HashMap::new(),
                    // domain-separate from per-engine dealers AND per shard
                    rng: Rng::new(seed ^ 0xB34B3A ^ ((i as u64) << 48)),
                })
            })
            .collect();
        TriplePool {
            shards,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            starved: AtomicU64::new(0),
            generated: AtomicU64::new(0),
            offline_bytes: AtomicU64::new(0),
            depth: depth.max(1),
            max_per_shape: 256,
            mac_key: AtomicU64::new(0),
            mac_rejected: AtomicU64::new(0),
        }
    }

    /// Switch on pool-item MACs with `key` (forced odd, so it is never
    /// mistaken for the off state and any single-bit corruption changes
    /// the keyed tag). Call before stocking: entries already pooled carry
    /// no tag and will be rejected fail-closed.
    pub fn enable_mac(&self, key: u64) {
        self.mac_key.store(key | 1, Ordering::Relaxed);
    }

    /// Whether pool-item MACs are on.
    pub fn mac_enabled(&self) -> bool {
        self.mac_key.load(Ordering::Relaxed) != 0
    }

    /// Pooled items rejected at [`TriplePool::take`] for a MAC mismatch.
    pub fn mac_rejected(&self) -> u64 {
        self.mac_rejected.load(Ordering::Relaxed)
    }

    /// Tamper-injection hook: flip one bit of one stored word of the next
    /// pooled entry for `shape` (after its tag was computed, emulating
    /// corruption while the item sat in the pool). Returns false when
    /// nothing is pooled for `shape`.
    pub fn tamper_one(&self, shape: TripleShape) -> bool {
        let mut inner = self.shards[self.shard_of(&shape)].lock().unwrap();
        let Some(sq) = inner.shapes.get_mut(&shape) else { return false };
        let Some(entry) = sq.q.front_mut() else { return false };
        let t = match &mut entry.item {
            PoolItem::Mat(t) => &mut t.a.s0,
            PoolItem::Square(p) => &mut p.a.s0,
            PoolItem::Fixed(c) => &mut c.mask.s0,
            PoolItem::FixedSession(cs) => match cs.first_mut() {
                Some(c) => &mut c.mask.s0,
                None => return false,
            },
        };
        if t.len() == 0 {
            return false;
        }
        t.data_mut()[0] ^= 1;
        true
    }

    /// Deterministic shard slot for a shape (FNV-1a over the key fields —
    /// the std `HashMap` hasher is randomized per process, which would make
    /// shard layout, and thus per-shard PRG streams, nondeterministic).
    fn shard_of(&self, shape: &TripleShape) -> usize {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for v in [
            shape.kind as u64,
            shape.m as u64,
            shape.k as u64,
            shape.n as u64,
            shape.uses as u64,
            shape.layers as u64,
        ] {
            h = (h ^ v).wrapping_mul(0x1000_0000_01b3);
        }
        (h % self.shards.len() as u64) as usize
    }

    fn target(&self, sq: &ShapeQueue) -> usize {
        (((sq.demand + sq.surge) as usize) * self.depth).min(self.max_per_shape)
    }

    /// Pop a pre-generated entry for `shape`, recording a hit or a miss.
    /// A miss before the shape was ever stocked also registers demand, so
    /// one cold inference teaches refill the per-request profile; a miss
    /// on a *drained* shape with live registered demand raises the surge
    /// target instead (load-adaptive: the cold-start target was too small
    /// for the concurrent-session load, so the service must stock more).
    /// Either way a miss on a shape the offline phase knew about counts as
    /// a starvation event.
    pub fn take(&self, shape: TripleShape) -> Option<PoolItem> {
        let key = self.mac_key.load(Ordering::Relaxed);
        let mut inner = self.shards[self.shard_of(&shape)].lock().unwrap();
        let sq = inner.shapes.entry(shape).or_default();
        loop {
            match sq.q.pop_front() {
                Some(entry) => {
                    if key != 0 && entry.tag != item_tag(key, &entry.item) {
                        // Quarantine: never hand a corrupted item to an
                        // open. The counter makes the consuming session's
                        // next MAC flush reject.
                        self.mac_rejected.fetch_add(1, Ordering::Relaxed);
                        continue;
                    }
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return Some(entry.item);
                }
                None => {
                    if sq.stocked > 0 || sq.demand > 0 {
                        self.starved.fetch_add(1, Ordering::Relaxed);
                    }
                    if sq.stocked == 0 {
                        sq.demand += 1;
                    } else if sq.demand > 0 {
                        sq.surge += 1;
                    }
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    return None;
                }
            }
        }
    }

    /// Pre-register per-request demand for `shape` without a cold miss:
    /// the refill machinery treats the accumulated count as one request's
    /// consumption, exactly as if a probe run had missed `count` times.
    /// Serving uses this to stock incremental-decode triple shapes (which a
    /// full-inference probe never touches) before the first generation
    /// request arrives — see `protocols::layer::decode_step_shapes`.
    pub fn register_demand(&self, shape: TripleShape, count: u64) {
        let mut inner = self.shards[self.shard_of(&shape)].lock().unwrap();
        let sq = inner.shapes.entry(shape).or_default();
        sq.demand += count;
    }

    /// Release previously registered demand on session teardown: a stream
    /// that ends early (client dropped, EOS before the step budget) gives
    /// back the per-step demand it will never consume, so the refill
    /// service stops overstocking dead shapes. Saturating — releasing more
    /// than was registered clamps the shape's demand at zero rather than
    /// underflowing — and releases of never-stocked, never-registered
    /// shapes are pure no-ops (no phantom map entry is created). When the
    /// last registered demand drains, the load-adaptive surge retires with
    /// it: a dead shape must not keep a ratcheted target alive.
    pub fn release_demand(&self, shape: TripleShape, count: u64) {
        let mut inner = self.shards[self.shard_of(&shape)].lock().unwrap();
        if let Some(sq) = inner.shapes.get_mut(&shape) {
            sq.demand = sq.demand.saturating_sub(count);
            if sq.demand == 0 {
                sq.surge = 0;
            }
        }
    }

    /// Outstanding registered demand for `shape` (0 for unknown shapes;
    /// no map entry is created by asking). The speculative rollback tests
    /// assert this balances to zero after session eviction releases the
    /// per-lane demand it registered.
    pub fn demand_for(&self, shape: TripleShape) -> u64 {
        let inner = self.shards[self.shard_of(&shape)].lock().unwrap();
        inner.shapes.get(&shape).map_or(0, |sq| sq.demand)
    }

    /// Refill target for `shape` right now: `(demand + surge) × depth`,
    /// capped by the per-shape memory guard (diagnostics / tests).
    pub fn target_for(&self, shape: TripleShape) -> usize {
        let inner = self.shards[self.shard_of(&shape)].lock().unwrap();
        inner.shapes.get(&shape).map_or(0, |sq| self.target(sq))
    }

    /// Push one freshly generated batch for `shape` into its shard,
    /// respecting the per-shape cap. Returns entries actually stocked.
    fn push_generated(&self, shard: usize, shape: TripleShape, items: Vec<PoolItem>) -> u64 {
        // Tag outside the shard lock: the MAC walks the item's whole
        // share state, and generation is already lock-free by design.
        let key = self.mac_key.load(Ordering::Relaxed);
        let entries: Vec<PoolEntry> = items
            .into_iter()
            .map(|item| {
                let tag = if key != 0 { item_tag(key, &item) } else { 0 };
                PoolEntry { item, tag }
            })
            .collect();
        let mut pushed = 0u64;
        {
            let mut inner = self.shards[shard].lock().unwrap();
            let sq = inner.shapes.entry(shape).or_default();
            for entry in entries {
                if sq.q.len() >= self.max_per_shape {
                    break;
                }
                sq.stocked += 1;
                sq.q.push_back(entry);
                pushed += 1;
            }
        }
        self.offline_bytes.fetch_add(pushed * shape.offline_bytes(), Ordering::Relaxed);
        pushed
    }

    /// Generate one entry for the globally most depleted known shape
    /// (outside any lock). Returns `false` when every shape is at target.
    /// Kept as the single-step refill primitive; the offline service and
    /// prefill use the batched [`TriplePool::refill_shard`] instead.
    pub fn refill_once(&self) -> bool {
        let mut best: Option<(usize, usize, TripleShape)> = None; // (q.len, shard, shape)
        for (si, shard) in self.shards.iter().enumerate() {
            let inner = shard.lock().unwrap();
            for (s, sq) in &inner.shapes {
                let more_depleted = match best {
                    Some((len, _, _)) => sq.q.len() < len,
                    None => true,
                };
                if sq.q.len() < self.target(sq) && more_depleted {
                    best = Some((sq.q.len(), si, *s));
                }
            }
        }
        let Some((_, si, shape)) = best else { return false };
        let mut rng = {
            let tag = self.generated.fetch_add(1, Ordering::Relaxed);
            self.shards[si].lock().unwrap().rng.fork(0xF111 ^ tag)
        };
        let item = generate_item(&mut rng, shape);
        self.push_generated(si, shape, vec![item]) == 1
    }

    /// Batched refill of one shard: pick its most depleted shape, then
    /// generate the **entire** deficit for that shape outside the lock
    /// (one lock to pick + fork PRGs, one lock to push the batch) instead
    /// of re-scanning every shape under the lock per single triple.
    /// Returns entries stocked (0 = this shard is at target).
    pub fn refill_shard(&self, shard: usize) -> u64 {
        let (shape, rngs) = {
            let mut inner = self.shards[shard].lock().unwrap();
            let pick = inner
                .shapes
                .iter()
                .filter(|(_, sq)| sq.q.len() < self.target(sq))
                .min_by_key(|(_, sq)| sq.q.len())
                .map(|(s, sq)| (*s, self.target(sq) - sq.q.len()));
            let Some((shape, deficit)) = pick else { return 0 };
            let rngs: Vec<Rng> = (0..deficit)
                .map(|_| {
                    let tag = self.generated.fetch_add(1, Ordering::Relaxed);
                    inner.rng.fork(0xF111 ^ tag)
                })
                .collect();
            (shape, rngs)
        };
        let items: Vec<PoolItem> =
            rngs.into_iter().map(|mut rng| generate_item(&mut rng, shape)).collect();
        self.push_generated(shard, shape, items)
    }

    /// Synchronously top up every known shape to target (server-start
    /// prefill), one batched shard pass at a time. Returns the number of
    /// entries generated.
    pub fn fill_to_target(&self) -> u64 {
        let mut n = 0;
        loop {
            let mut round = 0;
            for si in 0..self.shards.len() {
                round += self.refill_shard(si);
            }
            if round == 0 {
                return n;
            }
            n += round;
        }
    }

    /// Pool hits so far (requests served from pre-generated randomness).
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Pool misses so far (on-demand generation on the request path).
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Misses on shapes the offline phase knew about — the online path had
    /// to generate on demand because the service fell behind (cold probe
    /// misses on never-seen shapes don't count).
    pub fn starvation_events(&self) -> u64 {
        self.starved.load(Ordering::Relaxed)
    }

    /// Entries ever generated into the pool (offline-throughput metric).
    pub fn generated_total(&self) -> u64 {
        self.generated.load(Ordering::Relaxed)
    }

    /// Fraction of takes served from the pool (0 when nothing was taken).
    pub fn hit_rate(&self) -> f64 {
        let h = self.hits() as f64;
        let m = self.misses() as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }

    /// Total entries currently pooled across all shapes.
    pub fn pooled_total(&self) -> usize {
        self.shard_depths().into_iter().sum()
    }

    /// Number of distinct shapes the pool has learned.
    pub fn shapes_known(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().shapes.len()).sum()
    }

    /// Number of independently locked shard slots.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Entries currently pooled per shard slot.
    pub fn shard_depths(&self) -> Vec<usize> {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().shapes.values().map(|sq| sq.q.len()).sum())
            .collect()
    }

    /// Bytes of correlated randomness generated into the pool (offline
    /// traffic, reported separately from the online ledger).
    pub fn offline_bytes(&self) -> u64 {
        self.offline_bytes.load(Ordering::Relaxed)
    }

    /// Point-in-time statistics (counters plus one pass over the shards).
    pub fn stats(&self) -> PoolStats {
        let mut pooled = 0u64;
        let mut shapes = 0u64;
        let mut shard_depths = Vec::with_capacity(self.shards.len());
        for s in &self.shards {
            let inner = s.lock().unwrap();
            let depth: usize = inner.shapes.values().map(|sq| sq.q.len()).sum();
            pooled += depth as u64;
            shapes += inner.shapes.len() as u64;
            shard_depths.push(depth);
        }
        PoolStats {
            hits: self.hits(),
            misses: self.misses(),
            starved: self.starvation_events(),
            generated: self.generated_total(),
            offline_bytes: self.offline_bytes(),
            pooled,
            shapes,
            shard_depths,
            mac_rejected: self.mac_rejected(),
        }
    }

    /// Spawn the offline phase as a service: `workers` background threads
    /// partition the shard slots round-robin and keep their shards at
    /// target, sleeping only when everything is topped up. Threads hold a
    /// [`Weak`] pool reference, so dropping the last owning [`Arc`] stops
    /// them even without an explicit [`PoolService::stop`].
    pub fn start_service(pool: &Arc<TriplePool>, workers: usize) -> PoolService {
        let stop = Arc::new(AtomicBool::new(false));
        let n = workers.clamp(1, pool.shard_count());
        let threads = (0..n)
            .map(|w| {
                let weak: Weak<TriplePool> = Arc::downgrade(pool);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || loop {
                    if stop.load(Ordering::Relaxed) {
                        return;
                    }
                    let Some(pool) = weak.upgrade() else { return };
                    let mut stocked = 0;
                    let mut si = w;
                    while si < pool.shard_count() {
                        stocked += pool.refill_shard(si);
                        si += n;
                    }
                    drop(pool);
                    if stocked == 0 {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                })
            })
            .collect();
        PoolService { stop, threads }
    }
}

/// Handle to a running offline-phase service (see
/// [`TriplePool::start_service`]). Stop it explicitly with
/// [`PoolService::stop`]; otherwise the worker threads exit on their own
/// once the last owning pool [`Arc`] is dropped.
pub struct PoolService {
    stop: Arc<AtomicBool>,
    threads: Vec<JoinHandle<()>>,
}

impl PoolService {
    /// Number of refill worker threads.
    pub fn workers(&self) -> usize {
        self.threads.len()
    }

    /// Signal every refill worker to stop and join them.
    pub fn stop(self) {
        self.stop.store(true, Ordering::Relaxed);
        for t in self.threads {
            let _ = t.join();
        }
    }
}

/// The dealer: a PRG plus offline-traffic accounting, optionally backed by
/// a shared [`TriplePool`].
pub struct Dealer {
    rng: Rng,
    pool: Option<Arc<TriplePool>>,
    /// Bytes of correlated randomness distributed (offline phase).
    pub offline_bytes: u64,
    /// Number of triples served (diagnostics).
    pub triples_served: u64,
}

impl Dealer {
    /// Dealer with no pool: every triple is generated on demand.
    pub fn new(rng: Rng) -> Self {
        Dealer { rng, pool: None, offline_bytes: 0, triples_served: 0 }
    }

    /// Attach a shared pool; subsequent triple requests try it first.
    pub fn attach_pool(&mut self, pool: Arc<TriplePool>) {
        self.pool = Some(pool);
    }

    /// The attached pool, if any.
    pub fn pool(&self) -> Option<&Arc<TriplePool>> {
        self.pool.as_ref()
    }

    fn share_of(&mut self, x: RingTensor) -> Share {
        share_with(&mut self.rng, x)
    }

    fn rand_tensor(&mut self, rows: usize, cols: usize) -> RingTensor {
        rand_tensor(&mut self.rng, rows, cols)
    }

    fn account(&mut self, shape: TripleShape) {
        self.offline_bytes += shape.offline_bytes();
        self.triples_served += 1;
    }

    /// Serve a matrix triple for `X (m×k) @ Y (k×n)` — from the pool when
    /// one is available, generated on demand otherwise.
    pub fn matmul_triple(&mut self, m: usize, k: usize, n: usize) -> MatTriple {
        let shape = TripleShape::matmul(m, k, n);
        self.account(shape);
        if let Some(pool) = &self.pool {
            if let Some(PoolItem::Mat(t)) = pool.take(shape) {
                return t;
            }
        }
        let a = self.rand_tensor(m, k);
        let b = self.rand_tensor(k, n);
        let c = ring::matmul(&a, &b);
        MatTriple { a: self.share_of(a), b: self.share_of(b), c: self.share_of(c) }
    }

    /// Serve an elementwise triple of shape `rows×cols`.
    pub fn elem_triple(&mut self, rows: usize, cols: usize) -> MatTriple {
        let shape = TripleShape::elem(rows, cols);
        self.account(shape);
        if let Some(pool) = &self.pool {
            if let Some(PoolItem::Mat(t)) = pool.take(shape) {
                return t;
            }
        }
        let a = self.rand_tensor(rows, cols);
        let b = self.rand_tensor(rows, cols);
        let c = ring::mul_elem(&a, &b);
        MatTriple { a: self.share_of(a), b: self.share_of(b), c: self.share_of(c) }
    }

    /// Serve a session-scoped fixed-operand correlation (mask + `uses`
    /// per-use bundles) — from the pool when one is stocked, generated on
    /// demand otherwise (the cold-start fallback). The whole bundle is
    /// charged to `offline_bytes` exactly once here; per-use consumption
    /// charges nothing offline (the mask is session-amortized, not
    /// re-distributed per take).
    pub fn fixed_correlation(&mut self, shape: TripleShape) -> FixedOperandCorrelation {
        debug_assert!(shape.is_fixed(), "fixed_correlation needs a fixed-operand shape");
        debug_assert!(!shape.is_session_bundle(), "use fixed_session_correlations for bundles");
        self.account(shape);
        if let Some(pool) = &self.pool {
            if let Some(PoolItem::Fixed(c)) = pool.take(shape) {
                return c;
            }
        }
        generate_fixed(&mut self.rng, shape)
    }

    /// Serve a shared-mask session bundle: `shape.layers` per-layer
    /// correlations holding the **same** mask sharing, so the session
    /// opens the fixed operand once and every layer adopts the opening
    /// (see [`FixedOperandCorrelation::adopt_shared_opening`]). Pool-first
    /// with the same cold-start on-demand fallback as the other families;
    /// the whole bundle — one mask plus `layers × uses` per-use
    /// correlations — is charged to `offline_bytes` exactly once here.
    pub fn fixed_session_correlations(&mut self, shape: TripleShape) -> Vec<FixedOperandCorrelation> {
        debug_assert!(shape.is_session_bundle(), "needs a layers > 0 session-bundle shape");
        self.account(shape);
        if let Some(pool) = &self.pool {
            if let Some(PoolItem::FixedSession(cs)) = pool.take(shape) {
                return cs;
            }
        }
        generate_fixed_session(&mut self.rng, shape)
    }

    /// Serve a square pair of shape `rows×cols`.
    pub fn square_pair(&mut self, rows: usize, cols: usize) -> SquarePair {
        let shape = TripleShape::square(rows, cols);
        self.account(shape);
        if let Some(pool) = &self.pool {
            if let Some(PoolItem::Square(p)) = pool.take(shape) {
                return p;
            }
        }
        let a = self.rand_tensor(rows, cols);
        let c = ring::mul_elem(&a, &a);
        SquarePair { a: self.share_of(a), c: self.share_of(c) }
    }

    /// Dealer-held RNG fork (for ideal-functionality resharing).
    pub fn fork_rng(&mut self, tag: u64) -> Rng {
        self.rng.fork(tag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triple_identity_holds() {
        let mut d = Dealer::new(Rng::new(7));
        let t = d.matmul_triple(3, 4, 5);
        let a = t.a.reconstruct();
        let b = t.b.reconstruct();
        let c = t.c.reconstruct();
        assert_eq!(ring::matmul(&a, &b), c);
    }

    #[test]
    fn elem_triple_identity() {
        let mut d = Dealer::new(Rng::new(8));
        let t = d.elem_triple(4, 4);
        assert_eq!(ring::mul_elem(&t.a.reconstruct(), &t.b.reconstruct()), t.c.reconstruct());
    }

    #[test]
    fn square_pair_identity() {
        let mut d = Dealer::new(Rng::new(9));
        let p = d.square_pair(2, 6);
        let a = p.a.reconstruct();
        assert_eq!(ring::mul_elem(&a, &a), p.c.reconstruct());
    }

    #[test]
    fn offline_accounting_grows() {
        let mut d = Dealer::new(Rng::new(10));
        let before = d.offline_bytes;
        d.matmul_triple(8, 8, 8);
        assert!(d.offline_bytes > before);
        assert_eq!(d.triples_served, 1);
    }

    #[test]
    fn pool_miss_learns_then_hit_after_refill() {
        let pool = TriplePool::new(21, 2);
        let shape = TripleShape::matmul(4, 6, 5);
        assert!(pool.take(shape).is_none());
        assert_eq!((pool.hits(), pool.misses()), (0, 1));
        // demand=1, depth=2 → two entries at target
        assert_eq!(pool.fill_to_target(), 2);
        assert_eq!(pool.pooled_total(), 2);
        let item = pool.take(shape).expect("prefilled");
        assert_eq!(pool.hits(), 1);
        match item {
            PoolItem::Mat(t) => {
                assert_eq!(t.a.shape(), (4, 6));
                assert_eq!(t.b.shape(), (6, 5));
                assert_eq!(
                    ring::matmul(&t.a.reconstruct(), &t.b.reconstruct()),
                    t.c.reconstruct()
                );
            }
            _ => panic!("matmul key must hold a matrix triple"),
        }
    }

    #[test]
    fn pool_keys_by_shape_and_kind() {
        let pool = TriplePool::new(22, 1);
        let mm = TripleShape::matmul(4, 4, 4);
        let el = TripleShape::elem(4, 4);
        let sq = TripleShape::square(4, 4);
        for s in [mm, el, sq] {
            assert!(pool.take(s).is_none());
        }
        assert_eq!(pool.shapes_known(), 3);
        pool.fill_to_target();
        assert_eq!(pool.pooled_total(), 3);
        // Each kind gets its own queue: draining one leaves the others.
        assert!(matches!(pool.take(sq), Some(PoolItem::Square(_))));
        assert!(matches!(pool.take(el), Some(PoolItem::Mat(_))));
        assert!(matches!(pool.take(mm), Some(PoolItem::Mat(_))));
        assert!(pool.take(mm).is_none(), "queue drained");
        // A different matmul shape is a different key.
        assert!(pool.take(TripleShape::matmul(4, 4, 8)).is_none());
    }

    #[test]
    fn refill_stops_at_target_and_counts_offline_bytes() {
        let pool = TriplePool::new(23, 3);
        let shape = TripleShape::elem(2, 8);
        let _ = pool.take(shape); // demand = 1
        assert!(pool.refill_once());
        assert!(pool.refill_once());
        assert!(pool.refill_once());
        assert!(!pool.refill_once(), "at target: nothing left to do");
        assert_eq!(pool.pooled_total(), 3);
        assert_eq!(pool.offline_bytes(), 3 * shape.offline_bytes());
    }

    #[test]
    fn drained_misses_under_live_demand_ratchet_the_target() {
        // Regression (ISSUE 8 satellite): take() used to grow demand only
        // while stocked == 0, so a shape drained under sustained load kept
        // its cold-start target forever and the refill service never
        // caught up. Hammering a drained shape with registered demand must
        // now raise the target.
        let pool = TriplePool::new(27, 2);
        let shape = TripleShape::elem(3, 3);
        pool.register_demand(shape, 1);
        assert_eq!(pool.fill_to_target(), 2);
        assert_eq!(pool.target_for(shape), 2);
        // A burst of concurrent sessions drains the stock, then keeps
        // missing: every drained miss is a starvation event AND a surge.
        for _ in 0..2 {
            assert!(pool.take(shape).is_some());
        }
        for _ in 0..3 {
            assert!(pool.take(shape).is_none());
        }
        assert_eq!(pool.starvation_events(), 3);
        assert_eq!(pool.target_for(shape), (1 + 3) * 2, "drained misses must grow the target");
        assert_eq!(pool.fill_to_target(), 8);
        // The ratchet retires with the last registered session: a dead
        // shape must not keep a surged target alive.
        pool.release_demand(shape, 1);
        assert_eq!(pool.target_for(shape), 0);
        assert_eq!(pool.fill_to_target(), 0);
    }

    #[test]
    fn cold_misses_still_learn_demand_without_starvation_events() {
        // Pre-first-stock misses are the probe teaching the pool its shape
        // profile — they register demand but are NOT starvation (the
        // offline phase could not have known the shape yet).
        let pool = TriplePool::new(28, 2);
        let shape = TripleShape::elem(3, 3);
        let _ = pool.take(shape);
        let _ = pool.take(shape);
        assert_eq!(pool.demand_for(shape), 2);
        assert_eq!(pool.starvation_events(), 1, "only the second miss hit a known shape");
        assert_eq!(pool.fill_to_target(), 4);
    }

    #[test]
    fn registered_demand_prefills_without_a_probe_miss() {
        // Decode-shape provisioning: register demand up front, fill, and
        // the first take is already a hit — no cold miss on the serve path.
        let pool = TriplePool::new(31, 1);
        pool.register_demand(TripleShape::matmul(32, 1, 64), 2);
        pool.register_demand(TripleShape::matmul(1, 32, 16), 4);
        assert_eq!(pool.shapes_known(), 2);
        assert_eq!(pool.fill_to_target(), 6);
        assert!(matches!(pool.take(TripleShape::matmul(32, 1, 64)), Some(PoolItem::Mat(_))));
        assert_eq!((pool.hits(), pool.misses()), (1, 0));
    }

    #[test]
    fn release_demand_retires_abandoned_session_stock() {
        // A generate stream that ends early must hand back the per-step
        // demand it registered, or the refill thread keeps overstocking a
        // shape nobody will take again.
        let pool = TriplePool::new(33, 2);
        let shape = TripleShape::matmul(1, 32, 16);
        pool.register_demand(shape, 5);
        assert_eq!(pool.fill_to_target(), 10);
        // Session consumed 2 steps, then the client dropped: release 3.
        pool.release_demand(shape, 3);
        // Drain exactly the stock (a trailing drained miss would be a
        // legitimate surge under the load-adaptive ratchet).
        for _ in 0..10 {
            assert!(pool.take(shape).is_some());
        }
        assert_eq!(pool.fill_to_target(), 4, "target follows the surviving demand");
        // Releasing more than was ever registered clamps at zero.
        pool.release_demand(shape, 100);
        for _ in 0..4 {
            assert!(pool.take(shape).is_some());
        }
        assert_eq!(pool.fill_to_target(), 0, "dead shape must not be restocked");
        // A miss on the dead shape is starvation-visible but must not
        // resurrect the target (no live demand → no surge).
        assert!(pool.take(shape).is_none());
        assert_eq!(pool.fill_to_target(), 0);
        // Releasing a never-registered shape is a harmless no-op.
        pool.release_demand(TripleShape::elem(2, 2), 7);
        assert_eq!(pool.fill_to_target(), 0);
    }

    #[test]
    fn releases_and_queries_of_unknown_shapes_leave_no_phantom_entries() {
        // Regression (ISSUE 8 satellite): release_demand used entry
        // or_default semantics, inserting an empty ShapeQueue for every
        // never-stocked shape a speculative eviction released — leaking a
        // map entry per unseen shape.
        let pool = TriplePool::new(41, 2);
        pool.register_demand(TripleShape::elem(2, 2), 1);
        assert_eq!(pool.shapes_known(), 1);
        pool.release_demand(TripleShape::matmul(1, 64, 16), 12);
        pool.release_demand(TripleShape::fixed_ppp(2, 8, 8), 1);
        assert_eq!(pool.demand_for(TripleShape::matmul(1, 64, 16)), 0);
        assert_eq!(pool.target_for(TripleShape::fixed_ppp(2, 8, 8)), 0);
        assert_eq!(pool.shapes_known(), 1, "unknown-shape releases must not leak map entries");
    }

    #[test]
    fn dealer_serves_from_attached_pool() {
        let pool = Arc::new(TriplePool::new(24, 2));
        let mut d = Dealer::new(Rng::new(25));
        d.attach_pool(Arc::clone(&pool));
        // Cold call: miss, generated on demand, demand recorded.
        let t0 = d.matmul_triple(3, 5, 4);
        assert_eq!(ring::matmul(&t0.a.reconstruct(), &t0.b.reconstruct()), t0.c.reconstruct());
        assert_eq!(pool.misses(), 1);
        pool.fill_to_target();
        // Warm call: served from the pool; accounting still advances.
        let before = d.offline_bytes;
        let t1 = d.matmul_triple(3, 5, 4);
        assert_eq!(ring::matmul(&t1.a.reconstruct(), &t1.b.reconstruct()), t1.c.reconstruct());
        assert_eq!(pool.hits(), 1);
        assert!(d.offline_bytes > before);
        assert_eq!(d.triples_served, 2);
        assert!(pool.hit_rate() > 0.49 && pool.hit_rate() < 0.51);
    }

    #[test]
    fn fixed_correlation_identities_hold() {
        // The dealt bundles satisfy the algebra every family relies on:
        // C = A·B (PppRight), C = B[:,i]·A (AppendLeft), C = A·B_blockᵀ
        // over the written rows (ScoresGrown).
        let mut d = Dealer::new(Rng::new(91));
        let mut ppp = d.fixed_correlation(TripleShape::fixed_ppp(3, 5, 4));
        let b = ppp.mask.reconstruct();
        for i in 0..4 {
            let (idx, u) = ppp.take_use().unwrap();
            assert_eq!(idx, i);
            let (a, c) = &u.blocks[0];
            assert_eq!(a.shape(), (3, 5));
            assert_eq!(ring::matmul(&a.reconstruct(), &b), c.reconstruct());
        }
        assert!(ppp.take_use().is_err(), "exhausted uses must error, not reuse");

        let mut app = d.fixed_correlation(TripleShape::fixed_append(6, 4, 3));
        let b = app.mask.reconstruct();
        for i in 0..3 {
            let (_, u) = app.take_use().unwrap();
            let (a, c) = &u.blocks[0];
            assert_eq!(a.shape(), (1, 4));
            assert_eq!(ring::matmul(&b.col_block(i, i + 1), &a.reconstruct()), c.reconstruct());
        }

        let mut sc = d.fixed_correlation(TripleShape::fixed_scores(2, 5, 8, 5));
        let b = sc.mask.reconstruct();
        for i in 0..5 {
            let (_, u) = sc.take_use().unwrap();
            assert_eq!(u.blocks.len(), 2, "one block per head");
            for (h, (a, c)) in u.blocks.iter().enumerate() {
                assert_eq!(a.shape(), (1, 4));
                assert_eq!(c.shape(), (1, i + 1));
                let bt = RingTensor::from_fn(4, i + 1, |r, cc| b.get(cc, h * 4 + r));
                assert_eq!(ring::matmul(&a.reconstruct(), &bt), c.reconstruct());
            }
        }
    }

    #[test]
    fn fixed_use_rewind_restores_identical_bundles_in_order() {
        let mut d = Dealer::new(Rng::new(96));
        let mut sc = d.fixed_correlation(TripleShape::fixed_scores(2, 6, 8, 6));
        let mut seen = Vec::new();
        for i in 0..4 {
            let (idx, u) = sc.take_use().unwrap();
            assert_eq!(idx, i);
            seen.push(u);
        }
        sc.opened = 4;
        assert_eq!(sc.uses_left(), 2);
        // Roll positions 2..4 back, then replay: the restored bundles must
        // be the very ones consumed, with matching indices and openings.
        sc.rewind_uses_to(2).unwrap();
        sc.rewind_opened_to(2).unwrap();
        assert_eq!(sc.uses_left(), 4);
        assert_eq!(sc.openings(), 2);
        for i in 2..6 {
            let (idx, u) = sc.take_use().unwrap();
            assert_eq!(idx, i);
            if i < 4 {
                for (b, (a0, c0)) in u.blocks.iter().zip(&seen[i].blocks) {
                    assert_eq!(&b.0, a0);
                    assert_eq!(&b.1, c0);
                }
            }
        }
        assert!(sc.take_use().is_err(), "dealt count still bounds total uses");
        // Rewinding forward (or past what was opened) is an error.
        assert!(sc.rewind_uses_to(7).is_err());
        assert!(sc.rewind_opened_to(9).is_err());
        // Full rewind-to-zero restores the entire session bundle.
        sc.rewind_uses_to(0).unwrap();
        assert_eq!(sc.uses_left(), 6);
    }

    #[test]
    fn demand_for_reports_outstanding_registrations() {
        let pool = TriplePool::new(97, 1);
        let shape = TripleShape::matmul(1, 16, 8);
        assert_eq!(pool.demand_for(shape), 0);
        pool.register_demand(shape, 6);
        assert_eq!(pool.demand_for(shape), 6);
        pool.release_demand(shape, 4);
        assert_eq!(pool.demand_for(shape), 2);
        pool.release_demand(shape, 5);
        assert_eq!(pool.demand_for(shape), 0, "release clamps at zero");
    }

    #[test]
    fn fixed_shapes_pool_hit_miss_refill_and_register_demand() {
        // The new shape class goes through the same pool lifecycle as the
        // per-use triples: a registered demand prefills it, the first take
        // is a hit, a drained queue misses.
        let pool = TriplePool::new(92, 1);
        let shape = TripleShape::fixed_ppp(2, 8, 8);
        pool.register_demand(shape, 1);
        pool.register_demand(TripleShape::fixed_append(8, 4, 8), 1);
        pool.register_demand(TripleShape::fixed_scores(2, 8, 4, 8), 1);
        assert_eq!(pool.shapes_known(), 3);
        assert_eq!(pool.fill_to_target(), 3);
        match pool.take(shape) {
            Some(PoolItem::Fixed(c)) => {
                assert_eq!(c.shape, shape);
                assert_eq!(c.dealt(), 8);
                assert_eq!(c.uses_left(), 8);
                assert_eq!(c.openings(), 0);
                assert_eq!(c.mask.shape(), (8, 8));
            }
            _ => panic!("fixed shape key must hold a correlation bundle"),
        }
        assert_eq!((pool.hits(), pool.misses()), (1, 0));
        assert!(pool.take(shape).is_none(), "queue drained");
        // A different use count is a different key.
        assert!(pool.take(TripleShape::fixed_ppp(2, 8, 4)).is_none());
    }

    #[test]
    fn fixed_offline_bytes_charge_session_bundle_exactly_once() {
        // The session-amortized mask is part of one per-session charge —
        // never re-counted per use or per pool hit beyond the dealer's
        // distribution accounting.
        let shape = TripleShape::fixed_ppp(2, 4, 3);
        // mask 4×4 + 3 uses × (A 2×4 + C 2×4) = 16 + 48 elements, ×16 B.
        assert_eq!(shape.offline_bytes(), 16 * (16 + 48));
        let app = TripleShape::fixed_append(4, 2, 3);
        // mask 16 + 3 × (A 2 + C 8) = 46 elements
        assert_eq!(app.offline_bytes(), 16 * 46);
        let sc = TripleShape::fixed_scores(2, 4, 2, 3);
        // mask 8 + uses·n 6 + h·u(u+1)/2 = 12 → 26 elements
        assert_eq!(sc.offline_bytes(), 16 * 26);

        let mut d = Dealer::new(Rng::new(93));
        let mut corr = d.fixed_correlation(shape);
        assert_eq!(d.offline_bytes, shape.offline_bytes());
        // Consuming uses moves no additional offline bytes.
        let _ = corr.take_use().unwrap();
        let _ = corr.take_use().unwrap();
        assert_eq!(d.offline_bytes, shape.offline_bytes());
        // A second session pays the bundle again (fresh mask), exactly once.
        let _ = d.fixed_correlation(shape);
        assert_eq!(d.offline_bytes, 2 * shape.offline_bytes());
        assert_eq!(d.triples_served, 2);
    }

    #[test]
    fn dealer_serves_fixed_correlation_from_pool_with_cold_fallback() {
        let pool = Arc::new(TriplePool::new(94, 1));
        let mut d = Dealer::new(Rng::new(95));
        d.attach_pool(Arc::clone(&pool));
        let shape = TripleShape::fixed_append(6, 3, 6);
        // Cold: pool miss, generated on demand — the session still works.
        let c0 = d.fixed_correlation(shape);
        assert_eq!(c0.uses_left(), 6);
        assert_eq!(pool.misses(), 1);
        pool.fill_to_target();
        // Warm: served from the pool.
        let c1 = d.fixed_correlation(shape);
        assert_eq!(c1.uses_left(), 6);
        assert_eq!(pool.hits(), 1);
        assert_eq!(pool.offline_bytes(), shape.offline_bytes());
    }

    #[test]
    fn pool_is_shareable_across_threads() {
        let pool = Arc::new(TriplePool::new(26, 2));
        let shape = TripleShape::square(4, 4);
        let _ = pool.take(shape);
        pool.fill_to_target();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let p = Arc::clone(&pool);
                std::thread::spawn(move || {
                    let _ = p.take(TripleShape::square(4, 4));
                    p.refill_once();
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(pool.hits() + pool.misses(), 5);
    }

    #[test]
    fn session_bundle_shares_one_mask_across_layers() {
        let mut d = Dealer::new(Rng::new(101));
        let shape = TripleShape::fixed_ppp_session(2, 6, 4, 3);
        let mut layers = d.fixed_session_correlations(shape);
        assert_eq!(layers.len(), 3);
        let mask0 = layers[0].mask.clone();
        let b = mask0.reconstruct();
        for corr in &mut layers {
            // Per-layer key (layers erased): downstream per-use machinery
            // is oblivious to how the mask was amortized.
            assert_eq!(corr.shape, TripleShape::fixed_ppp(2, 6, 4));
            assert_eq!(corr.mask, mask0, "every layer holds the same mask sharing");
            assert_eq!(corr.openings(), 0);
            corr.adopt_shared_opening().unwrap();
            assert_eq!(corr.openings(), 1);
            assert!(corr.adopt_shared_opening().is_err(), "no second opening per layer");
            for _ in 0..4 {
                let (_, u) = corr.take_use().unwrap();
                let (a, c) = &u.blocks[0];
                assert_eq!(ring::matmul(&a.reconstruct(), &b), c.reconstruct());
            }
            assert!(corr.take_use().is_err(), "per-layer uses still bounded");
        }
        // Per-use randomness stays fresh per layer despite the shared mask.
        assert_ne!(
            layers[0].consumed[0].blocks[0].0.reconstruct(),
            layers[1].consumed[0].blocks[0].0.reconstruct()
        );
        // The row-grown family never adopts (it opens per written row).
        let mut sc = d.fixed_correlation(TripleShape::fixed_scores(2, 4, 4, 2));
        assert!(sc.adopt_shared_opening().is_err());
    }

    #[test]
    fn session_append_bundle_keeps_column_per_use_identity() {
        let mut d = Dealer::new(Rng::new(102));
        let shape = TripleShape::fixed_append_session(6, 3, 6, 2);
        let mut layers = d.fixed_session_correlations(shape);
        assert_eq!(layers.len(), 2);
        assert_eq!(layers[0].mask, layers[1].mask);
        let b = layers[0].mask.reconstruct();
        for corr in &mut layers {
            for i in 0..6 {
                let (_, u) = corr.take_use().unwrap();
                let (a, c) = &u.blocks[0];
                assert_eq!(
                    ring::matmul(&b.col_block(i, i + 1), &a.reconstruct()),
                    c.reconstruct()
                );
            }
        }
    }

    #[test]
    fn session_bundle_offline_bytes_charge_the_shared_mask_once() {
        let shape = TripleShape::fixed_ppp_session(2, 4, 3, 3);
        // mask 4×4 + 3 layers × 3 uses × (A 2×4 + C 2×4) elements, ×16 B.
        assert_eq!(shape.offline_bytes(), 16 * (16 + 3 * 48));
        // Cheaper than 3 independent per-layer bundles: the mask sharing
        // is distributed once, not once per layer.
        let per_layer = TripleShape::fixed_ppp(2, 4, 3);
        assert_eq!(shape.offline_bytes() + 2 * 16 * 16, 3 * per_layer.offline_bytes());
        let app = TripleShape::fixed_append_session(4, 2, 3, 3);
        // mask 4×4 + 3 layers × 3 uses × (A 2 + C 8) elements.
        assert_eq!(app.offline_bytes(), 16 * (16 + 3 * 30));

        let mut d = Dealer::new(Rng::new(103));
        let _ = d.fixed_session_correlations(shape);
        assert_eq!(d.offline_bytes, shape.offline_bytes());
        assert_eq!(d.triples_served, 1, "one session bundle, one serve");
    }

    #[test]
    fn session_bundles_pool_like_any_other_shape() {
        let pool = Arc::new(TriplePool::new(104, 1));
        let mut d = Dealer::new(Rng::new(105));
        d.attach_pool(Arc::clone(&pool));
        let shape = TripleShape::fixed_append_session(6, 3, 6, 2);
        pool.register_demand(shape, 1);
        assert_eq!(pool.fill_to_target(), 1);
        let layers = d.fixed_session_correlations(shape);
        assert_eq!(layers.len(), 2);
        assert_eq!(layers[0].mask, layers[1].mask);
        assert_eq!((pool.hits(), pool.misses()), (1, 0));
        assert_eq!(pool.offline_bytes(), shape.offline_bytes());
        // Cold fallback still works once the pool is drained.
        let cold = d.fixed_session_correlations(shape);
        assert_eq!(cold.len(), 2);
        assert_eq!(pool.misses(), 1);
        // The session key and its per-layer key are distinct pool shapes.
        assert!(pool.take(TripleShape::fixed_append(6, 3, 6)).is_none());
    }

    #[test]
    fn batched_shard_refill_matches_fill_semantics() {
        // refill_shard generates the full deficit of its pick in one
        // batch; driving shards to fixpoint equals the old per-item fill.
        let pool = TriplePool::new(106, 3);
        pool.register_demand(TripleShape::matmul(2, 4, 3), 2);
        pool.register_demand(TripleShape::elem(5, 5), 1);
        let mut total = 0;
        loop {
            let round: u64 = (0..pool.shard_count()).map(|si| pool.refill_shard(si)).sum();
            if round == 0 {
                break;
            }
            total += round;
        }
        assert_eq!(total, 2 * 3 + 3);
        assert_eq!(pool.pooled_total(), 9);
        assert!(!pool.refill_once(), "already at target");
    }

    #[test]
    fn offline_service_keeps_shards_topped_up() {
        let pool = Arc::new(TriplePool::new(107, 2));
        let shape = TripleShape::matmul(2, 4, 3);
        pool.register_demand(shape, 2);
        let service = TriplePool::start_service(&pool, 2);
        assert_eq!(service.workers(), 2);
        // The service reaches the 4-entry target with no synchronous fill.
        let mut waited = 0;
        while pool.pooled_total() < 4 && waited < 5000 {
            std::thread::sleep(Duration::from_millis(1));
            waited += 1;
        }
        assert_eq!(pool.pooled_total(), 4);
        // Draining under live demand: the service restocks on its own.
        for _ in 0..4 {
            assert!(pool.take(shape).is_some());
        }
        let mut waited = 0;
        while pool.pooled_total() < 4 && waited < 5000 {
            std::thread::sleep(Duration::from_millis(1));
            waited += 1;
        }
        assert!(pool.pooled_total() >= 4);
        service.stop();
        // A stopped service generates nothing more.
        let left = pool.pooled_total();
        for _ in 0..left {
            assert!(pool.take(shape).is_some());
        }
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(pool.pooled_total(), 0);
    }

    #[test]
    fn pool_stress_producers_consumers_balance() {
        // ISSUE 8 satellite: N producer / M consumer stress — no deadlock,
        // hits + misses == takes, offline_bytes monotone, and demand
        // balances back to zero once every session has evicted.
        const PRODUCERS: usize = 3;
        const CONSUMERS: usize = 4;
        const ROUNDS: usize = 50;
        let pool = Arc::new(TriplePool::new(108, 2));
        let shapes = [
            TripleShape::matmul(1, 16, 8),
            TripleShape::elem(4, 4),
            TripleShape::square(3, 5),
            TripleShape::fixed_ppp(2, 8, 4),
        ];
        let takes = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let producers: Vec<_> = (0..PRODUCERS)
            .map(|w| {
                let p = Arc::clone(&pool);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    let mut last = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        let mut si = w;
                        while si < p.shard_count() {
                            p.refill_shard(si);
                            si += PRODUCERS;
                        }
                        let now = p.offline_bytes();
                        assert!(now >= last, "offline_bytes must be monotone");
                        last = now;
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..CONSUMERS)
            .map(|c| {
                let p = Arc::clone(&pool);
                let takes = Arc::clone(&takes);
                std::thread::spawn(move || {
                    for round in 0..ROUNDS {
                        let s = shapes[(c + round) % shapes.len()];
                        p.register_demand(s, 1); // session admits
                        for _ in 0..3 {
                            let _ = p.take(s);
                            takes.fetch_add(1, Ordering::Relaxed);
                        }
                        p.release_demand(s, 1); // session evicts
                    }
                })
            })
            .collect();
        for h in consumers {
            h.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        for h in producers {
            h.join().unwrap();
        }
        assert_eq!(
            pool.hits() + pool.misses(),
            takes.load(Ordering::Relaxed),
            "every take is exactly one hit or one miss"
        );
        let stats = pool.stats();
        assert_eq!(stats.hits + stats.misses, (CONSUMERS * ROUNDS * 3) as u64);
        assert_eq!(stats.offline_bytes, pool.offline_bytes());
        assert_eq!(stats.shard_depths.len(), pool.shard_count());
        // All sessions evicted → registered demand balances to zero.
        for s in shapes {
            pool.release_demand(s, u64::MAX); // retire any surge leftovers
            assert_eq!(pool.demand_for(s), 0);
        }
    }

    // ------------------------------------------------------------------
    // Pool-item MACs (integrity-checked mode)
    // ------------------------------------------------------------------

    #[test]
    fn mac_tags_quarantine_a_tampered_entry_at_take() {
        let pool = TriplePool::new(200, 2);
        pool.enable_mac(0xFEED_FACE);
        assert!(pool.mac_enabled());
        let shape = TripleShape::matmul(4, 4, 4);
        pool.register_demand(shape, 1);
        assert_eq!(pool.fill_to_target(), 2);
        // Corrupt the front entry while it sits in the pool.
        assert!(pool.tamper_one(shape));
        // take() rejects the corrupted entry and serves the clean one.
        assert!(matches!(pool.take(shape), Some(PoolItem::Mat(_))));
        assert_eq!(pool.mac_rejected(), 1);
        assert_eq!((pool.hits(), pool.misses()), (1, 0));
        assert_eq!(pool.stats().mac_rejected, 1, "PoolStats must surface the rejection");
        // Draining the (now empty) queue is an ordinary miss.
        assert!(pool.take(shape).is_none());
        // Nothing pooled for an unknown shape → nothing to tamper with.
        assert!(!pool.tamper_one(TripleShape::elem(9, 9)));
    }

    #[test]
    fn mac_rejects_untagged_entries_fail_closed() {
        // Entries stocked before the key was set carry tag 0; turning the
        // MAC on afterwards must reject them rather than trust them.
        let pool = TriplePool::new(201, 1);
        let shape = TripleShape::square(3, 3);
        pool.register_demand(shape, 1);
        assert_eq!(pool.fill_to_target(), 1);
        pool.enable_mac(0xB00);
        assert!(pool.take(shape).is_none(), "untagged entries must not be served");
        assert_eq!(pool.mac_rejected(), 1);
        // The refill path restocks with valid tags and service resumes.
        assert!(pool.fill_to_target() >= 1);
        assert!(pool.take(shape).is_some());
    }

    #[test]
    fn mac_tags_cover_every_pool_item_family() {
        let pool = TriplePool::new(202, 1);
        pool.enable_mac(0xAB5);
        let shapes = [
            TripleShape::matmul(2, 3, 4),
            TripleShape::elem(3, 3),
            TripleShape::square(2, 5),
            TripleShape::fixed_ppp(2, 4, 3),
            TripleShape::fixed_append_session(4, 2, 3, 2),
        ];
        for s in shapes {
            pool.register_demand(s, 1);
        }
        assert_eq!(pool.fill_to_target(), 5);
        for s in shapes {
            assert!(pool.tamper_one(s), "tamper hook must reach {s:?}");
            assert!(pool.take(s).is_none(), "corrupted {s:?} must be quarantined");
        }
        assert_eq!(pool.mac_rejected(), 5);
    }

    #[test]
    fn offline_service_stocks_verifiable_entries_under_mac() {
        // PoolService workers tag what they generate; consuming takes
        // verify clean — audit mode does not starve the warm path.
        let pool = Arc::new(TriplePool::new(203, 2));
        pool.enable_mac(0xD0_0DAD);
        let shape = TripleShape::matmul(1, 8, 8);
        pool.register_demand(shape, 1);
        let service = TriplePool::start_service(&pool, 1);
        let mut waited = 0;
        while pool.pooled_total() < 2 && waited < 5000 {
            std::thread::sleep(Duration::from_millis(1));
            waited += 1;
        }
        assert!(pool.take(shape).is_some());
        assert_eq!(pool.mac_rejected(), 0);
        assert_eq!(pool.hits(), 1);
        service.stop();
    }

    #[test]
    fn a_mac_corrupted_pooled_triple_fails_the_consuming_flush() {
        use crate::mpc::Mpc;
        use crate::net::{NetSim, NetworkProfile, OpClass};
        let pool = Arc::new(TriplePool::new(204, 2));
        pool.enable_mac(0x5EED);
        let shape = TripleShape::matmul(4, 4, 4);
        pool.register_demand(shape, 1);
        assert_eq!(pool.fill_to_target(), 2);
        assert!(pool.tamper_one(shape));

        let mut mpc = Mpc::new(NetSim::new(NetworkProfile::lan()), 77);
        mpc.dealer.attach_pool(Arc::clone(&pool));
        mpc.enable_audit(77);
        let x = RingTensor::from_fn(4, 4, |r, c| (r * 4 + c) as i64 - 7);
        let sx = mpc.share_local(&x);
        let sy = mpc.share_local(&x);
        // The consuming matmul's take quarantines the corrupted entry and
        // serves the clean one — the opening itself stays honest…
        mpc.matmul(&sx, &sy, OpClass::Linear);
        assert_eq!(pool.mac_rejected(), 1);
        // …but the session's next MAC flush must still reject: a
        // corrupted item surfaced on this session's watch.
        let err = mpc.flush_mac_checks().unwrap_err();
        assert!(err.to_string().contains("corrupted pool items = 1"), "unexpected error: {err}");
        assert_eq!(mpc.audit_counters().unwrap().mac_failures, 1);
        // The rejection was consumed; subsequent flushes are clean.
        mpc.matmul(&sx, &sy, OpClass::Linear);
        assert_eq!(mpc.flush_mac_checks().unwrap(), 1);
    }

    #[test]
    fn audited_session_demand_balances_to_zero_on_release() {
        // An audited session registers decode-shape demand exactly like a
        // semi-honest one and hands it back on eviction.
        let pool = TriplePool::new(205, 1);
        pool.enable_mac(0xCAFE);
        let shapes = [TripleShape::matmul(1, 16, 8), TripleShape::fixed_ppp(1, 8, 4)];
        for s in shapes {
            pool.register_demand(s, 3);
            assert_eq!(pool.demand_for(s), 3);
        }
        pool.fill_to_target();
        for s in shapes {
            assert!(pool.take(s).is_some(), "warm take under MAC must succeed");
            pool.release_demand(s, 3);
            assert_eq!(pool.demand_for(s), 0, "audited demand must balance to zero");
        }
        assert_eq!(pool.mac_rejected(), 0);
    }
}
