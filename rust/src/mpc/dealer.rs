//! Trusted dealer for correlated randomness (Beaver triples).
//!
//! CrypTen's TTP ("trusted first party") provider model: during an offline
//! phase, a dealer generates multiplication triples and distributes shares.
//! Like the paper (and CrypTen's cost reporting), dealer↔party traffic is
//! **not** charged against the online communication ledger; it is tracked
//! separately in [`Dealer::offline_bytes`] so the offline/online split can
//! be reported (EXPERIMENTS.md notes it).

use crate::ring;
use crate::tensor::RingTensor;
use crate::util::rng::Rng;

use super::Share;

/// A matrix Beaver triple `C = A·B` in shares.
pub struct MatTriple {
    pub a: Share,
    pub b: Share,
    pub c: Share,
}

/// A square pair `C = A∘A` in shares (for the cheap square protocol).
pub struct SquarePair {
    pub a: Share,
    pub c: Share,
}

/// The dealer: a PRG plus offline-traffic accounting.
pub struct Dealer {
    rng: Rng,
    /// Bytes of correlated randomness distributed (offline phase).
    pub offline_bytes: u64,
    /// Number of triples served (diagnostics).
    pub triples_served: u64,
}

impl Dealer {
    pub fn new(rng: Rng) -> Self {
        Dealer { rng, offline_bytes: 0, triples_served: 0 }
    }

    fn share_of(&mut self, x: RingTensor) -> Share {
        let s0 = RingTensor::from_vec(x.rows(), x.cols(), self.rng.vec_i64(x.len()));
        let s1 = ring::sub(&x, &s0);
        Share { s0, s1 }
    }

    fn rand_tensor(&mut self, rows: usize, cols: usize) -> RingTensor {
        RingTensor::from_vec(rows, cols, self.rng.vec_i64(rows * cols))
    }

    /// Serve a matrix triple for `X (m×k) @ Y (k×n)`.
    pub fn matmul_triple(&mut self, m: usize, k: usize, n: usize) -> MatTriple {
        let a = self.rand_tensor(m, k);
        let b = self.rand_tensor(k, n);
        let c = ring::matmul(&a, &b);
        self.offline_bytes += 8 * 2 * (m * k + k * n + m * n) as u64;
        self.triples_served += 1;
        MatTriple { a: self.share_of(a), b: self.share_of(b), c: self.share_of(c) }
    }

    /// Serve an elementwise triple of shape `rows×cols`.
    pub fn elem_triple(&mut self, rows: usize, cols: usize) -> MatTriple {
        let a = self.rand_tensor(rows, cols);
        let b = self.rand_tensor(rows, cols);
        let c = ring::mul_elem(&a, &b);
        self.offline_bytes += 8 * 2 * 3 * (rows * cols) as u64;
        self.triples_served += 1;
        MatTriple { a: self.share_of(a), b: self.share_of(b), c: self.share_of(c) }
    }

    /// Serve a square pair of shape `rows×cols`.
    pub fn square_pair(&mut self, rows: usize, cols: usize) -> SquarePair {
        let a = self.rand_tensor(rows, cols);
        let c = ring::mul_elem(&a, &a);
        self.offline_bytes += 8 * 2 * 2 * (rows * cols) as u64;
        self.triples_served += 1;
        SquarePair { a: self.share_of(a), c: self.share_of(c) }
    }

    /// Dealer-held RNG fork (for ideal-functionality resharing).
    pub fn fork_rng(&mut self, tag: u64) -> Rng {
        self.rng.fork(tag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triple_identity_holds() {
        let mut d = Dealer::new(Rng::new(7));
        let t = d.matmul_triple(3, 4, 5);
        let a = t.a.reconstruct();
        let b = t.b.reconstruct();
        let c = t.c.reconstruct();
        assert_eq!(ring::matmul(&a, &b), c);
    }

    #[test]
    fn elem_triple_identity() {
        let mut d = Dealer::new(Rng::new(8));
        let t = d.elem_triple(4, 4);
        assert_eq!(ring::mul_elem(&t.a.reconstruct(), &t.b.reconstruct()), t.c.reconstruct());
    }

    #[test]
    fn square_pair_identity() {
        let mut d = Dealer::new(Rng::new(9));
        let p = d.square_pair(2, 6);
        let a = p.a.reconstruct();
        assert_eq!(ring::mul_elem(&a, &a), p.c.reconstruct());
    }

    #[test]
    fn offline_accounting_grows() {
        let mut d = Dealer::new(Rng::new(10));
        let before = d.offline_bytes;
        d.matmul_triple(8, 8, 8);
        assert!(d.offline_bytes > before);
        assert_eq!(d.triples_served, 1);
    }
}
