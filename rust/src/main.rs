//! `centaur` CLI — leader entrypoint for the Centaur PPTI system.
//!
//! ```text
//! centaur report <table1|table2|table3|table4|fig3|fig4|fig7|fig8|fig10|all> [--fast]
//! centaur infer  --weights bert-tiny-qnli --text "..." [--net lan]
//! centaur serve  --weights bert-tiny-qnli --requests 32 --batch 8 [--framework centaur]
//!                [--offline-prefill] [--pool-depth 2]
//! centaur serve  --weights gpt2-tiny-wikitext103 --gen-steps 8 --requests 4
//!                [--offline-prefill] [--no-decode-corr] [--no-round-batching]  # streaming incremental decode
//!                [--spec-k 4]  # speculative multi-token verify per flight chain
//!                [--audit]        # SPDZ-style share MACs + transcript digests
//!                [--audit-tamper] # deliberate fault; run fails unless detected
//! centaur compare --model bert-tiny [--full]
//! centaur artifacts-check
//! ```

use centaur::baselines::FrameworkKind;
use centaur::coordinator::{Coordinator, ServerConfig, StreamEvent};
use centaur::data::{artifacts_dir, TaskData, Vocab};
use centaur::model::{ModelConfig, ModelKind, ModelWeights};
use centaur::net::NetworkProfile;
use centaur::report;
use centaur::util::cli::Args;
use centaur::Result;

fn main() {
    let args = Args::from_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    // Global ring-kernel selection: `--ring-kernel scalar|avx2|avx512|neon|xla`
    // (same registry as CENTAUR_RING_KERNEL, wins over it). Fail fast here so
    // a typo'd or host-unsupported kernel is a CLI error, not a mid-run panic.
    centaur::runtime::kernel::set_override(args.opt("ring-kernel"))?;
    match args.command.as_deref() {
        Some("report") => cmd_report(args),
        Some("infer") => cmd_infer(args),
        Some("serve") => cmd_serve(args),
        Some("compare") => cmd_compare(args),
        Some("artifacts-check") => cmd_artifacts_check(args),
        _ => {
            eprintln!(
                "centaur {} — hybrid privacy-preserving transformer inference\n\
                 usage: centaur <report|infer|serve|compare|artifacts-check> [options]\n\
                 global options: --ring-kernel <scalar|avx2|avx512|neon|xla>\n\
                 report targets: table1 table2 table3 table4 fig3 fig4 fig7 fig8 fig10 all",
                centaur::VERSION
            );
            Ok(())
        }
    }
}

fn profile_arg(args: &Args) -> NetworkProfile {
    NetworkProfile::by_name(args.opt_or("net", "lan")).unwrap_or_else(NetworkProfile::lan)
}

fn cmd_report(args: &Args) -> Result<()> {
    let target = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let dir = args.opt_or("artifacts", &artifacts_dir()).to_string();
    let extrapolate = !args.flag("full"); // --full disables layer extrapolation
    let quick = args.flag("fast");
    let run = |t: &str| -> Result<String> {
        match t {
            "table1" => report::table1(args.opt_usize("n", 128)),
            "table2" | "table4" => {
                let mut opts = report::AttackTableOpts::default();
                if quick {
                    opts.seeds = 1;
                    opts.sentences = 6;
                    opts.eia_sentences = 2;
                    opts.eia_candidates = 12;
                    opts.aux_train = 150;
                }
                opts.seeds = args.opt_u64("seeds", opts.seeds);
                opts.sentences = args.opt_usize("sentences", opts.sentences);
                report::attack_table(&dir, t == "table4", &opts)
            }
            "table3" => report::table3(&dir, args.opt_usize("engine-check", if quick { 2 } else { 8 })),
            "fig3" => report::fig3(extrapolate),
            "fig4" => report::fig4(&dir, args.opt_usize("examples", 3)),
            "fig7" => {
                let models = models_arg(args, "fig7");
                report::fig7(&models, extrapolate)
            }
            "fig8" => {
                let models = models_arg(args, "fig8");
                report::fig8(&models, extrapolate)
            }
            "fig10" => {
                let models = models_arg(args, "fig10");
                report::fig8(&models, extrapolate)
            }
            other => anyhow::bail!("unknown report target '{other}'"),
        }
    };
    if target == "all" {
        for t in ["table1", "fig7", "fig8", "fig10", "fig3", "table3", "table2", "table4", "fig4"] {
            println!("\n################ {t} ################");
            println!("{}", run(t)?);
        }
    } else {
        println!("{}", run(target)?);
    }
    Ok(())
}

fn models_arg(args: &Args, fig: &str) -> Vec<String> {
    args.opt("models")
        .map(|m| m.split(',').map(|s| s.trim().to_string()).collect())
        .unwrap_or_else(|| report::default_models(fig))
}

fn cmd_infer(args: &Args) -> Result<()> {
    let dir = args.opt_or("artifacts", &artifacts_dir()).to_string();
    let tag = args.opt_or("weights", "bert-tiny-qnli");
    let (cfg, weights) = ModelWeights::load_tag(&dir, tag)?;
    let vocab = Vocab::load(&dir)?;
    let text = args.opt_or("text", "omar captured the famous tower near london in march 1862");
    let tokens = vocab.encode(text, cfg.n_ctx);
    let mut engine = centaur::engine::CentaurEngine::new(&cfg, &weights, profile_arg(args), 7)?;
    let out = engine.infer(&tokens)?;
    println!("model   : {tag} ({} params)", cfg.param_count());
    println!("input   : {text}");
    println!("logits  : {:?}", out.logits.row(0).iter().take(8).collect::<Vec<_>>());
    println!("comm    : {}", centaur::util::human_bytes(out.stats.bytes_total()));
    println!("rounds  : {}", out.stats.rounds_total());
    let p = profile_arg(args);
    println!("est time: {} under {}", centaur::util::human_secs(out.stats.total_time(&p)), p.name);
    println!("leaks   : {:?}", engine.leaks());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let dir = args.opt_or("artifacts", &artifacts_dir()).to_string();
    let tag = args.opt_or("weights", "bert-tiny-qnli").to_string();
    // Trained artifacts when present; otherwise random weights for the
    // matching architecture so serving smoke runs (CI) work untrained.
    let (cfg, weights) = match ModelWeights::load_tag(&dir, &tag) {
        Ok(cw) => cw,
        Err(e) => {
            let name = ModelConfig::ALL_NAMES
                .iter()
                .copied()
                .find(|n| tag.starts_with(n))
                .ok_or_else(|| anyhow::anyhow!("no artifacts for '{tag}' and no matching architecture: {e}"))?;
            let cfg = ModelConfig::by_name(name).expect("ALL_NAMES entries resolve");
            eprintln!("artifacts for '{tag}' missing — falling back to random {name} weights (smoke mode)");
            let w = ModelWeights::random(&cfg, 7);
            (cfg, w)
        }
    };
    let mut sc = ServerConfig::new(cfg.clone(), weights);
    sc.framework = FrameworkKind::by_name(args.opt_or("framework", "centaur"))
        .ok_or_else(|| anyhow::anyhow!("unknown framework"))?;
    sc.backend = args.opt_or("backend", "native").to_string();
    sc.artifacts_dir = dir.clone();
    sc.profile = profile_arg(args);
    sc.workers = args.opt_usize("workers", 1);
    sc.max_batch = args.opt_usize("batch", 8);
    // Amortized offline phase: prefill a shared TriplePool at server start
    // and keep it topped up in the background (Centaur framework only).
    sc.offline_prefill = args.flag("offline-prefill");
    sc.pool_depth = args.opt_usize("pool-depth", sc.pool_depth);
    // Fixed-operand correlated triples are on by default for decode
    // sessions; `--no-decode-corr` runs the plain per-step baseline.
    sc.decode_correlations = !args.flag("no-decode-corr");
    // Batched-opening decode schedule on by default; `--no-round-batching`
    // runs the sequential per-opening schedule (round-budget baseline).
    sc.round_batching = !args.flag("no-round-batching");
    // Speculative decode width: `--spec-k 4` verifies up to 4 draft
    // tokens per flight chain (tiny-model draft over the serving
    // weights), output token-identical to plain greedy.
    sc.spec_k = args.opt_usize("spec-k", 1);
    anyhow::ensure!(sc.spec_k >= 1, "--spec-k must be >= 1");
    anyhow::ensure!(
        sc.spec_k == 1 || sc.round_batching,
        "--spec-k > 1 needs the batched decode schedule (drop --no-round-batching)"
    );
    // Integrity-checked serving: `--audit` turns on share MACs +
    // transcript digests (also honors CENTAUR_AUDIT=1); `--audit-tamper`
    // additionally arms one deliberate share fault in the decode
    // scheduler and the run FAILS unless the audit layer catches it.
    sc.audit = sc.audit || args.flag("audit");
    sc.audit_tamper = args.flag("audit-tamper");
    anyhow::ensure!(!sc.audit_tamper || sc.audit, "--audit-tamper needs --audit");
    let audit = sc.audit;
    let audit_tamper = sc.audit_tamper;
    let n_req = args.opt_usize("requests", 16);

    // Streaming generation mode: each request decodes `--gen-steps` tokens
    // incrementally over the secret-shared KV cache, tokens streamed back
    // as the protocol produces them.
    let gen_steps = args.opt_usize("gen-steps", 0);
    anyhow::ensure!(
        !audit_tamper || gen_steps > 0,
        "--audit-tamper arms the decode scheduler; combine it with --gen-steps"
    );
    if gen_steps > 0 {
        anyhow::ensure!(
            sc.cfg.kind == ModelKind::Gpt2,
            "--gen-steps requires a decoder (gpt2-*) model"
        );
        anyhow::ensure!(
            sc.framework == FrameworkKind::Centaur,
            "--gen-steps requires the centaur framework (incremental KV-cache decode)"
        );
        let prompt_len = 4usize.min(sc.cfg.n_ctx.saturating_sub(gen_steps)).max(1);
        anyhow::ensure!(prompt_len + gen_steps <= sc.cfg.n_ctx, "--gen-steps exceeds n_ctx");
        // Provision decode-shape triples for every absorb of a request,
        // scaled to the sessions the decode scheduler can batch at once.
        sc.decode_prefill_steps = prompt_len + gen_steps;
        sc.decode_prefill_sessions = n_req.min(sc.max_batch).max(1);
        println!(
            "serving {} generation requests ({} steps each) through {} (batch<={}, {})",
            n_req,
            gen_steps,
            sc.framework.name(),
            sc.max_batch,
            sc.profile.name
        );
        let coord = Coordinator::start(sc)?;
        if let Some(pool) = coord.triple_pool() {
            println!(
                "offline phase done: {} triples pooled across {} shapes ({} correlated randomness)",
                pool.pooled_total(),
                pool.shapes_known(),
                centaur::util::human_bytes(pool.offline_bytes())
            );
        }
        let rxs: Vec<_> = (0..n_req)
            .map(|i| {
                let mut prompt = vec![centaur::data::CLS];
                prompt.extend((1..prompt_len).map(|j| (4 + (i * 7 + j * 3) % 100) as u32));
                coord.submit_generate(prompt, gen_steps)
            })
            .collect();
        let mut rejected = 0u64;
        for (i, rx) in rxs.into_iter().enumerate() {
            loop {
                match rx.recv().map_err(|_| anyhow::anyhow!("coordinator died"))? {
                    // Under the deliberate-tamper smoke, rejected requests
                    // are the expected outcome — count them and move on.
                    Err(e) if audit_tamper => {
                        println!("  req{i} rejected by audit: {e:#}");
                        rejected += 1;
                        break;
                    }
                    Err(e) => return Err(e),
                    Ok(StreamEvent::Token { index, token, step_bytes, .. }) => {
                        if i == 0 {
                            println!(
                                "  req0 token[{index}] = {token}  ({} online this step)",
                                centaur::util::human_bytes(step_bytes)
                            );
                        }
                    }
                    Ok(StreamEvent::Done(s)) => {
                        if i == 0 {
                            let per_tok = s.decode_bytes / (s.tokens.len().max(1) as u64);
                            println!(
                                "  req0 done: corr setup {} | prefill {} | decode {} ({} per token)",
                                centaur::util::human_bytes(s.setup_bytes),
                                centaur::util::human_bytes(s.prefill_bytes),
                                centaur::util::human_bytes(s.decode_bytes),
                                centaur::util::human_bytes(per_tok)
                            );
                        }
                        break;
                    }
                }
            }
        }
        let snap = coord.shutdown();
        println!("{}", snap.summary());
        if audit_tamper {
            anyhow::ensure!(
                rejected > 0 && snap.audit_failures > 0,
                "--audit-tamper: the armed fault went UNDETECTED (rejected={rejected}, audit_failures={})",
                snap.audit_failures
            );
            println!(
                "audit tamper smoke: {rejected} request(s) rejected, audit_failures={}",
                snap.audit_failures
            );
        } else if audit {
            anyhow::ensure!(
                snap.audit_failures == 0,
                "audit flagged an honest run (audit_failures={})",
                snap.audit_failures
            );
            println!(
                "audit clean: mac_checks={} overhead={}",
                snap.mac_checks,
                centaur::util::human_bytes(snap.audit_overhead_bytes)
            );
        }
        return Ok(());
    }

    // requests from the matching task's test set when available
    let task = tag.split('-').next_back().unwrap_or("qnli").to_string();
    let inputs: Vec<Vec<u32>> = match TaskData::load(&dir, &task) {
        Ok(td) => td.test.ids.into_iter().take(n_req).collect(),
        Err(_) => (0..n_req).map(|i| vec![(4 + i % 100) as u32; cfg.n_ctx]).collect(),
    };
    println!(
        "serving {} requests through {} ({} workers, batch<={}, {})",
        inputs.len(),
        sc.framework.name(),
        sc.workers,
        sc.max_batch,
        sc.profile.name
    );
    let coord = Coordinator::start(sc)?;
    if let Some(pool) = coord.triple_pool() {
        println!(
            "offline phase done: {} triples pooled across {} shapes ({} correlated randomness)",
            pool.pooled_total(),
            pool.shapes_known(),
            centaur::util::human_bytes(pool.offline_bytes())
        );
    }
    let rxs: Vec<_> = inputs.into_iter().map(|t| coord.submit(t)).collect();
    for rx in rxs {
        rx.recv().map_err(|_| anyhow::anyhow!("coordinator died"))??;
    }
    let snap = coord.shutdown();
    println!("{}", snap.summary());
    if audit {
        anyhow::ensure!(
            snap.audit_failures == 0,
            "audit flagged an honest run (audit_failures={})",
            snap.audit_failures
        );
        println!(
            "audit clean: mac_checks={} overhead={}",
            snap.mac_checks,
            centaur::util::human_bytes(snap.audit_overhead_bytes)
        );
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let model = args.opt_or("model", "bert-tiny");
    let cfg = ModelConfig::by_name(model).ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let extrapolate = !args.flag("full");
    println!("{}", report::fig7(&[model.to_string()], extrapolate)?);
    let _ = cfg;
    Ok(())
}

fn cmd_artifacts_check(args: &Args) -> Result<()> {
    let dir = args.opt_or("artifacts", &artifacts_dir()).to_string();
    let vocab = Vocab::load(&dir)?;
    println!("vocab: {} words", vocab.len());
    for t in TaskData::ALL_TASKS {
        let td = TaskData::load(&dir, t)?;
        println!("task {t}: {} train / {} test", td.train.ids.len(), td.test.ids.len());
    }
    for model in ["bert-tiny", "gpt2-tiny", "bert-base", "bert-large", "gpt2-base", "gpt2-large"] {
        match centaur::runtime::ArtifactRegistry::load(&dir, model) {
            Ok(reg) => println!("hlo {model}: {} ops", reg.keys().count()),
            Err(e) => println!("hlo {model}: MISSING ({e})"),
        }
    }
    for tag in ["bert-tiny-qnli", "gpt2-tiny-wikitext103"] {
        let (cfg, _w) = ModelWeights::load_tag(&dir, tag)?;
        println!("weights {tag}: d={} layers={}", cfg.d, cfg.layers);
    }
    println!("artifacts OK");
    Ok(())
}
