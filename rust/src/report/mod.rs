//! Report generators — one per paper table/figure (DESIGN.md experiment
//! index). Each returns the formatted text the CLI prints; EXPERIMENTS.md
//! records the outputs next to the paper's numbers.

pub mod metrics;

use std::collections::BTreeMap;

use crate::attacks::eia::EiaConfig;
use crate::attacks::harness::{self, AttackExperiment, AttackKind};
use crate::attacks::{Condition, TargetOp};
use crate::baselines::{permonly::PermOnlyEngine, smpc::SmpcEngine, FrameworkKind, PptiFramework};
use crate::data::{AttackCorpora, LmData, TaskData, Vocab};
use crate::engine::{CentaurEngine, EngineOptions};
use crate::model::{ModelConfig, ModelWeights, Variant};
use crate::net::{CostLedger, NetworkProfile, OpClass};
use crate::runtime::NativeBackend;
use crate::util::{human_bytes, human_secs};
use crate::Result;

// ---------------------------------------------------------------------
// Shared measurement machinery
// ---------------------------------------------------------------------

/// Measure one framework × model cost ledger for a single inference.
///
/// With `extrapolate` (default for paper-scale models), runs 1-layer and
/// 2-layer variants and extends exactly:
/// `total = run(1) + (run(2) − run(1)) × (L − 1)` — exact for bytes and
/// rounds because transformer layers are cost-identical; compute scales
/// linearly, which EXPERIMENTS.md notes.
pub fn measure_framework(
    kind: FrameworkKind,
    cfg: &ModelConfig,
    seed: u64,
    extrapolate: bool,
) -> Result<CostLedger> {
    let tokens: Vec<u32> = (0..cfg.n_ctx).map(|i| (i % (cfg.vocab - 4) + 4) as u32).collect();
    let run_one = |layers: usize| -> Result<CostLedger> {
        let c = cfg.with_layers(layers);
        let w = ModelWeights::random(&c, seed);
        let mut fw: Box<dyn PptiFramework> = match kind {
            FrameworkKind::Centaur => Box::new(CentaurEngine::with_backend(
                &c,
                &w,
                Box::new(NativeBackend::new()),
                EngineOptions { profile: NetworkProfile::lan(), seed, record_views: false, fast_sim: true, ..Default::default() },
            )?),
            FrameworkKind::PermOnly => Box::new(PermOnlyEngine::new(&c, &w, NetworkProfile::lan(), false)),
            smpc => Box::new(SmpcEngine::new(smpc, &c, &w, NetworkProfile::lan(), seed)?),
        };
        Ok(fw.infer(&tokens)?.stats)
    };
    if !extrapolate || cfg.layers <= 2 {
        let c = cfg.clone();
        let w = ModelWeights::random(&c, seed);
        let mut fw: Box<dyn PptiFramework> = match kind {
            FrameworkKind::Centaur => Box::new(CentaurEngine::with_backend(
                &c,
                &w,
                Box::new(NativeBackend::new()),
                EngineOptions { profile: NetworkProfile::lan(), seed, record_views: false, fast_sim: true, ..Default::default() },
            )?),
            FrameworkKind::PermOnly => Box::new(PermOnlyEngine::new(&c, &w, NetworkProfile::lan(), false)),
            smpc => Box::new(SmpcEngine::new(smpc, &c, &w, NetworkProfile::lan(), seed)?),
        };
        return Ok(fw.infer(&tokens)?.stats);
    }
    let l1 = run_one(1)?;
    let l2 = run_one(2)?;
    let per_layer = l2.delta(&l1);
    let mut total = l1;
    total.merge(&per_layer.scaled(cfg.layers as u64 - 1));
    Ok(total)
}

fn ratio(a: f64, b: f64) -> f64 {
    if b == 0.0 {
        f64::INFINITY
    } else {
        a / b
    }
}

// ---------------------------------------------------------------------
// Table 1 — per-protocol communication costs
// ---------------------------------------------------------------------

/// Table 1: measured rounds / volume of each protocol vs the paper formula.
pub fn table1(n: usize) -> Result<String> {
    use crate::engine::views::Views;
    use crate::fixed;
    use crate::mpc::Mpc;
    use crate::net::NetSim;
    use crate::protocols::{nonlin, ppp};
    use crate::tensor::{FloatTensor, RingTensor};

    let mut out = String::new();
    out.push_str(&format!(
        "Table 1 — protocol costs on {n}x{n} operands (paper formulas in bits)\n\
         {:<12} {:>7} {:>16} {:>16} {:>7}\n",
        "protocol", "rounds", "measured bits", "paper", "match"
    ));
    let mut row = |name: &str, rounds: u64, bits: u64, paper: u64| {
        out.push_str(&format!(
            "{:<12} {:>7} {:>16} {:>16} {:>7}\n",
            name,
            rounds,
            bits,
            paper,
            if bits == paper { "yes" } else { "NO" }
        ));
    };

    let fresh = || Mpc::new(NetSim::new(NetworkProfile::lan()), 7);
    let x = FloatTensor::from_fn(n, n, |r, c| ((r * 31 + c) % 13) as f32 * 0.05);
    let x_fx = fixed::encode_tensor(&x);

    // Π_Add
    {
        let mut mpc = fresh();
        let a = mpc.share_local(&x_fx);
        let b = mpc.share_local(&x_fx);
        let _ = mpc.add(&a, &b);
        row("Pi_Add", mpc.net.ledger.rounds_total(), mpc.net.ledger.bytes_total() * 8, 0);
    }
    // Π_ScalMul
    {
        let mut mpc = fresh();
        let a = mpc.share_local(&x_fx);
        let _ = mpc.scalmul(&x_fx, &a, OpClass::Linear);
        row("Pi_ScalMul", mpc.net.ledger.rounds_total(), mpc.net.ledger.bytes_total() * 8, 0);
    }
    // Π_MatMul
    {
        let mut mpc = fresh();
        let a = mpc.share_local(&x_fx);
        let b = mpc.share_local(&x_fx);
        let _ = mpc.matmul(&a, &b, OpClass::Linear);
        row(
            "Pi_MatMul",
            mpc.net.ledger.rounds_total(),
            mpc.net.ledger.bytes_total() * 8,
            256 * (n as u64) * (n as u64),
        );
    }
    // Π_PPSM / Π_PPGeLU / Π_PPLN — 2 rounds, 128 n² bits
    let paper_pp = 128 * (n as u64) * (n as u64);
    {
        let mut mpc = fresh();
        let mut backend = NativeBackend::new();
        let mut views = Views::new(false);
        let a = mpc.share_local(&x_fx);
        let _ = nonlin::pp_softmax(&mut mpc, &mut backend, &mut views, &a, "t1")?;
        row("Pi_PPSM", mpc.net.ledger.rounds_total(), mpc.net.ledger.bytes_total() * 8, paper_pp);
    }
    {
        let mut mpc = fresh();
        let mut backend = NativeBackend::new();
        let mut views = Views::new(false);
        let a = mpc.share_local(&x_fx);
        let _ = nonlin::pp_gelu(&mut mpc, &mut backend, &mut views, &a, "t1")?;
        row("Pi_PPGeLU", mpc.net.ledger.rounds_total(), mpc.net.ledger.bytes_total() * 8, paper_pp);
    }
    {
        let mut mpc = fresh();
        let mut backend = NativeBackend::new();
        let mut views = Views::new(false);
        let a = mpc.share_local(&x_fx);
        let gamma = vec![1.0f32; n];
        let beta = vec![0.0f32; n];
        let _ = nonlin::pp_layernorm(&mut mpc, &mut backend, &mut views, &a, &gamma, &beta, OpClass::LayerNorm, "t1")?;
        row("Pi_PPLN", mpc.net.ledger.rounds_total(), mpc.net.ledger.bytes_total() * 8, paper_pp);
    }
    // Π_PPP (matmul against shared π, excluding the one-time dealing)
    {
        let mut mpc = fresh();
        let mut rng = crate::util::rng::Rng::new(5);
        let p = crate::perm::Perm::random(n, &mut rng);
        let a = mpc.share_local(&RingTensor::zeros(n, n));
        let pi_sh = ppp::share_perm(&mut mpc, &p, OpClass::Linear);
        let before = mpc.net.ledger.clone();
        let _ = ppp::ppp_cols(&mut mpc, &a, &pi_sh, OpClass::Linear);
        let bits = (mpc.net.ledger.bytes_total() - before.bytes_total()) * 8;
        let rounds = mpc.net.ledger.rounds_total() - before.rounds_total();
        row("Pi_PPP", rounds, bits, 256 * (n as u64) * (n as u64));
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// Tables 2 & 4 — DRA attack grids
// ---------------------------------------------------------------------

/// Options for the attack tables.
pub struct AttackTableOpts {
    /// Independent attack repetitions.
    pub seeds: u64,
    /// Victim sentences per seed.
    pub sentences: usize,
    /// Victim sentences given to the (expensive) EIA attack.
    pub eia_sentences: usize,
    /// EIA candidate tokens sampled per position.
    pub eia_candidates: usize,
    /// Auxiliary sentences used to train SIP/BRE.
    pub aux_train: usize,
}

impl Default for AttackTableOpts {
    fn default() -> Self {
        AttackTableOpts { seeds: 3, sentences: 12, eia_sentences: 4, eia_candidates: 24, aux_train: 400 }
    }
}

/// Table 2 (qnli + wikitext103) or Table 4 (mrpc + wikitext2).
pub fn attack_table(artifacts_dir: &str, variant_t4: bool, opts: &AttackTableOpts) -> Result<String> {
    let (task, corpus, label) = if variant_t4 {
        ("mrpc", "wikitext2", "Table 4 — BERT(mrpc) + GPT-2(wikitext2)")
    } else {
        ("qnli", "wikitext103", "Table 2 — BERT(qnli) + GPT-2(wikitext103)")
    };
    let corpora = AttackCorpora::load(artifacts_dir)?;
    let mut out = format!("{label}  (ROUGE-L F1 %, mean ± std over {} seeds)\n", opts.seeds);

    let run_side = |tag: String, victims: Vec<Vec<u32>>, out: &mut String| -> Result<()> {
        let (cfg, w) = ModelWeights::load_tag(artifacts_dir, &tag)?;
        // the paper's "overly idealized" adversary: give it the stronger
        // in-distribution auxiliary corpus (EXPERIMENTS.md discusses the
        // OOD variant)
        let exp = AttackExperiment {
            cfg: &cfg,
            weights: &w,
            aux: &corpora.aux_indist,
            private: &victims,
            seeds: opts.seeds,
            sentences: opts.sentences,
            eia_sentences: opts.eia_sentences,
            eia: EiaConfig { candidates: opts.eia_candidates, sweeps: 1 },
            aux_train: opts.aux_train,
            ops: TargetOp::ALL.to_vec(),
        };
        let table = harness::run(&exp)?;
        out.push_str(&format!("\n== {tag} ==\n{:<6} {:<9}", "attack", "method"));
        for op in TargetOp::ALL {
            out.push_str(&format!(" {:>14}", op.name()));
        }
        out.push_str(&format!(" {:>8}\n", "Avg"));
        for attack in AttackKind::ALL {
            for cond in Condition::ALL {
                out.push_str(&format!("{:<6} {:<9}", attack.name(), cond.name()));
                let mut avg = 0.0;
                for op in TargetOp::ALL {
                    let cell = table.get(&(attack, cond as usize, op)).copied().unwrap_or_default();
                    out.push_str(&format!(" {:>7.2}±{:<5.2}", cell.mean, cell.std));
                    avg += cell.mean;
                }
                out.push_str(&format!(" {:>8.2}\n", avg / TargetOp::ALL.len() as f64));
            }
        }
        Ok(())
    };

    // BERT side: victims are task test inputs.
    let td = TaskData::load(artifacts_dir, task)?;
    run_side(format!("bert-tiny-{task}"), td.test.ids.clone(), &mut out)?;
    // GPT side: victims are LM test sequences.
    let lm = LmData::load(artifacts_dir, corpus)?;
    run_side(format!("gpt2-tiny-{corpus}"), lm.test.clone(), &mut out)?;
    Ok(out)
}

// ---------------------------------------------------------------------
// Table 3 — accuracy comparison
// ---------------------------------------------------------------------

/// Table 3: plaintext / PUMA / MPCFormer(±) / SecFormer(±) / Centaur.
///
/// Headline numbers come from full-test-set evaluation of the exact
/// semantics each framework computes (plaintext forwards with the
/// framework's substitutions); `engine_check` examples are additionally
/// pushed through the *actual protocol engines* and the agreement rate is
/// reported (Centaur and the SMPC baselines compute those same semantics
/// under MPC).
pub fn table3(artifacts_dir: &str, engine_check: usize) -> Result<String> {
    let mut out = String::from(
        "Table 3 — performance (task metric / perplexity)\n\
         rows: plaintext, PUMA, MPCFormer w/o, MPCFormer, SecFormer w/o, SecFormer, Centaur\n\n",
    );
    // BERT tasks
    out.push_str(&format!("{:<16}", "framework"));
    for task in TaskData::ALL_TASKS {
        out.push_str(&format!(" {:>8}", task));
    }
    out.push_str(&format!(" {:>8}\n", "Avg"));

    let mut rows: BTreeMap<&str, Vec<f64>> = BTreeMap::new();
    let mut checks: Vec<String> = Vec::new();
    for task in TaskData::ALL_TASKS {
        let td = TaskData::load(artifacts_dir, task)?;
        let (cfg, w_exact) = ModelWeights::load_tag(artifacts_dir, &format!("bert-tiny-{task}"))?;
        let score = |w: &ModelWeights, v: Variant| -> f64 {
            let preds = metrics::predict(&cfg, w, &td.test, v);
            metrics::task_score(task, td.ttype, &preds, &td.test.labels)
        };
        rows.entry("Plain-text").or_default().push(score(&w_exact, Variant::Exact));
        rows.entry("PUMA").or_default().push(score(&w_exact, Variant::Exact));
        rows.entry("Centaur (Ours)").or_default().push(score(&w_exact, Variant::Exact));
        rows.entry("MPCFormer w/o").or_default().push(score(&w_exact, Variant::MpcFormer));
        rows.entry("SecFormer w/o").or_default().push(score(&w_exact, Variant::SecFormer));
        let (_c, w_m) = ModelWeights::load_tag(artifacts_dir, &format!("bert-tiny-{task}-mpcformer"))?;
        rows.entry("MPCFormer").or_default().push(score(&w_m, Variant::MpcFormer));
        let (_c, w_s) = ModelWeights::load_tag(artifacts_dir, &format!("bert-tiny-{task}-secformer"))?;
        rows.entry("SecFormer").or_default().push(score(&w_s, Variant::SecFormer));

        // protocol-engine agreement spot check (Centaur vs plaintext argmax)
        if engine_check > 0 {
            let mut eng = CentaurEngine::new(&cfg, &w_exact, NetworkProfile::lan(), 3)?;
            let mut agree = 0;
            let ncheck = engine_check.min(td.test.ids.len());
            for ids in td.test.ids.iter().take(ncheck) {
                let got = eng.infer(ids)?.logits;
                let want = crate::model::forward(&cfg, &w_exact, ids, Variant::Exact);
                let am = |t: &crate::tensor::FloatTensor| {
                    (0..t.cols()).max_by(|&a, &b| t.get(0, a).partial_cmp(&t.get(0, b)).unwrap()).unwrap()
                };
                if am(&got) == am(&want) {
                    agree += 1;
                }
            }
            checks.push(format!("{task}: centaur-engine argmax agreement {agree}/{ncheck}"));
        }
    }
    for (name, vals) in [
        ("Plain-text", rows["Plain-text"].clone()),
        ("PUMA", rows["PUMA"].clone()),
        ("MPCFormer w/o", rows["MPCFormer w/o"].clone()),
        ("MPCFormer", rows["MPCFormer"].clone()),
        ("SecFormer w/o", rows["SecFormer w/o"].clone()),
        ("SecFormer", rows["SecFormer"].clone()),
        ("Centaur (Ours)", rows["Centaur (Ours)"].clone()),
    ] {
        out.push_str(&format!("{:<16}", name));
        for v in &vals {
            out.push_str(&format!(" {:>8.1}", v));
        }
        out.push_str(&format!(" {:>8.1}\n", vals.iter().sum::<f64>() / vals.len() as f64));
    }

    // GPT corpora (perplexity ↓)
    out.push_str(&format!("\n{:<16}", "framework"));
    for c in LmData::ALL_CORPORA {
        out.push_str(&format!(" {:>12}", c));
    }
    out.push('\n');
    let mut grows: BTreeMap<&str, Vec<f64>> = BTreeMap::new();
    for corpus in LmData::ALL_CORPORA {
        let lm = LmData::load(artifacts_dir, corpus)?;
        let test: Vec<Vec<u32>> = lm.test.iter().take(120).cloned().collect();
        let (cfg, w_exact) = ModelWeights::load_tag(artifacts_dir, &format!("gpt2-tiny-{corpus}"))?;
        let ppl = |w: &ModelWeights, v: Variant| metrics::perplexity(&cfg, w, &test, v);
        grows.entry("Plain-text").or_default().push(ppl(&w_exact, Variant::Exact));
        grows.entry("PUMA").or_default().push(ppl(&w_exact, Variant::Exact));
        grows.entry("Centaur (Ours)").or_default().push(ppl(&w_exact, Variant::Exact));
        grows.entry("MPCFormer w/o").or_default().push(ppl(&w_exact, Variant::MpcFormer));
        grows.entry("SecFormer w/o").or_default().push(ppl(&w_exact, Variant::SecFormer));
        let (_c, w_m) = ModelWeights::load_tag(artifacts_dir, &format!("gpt2-tiny-{corpus}-mpcformer"))?;
        grows.entry("MPCFormer").or_default().push(ppl(&w_m, Variant::MpcFormer));
        let (_c, w_s) = ModelWeights::load_tag(artifacts_dir, &format!("gpt2-tiny-{corpus}-secformer"))?;
        grows.entry("SecFormer").or_default().push(ppl(&w_s, Variant::SecFormer));
    }
    for name in ["Plain-text", "PUMA", "MPCFormer w/o", "MPCFormer", "SecFormer w/o", "SecFormer", "Centaur (Ours)"] {
        out.push_str(&format!("{:<16}", name));
        for v in &grows[name] {
            out.push_str(&format!(" {:>12.1}", v));
        }
        out.push('\n');
    }
    if !checks.is_empty() {
        out.push_str("\nprotocol-engine spot checks:\n");
        for c in checks {
            out.push_str(&format!("  {c}\n"));
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// Fig 3 — runtime breakdown of PUMA / MPCFormer on BERT_BASE
// ---------------------------------------------------------------------

/// Fig. 3 — runtime breakdown of PUMA/MPCFormer on BERT_BASE (WAN).
pub fn fig3(extrapolate: bool) -> Result<String> {
    let cfg = ModelConfig::bert_base();
    let wan = NetworkProfile::wan1();
    let mut out = String::from("Fig 3a — runtime breakdown, BERT_BASE PPTI (WAN 200Mbps/40ms)\n");
    for kind in [FrameworkKind::Puma, FrameworkKind::MpcFormer] {
        let ledger = measure_framework(kind, &cfg, 17, extrapolate)?;
        let total = ledger.total_time(&wan);
        out.push_str(&format!("\n{} — total {}\n", kind.name(), human_secs(total)));
        for class in OpClass::ALL {
            let t = ledger.class_time(class, &wan);
            if t <= 0.0 {
                continue;
            }
            out.push_str(&format!("  {:<12} {:>10}  {:>5.1}%\n", class.name(), human_secs(t), 100.0 * t / total));
        }
        let nonlinear: f64 = [OpClass::Softmax, OpClass::Gelu, OpClass::LayerNorm]
            .iter()
            .map(|&c| ledger.class_time(c, &wan))
            .sum();
        out.push_str(&format!("  non-linear share: {:.1}%\n", 100.0 * nonlinear / total));
    }
    out.push_str("\nFig 3b — substitution impact on performance: see Table 3 'w/o' rows.\n");
    Ok(out)
}

// ---------------------------------------------------------------------
// Fig 4 / 9 — text recovery examples
// ---------------------------------------------------------------------

/// Fig. 4/9 — qualitative text-recovery examples from O1.
pub fn fig4(artifacts_dir: &str, examples: usize) -> Result<String> {
    let vocab = Vocab::load(artifacts_dir)?;
    let corpora = AttackCorpora::load(artifacts_dir)?;
    let (cfg, w) = ModelWeights::load_tag(artifacts_dir, "gpt2-tiny-wikitext103")?;
    let aux: Vec<Vec<u32>> = corpora.aux_indist.iter().take(600).cloned().collect();
    let mut out = String::from("Fig 4 — recovering inference inputs from O1 (QKᵀ)\n");
    for (i, victim) in corpora.private.iter().take(examples).enumerate() {
        let (truth, rec_plain, rec_perm) =
            harness::recovery_example(&cfg, &w, &aux, victim, &vocab, 0xF16 + i as u64)?;
        out.push_str(&format!(
            "\n#{i} ground truth : {truth}\n#{i} DRA on plaintext O1 (perm-only PPTI): {rec_plain}\n#{i} DRA on O1π₁ (Centaur)              : {rec_perm}\n"
        ));
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// Fig 7 — communication volume; Fig 8/10 — time breakdowns
// ---------------------------------------------------------------------

const EFF_MODELS: [&str; 4] = ["bert-base", "bert-large", "gpt2-base", "gpt2-large"];

/// Fig 7: per-op-class communication volume + totals, all frameworks.
pub fn fig7(models: &[String], extrapolate: bool) -> Result<String> {
    let mut out = String::from("Fig 7 — communication volume per inference\n");
    for name in models {
        let cfg = ModelConfig::by_name(name).ok_or_else(|| anyhow::anyhow!("unknown model {name}"))?;
        out.push_str(&format!("\n== {name} (n={}) ==\n{:<12}", cfg.n_ctx, "class"));
        let frameworks =
            [FrameworkKind::Centaur, FrameworkKind::MpcFormer, FrameworkKind::SecFormer, FrameworkKind::Puma];
        let ledgers: Vec<CostLedger> = frameworks
            .iter()
            .map(|&k| measure_framework(k, &cfg, 23, extrapolate))
            .collect::<Result<_>>()?;
        for k in frameworks {
            out.push_str(&format!(" {:>12}", k.name()));
        }
        out.push('\n');
        for class in OpClass::ALL {
            if ledgers.iter().all(|l| l.class(class).bytes == 0) {
                continue;
            }
            out.push_str(&format!("{:<12}", class.name()));
            for l in &ledgers {
                out.push_str(&format!(" {:>12}", human_bytes(l.class(class).bytes)));
            }
            out.push('\n');
        }
        out.push_str(&format!("{:<12}", "TOTAL"));
        for l in &ledgers {
            out.push_str(&format!(" {:>12}", human_bytes(l.bytes_total())));
        }
        out.push('\n');
        let cent = ledgers[0].bytes_total() as f64;
        out.push_str(&format!("{:<12}", "vs Centaur"));
        for l in &ledgers {
            out.push_str(&format!(" {:>11.1}x", ratio(l.bytes_total() as f64, cent)));
        }
        out.push('\n');
    }
    Ok(out)
}

/// Fig 8 (large models) / Fig 10 (base models): time breakdown per network.
pub fn fig8(models: &[String], extrapolate: bool) -> Result<String> {
    let mut out = String::from(
        "Fig 8/10 — inference time (compute measured on this host, 1 core; \
         network simulated per profile)\n",
    );
    let profiles = [NetworkProfile::lan(), NetworkProfile::wan1(), NetworkProfile::wan2()];
    for name in models {
        let cfg = ModelConfig::by_name(name).ok_or_else(|| anyhow::anyhow!("unknown model {name}"))?;
        let frameworks =
            [FrameworkKind::Centaur, FrameworkKind::MpcFormer, FrameworkKind::SecFormer, FrameworkKind::Puma];
        let ledgers: Vec<CostLedger> = frameworks
            .iter()
            .map(|&k| measure_framework(k, &cfg, 29, extrapolate))
            .collect::<Result<_>>()?;
        for profile in profiles {
            out.push_str(&format!("\n== {name} under {} ==\n{:<12}", profile.name, "class"));
            for k in frameworks {
                out.push_str(&format!(" {:>12}", k.name()));
            }
            out.push('\n');
            for class in OpClass::ALL {
                if ledgers.iter().all(|l| l.class_time(class, &profile) == 0.0) {
                    continue;
                }
                out.push_str(&format!("{:<12}", class.name()));
                for l in &ledgers {
                    out.push_str(&format!(" {:>12}", human_secs(l.class_time(class, &profile))));
                }
                out.push('\n');
            }
            out.push_str(&format!("{:<12}", "TOTAL"));
            for l in &ledgers {
                out.push_str(&format!(" {:>12}", human_secs(l.total_time(&profile))));
            }
            out.push('\n');
            let cent = ledgers[0].total_time(&profile);
            out.push_str(&format!("{:<12}", "speedup"));
            for l in &ledgers {
                out.push_str(&format!(" {:>11.1}x", ratio(l.total_time(&profile), cent)));
            }
            out.push('\n');
        }
    }
    Ok(out)
}

/// Default model lists for Figs 7/8/10.
pub fn default_models(fig: &str) -> Vec<String> {
    match fig {
        "fig8" => vec!["bert-large".into(), "gpt2-large".into()],
        "fig10" => vec!["bert-base".into(), "gpt2-base".into()],
        _ => EFF_MODELS.iter().map(|s| s.to_string()).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper_formulas() {
        let t = table1(16).unwrap();
        assert!(!t.contains(" NO\n"), "cost mismatch:\n{t}");
        assert!(t.contains("Pi_PPSM"));
    }

    #[test]
    fn measure_extrapolation_consistent_with_direct() {
        // For a small model, extrapolated bytes must equal a direct run.
        let cfg = ModelConfig::bert_tiny().with_layers(4);
        let direct = measure_framework(FrameworkKind::Centaur, &cfg, 5, false).unwrap();
        let extrap = measure_framework(FrameworkKind::Centaur, &cfg, 5, true).unwrap();
        assert_eq!(direct.bytes_total(), extrap.bytes_total());
        assert_eq!(direct.rounds_total(), extrap.rounds_total());
    }

    #[test]
    fn fig7_ordering_tiny() {
        // Using tiny dims to keep runtime low: Centaur < all baselines.
        let cfg = ModelConfig::bert_tiny();
        let cent = measure_framework(FrameworkKind::Centaur, &cfg, 7, false).unwrap();
        for k in FrameworkKind::SMPC_BASELINES {
            let b = measure_framework(k, &cfg, 7, false).unwrap();
            assert!(
                b.bytes_total() > cent.bytes_total(),
                "{:?} {} !> centaur {}",
                k,
                b.bytes_total(),
                cent.bytes_total()
            );
        }
    }
}
