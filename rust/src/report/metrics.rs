//! Task metrics (paper §7.1): accuracy (QNLI/RTE), F1 (MRPC), Matthews
//! (CoLA), Pearson+Spearman (STS-B), perplexity (Wikitext).

use crate::data::{Split, TaskType};
use crate::model::{forward, ModelConfig, ModelWeights, Variant};
use crate::tensor::FloatTensor;

/// Predict class logits / regression value for every example.
pub fn predict(cfg: &ModelConfig, w: &ModelWeights, split: &Split, variant: Variant) -> Vec<Vec<f32>> {
    split.ids.iter().map(|ids| forward(cfg, w, ids, variant).row(0).to_vec()).collect()
}

/// Percent accuracy (argmax vs integer label).
pub fn accuracy(preds: &[Vec<f32>], labels: &[f32]) -> f64 {
    let hits = preds
        .iter()
        .zip(labels)
        .filter(|(p, &y)| argmax(p) == y as usize)
        .count();
    100.0 * hits as f64 / preds.len().max(1) as f64
}

/// Binary F1 (positive class 1), in percent.
pub fn f1(preds: &[Vec<f32>], labels: &[f32]) -> f64 {
    let (mut tp, mut fp, mut fnn) = (0.0f64, 0.0f64, 0.0f64);
    for (p, &y) in preds.iter().zip(labels) {
        let pred = argmax(p);
        match (pred, y as usize) {
            (1, 1) => tp += 1.0,
            (1, 0) => fp += 1.0,
            (0, 1) => fnn += 1.0,
            _ => {}
        }
    }
    let prec = tp / (tp + fp).max(1.0);
    let rec = tp / (tp + fnn).max(1.0);
    if prec + rec == 0.0 {
        0.0
    } else {
        100.0 * 2.0 * prec * rec / (prec + rec)
    }
}

/// Matthews correlation coefficient ×100 (CoLA).
pub fn matthews(preds: &[Vec<f32>], labels: &[f32]) -> f64 {
    let (mut tp, mut fp, mut fnn, mut tn) = (0.0f64, 0.0, 0.0, 0.0);
    for (p, &y) in preds.iter().zip(labels) {
        match (argmax(p), y as usize) {
            (1, 1) => tp += 1.0,
            (1, 0) => fp += 1.0,
            (0, 1) => fnn += 1.0,
            _ => tn += 1.0,
        }
    }
    let denom = ((tp + fp) * (tp + fnn) * (tn + fp) * (tn + fnn)).sqrt();
    if denom == 0.0 {
        0.0
    } else {
        100.0 * (tp * tn - fp * fnn) / denom
    }
}

/// Mean of Pearson and Spearman correlation ×100 (STS-B).
pub fn pearson_spearman(preds: &[Vec<f32>], labels: &[f32]) -> f64 {
    let xs: Vec<f64> = preds.iter().map(|p| p[0] as f64).collect();
    let ys: Vec<f64> = labels.iter().map(|&y| y as f64).collect();
    let pearson = corr(&xs, &ys);
    let spearman = corr(&ranks(&xs), &ranks(&ys));
    100.0 * (pearson + spearman) / 2.0
}

fn corr(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let (mut num, mut va, mut vb) = (0.0, 0.0, 0.0);
    for (&x, &y) in a.iter().zip(b) {
        num += (x - ma) * (y - mb);
        va += (x - ma) * (x - ma);
        vb += (y - mb) * (y - mb);
    }
    if va == 0.0 || vb == 0.0 {
        0.0
    } else {
        num / (va.sqrt() * vb.sqrt())
    }
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&i, &j| xs[i].partial_cmp(&xs[j]).unwrap());
    let mut r = vec![0.0; xs.len()];
    for (rank, &i) in idx.iter().enumerate() {
        r[i] = rank as f64;
    }
    r
}

fn argmax(p: &[f32]) -> usize {
    p.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).map(|(i, _)| i).unwrap_or(0)
}

/// Task-appropriate score (matches the paper's metric per dataset).
pub fn task_score(task: &str, ttype: TaskType, preds: &[Vec<f32>], labels: &[f32]) -> f64 {
    match (task, ttype) {
        ("mrpc", _) => f1(preds, labels),
        ("cola", _) => matthews(preds, labels),
        (_, TaskType::Reg) => pearson_spearman(preds, labels),
        _ => accuracy(preds, labels),
    }
}

/// Perplexity of a GPT-2 model over a corpus (PAD-masked next-token NLL).
pub fn perplexity(cfg: &ModelConfig, w: &ModelWeights, seqs: &[Vec<u32>], variant: Variant) -> f64 {
    let mut tot = 0.0f64;
    let mut cnt = 0.0f64;
    for seq in seqs {
        let logits = forward(cfg, w, seq, variant);
        for r in 0..seq.len() - 1 {
            let target = seq[r + 1];
            if target == 0 {
                continue; // PAD
            }
            tot += nll_row(logits.row(r), target as usize);
            cnt += 1.0;
        }
    }
    (tot / cnt.max(1.0)).exp()
}

fn nll_row(row: &[f32], target: usize) -> f64 {
    let m = row.iter().cloned().fold(f32::MIN, f32::max) as f64;
    let logsum = row.iter().map(|&v| ((v as f64) - m).exp()).sum::<f64>().ln() + m;
    logsum - row[target] as f64
}

/// Perplexity from already-computed logits (engine-output evaluation).
pub fn perplexity_from_logits(logits: &FloatTensor, seq: &[u32]) -> (f64, f64) {
    let mut tot = 0.0;
    let mut cnt = 0.0;
    for r in 0..seq.len() - 1 {
        let target = seq[r + 1];
        if target == 0 {
            continue;
        }
        tot += nll_row(logits.row(r), target as usize);
        cnt += 1.0;
    }
    (tot, cnt)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn onehotish(cls: usize) -> Vec<f32> {
        let mut v = vec![0.0; 2];
        v[cls] = 5.0;
        v
    }

    #[test]
    fn accuracy_and_f1_perfect() {
        let labels = vec![0.0, 1.0, 1.0, 0.0];
        let preds: Vec<Vec<f32>> = labels.iter().map(|&y| onehotish(y as usize)).collect();
        assert_eq!(accuracy(&preds, &labels), 100.0);
        assert_eq!(f1(&preds, &labels), 100.0);
        assert!((matthews(&preds, &labels) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn matthews_zero_for_constant_predictor() {
        let labels = vec![0.0, 1.0, 1.0, 0.0];
        let preds: Vec<Vec<f32>> = labels.iter().map(|_| onehotish(1)).collect();
        assert_eq!(matthews(&preds, &labels), 0.0);
    }

    #[test]
    fn pearson_spearman_monotone() {
        let labels = vec![0.0, 1.0, 2.0, 3.0, 4.0];
        let preds: Vec<Vec<f32>> = labels.iter().map(|&y| vec![y * 2.0 + 1.0]).collect();
        assert!((pearson_spearman(&preds, &labels) - 100.0).abs() < 1e-6);
    }

    #[test]
    fn nll_uniform() {
        let row = vec![0.0f32; 10];
        assert!((nll_row(&row, 3) - (10.0f64).ln()).abs() < 1e-9);
    }
}
