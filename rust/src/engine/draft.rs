//! Public greedy draft sources for speculative decode.
//!
//! Speculative decode (DESIGN.md §Speculative decode) splits each decode
//! step into a cheap public *draft* phase and one private *verify* flight
//! chain: a draft source proposes k tokens, the engine absorbs all k as
//! extra lanes on the batched schedule, and the accept rule keeps the
//! longest prefix the private model's own greedy choices agree with.
//!
//! Both sources here are **public by construction** — CENTAUR's principle
//! of pushing work outside the SMPC hot path without new assumptions:
//!
//! - [`Draft::TinyModel`] runs the plaintext reference forward over the
//!   emitted prefix. The token stream is already public output (it is
//!   returned to the client and seen by P1's scheduler), so conditioning a
//!   public model on it reveals nothing new.
//! - [`Draft::Ngram`] uses bigram statistics over the emitted prefix
//!   itself — exactly the data P1 already holds.
//!
//! [`Draft::Adversarial`] is a test-only worst case: it proposes a token
//! greedy decoding can never emit, so every proposal is rejected and the
//! speculative path degenerates to one accepted (corrected) token per
//! verify step — the rollback machinery's stress diet.

use crate::data::{greedy_regular_token, NUM_SPECIAL_TOKENS};
use crate::model::{forward, ModelConfig, ModelWeights, Variant};

/// A public greedy draft source: proposes the next k tokens given the
/// (public) emitted token history. Proposals are deterministic in the
/// history, which is what makes speculative output reproducible enough to
/// pin token-for-token in the parity tests.
pub enum Draft {
    /// Plaintext tiny-model forward over the history, greedy, iterated.
    /// When serving drafts with the same weights the private model uses,
    /// disagreements come only from fixed-point noise — acceptance is
    /// near-total and k tokens ride almost every verify step.
    TinyModel {
        /// Draft model shape (its `n_ctx` bounds the conditioning window).
        cfg: ModelConfig,
        /// Draft model weights (public — e.g. the serving weights).
        weights: ModelWeights,
    },
    /// Bigram most-frequent-successor statistics over the emitted prefix,
    /// falling back to repeating the last token for unseen contexts. No
    /// model at all — the cheapest possible draft, useful when no public
    /// weights are available.
    Ngram,
    /// Always proposes token 0 (a special token greedy decoding never
    /// emits): every proposal is rejected. Test-only worst case.
    Adversarial,
}

impl Draft {
    /// A tiny-model draft from (a copy of) public weights.
    pub fn tiny(cfg: &ModelConfig, weights: &ModelWeights) -> Draft {
        Draft::TinyModel { cfg: cfg.clone(), weights: weights.clone() }
    }

    /// Short display name for metrics and bench tables.
    pub fn name(&self) -> &'static str {
        match self {
            Draft::TinyModel { .. } => "tiny-model",
            Draft::Ngram => "ngram",
            Draft::Adversarial => "adversarial",
        }
    }

    /// Propose the next `k` tokens after `history` (prompt + every emitted
    /// token), greedily and deterministically.
    pub fn propose(&self, history: &[u32], k: usize) -> Vec<u32> {
        match self {
            Draft::TinyModel { cfg, weights } => {
                let mut ctxt: Vec<u32> = history.to_vec();
                let mut out = Vec::with_capacity(k);
                for _ in 0..k {
                    let lo = ctxt.len().saturating_sub(cfg.n_ctx);
                    let logits = forward(cfg, weights, &ctxt[lo..], Variant::Exact);
                    let next = greedy_regular_token(logits.row(logits.rows() - 1));
                    ctxt.push(next);
                    out.push(next);
                }
                out
            }
            Draft::Ngram => {
                let mut ctxt: Vec<u32> = history.to_vec();
                let mut out = Vec::with_capacity(k);
                for _ in 0..k {
                    let next = bigram_next(&ctxt);
                    ctxt.push(next);
                    out.push(next);
                }
                out
            }
            Draft::Adversarial => vec![0; k],
        }
    }
}

/// Most frequent successor of the last token within `ctxt`, ties resolved
/// to the smallest token id; repeats the last regular token (or the first
/// regular id) when the context gives no bigram evidence.
fn bigram_next(ctxt: &[u32]) -> u32 {
    let last = match ctxt.last() {
        Some(&t) => t,
        None => return NUM_SPECIAL_TOKENS as u32,
    };
    let mut counts: std::collections::BTreeMap<u32, u32> = std::collections::BTreeMap::new();
    for w in ctxt.windows(2) {
        if w[0] == last && (w[1] as usize) >= NUM_SPECIAL_TOKENS {
            *counts.entry(w[1]).or_insert(0) += 1;
        }
    }
    match counts.into_iter().max_by_key(|&(t, c)| (c, std::cmp::Reverse(t))) {
        Some((t, _)) => t,
        None if (last as usize) >= NUM_SPECIAL_TOKENS => last,
        None => NUM_SPECIAL_TOKENS as u32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ngram_prefers_most_frequent_successor() {
        // 5→6 twice, 5→7 once: propose 6 after 5.
        let hist = vec![5, 6, 5, 7, 5, 6, 5];
        assert_eq!(Draft::Ngram.propose(&hist, 1), vec![6]);
    }

    #[test]
    fn ngram_falls_back_to_repeating_unseen_last_token() {
        let hist = vec![5, 6, 9];
        assert_eq!(Draft::Ngram.propose(&hist, 2), vec![9, 9]);
    }

    #[test]
    fn ngram_never_proposes_special_tokens() {
        let hist = vec![0, 0, 0];
        for t in Draft::Ngram.propose(&hist, 3) {
            assert!((t as usize) >= NUM_SPECIAL_TOKENS);
        }
    }

    #[test]
    fn adversarial_proposes_unemittable_specials() {
        assert_eq!(Draft::Adversarial.propose(&[5, 6], 3), vec![0, 0, 0]);
    }

    #[test]
    fn tiny_model_draft_is_deterministic() {
        let cfg = ModelConfig::gpt2_tiny();
        let w = ModelWeights::random(&cfg, 7);
        let d = Draft::tiny(&cfg, &w);
        let a = d.propose(&[5, 6, 7], 4);
        let b = d.propose(&[5, 6, 7], 4);
        assert_eq!(a, b);
        assert_eq!(a.len(), 4);
        for &t in &a {
            assert!((t as usize) >= NUM_SPECIAL_TOKENS && (t as usize) < cfg.vocab);
        }
    }
}
