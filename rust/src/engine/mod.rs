//! The Centaur inference engine: end-to-end PPTI across `P0/P1/P2`
//! (paper §5.1, Fig. 5/6).
//!
//! Lifecycle:
//! 1. **Initialization** (paper: model developer side) — draw `Π`, build
//!    the permuted parameter set Θ′ ([`PermutedModel`]), deal the shared
//!    permutation matrices for `Π_PPP`.
//! 2. **Inference** — client shares its one-hot input; the servers run
//!    `Π_PPEmbedding` → `L×` transformer layers → `Π_PPAdaptation`; logit
//!    shares return to the client.
//!
//! All communication lands in [`crate::net::CostLedger`]; every plaintext
//! P1 reconstructs is recorded in [`views::Views`].

pub mod audit;
pub mod decoder;
pub mod draft;
pub mod views;

use crate::model::{ModelConfig, ModelKind, ModelWeights, PermSet, PermutedModel};
use crate::mpc::{Mpc, Share};
use crate::net::{CostLedger, NetSim, NetworkProfile, OpClass};
use crate::protocols::{adaptation, embedding, layer, ppp};
use crate::runtime::{Backend, NativeBackend};
use crate::tensor::{FloatTensor, RingTensor};
use crate::util::rng::Rng;
use crate::Result;
use views::Views;

/// Engine construction options.
pub struct EngineOptions {
    /// Simulated network conditions for the cost model.
    pub profile: NetworkProfile,
    /// Seed for permutations, share masks, and the dealer PRG.
    pub seed: u64,
    /// Keep P1's observed tensors (attack experiments).
    pub record_views: bool,
    /// Charged-ideal share×share products (paper-scale efficiency runs).
    pub fast_sim: bool,
    /// Shared offline-phase pool: when set, the dealer pops pre-generated
    /// Beaver triples instead of generating them on the request path
    /// (serving amortization — see [`crate::mpc::TriplePool`]).
    pub triple_pool: Option<std::sync::Arc<crate::mpc::TriplePool>>,
    /// Fixed-operand correlated triples for incremental decode (DESIGN.md
    /// §Fixed-operand correlations): the session-fixed π₁/π₁ᵀ operands and
    /// the write-once K cache ride one session mask each instead of a
    /// fresh Beaver triple per step. On by default; turn off to run the
    /// plain per-step path (the pre-correlation baseline benches compare
    /// against).
    pub decode_correlations: bool,
    /// Batched-opening decode schedule (DESIGN.md §Batched openings):
    /// coalesce each decode step's independent openings into shared
    /// flights — identical transfers and bytes, 16 rounds/token instead
    /// of 30 on gpt2-tiny. On by default; turn off to run the sequential
    /// per-opening schedule (the round-budget baseline benches compare
    /// against).
    pub round_batching: bool,
    /// Record a digest of every transferred payload in the [`crate::net`]
    /// transfer census (security tests); off by default.
    pub record_transfers: bool,
    /// Integrity-checked inference (DESIGN.md §Integrity-checked
    /// inference): SPDZ-style deferred share MACs batch-verified at step
    /// and request boundaries, plus the transfer census for the
    /// transcript wire chain. Zero perturbation: shares, ledgers, views,
    /// and tokens stay bit-identical to an audit-off run of the same
    /// seed. Defaults to the `CENTAUR_AUDIT` environment variable
    /// (`1`/`true` = on).
    pub audit: bool,
}

/// Whether `CENTAUR_AUDIT` asks for integrity-checked mode by default.
pub fn audit_env_default() -> bool {
    matches!(
        std::env::var("CENTAUR_AUDIT").ok().as_deref(),
        Some("1") | Some("true") | Some("on")
    )
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            profile: NetworkProfile::lan(),
            seed: 7,
            record_views: false,
            fast_sim: false,
            triple_pool: None,
            decode_correlations: true,
            round_batching: true,
            record_transfers: false,
            audit: audit_env_default(),
        }
    }
}

/// Result of one private inference.
pub struct InferenceOutput {
    /// BERT: `(1, n_classes)`; GPT-2: `(n, vocab)` logits.
    pub logits: FloatTensor,
    /// Communication + compute ledger for this inference.
    pub stats: CostLedger,
}

/// The three-party Centaur engine.
pub struct CentaurEngine {
    /// Model shape being served.
    pub cfg: ModelConfig,
    pm: PermutedModel,
    mpc: Mpc,
    backend: Box<dyn Backend>,
    /// P1's observation ledger (security bookkeeping).
    pub views: Views,
    pi1_sh: Share,
    pi1_t_sh: Share,
    mask_fx: Option<RingTensor>,
    fast_sim: bool,
    decode_correlations: bool,
    round_batching: bool,
    /// Ledger snapshot taken at construction (perm dealing cost).
    init_ledger: CostLedger,
}

impl CentaurEngine {
    /// Build with the native backend and default options.
    pub fn new(cfg: &ModelConfig, w: &ModelWeights, profile: NetworkProfile, seed: u64) -> Result<Self> {
        Self::with_backend(cfg, w, Box::new(NativeBackend::new()), EngineOptions { profile, seed, ..Default::default() })
    }

    /// Build with an explicit backend (e.g. [`crate::runtime::XlaBackend`]).
    pub fn with_backend(
        cfg: &ModelConfig,
        w: &ModelWeights,
        backend: Box<dyn Backend>,
        opts: EngineOptions,
    ) -> Result<Self> {
        let mut rng = Rng::new(opts.seed);
        let perms = PermSet::random(cfg, &mut rng);
        Self::with_perms(cfg, w, backend, opts, perms)
    }

    /// Build with explicit permutations (identity = leakage ablation).
    pub fn with_perms(
        cfg: &ModelConfig,
        w: &ModelWeights,
        backend: Box<dyn Backend>,
        opts: EngineOptions,
        perms: PermSet,
    ) -> Result<Self> {
        let pm = PermutedModel::build(cfg, w, perms);
        let mut mpc = Mpc::new(NetSim::new(opts.profile), opts.seed ^ 0xEE);
        // Audit mode needs the census for the transcript wire chain; the
        // MAC key derives from the seed without touching any protocol PRG,
        // so everything stays bit-identical to an audit-off run.
        mpc.net.record_transfers = opts.record_transfers || opts.audit;
        if let Some(pool) = &opts.triple_pool {
            mpc.dealer.attach_pool(std::sync::Arc::clone(pool));
        }
        if opts.audit {
            mpc.enable_audit(opts.seed);
        }
        // Deal the shared π₁ matrices once (Algorithm 6 setup).
        let pi1_sh = ppp::share_perm(&mut mpc, &pm.perms.pi1, OpClass::Linear);
        let pi1_t_sh = ppp::share_perm_t(&mut mpc, &pm.perms.pi1, OpClass::Linear);
        let mask_fx = (cfg.kind == ModelKind::Gpt2).then(|| layer::causal_mask_fx(cfg.h, cfg.n_ctx));
        let init_ledger = mpc.net.ledger.clone();
        Ok(CentaurEngine {
            cfg: cfg.clone(),
            pm,
            mpc,
            backend,
            views: Views::new(opts.record_views),
            pi1_sh,
            pi1_t_sh,
            mask_fx,
            fast_sim: opts.fast_sim,
            decode_correlations: opts.decode_correlations,
            round_batching: opts.round_batching,
            init_ledger,
        })
    }

    /// Permutations in use (client side needs π to unpermute outputs in
    /// the general case; our adaptation heads already cancel it).
    pub fn perms(&self) -> &PermSet {
        &self.pm.perms
    }

    /// Bytes of permuted parameters shipped to P1 at initialization.
    pub fn init_param_bytes(&self) -> u64 {
        self.pm.bytes()
    }

    /// Run one private inference over `tokens` (must be `n_ctx` long —
    /// pad with the tokenizer's PAD id; lengths below `n_ctx` are allowed
    /// and processed at the shorter length).
    pub fn infer(&mut self, tokens: &[u32]) -> Result<InferenceOutput> {
        anyhow::ensure!(!tokens.is_empty(), "empty input");
        anyhow::ensure!(tokens.len() <= self.cfg.n_ctx, "sequence too long");
        // π₁ was dealt at n_ctx; require full length for the PPP shapes.
        anyhow::ensure!(
            tokens.len() == self.cfg.n_ctx,
            "pad input to n_ctx={} (got {})",
            self.cfg.n_ctx,
            tokens.len()
        );
        self.mpc.net.reset();
        self.views.clear();

        let mut ctx = layer::ProtoCtx {
            mpc: &mut self.mpc,
            backend: self.backend.as_mut(),
            views: &mut self.views,
            fast_sim: self.fast_sim,
            // The full-sequence forward keeps the sequential schedule; the
            // batched flights are a decode-step specialization.
            round_batching: false,
        };
        // Embedding.
        let mut x_pi = embedding::pp_embedding(&mut ctx, &self.pm, tokens)?;
        // Transformer layers.
        for (i, pl) in self.pm.layers.iter().enumerate() {
            x_pi = layer::transformer_layer(
                &mut ctx,
                &self.cfg,
                pl,
                &self.pi1_sh,
                &self.pi1_t_sh,
                &x_pi,
                self.mask_fx.as_ref(),
                i,
            )?;
        }
        // Adaptation + return to client.
        let logits_sh = match self.cfg.kind {
            ModelKind::Bert => adaptation::pp_adaptation_bert(&mut ctx, &self.pm, &x_pi)?,
            ModelKind::Gpt2 => adaptation::pp_adaptation_gpt2(&mut ctx, &self.pm, &x_pi)?,
        };
        let logits = adaptation::return_to_client(&mut self.mpc, &logits_sh)?;
        // Request boundary: batch-verify every opening of this inference.
        self.mpc.flush_mac_checks()?;
        Ok(InferenceOutput { logits, stats: self.mpc.net.ledger.clone() })
    }

    /// Autoregressive generation through the private protocol (GPT-2 only)
    /// — the workload the paper's introduction motivates ("SMPC-based
    /// inference takes 25+ minutes per token"; Centaur makes it
    /// interactive). Runs **incrementally** over a secret-shared KV cache
    /// ([`decoder::DecoderSession`]): each step is a single-token forward
    /// instead of a whole-sequence re-run, and (by default) over
    /// fixed-operand correlated triples, so per-token communication drops
    /// ~20× versus [`CentaurEngine::generate_full_recompute`]. Returns the
    /// generated continuation and the total cost (correlation setup +
    /// prefill + decode).
    pub fn generate(&mut self, prompt: &[u32], steps: usize) -> Result<(Vec<u32>, CostLedger)> {
        let out = self.generate_streaming(prompt, steps, &mut |_, _, _| true)?;
        let total = out.total();
        Ok((out.tokens, total))
    }

    /// Streaming incremental generation: `on_token(index, token, step_cost)`
    /// fires after every generated token with that step's online ledger and
    /// returns whether to continue — `false` aborts the remaining steps
    /// (e.g. the serving client dropped its stream), yielding the tokens
    /// produced so far. Returns the tokens plus the correlation-setup /
    /// cold-prefill / warm-decode cost split.
    pub fn generate_streaming(
        &mut self,
        prompt: &[u32],
        steps: usize,
        on_token: &mut dyn FnMut(usize, u32, &CostLedger) -> bool,
    ) -> Result<decoder::GenOutcome> {
        anyhow::ensure!(!prompt.is_empty() && prompt.len() + steps <= self.cfg.n_ctx, "prompt+steps must fit n_ctx");
        let mut sess = decoder::DecoderSession::new(self, prompt)?;
        let mut tokens = Vec::with_capacity(steps);
        for i in 0..steps {
            let tok = sess.step_greedy()?;
            let keep_going = on_token(i, tok, sess.last_step_cost());
            tokens.push(tok);
            if !keep_going {
                break;
            }
        }
        let (setup, prefill, decode) =
            (sess.setup_cost().clone(), sess.prefill_cost().clone(), sess.decode_cost().clone());
        let transcript = sess.transcript();
        Ok(decoder::GenOutcome { tokens, setup, prefill, decode, transcript })
    }

    /// Speculative incremental generation (DESIGN.md §Speculative decode):
    /// like [`CentaurEngine::generate_streaming`], but each warm step
    /// verifies up to `spec_k` tokens — the session's own greedy lead plus
    /// `spec_k - 1` proposals from the public `draft` — in ONE batched
    /// flight chain, keeping the longest greedy-agreeing prefix and
    /// rolling the rest back. The emitted stream is token-for-token what
    /// plain greedy decode produces; rounds per *accepted* token drop
    /// toward (flight rounds)/spec_k as acceptance rises. Returns the
    /// outcome plus the accept/reject bookkeeping.
    pub fn generate_speculative(
        &mut self,
        prompt: &[u32],
        steps: usize,
        draft: &draft::Draft,
        spec_k: usize,
    ) -> Result<(decoder::GenOutcome, decoder::SpeculativeState)> {
        anyhow::ensure!(spec_k >= 1, "spec_k must be >= 1");
        anyhow::ensure!(!prompt.is_empty() && prompt.len() + steps <= self.cfg.n_ctx, "prompt+steps must fit n_ctx");
        let mut sess = decoder::DecoderSession::new(self, prompt)?;
        let mut tokens = Vec::with_capacity(steps);
        while tokens.len() < steps {
            let k = spec_k.min(steps - tokens.len());
            tokens.extend(sess.step_speculative(draft, k)?);
        }
        let spec = *sess.speculative();
        let (setup, prefill, decode) =
            (sess.setup_cost().clone(), sess.prefill_cost().clone(), sess.decode_cost().clone());
        let transcript = sess.transcript();
        Ok((decoder::GenOutcome { tokens, setup, prefill, decode, transcript }, spec))
    }

    /// The pre-KV-cache generation path: re-run the full padded forward
    /// pass for every token (kept as the baseline the cache is measured
    /// against, and as a parity oracle for the incremental path).
    pub fn generate_full_recompute(&mut self, prompt: &[u32], steps: usize) -> Result<(Vec<u32>, CostLedger)> {
        anyhow::ensure!(self.cfg.kind == ModelKind::Gpt2, "generate() needs a decoder model");
        anyhow::ensure!(!prompt.is_empty() && prompt.len() + steps <= self.cfg.n_ctx, "prompt+steps must fit n_ctx");
        let mut ctx: Vec<u32> = prompt.to_vec();
        let mut total = CostLedger::new();
        for _ in 0..steps {
            let mut padded = ctx.clone();
            padded.resize(self.cfg.n_ctx, 0); // PAD; causal mask keeps them inert
            let out = self.infer(&padded)?;
            total.merge(&out.stats);
            let next = crate::data::greedy_regular_token(out.logits.row(ctx.len() - 1));
            ctx.push(next);
        }
        Ok((ctx[prompt.len()..].to_vec(), total))
    }

    /// One-time initialization communication (permutation dealing).
    pub fn init_stats(&self) -> &CostLedger {
        &self.init_ledger
    }

    /// Leak check: labels of unpermuted plaintext P1 observed (must be
    /// empty for real permutations).
    pub fn leaks(&self) -> Vec<&str> {
        self.views.leaks()
    }

    /// Recorded transfer census (empty unless
    /// [`EngineOptions::record_transfers`]); spans every protocol run
    /// since construction — the security tests compare the payload
    /// multisets of two schedules with it.
    pub fn transfer_log(&self) -> &[crate::net::TransferRecord] {
        &self.mpc.net.transfer_log
    }

    /// Whether integrity-checked mode is on ([`EngineOptions::audit`]).
    pub fn audit_enabled(&self) -> bool {
        self.mpc.audit_enabled()
    }

    /// Audit counters so far (`None` when audit is off) — MAC checks,
    /// failures, and audit-only overhead, never charged to the protocol
    /// ledger (see [`crate::mpc::AuditCounters`]).
    pub fn audit_counters(&self) -> Option<crate::mpc::AuditCounters> {
        self.mpc.audit_counters()
    }

    /// MAC-covered openings so far (the target domain of
    /// [`CentaurEngine::inject_share_fault`]); 0 when audit is off.
    pub fn audit_open_count(&self) -> u64 {
        self.mpc.audit_open_count()
    }

    /// Transfers executed by this engine's network so far (the target
    /// domain of [`CentaurEngine::schedule_tamper`]).
    pub fn transfer_count(&self) -> u64 {
        self.mpc.net.transfer_seq
    }

    /// Wire-level faults the tamper harness actually landed.
    pub fn faults_applied(&self) -> u64 {
        self.mpc.net.faults_applied
    }

    /// Schedule a single-shot wire fault (tamper harness — see
    /// [`crate::net::TamperPlan`]).
    pub fn schedule_tamper(&mut self, plan: crate::net::TamperPlan) {
        self.mpc.net.schedule_tamper(plan);
    }

    /// Schedule a single-shot share fault (tamper harness). Returns false
    /// when audit is off.
    pub fn inject_share_fault(&mut self, fault: crate::mpc::ShareFault) -> bool {
        self.mpc.inject_share_fault(fault)
    }

    /// Backend fallback count (XLA backend health check).
    pub fn backend_fallbacks(&self) -> u64 {
        self.backend.fallbacks()
    }

    /// Label of the active P1 backend.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{plaintext, Variant};

    fn tiny_tokens(cfg: &ModelConfig, seed: u64) -> Vec<u32> {
        let mut rng = Rng::new(seed);
        (0..cfg.n_ctx).map(|_| (rng.below(cfg.vocab - 4) + 4) as u32).collect()
    }

    #[test]
    fn bert_centaur_matches_plaintext() {
        let cfg = ModelConfig::bert_tiny();
        let w = ModelWeights::random(&cfg, 61);
        let tokens = tiny_tokens(&cfg, 62);
        let mut engine = CentaurEngine::new(&cfg, &w, NetworkProfile::lan(), 63).unwrap();
        let out = engine.infer(&tokens).unwrap();
        let want = plaintext::forward(&cfg, &w, &tokens, Variant::Exact);
        assert_eq!(out.logits.shape(), (1, cfg.n_classes));
        let diff = out.logits.max_abs_diff(&want);
        assert!(diff < 0.05, "centaur vs plaintext diff {diff}");
        // no unpermuted plaintext at P1
        assert!(engine.leaks().is_empty());
        // communication happened
        assert!(out.stats.bytes_total() > 0);
        assert!(out.stats.rounds_total() > 0);
    }

    #[test]
    fn gpt_centaur_matches_plaintext() {
        let cfg = ModelConfig::gpt2_tiny();
        let w = ModelWeights::random(&cfg, 64);
        let tokens = tiny_tokens(&cfg, 65);
        let mut engine = CentaurEngine::new(&cfg, &w, NetworkProfile::wan1(), 66).unwrap();
        let out = engine.infer(&tokens).unwrap();
        let want = plaintext::forward(&cfg, &w, &tokens, Variant::Exact);
        assert_eq!(out.logits.shape(), (cfg.n_ctx, cfg.vocab));
        // compare argmax per position (fixed-point noise over vocab logits)
        let mut agree = 0;
        for r in 0..cfg.n_ctx {
            let am = |t: &FloatTensor| {
                (0..cfg.vocab).max_by(|&a, &b| t.get(r, a).partial_cmp(&t.get(r, b)).unwrap()).unwrap()
            };
            if am(&out.logits) == am(&want) {
                agree += 1;
            }
        }
        assert!(agree * 10 >= cfg.n_ctx * 9, "argmax agreement {agree}/{}", cfg.n_ctx);
        assert!(engine.leaks().is_empty());
    }

    #[test]
    fn fast_sim_same_costs_as_full() {
        let cfg = ModelConfig::bert_tiny();
        let w = ModelWeights::random(&cfg, 67);
        let tokens = tiny_tokens(&cfg, 68);
        let run = |fast_sim: bool| {
            let mut e = CentaurEngine::with_backend(
                &cfg,
                &w,
                Box::new(NativeBackend::new()),
                EngineOptions { fast_sim, seed: 69, ..Default::default() },
            )
            .unwrap();
            let out = e.infer(&tokens).unwrap();
            (out.stats.bytes_total(), out.stats.rounds_total(), out.logits)
        };
        let (b_full, r_full, l_full) = run(false);
        let (b_fast, r_fast, l_fast) = run(true);
        assert_eq!(b_full, b_fast, "fast-sim must charge identical bytes");
        assert_eq!(r_full, r_fast, "fast-sim must charge identical rounds");
        assert!(l_full.max_abs_diff(&l_fast) < 0.05);
    }

    /// The fast-sim execution mode must charge byte/round-identical
    /// ledgers for correlated decode too (the charged-ideal twins of the
    /// fixed-operand protocols) — the same invariant
    /// [`fast_sim_same_costs_as_full`] pins for one-shot inference.
    #[test]
    fn fast_sim_decode_same_costs_as_full() {
        let cfg = ModelConfig::gpt2_tiny();
        let w = ModelWeights::random(&cfg, 95);
        let run = |fast_sim: bool| {
            let mut e = CentaurEngine::with_backend(
                &cfg,
                &w,
                Box::new(NativeBackend::new()),
                EngineOptions { fast_sim, seed: 96, ..Default::default() },
            )
            .unwrap();
            let out = e.generate_streaming(&[5, 9, 13], 3, &mut |_, _, _| true).unwrap();
            (
                out.setup.bytes_total(),
                out.prefill.bytes_total(),
                out.decode.bytes_total(),
                out.total().rounds_total(),
            )
        };
        assert_eq!(run(false), run(true), "fast-sim decode must charge identical ledgers");
    }

    #[test]
    fn views_record_attack_surface() {
        let cfg = ModelConfig::bert_tiny();
        let w = ModelWeights::random(&cfg, 70);
        let tokens = tiny_tokens(&cfg, 71);
        let mut e = CentaurEngine::with_backend(
            &cfg,
            &w,
            Box::new(NativeBackend::new()),
            EngineOptions { record_views: true, seed: 72, ..Default::default() },
        )
        .unwrap();
        e.infer(&tokens).unwrap();
        // per layer: O1π₁ softmax input, two LN inputs, one GeLU input
        assert!(e.views.find("O1pi1 layer0").is_some());
        assert!(e.views.find("O5pi2 layer1").is_some());
        assert!(e.views.find("pooler pre-tanh").is_some());
        let o1 = e.views.find("O1pi1 layer0").unwrap();
        assert_eq!((o1.rows, o1.cols), (cfg.h * cfg.n_ctx, cfg.n_ctx));
        assert!(o1.tensor.is_some());
    }

    #[test]
    fn generate_is_private_and_matches_plaintext_greedy() {
        let cfg = ModelConfig::gpt2_tiny();
        let w = ModelWeights::random(&cfg, 75);
        let prompt: Vec<u32> = vec![7, 11, 13, 17];
        let mut e = CentaurEngine::new(&cfg, &w, NetworkProfile::lan(), 76).unwrap();
        let (gen, cost) = e.generate_full_recompute(&prompt, 3).unwrap();
        assert_eq!(gen.len(), 3);
        assert!(cost.bytes_total() > 0);
        assert!(e.leaks().is_empty());
        // plaintext greedy reference
        let mut ctx = prompt.clone();
        for _ in 0..3 {
            let mut padded = ctx.clone();
            padded.resize(cfg.n_ctx, 0);
            let logits = plaintext::forward(&cfg, &w, &padded, Variant::Exact);
            let next = crate::data::greedy_regular_token(logits.row(ctx.len() - 1));
            ctx.push(next);
        }
        assert_eq!(gen, ctx[prompt.len()..].to_vec(), "private greedy decode must match plaintext");
    }

    /// The headline KV-cache claim (PR 2 acceptance criterion): for an
    /// 8-step generation at `n_ctx = 64`, warm incremental decode moves at
    /// least 3× fewer online bytes per token than full recomputation —
    /// pinned on the **plain** per-step path (correlations off) so the
    /// PR 2 floor stays asserted independently of the fixed-operand win.
    /// Byte charges are deterministic, so the bound is exact.
    #[test]
    fn incremental_decode_at_least_3x_less_comm_than_full_recompute() {
        let cfg = ModelConfig::gpt2_tiny().with_n_ctx(64);
        let w = ModelWeights::random(&cfg, 81);
        let prompt: Vec<u32> = vec![7, 11, 13, 17];
        let steps = 8;
        let mut full_e = CentaurEngine::new(&cfg, &w, NetworkProfile::lan(), 82).unwrap();
        let (full_gen, full_cost) = full_e.generate_full_recompute(&prompt, steps).unwrap();
        let mut inc_e = CentaurEngine::with_backend(
            &cfg,
            &w,
            Box::new(NativeBackend::new()),
            EngineOptions { seed: 82, decode_correlations: false, ..Default::default() },
        )
        .unwrap();
        let (inc_gen, inc_cost) = inc_e.generate(&prompt, steps).unwrap();
        assert_eq!(full_gen.len(), steps);
        assert_eq!(inc_gen.len(), steps);
        assert!(inc_e.leaks().is_empty(), "multi-step decode must stay leak-free");
        // Total (even including the incremental path's prompt prefill):
        assert!(
            full_cost.bytes_total() >= 3 * inc_cost.bytes_total(),
            "full recompute {} B vs incremental {} B — less than 3x apart",
            full_cost.bytes_total(),
            inc_cost.bytes_total()
        );
        // With the batched-opening schedule (the default), the incremental
        // session also wins on rounds despite absorbing prompt + steps
        // (12 absorbs × 16 rounds) where recompute runs steps full
        // forwards (8 × 30) — PR 2's "rounds do not shrink" caveat is
        // retired by round compression (DESIGN.md §Batched openings).
        assert!(
            inc_cost.rounds_total() < full_cost.rounds_total(),
            "batched incremental decode must also cut total rounds: {} vs {}",
            inc_cost.rounds_total(),
            full_cost.rounds_total()
        );
    }

    /// The ISSUE 4 acceptance criterion, pinned at the engine level: with
    /// fixed-operand correlations, warm-step decode communication at
    /// `n_ctx = 64` is ≥1.8× lower than the plain per-step (PR 2) path.
    /// Byte charges are deterministic, so the bound is exact.
    #[test]
    fn correlated_decode_warm_step_at_least_1_8x_less_comm_than_plain() {
        let cfg = ModelConfig::gpt2_tiny().with_n_ctx(64);
        let w = ModelWeights::random(&cfg, 91);
        let prompt: Vec<u32> = vec![7, 11];
        let steps = 2usize;
        let run = |decode_correlations: bool| {
            let mut e = CentaurEngine::with_backend(
                &cfg,
                &w,
                Box::new(NativeBackend::new()),
                EngineOptions { seed: 92, decode_correlations, ..Default::default() },
            )
            .unwrap();
            let out = e.generate_streaming(&prompt, steps, &mut |_, _, _| true).unwrap();
            assert!(e.leaks().is_empty());
            (out.setup.bytes_total(), out.decode.bytes_total() / steps as u64)
        };
        let (corr_setup, corr_tok) = run(true);
        let (plain_setup, plain_tok) = run(false);
        assert_eq!(plain_setup, 0, "plain sessions have no correlation setup");
        assert!(corr_setup > 0);
        assert!(
            plain_tok * 10 >= corr_tok * 18,
            "correlated warm step must be >=1.8x cheaper: plain {plain_tok} B vs corr {corr_tok} B \
             ({:.2}x)",
            plain_tok as f64 / corr_tok as f64
        );
        // the one-time setup breaks even within two warm steps
        assert!(corr_setup <= 2 * (plain_tok - corr_tok), "setup must amortize within two steps");
    }

    #[test]
    fn streaming_decode_reports_per_step_costs_and_phase_split() {
        let cfg = ModelConfig::gpt2_tiny();
        let w = ModelWeights::random(&cfg, 83);
        let mut e = CentaurEngine::new(&cfg, &w, NetworkProfile::lan(), 84).unwrap();
        let prompt: Vec<u32> = vec![5, 9, 21];
        let mut seen: Vec<(usize, u32, u64)> = Vec::new();
        let out = e
            .generate_streaming(&prompt, 4, &mut |i, tok, step| {
                seen.push((i, tok, step.bytes_total()));
                true
            })
            .unwrap();
        assert_eq!(out.tokens.len(), 4);
        assert_eq!(seen.iter().map(|s| s.1).collect::<Vec<_>>(), out.tokens);
        assert_eq!(seen.iter().map(|s| s.0).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        // Per-step cost is position-independent (fixed cache shape), so the
        // phase split is exactly proportional to absorb counts: 3 vs 4.
        assert!(seen.windows(2).all(|w| w[0].2 == w[1].2), "steps must cost the same");
        assert_eq!(out.prefill.bytes_total() * 4, out.decode.bytes_total() * 3);
        // one-time correlation setup is attributed separately (and only to
        // the Correlation class), so warm-step ledgers stay clean
        assert!(out.setup.bytes_total() > 0, "default sessions set up correlations");
        assert_eq!(out.setup.bytes_total(), out.setup.class(OpClass::Correlation).bytes);
        // the shared-π₁ session mask keeps setup layer-independent: exactly
        // two masked openings (π₁ − B, π₁ᵀ − B') regardless of n_layers
        let n = cfg.n_ctx as u64;
        assert_eq!(out.setup.bytes_total(), 2 * 2 * 8 * n * n);
        assert_eq!(out.setup.rounds_total(), 2);
        assert_eq!(
            out.total().bytes_total(),
            out.setup.bytes_total() + out.prefill.bytes_total() + out.decode.bytes_total()
        );
        // Specials are never emitted.
        assert!(out.tokens.iter().all(|&t| (t as usize) >= crate::data::NUM_SPECIAL_TOKENS));
        assert!(e.leaks().is_empty());
    }

    #[test]
    fn streaming_decode_aborts_when_callback_stops() {
        // A `false` from the callback (serving: client dropped its stream)
        // must end the generation with the tokens produced so far instead
        // of burning the remaining steps.
        let cfg = ModelConfig::gpt2_tiny();
        let w = ModelWeights::random(&cfg, 87);
        let mut e = CentaurEngine::new(&cfg, &w, NetworkProfile::lan(), 88).unwrap();
        let out = e.generate_streaming(&[5, 9], 6, &mut |i, _, _| i < 1).unwrap();
        assert_eq!(out.tokens.len(), 2, "abort right after the second token");
        assert!(out.decode.bytes_total() > 0);
    }

    #[test]
    fn decoder_session_enforces_context_bounds() {
        let cfg = ModelConfig::gpt2_tiny();
        let w = ModelWeights::random(&cfg, 85);
        let mut e = CentaurEngine::new(&cfg, &w, NetworkProfile::lan(), 86).unwrap();
        // prompt + steps beyond n_ctx is rejected up front
        assert!(e.generate(&vec![5; cfg.n_ctx], 1).is_err());
        // a session can absorb exactly up to n_ctx then refuses
        let mut sess = decoder::DecoderSession::new(&mut e, &[5, 6, 7]).unwrap();
        assert_eq!(sess.position(), 3);
        assert_eq!(sess.logits().shape(), (1, cfg.vocab));
        while sess.remaining() > 0 {
            sess.absorb(9).unwrap();
        }
        assert!(sess.absorb(9).is_err(), "context window exhausted");
    }

    #[test]
    fn generate_rejects_encoder_models() {
        let cfg = ModelConfig::bert_tiny();
        let w = ModelWeights::random(&cfg, 77);
        let mut e = CentaurEngine::new(&cfg, &w, NetworkProfile::lan(), 78).unwrap();
        assert!(e.generate(&[1, 2], 2).is_err());
    }

    #[test]
    fn rejects_bad_lengths() {
        let cfg = ModelConfig::bert_tiny();
        let w = ModelWeights::random(&cfg, 73);
        let mut e = CentaurEngine::new(&cfg, &w, NetworkProfile::lan(), 74).unwrap();
        assert!(e.infer(&[]).is_err());
        assert!(e.infer(&vec![1; cfg.n_ctx + 1]).is_err());
        assert!(e.infer(&vec![1; cfg.n_ctx - 1]).is_err());
    }
}
