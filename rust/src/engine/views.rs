//! Per-party view ledger — the security bookkeeping of DESIGN.md §Security.
//!
//! Every plaintext value the cloud party `P1` reconstructs during a
//! `Π_PP*` protocol is recorded here with its permutation tag. The leak
//! detector asserts that no *unpermuted* activation ever appears in P1's
//! view; the attack harness replays exactly these tensors as the
//! adversary's observations (Table 2/4 of the paper).

use crate::tensor::FloatTensor;

/// Which permutation protects a value P1 sees (None = plaintext leak).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PermTag {
    /// Feature permutation π (d-dim streams: O4+X, O6, pooler input).
    Pi,
    /// Sequence permutation π₁ (attention scores O1, probs O2).
    Pi1,
    /// FFN-intermediate permutation π₂ (O5).
    Pi2,
    /// Unpermuted plaintext — only legal in the PermOnly baseline and in
    /// failure-injection tests; the leak detector flags it.
    None,
}

/// One observation by P1.
#[derive(Clone, Debug)]
pub struct ViewRecord {
    /// Where in the protocol the observation happened.
    pub label: String,
    /// Permutation under which the tensor was observed.
    pub tag: PermTag,
    /// Tensor payload (kept only when `record_tensors` is on).
    pub tensor: Option<FloatTensor>,
    /// Observed row count.
    pub rows: usize,
    /// Observed column count.
    pub cols: usize,
}

/// The cloud party's accumulated view.
#[derive(Debug, Default)]
pub struct Views {
    /// Everything P1 reconstructed, in order.
    pub p1: Vec<ViewRecord>,
    /// Keep tensor payloads (attack experiments); off by default to save
    /// memory during benches.
    pub record_tensors: bool,
}

impl Views {
    /// Fresh ledger; `record_tensors` keeps payloads.
    pub fn new(record_tensors: bool) -> Self {
        Views { p1: Vec::new(), record_tensors }
    }

    /// Record a plaintext reconstruction at P1.
    pub fn observe_p1(&mut self, label: impl Into<String>, tensor: &FloatTensor, tag: PermTag) {
        self.p1.push(ViewRecord {
            label: label.into(),
            tag,
            tensor: self.record_tensors.then(|| tensor.clone()),
            rows: tensor.rows(),
            cols: tensor.cols(),
        });
    }

    /// Leak detector: labels of unpermuted plaintext observations.
    pub fn leaks(&self) -> Vec<&str> {
        self.p1.iter().filter(|r| r.tag == PermTag::None).map(|r| r.label.as_str()).collect()
    }

    /// Find the first recorded observation whose label contains `pat`.
    pub fn find(&self, pat: &str) -> Option<&ViewRecord> {
        self.p1.iter().find(|r| r.label.contains(pat))
    }

    /// Drop all records (new inference).
    pub fn clear(&mut self) {
        self.p1.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn leak_detector_flags_unpermuted() {
        let mut v = Views::new(false);
        let t = FloatTensor::zeros(2, 2);
        v.observe_p1("softmax_in layer0", &t, PermTag::Pi1);
        v.observe_p1("oops plaintext", &t, PermTag::None);
        assert_eq!(v.leaks(), vec!["oops plaintext"]);
    }

    #[test]
    fn tensors_kept_only_when_recording() {
        let t = FloatTensor::zeros(2, 3);
        let mut off = Views::new(false);
        off.observe_p1("a", &t, PermTag::Pi);
        assert!(off.p1[0].tensor.is_none());
        assert_eq!((off.p1[0].rows, off.p1[0].cols), (2, 3));
        let mut on = Views::new(true);
        on.observe_p1("a", &t, PermTag::Pi);
        assert!(on.p1[0].tensor.is_some());
    }
}
