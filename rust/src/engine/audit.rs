//! Replayable per-request transcript digests (integrity-checked mode,
//! DESIGN.md §Integrity-checked inference).
//!
//! A [`RequestTranscript`] is an ordered commitment to everything a decode
//! request *does* that an honest re-execution must reproduce:
//!
//! - one [`StepCommit`] per protocol step (session setup, each prefill
//!   absorb, each decode flight chain) holding the step's per-
//!   [`OpClass`] byte and round deltas and its lane width;
//! - every token the session absorbed or emitted, in order;
//! - optionally (full execution mode with the transfer census on) the
//!   [`crate::net::NetSim::wire_digest`] — a rolling chain over every
//!   transferred payload.
//!
//! The **core digest** — the rolling FNV fold over step commits and
//! tokens — deliberately commits only to quantities that are pinned
//! mode-, profile-, and kernel-independent elsewhere in the test suite
//! (ledger charges and greedy tokens), so the same seeded request yields
//! the *same* core digest under fast-sim or full execution, `lan` or
//! `wan3`, scalar or SIMD ring kernels (`rust/tests/audit.rs` pins this).
//! The **wire component** is the opposite trade: it commits to the actual
//! payload bits, so it only exists for full-mode runs with the census on,
//! and it catches any single-bit payload change — including tampering
//! with one-way transfers the share-MAC does not cover (resharings,
//! client share halves).
//!
//! [`verify_transcript`] re-executes a request (the caller supplies the
//! re-execution — a fresh engine driven with the same seed and inputs)
//! and reports the **first divergence** between the recorded and replayed
//! transcripts: which step, which field, or which token.

use crate::net::{fnv1a_fold, CostLedger, OpClass, FNV_OFFSET};

/// Which session phase a step belongs to (part of the commitment — a
/// replay that moves bytes between phases must not verify).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepPhase {
    /// One-time session-correlation setup (`OpClass::Correlation`).
    Setup,
    /// Cold prefill (prompt absorption).
    Prefill,
    /// Warm decode (generated tokens / verify flight chains).
    Decode,
}

impl StepPhase {
    fn tag(self) -> u64 {
        match self {
            StepPhase::Setup => 1,
            StepPhase::Prefill => 2,
            StepPhase::Decode => 3,
        }
    }
    /// Display label.
    pub fn name(self) -> &'static str {
        match self {
            StepPhase::Setup => "setup",
            StepPhase::Prefill => "prefill",
            StepPhase::Decode => "decode",
        }
    }
}

/// Commitment to one protocol step: lane width plus the step ledger's
/// per-class byte and round deltas, in [`OpClass::ALL`] order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StepCommit {
    /// Session phase of the step.
    pub phase: StepPhase,
    /// Lanes the step carried (tokens absorbed across all sessions).
    pub lanes: u32,
    /// Per-class bytes of the step, in ledger order.
    pub bytes_by_class: [u64; 8],
    /// Per-class rounds of the step, in ledger order.
    pub rounds_by_class: [u64; 8],
}

impl StepCommit {
    /// Build a commit from a step's ledger (the per-step clone every
    /// decode path already takes).
    pub fn from_ledger(phase: StepPhase, lanes: u32, step: &CostLedger) -> Self {
        let mut bytes_by_class = [0u64; 8];
        let mut rounds_by_class = [0u64; 8];
        for (i, &c) in OpClass::ALL.iter().enumerate() {
            bytes_by_class[i] = step.class(c).bytes;
            rounds_by_class[i] = step.class(c).rounds;
        }
        StepCommit { phase, lanes, bytes_by_class, rounds_by_class }
    }

    fn fold_into(&self, mut h: u64) -> u64 {
        h = fnv1a_fold(h, &[STEP_TAG, self.phase.tag(), self.lanes as u64]);
        h = fnv1a_fold(h, &self.bytes_by_class);
        fnv1a_fold(h, &self.rounds_by_class)
    }

    /// Total bytes of the step.
    pub fn bytes_total(&self) -> u64 {
        self.bytes_by_class.iter().sum()
    }

    /// Total rounds of the step.
    pub fn rounds_total(&self) -> u64 {
        self.rounds_by_class.iter().sum()
    }
}

// Domain separators inside the rolling core digest.
const STEP_TAG: u64 = 0x51;
const TOKEN_TAG: u64 = 0x70;

/// First point where a recorded transcript and its replay disagree.
#[derive(Clone, Debug)]
pub struct TranscriptDivergence {
    /// 0-based step commit index (`None` for token / wire / length
    /// divergences past the common step prefix).
    pub step: Option<usize>,
    /// Human-readable description of what diverged.
    pub what: String,
}

impl std::fmt::Display for TranscriptDivergence {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.step {
            Some(i) => write!(f, "step {i}: {}", self.what),
            None => write!(f, "{}", self.what),
        }
    }
}

/// Ordered, replayable commitment to one decode request (or one shared
/// batch — a [`super::decoder::DecodeBatch`] keeps a single transcript
/// for its interleaved schedule).
#[derive(Clone, Debug, Default)]
pub struct RequestTranscript {
    commits: Vec<StepCommit>,
    tokens: Vec<u32>,
    core: u64,
    wire: Option<u64>,
}

impl RequestTranscript {
    /// Empty transcript.
    pub fn new() -> Self {
        RequestTranscript { commits: Vec::new(), tokens: Vec::new(), core: FNV_OFFSET, wire: None }
    }

    /// Append one step commit (rolls the core digest forward).
    pub fn commit_step(&mut self, phase: StepPhase, lanes: u32, step: &CostLedger) {
        let c = StepCommit::from_ledger(phase, lanes, step);
        self.core = c.fold_into(self.core);
        self.commits.push(c);
    }

    /// Append one absorbed/emitted token (order matters and is committed).
    pub fn commit_token(&mut self, token: u32) {
        self.core = fnv1a_fold(self.core, &[TOKEN_TAG, token as u64]);
        self.tokens.push(token);
    }

    /// Attach the full-mode payload chain (see module docs); fast-sim and
    /// census-off runs leave it `None` and the wire comparison is skipped.
    pub fn set_wire_digest(&mut self, d: u64) {
        self.wire = Some(d);
    }

    /// Rolling core digest over every commit and token so far —
    /// mode/profile/kernel-independent for the same seeded request.
    pub fn core_digest(&self) -> u64 {
        self.core
    }

    /// Full-mode payload-chain digest, when one was attached.
    pub fn wire_digest(&self) -> Option<u64> {
        self.wire
    }

    /// Step commits recorded so far.
    pub fn commits(&self) -> &[StepCommit] {
        &self.commits
    }

    /// Tokens recorded so far, in commitment order.
    pub fn tokens(&self) -> &[u32] {
        &self.tokens
    }

    /// Keyed signature over the transcript (the SPDZ-style emulation of a
    /// party signing its view): any change to a commit, a token, or the
    /// order of either changes the core digest and thus the tag.
    pub fn sign(&self, key: u64) -> u64 {
        let k = key | 1;
        fnv1a_fold(FNV_OFFSET, &[k, self.core, self.commits.len() as u64, self.tokens.len() as u64])
            .wrapping_mul(k)
    }

    /// The first divergence between this (recorded) transcript and a
    /// replayed one, or `None` when they verify equal. Steps are compared
    /// first (field-precise), then tokens, then lengths, then the wire
    /// chain (only when both sides carry one).
    pub fn first_divergence(&self, replay: &RequestTranscript) -> Option<TranscriptDivergence> {
        for (i, (a, b)) in self.commits.iter().zip(&replay.commits).enumerate() {
            if a == b {
                continue;
            }
            let what = if a.phase != b.phase {
                format!("phase {} vs {}", a.phase.name(), b.phase.name())
            } else if a.lanes != b.lanes {
                format!("lanes {} vs {}", a.lanes, b.lanes)
            } else {
                // Name the first class whose charge moved.
                let mut what = String::from("per-class charges diverged");
                for (j, &c) in OpClass::ALL.iter().enumerate() {
                    if a.bytes_by_class[j] != b.bytes_by_class[j] {
                        what = format!(
                            "{} bytes {} vs {}",
                            c.name(),
                            a.bytes_by_class[j],
                            b.bytes_by_class[j]
                        );
                        break;
                    }
                    if a.rounds_by_class[j] != b.rounds_by_class[j] {
                        what = format!(
                            "{} rounds {} vs {}",
                            c.name(),
                            a.rounds_by_class[j],
                            b.rounds_by_class[j]
                        );
                        break;
                    }
                }
                what
            };
            return Some(TranscriptDivergence { step: Some(i), what });
        }
        if self.commits.len() != replay.commits.len() {
            return Some(TranscriptDivergence {
                step: Some(self.commits.len().min(replay.commits.len())),
                what: format!("step count {} vs {}", self.commits.len(), replay.commits.len()),
            });
        }
        for (i, (a, b)) in self.tokens.iter().zip(&replay.tokens).enumerate() {
            if a != b {
                return Some(TranscriptDivergence {
                    step: None,
                    what: format!("token {i}: {a} vs {b}"),
                });
            }
        }
        if self.tokens.len() != replay.tokens.len() {
            return Some(TranscriptDivergence {
                step: None,
                what: format!("token count {} vs {}", self.tokens.len(), replay.tokens.len()),
            });
        }
        if let (Some(a), Some(b)) = (self.wire, replay.wire) {
            if a != b {
                return Some(TranscriptDivergence {
                    step: None,
                    what: format!("wire payload chain {a:#018x} vs {b:#018x}"),
                });
            }
        }
        None
    }
}

/// Re-execute a request and check it against a recorded transcript:
/// `reexecute` runs the request afresh (same seed, inputs, and options)
/// and returns its transcript; the first divergence — step, token, or
/// payload chain — becomes the error. `Ok(())` means the replay verified.
pub fn verify_transcript<F>(recorded: &RequestTranscript, reexecute: F) -> crate::Result<()>
where
    F: FnOnce() -> crate::Result<RequestTranscript>,
{
    let replay = reexecute()?;
    if let Some(d) = recorded.first_divergence(&replay) {
        anyhow::bail!("transcript verification failed: {d}");
    }
    // Belt and braces: the rolling digests must agree whenever the parts
    // do (a digest mismatch here would mean a fold bug, not tampering).
    anyhow::ensure!(
        recorded.core_digest() == replay.core_digest(),
        "transcript parts match but core digests differ — digest fold bug"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ledger(class: OpClass, bytes: u64, rounds: u64) -> CostLedger {
        let mut l = CostLedger::new();
        l.add_bytes(class, bytes);
        l.add_rounds(class, rounds);
        l
    }

    #[test]
    fn identical_transcripts_verify_and_sign_identically() {
        let mk = || {
            let mut t = RequestTranscript::new();
            t.commit_step(StepPhase::Setup, 0, &ledger(OpClass::Correlation, 4096, 2));
            t.commit_step(StepPhase::Prefill, 1, &ledger(OpClass::Linear, 128, 16));
            t.commit_token(7);
            t.commit_step(StepPhase::Decode, 1, &ledger(OpClass::Linear, 128, 16));
            t.commit_token(9);
            t
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.core_digest(), b.core_digest());
        assert_eq!(a.sign(0xA5), b.sign(0xA5));
        assert_ne!(a.sign(0xA5), a.sign(0xA7), "signature must be keyed");
        assert!(a.first_divergence(&b).is_none());
        assert!(verify_transcript(&a, || Ok(b)).is_ok());
    }

    #[test]
    fn divergences_name_the_first_difference() {
        let base = {
            let mut t = RequestTranscript::new();
            t.commit_step(StepPhase::Prefill, 1, &ledger(OpClass::Linear, 100, 4));
            t.commit_token(5);
            t
        };
        // A moved byte charge is named with its class.
        let mut bytes = RequestTranscript::new();
        bytes.commit_step(StepPhase::Prefill, 1, &ledger(OpClass::Linear, 101, 4));
        bytes.commit_token(5);
        let d = base.first_divergence(&bytes).expect("must diverge");
        assert_eq!(d.step, Some(0));
        assert!(d.what.contains("Linear bytes 100 vs 101"), "got {}", d.what);
        assert_ne!(base.core_digest(), bytes.core_digest());
        // A different token stream.
        let mut tok = RequestTranscript::new();
        tok.commit_step(StepPhase::Prefill, 1, &ledger(OpClass::Linear, 100, 4));
        tok.commit_token(6);
        let d = base.first_divergence(&tok).expect("must diverge");
        assert!(d.what.contains("token 0: 5 vs 6"), "got {}", d.what);
        // A truncated replay.
        let mut short = RequestTranscript::new();
        short.commit_step(StepPhase::Prefill, 1, &ledger(OpClass::Linear, 100, 4));
        let d = base.first_divergence(&short).expect("must diverge");
        assert!(d.what.contains("token count 1 vs 0"), "got {}", d.what);
        let err = verify_transcript(&base, || Ok(tok)).unwrap_err();
        assert!(err.to_string().contains("transcript verification failed"), "got {err}");
    }

    #[test]
    fn commitment_is_order_sensitive() {
        let mut ab = RequestTranscript::new();
        ab.commit_token(1);
        ab.commit_token(2);
        let mut ba = RequestTranscript::new();
        ba.commit_token(2);
        ba.commit_token(1);
        assert_ne!(ab.core_digest(), ba.core_digest());
        // Phase moves change the digest even at equal charges.
        let mut p = RequestTranscript::new();
        p.commit_step(StepPhase::Prefill, 1, &ledger(OpClass::Linear, 64, 2));
        let mut d = RequestTranscript::new();
        d.commit_step(StepPhase::Decode, 1, &ledger(OpClass::Linear, 64, 2));
        assert_ne!(p.core_digest(), d.core_digest());
        assert!(p.first_divergence(&d).unwrap().what.contains("phase"));
    }

    #[test]
    fn wire_chain_is_compared_only_when_both_sides_carry_one() {
        let mut rec = RequestTranscript::new();
        rec.commit_token(3);
        rec.set_wire_digest(0xAAAA);
        // Fast-sim replay (no wire chain): skipped, verifies clean.
        let mut fast = RequestTranscript::new();
        fast.commit_token(3);
        assert!(rec.first_divergence(&fast).is_none());
        // Full-mode replay with a different chain: rejected.
        let mut full = RequestTranscript::new();
        full.commit_token(3);
        full.set_wire_digest(0xBBBB);
        let d = rec.first_divergence(&full).expect("must diverge");
        assert!(d.what.contains("wire payload chain"), "got {}", d.what);
    }
}
