//! Incremental private decoding over a secret-shared KV cache.
//!
//! The paper's headline motivation is autoregressive NLG ("SMPC-based GPT-2
//! takes 25+ minutes per token"), yet re-running the full three-party
//! forward pass per generated token makes every token cost a whole-sequence
//! inference. A [`DecoderSession`] instead owns per-layer
//! [`crate::protocols::layer::LayerKvCache`]s — `[K]`/`[Ṽ]` sharings that
//! are **never reconstructed** — and drives single-token forwards through
//! [`crate::protocols::layer::transformer_layer_step`]: every step moves
//! `(1, ·)` rows through the same `Π_PP*` protocols, cutting per-token
//! online communication ~8× at `n_ctx = 64` (DESIGN.md §KV-cache).
//!
//! Cost attribution: the session splits its [`CostLedger`] into a one-time
//! **setup** phase (fixed-operand correlation openings,
//! `OpClass::Correlation`), a **cold-prefill** phase (absorbing the
//! prompt) and a **warm-decode** phase (generated tokens), so benches and
//! serving metrics can report the split per token. Per-step cost is
//! position-independent — the cache has a fixed `(n_ctx, d)` shape and
//! unwritten rows are masked — so one warm step is representative of all
//! of them. With fixed-operand correlations (DESIGN.md §Fixed-operand
//! correlations, on by default) the session-fixed π₁/π₁ᵀ operands and the
//! write-once K cache ride session masks opened once, cutting warm-step
//! communication a further ~2.5× beyond the KV cache itself.

use crate::data::greedy_regular_token;
use crate::model::ModelKind;
use crate::net::CostLedger;
use crate::protocols::layer::{self, LayerKvCache, SpecLane, StepLaneGroup};
use crate::protocols::{adaptation, embedding};
use crate::tensor::FloatTensor;
use crate::Result;

use super::audit::{RequestTranscript, StepPhase};
use super::draft::Draft;
use super::CentaurEngine;

/// Per-session speculative-decode bookkeeping (DESIGN.md §Speculative
/// decode): draft proposals vs acceptances plus verify-step counts — the
/// numbers behind the acceptance-rate and rounds-per-*accepted*-token
/// serving metrics.
#[derive(Clone, Copy, Debug, Default)]
pub struct SpeculativeState {
    /// Draft tokens proposed so far (`k - 1` per verify step — the lead
    /// token is the session's own greedy choice, not a proposal).
    pub proposed: u64,
    /// Draft tokens the private model's greedy choices agreed with.
    pub accepted: u64,
    /// Speculative verify steps executed.
    pub verify_steps: u64,
}

impl SpeculativeState {
    /// Fraction of draft proposals accepted (1.0 before any proposal —
    /// the degenerate k=1 schedule never speculates and never misses).
    pub fn acceptance_rate(&self) -> f64 {
        if self.proposed == 0 {
            1.0
        } else {
            self.accepted as f64 / self.proposed as f64
        }
    }
}

/// Result of one streamed generation: the tokens plus the phase-split cost.
pub struct GenOutcome {
    /// Generated continuation (prompt excluded).
    pub tokens: Vec<u32>,
    /// One-time session-correlation setup cost (the fixed-operand masked
    /// openings, `OpClass::Correlation`); empty when correlations are off.
    pub setup: CostLedger,
    /// Online cost of absorbing the prompt (cold prefill).
    pub prefill: CostLedger,
    /// Online cost of the generated steps (warm decode).
    pub decode: CostLedger,
    /// Replayable per-request transcript (DESIGN.md §Integrity-checked
    /// inference): step commits + token stream, with the payload wire
    /// chain attached in full execution mode with the census on.
    pub transcript: RequestTranscript,
}

/// Merge the three session phases into one ledger (single definition
/// shared by [`GenOutcome::total`] and [`DecoderSession::total_cost`]).
fn merged_phases(setup: &CostLedger, prefill: &CostLedger, decode: &CostLedger) -> CostLedger {
    setup.merged(prefill).merged(decode)
}

impl GenOutcome {
    /// Setup + prefill + decode merged into one ledger.
    pub fn total(&self) -> CostLedger {
        merged_phases(&self.setup, &self.prefill, &self.decode)
    }
}

/// An in-progress incremental decode over one engine (GPT-2 only).
///
/// The session borrows the engine mutably: its KV cache is bound to the
/// engine's permutations (`[Ṽ]` is pre-permuted by the session-fixed π₁),
/// and all communication lands in the engine's ledger. P1's observations
/// accumulate in the engine's [`super::views::Views`] across the whole
/// session, so `engine.leaks()` after a multi-step generate audits every
/// step at once.
pub struct DecoderSession<'e> {
    eng: &'e mut CentaurEngine,
    kv: Vec<LayerKvCache>,
    pos: usize,
    setup: CostLedger,
    prefill: CostLedger,
    decode: CostLedger,
    decode_steps: u64,
    last_step: CostLedger,
    last_logits: FloatTensor,
    history: Vec<u32>,
    tokens_emitted: u64,
    spec: SpeculativeState,
    transcript: RequestTranscript,
}

impl<'e> DecoderSession<'e> {
    /// Start a session and absorb `prompt` (cold prefill). The prompt must
    /// be non-empty and fit the context window.
    ///
    /// With fixed-operand correlations enabled (the default,
    /// [`super::EngineOptions::decode_correlations`]), session start deals
    /// the whole session's correlations in one shared-mask bundle per
    /// open-once family — pool-first, generated on demand on a cold start —
    /// and performs the one-time masked openings of π₁/π₁ᵀ **once for all
    /// layers** (`layer::deal_session_kv_correlations`), charged to the
    /// separate `setup` ledger (`OpClass::Correlation`) so warm-step
    /// ledgers stay clean.
    pub fn new(eng: &'e mut CentaurEngine, prompt: &[u32]) -> Result<Self> {
        anyhow::ensure!(eng.cfg.kind == ModelKind::Gpt2, "incremental decode needs a decoder model");
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        anyhow::ensure!(prompt.len() <= eng.cfg.n_ctx, "prompt longer than n_ctx");
        eng.mpc.net.reset();
        let mut kv = Vec::with_capacity(eng.cfg.layers);
        if eng.decode_correlations {
            let corrs =
                layer::deal_session_kv_correlations(&mut eng.mpc, &eng.cfg, &eng.pi1_sh, &eng.pi1_t_sh)?;
            for corr in corrs {
                kv.push(LayerKvCache::with_correlations(eng.cfg.n_ctx, eng.cfg.d, corr));
            }
        } else {
            for _ in 0..eng.cfg.layers {
                kv.push(LayerKvCache::new(eng.cfg.n_ctx, eng.cfg.d));
            }
        }
        let setup = eng.mpc.net.ledger.clone();
        // Step boundary: verify the setup openings' MACs, then commit the
        // setup phase to the transcript.
        eng.mpc.flush_mac_checks()?;
        let mut transcript = RequestTranscript::new();
        transcript.commit_step(StepPhase::Setup, 0, &setup);
        eng.views.clear();
        let mut sess = DecoderSession {
            eng,
            kv,
            pos: 0,
            setup,
            prefill: CostLedger::new(),
            decode: CostLedger::new(),
            decode_steps: 0,
            last_step: CostLedger::new(),
            last_logits: FloatTensor::zeros(1, 1),
            history: Vec::new(),
            tokens_emitted: 0,
            spec: SpeculativeState::default(),
            transcript,
        };
        for &t in prompt {
            sess.absorb_phase(t, false)?;
        }
        Ok(sess)
    }

    /// Tokens absorbed so far (prompt + generated).
    pub fn position(&self) -> usize {
        self.pos
    }

    /// Remaining context capacity.
    pub fn remaining(&self) -> usize {
        self.eng.cfg.n_ctx - self.pos
    }

    /// Next-token logits `(1, vocab)` for the last absorbed position.
    pub fn logits(&self) -> &FloatTensor {
        &self.last_logits
    }

    /// Absorb one externally chosen token (teacher forcing / sampling done
    /// client-side), charged to the warm-decode phase.
    pub fn absorb(&mut self, token: u32) -> Result<()> {
        self.absorb_phase(token, true)
    }

    /// Greedily pick the next token from the current logits (specials are
    /// never emitted), absorb it, and return it.
    ///
    /// The emitted token is absorbed immediately so the cache always
    /// covers every emitted token — the session stays resumable (the
    /// client can keep stepping, or [`DecoderSession::absorb`] more input,
    /// at any point). The price is that a session discarded right after
    /// its last step has paid one absorb whose logits were never read.
    pub fn step_greedy(&mut self) -> Result<u32> {
        let next = greedy_regular_token(self.last_logits.row(0));
        self.absorb_phase(next, true)?;
        Ok(next)
    }

    /// One speculative verify step (DESIGN.md §Speculative decode): the
    /// session's own greedy token leads, `draft` proposes up to `k - 1`
    /// follow-ups conditioned on the public token history, and all of
    /// them ride ONE batched flight chain as extra verify lanes. The
    /// longest prefix agreeing with the private model's own greedy
    /// choices is kept (the lead token always is — it *is* the greedy
    /// choice), rejected rows are rolled back
    /// ([`LayerKvCache::truncate_to`], which also rewinds the
    /// fixed-operand correlation uses), and the accepted tokens are
    /// returned: token-for-token what repeated [`DecoderSession::step_greedy`]
    /// would have emitted, at one flight chain per up-to-k tokens.
    pub fn step_speculative(&mut self, draft: &Draft, k: usize) -> Result<Vec<u32>> {
        anyhow::ensure!(k >= 1, "spec_k must be >= 1");
        let cap = self.remaining();
        anyhow::ensure!(cap >= 1, "context window exhausted");
        let l = k.min(cap);
        let mut tokens = Vec::with_capacity(l);
        tokens.push(greedy_regular_token(self.last_logits.row(0)));
        if l > 1 {
            let mut hist = self.history.clone();
            hist.push(tokens[0]);
            tokens.extend(draft.propose(&hist, l - 1));
        }
        let pos0 = self.pos;
        let logits = self.absorb_spec(&tokens)?;
        let mut m = 1;
        while m < l && tokens[m] == greedy_regular_token(logits[m - 1].row(0)) {
            m += 1;
        }
        if m < l {
            for kvc in &mut self.kv {
                kvc.truncate_to(pos0 + m)?;
            }
            self.pos = pos0 + m;
        }
        self.last_logits = logits[m - 1].clone();
        tokens.truncate(m);
        // Only the accepted prefix is part of the request's token stream.
        for &t in &tokens {
            self.transcript.commit_token(t);
        }
        self.history.extend_from_slice(&tokens);
        self.tokens_emitted += m as u64;
        self.spec.proposed += (l - 1) as u64;
        self.spec.accepted += (m - 1) as u64;
        self.spec.verify_steps += 1;
        Ok(tokens)
    }

    /// Absorb `tokens` at successive positions in ONE multi-lane flight
    /// chain (warm-decode phase; requires the batched schedule). Returns
    /// each lane's next-token logits; the caller applies the accept rule
    /// and rolls rejected rows back.
    fn absorb_spec(&mut self, tokens: &[u32]) -> Result<Vec<FloatTensor>> {
        anyhow::ensure!(!tokens.is_empty(), "empty speculative absorb");
        anyhow::ensure!(self.pos + tokens.len() <= self.eng.cfg.n_ctx, "context window exhausted");
        anyhow::ensure!(
            self.eng.round_batching,
            "speculative decode needs the batched decode schedule (round_batching)"
        );
        for &t in tokens {
            anyhow::ensure!((t as usize) < self.eng.cfg.vocab, "token {t} out of vocab");
        }
        let pos0 = self.pos;
        let eng = &mut *self.eng;
        eng.mpc.net.reset();
        let logits = {
            let mut ctx = layer::ProtoCtx {
                mpc: &mut eng.mpc,
                backend: eng.backend.as_mut(),
                views: &mut eng.views,
                fast_sim: eng.fast_sim,
                round_batching: eng.round_batching,
            };
            let mut lanes = Vec::with_capacity(tokens.len());
            for (j, &t) in tokens.iter().enumerate() {
                let x_pi =
                    embedding::pp_embedding_at_lane(&mut ctx, &eng.pm, t, pos0 + j, j == 0, "")?;
                lanes.push(SpecLane { x_pi, pos: pos0 + j, bytes: 0 });
            }
            let mut groups = [StepLaneGroup { kv: &mut self.kv, prefix: "", lanes }];
            let last = eng.pm.layers.len() - 1;
            for (i, pl) in eng.pm.layers[..last].iter().enumerate() {
                layer::transformer_layer_step_batch(
                    &mut ctx,
                    &eng.cfg,
                    pl,
                    &eng.pi1_sh,
                    &eng.pi1_t_sh,
                    &mut groups,
                    i,
                    None,
                )?;
            }
            let h_pis = layer::transformer_layer_step_batch(
                &mut ctx,
                &eng.cfg,
                &eng.pm.layers[last],
                &eng.pi1_sh,
                &eng.pi1_t_sh,
                &mut groups,
                last,
                Some((
                    eng.pm.final_ln_g.as_deref().expect("gpt weights"),
                    eng.pm.final_ln_b.as_deref().expect("gpt weights"),
                )),
            )?
            .expect("final tail returns the final-LN shares");
            let mut outs = Vec::with_capacity(tokens.len());
            for (j, h_pi) in h_pis[0].iter().enumerate() {
                let logits_sh = adaptation::pp_lm_head_gpt2(&mut ctx, &eng.pm, h_pi)?;
                outs.push(if j == 0 {
                    adaptation::return_to_client(ctx.mpc, &logits_sh)?
                } else {
                    adaptation::return_to_client_unrounded(ctx.mpc, &logits_sh)?
                });
            }
            outs
        };
        let step = eng.mpc.net.ledger.clone();
        // Step boundary: batch-verify this flight chain's opening MACs
        // and commit the step (the caller commits the accepted tokens).
        eng.mpc.flush_mac_checks()?;
        self.transcript.commit_step(StepPhase::Decode, tokens.len() as u32, &step);
        self.decode.merge(&step);
        self.decode_steps += 1;
        self.last_step = step;
        self.pos += tokens.len();
        Ok(logits)
    }

    /// One single-token forward through the full three-party protocol.
    fn absorb_phase(&mut self, token: u32, decode_phase: bool) -> Result<()> {
        anyhow::ensure!(self.pos < self.eng.cfg.n_ctx, "context window exhausted");
        anyhow::ensure!((token as usize) < self.eng.cfg.vocab, "token {token} out of vocab");
        let pos = self.pos;
        let eng = &mut *self.eng;
        eng.mpc.net.reset();
        let logits_sh = {
            let mut ctx = layer::ProtoCtx {
                mpc: &mut eng.mpc,
                backend: eng.backend.as_mut(),
                views: &mut eng.views,
                fast_sim: eng.fast_sim,
                round_batching: eng.round_batching,
            };
            let mut x_pi = embedding::pp_embedding_at(&mut ctx, &eng.pm, token, pos)?;
            if ctx.round_batching {
                // Batched schedule: the last layer fuses the final Π_PPLN
                // into its reshare flight, so adaptation is just the
                // communication-free LM head plus the logits return.
                let last = eng.pm.layers.len() - 1;
                for (i, pl) in eng.pm.layers[..last].iter().enumerate() {
                    x_pi = layer::transformer_layer_step(
                        &mut ctx,
                        &eng.cfg,
                        pl,
                        &eng.pi1_sh,
                        &eng.pi1_t_sh,
                        &x_pi,
                        &mut self.kv[i],
                        pos,
                        i,
                    )?;
                }
                let (_, h_pi) = layer::transformer_layer_step_final(
                    &mut ctx,
                    &eng.cfg,
                    &eng.pm.layers[last],
                    &eng.pi1_sh,
                    &eng.pi1_t_sh,
                    &x_pi,
                    &mut self.kv[last],
                    pos,
                    last,
                    eng.pm.final_ln_g.as_deref().expect("gpt weights"),
                    eng.pm.final_ln_b.as_deref().expect("gpt weights"),
                )?;
                adaptation::pp_lm_head_gpt2(&mut ctx, &eng.pm, &h_pi)?
            } else {
                for (i, pl) in eng.pm.layers.iter().enumerate() {
                    x_pi = layer::transformer_layer_step(
                        &mut ctx,
                        &eng.cfg,
                        pl,
                        &eng.pi1_sh,
                        &eng.pi1_t_sh,
                        &x_pi,
                        &mut self.kv[i],
                        pos,
                        i,
                    )?;
                }
                adaptation::pp_adaptation_gpt2(&mut ctx, &eng.pm, &x_pi)?
            }
        };
        let logits = adaptation::return_to_client(&mut eng.mpc, &logits_sh)?;
        let step = eng.mpc.net.ledger.clone();
        // Step boundary: batch-verify this step's opening MACs, then
        // commit the step and its token to the transcript.
        eng.mpc.flush_mac_checks()?;
        self.transcript.commit_step(
            if decode_phase { StepPhase::Decode } else { StepPhase::Prefill },
            1,
            &step,
        );
        self.transcript.commit_token(token);
        if decode_phase {
            self.decode.merge(&step);
            self.decode_steps += 1;
            self.tokens_emitted += 1;
        } else {
            self.prefill.merge(&step);
        }
        self.last_step = step;
        self.last_logits = logits;
        self.history.push(token);
        self.pos += 1;
        Ok(())
    }

    /// One-time session setup cost (fixed-operand correlation openings;
    /// empty when correlations are disabled).
    pub fn setup_cost(&self) -> &CostLedger {
        &self.setup
    }

    /// Per-layer fixed-operand opening counters
    /// `(π₁ openings, π₁ᵀ openings, K rows opened)` — the security census
    /// asserts exactly one π₁-side opening per session per layer. Empty
    /// when correlations are disabled.
    pub fn correlation_openings(&self) -> Vec<(u64, u64, u64)> {
        self.kv
            .iter()
            .filter_map(|kv| {
                kv.correlations()
                    .map(|c| (c.ppp.openings(), c.append.openings(), c.scores.openings()))
            })
            .collect()
    }

    /// Per-layer unused correlation bundles left
    /// `(ppp, append, scores)` — exhausting any of them makes further
    /// absorbs error instead of reusing a mask.
    pub fn correlation_uses_left(&self) -> Vec<(usize, usize, usize)> {
        self.kv
            .iter()
            .filter_map(|kv| {
                kv.correlations()
                    .map(|c| (c.ppp.uses_left(), c.append.uses_left(), c.scores.uses_left()))
            })
            .collect()
    }

    /// Online cost of the cold-prefill phase (prompt absorption).
    pub fn prefill_cost(&self) -> &CostLedger {
        &self.prefill
    }

    /// Online cost of the warm-decode phase (generated tokens).
    pub fn decode_cost(&self) -> &CostLedger {
        &self.decode
    }

    /// Warm-decode absorbs so far (generated tokens; excludes prefill).
    pub fn decode_steps(&self) -> u64 {
        self.decode_steps
    }

    /// Warm-decode protocol rounds per generated token — the WAN latency
    /// lever (`rounds · RTT` dominates decode under the WAN profiles); 0
    /// before the first warm step. Per-step rounds are
    /// position-independent, so this is exact, not an average.
    pub fn decode_rounds_per_token(&self) -> u64 {
        if self.decode_steps == 0 {
            0
        } else {
            self.decode.rounds_total() / self.decode_steps
        }
    }

    /// Tokens emitted during warm decode — accepted tokens for
    /// speculative sessions, one per absorb otherwise.
    pub fn tokens_emitted(&self) -> u64 {
        self.tokens_emitted
    }

    /// Warm-decode wire rounds per *accepted* token — the speculative
    /// headline metric: one verify flight chain (a fixed round count)
    /// yields up to k tokens, so this drops below the per-step round
    /// floor as acceptance rises. 0.0 before the first emitted token.
    pub fn decode_rounds_per_accepted_token(&self) -> f64 {
        if self.tokens_emitted == 0 {
            0.0
        } else {
            self.decode.rounds_total() as f64 / self.tokens_emitted as f64
        }
    }

    /// Speculative accept/reject bookkeeping (all-zero for sessions that
    /// never called [`DecoderSession::step_speculative`]).
    pub fn speculative(&self) -> &SpeculativeState {
        &self.spec
    }

    /// Per-[`crate::net::OpClass`] round breakdown of the most recent
    /// step — the table the round-budget harness pins golden values
    /// against (`rust/tests/round_budget.rs`).
    pub fn last_step_rounds_by_class(&self) -> [(crate::net::OpClass, u64); 8] {
        self.last_step.rounds_by_class()
    }

    /// Online cost of the most recent step.
    pub fn last_step_cost(&self) -> &CostLedger {
        &self.last_step
    }

    /// Setup + prefill + decode merged.
    pub fn total_cost(&self) -> CostLedger {
        merged_phases(&self.setup, &self.prefill, &self.decode)
    }

    /// The session's replayable transcript so far. In full execution mode
    /// with the transfer census on, the current payload wire chain is
    /// attached; fast-sim transcripts carry only the (mode-independent)
    /// core commitment.
    pub fn transcript(&self) -> RequestTranscript {
        let mut t = self.transcript.clone();
        if self.eng.mpc.net.record_transfers && !self.eng.fast_sim {
            t.set_wire_digest(self.eng.mpc.net.wire_digest);
        }
        t
    }

    /// Rolling core transcript digest (mode/profile/kernel-independent).
    pub fn transcript_digest(&self) -> u64 {
        self.transcript.core_digest()
    }
}

/// One session's state inside a [`DecodeBatch`]: private KV caches, the
/// token stream, and the per-phase cost attribution of a solo
/// [`DecoderSession`] — plus the continuous-batching lifecycle (step
/// budget, optional EOS, done flag).
pub struct BatchSession {
    id: usize,
    kv: Vec<LayerKvCache>,
    pos: usize,
    prefix: String,
    tokens: Vec<u32>,
    steps_left: usize,
    eos: Option<u32>,
    done: bool,
    setup: CostLedger,
    prefill_bytes: u64,
    prefill_rounds: u64,
    decode_bytes: u64,
    decode_rounds: u64,
    decode_steps: u64,
    last_step_bytes: u64,
    last_step_rounds: u64,
    last_logits: FloatTensor,
    history: Vec<u32>,
    spec: SpeculativeState,
}

impl BatchSession {
    /// Stable session id within the batch (admission order, 0-based).
    pub fn id(&self) -> usize {
        self.id
    }

    /// Continuation tokens emitted so far (prompt excluded).
    pub fn tokens(&self) -> &[u32] {
        &self.tokens
    }

    /// Tokens absorbed so far (prompt + generated).
    pub fn position(&self) -> usize {
        self.pos
    }

    /// Next-token logits `(1, vocab)` for the last absorbed position.
    pub fn logits(&self) -> &FloatTensor {
        &self.last_logits
    }

    /// Whether the session has finished (step budget, EOS, or context
    /// exhaustion) and only awaits [`DecodeBatch::remove`].
    pub fn is_done(&self) -> bool {
        self.done
    }

    /// One-time session-correlation setup cost (cf.
    /// [`DecoderSession::setup_cost`]).
    pub fn setup_cost(&self) -> &CostLedger {
        &self.setup
    }

    /// Lane-attributed online bytes of the cold-prefill phase.
    pub fn prefill_bytes(&self) -> u64 {
        self.prefill_bytes
    }

    /// Wire rounds this session waited through during prefill.
    pub fn prefill_rounds(&self) -> u64 {
        self.prefill_rounds
    }

    /// Lane-attributed online bytes of the warm-decode phase.
    pub fn decode_bytes(&self) -> u64 {
        self.decode_bytes
    }

    /// Wire rounds this session waited through during warm decode (the
    /// latency it experienced — shared flights count once per step, not
    /// once per lane).
    pub fn decode_rounds(&self) -> u64 {
        self.decode_rounds
    }

    /// Warm-decode absorbs so far.
    pub fn decode_steps(&self) -> u64 {
        self.decode_steps
    }

    /// Lane-attributed bytes of the most recent absorb.
    pub fn last_step_bytes(&self) -> u64 {
        self.last_step_bytes
    }

    /// Whole-step wire rounds of the most recent absorb.
    pub fn last_step_rounds(&self) -> u64 {
        self.last_step_rounds
    }

    /// Speculative accept/reject bookkeeping (all-zero for sessions only
    /// stepped through plain [`DecodeBatch::step`]).
    pub fn speculative(&self) -> &SpeculativeState {
        &self.spec
    }
}

/// Everything a scheduler needs to report a finished (or early-evicted)
/// session, harvested by [`DecodeBatch::remove`].
pub struct SessionSummary {
    /// Continuation tokens emitted (prompt excluded).
    pub tokens: Vec<u32>,
    /// One-time correlation-setup bytes.
    pub setup_bytes: u64,
    /// Cold-prefill online bytes (lane-attributed).
    pub prefill_bytes: u64,
    /// Warm-decode online bytes (lane-attributed).
    pub decode_bytes: u64,
    /// Total wire rounds the session waited through (setup + prefill +
    /// decode).
    pub rounds: u64,
    /// Warm-decode wire rounds.
    pub decode_rounds: u64,
    /// Generate steps the session never consumed (early eviction) — the
    /// scheduler releases the matching pool demand.
    pub steps_unconsumed: u64,
    /// Core transcript digest of the *batch* at harvest time (batched
    /// steps are shared flights, so the commitment is batch-level; a B=1
    /// batch's digest equals the solo session's).
    pub transcript_digest: u64,
}

/// One token emission from a batched decode step.
pub struct StepEmission {
    /// Session id the token belongs to.
    pub session: usize,
    /// 0-based index of the token within the session's continuation.
    pub index: usize,
    /// The emitted token.
    pub token: u32,
    /// Online bytes attributed to this session's lane this step.
    pub step_bytes: u64,
    /// Whole-step wire rounds (the latency every lane shares).
    pub step_rounds: u64,
    /// Whether this emission finished the session (step budget, EOS, or
    /// context exhaustion).
    pub done: bool,
}

/// Continuous batching over one engine (DESIGN.md §Continuous batching):
/// B concurrent generate sessions advance one token per [`DecodeBatch::step`],
/// all riding the same flight schedule — rounds amortize to (solo
/// rounds)/B per token while each session keeps its own KV caches,
/// fixed-operand correlations, position, and P1 view labels.
///
/// Lifecycle: [`DecodeBatch::admit`] at any step boundary (the new
/// session's prompt is prefilled solo, then it joins the shared steps),
/// [`DecodeBatch::step`] advances every live session, sessions finish on
/// their step budget / EOS / context exhaustion (or early via
/// [`DecodeBatch::remove`]), and [`DecodeBatch::remove`] harvests the
/// [`SessionSummary`].
///
/// With one session the batch is transfer-, ledger-, PRG-, and
/// view-identical to a [`DecoderSession`] driven by `step_greedy` — the
/// parity tests in `rust/tests/batched_decode.rs` pin that bit-exactly.
/// With B > 1 the dealer's randomness interleaves across lanes, so shares
/// differ from a solo run while each session's *token stream* still
/// matches its solo run (low-bit truncation noise does not move the
/// greedy argmax; asserted empirically under the test seeds).
pub struct DecodeBatch<'e> {
    eng: &'e mut CentaurEngine,
    sessions: Vec<BatchSession>,
    next_id: usize,
    batch_decode_steps: u64,
    batch_wire_rounds: u64,
    batch_tokens: u64,
    max_concurrent: usize,
    spec_proposed: u64,
    spec_accepted: u64,
    transcript: RequestTranscript,
}

impl<'e> DecodeBatch<'e> {
    /// Wrap an engine for continuous batching. Requires a decoder model
    /// and the batched round schedule
    /// ([`super::EngineOptions::round_batching`], the default) — the
    /// shared flights *are* the round batching, generalized over lanes.
    pub fn new(eng: &'e mut CentaurEngine) -> Result<Self> {
        anyhow::ensure!(eng.cfg.kind == ModelKind::Gpt2, "incremental decode needs a decoder model");
        anyhow::ensure!(
            eng.round_batching,
            "continuous batching needs the batched decode schedule (round_batching)"
        );
        Ok(DecodeBatch {
            eng,
            sessions: Vec::new(),
            next_id: 0,
            batch_decode_steps: 0,
            batch_wire_rounds: 0,
            batch_tokens: 0,
            max_concurrent: 0,
            spec_proposed: 0,
            spec_accepted: 0,
            transcript: RequestTranscript::new(),
        })
    }

    /// Admit a session at a step boundary: deal its correlations, prefill
    /// its prompt (solo lanes — the cold phase does not ride the running
    /// batch's flights), and schedule up to `steps` generated tokens,
    /// stopping early when `eos` is emitted. Returns the session id.
    ///
    /// Mirrors [`DecoderSession::new`] exactly; the engine's P1 view
    /// ledger is cleared only when the batch is empty, so live sessions'
    /// censuses are never dropped.
    pub fn admit(&mut self, prompt: &[u32], steps: usize, eos: Option<u32>) -> Result<usize> {
        {
            let eng = &mut *self.eng;
            anyhow::ensure!(!prompt.is_empty(), "empty prompt");
            anyhow::ensure!(
                prompt.len() + steps <= eng.cfg.n_ctx,
                "prompt + generate steps must fit the context window"
            );
            eng.mpc.net.reset();
            let mut kv = Vec::with_capacity(eng.cfg.layers);
            if eng.decode_correlations {
                let corrs = layer::deal_session_kv_correlations(
                    &mut eng.mpc,
                    &eng.cfg,
                    &eng.pi1_sh,
                    &eng.pi1_t_sh,
                )?;
                for corr in corrs {
                    kv.push(LayerKvCache::with_correlations(eng.cfg.n_ctx, eng.cfg.d, corr));
                }
            } else {
                for _ in 0..eng.cfg.layers {
                    kv.push(LayerKvCache::new(eng.cfg.n_ctx, eng.cfg.d));
                }
            }
            let setup = eng.mpc.net.ledger.clone();
            // Admission boundary: batch-verify the correlation-dealing
            // MACs, then commit the setup phase to the batch transcript.
            eng.mpc.flush_mac_checks()?;
            self.transcript.commit_step(StepPhase::Setup, 0, &setup);
            if self.sessions.is_empty() {
                eng.views.clear();
            }
            let id = self.next_id;
            self.next_id += 1;
            self.sessions.push(BatchSession {
                id,
                kv,
                pos: 0,
                prefix: if id == 0 { String::new() } else { format!("s{id} ") },
                tokens: Vec::new(),
                steps_left: steps,
                eos,
                done: steps == 0,
                setup,
                prefill_bytes: 0,
                prefill_rounds: 0,
                decode_bytes: 0,
                decode_rounds: 0,
                decode_steps: 0,
                last_step_bytes: 0,
                last_step_rounds: 0,
                last_logits: FloatTensor::zeros(1, 1),
                history: Vec::new(),
                spec: SpeculativeState::default(),
            });
        }
        let idx = self.sessions.len() - 1;
        for &t in prompt {
            if let Err(e) = self.absorb_lanes(&[(idx, t)], false) {
                self.sessions.pop();
                return Err(e);
            }
        }
        Ok(self.sessions[idx].id)
    }

    /// Advance every live session by one greedy token in ONE shared
    /// flight schedule, returning the emissions in session order. An
    /// empty return means the batch is idle (admit or remove sessions).
    pub fn step(&mut self) -> Result<Vec<StepEmission>> {
        let work: Vec<(usize, u32)> = self
            .sessions
            .iter()
            .enumerate()
            .filter(|(_, s)| !s.done)
            .map(|(i, s)| (i, greedy_regular_token(s.last_logits.row(0))))
            .collect();
        if work.is_empty() {
            return Ok(Vec::new());
        }
        self.absorb_lanes(&work, true)?;
        self.max_concurrent = self.max_concurrent.max(work.len());
        self.batch_decode_steps += 1;
        self.batch_wire_rounds += self.sessions[work[0].0].last_step_rounds;
        self.batch_tokens += work.len() as u64;
        let n_ctx = self.eng.cfg.n_ctx;
        let mut out = Vec::with_capacity(work.len());
        for &(i, tok) in &work {
            let s = &mut self.sessions[i];
            s.tokens.push(tok);
            s.steps_left -= 1;
            if s.steps_left == 0 || s.eos == Some(tok) || s.pos >= n_ctx {
                s.done = true;
            }
            out.push(StepEmission {
                session: s.id,
                index: s.tokens.len() - 1,
                token: tok,
                step_bytes: s.last_step_bytes,
                step_rounds: s.last_step_rounds,
                done: s.done,
            });
        }
        Ok(out)
    }

    /// Advance every live session by one speculative verify step in ONE
    /// shared flight schedule (DESIGN.md §Speculative decode): each
    /// session contributes its greedy lead token plus up to `k - 1`
    /// proposals from the public `draft` as extra lanes — B groups × k
    /// lanes over one flight chain — then keeps its longest
    /// greedy-agreeing prefix and rolls the rest back. Emissions come
    /// back in session order, possibly several per session; the batch's
    /// token counter advances by *accepted* tokens only, so
    /// [`DecodeBatch::amortized_rounds_per_token`] is rounds per accepted
    /// token. `step_spec(draft, 1)` never consults the draft and emits
    /// exactly like [`DecodeBatch::step`].
    pub fn step_spec(&mut self, draft: &Draft, k: usize) -> Result<Vec<StepEmission>> {
        anyhow::ensure!(k >= 1, "spec_k must be >= 1");
        let n_ctx = self.eng.cfg.n_ctx;
        let mut work: Vec<(usize, Vec<u32>)> = Vec::new();
        for (i, s) in self.sessions.iter().enumerate() {
            if s.done {
                continue;
            }
            let lead = greedy_regular_token(s.last_logits.row(0));
            let l = k.min(s.steps_left).min(n_ctx - s.pos).max(1);
            let mut toks = Vec::with_capacity(l);
            toks.push(lead);
            if l > 1 {
                let mut hist = s.history.clone();
                hist.push(lead);
                toks.extend(draft.propose(&hist, l - 1));
            }
            work.push((i, toks));
        }
        if work.is_empty() {
            return Ok(Vec::new());
        }
        let pos0s: Vec<usize> = work.iter().map(|(i, _)| self.sessions[*i].pos).collect();
        let all_logits = self.absorb_groups(&work, true)?;
        self.max_concurrent = self.max_concurrent.max(work.len());
        self.batch_decode_steps += 1;
        self.batch_wire_rounds += self.sessions[work[0].0].last_step_rounds;
        let mut out = Vec::new();
        for (((idx, toks), logits), pos0) in work.iter().zip(all_logits).zip(pos0s) {
            let s = &mut self.sessions[*idx];
            let l = toks.len();
            let mut m = 1;
            while m < l && toks[m] == greedy_regular_token(logits[m - 1].row(0)) {
                m += 1;
            }
            // An accepted EOS ends the session — later accepted tokens
            // would never have been generated, so roll them back too.
            if let Some(e) = s.eos {
                if let Some(j) = toks[..m].iter().position(|&t| t == e) {
                    m = j + 1;
                }
            }
            if m < l {
                for kvc in &mut s.kv {
                    kvc.truncate_to(pos0 + m)?;
                }
                s.pos = pos0 + m;
            }
            s.last_logits = logits[m - 1].clone();
            s.spec.proposed += (l - 1) as u64;
            s.spec.accepted += (m - 1) as u64;
            s.spec.verify_steps += 1;
            self.spec_proposed += (l - 1) as u64;
            self.spec_accepted += (m - 1) as u64;
            self.batch_tokens += m as u64;
            for &tok in &toks[..m] {
                // Only the accepted prefix joins the batch token stream.
                self.transcript.commit_token(tok);
                s.tokens.push(tok);
                s.history.push(tok);
                s.steps_left -= 1;
                if s.steps_left == 0 || s.eos == Some(tok) || s.pos >= n_ctx {
                    s.done = true;
                }
                out.push(StepEmission {
                    session: s.id,
                    index: s.tokens.len() - 1,
                    token: tok,
                    step_bytes: s.last_step_bytes,
                    step_rounds: s.last_step_rounds,
                    done: s.done,
                });
            }
        }
        Ok(out)
    }

    /// Remove a session (finished or early-evicted) and harvest its
    /// summary. Returns `None` for an unknown id.
    pub fn remove(&mut self, session_id: usize) -> Option<SessionSummary> {
        let idx = self.sessions.iter().position(|s| s.id == session_id)?;
        let s = self.sessions.remove(idx);
        Some(SessionSummary {
            setup_bytes: s.setup.bytes_total(),
            prefill_bytes: s.prefill_bytes,
            decode_bytes: s.decode_bytes,
            rounds: s.setup.rounds_total() + s.prefill_rounds + s.decode_rounds,
            decode_rounds: s.decode_rounds,
            steps_unconsumed: s.steps_left as u64,
            transcript_digest: self.transcript.core_digest(),
            tokens: s.tokens,
        })
    }

    /// The session with this id, if still in the batch.
    pub fn session(&self, session_id: usize) -> Option<&BatchSession> {
        self.sessions.iter().find(|s| s.id == session_id)
    }

    /// Ids of every session currently in the batch (live and finished).
    pub fn session_ids(&self) -> Vec<usize> {
        self.sessions.iter().map(|s| s.id).collect()
    }

    /// Sessions still generating (admitted, not yet done).
    pub fn active(&self) -> usize {
        self.sessions.iter().filter(|s| !s.done).count()
    }

    /// Sessions in the batch, including finished ones awaiting removal.
    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    /// Whether the batch holds no sessions at all.
    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// Batched decode steps executed so far.
    pub fn batch_decode_steps(&self) -> u64 {
        self.batch_decode_steps
    }

    /// Wire rounds spent across all batched decode steps (counted once
    /// per step — the whole batch shares each flight).
    pub fn batch_wire_rounds(&self) -> u64 {
        self.batch_wire_rounds
    }

    /// Tokens emitted through batched decode steps.
    pub fn batch_tokens(&self) -> u64 {
        self.batch_tokens
    }

    /// Amortized wire rounds per generated token — the continuous-batching
    /// headline ((solo rounds)/B when B lanes ride every step).
    pub fn amortized_rounds_per_token(&self) -> f64 {
        if self.batch_tokens == 0 {
            0.0
        } else {
            self.batch_wire_rounds as f64 / self.batch_tokens as f64
        }
    }

    /// Largest number of lanes that shared one decode step.
    pub fn max_concurrent(&self) -> usize {
        self.max_concurrent
    }

    /// Draft tokens proposed across every speculative step.
    pub fn spec_proposed(&self) -> u64 {
        self.spec_proposed
    }

    /// Draft tokens accepted across every speculative step.
    pub fn spec_accepted(&self) -> u64 {
        self.spec_accepted
    }

    /// Fraction of draft proposals accepted (1.0 before any proposal).
    pub fn acceptance_rate(&self) -> f64 {
        if self.spec_proposed == 0 {
            1.0
        } else {
            self.spec_accepted as f64 / self.spec_proposed as f64
        }
    }

    /// The batch's replayable transcript so far (shared steps commit
    /// once, tagged with the lane count). In full execution mode with the
    /// transfer census on, the current payload wire chain is attached.
    pub fn transcript(&self) -> RequestTranscript {
        let mut t = self.transcript.clone();
        if self.eng.mpc.net.record_transfers && !self.eng.fast_sim {
            t.set_wire_digest(self.eng.mpc.net.wire_digest);
        }
        t
    }

    /// Rolling core transcript digest (mode/profile/kernel-independent).
    pub fn transcript_digest(&self) -> u64 {
        self.transcript.core_digest()
    }

    /// Cumulative audit counters of the underlying engine (`None` with
    /// audit mode off) — the batch borrows the engine for its whole life,
    /// so the scheduler harvests through this passthrough.
    pub fn audit_counters(&self) -> Option<crate::mpc::AuditCounters> {
        self.eng.audit_counters()
    }

    /// One shared single-token forward for `work` = ascending
    /// `(session index, token)` lanes. Prefill calls pass a single lane;
    /// decode steps pass every live session — both run the exact same
    /// path, which is what makes a B=1 batch bit-identical to a solo
    /// [`DecoderSession`].
    fn absorb_lanes(&mut self, work: &[(usize, u32)], decode_phase: bool) -> Result<()> {
        let grouped: Vec<(usize, Vec<u32>)> = work.iter().map(|&(i, t)| (i, vec![t])).collect();
        let logits = self.absorb_groups(&grouped, decode_phase)?;
        for (&(idx, token), mut outs) in work.iter().zip(logits) {
            self.transcript.commit_token(token);
            let s = &mut self.sessions[idx];
            s.history.push(token);
            s.last_logits = outs.pop().expect("one logits row per lane");
        }
        Ok(())
    }

    /// One shared forward for `work` = ascending
    /// `(session index, tokens)` lane groups, each session absorbing its
    /// tokens at successive positions (speculative verify lanes). Updates
    /// byte/round/position bookkeeping and returns every lane's
    /// next-token logits per group; the caller owns token bookkeeping
    /// (accept rule, history, rollback).
    fn absorb_groups(
        &mut self,
        work: &[(usize, Vec<u32>)],
        decode_phase: bool,
    ) -> Result<Vec<Vec<FloatTensor>>> {
        anyhow::ensure!(!work.is_empty(), "empty absorb");
        let eng = &mut *self.eng;
        for (idx, tokens) in work {
            let s = &self.sessions[*idx];
            anyhow::ensure!(!tokens.is_empty(), "empty lane group");
            anyhow::ensure!(s.pos + tokens.len() <= eng.cfg.n_ctx, "context window exhausted");
            for &t in tokens {
                anyhow::ensure!((t as usize) < eng.cfg.vocab, "token {t} out of vocab");
            }
        }
        eng.mpc.net.reset();
        let mut lane_bytes = vec![0u64; work.len()];
        let logits: Vec<Vec<FloatTensor>> = {
            let mut ctx = layer::ProtoCtx {
                mpc: &mut eng.mpc,
                backend: eng.backend.as_mut(),
                views: &mut eng.views,
                fast_sim: eng.fast_sim,
                round_batching: eng.round_batching,
            };
            // Embedding: the first lane overall pays the input-share +
            // Π_PPLN rounds, every other lane's independent payload rides
            // the same flights.
            let mut x_pis: Vec<Vec<_>> = Vec::with_capacity(work.len());
            let mut first = true;
            for (wi, (idx, tokens)) in work.iter().enumerate() {
                let s = &self.sessions[*idx];
                let b0 = ctx.mpc.net.ledger.bytes_total();
                let mut xs = Vec::with_capacity(tokens.len());
                for (j, &t) in tokens.iter().enumerate() {
                    xs.push(embedding::pp_embedding_at_lane(
                        &mut ctx,
                        &eng.pm,
                        t,
                        s.pos + j,
                        first,
                        &s.prefix,
                    )?);
                    first = false;
                }
                lane_bytes[wi] += ctx.mpc.net.ledger.bytes_total() - b0;
                x_pis.push(xs);
            }
            // Build the protocol lane groups: each borrows its session's
            // KV caches and census prefix, disjoint across sessions.
            let mut groups: Vec<StepLaneGroup> = Vec::with_capacity(work.len());
            {
                let mut x_it = x_pis.into_iter();
                let mut wi = 0;
                for (i, s) in self.sessions.iter_mut().enumerate() {
                    if wi < work.len() && work[wi].0 == i {
                        wi += 1;
                        let xs = x_it.next().expect("one x set per group");
                        let pos0 = s.pos;
                        groups.push(StepLaneGroup {
                            kv: &mut s.kv,
                            prefix: &s.prefix,
                            lanes: xs
                                .into_iter()
                                .enumerate()
                                .map(|(j, x_pi)| SpecLane { x_pi, pos: pos0 + j, bytes: 0 })
                                .collect(),
                        });
                    }
                }
            }
            anyhow::ensure!(groups.len() == work.len(), "lane work list must be ascending");
            let last = eng.pm.layers.len() - 1;
            for (i, pl) in eng.pm.layers[..last].iter().enumerate() {
                layer::transformer_layer_step_batch(
                    &mut ctx,
                    &eng.cfg,
                    pl,
                    &eng.pi1_sh,
                    &eng.pi1_t_sh,
                    &mut groups,
                    i,
                    None,
                )?;
            }
            let h_pis = layer::transformer_layer_step_batch(
                &mut ctx,
                &eng.cfg,
                &eng.pm.layers[last],
                &eng.pi1_sh,
                &eng.pi1_t_sh,
                &mut groups,
                last,
                Some((
                    eng.pm.final_ln_g.as_deref().expect("gpt weights"),
                    eng.pm.final_ln_b.as_deref().expect("gpt weights"),
                )),
            )?
            .expect("final tail returns the final-LN shares");
            // Communication-free LM head per lane, then the logit
            // returns: the first lane overall pays the single Adaptation
            // round, every lane's payload pair ships in that flight.
            let mut logits = Vec::with_capacity(work.len());
            let mut first = true;
            for (wi, group_h) in h_pis.iter().enumerate() {
                let b0 = ctx.mpc.net.ledger.bytes_total();
                let mut outs = Vec::with_capacity(group_h.len());
                for h_pi in group_h {
                    let logits_sh = adaptation::pp_lm_head_gpt2(&mut ctx, &eng.pm, h_pi)?;
                    outs.push(if first {
                        adaptation::return_to_client(ctx.mpc, &logits_sh)?
                    } else {
                        adaptation::return_to_client_unrounded(ctx.mpc, &logits_sh)?
                    });
                    first = false;
                }
                lane_bytes[wi] += ctx.mpc.net.ledger.bytes_total() - b0;
                logits.push(outs);
            }
            for (wi, g) in groups.iter().enumerate() {
                lane_bytes[wi] += g.lanes.iter().map(|l| l.bytes).sum::<u64>();
            }
            logits
        };
        let step = eng.mpc.net.ledger.clone();
        let step_rounds = step.rounds_total();
        // Step boundary: batch-verify this step's opening MACs, then
        // commit the shared step (all lanes ride one flight schedule, so
        // the batch transcript commits it once, tagged with lane count).
        eng.mpc.flush_mac_checks()?;
        let lanes: u32 = work.iter().map(|(_, t)| t.len() as u32).sum();
        self.transcript.commit_step(
            if decode_phase { StepPhase::Decode } else { StepPhase::Prefill },
            lanes,
            &step,
        );
        for ((idx, tokens), bytes) in work.iter().zip(&lane_bytes) {
            let s = &mut self.sessions[*idx];
            if decode_phase {
                s.decode_bytes += bytes;
                s.decode_rounds += step_rounds;
                s.decode_steps += 1;
            } else {
                s.prefill_bytes += bytes;
                s.prefill_rounds += step_rounds;
            }
            s.last_step_bytes = *bytes;
            s.last_step_rounds = step_rounds;
            s.pos += tokens.len();
        }
        Ok(logits)
    }
}
